"""Fig. 1: D-Adam training loss vs iterations for p in {1,2,4,8,16} —
the claim: every p converges to (almost) the same value as vanilla (p=1).
Synthetic-CTR DeepFM analogue (paper hyperparameters: eta=1e-3, ring,
8 workers, beta1=.9, beta2=.999)."""
from benchmarks.common import emit, train_ctr


def main(steps: int = 150) -> None:
    losses = {}
    for p in (1, 2, 4, 8, 16):
        out, us = train_ctr("d-adam", steps, period=p)
        losses[p] = out["log"].loss[-1]
        emit(f"fig1/d-adam_p{p}_final_loss", us, f"{losses[p]:.4f}")
    spread = max(losses.values()) - min(losses.values())
    emit("fig1/loss_spread_across_p", 0.0, f"{spread:.4f}")


if __name__ == "__main__":
    main()
