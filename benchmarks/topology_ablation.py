"""Remark 1 ablation: the spectral gap rho only affects higher-order terms
when p is moderate — but consensus error scales ~1/rho (Lemma 1).

We train the same DeepFM task over ring / exponential / fully-connected
topologies (rho: ring < exp < full = 1) and report final loss (should be
~equal — the leading 1/sqrt(KT) term dominates) and consensus error
(should order inversely with rho — Lemma 1's (1 + 4/rho^2) factor)."""
import jax

from benchmarks.common import TASK, emit, ctr_iter
from repro.core import make_optimizer
from repro.models.deepfm import deepfm_loss, init_deepfm
from repro.train import DecentralizedTrainer

K = 8


def main(steps: int = 120) -> None:
    results = {}
    for topo_name in ("ring", "exponential", "fully_connected"):
        opt = make_optimizer("d-adam", K=K, eta=1e-3, period=4,
                             topology=topo_name)
        trainer = DecentralizedTrainer(lambda p, b: deepfm_loss(p, b), opt)
        params = init_deepfm(jax.random.PRNGKey(0), TASK.n_features,
                             TASK.n_fields, hidden=(64, 64))
        state = trainer.init(params)
        state, log = trainer.fit(state, ctr_iter(), steps, log_every=steps)
        rho = opt.topo.spectral_gap
        results[topo_name] = (rho, log.loss[-1], log.consensus[-1])
        emit(f"topology/{topo_name}_rho", 0.0, f"{rho:.3f}")
        emit(f"topology/{topo_name}_final_loss", 0.0,
             f"{log.loss[-1]:.4f}")
        emit(f"topology/{topo_name}_consensus", 0.0,
             f"{log.consensus[-1]:.3e}")
    # Remark 1: leading-term losses match across rho at moderate p
    losses = [v[1] for v in results.values()]
    emit("topology/loss_spread_across_rho", 0.0,
         f"{max(losses) - min(losses):.4f}")
    # Lemma 1: better-connected graphs keep workers closer
    emit("topology/consensus_ring_over_full", 0.0,
         f"{results['ring'][2] / max(results['fully_connected'][2], 1e-12):.1f}x")


if __name__ == "__main__":
    main()
