"""Reference-vs-Pallas optimizer step latency + bytes-moved accounting.

Times one jitted optimizer step (the in-graph comm-skip cond included) for
``backend='reference'`` and ``backend='pallas'`` over a stacked synthetic
parameter pytree, for both D-Adam and CD-Adam, and emits:

* the usual CSV rows (``emit``), and
* one JSON record (line prefixed ``JSON``) with per-step latency for both
  backends plus the analytic HBM / wire byte counts.

On CPU the Pallas kernels execute in interpret mode, so the pallas column
is a CORRECTNESS path here, not a speed claim — the meaningful numbers on
this host are the reference-XLA latencies and the byte accounting; on TPU
the same dispatch compiles to Mosaic. Sizes are deliberately modest so
interpret mode finishes in seconds (``--size`` scales them up on real
hardware).
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import make_optimizer

LANE = 128


def make_params(key, K: int, size: int):
    """Ragged stacked pytree totalling ~``size`` elements per worker."""
    a = size // 2
    b = size // 3
    c = size - a - b
    ks = jax.random.split(key, 3)
    return {
        "w": jax.random.normal(ks[0], (K, max(1, a // LANE), LANE)),
        "u": jax.random.normal(ks[1], (K, b)),
        "b": jax.random.normal(ks[2], (K, c + 1)),  # non-lane-aligned tail
    }


def bench_kind(kind: str, K: int, size: int, period: int) -> dict:
    key = jax.random.PRNGKey(0)
    params = make_params(key, K, size)
    grads = jax.tree_util.tree_map(
        lambda x: 0.1 * x + 0.01, params)
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    rec: dict = {"kind": kind, "workers": K, "elements": int(n)}

    for backend in ("reference", "pallas"):
        opt = make_optimizer(kind, K=K, eta=1e-3, period=period,
                             backend=backend)
        state = opt.init(jax.tree_util.tree_map(jnp.copy, params))
        step = jax.jit(lambda s, g, opt=opt: opt.step(s, g))
        us = time_fn(step, state, grads, iters=3, warmup=1)
        rec[f"{backend}_us_per_step"] = round(us, 1)
        emit(f"fused_step/{kind}_{backend}", us,
             f"{n * 4 / (us / 1e6) / 1e9:.2f}GB/s param-touch")
        if kind == "cd-adam":
            rec["wire_bytes_per_round"] = opt.comm_bytes_per_round(
                opt.params_of(state))

    # analytic HBM traffic of the local Adam update, f32 elements:
    # unfused XLA ~11 round-trips (separate m/v/rsqrt/axpy passes) vs the
    # fused kernel's 4 reads + 3 writes.
    rec["adam_hbm_bytes_unfused"] = int(n * 4 * 11)
    rec["adam_hbm_bytes_fused"] = int(n * 4 * 7)
    return rec


def main(workers: int = 8, size: int = 1 << 16, period: int = 1) -> dict:
    record = {"benchmark": "fused_step",
              "records": [bench_kind(k, workers, size, period)
                          for k in ("d-adam", "cd-adam")]}
    print("JSON " + json.dumps(record))
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--size", type=int, default=1 << 16,
                    help="elements per worker (keep small on CPU: "
                         "interpret mode)")
    ap.add_argument("--period", type=int, default=1,
                    help="p=1 so the timed step includes communication")
    args = ap.parse_args()
    main(args.workers, args.size, args.period)
