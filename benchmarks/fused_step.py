"""Reference-vs-Pallas optimizer step latency + bytes-moved accounting.

Times one jitted optimizer step (the in-graph comm-skip cond included)
over a stacked synthetic parameter pytree, for both D-Adam and CD-Adam,
across four execution paths:

* ``reference``        — jnp tree_map update + roll gossip,
* ``pallas_resident``  — the packed-resident runtime: state stays in the
  (K, rows, 128) layout across steps, grads enter as a packed buffer,
  fused-Adam / gossip / sign-compress kernels run on resident buffers
  with zero per-step pack/unpack,
* ``pallas_axis``      — the same resident runtime with comm='axis': the
  packed buffer is sharded one worker per slot of a 'worker' mesh and the
  step runs per-shard inside shard_map with ppermute gossip — this is the
  per-worker wall clock the paper's linear-speedup claim is about (needs
  >= K devices; when invoked as __main__ on CPU the script forces enough
  host devices before jax initializes),
* ``pallas_axis2d``    — comm='axis' on the 2D (worker x model) mesh:
  each worker is an M-device model-parallel group holding (1, rows/M, 128)
  row shards of the packed state; gossip still crosses only the worker
  axis and CD-Adam's compression scales psum over 'model' (needs K * M
  devices), and
* ``pallas_repack``    — the PR-1 dispatch that re-packs the pytree state
  around the kernels every step (kept precisely to expose what residency
  saves).

Both sharded paths are additionally timed with ``overlap=True``
(``pallas_axis_overlap`` / ``pallas_axis2d_overlap``): the delay-1 wire
schedule that issues round r's gossip eagerly and folds it in at round
r+1, letting the ppermute hide behind the local Adam work. The record
pairs overlap-on vs overlap-off latency AND per-variant collective
accounting, so a regression that grows per-round bytes or reintroduces
an all-gather under overlap is visible per push.

Each timed loop threads the stepped state back in and calls
``jax.block_until_ready`` on it INSIDE the loop — without that, XLA's
async dispatch lets the cheap paths under-report by returning before the
step has executed. The JSON record carries per-step latency for all
paths, the analytic HBM / wire byte counts, per-variant collective
counts/bytes of the compiled step (``repro.analysis.hlo`` on the
partitioned HLO — the communication trajectory, incl. the 2D step's
all-gather count), and the jax version + platform the numbers were
measured on.

On CPU the Pallas kernels execute in interpret mode, so the pallas
columns are a CORRECTNESS path here, not a speed claim — the meaningful
numbers on this host are the reference-XLA latencies and the byte
accounting; on TPU the same dispatch compiles to Mosaic. Sizes are
deliberately modest so interpret mode finishes in seconds (``--size``
scales them up on real hardware).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __name__ == "__main__":
    # the pallas_axis path needs one device per worker (and pallas_axis2d
    # one per worker x model shard); opt into forced host devices BEFORE
    # jax initializes. repro.launch.env APPENDS to any pre-set XLA_FLAGS
    # (a caller-forced device count still wins) instead of the old
    # behavior of skipping the flag entirely whenever XLA_FLAGS was set.
    _workers, _mp = 8, 2

    def _argval(flag: str, default: int) -> int:
        val = default
        for _i, _a in enumerate(sys.argv):
            try:
                if _a.startswith(flag + "="):
                    val = int(_a.split("=", 1)[1])
                elif _a == flag and _i + 1 < len(sys.argv):
                    val = int(sys.argv[_i + 1])
            except ValueError:
                break  # malformed value: leave it to argparse's error
        return val

    _workers = _argval("--workers", _workers)
    _mp = _argval("--model-parallel", _mp)
    from repro.launch import env as _env
    _env.setup(_workers * max(_mp, 1))

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.analysis.hlo import collective_summary
from repro.core import cdadam, dadam, make_compressor, make_optimizer
from repro.kernels import pack as packing
from repro.launch.mesh import make_worker_mesh

LANE = 128


def compile_step(step_fn, state, grads):
    """AOT-compile the step ONCE for these exact (sharded) arguments; the
    compiled callable is both timed and mined for its collective summary
    — no second compile behind jit's back."""
    return jax.jit(step_fn).lower(state, grads).compile()


def step_collectives(compiled) -> dict:
    """Per-kind collective {count, bytes, max_bytes} of the compiled step
    (repro.analysis.hlo on the partitioned HLO text) — the bench record's
    communication column: the trajectory captures what crosses the wire,
    not just latency. In particular a regression that re-introduces a
    full-parameter all-gather into the 2D step shows up here per push."""
    return dict(collective_summary(compiled.as_text()))


def make_params(key, K: int, size: int):
    """Ragged stacked pytree totalling ~``size`` elements per worker."""
    a = size // 2
    b = size // 3
    c = size - a - b
    ks = jax.random.split(key, 3)
    return {
        "w": jax.random.normal(ks[0], (K, max(1, a // LANE), LANE)),
        "u": jax.random.normal(ks[1], (K, b)),
        "b": jax.random.normal(ks[2], (K, c + 1)),  # non-lane-aligned tail
    }


def time_stepped(step, state, grads, iters: int = 3, warmup: int = 1
                 ) -> float:
    """us per step, threading the stepped state through the loop and
    blocking on it inside the timed region."""
    s = state
    for _ in range(warmup):
        s = jax.block_until_ready(step(s, grads))
    s = state
    t0 = time.perf_counter()
    for _ in range(iters):
        s = jax.block_until_ready(step(s, grads))
    return (time.perf_counter() - t0) / iters * 1e6


def _repack_state_and_step(kind: str, opt, params):
    """The PR-1 pallas path: pytree state, pack/unpack around the kernels
    every step. Reconstructed from the raw NamedTuple states so the
    resident runtime (which `opt.init` now returns) can be compared
    against it."""
    cfg, topo = opt.cfg, opt.topo
    if kind == "d-adam":
        state = dadam.DAdamState(params, dadam.init_moments(params, cfg))
        return state, jax.jit(lambda s, g: dadam.step(s, g, topo, cfg))
    comp = make_compressor("sign")
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    hat_nbrs = tuple(jax.tree_util.tree_map(jnp.zeros_like, params)
                     for _ in topo.offsets)
    state = cdadam.CDAdamState(params, dadam.init_moments(params, cfg),
                               zeros, hat_nbrs)
    return state, jax.jit(lambda s, g: cdadam.step(s, g, topo, cfg, comp))


def bench_kind(kind: str, K: int, size: int, period: int,
               model_parallel: int = 2) -> dict:
    key = jax.random.PRNGKey(0)
    params = make_params(key, K, size)
    grads = jax.tree_util.tree_map(lambda x: 0.1 * x + 0.01, params)
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    rec: dict = {"kind": kind, "workers": K, "elements": int(n)}

    # reference backend: pytree state, jnp tree_map + roll gossip
    opt = make_optimizer(kind, K=K, eta=1e-3, period=period,
                         backend="reference")
    state = opt.init(jax.tree_util.tree_map(jnp.copy, params))
    ref_step = compile_step(lambda s, g: opt.step(s, g), state, grads)
    us = time_stepped(ref_step, state, grads)
    rec["reference_us_per_step"] = round(us, 1)
    rec["reference_collectives"] = step_collectives(ref_step)
    emit(f"fused_step/{kind}_reference", us,
         f"{n * 4 / (us / 1e6) / 1e9:.2f}GB/s param-touch")
    if kind == "cd-adam":
        rec["wire_bytes_per_round"] = opt.comm_bytes_per_round(
            opt.params_of(state))

    # pallas resident: packed state across steps, packed grads in
    popt = make_optimizer(kind, K=K, eta=1e-3, period=period,
                          backend="pallas")
    pstate = popt.init(jax.tree_util.tree_map(jnp.copy, params))
    gbuf = packing.pack(grads, pstate.spec, dtype=pstate.buf.dtype)
    res_step = compile_step(lambda s, g: popt.step(s, g), pstate, gbuf)
    us_res = time_stepped(res_step, pstate, gbuf)
    rec["pallas_resident_us_per_step"] = round(us_res, 1)
    rec["pallas_us_per_step"] = rec["pallas_resident_us_per_step"]
    rec["pallas_resident_collectives"] = step_collectives(res_step)
    emit(f"fused_step/{kind}_pallas_resident", us_res,
         f"{n * 4 / (us_res / 1e6) / 1e9:.2f}GB/s param-touch")

    # pallas axis: the SAME resident runtime, sharded one worker per slot
    # of a 'worker' mesh — per-worker wall clock instead of a stacked
    # simulation. Skipped (null) when the host has fewer devices than
    # workers.
    if jax.device_count() >= K:
        mesh = make_worker_mesh(K)
        aopt = make_optimizer(kind, K=K, eta=1e-3, period=period,
                              backend="pallas", comm="axis", mesh=mesh)
        astate = aopt.init(jax.tree_util.tree_map(jnp.copy, params))
        gbuf_axis = jax.device_put(gbuf, astate.buf.sharding)
        axis_step = compile_step(lambda s, g: aopt.step(s, g), astate,
                                 gbuf_axis)
        us_axis = time_stepped(axis_step, astate, gbuf_axis)
        rec["pallas_axis_us_per_step"] = round(us_axis, 1)
        rec["pallas_axis_collectives"] = step_collectives(axis_step)
        emit(f"fused_step/{kind}_pallas_axis", us_axis,
             f"{K}-device shard_map; "
             f"{n * 4 / (us_axis / 1e6) / 1e9:.2f}GB/s param-touch")

        # the same sharded step with overlap=True: round r's gossip is
        # issued eagerly and folded in at r+1, so the ppermute can ride
        # the async-collective stream behind the local Adam work. Same
        # mesh, same grads — the record pairs overlap-on vs overlap-off
        # latency AND wire accounting (bytes per round must not grow).
        oopt = make_optimizer(kind, K=K, eta=1e-3, period=period,
                              backend="pallas", comm="axis", mesh=mesh,
                              overlap=True)
        ostate = oopt.init(jax.tree_util.tree_map(jnp.copy, params))
        gbuf_ov = jax.device_put(gbuf, ostate.buf.sharding)
        ov_step = compile_step(lambda s, g: oopt.step(s, g), ostate,
                               gbuf_ov)
        us_ov = time_stepped(ov_step, ostate, gbuf_ov)
        rec["pallas_axis_overlap_us_per_step"] = round(us_ov, 1)
        rec["pallas_axis_overlap_collectives"] = step_collectives(ov_step)
        emit(f"fused_step/{kind}_pallas_axis_overlap", us_ov,
             f"{K}-device shard_map, delay-1 wire; "
             f"{us_axis / max(us_ov, 1e-9):.2f}x vs eager")
    else:
        rec["pallas_axis_us_per_step"] = None
        rec["pallas_axis_collectives"] = None
        rec["pallas_axis_skipped"] = (
            f"needs {K} devices, have {jax.device_count()}")
        rec["pallas_axis_overlap_us_per_step"] = None
        rec["pallas_axis_overlap_collectives"] = None
        rec["pallas_axis_overlap_skipped"] = rec["pallas_axis_skipped"]

    # pallas axis 2D: the (worker x model) mesh — each worker an M-device
    # model-parallel group over row shards of the packed state. The grads
    # are packed against the 2D state's own row-sharded spec.
    M = model_parallel
    if M > 1 and jax.device_count() >= K * M:
        mesh2 = make_worker_mesh(K, model_parallel=M)
        aopt2 = make_optimizer(kind, K=K, eta=1e-3, period=period,
                               backend="pallas", comm="axis", mesh=mesh2)
        astate2 = aopt2.init(jax.tree_util.tree_map(jnp.copy, params))
        gbuf2 = packing.pack(grads, astate2.spec, dtype=astate2.buf.dtype)
        gbuf2 = jax.device_put(gbuf2, astate2.buf.sharding)
        axis2d_step = compile_step(lambda s, g: aopt2.step(s, g), astate2,
                                   gbuf2)
        us_2d = time_stepped(axis2d_step, astate2, gbuf2)
        rec["pallas_axis2d_us_per_step"] = round(us_2d, 1)
        # the 2D regression instrument: all-gather count/max_bytes of the
        # compiled step must stay at zero / below full-parameter size
        rec["pallas_axis2d_collectives"] = step_collectives(axis2d_step)
        emit(f"fused_step/{kind}_pallas_axis2d", us_2d,
             f"{K}x{M}-device shard_map; "
             f"{n * 4 / (us_2d / 1e6) / 1e9:.2f}GB/s param-touch")

        # 2D overlap: delay rings are (K, T, rows/M, 128) row shards, so
        # the eager schedule must keep gossip on 'worker' only — the
        # collectives column here is the invariant the CI summary reads.
        oopt2 = make_optimizer(kind, K=K, eta=1e-3, period=period,
                               backend="pallas", comm="axis", mesh=mesh2,
                               overlap=True)
        ostate2 = oopt2.init(jax.tree_util.tree_map(jnp.copy, params))
        gbuf2_ov = jax.device_put(
            packing.pack(grads, ostate2.spec, dtype=ostate2.buf.dtype),
            ostate2.buf.sharding)
        ov2_step = compile_step(lambda s, g: oopt2.step(s, g), ostate2,
                                gbuf2_ov)
        us_2d_ov = time_stepped(ov2_step, ostate2, gbuf2_ov)
        rec["pallas_axis2d_overlap_us_per_step"] = round(us_2d_ov, 1)
        rec["pallas_axis2d_overlap_collectives"] = step_collectives(
            ov2_step)
        emit(f"fused_step/{kind}_pallas_axis2d_overlap", us_2d_ov,
             f"{K}x{M}-device shard_map, delay-1 wire; "
             f"{us_2d / max(us_2d_ov, 1e-9):.2f}x vs eager")
    else:
        rec["pallas_axis2d_us_per_step"] = None
        rec["pallas_axis2d_collectives"] = None
        rec["pallas_axis2d_skipped"] = (
            "disabled (--model-parallel <= 1)" if M <= 1 else
            f"needs {K * M} devices (model_parallel={M}), "
            f"have {jax.device_count()}")
        rec["pallas_axis2d_overlap_us_per_step"] = None
        rec["pallas_axis2d_overlap_collectives"] = None
        rec["pallas_axis2d_overlap_skipped"] = rec["pallas_axis2d_skipped"]

    # pallas repack: the pre-residency dispatch, pack/unpack every step
    rstate, rstep = _repack_state_and_step(kind, popt, params)
    us_rep = time_stepped(rstep, rstate, grads)
    rec["pallas_repack_us_per_step"] = round(us_rep, 1)
    rec["resident_speedup_vs_repack"] = round(us_rep / max(us_res, 1e-9), 2)
    emit(f"fused_step/{kind}_pallas_repack", us_rep,
         f"resident {rec['resident_speedup_vs_repack']}x vs repack")

    # analytic HBM traffic of the local Adam update, f32 elements:
    # unfused XLA ~11 round-trips (separate m/v/rsqrt/axpy passes); the
    # fused kernel on resident buffers is 4 reads + 3 writes; the repack
    # dispatch adds a read+write per packed operand (4 packs + 3 unpacks).
    rec["adam_hbm_bytes_unfused"] = int(n * 4 * 11)
    rec["adam_hbm_bytes_fused_resident"] = int(n * 4 * 7)
    rec["adam_hbm_bytes_fused_repack"] = int(n * 4 * (7 + 4 * 2 + 3 * 2))
    rec["adam_hbm_bytes_fused"] = rec["adam_hbm_bytes_fused_resident"]
    return rec


def main(workers: int = 8, size: int = 1 << 16, period: int = 1,
         out: str = "", model_parallel: int = 2) -> dict:
    record = {"benchmark": "fused_step",
              "jax_version": jax.__version__,
              "platform": jax.default_backend(),
              "device_count": jax.device_count(),
              "model_parallel": model_parallel,
              "records": [bench_kind(k, workers, size, period,
                                     model_parallel)
                          for k in ("d-adam", "cd-adam")]}
    print("JSON " + json.dumps(record))
    if out:
        with open(out, "w") as f:
            json.dump(record, f, indent=2)
        print(f"wrote {out}")
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--size", type=int, default=1 << 16,
                    help="elements per worker (keep small on CPU: "
                         "interpret mode)")
    ap.add_argument("--period", type=int, default=1,
                    help="p=1 so the timed step includes communication")
    ap.add_argument("--model-parallel", type=int, default=2,
                    help="inner model-parallel group size M for the "
                         "pallas_axis2d path (needs workers * M devices; "
                         "0/1 disables the 2D timing)")
    ap.add_argument("--out", default="",
                    help="also write the JSON record to this path "
                         "(CI uploads it as the bench-smoke artifact)")
    args = ap.parse_args()
    main(args.workers, args.size, args.period, args.out,
         args.model_parallel)
