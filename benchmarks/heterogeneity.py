"""Theorem 1's sigma^2 term: under data heterogeneity the CONSENSUS error
(Lemma 1's quantity) grows with the non-IID skew of the worker shards —
normalized adaptive updates pull workers toward different local optima
between gossip rounds. Consensus is the theory-aligned metric here; the
per-worker train LOSS is not comparable across skews (skewed local shards
are locally easier) and is reported only for completeness."""
import jax

from benchmarks.common import TASK, emit
from repro.core import make_optimizer
from repro.data import ctr_batch_stacked
from repro.models.deepfm import deepfm_loss, init_deepfm
from repro.train import DecentralizedTrainer

K = 8


def run(skew: float, steps: int):
    opt = make_optimizer("d-adam", K=K, eta=1e-3, period=4)
    trainer = DecentralizedTrainer(lambda p, b: deepfm_loss(p, b), opt)
    params = init_deepfm(jax.random.PRNGKey(0), TASK.n_features,
                         TASK.n_fields, hidden=(64, 64))
    state = trainer.init(params)

    def it():
        key = jax.random.PRNGKey(5)
        t = 0
        while True:
            yield ctr_batch_stacked(TASK, jax.random.fold_in(key, t), K, 32,
                                    skew=skew)
            t += 1

    state, log = trainer.fit(state, it(), steps, log_every=steps)
    return log.loss[-1], log.consensus[-1]


def main(steps: int = 120) -> None:
    for skew in (0.0, 0.5, 0.9):
        loss, cons = run(skew, steps)
        emit(f"heterogeneity/skew{skew:g}_loss", 0.0, f"{loss:.4f}")
        emit(f"heterogeneity/skew{skew:g}_consensus", 0.0, f"{cons:.3e}")


if __name__ == "__main__":
    main()
