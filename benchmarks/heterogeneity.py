"""Theorem 1's sigma^2 term under system heterogeneity, not just data
heterogeneity.

Four scenarios, one JSON record:

* ``skew``      — the original data-heterogeneity sweep: CONSENSUS error
  (Lemma 1's quantity) grows with the non-IID skew of the worker shards —
  normalized adaptive updates pull workers toward different local optima
  between gossip rounds. Consensus is the theory-aligned metric; the
  per-worker train LOSS is not comparable across skews (skewed local
  shards are locally easier) and is reported only for completeness.
* ``straggler`` — system heterogeneity: the same run with straggling
  edges (payloads up to ``tau`` rounds stale consumed instead of blocking
  the round). Pins that bounded staleness degrades consensus boundedly
  rather than diverging.
* ``schedule``  — time-varying topologies: one-peer-exponential vs the
  static ring at equal worker count; the schedule touches every peer
  within log2(K) rounds with 1-peer-per-round wire cost.
* ``churn``     — elastic membership: shrink K -> K-2 mid-run, grow back
  to K, training continuing through both resizes (one recompile each).
  Pins that loss keeps improving and consensus stays finite across
  membership changes.

Emits the usual CSV rows for the human-readable trajectory plus one
``JSON {...}`` stdout line and an optional ``--out`` artifact for CI.
"""
from __future__ import annotations

import argparse
import json

if __name__ == "__main__":
    # K=8 workers; force matching host devices BEFORE jax initializes,
    # appending to (never clobbering) a pre-set XLA_FLAGS
    from repro.launch import env as _env
    _env.setup(8)

import jax

from benchmarks.common import TASK, emit
from repro.core import make_optimizer
from repro.data import ctr_batch_stacked
from repro.models.deepfm import deepfm_loss, init_deepfm
from repro.train import DecentralizedTrainer

K = 8


def ctr_iter(K: int, skew: float, seed: int = 5, batch: int = 32):
    key = jax.random.PRNGKey(seed)
    t = 0
    while True:
        yield ctr_batch_stacked(TASK, jax.random.fold_in(key, t), K, batch,
                                skew=skew)
        t += 1


def make_trainer(K: int, **opt_kw):
    opt = make_optimizer("d-adam", K=K, eta=1e-3, period=4, **opt_kw)
    trainer = DecentralizedTrainer(lambda p, b: deepfm_loss(p, b), opt)
    params = init_deepfm(jax.random.PRNGKey(0), TASK.n_features,
                         TASK.n_fields, hidden=(64, 64))
    return trainer, trainer.init(params)


def run(steps: int, *, skew: float, K: int = K, **opt_kw):
    trainer, state = make_trainer(K, **opt_kw)
    state, log = trainer.fit(state, ctr_iter(K, skew), steps,
                             log_every=steps)
    return log.loss[-1], log.consensus[-1]


def run_churn(steps: int, *, skew: float = 0.5):
    """K -> K-2 -> K with training in between; one recompile per resize."""
    third = max(steps // 3, 1)
    trainer, state = make_trainer(K)
    state, log = trainer.fit(state, ctr_iter(K, skew), third,
                             log_every=third)
    loss_before = log.loss[-1]

    opt_small = make_optimizer("d-adam", K=K - 2, eta=1e-3, period=4)
    state = trainer.resize(state, opt_small)
    state, log = trainer.fit(state, ctr_iter(K - 2, skew, seed=6), third,
                             log_every=third)
    compiles_small = trainer._step._cache_size()

    opt_back = make_optimizer("d-adam", K=K, eta=1e-3, period=4)
    state = trainer.resize(state, opt_back, strategy="mean")
    state, log = trainer.fit(state, ctr_iter(K, skew, seed=7),
                             steps - 2 * third, log_every=max(
                                 steps - 2 * third, 1), log=log)
    return {
        "loss_before": loss_before,
        "loss_after": log.loss[-1],
        "consensus_after": log.consensus[-1],
        "compiles_per_membership": compiles_small,
    }


def main(steps: int = 120, out: str = "") -> dict:
    records = []

    for skew in (0.0, 0.5, 0.9):
        loss, cons = run(steps, skew=skew)
        emit(f"heterogeneity/skew{skew:g}_loss", 0.0, f"{loss:.4f}")
        emit(f"heterogeneity/skew{skew:g}_consensus", 0.0, f"{cons:.3e}")
        records.append({"scenario": "skew", "skew": skew,
                        "loss": float(loss), "consensus": float(cons)})

    for tau, rate in ((2, 0.3), (4, 0.5)):
        loss, cons = run(steps, skew=0.5, staleness=tau,
                         straggler_rate=rate, straggler_seed=1)
        emit(f"heterogeneity/straggler_tau{tau}_rate{rate:g}_consensus",
             0.0, f"{cons:.3e}")
        records.append({"scenario": "straggler", "staleness": tau,
                        "straggler_rate": rate, "loss": float(loss),
                        "consensus": float(cons)})

    for topo in ("ring", "one-peer-exponential"):
        loss, cons = run(steps, skew=0.5, topology=topo)
        emit(f"heterogeneity/schedule_{topo}_consensus", 0.0, f"{cons:.3e}")
        records.append({"scenario": "schedule", "topology": topo,
                        "loss": float(loss), "consensus": float(cons)})

    churn = run_churn(steps)
    emit("heterogeneity/churn_loss_after", 0.0,
         f"{churn['loss_after']:.4f}")
    emit("heterogeneity/churn_compiles_per_membership", 0.0,
         f"{churn['compiles_per_membership']}")
    records.append({"scenario": "churn", **{
        k: (float(v) if isinstance(v, float) else v)
        for k, v in churn.items()}})

    record = {
        "benchmark": "heterogeneity",
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
        "workers": K,
        "steps": steps,
        "records": records,
    }
    print("JSON " + json.dumps(record), flush=True)
    if out:
        with open(out, "w") as f:
            json.dump(record, f, indent=2)
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    main(steps=args.steps, out=args.out)
