"""Kernel microbenchmarks.

On CPU the Pallas kernels run in interpret mode (not representative of TPU
wall time), so we report BOTH: interpret-mode correctness deltas vs ref, and
the XLA-path timings that ARE meaningful on this host (fused-vs-unfused
Adam, chunked-vs-naive attention) as the derived column."""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels import ops, ref
from repro.models.attention import flash_attention_xla, sdpa


def main() -> None:
    key = jax.random.PRNGKey(0)

    # fused adam: XLA-jitted ref (fused by XLA on CPU too) as baseline
    n = 1 << 20
    p = jax.random.normal(key, (n,))
    g = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    m = jnp.zeros(n)
    v = jnp.zeros(n)
    ref_fn = jax.jit(lambda p, g, m, v: ref.fused_adam_ref(
        p, g, m, v, eta=1e-3, beta1=0.9, beta2=0.999, tau=1e-6))
    us = time_fn(ref_fn, p, g, m, v)
    emit("kernels/adam_ref_1M", us, f"{n * 4 * 7 / (us / 1e6) / 1e9:.1f}GB/s")
    po, _, _ = ops.fused_adam(p[:8192], g[:8192], m[:8192], v[:8192],
                              eta=1e-3)
    pr, _, _ = ref.fused_adam_ref(p[:8192], g[:8192], m[:8192], v[:8192],
                                  eta=1e-3, beta1=0.9, beta2=0.999, tau=1e-6)
    emit("kernels/fused_adam_interpret_maxerr", 0.0,
         f"{float(jnp.max(jnp.abs(po - pr))):.2e}")

    # sign compress
    x = jax.random.normal(key, (1 << 18,))
    hat = jnp.zeros_like(x)
    ref_fn = jax.jit(lambda x, h: ref.sign_compress_ref(x, h))
    us = time_fn(ref_fn, x, hat)
    emit("kernels/sign_compress_ref_256k", us, "int8+scale wire")
    q, s, hn = ops.sign_compress(x[:8192], hat[:8192])
    qr, sr, hnr = ref.sign_compress_ref(x[:8192], hat[:8192])
    emit("kernels/sign_compress_interpret_maxerr", 0.0,
         f"{float(jnp.max(jnp.abs(hn - hnr))):.2e}")

    # attention: chunked (flash-in-XLA) vs naive on a 2k sequence
    B, S, Hq, Hk, D = 1, 2048, 8, 2, 64
    q_ = jax.random.normal(key, (B, S, Hq, D), jnp.float32)
    k_ = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hk, D))
    v_ = jax.random.normal(jax.random.fold_in(key, 3), (B, S, Hk, D))
    naive = jax.jit(lambda q, k, v: sdpa(q, k, v, causal=True, impl="naive"))
    chunk = jax.jit(lambda q, k, v: flash_attention_xla(
        q, k, v, causal=True, chunk_q=512, chunk_kv=512))
    us_n = time_fn(naive, q_, k_, v_, iters=3)
    us_c = time_fn(chunk, q_, k_, v_, iters=3)
    emit("kernels/attn_naive_2k", us_n, "materializes S^2")
    emit("kernels/attn_chunked_2k", us_c,
         f"{us_n / us_c:.2f}x vs naive (CPU)")
    out_c = chunk(q_, k_, v_)
    out_n = naive(q_, k_, v_)
    emit("kernels/attn_chunked_maxerr", 0.0,
         f"{float(jnp.max(jnp.abs(out_c.reshape(out_n.shape) - out_n))):.2e}")

    # rwkv: pallas interpret vs lax.scan ref on a small shape
    B, S, H, Dh = 1, 256, 4, 64
    ks = [jax.random.fold_in(key, 10 + i) for i in range(5)]
    r_ = jax.random.normal(ks[0], (B, S, H, Dh)) * 0.3
    kk = jax.random.normal(ks[1], (B, S, H, Dh)) * 0.3
    vv = jax.random.normal(ks[2], (B, S, H, Dh)) * 0.3
    ww = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, Dh)))
    uu = jax.random.normal(ks[4], (H, Dh)) * 0.1
    s0 = jnp.zeros((B, H, Dh, Dh))
    scan_fn = jax.jit(lambda *a: ref.rwkv_scan_ref(*a))
    us = time_fn(scan_fn, r_, kk, vv, ww, uu, s0, iters=3)
    emit("kernels/wkv_scan_ref_256", us, "lax.scan per-step state HBM RT")
    y, sf = ops.rwkv_scan(r_, kk, vv, ww, uu, s0, chunk=64)
    yr, sfr = ref.rwkv_scan_ref(r_, kk, vv, ww, uu, s0)
    emit("kernels/wkv_interpret_maxerr", 0.0,
         f"{float(jnp.max(jnp.abs(y - yr))):.2e}")


if __name__ == "__main__":
    main()
