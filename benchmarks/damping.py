"""Fixed-batch vs adaptively damped batch: steps and gradient evaluations
to a target loss.

The damping claim (ROADMAP 'adaptive batch damping', AdaDamp/PadaDamp/
GeoDamp style): growing the effective batch as the loss falls reaches the
same loss in FEWER gradient evaluations than training at the final batch
size from step 0 — early steps don't need the variance reduction they
would be paying for. The gradient-evaluation count is the serverless
billing unit (SMLT's resource-scaling argument), tracked exactly by
``TrainLog.grad_evals``.

Two tasks, one JSON record:

* ``ctr`` — DeepFM on the synthetic CTR task (the paper's main workload),
  non-IID worker shards, per-worker damping signals.
* ``lm``  — the reduced llama3.2-1b config on synthetic LM batches
  (registry smoke size), global damping signal.

Per task, the FIXED baseline runs ``microbatch=max_chunks`` (all chunks
live every step — bitwise the damped pipeline at its ceiling) and sets
the target loss; the DAMPED run (AdaDamp) gets a 3x step budget to reach
it and reports ``steps_to_target`` / ``grad_evals_to_target``. The
damped trainer is armed with ``recompile_limit=1``: every damping level
must reuse ONE compiled step (the record's ``compiles`` field pins it).

Emits the usual CSV rows plus one ``JSON {...}`` stdout line and an
optional ``--out`` artifact for CI (schema pinned by
``tests/test_bench_smoke.py`` and the committed ``BENCH_<pr>.json``).
"""
from __future__ import annotations

import argparse
import json

if __name__ == "__main__":
    # K=4 workers; force matching host devices BEFORE jax initializes,
    # appending to (never clobbering) a pre-set XLA_FLAGS
    from repro.launch import env as _env
    _env.setup(4)

import jax
import jax.numpy as jnp

from benchmarks.common import TASK, emit
from repro.core import make_optimizer
from repro.data import ctr_batch_stacked, lm_batch
from repro.models.deepfm import deepfm_loss, init_deepfm
from repro.train import DampingConfig, DecentralizedTrainer

K = 4
CTR_CHUNKS = 8     # per-worker batch 32 -> chunks of 4 samples
LM_CHUNKS = 4      # per-worker batch 8  -> chunks of 2 sequences


def ctr_iter(seed: int = 11, batch: int = 32, skew: float = 0.5):
    key = jax.random.PRNGKey(seed)
    t = 0
    while True:
        yield ctr_batch_stacked(TASK, jax.random.fold_in(key, t), K, batch,
                                skew=skew)
        t += 1


def make_ctr_trainer(damping: "DampingConfig | None", **trainer_kw):
    opt = make_optimizer("d-adam", K=K, eta=1e-3, period=4)
    trainer = DecentralizedTrainer(lambda p, b: deepfm_loss(p, b), opt,
                                   damping=damping, **trainer_kw)
    params = init_deepfm(jax.random.PRNGKey(0), TASK.n_features,
                         TASK.n_fields, hidden=(64, 64))
    return trainer, trainer.init(params)


def lm_setup():
    from repro.configs import get_reduced
    from repro.models import build_model

    arch = get_reduced("llama3.2-1b")
    cfg = arch.model
    api = build_model(cfg)

    def it(seed: int = 13, batch: int = 8, seq: int = 16):
        key = jax.random.PRNGKey(seed)
        t = 0
        while True:
            kt = jax.random.fold_in(key, t)
            yield {"tokens": jnp.stack([
                lm_batch(kt, batch, seq, cfg.vocab_size, k, K, 0.5)
                for k in range(K)])}
            t += 1

    def make_trainer(damping, **trainer_kw):
        opt = make_optimizer("d-adam", K=K, eta=1e-3, period=4)
        trainer = DecentralizedTrainer(lambda p, b: api.loss(p, b), opt,
                                       damping=damping, **trainer_kw)
        return trainer, trainer.init(api.init(jax.random.PRNGKey(0)))

    return it, make_trainer


def run_to_target(trainer, state, it, target: float, max_steps: int):
    """Step until the logged loss reaches ``target`` (or the budget runs
    out), CONTINUING one TrainLog across 1-step fit windows — the
    streaming use of the cumulative log counters."""
    log = None
    for _ in range(max_steps):
        state, log = trainer.fit(state, it, 1, log_every=1, log=log)
        if log.loss[-1] <= target:
            break
    return state, log


def run_task(task: str, make_trainer, make_iter, max_chunks: int,
             steps: int, per_worker: bool) -> dict:
    # fixed baseline: every chunk live from step 0 (the damped pipeline
    # at its ceiling), sets the target
    trainer, state = make_trainer(None, microbatch=max_chunks)
    state, log = trainer.fit(state, make_iter(), steps, log_every=1)
    target = float(min(log.loss))
    fixed = {"steps": int(log.steps_total),
             "grad_evals": int(log.grad_evals_total),
             "final_loss": float(log.loss[-1])}

    damping = DampingConfig(policy="adadamp", max_chunks=max_chunks,
                            ema=0.7, per_worker=per_worker)
    dtrainer, dstate = make_trainer(damping, recompile_limit=1)
    dstate, dlog = run_to_target(dtrainer, dstate, make_iter(), target,
                                 max_steps=3 * steps)
    reached = bool(dlog.loss[-1] <= target)
    damped = {"steps": int(dlog.steps_total),
              "grad_evals": int(dlog.grad_evals_total),
              "final_loss": float(dlog.loss[-1]),
              "reached": reached,
              "compiles": int(dtrainer._step._cache_size())}
    emit(f"damping/{task}_target_loss", 0.0, f"{target:.4f}")
    emit(f"damping/{task}_fixed_grad_evals", 0.0, fixed["grad_evals"])
    emit(f"damping/{task}_damped_grad_evals", 0.0, damped["grad_evals"])
    emit(f"damping/{task}_damped_compiles", 0.0, damped["compiles"])
    return {"task": task, "policy": "adadamp", "max_chunks": max_chunks,
            "per_worker": per_worker, "target_loss": target,
            "fixed": fixed, "damped": damped}


def main(steps: int = 60, lm_steps: int = 30, out: str = "") -> dict:
    records = [run_task("ctr", make_ctr_trainer, ctr_iter, CTR_CHUNKS,
                        steps, per_worker=True)]
    lm_iter, make_lm_trainer = lm_setup()
    records.append(run_task("lm", make_lm_trainer, lm_iter, LM_CHUNKS,
                            lm_steps, per_worker=False))

    record = {
        "benchmark": "damping",
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
        "workers": K,
        "steps": steps,
        "records": records,
    }
    print("JSON " + json.dumps(record), flush=True)
    if out:
        with open(out, "w") as f:
            json.dump(record, f, indent=2)
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--lm-steps", type=int, default=30)
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    main(steps=args.steps, lm_steps=args.lm_steps, out=args.out)
