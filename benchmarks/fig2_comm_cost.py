"""Fig. 2 / Fig. 5: test metric (AUC) vs communication cost (MB).
Claim: larger p reaches the same AUC at ~1/p the bytes."""
from benchmarks.common import emit, train_ctr


def main(steps: int = 150) -> None:
    base_mb = None
    for p in (1, 4, 16):
        out, us = train_ctr("d-adam", steps, period=p)
        mb = out["log"].comm_mb[-1]
        if base_mb is None:
            base_mb = mb
        emit(f"fig2/d-adam_p{p}_auc", us, f"{out['auc']:.4f}")
        emit(f"fig2/d-adam_p{p}_comm_mb", us, f"{mb:.2f}")
    emit("fig2/comm_reduction_p16_vs_p1", 0.0,
         f"{base_mb / max(mb, 1e-9):.1f}x")


if __name__ == "__main__":
    main()
