"""Online-serving benchmark: bucketed batch decode + lock-free hot-swap.

Three claims of the online train->serve design, one JSON record:

* **batching wins** — decode QPS (requests/s) through the (8, P) bucket
  must beat the (1, P) bucket: the batched step amortizes the weight
  reads the paper's serverless replicas would otherwise each pay alone.
* **swap is non-blocking** — per-call decode latency p99 while a trainer
  publishes packed-state snapshots between calls must stay within 1.5x
  the steady-state p99: the ParamStore pointer swap never stalls an
  in-flight request.
* **publish is unpack-once** — the HBM bytes a publish reads from the
  packed-resident buffer (one ``(rows, 128)`` row block, or the K-row
  mean) versus the full K-way unpack it replaces, from the same
  accounting ``serve.publish.publish_hbm_bytes`` reports at runtime.

Plus the serve-path invariant: the compiled single-token decode step
contains ZERO collectives (``analysis.check.serve_decode_report``).

Emits the usual CSV rows plus one ``JSON {...}`` stdout line and an
optional ``--out`` artifact for CI (schema pinned by
``tests/test_bench_smoke.py`` and the committed ``BENCH_<pr>.json``).
"""
from __future__ import annotations

import argparse
import json
import time

if __name__ == "__main__":
    from repro.launch import env as _env
    _env.setup()

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.analysis.check import serve_decode_report
from repro.configs import get_reduced
from repro.core import make_optimizer
from repro.data import lm_batch
from repro.models import build_model
from repro.serve import DecodeEngine, ParamStore, publish_from_state, \
    publish_hbm_bytes
from repro.train import DecentralizedTrainer

K_TRAIN = 2  # packed trainer workers behind the swap phase


def _pct(xs, q):
    return float(np.percentile(np.asarray(xs), q))


def _decode_phase(engine, tokens, n_new, calls, *, on_call=None):
    """Per-call wall times for ``calls`` generate_batch rounds; ``on_call``
    (e.g. a publish) runs between timed calls, timed separately."""
    times, extra = [], []
    for i in range(calls):
        if on_call is not None:
            t0 = time.perf_counter()
            on_call(i)
            extra.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        out = engine.generate_batch(tokens, n_new)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return times, extra


def main(arch: str = "llama3.2-1b", prompt_len: int = 16,
         new_tokens: int = 8, calls: int = 12, train_steps: int = 2,
         out: str = "") -> dict:
    cfg = get_reduced(arch).model
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))

    # the trainer that feeds the swap phase: packed-resident D-Adam over
    # the SAME LM params, so a publish exercises the unpack-once path
    opt = make_optimizer("d-adam", K=K_TRAIN, eta=1e-4, period=2,
                         backend="pallas")
    trainer = DecentralizedTrainer(lambda p, b: api.loss(p, b), opt)
    state = trainer.init(params)

    def lm_iter(seed: int = 3, batch: int = 2):
        key = jax.random.PRNGKey(seed)
        t = 0
        while True:
            kt = jax.random.fold_in(key, t)
            yield {"tokens": jnp.stack([
                lm_batch(kt, batch, prompt_len, cfg.vocab_size, k,
                         K_TRAIN, 0.5) for k in range(K_TRAIN)])}
            t += 1

    if train_steps:
        state, _ = trainer.fit(state, lm_iter(), train_steps,
                               log_every=train_steps)

    store = ParamStore()
    publish_from_state(store, state, mode="mean")
    buckets = ((1, prompt_len), (8, prompt_len))
    engine = DecodeEngine(cfg, store, buckets=buckets,
                          max_new_tokens=new_tokens)
    key = jax.random.PRNGKey(1)
    toks1 = jax.random.randint(key, (1, prompt_len), 0, cfg.vocab_size)
    toks8 = jax.random.randint(key, (8, prompt_len), 0, cfg.vocab_size)

    # warm both buckets (compile once each), then measure
    for toks in (toks1, toks8):
        jax.block_until_ready(engine.generate_batch(toks, new_tokens))

    t_single, _ = _decode_phase(engine, toks1, new_tokens, calls)
    t_batched, _ = _decode_phase(engine, toks8, new_tokens, calls)
    single_qps = calls / sum(t_single)
    batched_qps = 8 * calls / sum(t_batched)

    # swap phase: a publish from the live packed state between every call
    t_swap, t_publish = _decode_phase(
        engine, toks8, new_tokens, calls,
        on_call=lambda i: publish_from_state(store, state, mode="mean"))
    p99_steady = _pct(t_batched, 99)
    p99_swap = _pct(t_swap, 99)
    swap_ratio = p99_swap / p99_steady

    hbm = {"worker": publish_hbm_bytes(state, mode="worker"),
           "mean": publish_hbm_bytes(state, mode="mean")}
    collectives = serve_decode_report(arch)

    emit("serving/single_qps", sum(t_single) / calls * 1e6,
         f"{single_qps:.2f}")
    emit("serving/batched_qps", sum(t_batched) / calls * 1e6,
         f"{batched_qps:.2f}")
    emit("serving/p99_swap_over_steady", 0.0, f"{swap_ratio:.3f}")
    emit("serving/publish_p50_ms", 0.0,
         f"{_pct(t_publish, 50) * 1e3:.2f}")
    emit("serving/decode_collectives_ok", 0.0, collectives.ok)

    record = {
        "benchmark": "serving",
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
        "arch": arch,
        "buckets": [list(b) for b in engine.buckets],
        "prompt_len": prompt_len,
        "new_tokens": new_tokens,
        "calls": calls,
        "compile_counts": engine.compile_counts,
        "served_version": engine.last_version,
        "single": {"qps": single_qps,
                   "p50_s": _pct(t_single, 50),
                   "p99_s": _pct(t_single, 99)},
        "batched": {"qps": batched_qps,
                    "p50_s": _pct(t_batched, 50),
                    "p99_s": _pct(t_batched, 99)},
        "batched_over_single": bool(batched_qps > single_qps),
        "swap": {"p99_steady_s": p99_steady,
                 "p99_during_swap_s": p99_swap,
                 "ratio": swap_ratio,
                 "publish_p50_s": _pct(t_publish, 50),
                 "ratio_ok": bool(swap_ratio <= 1.5)},
        "publish_hbm_bytes": hbm,
        "decode_collectives_ok": bool(collectives.ok),
    }
    print("JSON " + json.dumps(record), flush=True)
    if out:
        with open(out, "w") as f:
            json.dump(record, f, indent=2)
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--calls", type=int, default=12)
    ap.add_argument("--train-steps", type=int, default=2)
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    main(arch=args.arch, prompt_len=args.prompt_len,
         new_tokens=args.new_tokens, calls=args.calls,
         train_steps=args.train_steps, out=args.out)
