"""Fig. 4 / Fig. 6: CD-Adam vs D-Adam test metric per communication MB.
Claim: with both skipping (p=16) AND sign compression, CD-Adam's bytes are
a small fraction of even D-Adam p=16, at matched AUC."""
from benchmarks.common import emit, train_ctr


def main(steps: int = 150) -> None:
    d16, us_d = train_ctr("d-adam", steps, period=16)
    c16, us_c = train_ctr("cd-adam", steps, period=16, gamma=0.4,
                          compressor="sign")
    emit("fig4/d-adam_p16_auc", us_d, f"{d16['auc']:.4f}")
    emit("fig4/d-adam_p16_comm_mb", us_d, f"{d16['log'].comm_mb[-1]:.3f}")
    emit("fig4/cd-adam_p16_auc", us_c, f"{c16['auc']:.4f}")
    emit("fig4/cd-adam_p16_comm_mb", us_c, f"{c16['log'].comm_mb[-1]:.3f}")
    ratio = d16["log"].comm_mb[-1] / max(c16["log"].comm_mb[-1], 1e-9)
    emit("fig4/bytes_reduction_cd_vs_d", 0.0, f"{ratio:.1f}x")


if __name__ == "__main__":
    main()
