"""Corollary 1: linear speedup in worker count K — with the global batch
fixed per-step, the gradient-norm/loss trajectory vs #samples-processed
improves ~linearly with K (O(1/sqrt(KT)) leading term)."""
import jax

from benchmarks.common import TASK, emit
from repro.core import make_optimizer
from repro.data import ctr_batch_stacked
from repro.models.deepfm import deepfm_loss, init_deepfm
from repro.train import DecentralizedTrainer


def run_k(K: int, steps: int, per_worker: int = 16):
    opt = make_optimizer("d-adam", K=K, eta=1e-3, topology="ring", period=4)
    trainer = DecentralizedTrainer(lambda p, b: deepfm_loss(p, b), opt)
    params = init_deepfm(jax.random.PRNGKey(0), TASK.n_features,
                         TASK.n_fields, hidden=(64, 64))
    state = trainer.init(params)

    def it():
        key = jax.random.PRNGKey(7)
        t = 0
        while True:
            yield ctr_batch_stacked(TASK, jax.random.fold_in(key, t), K,
                                    per_worker)
            t += 1

    state, log = trainer.fit(state, it(), steps, log_every=steps)
    return log.loss[-1]


def main(steps: int = 120) -> None:
    losses = {}
    for K in (1, 2, 4, 8):
        losses[K] = run_k(K, steps)
        emit(f"speedup/K{K}_final_loss_same_T", 0.0, f"{losses[K]:.4f}")
    # linear-speedup signature: more workers => lower loss at equal T
    emit("speedup/loss_K8_minus_K1", 0.0,
         f"{losses[8] - losses[1]:.4f}")


if __name__ == "__main__":
    main()
