"""Fig. 3: CD-Adam training loss vs iterations (sign compression,
gamma=0.4) — converges to ~the same value as full-precision vanilla."""
from benchmarks.common import emit, train_ctr


def main(steps: int = 150) -> None:
    ref, us_v = train_ctr("d-adam", steps, period=1)
    emit("fig3/d-adam-vanilla_final_loss", us_v,
         f"{ref['log'].loss[-1]:.4f}")
    for p in (2, 8):
        out, us = train_ctr("cd-adam", steps, period=p, gamma=0.4,
                            compressor="sign")
        emit(f"fig3/cd-adam_p{p}_final_loss", us,
             f"{out['log'].loss[-1]:.4f}")


if __name__ == "__main__":
    main()
