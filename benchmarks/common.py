"""Shared benchmark harness.

Every benchmark emits CSV rows ``name,us_per_call,derived`` (the derived
column carries the figure-specific quantity: final loss, AUC, comm MB,
grad-norm, roofline seconds, ...). Budgets are sized for CPU (`--quick`
shrinks them further for CI).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterator, List, Tuple

import jax
import numpy as np

from repro.core import make_optimizer
from repro.data import ctr_batch_stacked, make_ctr_task
from repro.models.deepfm import deepfm_logits, deepfm_loss, init_deepfm
from repro.train import DecentralizedTrainer
from repro.train.metrics import auc

K = 8  # the paper's worker count
ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived) -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def time_fn(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    """us per call (blocks on jax outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


# ---------------------- the paper's CTR training setup ----------------------

TASK = make_ctr_task(seed=0, n_fields=8, features_per_field=32)


def ctr_iter(seed: int = 1, batch: int = 32) -> Iterator:
    key = jax.random.PRNGKey(seed)
    t = 0
    while True:
        yield ctr_batch_stacked(TASK, jax.random.fold_in(key, t), K, batch)
        t += 1


def train_ctr(kind: str, steps: int, *, log_every: int = 10, **kw
              ) -> Tuple[Dict, float]:
    """Returns (log dict, us_per_step)."""
    opt = make_optimizer(kind, K=K, eta=1e-3, topology="ring", **kw)
    trainer = DecentralizedTrainer(lambda p, b: deepfm_loss(p, b), opt)
    params = init_deepfm(jax.random.PRNGKey(0), TASK.n_features,
                         TASK.n_fields, hidden=(64, 64))
    state = trainer.init(params)
    t0 = time.perf_counter()
    state, log = trainer.fit(state, ctr_iter(), steps, log_every=log_every)
    us = (time.perf_counter() - t0) / steps * 1e6
    avg = trainer.averaged_params(state)
    test = ctr_batch_stacked(TASK, jax.random.PRNGKey(999), K, 256)
    flat = jax.tree_util.tree_map(
        lambda x: x.reshape((-1,) + x.shape[2:]), test)
    scores = deepfm_logits(avg, flat["feat_ids"])
    test_auc = auc(np.asarray(scores), np.asarray(flat["label"]))
    return {"log": log, "auc": test_auc}, us


# ------------------------- record-schema pinning -----------------------------


def schema_of(obj):
    """Nested type schema of a benchmark record (for trajectory pinning).

    Dicts keep their keys, lists collapse to the deduped element schemas
    (so a longer run does not change the schema), scalars reduce to a type
    tag. Two records produced by the same code at different sizes/steps
    compare equal; a renamed/dropped/retyped field does not — that drift is
    what the bench-smoke CI job diffs against the committed BENCH_<pr>.json.
    """
    if isinstance(obj, dict):
        return {k: schema_of(v) for k, v in sorted(obj.items())}
    if isinstance(obj, (list, tuple)):
        uniq: list = []
        for s in (schema_of(v) for v in obj):
            if s not in uniq:
                uniq.append(s)
        return uniq
    if isinstance(obj, bool):
        return "bool"
    if isinstance(obj, int):
        return "int"
    if isinstance(obj, float):
        return "float"
    if obj is None:
        return "none"
    return type(obj).__name__
