"""Roofline table from the dry-run artifacts (EXPERIMENTS.md section
Roofline reads from here). One row per (arch x shape) on the single-pod
mesh: the three terms in seconds, the bottleneck, and the usefulness
ratio MODEL_FLOPS / HLO_FLOPs."""
import glob
import json
import os

from benchmarks.common import emit
from repro.analysis.roofline import from_artifact

ART_DIR = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")


def main() -> None:
    rows = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, "*_1616.json"))):
        with open(path) as f:
            art = json.load(f)
        if art.get("skipped") or art.get("tag"):
            continue
        r = from_artifact(art)
        rows.append(r)
        emit(f"roofline/{r.arch}/{r.shape}", 0.0,
             f"Tc={r.t_compute:.3e};Tm={r.t_memory:.3e};"
             f"Tcoll={r.t_collective:.3e};bound={r.bottleneck};"
             f"useful={r.usefulness:.2f}")
    if not rows:
        emit("roofline/NO_ARTIFACTS", 0.0,
             "run: python -m repro.launch.dryrun --all first")


if __name__ == "__main__":
    main()
