"""Benchmark driver: one section per paper table/figure + the roofline and
kernel microbenchmarks. Prints ``name,us_per_call,derived`` CSV."""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced step counts (CI)")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args()
    steps = 40 if args.quick else 150

    from benchmarks import (fig1_dadam_convergence, fig2_comm_cost,
                            fig3_cdadam_convergence, fig4_compression_cost,
                            fused_step, heterogeneity, kernels, roofline,
                            speedup, topology_ablation, vision_resnet)

    benches = {
        "fig1": lambda: fig1_dadam_convergence.main(steps),
        "fig2": lambda: fig2_comm_cost.main(steps),
        "fig3": lambda: fig3_cdadam_convergence.main(steps),
        "fig4": lambda: fig4_compression_cost.main(steps),
        "vision": lambda: vision_resnet.main(max(20, steps // 3)),
        "speedup": lambda: speedup.main(max(30, steps // 2)),
        "topology": lambda: topology_ablation.main(max(40, steps // 2)),
        "heterogeneity": lambda: heterogeneity.main(max(40, steps // 2)),
        "kernels": kernels.main,
        "fused_step": lambda: fused_step.main(
            size=(1 << 14) if args.quick else (1 << 16)),
        "roofline": roofline.main,
    }
    chosen = (args.only.split(",") if args.only else list(benches))
    print("name,us_per_call,derived")
    failures = []
    for name in chosen:
        try:
            benches[name]()
        except Exception as e:  # noqa: BLE001 — report-all driver
            failures.append((name, repr(e)))
            traceback.print_exc()
    if failures:
        sys.exit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
