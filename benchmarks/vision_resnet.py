"""The paper's first experiment analogue: ResNet20 on CIFAR-shaped images
(synthetic class-conditional data), D-Adam vs vanilla vs CD-Adam — training
loss + accuracy per communication MB (the paper's Fig. 1a / 2a panel).

Hyperparameters per Section 6.1: eta=1e-3, weight decay 1e-4, 8 workers,
ring. Scaled down: width-8 ResNet20, small batches, synthetic data."""
import jax

from benchmarks.common import emit
from repro.core import make_optimizer
from repro.data import image_batch_stacked
from repro.models.deepfm import init_resnet20, resnet20_logits, resnet20_loss
from repro.train import DecentralizedTrainer
from repro.train.metrics import accuracy

K = 8


def run(kind, steps, **kw):
    opt = make_optimizer(kind, K=K, eta=1e-3, weight_decay=1e-4,
                         topology="ring", **kw)
    trainer = DecentralizedTrainer(lambda p, b: resnet20_loss(p, b), opt)
    params = init_resnet20(jax.random.PRNGKey(0), width=8)
    state = trainer.init(params)

    def it():
        key = jax.random.PRNGKey(11)
        t = 0
        while True:
            yield image_batch_stacked(jax.random.fold_in(key, t), K, 8)
            t += 1

    state, log = trainer.fit(state, it(), steps, log_every=steps)
    avg = trainer.averaged_params(state)
    test = image_batch_stacked(jax.random.PRNGKey(99), K, 32)
    images = test["images"].reshape((-1,) + test["images"].shape[2:])
    labels = test["label"].reshape(-1)
    acc = accuracy(resnet20_logits(avg, images), labels)
    return log.loss[-1], acc, log.comm_mb[-1]


def main(steps: int = 60) -> None:
    loss_v, acc_v, mb_v = run("d-adam", steps, period=1)
    emit("vision/d-adam-vanilla_loss", 0.0, f"{loss_v:.4f}")
    emit("vision/d-adam-vanilla_acc", 0.0, f"{acc_v:.3f}")
    loss_p, acc_p, mb_p = run("d-adam", steps, period=8)
    emit("vision/d-adam_p8_loss", 0.0, f"{loss_p:.4f}")
    emit("vision/d-adam_p8_acc", 0.0, f"{acc_p:.3f}")
    emit("vision/d-adam_p8_comm_reduction", 0.0,
         f"{mb_v / max(mb_p, 1e-9):.1f}x")
    loss_c, acc_c, mb_c = run("cd-adam", steps, period=8, gamma=0.4,
                              compressor="sign")
    emit("vision/cd-adam_p8_loss", 0.0, f"{loss_c:.4f}")
    emit("vision/cd-adam_p8_acc", 0.0, f"{acc_c:.3f}")
    emit("vision/cd-adam_p8_comm_reduction", 0.0,
         f"{mb_v / max(mb_c, 1e-9):.1f}x")


if __name__ == "__main__":
    main()
