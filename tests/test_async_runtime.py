"""The straggler-tolerant time-varying-topology runtime.

Parity pins (the acceptance bar for the async machinery):

* a single-entry schedule is BITWISE the static topology it wraps, over a
  10-step trainer run, for both D-Adam and CD-Adam and both backends;
* tau=0 with the staleness buffers wired in is BITWISE the synchronous
  step — the buffers must change nothing until a payload actually lags.

Behavioral pins: consensus stays bounded (and keeps contracting) under
tau-stale straggling edges; elastic join/leave carries params/moments
and recompiles the trainer step exactly once per membership change;
checkpoints strip transient comm state and restore it cold.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore, save
from repro.core import dadam, make_optimizer
from repro.train.loop import DecentralizedTrainer

K = 8


def loss_fn(p, batch):
    pred = batch["x"] @ p["w"] + p["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


def init_params():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    return {"w": jax.random.normal(k1, (6, 1)) * 0.3,
            "b": jax.random.normal(k2, (1,)) * 0.1}


def batches(K, seed=0):
    key = jax.random.PRNGKey(seed)
    while True:
        key, k1 = jax.random.split(key)
        x = jax.random.normal(k1, (K, 8, 6))
        y = jnp.sum(x, axis=-1, keepdims=True)
        yield {"x": x, "y": y}


def params_equal(a, b):
    flat_a = jax.tree_util.tree_leaves(a)
    flat_b = jax.tree_util.tree_leaves(b)
    return all(bool((x == y).all()) for x, y in zip(flat_a, flat_b))


def fit_params(opt, steps=10, seed=0):
    tr = DecentralizedTrainer(loss_fn, opt)
    state = tr.init(init_params())
    state, _ = tr.fit(state, batches(opt.K, seed), steps, log_every=steps)
    return tr.opt.params_of(state)


# ----------------------------- parity pins -----------------------------


@pytest.mark.parametrize("kind", ["d-adam", "cd-adam"])
@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_single_entry_schedule_is_bitwise_static(kind, backend):
    """Wrapping a static graph in a one-entry schedule must not change a
    single bit of a 10-step trainer run."""
    from repro.core.schedule import static_schedule
    from repro.core.topology import make_topology
    topo = make_topology("ring", K)
    kw = dict(eta=1e-2, period=2, backend=backend)
    p_static = fit_params(make_optimizer(kind, K, topology=topo, **kw))
    p_sched = fit_params(
        make_optimizer(kind, K, topology=static_schedule(topo), **kw))
    assert params_equal(p_static, p_sched)


@pytest.mark.parametrize("kind", ["d-adam", "cd-adam"])
@pytest.mark.parametrize("backend", ["reference", "pallas"])
@pytest.mark.parametrize("period", [1, 3])
def test_tau_zero_is_bitwise_synchronous(kind, backend, period):
    """staleness=0 wires in the double-buffered payload machinery but must
    reproduce the synchronous step bit-for-bit (jit included)."""
    kw = dict(eta=1e-2, period=period, backend=backend, topology="ring")
    p_sync = fit_params(make_optimizer(kind, K, **kw))
    p_tau0 = fit_params(make_optimizer(kind, K, staleness=0, **kw))
    assert params_equal(p_sync, p_tau0)


# --------------------------- staleness bounds ---------------------------


@pytest.mark.parametrize("kind,backend,tol", [
    ("d-adam", "reference", 1e-4), ("d-adam", "pallas", 1e-4),
    ("cd-adam", "reference", 5e-1), ("cd-adam", "pallas", 5e-1)])
def test_stale_gossip_consensus_contracts(kind, backend, tol):
    """Pure gossip rounds (zero grad) with straggling edges at tau=2:
    consensus error must contract by orders of magnitude, never diverge —
    the bounded-staleness claim. CD-Adam contracts more slowly (sign
    compression moves hats by gamma steps), hence the looser tolerance."""
    opt = make_optimizer(kind, K, topology="ring", eta=1e-2, period=1,
                         backend=backend, staleness=2, straggler_rate=0.4,
                         straggler_seed=3)
    p0 = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (K,) + x.shape).copy() +
        jax.random.normal(jax.random.PRNGKey(1), (K,) + x.shape),
        init_params())
    state = opt.init(p0)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, p0)
    e0 = float(dadam.consensus_error(opt.params_of(state)))
    step = jax.jit(opt.step)
    for _ in range(60):
        state = step(state, zeros)
    e1 = float(dadam.consensus_error(opt.params_of(state)))
    assert np.isfinite(e1)
    assert e1 < tol * max(e0, 1.0)


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_stale_with_schedule_runs_and_contracts(backend):
    opt = make_optimizer("d-adam", K, topology="one-peer-exponential",
                         eta=1e-2, period=1, backend=backend,
                         staleness=2, straggler_rate=0.3)
    p0 = jax.tree_util.tree_map(
        lambda x: jax.random.normal(jax.random.PRNGKey(2),
                                    (K,) + x.shape), init_params())
    state = opt.init(p0)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, p0)
    e0 = float(dadam.consensus_error(opt.params_of(state)))
    step = jax.jit(opt.step)
    for _ in range(40):
        state = step(state, zeros)
    e1 = float(dadam.consensus_error(opt.params_of(state)))
    assert e1 < 1e-3 * max(e0, 1.0)


def test_cdadam_staleness_rejects_axis_comm():
    with pytest.raises(ValueError, match="ring buffers"):
        make_optimizer("cd-adam", K, comm="axis", staleness=2,
                       straggler_rate=0.1)


@pytest.mark.skipif(jax.device_count() < K,
                    reason="comm='axis' needs one device per worker "
                           "(tier1.sh forces 8 host devices)")
def test_dadam_axis_tau_zero_matches_stacked():
    """tau=0 parity extends to the sharded comm='axis' execution."""
    from repro.launch.mesh import make_worker_mesh
    mesh = make_worker_mesh(K)
    kw = dict(eta=1e-2, period=2, topology="ring")
    p_stacked = fit_params(make_optimizer("d-adam", K, **kw))
    opt_axis = make_optimizer("d-adam", K, comm="axis", mesh=mesh,
                              staleness=0, **kw)
    p_axis = fit_params(opt_axis)
    assert jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda a, b: jnp.allclose(a, b, atol=1e-6), p_stacked,
        jax.device_get(p_axis)))


# ------------------------------ elasticity ------------------------------


@pytest.mark.parametrize("kind", ["d-adam", "cd-adam"])
@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_elastic_resize_carries_state(kind, backend):
    from repro.core import resize_state
    kw = dict(topology="one-peer-exponential", eta=1e-2, period=1,
              backend=backend, staleness=2, straggler_rate=0.3)
    opt = make_optimizer(kind, K, **kw)
    tr = DecentralizedTrainer(loss_fn, opt)
    state = tr.init(init_params())
    state, _ = tr.fit(state, batches(K), 5, log_every=5)
    p_old = np.asarray(tr.opt.params_of(state)["w"])

    grown = make_optimizer(kind, K + 4, **kw)
    st2 = resize_state(state, grown, strategy="clone")
    p_new = np.asarray(grown.params_of(st2)["w"])
    assert (p_new[:K] == p_old).all()          # survivors untouched
    assert (p_new[K:] == p_old[:4]).all()      # joiners cloned round-robin
    assert int(jax.tree_util.tree_leaves(
        st2.moments.count if hasattr(st2, "moments")
        else st2.moments.count)[0]) == 5       # bias correction continues

    st2m = resize_state(state, grown, strategy="mean")
    pm = np.asarray(grown.params_of(st2m)["w"])
    assert np.allclose(pm[K:], p_old.mean(0), atol=1e-6)

    shrunk = make_optimizer(kind, K - 3, **kw)
    st3 = resize_state(state, shrunk)
    assert (np.asarray(shrunk.params_of(st3)["w"]) == p_old[:K - 3]).all()


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_trainer_resize_recompiles_exactly_once(backend):
    """One recompile per membership change — the elastic-runtime cost
    model. fit at the new K must then reuse the fresh cache."""
    kw = dict(topology="one-peer-exponential", eta=1e-2,
              backend=backend, staleness=2, straggler_rate=0.3)
    tr = DecentralizedTrainer(loss_fn, make_optimizer("d-adam", K, **kw))
    state = tr.init(init_params())
    state, _ = tr.fit(state, batches(K), 4, log_every=4)
    assert tr._step._cache_size() == 1

    state = tr.resize(state, make_optimizer("d-adam", K + 2, **kw))
    state, _ = tr.fit(state, batches(K + 2), 4, log_every=4)
    assert tr._step._cache_size() == 1

    state = tr.resize(state, make_optimizer("d-adam", K, **kw),
                      strategy="mean")
    state, log = tr.fit(state, batches(K), 4, log_every=4)
    assert tr._step._cache_size() == 1
    assert np.isfinite(log.loss[-1])


# --------------------------- checkpoint + comm ---------------------------


@pytest.mark.parametrize("kind", ["d-adam", "cd-adam"])
@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_checkpoint_strips_transient_and_restores_cold(kind, backend):
    """Transient straggler buffers never hit the wire format: the bytes
    match a staleness-free run's layout, portable params round-trip
    exactly, and the restored comm state is COLD."""
    opt = make_optimizer(kind, K, topology="one-peer-exponential",
                         eta=1e-2, period=1, backend=backend,
                         staleness=2, straggler_rate=0.3)
    state = opt.init(jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (K,) + x.shape).copy(),
        init_params()))
    g = jax.tree_util.tree_map(jnp.ones_like, opt.params_of(state))
    for _ in range(4):
        state = opt.step(state, g)
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "ck.npz")
        save(path, state, step=4)
        rst, step = restore(path, opt.init(jax.tree_util.tree_map(
            jnp.zeros_like, opt.params_of(state))))
    assert step == 4
    assert params_equal(opt.params_of(state), opt.params_of(rst))
    if kind == "d-adam":
        assert bool((rst.stale.age == dadam.COLD_AGE).all())
        assert all(bool((b == 0).all())
                   for b in jax.tree_util.tree_leaves(rst.stale.bufs))
    else:
        assert all(bool((r == 0).all())
                   for r in jax.tree_util.tree_leaves(rst.pending))


def test_checkpoint_without_staleness_restores_into_stale_like():
    """A pre-async checkpoint (no transient fields on disk) restores into
    a staleness-enabled like — forward compatibility of old checkpoints."""
    plain = make_optimizer("d-adam", K, topology="ring", eta=1e-2)
    stale = make_optimizer("d-adam", K, topology="ring", eta=1e-2,
                           staleness=2, straggler_rate=0.2)
    p0 = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (K,) + x.shape).copy(), init_params())
    st = plain.init(p0)
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "ck.npz")
        save(path, st, step=0)
        rst, _ = restore(path, stale.init(p0))
    assert params_equal(plain.params_of(st), stale.params_of(rst))
    assert bool((rst.stale.age == dadam.COLD_AGE).all())
