"""Topology invariants (Definition 1).

Formerly hypothesis-driven; the @given ranges are now explicit K tables
(edges: smallest ring, even/odd, powers of two, off-by-one, the old upper
bound) so the suite runs with stdlib pytest only.
"""
import numpy as np
import pytest

from repro.core.topology import (exponential, fully_connected,
                                 make_topology, offsets_matrix, ring)


@pytest.mark.parametrize("name", ["ring", "fully_connected", "exponential",
                                  "torus"])
@pytest.mark.parametrize("K", [1, 2, 3, 4, 8, 16, 32])
def test_doubly_stochastic(name, K):
    topo = make_topology(name, K)
    W = topo.weights
    assert np.allclose(W, W.T)
    assert np.allclose(W.sum(0), 1.0)
    assert np.allclose(W.sum(1), 1.0)
    assert np.all(W >= -1e-12)


@pytest.mark.parametrize("name", ["ring", "fully_connected", "exponential"])
@pytest.mark.parametrize("K", [2, 4, 8, 16])
def test_spectral_gap_in_range(name, K):
    topo = make_topology(name, K)
    rho = topo.spectral_gap
    assert 0.0 < rho <= 1.0 + 1e-9


def test_fully_connected_gap_is_one():
    assert abs(fully_connected(8).spectral_gap - 1.0) < 1e-9


def test_exponential_better_conditioned_than_ring():
    # exp graph mixes faster than the ring at equal K
    assert exponential(16).spectral_gap > ring(16).spectral_gap


@pytest.mark.parametrize("K", [3, 4, 5, 7, 8, 9, 16, 31, 32, 33, 63, 64])
def test_ring_offsets_reconstruct_matrix(K):
    topo = ring(K)
    W = np.zeros((K, K))
    for k in range(K):
        W[k, k] = topo.self_weight
        for s, w in zip(topo.offsets, topo.offset_weights):
            W[k, (k + s) % K] += w
    assert np.allclose(W, topo.weights)


@pytest.mark.parametrize("name", ["ring", "fully_connected", "exponential",
                                  "torus"])
@pytest.mark.parametrize("K", [1, 2, 3, 4, 6, 8, 9, 12, 16, 25, 32])
def test_offsets_reconstruct_weights_zoo_wide(name, K):
    """THE invariant the wrong-neighbor torus lowering violated: the
    mixing matrix the shift lowering applies (self_weight on the diagonal
    + w at each offset's source permutation) must equal ``topo.weights``
    exactly — otherwise roll/ppermute gossip mixes with the wrong
    neighbors while the spectral-gap reporting describes the intended
    graph. Property-checked over the whole topology zoo, including the
    non-square and degenerate-extent torus factorizations."""
    topo = make_topology(name, K)
    assert np.allclose(offsets_matrix(topo), topo.weights, atol=1e-12)


@pytest.mark.parametrize("K", [2, 3, 5, 7, 11, 13, 31])
def test_torus_prime_K_falls_back_to_ring(K):
    """A prime K only factors as 1 x K, whose torus degenerates to a
    worse-conditioned self-loop-heavy ring; make_topology must refuse the
    degenerate lowering, warn, and hand back the honest ring."""
    with pytest.warns(RuntimeWarning, match="falling back to ring"):
        topo = make_topology("torus", K)
    expected = ring(K)
    assert topo.name == expected.name
    assert np.allclose(topo.weights, expected.weights)
    assert np.allclose(offsets_matrix(topo), topo.weights)


def test_torus_composite_K_does_not_warn():
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        topo = make_topology("torus", 12)
    assert topo.name.startswith("torus")


def test_gossip_contraction_property():
    """||XW - X_bar|| <= (1-rho) ||X - X_bar|| (Lemma 3)."""
    rng = np.random.default_rng(0)
    for name in ("ring", "exponential", "fully_connected"):
        topo = make_topology(name, 8)
        X = rng.normal(size=(5, 8))
        Xb = X.mean(1, keepdims=True)
        lhs = np.linalg.norm(X @ topo.weights - Xb)
        rhs = (1 - topo.spectral_gap) * np.linalg.norm(X - Xb) + 1e-9
        assert lhs <= rhs + 1e-7


def test_neighbors_consistent_with_weights():
    topo = ring(8)
    for k in range(8):
        nbrs = dict(topo.neighbors_of(k))
        assert set(nbrs) == {(k + 1) % 8, (k - 1) % 8}
