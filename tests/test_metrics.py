"""train/metrics: AUC (rank-based with tie midranks) and accuracy.

The AUC pins matter because the CTR benchmark reports it as its quality
metric: ties must get midranks (not first-seen ranks), a one-class batch
must degrade to 0.5 rather than divide by zero, and the fast rank-based
computation must agree with the naive O(n^2) pairwise definition
P(score+ > score-) + 0.5 * P(tie) on random data.
"""
import numpy as np
import pytest

from repro.train.metrics import accuracy, auc


def naive_auc(scores, labels):
    """O(n^2) pairwise definition: wins + half-ties over all pos/neg
    pairs."""
    scores = np.asarray(scores, np.float64)
    labels = np.asarray(labels)
    pos = scores[labels == 1]
    neg = scores[labels == 0]
    if len(pos) == 0 or len(neg) == 0:
        return 0.5
    wins = (pos[:, None] > neg[None, :]).sum()
    ties = (pos[:, None] == neg[None, :]).sum()
    return float((wins + 0.5 * ties) / (len(pos) * len(neg)))


class TestAUC:
    def test_perfect_ranking(self):
        assert auc([0.1, 0.2, 0.8, 0.9], [0, 0, 1, 1]) == 1.0

    def test_reversed_ranking(self):
        assert auc([0.9, 0.8, 0.2, 0.1], [0, 0, 1, 1]) == 0.0

    def test_random_is_half(self):
        rng = np.random.default_rng(0)
        scores = rng.random(4000)
        labels = rng.integers(0, 2, 4000)
        assert auc(scores, labels) == pytest.approx(0.5, abs=0.03)

    def test_all_tied_scores_are_half(self):
        assert auc([0.5, 0.5, 0.5, 0.5], [0, 1, 0, 1]) == 0.5

    def test_tie_midranks(self):
        # pos at 0.5 ties one neg (half credit), beats the 0.1 neg,
        # loses to the 0.9 neg: (1 + 0.5) / 3
        assert auc([0.1, 0.5, 0.5, 0.9],
                   [0, 0, 1, 0]) == pytest.approx(1.5 / 3)

    @pytest.mark.parametrize("labels", [[0, 0, 0, 0], [1, 1, 1, 1]])
    def test_one_class_degrades_to_half(self, labels):
        assert auc([0.1, 0.4, 0.6, 0.9], labels) == 0.5

    def test_parity_with_naive_pairwise(self):
        rng = np.random.default_rng(7)
        for trial in range(20):
            n = int(rng.integers(2, 60))
            # coarse quantization forces plenty of ties
            scores = rng.integers(0, 5, n) / 4.0
            labels = rng.integers(0, 2, n)
            assert auc(scores, labels) == pytest.approx(
                naive_auc(scores, labels)), (trial, scores, labels)

    def test_accepts_jax_arrays(self):
        import jax.numpy as jnp

        assert auc(jnp.array([0.1, 0.9]), jnp.array([0, 1])) == 1.0


class TestAccuracy:
    def test_argmax_match(self):
        logits = np.array([[2.0, 1.0], [0.0, 3.0], [5.0, 0.0]])
        assert accuracy(logits, np.array([0, 1, 1])) == pytest.approx(2 / 3)
