"""The trip-count-aware HLO analyzer (roofline input correctness)."""
import warnings

import jax
import jax.numpy as jnp

from repro.analysis.hlo import (analyze, collective_bytes, full_cost,
                                unknown_dtypes_in)


def _compile(fn, *sds):
    return jax.jit(fn).lower(*sds).compile().as_text()


def test_scan_trip_count_multiplies_flops():
    """XLA's cost_analysis counts a while body once; ours multiplies by the
    trip count — pinned against the analytic matmul count."""
    def body(c, w):
        return jnp.tanh(c @ w), ()

    def fn(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)
    comp = jax.jit(fn).lower(x, ws).compile()
    ours = full_cost(comp.as_text())
    analytic = 2 * 128 * 256 * 256 * 10
    assert abs(ours["flops"] - analytic) / analytic < 0.05
    assert ours["unknown_trip_counts"] == 0
    # and XLA's raw number is ~10x short (the bug we correct)
    ca = comp.cost_analysis()
    if isinstance(ca, list):  # older jax returns a one-element list
        ca = ca[0]
    xla_flops = ca["flops"]
    assert xla_flops < analytic / 5


def test_nested_scan_multiplier():
    def inner(c, w):
        return c @ w, ()

    def outer(c, ws):
        c2, _ = jax.lax.scan(inner, c, ws)
        return c2, ()

    def fn(x, ws):
        return jax.lax.scan(lambda c, _: outer(c, ws), x, None, length=3)[0]

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    txt = _compile(fn, x, ws)
    ours = full_cost(txt)
    analytic = 2 * 64 * 64 * 64 * 5 * 3
    assert abs(ours["flops"] - analytic) / analytic < 0.1


def test_dot_flops_exact():
    def fn(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    ours = full_cost(_compile(fn, a, b))
    assert abs(ours["flops"] - 2 * 64 * 128 * 32) / (2 * 64 * 128 * 32) < 0.05


def test_collective_parsing_synthetic_hlo():
    """Operand-byte semantics per collective kind on hand-written HLO."""
    hlo = """
HloModule test

ENTRY %main (p0: f32[128,8]) -> f32[128,8] {
  %p0 = f32[128,8]{1,0} parameter(0)
  %ar = f32[128,8]{1,0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[128,32]{1,0} all-gather(%ar), replica_groups=[2,4]<=[8], dimensions={1}
  %cp = f32[128,8]{1,0} collective-permute(%ar), source_target_pairs={{0,1},{1,0}}
  ROOT %out = f32[128,8]{1,0} add(%ar, %cp)
}
"""
    coll = collective_bytes(hlo)
    assert coll["all-reduce"] == 128 * 8 * 4
    # all-gather result / group_size(4) = operand
    assert coll["all-gather"] == 128 * 32 * 4 // 4
    assert coll["collective-permute"] == 128 * 8 * 4
    assert coll["total"] == sum(coll[k] for k in
                                ("all-reduce", "all-gather",
                                 "collective-permute", "all-to-all",
                                 "reduce-scatter"))


def test_collectives_inside_while_multiplied():
    hlo = """
HloModule test

%cond (arg: (s32[], f32[64])) -> pred[] {
  %arg = (s32[], f32[64]{0}) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %t = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %t), direction=LT
}

%body (arg: (s32[], f32[64])) -> (s32[], f32[64]) {
  %arg = (s32[], f32[64]{0}) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[64]{0} get-tuple-element(%arg), index=1
  %ar = f32[64]{0} all-reduce(%x), replica_groups={{0,1}}, to_apply=%add
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %tup = (s32[], f32[64]) tuple(%i2, %ar)
}

ENTRY %main (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %p = (s32[], f32[64]{0}) parameter(0)
  ROOT %w = (s32[], f32[64]) while(%p), condition=%cond, body=%body
}
"""
    coll = collective_bytes(hlo)
    assert coll["all-reduce"] == 7 * 64 * 4


def test_real_sharded_program_collectives(tmp_path):
    """Rolls over a sharded leading dim lower to collective-permutes whose
    bytes the analyzer attributes (run on whatever host devices exist —
    single-device programs simply have zero collective bytes)."""
    def fn(x):
        return x / 3 + jnp.roll(x, 1, 0) / 3 + jnp.roll(x, -1, 0) / 3

    x = jax.ShapeDtypeStruct((4, 64), jnp.float32)
    txt = _compile(fn, x)
    coll = collective_bytes(txt)
    assert coll["total"] >= 0  # parses without error


# ------------------------- dtype-table coverage ------------------------------


def test_unknown_dtype_counted_not_dropped():
    """A dtype outside the table contributes a conservative 4 bytes/elem
    (and warns once) instead of silently zeroing the byte accounting."""
    hlo = """
HloModule test

ENTRY %main (p0: f9z[16,8]) -> f9z[16,8] {
  %p0 = f9z[16,8]{1,0} parameter(0)
  ROOT %ar = f9z[16,8]{1,0} all-reduce(%p0), replica_groups={{0,1}}, to_apply=%add
}
"""
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        coll = collective_bytes(hlo)
    assert coll["all-reduce"] == 16 * 8 * 4  # conservative fallback, not 0
    assert any("f9z" in str(w.message) for w in caught)

    # every textual shape occurrence counts: 2 in the ENTRY signature +
    # the parameter and all-reduce defs
    cost = analyze(hlo)
    assert cost.unknown_dtypes == {"f9z": 4 * 16 * 8}
    assert full_cost(hlo)["unknown_dtype_elems"] == 4 * 16 * 8
    assert unknown_dtypes_in(hlo) == {"f9z": 4 * 16 * 8}


def test_known_exotic_dtypes_in_table():
    """The narrow-float / sub-byte additions carry their real widths."""
    hlo = """
HloModule test

ENTRY %main (p0: f8e4m3[32]) -> bf16[32] {
  %p0 = f8e4m3[32]{0} parameter(0)
  %a = f8e4m3[32]{0} all-reduce(%p0), replica_groups={{0,1}}, to_apply=%add
  ROOT %c = bf16[32]{0} convert(%a)
}
"""
    assert not unknown_dtypes_in(hlo)
    assert collective_bytes(hlo)["all-reduce"] == 32 * 1  # 1 byte/elem


def test_metadata_brackets_not_parsed_as_dtypes():
    """Identifiers like pending[4] / bufs[1] inside op metadata must not
    register as unknown dtypes (the INV005 false-positive class)."""
    hlo = """
HloModule test

ENTRY %main (p0: f32[4]) -> f32[4] {
  %p0 = f32[4]{0} parameter(0), metadata={op_name="jit(f)/pending[4]/bufs[1]"}
  ROOT %n = f32[4]{0} negate(%p0)
}
"""
    assert unknown_dtypes_in(hlo) == {}


def test_max_trip_count_tracked():
    hlo = """
HloModule test

%cond (arg: (s32[], f32[64])) -> pred[] {
  %arg = (s32[], f32[64]{0}) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %t = s32[] constant(9)
  ROOT %lt = pred[] compare(%i, %t), direction=LT
}

%body (arg: (s32[], f32[64])) -> (s32[], f32[64]) {
  %arg = (s32[], f32[64]{0}) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[64]{0} get-tuple-element(%arg), index=1
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %tup = (s32[], f32[64]) tuple(%i2, %x)
}

ENTRY %main (p: (s32[], f32[64])) -> (s32[], f32[64]) {
  %p = (s32[], f32[64]{0}) parameter(0)
  ROOT %w = (s32[], f32[64]) while(%p), condition=%cond, body=%body
}
"""
    cost = analyze(hlo)
    assert cost.max_trip_count == 9
    assert full_cost(hlo)["max_trip_count"] == 9
