"""Serving engine: cache_spec consistency with real prefill outputs,
greedy generation, long-context window substitution."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced, list_archs
from repro.configs.base import INPUT_SHAPES
from repro.models import build_model
from repro.serve import (cache_spec, effective_config, greedy_generate)

KEY = jax.random.PRNGKey(0)


def make_prompt(cfg, batch=2, seq=12):
    b = {"tokens": jax.random.randint(KEY, (batch, seq), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        b["patches"] = jax.random.normal(KEY, (batch, cfg.n_patches, 1024))
    if cfg.family == "audio":
        b["audio_embeds"] = jax.random.normal(
            KEY, (batch, cfg.n_audio_ctx, cfg.d_model))
    return b


@pytest.mark.parametrize("arch_id", list_archs())
@pytest.mark.slow
def test_cache_spec_matches_actual_prefill(arch_id):
    """cache_spec's ShapeDtypeStructs must exactly match the cache a real
    prefill produces — the dry-run depends on this contract."""
    cfg = get_reduced(arch_id).model
    api = build_model(cfg)
    B, S = 2, 16
    extra = cfg.n_patches if cfg.family == "vlm" else 0
    spec = cache_spec(cfg, B, S + extra)

    from repro.serve.engine import kv_cache_len
    cache_len = kv_cache_len(cfg, S + extra)
    params_sds = jax.eval_shape(lambda: api.init(KEY))
    batch = make_prompt(cfg, B, S)
    batch_sds = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch)
    _, cache_sds = jax.eval_shape(
        lambda p, b: api.prefill(p, b, cache_len=cache_len),
        params_sds, batch_sds)
    got = jax.tree_util.tree_map(lambda l: (l.shape, str(l.dtype)),
                                 cache_sds)
    want = jax.tree_util.tree_map(lambda l: (l.shape, str(l.dtype)), spec)
    assert jax.tree_util.tree_structure(got) == \
        jax.tree_util.tree_structure(want), arch_id
    for g, w in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        assert g == w, f"{arch_id}: cache leaf {g} != spec {w}"


def test_effective_config_substitutes_window():
    cfg = get_reduced("llama3.2-1b").model
    shape = INPUT_SHAPES["long_500k"]
    eff = effective_config(cfg, shape)
    assert eff.sliding_window == cfg.long_context_window > 0
    # other shapes untouched
    eff2 = effective_config(cfg, INPUT_SHAPES["decode_32k"])
    assert eff2.sliding_window == cfg.sliding_window


def test_ssm_cache_size_independent_of_context():
    cfg = get_reduced("rwkv6-3b").model
    s1 = cache_spec(cfg, 1, 32768)
    s2 = cache_spec(cfg, 1, 524288)
    for a, b in zip(jax.tree_util.tree_leaves(s1),
                    jax.tree_util.tree_leaves(s2)):
        assert a.shape == b.shape  # O(1) state


def test_windowed_cache_is_window_sized():
    cfg = dataclasses.replace(get_reduced("llama3.2-1b").model,
                              sliding_window=8)
    spec = cache_spec(cfg, 1, 524288)
    assert spec.k.shape[2] == 8


@pytest.mark.slow
def test_greedy_generate():
    cfg = get_reduced("llama3.2-1b").model
    api = build_model(cfg)
    params = api.init(KEY)
    out = greedy_generate(cfg, params, make_prompt(cfg), n_new=5)
    assert out.shape == (2, 5)
    assert out.dtype == jnp.int32
    assert bool(jnp.all((out >= 0) & (out < cfg.vocab_size)))


def test_greedy_generate_n_new_zero_returns_empty():
    """n_new=0 must return an empty (B, 0) int32 batch — it used to fall
    through prefill and hand back one unrequested token."""
    cfg = get_reduced("llama3.2-1b").model
    api = build_model(cfg)
    params = api.init(KEY)
    out = greedy_generate(cfg, params, make_prompt(cfg), n_new=0)
    assert out.shape == (2, 0)
    assert out.dtype == jnp.int32


def test_greedy_generate_rejects_negative_n_new():
    cfg = get_reduced("llama3.2-1b").model
    with pytest.raises(ValueError, match="n_new"):
        greedy_generate(cfg, None, make_prompt(cfg), n_new=-1)


def test_greedy_generate_rejects_undersized_cache():
    """An explicit cache_len too small to hold prompt + n_new must raise
    up front instead of silently clobbering KV slots mid-decode. An
    explicit 0 used to be treated as *unset* by the `or` default."""
    cfg = get_reduced("llama3.2-1b").model
    with pytest.raises(ValueError, match="cache_len"):
        greedy_generate(cfg, None, make_prompt(cfg), n_new=4, cache_len=0)
    with pytest.raises(ValueError, match="cache_len"):
        greedy_generate(cfg, None, make_prompt(cfg), n_new=4, cache_len=13)


@pytest.mark.slow
def test_greedy_generate_explicit_cache_len_matches_default():
    cfg = get_reduced("llama3.2-1b").model
    api = build_model(cfg)
    params = api.init(KEY)
    prompt = make_prompt(cfg)
    o1 = greedy_generate(cfg, params, prompt, n_new=3)
    o2 = greedy_generate(cfg, params, prompt, n_new=3,
                         cache_len=prompt["tokens"].shape[1] + 3)
    assert bool(jnp.all(o1 == o2))


@pytest.mark.slow
def test_greedy_generate_deterministic():
    cfg = get_reduced("yi-6b").model
    api = build_model(cfg)
    params = api.init(KEY)
    prompt = make_prompt(cfg)
    o1 = greedy_generate(cfg, params, prompt, n_new=4)
    o2 = greedy_generate(cfg, params, prompt, n_new=4)
    assert bool(jnp.all(o1 == o2))
