"""backend='pallas' == backend='reference' parity for the optimizer core.

The Pallas kernels run in interpret mode on CPU (pl.pallas_call(...,
interpret=True) via repro.kernels.ops), so these tests exercise the exact
kernel bodies that compile to Mosaic on TPU. Covers:

* pack/unpack inverse property over ragged pytrees (flat + stacked),
* fused-Adam local_update parity incl. weight_decay, moment_dtype=bfloat16
  and non-lane-aligned shapes,
* sign-compress encode/apply round-trips vs the reference compressor,
* 10-step make_optimizer parity for d-adam and cd-adam (jitted, in-graph
  comm-skip cond), and config validation of the backend switch.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cdadam, dadam, make_optimizer, make_topology
from repro.core.compression import sign
from repro.core.dadam import DAdamConfig
from repro.kernels import ops
from repro.kernels import pack as packing

KEY = jax.random.PRNGKey(0)

FTOL = dict(rtol=2e-5, atol=2e-6)
BTOL = dict(rtol=2e-2, atol=2e-2)  # bf16 intermediates differ in rounding


def assert_trees_close(a, b, **tol):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32), **tol),
        a, b)


def ragged_tree(key, K=None, dtype=jnp.float32):
    """Deliberately lane-hostile leaf shapes (primes, scalars-per-worker)."""
    lead = () if K is None else (K,)
    ks = jax.random.split(key, 4)
    return {
        "w": jax.random.normal(ks[0], lead + (13, 7), dtype),
        "b": jax.random.normal(ks[1], lead + (5,), dtype),
        "nest": {
            "u": jax.random.normal(ks[2], lead + (3, 11, 2), dtype),
            "v": jax.random.normal(ks[3], lead + (1,), dtype),
        },
    }


# ------------------------------ pack/unpack --------------------------------


class TestPack:
    @pytest.mark.parametrize("block_rows", [1, 8, 256])
    def test_flat_inverse(self, block_rows):
        tree = ragged_tree(KEY)
        spec = packing.make_spec(tree, block_rows=block_rows)
        buf = packing.pack(tree, spec)
        assert buf.shape == (spec.rows, packing.LANE)
        assert spec.rows * packing.LANE % (block_rows * packing.LANE) == 0
        assert_trees_close(packing.unpack(buf, spec), tree, rtol=0, atol=0)

    def test_stacked_inverse_and_worker_locality(self):
        K = 5
        tree = ragged_tree(KEY, K=K)
        spec = packing.make_spec(tree, stacked=True, block_rows=8)
        buf = packing.pack(tree, spec)
        assert buf.shape == (K, spec.rows, packing.LANE)
        assert_trees_close(packing.unpack(buf, spec), tree, rtol=0, atol=0)
        # row k of the buffer holds exactly worker k's parameters
        sub = jax.tree_util.tree_map(lambda x: x[2:3], tree)
        sub_spec = packing.make_spec(sub, stacked=True, block_rows=8)
        np.testing.assert_array_equal(np.asarray(buf[2:3]),
                                      np.asarray(packing.pack(sub, sub_spec)))

    def test_mixed_dtype_roundtrip_is_exact(self):
        tree = {"f32": jnp.asarray([1.5, -2.25, 3e-8], jnp.float32),
                "bf16": jnp.asarray([1.0, -0.5, 1024.0], jnp.bfloat16)}
        spec = packing.make_spec(tree)
        back = packing.unpack(packing.pack(tree, spec), spec)
        assert back["bf16"].dtype == jnp.bfloat16
        assert back["f32"].dtype == jnp.float32
        assert_trees_close(back, tree, rtol=0, atol=0)

    def test_congruence_checked(self):
        tree = ragged_tree(KEY)
        spec = packing.make_spec(tree)
        bad = jax.tree_util.tree_map(lambda x: x.reshape(-1), tree)
        with pytest.raises(ValueError):
            packing.pack(bad, spec)

    def test_empty_tree_rejected(self):
        with pytest.raises(ValueError):
            packing.make_spec({})

    def test_int_tree_rejected(self):
        """Integer leaves must not silently pack through the float buffer
        (sqrt/sign on bit-reinterpreted ints would be garbage)."""
        with pytest.raises(ValueError, match="float"):
            packing.make_spec({"ids": jnp.arange(8, dtype=jnp.int32)})

    def test_mixed_int_tree_rejected(self):
        tree = {"w": jnp.ones((4, 4), jnp.float32),
                "ids": jnp.arange(8, dtype=jnp.int32)}
        with pytest.raises(ValueError, match="float"):
            packing.make_spec(tree)
        # an int tree packed against a float spec is rejected too
        spec = packing.make_spec({"w": jnp.ones((4, 4), jnp.float32),
                                  "ids": jnp.ones((8,), jnp.float32)})
        with pytest.raises(ValueError, match="float"):
            packing.pack(tree, spec)

    def test_bool_tree_rejected(self):
        with pytest.raises(ValueError, match="float"):
            packing.make_spec({"mask": jnp.ones((4,), bool)})

    @pytest.mark.parametrize("stacked", [False, True])
    def test_leaf_aligned_inverse_and_row_ranges(self, stacked):
        K = 3 if stacked else None
        tree = ragged_tree(KEY, K=K)
        spec = packing.make_spec(tree, stacked=stacked, block_rows=8,
                                 leaf_align=True)
        buf = packing.pack(tree, spec)
        assert_trees_close(packing.unpack(buf, spec), tree, rtol=0, atol=0)
        ranges = packing.leaf_row_ranges(spec)
        assert ranges[0][0] == 0 and ranges[-1][1] == spec.rows
        for (r0, r1), sz in zip(ranges, spec.sizes):
            assert (r1 - r0) % 8 == 0  # whole (block_rows, LANE) tiles
            assert (r1 - r0) * packing.LANE >= sz
        # non-aligned specs refuse to hand out row ranges
        flat_spec = packing.make_spec(tree, stacked=stacked, block_rows=8)
        with pytest.raises(ValueError, match="leaf_align"):
            packing.leaf_row_ranges(flat_spec)


# ------------------------------ fused Adam ---------------------------------


class TestFusedAdamParity:
    def run_both(self, cfg_kw, tree_kw, steps=3):
        params = ragged_tree(KEY, **tree_kw)
        outs = {}
        for backend in ("reference", "pallas"):
            cfg = DAdamConfig(eta=1e-2, backend=backend, **cfg_kw)
            cfg.validate()
            p = jax.tree_util.tree_map(jnp.copy, params)
            mom = dadam.init_moments(p, cfg)
            upd = jax.jit(lambda p, g, mom: dadam.local_update(p, g, mom,
                                                               cfg))
            for t in range(steps):
                g = jax.tree_util.tree_map(
                    lambda x: 0.5 * x + 0.01 * (t + 1), p)
                p, mom = upd(p, g, mom)
            outs[backend] = (p, mom)
        return outs

    def test_plain(self):
        outs = self.run_both({}, {})
        assert_trees_close(outs["reference"][0], outs["pallas"][0], **FTOL)
        assert_trees_close(outs["reference"][1].m, outs["pallas"][1].m, **FTOL)
        assert_trees_close(outs["reference"][1].v, outs["pallas"][1].v, **FTOL)

    def test_weight_decay(self):
        outs = self.run_both({"weight_decay": 0.1}, {})
        assert_trees_close(outs["reference"][0], outs["pallas"][0], **FTOL)

    def test_moment_dtype_bf16(self):
        outs = self.run_both({"moment_dtype": jnp.bfloat16}, {})
        assert outs["pallas"][1].m["w"].dtype == jnp.bfloat16
        assert_trees_close(outs["reference"][0], outs["pallas"][0], **BTOL)
        assert_trees_close(outs["reference"][1].m, outs["pallas"][1].m,
                           **BTOL)

    def test_stacked_worker_dim(self):
        outs = self.run_both({}, {"K": 4})
        assert_trees_close(outs["reference"][0], outs["pallas"][0], **FTOL)

    def test_count_advances(self):
        outs = self.run_both({}, {}, steps=2)
        assert int(outs["pallas"][1].count) == 2

    def test_bias_correction_rejected_on_pallas(self):
        with pytest.raises(ValueError, match="bias"):
            DAdamConfig(backend="pallas", bias_correction=True).validate()

    def test_unknown_backend_rejected(self):
        with pytest.raises(ValueError, match="backend"):
            DAdamConfig(backend="cuda").validate()


# ----------------------------- sign compress -------------------------------


class TestSignCompressParity:
    @pytest.mark.parametrize("shape", [(3, 37, 5), (2, 100), (4, 256, 128),
                                       (1, 7)])
    def test_stacked_kernel_matches_reference_encode(self, shape):
        """Kernel (q, scale, hat+scale*q) == reference sign() encode/decode
        round-trip applied per worker."""
        x = jax.random.normal(KEY, shape)
        hat = jax.random.normal(jax.random.fold_in(KEY, 1), shape) * 0.5
        q, scale, hat_new = ops.sign_compress_stacked(x, hat)
        assert q.dtype == jnp.int8 and scale.shape == (shape[0],)
        comp = sign()
        for k in range(shape[0]):
            resid = x[k] - hat[k]
            enc = comp.encode(resid)
            np.testing.assert_array_equal(np.asarray(q[k]),
                                          np.asarray(enc["bits"]))
            np.testing.assert_allclose(float(scale[k]), float(enc["scale"]),
                                       rtol=1e-5)
            np.testing.assert_allclose(
                np.asarray(hat[k] + comp.decode(enc, resid.shape,
                                                resid.dtype)),
                np.asarray(hat_new[k]), rtol=1e-5, atol=1e-6)

    def test_roundtrip_is_contraction(self):
        x = jax.random.normal(KEY, (4, 4096))
        hat = jnp.zeros_like(x)
        _, _, hat_new = ops.sign_compress_stacked(x, hat)
        err = float(jnp.sum((x - hat_new) ** 2))
        assert err <= float(jnp.sum(x ** 2))


# ------------------------- optimizer end-to-end ----------------------------


def _grads_of(params, t):
    k = jax.random.fold_in(jax.random.PRNGKey(9), t)
    return jax.tree_util.tree_map(
        lambda x: 0.5 * x + 0.1 * jax.random.normal(k, x.shape,
                                                    jnp.float32).astype(
                                                        x.dtype), params)


class TestOptimizerParity:
    @pytest.mark.parametrize("kind", ["d-adam", "cd-adam"])
    def test_ten_step_allclose(self, kind):
        """Acceptance: make_optimizer(..., backend='pallas') and 'reference'
        produce allclose params AND moments after 10 jitted steps."""
        params = ragged_tree(KEY, K=4)
        states = {}
        for backend in ("reference", "pallas"):
            opt = make_optimizer(kind, K=4, eta=1e-2, period=2,
                                 weight_decay=0.01, backend=backend)
            s = opt.init(jax.tree_util.tree_map(jnp.copy, params))
            step = jax.jit(lambda s, g, opt=opt: opt.step(s, g))
            for t in range(10):
                s = step(s, _grads_of(opt.params_of(s), t))
            states[backend] = s
        ref, pal = states["reference"], states["pallas"]
        assert_trees_close(ref.params, pal.params, **FTOL)
        assert_trees_close(ref.moments.m, pal.moments.m, **FTOL)
        assert_trees_close(ref.moments.v, pal.moments.v, **FTOL)
        if kind == "cd-adam":
            assert_trees_close(ref.hat_self, pal.hat_self, **FTOL)
            for hr, hp in zip(ref.hat_nbrs, pal.hat_nbrs):
                assert_trees_close(hr, hp, **FTOL)

    def test_pallas_requires_sign_compressor(self):
        with pytest.raises(ValueError, match="sign"):
            make_optimizer("cd-adam", K=4, compressor="topk",
                           backend="pallas")

    def test_dpsgd_rejects_pallas(self):
        with pytest.raises(ValueError, match="d-psgd"):
            make_optimizer("d-psgd", K=4, backend="pallas")


# --------------- invariants the kernels must preserve ----------------------


class TestInvariantsUnderBothBackends:
    @pytest.mark.parametrize("backend", ["reference", "pallas"])
    def test_k1_dadam_equals_plain_adam(self, backend):
        """K=1 D-Adam == the independent reference Adam, per backend."""
        from repro.optim import adam as ref_adam
        d = 16
        c = jax.random.normal(KEY, (1, d))
        opt = make_optimizer("d-adam", K=1, eta=0.01, tau=1e-6,
                             backend=backend)
        state = opt.init({"x": jnp.zeros((1, d))})
        ref_p = {"x": jnp.zeros((1, d))}
        ref_s = ref_adam.init(ref_p)
        step = jax.jit(lambda s, g: opt.step(s, g))
        for t in range(15):
            g = {"x": 2.0 * (opt.params_of(state)["x"] - c)}
            state = step(state, g)
            ref_p, ref_s = ref_adam.step(
                ref_p, {"x": 2.0 * (ref_p["x"] - c)}, ref_s,
                eta=0.01, tau=1e-6)
        np.testing.assert_allclose(np.asarray(state.params["x"]),
                                   np.asarray(ref_p["x"]),
                                   rtol=1e-5, atol=1e-6)

    @staticmethod
    def _round_grad_fn(state, centers):
        """grad of sum_k ||x_k - c_k||^2, in the form round_step hands out:
        a pytree for NamedTuple states, the resident packed buffer for
        packed states (where the elementwise grad applies to the buffer
        directly — centers packed once, user-side)."""
        if hasattr(state, "spec"):
            centers_buf = packing.pack({"x": centers}, state.spec)
            return lambda buf, batch: 2.0 * (buf - centers_buf)
        return lambda params, batch: {
            "x": 2.0 * (params["x"] - centers) + 0.0 * batch}

    @pytest.mark.parametrize("backend", ["reference", "pallas"])
    def test_dadam_round_equals_p_steps(self, backend):
        K, d, p = 4, 6, 3
        topo = make_topology("ring", K)
        cfg = DAdamConfig(eta=0.05, period=p, tau=1e-3, backend=backend)
        centers = jax.random.normal(KEY, (K, d))
        batches = jax.random.normal(jax.random.fold_in(KEY, 2), (p, K, d))

        s1 = dadam.init({"x": jnp.zeros((K, d))}, cfg)
        s1 = dadam.round_step(s1, self._round_grad_fn(s1, centers), batches,
                              topo, cfg)
        s2 = dadam.init({"x": jnp.zeros((K, d))}, cfg)
        for t in range(p):
            g = {"x": 2.0 * (s2.params["x"] - centers)}
            s2 = dadam.step(s2, g, topo, cfg)
        np.testing.assert_allclose(np.asarray(s1.params["x"]),
                                   np.asarray(s2.params["x"]),
                                   rtol=1e-5, atol=1e-6)

    @pytest.mark.parametrize("backend", ["reference", "pallas"])
    def test_cdadam_round_equals_p_steps(self, backend):
        from repro.core.cdadam import CDAdamConfig
        K, d, p = 4, 6, 2
        topo = make_topology("ring", K)
        cfg = CDAdamConfig(eta=0.05, period=p, tau=1e-3, backend=backend)
        comp = sign()
        centers = jax.random.normal(KEY, (K, d))
        batches = jax.random.normal(jax.random.fold_in(KEY, 2), (p, K, d))

        s1 = cdadam.init({"x": jnp.zeros((K, d))}, cfg, topo)
        s1 = cdadam.round_step(s1, self._round_grad_fn(s1, centers), batches,
                               topo, cfg, comp)
        s2 = cdadam.init({"x": jnp.zeros((K, d))}, cfg, topo)
        for t in range(p):
            g = {"x": 2.0 * (s2.params["x"] - centers)}
            s2 = cdadam.step(s2, g, topo, cfg, comp)
        np.testing.assert_allclose(np.asarray(s1.params["x"]),
                                   np.asarray(s2.params["x"]),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(s1.hat_self["x"]),
                                   np.asarray(s2.hat_self["x"]),
                                   rtol=1e-5, atol=1e-6)
