"""Randomized property-style round-trip tests for ``repro.kernels.pack``.

``tests/test_backend_parity.py`` pins hand-picked layouts; this module
sweeps a seeded randomized space of tree structures instead (stdlib +
numpy RNG only, no hypothesis): ragged/odd leaf shapes (primes,
singletons, rank 0-4), mixed f32/bf16 dtypes, non-divisible row counts,
every layout combination (flat / stacked / leaf-aligned / row-sharded)
and random block_rows. Invariants checked per sample:

* ``unpack(pack(tree)) == tree`` exactly (dtype-preserving, bf16 exact),
* buffer shape / tile divisibility / ``local_rows`` consistency,
* all padding slots are exactly zero (the resident-layout soundness
  invariant the optimizer kernels rely on),
* leaf-aligned row ranges tile the (local) buffer exactly, in order, and
  each leaf's range holds its elements,
* the row-sharded layout really round-robins every leaf across shard
  blocks: slicing shard block j of the buffer and re-joining reproduces
  ``pack`` with ``row_shards=1`` leaf-for-leaf,
* worker locality: row k of a stacked buffer holds exactly worker k's
  elements,

plus the construction-time rejections: empty pytrees, integer/bool
leaves, row_shards without stacked+leaf_align, and incongruent trees.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import pack as packing

LANE = packing.LANE


def random_tree(rng: np.random.Generator, stacked_k):
    """Random pytree: 1-5 leaves, awkward shapes, mixed float dtypes."""
    n_leaves = int(rng.integers(1, 6))
    dims_pool = [1, 2, 3, 5, 7, 11, 13, 17, 127, 129, 300]
    tree = {}
    for i in range(n_leaves):
        rank = int(rng.integers(0, 4))
        shape = tuple(int(rng.choice(dims_pool)) for _ in range(rank))
        if stacked_k is not None:
            shape = (stacked_k,) + shape
        dtype = jnp.bfloat16 if rng.random() < 0.3 else jnp.float32
        leaf = jnp.asarray(rng.standard_normal(shape), dtype)
        # nest roughly half the leaves one level down
        if rng.random() < 0.5:
            tree.setdefault("nest", {})[f"l{i}"] = leaf
        else:
            tree[f"l{i}"] = leaf
    return tree


def assert_exact(a, b):
    jax.tree_util.tree_map(
        lambda x, y: (np.testing.assert_array_equal(
            np.asarray(x, np.float32), np.asarray(y, np.float32)),
            # dtype must round-trip too
            np.testing.assert_equal(jnp.dtype(x.dtype), jnp.dtype(y.dtype))),
        a, b)


@pytest.mark.parametrize("seed", range(12))
def test_roundtrip_random_layout(seed):
    rng = np.random.default_rng(seed)
    stacked = bool(rng.random() < 0.7)
    k = int(rng.integers(1, 6)) if stacked else None
    block_rows = int(rng.choice([1, 2, 8, 32]))
    leaf_align = bool(stacked and rng.random() < 0.7)
    row_shards = int(rng.choice([1, 2, 3, 4])) if leaf_align else 1
    tree = random_tree(rng, k)

    spec = packing.make_spec(tree, stacked=stacked, block_rows=block_rows,
                             leaf_align=leaf_align, row_shards=row_shards)
    buf = packing.pack(tree, spec)

    # shape + divisibility invariants
    assert buf.shape == spec.buf_shape()
    assert spec.rows % block_rows == 0
    assert spec.rows % row_shards == 0
    assert spec.local_rows == spec.rows // row_shards
    if leaf_align:
        assert spec.local_rows % block_rows == 0

    # exact inverse, dtypes preserved
    assert_exact(packing.unpack(buf, spec), tree)

    # padding slots are exactly zero: rebuild the data mask from the spec
    flat = np.asarray(buf, np.float32).reshape(spec.k or 1, -1)
    mask = np.zeros(flat.shape[1], bool)
    chunks = packing._shard_chunks(spec)
    per_shard = spec.padded // spec.row_shards
    for o, c, sz in zip(spec.offsets, chunks, spec.sizes):
        for j in range(spec.row_shards):
            lo = j * per_shard + o
            # data fills the leaf's chunks in order; chunk j holds
            # elements [j*c, min((j+1)*c, sz))
            fill = min(max(sz - j * c, 0), c)
            mask[lo:lo + fill] = True
    assert np.all(flat[:, ~mask] == 0.0)

    if leaf_align:
        ranges = packing.leaf_row_ranges(spec)
        # ranges tile the local row space exactly, in leaf order
        assert ranges[0][0] == 0 and ranges[-1][1] == spec.local_rows
        for (a0, a1), (b0, b1) in zip(ranges, ranges[1:]):
            assert a1 == b0
        for (r0, r1), sz in zip(ranges, spec.sizes):
            assert (r1 - r0) * LANE * row_shards >= sz
            assert (r1 - r0) % block_rows == 0
    else:
        with pytest.raises(ValueError, match="leaf_align"):
            packing.leaf_row_ranges(spec)


@pytest.mark.parametrize("seed", range(6))
def test_row_sharded_blocks_reorder_the_unsharded_layout(seed):
    """Shard block j of the row-sharded buffer holds the j-th 1/M chunk of
    every leaf — re-joining the blocks chunk-wise reproduces each leaf."""
    rng = np.random.default_rng(100 + seed)
    k = int(rng.integers(1, 5))
    m = int(rng.choice([2, 3, 4]))
    block_rows = int(rng.choice([1, 4, 8]))
    tree = random_tree(rng, k)
    spec = packing.make_spec(tree, stacked=True, block_rows=block_rows,
                             leaf_align=True, row_shards=m)
    buf = np.asarray(packing.pack(tree, spec), np.float32)
    blocks = buf.reshape(k, m, -1)                 # (K, shard, slots)
    leaves = jax.tree_util.tree_leaves(tree)
    for leaf, o, c, sz in zip(leaves, spec.offsets,
                              packing._shard_chunks(spec), spec.sizes):
        rejoined = blocks[:, :, o:o + c].reshape(k, -1)[:, :sz]
        np.testing.assert_array_equal(
            rejoined, np.asarray(leaf, np.float32).reshape(k, -1))


@pytest.mark.parametrize("seed", range(4))
def test_stacked_worker_locality(seed):
    """Row k of a stacked buffer holds exactly worker k's data, in every
    layout — packing a single-worker slice reproduces buffer row k."""
    rng = np.random.default_rng(200 + seed)
    k = int(rng.integers(2, 6))
    row_shards = int(rng.choice([1, 2]))
    tree = random_tree(rng, k)
    spec = packing.make_spec(tree, stacked=True, block_rows=4,
                             leaf_align=True, row_shards=row_shards)
    buf = packing.pack(tree, spec)
    w = int(rng.integers(0, k))
    sub = jax.tree_util.tree_map(lambda x: x[w:w + 1], tree)
    sub_spec = packing.make_spec(sub, stacked=True, block_rows=4,
                                 leaf_align=True, row_shards=row_shards)
    np.testing.assert_array_equal(np.asarray(buf[w:w + 1]),
                                  np.asarray(packing.pack(sub, sub_spec)))


@pytest.mark.parametrize("seed", range(4))
def test_grads_through_unpack_transpose(seed):
    """AD's transpose of unpack deposits grads into the right slots for
    every layout (the trainer's zero-pack grad path)."""
    rng = np.random.default_rng(300 + seed)
    k = int(rng.integers(1, 4))
    row_shards = int(rng.choice([1, 2, 4]))
    tree = random_tree(rng, k)
    # f32 only: grad-of-bf16 comparisons would just test rounding
    tree = jax.tree_util.tree_map(lambda x: x.astype(jnp.float32), tree)
    spec = packing.make_spec(tree, stacked=True, block_rows=2,
                             leaf_align=True, row_shards=row_shards)
    buf = packing.pack(tree, spec)

    def loss(b):
        return sum(jnp.sum(x.astype(jnp.float32) ** 2)
                   for x in jax.tree_util.tree_leaves(
                       packing.unpack(b, spec)))

    g = jax.grad(loss)(buf)
    np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(buf),
                               rtol=1e-6)


class TestRejections:
    def test_empty_pytree_rejected(self):
        with pytest.raises(ValueError, match="empty"):
            packing.make_spec({})
        with pytest.raises(ValueError, match="empty"):
            packing.make_spec({"a": {}, "b": ()})

    @pytest.mark.parametrize("bad", [
        {"ids": jnp.arange(8, dtype=jnp.int32)},
        {"mask": jnp.ones((4,), bool)},
        {"w": jnp.ones((4, 4)), "ids": jnp.arange(8, dtype=jnp.int32)},
    ])
    def test_non_float_leaves_rejected(self, bad):
        with pytest.raises(ValueError, match="float"):
            packing.make_spec(bad)

    def test_row_shards_needs_stacked_and_aligned(self):
        tree = {"w": jnp.ones((4, 8))}
        with pytest.raises(ValueError, match="row_shards"):
            packing.make_spec(tree, row_shards=2)
        with pytest.raises(ValueError, match="row_shards"):
            packing.make_spec(tree, stacked=True, row_shards=2)
        with pytest.raises(ValueError, match="row_shards"):
            packing.make_spec(tree, row_shards=0)

    def test_ragged_worker_dims_rejected(self):
        with pytest.raises(ValueError, match="worker dim"):
            packing.make_spec({"a": jnp.ones((2, 3)), "b": jnp.ones((4, 3))},
                              stacked=True)

    def test_incongruent_tree_rejected(self):
        tree = {"w": jnp.ones((3, 8)), "b": jnp.ones((3, 5))}
        spec = packing.make_spec(tree, stacked=True, leaf_align=True,
                                 block_rows=2, row_shards=3)
        bad = {"w": jnp.ones((3, 8)), "b": jnp.ones((3, 6))}
        with pytest.raises(ValueError, match="match spec"):
            packing.pack(bad, spec)
