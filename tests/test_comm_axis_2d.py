"""2D (worker × model) mesh execution of the packed backend.

Extends the 1D comm='axis' tests: a mesh built by
``make_worker_mesh(K, model_parallel=M)`` carries both a 'worker' axis
(gossip ppermutes over it) and a 'model' axis (the packed (K, rows, 128)
state's row dim is sharded M-ways via the ``row_shards=M`` pack layout,
and CD-Adam's per-(worker, leaf) compression scales psum over it). These
tests pin, for both optimizers:

* sharded-2D ≡ sharded-1D ≡ single-device packed ≡ reference parity over
  a 10-step trainer run (the acceptance chain),
* multi-step ``step`` / ``round`` parity vs the stacked runtime across
  square and rectangular worker × model factorizations,
* the state really lands as one (1, rows/M, 128) block per device,
* checkpoint portability 1D mesh -> 2D mesh and back, bit-identically,
* ``comm_bytes_per_round`` unchanged by the model axis (regression: the
  model axis must not inflate per-round byte accounting), and
* construction-time validation of the 2D mode's requirements.

Device-requiring tests skip when the process has fewer devices than
K * M (``scripts/tier1.sh`` forces 8 host devices → the (4, 2)
factorization runs there; the CI device matrix adds a 16-device run
covering the square (4, 4) and rectangular (8, 2) factorizations).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_optimizer
from repro.core.cdadam import CDAdamConfig
from repro.core.dadam import DAdamConfig
from repro.kernels import pack as packing
from repro.launch.mesh import make_worker_mesh

KEY = jax.random.PRNGKey(0)
K, M = 4, 2  # primary factorization; needs the 8 devices tier1.sh forces

# square and rectangular worker x model splits; beyond-(4,2) entries run
# under the CI device matrix's 16-device job and skip elsewhere
FACTORIZATIONS = [(4, 2), (4, 4), (8, 2)]

KINDS = ["d-adam", "cd-adam"]


def ragged_tree(key, k):
    ks = jax.random.split(key, 3)
    return {
        "w": jax.random.normal(ks[0], (k, 13, 7)),
        "b": jax.random.normal(ks[1], (k, 5)),
        "nest": {"u": jax.random.normal(ks[2], (k, 3, 11, 2))},
    }


def needs_devices(n):
    return pytest.mark.skipif(
        jax.device_count() < n,
        reason=f"needs >= {n} devices (tier1.sh forces 8; the CI device "
               f"matrix runs 8 and 16)")


def skip_unless_devices(n):
    if jax.device_count() < n:
        pytest.skip(f"needs >= {n} devices, have {jax.device_count()}")


@pytest.fixture(scope="module")
def mesh2d():
    skip_unless_devices(K * M)
    return make_worker_mesh(K, model_parallel=M)


@pytest.fixture(scope="module")
def mesh1d():
    skip_unless_devices(K)
    return make_worker_mesh(K)


# ------------------------------ validation ----------------------------------


class TestValidation:
    def test_model_parallel_requires_axis_comm(self):
        with pytest.raises(ValueError, match="comm='axis'"):
            DAdamConfig(model_parallel=2, backend="pallas").validate()

    def test_model_parallel_requires_pallas_backend(self):
        with pytest.raises(ValueError, match="pallas"):
            DAdamConfig(comm="axis", model_parallel=2,
                        backend="reference").validate()

    def test_model_parallel_must_be_positive(self):
        with pytest.raises(ValueError, match="model_parallel"):
            DAdamConfig(model_parallel=0).validate()

    def test_cdadam_inherits_2d_validation(self):
        with pytest.raises(ValueError, match="pallas"):
            CDAdamConfig(comm="axis", model_parallel=2,
                         backend="reference").validate()

    @needs_devices(K * M)
    def test_reference_backend_on_2d_mesh_stays_1d(self, mesh2d):
        """2D row-sharding is declared by backend='pallas' + a model axis;
        under backend='reference' a model axis on the mesh keeps its
        pre-2D meaning (state replicated over it) — the run must still
        match the stacked reference bit-for-bit in parity terms."""
        opt = make_optimizer("d-adam", K=K, eta=1e-2, period=2,
                             comm="axis", mesh=mesh2d,
                             backend="reference")
        assert opt.cfg.model_parallel == 1
        base = make_optimizer("d-adam", K=K, eta=1e-2, period=2,
                              backend="reference")
        params = ragged_tree(KEY, K)
        s0 = base.init(jax.tree_util.tree_map(jnp.copy, params))
        s1 = opt.init(jax.tree_util.tree_map(jnp.copy, params))
        for t in range(3):
            g = jax.tree_util.tree_map(
                lambda x: 0.5 * x + 0.01 * (t + 1), base.params_of(s0))
            s0, s1 = base.step(s0, g), opt.step(s1, g)
        for a, b in zip(jax.tree_util.tree_leaves(base.params_of(s0)),
                        jax.tree_util.tree_leaves(opt.params_of(s1))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=1e-6)

    @needs_devices(K * M)
    def test_wrong_worker_axis_size_on_2d_mesh_rejected(self, mesh2d):
        with pytest.raises(ValueError, match="size K"):
            make_optimizer("d-adam", K=K + 1, comm="axis", mesh=mesh2d,
                           backend="pallas")


# ----------------------- state placement on the mesh -------------------------


@needs_devices(K * M)
class TestStatePlacement:
    @pytest.mark.parametrize("kind", KINDS)
    def test_one_row_block_per_device(self, kind, mesh2d):
        """init really lands one (1, rows/M, 128) block on each of the
        K x M devices, with the row-sharded pack layout recorded in the
        spec; the scalar count stays fully replicated."""
        opt = make_optimizer(kind, K=K, eta=1e-2, backend="pallas",
                             comm="axis", mesh=mesh2d)
        state = opt.init(ragged_tree(KEY, K))
        assert state.spec.row_shards == M
        assert state.spec.rows % (M * packing.BLOCK_ROWS) == 0
        shard_shapes = {s.data.shape for s in state.buf.addressable_shards}
        assert shard_shapes == {(1, state.buf.shape[1] // M, 128)}
        assert len(state.buf.addressable_shards) == K * M
        assert len(state.count.addressable_shards) == K * M
        if kind == "cd-adam":
            for h in state.hat_nbr_bufs:
                assert {s.data.shape for s in h.addressable_shards} == \
                    {(1, state.buf.shape[1] // M, 128)}

    def test_unpacked_view_roundtrips_from_shards(self, mesh2d):
        """params_of on the 2D-sharded row-sharded buffer materializes the
        exact original tree (the row-sharded unpack is layout-exact)."""
        params = ragged_tree(KEY, K)
        opt = make_optimizer("d-adam", K=K, eta=1e-2, backend="pallas",
                             comm="axis", mesh=mesh2d)
        state = opt.init(jax.tree_util.tree_map(jnp.copy, params))
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                       np.asarray(b)),
            opt.params_of(state), params)


# --------------------------- 2D == stacked parity ----------------------------


def _step_parity(kind, k, m):
    """4 steps with period=2 (both cond branches): 2D shard_map == the
    stacked single-program packed run."""
    mesh = make_worker_mesh(k, model_parallel=m)
    params = ragged_tree(KEY, k)
    base = make_optimizer(kind, K=k, eta=1e-2, period=2, weight_decay=0.01,
                          backend="pallas")
    axis2 = make_optimizer(kind, K=k, eta=1e-2, period=2, weight_decay=0.01,
                           backend="pallas", comm="axis", mesh=mesh)
    s0 = base.init(jax.tree_util.tree_map(jnp.copy, params))
    s2 = axis2.init(jax.tree_util.tree_map(jnp.copy, params))
    step0 = jax.jit(lambda s, g: base.step(s, g))
    step2 = jax.jit(lambda s, g: axis2.step(s, g))
    for t in range(4):
        g = jax.tree_util.tree_map(
            lambda x: 0.5 * x + 0.01 * (t + 1), base.params_of(s0))
        # each runtime's grads pack against its OWN layout (row-sharded
        # for the 2D state)
        s0 = step0(s0, packing.pack(g, s0.spec, dtype=s0.buf.dtype))
        s2 = step2(s2, packing.pack(g, s2.spec, dtype=s2.buf.dtype))
    for a, b in zip(jax.tree_util.tree_leaves(base.params_of(s0)),
                    jax.tree_util.tree_leaves(axis2.params_of(s2))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-6)


class TestAxis2DMatchesStacked:
    @pytest.mark.parametrize("kind", KINDS)
    @pytest.mark.parametrize("factor", FACTORIZATIONS,
                             ids=lambda f: f"K{f[0]}xM{f[1]}")
    def test_multi_step_parity(self, kind, factor):
        k, m = factor
        skip_unless_devices(k * m)
        _step_parity(kind, k, m)

    @pytest.mark.parametrize("kind", KINDS)
    def test_round_step_parity(self, kind, mesh2d):
        """p local fused steps + one gossip inside the 2D shard_map ==
        the stacked round; grad_fn sees each device's (1, rows/M, 128)
        row-shard block."""
        params = ragged_tree(KEY, K)
        base = make_optimizer(kind, K=K, eta=1e-2, period=3,
                              backend="pallas")
        axis2 = make_optimizer(kind, K=K, eta=1e-2, period=3,
                               backend="pallas", comm="axis", mesh=mesh2d)
        batches = jnp.zeros((3, K, 1))
        grad_fn = lambda buf, batch: 0.5 * buf
        s0 = base.round(base.init(jax.tree_util.tree_map(jnp.copy, params)),
                        grad_fn, batches)
        s2 = axis2.round(axis2.init(jax.tree_util.tree_map(jnp.copy,
                                                           params)),
                         grad_fn, batches)
        assert int(s2.count) == 3
        for a, b in zip(jax.tree_util.tree_leaves(base.params_of(s0)),
                        jax.tree_util.tree_leaves(axis2.params_of(s2))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=1e-6)


# ---------------- acceptance: the full parity chain, 10 steps ----------------


@needs_devices(K * M)
class TestTrainerParityChain:
    @pytest.mark.parametrize("kind", KINDS)
    def test_2d_equals_1d_equals_packed_equals_reference(self, kind,
                                                         mesh1d, mesh2d):
        """10-step trainer run: sharded-2D ≡ sharded-1D ≡ single-device
        packed ≡ reference, for losses and final params. The 2D config
        exercises the full production path: batch placement, the
        differentiate-through-unpack grads on row shards, ppermute gossip
        and (for CD-Adam) model-axis-psum'd compression scales."""
        from repro.train import DecentralizedTrainer

        d = 37
        centers = jax.random.normal(KEY, (K, d))

        def loss_fn(params, batch):
            return jnp.sum((params["x"] - batch) ** 2)

        def batch_iter():
            t = 0
            while True:
                yield centers + 0.01 * t
                t += 1

        configs = {
            "reference": dict(backend="reference"),
            "packed": dict(backend="pallas"),
            "axis1d": dict(backend="pallas", comm="axis", mesh=mesh1d),
            "axis2d": dict(backend="pallas", comm="axis", mesh=mesh2d),
        }
        logs, finals = {}, {}
        for name, kw in configs.items():
            opt = make_optimizer(kind, K=K, eta=5e-2, period=2, **kw)
            trainer = DecentralizedTrainer(loss_fn, opt)
            state = trainer.init({"x": jnp.zeros((d,))})
            state, log = trainer.fit(state, batch_iter(), 10, log_every=5)
            logs[name] = log
            finals[name] = np.asarray(opt.params_of(state)["x"])
        for name in ("packed", "axis1d", "axis2d"):
            np.testing.assert_allclose(logs["reference"].loss,
                                       logs[name].loss,
                                       rtol=2e-4, atol=1e-5)
            np.testing.assert_allclose(finals["reference"], finals[name],
                                       rtol=2e-4, atol=2e-5)
        # the three packed runtimes agree much tighter among themselves
        for name in ("axis1d", "axis2d"):
            np.testing.assert_allclose(finals["packed"], finals[name],
                                       rtol=2e-5, atol=2e-6)


# --------------------------- checkpoint portability --------------------------


@needs_devices(K * M)
class TestCheckpoint1Dto2D:
    @pytest.mark.parametrize("kind", KINDS)
    def test_both_directions_bit_identical(self, kind, tmp_path, mesh1d,
                                           mesh2d):
        """save on the 1D worker mesh -> restore onto the 2D mesh (and
        back): portable leaf values bit-identical, layout re-sharded to
        the like-state's row_shards, placement the like-state's; the
        restored state keeps stepping in lockstep."""
        from repro.checkpoint import restore, save

        params = ragged_tree(KEY, K)
        ax1 = make_optimizer(kind, K=K, eta=1e-2, backend="pallas",
                             comm="axis", mesh=mesh1d)
        ax2 = make_optimizer(kind, K=K, eta=1e-2, backend="pallas",
                             comm="axis", mesh=mesh2d)
        s1 = ax1.init(jax.tree_util.tree_map(jnp.copy, params))
        s1 = ax1.step(s1, 0.3 * s1.buf)

        # 1D -> 2D
        path = str(tmp_path / "ck1d.npz")
        save(path, s1, step=1)
        like2 = ax2.init(jax.tree_util.tree_map(jnp.copy, params))
        r2, step = restore(path, like2)
        assert step == 1
        assert r2.spec.row_shards == M
        assert r2.buf.sharding == like2.buf.sharding
        for a, b in zip(jax.tree_util.tree_leaves(s1.unpacked()),
                        jax.tree_util.tree_leaves(r2.unpacked())):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        # 2D -> 1D
        path2 = str(tmp_path / "ck2d.npz")
        save(path2, r2, step=2)
        r1, step = restore(path2, ax1.init(
            jax.tree_util.tree_map(jnp.copy, params)))
        assert step == 2
        assert r1.spec.row_shards == 1
        np.testing.assert_array_equal(np.asarray(r1.buf), np.asarray(s1.buf))

        # restored 2D state steps in lockstep with the 1D original
        o2 = ax2.step(r2, 0.3 * r2.buf)
        o1 = ax1.step(s1, 0.3 * s1.buf)
        for a, b in zip(jax.tree_util.tree_leaves(o1.unpacked()),
                        jax.tree_util.tree_leaves(o2.unpacked())):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=1e-6)


# ------------------- byte accounting unchanged by 'model' --------------------


@needs_devices(K * M)
class TestCommBytes2D:
    @pytest.mark.parametrize("kind", KINDS)
    def test_model_axis_does_not_inflate_bytes(self, kind, mesh2d):
        """Per-round wire bytes are a per-worker quantity: sharding each
        worker over M model devices must not change the accounting
        (extends the PR 2 degree-from-weight-matrix fix)."""
        params = ragged_tree(KEY, K)
        stacked = make_optimizer(kind, K=K, eta=1e-2, backend="pallas")
        axis2 = make_optimizer(kind, K=K, eta=1e-2, backend="pallas",
                               comm="axis", mesh=mesh2d)
        want = stacked.comm_bytes_per_round(params)
        state2 = axis2.init(jax.tree_util.tree_map(jnp.copy, params))
        got = axis2.comm_bytes_per_round(axis2.params_of(state2))
        assert got == want > 0
