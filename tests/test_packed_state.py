"""Packed-resident optimizer state (backend='pallas') invariants.

Pins the acceptance criteria of the resident-layout refactor:

* ``step`` / ``round_step`` on packed states perform ZERO ``pack`` /
  ``unpack`` calls in steady state (counted via monkeypatch on the
  un-jitted step, so even trace-time calls are caught) — packing happens
  once in ``init``; unpacking only at ``params_of`` / checkpoint / eval
  boundaries,
* the ``kernels/gossip.py`` Pallas kernels match the reference roll
  mixing and CD-Adam consensus update,
* buffer padding stays exactly zero across steps (the resident-layout
  soundness invariant),
* checkpoints are backend-portable: save under 'pallas', restore under
  'reference' (and back) bit-identically, incl. bfloat16 moments and the
  tuple-of-pytrees ``hat_nbrs``,
* ``comm_bytes_per_round`` counts true graph degree for dense/non-shift
  topologies (regression: it returned 0),
* the trainer's differentiate-through-unpack grad path matches the
  reference backend end to end.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore, save
from repro.core import (cdadam, dadam, is_packed_state, make_optimizer,
                        make_topology)
from repro.core.cdadam import CDAdamConfig
from repro.core.dadam import DAdamConfig, PackedDAdamState, gossip_roll
from repro.kernels import ops
from repro.kernels import pack as packing

KEY = jax.random.PRNGKey(0)


def ragged_tree(key, K, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "w": jax.random.normal(ks[0], (K, 13, 7), dtype),
        "b": jax.random.normal(ks[1], (K, 5), dtype),
        "nest": {"u": jax.random.normal(ks[2], (K, 3, 11, 2), dtype)},
    }


def assert_trees_equal(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                   np.asarray(y)), a, b)


# -------------------- zero pack/unpack in steady state ----------------------


class _PackCounter:
    """Monkeypatch harness counting packing.pack / packing.unpack calls."""

    def __init__(self, monkeypatch):
        self.calls = {"pack": 0, "unpack": 0}
        orig_pack, orig_unpack = packing.pack, packing.unpack

        def count_pack(*a, **k):
            self.calls["pack"] += 1
            return orig_pack(*a, **k)

        def count_unpack(*a, **k):
            self.calls["unpack"] += 1
            return orig_unpack(*a, **k)

        monkeypatch.setattr(packing, "pack", count_pack)
        monkeypatch.setattr(packing, "unpack", count_unpack)


class TestZeroRepackSteadyState:
    @pytest.mark.parametrize("kind", ["d-adam", "cd-adam"])
    def test_step_is_resident(self, kind, monkeypatch):
        """Un-jitted packed step with packed grads: zero pack/unpack even
        at trace level, for both the comm and no-comm branches."""
        opt = make_optimizer(kind, K=4, eta=1e-2, period=2,
                             backend="pallas")
        state = opt.init(ragged_tree(KEY, K=4))
        assert is_packed_state(state)
        gbuf = 0.5 * state.buf
        counter = _PackCounter(monkeypatch)
        for _ in range(4):
            state = opt.step(state, gbuf)
        assert counter.calls == {"pack": 0, "unpack": 0}

    @pytest.mark.parametrize("kind", ["d-adam", "cd-adam"])
    def test_round_step_is_resident(self, kind, monkeypatch):
        """round_step hands grad_fn the resident buffer; with a buffer
        grad_fn the whole round (p fused local steps + gossip) performs
        zero pack/unpack."""
        opt = make_optimizer(kind, K=4, eta=1e-2, period=3,
                             backend="pallas")
        state = opt.init(ragged_tree(KEY, K=4))
        batches = jnp.zeros((3, 4, 1))  # p microbatches, unused by grad_fn
        grad_fn = lambda buf, batch: 0.5 * buf
        counter = _PackCounter(monkeypatch)
        state = opt.round(state, grad_fn, batches)
        assert counter.calls == {"pack": 0, "unpack": 0}
        assert int(state.count) == 3

    def test_pytree_grads_pack_once_at_boundary(self, monkeypatch):
        """Convenience path: pytree grads are packed exactly once per step
        (the boundary pack), never unpacked."""
        opt = make_optimizer("d-adam", K=4, eta=1e-2, backend="pallas")
        state = opt.init(ragged_tree(KEY, K=4))
        grads = jax.tree_util.tree_map(lambda x: 0.1 * x, state.params)
        counter = _PackCounter(monkeypatch)
        opt.step(state, grads)
        assert counter.calls == {"pack": 1, "unpack": 0}

    def test_shape_mismatched_buffer_grads_rejected(self):
        opt = make_optimizer("d-adam", K=4, backend="pallas")
        state = opt.init(ragged_tree(KEY, K=4))
        with pytest.raises(ValueError, match="packed grads"):
            opt.step(state, state.buf[:, :-1])

    def test_bare_array_grads_accepted(self):
        """A bare-array params tree (valid under backend='reference') must
        keep accepting bare-array grads under 'pallas' — regression: any
        jax.Array used to be treated as an already-packed buffer."""
        for backend in ("reference", "pallas"):
            opt = make_optimizer("d-adam", K=4, eta=1e-2, backend=backend)
            state = opt.init(jnp.ones((4, 37)))
            state = opt.step(state, 0.1 * jnp.ones((4, 37)))
        np.testing.assert_allclose(
            np.asarray(opt.params_of(state)),
            np.asarray(opt.params_of(opt.step(
                make_optimizer("d-adam", K=4, eta=1e-2,
                               backend="reference").init(jnp.ones((4, 37))),
                0.1 * jnp.ones((4, 37))))),
            rtol=2e-5, atol=2e-6)


# ------------------------- gossip kernel parity -----------------------------


class TestGossipKernel:
    @pytest.mark.parametrize("name", ["ring", "exponential",
                                      "fully_connected"])
    def test_mix_matches_reference_roll(self, name):
        topo = make_topology(name, 8)
        buf = jax.random.normal(KEY, (8, 256, 128))
        out = ops.gossip_mix(buf, topo.offsets, topo.offset_weights,
                             topo.self_weight)
        ref = gossip_roll({"x": buf}, topo)["x"]
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)

    def test_mix_matches_dense_einsum(self):
        topo = make_topology("ring", 5)
        buf = jax.random.normal(KEY, (5, 256, 128))
        out = ops.gossip_mix(buf, topo.offsets, topo.offset_weights,
                             topo.self_weight)
        W = jnp.asarray(topo.weights, jnp.float32)
        ref = jnp.einsum("kj,jrc->krc", W, buf)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_consensus_matches_reference(self):
        topo = make_topology("ring", 6)
        cfg = CDAdamConfig(gamma=0.37)
        ks = jax.random.split(KEY, 2 + len(topo.offsets))
        x = jax.random.normal(ks[0], (6, 256, 128))
        hs = jax.random.normal(ks[1], (6, 256, 128))
        hns = tuple(jax.random.normal(k, (6, 256, 128)) for k in ks[2:])
        out = ops.consensus_mix(x, hs, hns, topo.offset_weights, cfg.gamma)
        ref = cdadam._mix_with_hats({"x": x}, {"x": hs},
                                    tuple({"x": h} for h in hns), topo,
                                    cfg)["x"]
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)

    def test_misaligned_buffer_rejected(self):
        with pytest.raises(ValueError, match="rows"):
            ops.gossip_mix(jnp.zeros((4, 100, 128)), (1,), (0.5,), 0.5)
        with pytest.raises(ValueError, match="buffer"):
            ops.gossip_mix(jnp.zeros((4, 256)), (1,), (0.5,), 0.5)


# ---------------------- resident-layout soundness ---------------------------


class TestResidentInvariants:
    @pytest.mark.parametrize("kind", ["d-adam", "cd-adam"])
    def test_padding_stays_zero_across_steps(self, kind):
        """repack(unpack(buf)) == buf bitwise after many steps — i.e. the
        kernels never leak nonzero values into the tile padding, so the
        resident buffer and its pytree view stay interchangeable."""
        opt = make_optimizer(kind, K=4, eta=1e-2, period=2,
                             weight_decay=0.01, backend="pallas")
        state = opt.init(ragged_tree(KEY, K=4))
        step = jax.jit(lambda s, g, opt=opt: opt.step(s, g))
        for t in range(6):
            g = jax.tree_util.tree_map(
                lambda x: 0.5 * x + 0.01 * (t + 1), opt.params_of(state))
            state = step(state, g)
        np.testing.assert_array_equal(
            np.asarray(packing.pack(state.params, state.spec)),
            np.asarray(state.buf))
        if kind == "cd-adam":
            np.testing.assert_array_equal(
                np.asarray(packing.pack(state.hat_self, state.spec)),
                np.asarray(state.hat_buf))

    def test_views_match_reference_init(self):
        params = ragged_tree(KEY, K=4)
        cfg = DAdamConfig(backend="pallas", moment_dtype=jnp.bfloat16)
        state = dadam.init(params, cfg)
        assert isinstance(state, PackedDAdamState)
        assert_trees_equal(state.params, params)
        assert state.moments.m["w"].dtype == jnp.bfloat16
        assert int(state.moments.count) == 0
        ref = dadam.init(params, DAdamConfig(moment_dtype=jnp.bfloat16))
        assert_trees_equal(state.unpacked().params, ref.params)
        assert_trees_equal(state.moments.m, ref.moments.m)


# ---------------------- checkpoint backend portability ----------------------


class TestCheckpointPortability:
    def _stepped_states(self, kind, tmp_path, steps=3):
        """The same 3-step trajectory under both backends (they are
        allclose but not bit-identical; portability is asserted per
        backend against its own checkpoint)."""
        params = ragged_tree(KEY, K=4)
        out = {}
        for backend in ("reference", "pallas"):
            opt = make_optimizer(kind, K=4, eta=1e-2, period=2,
                                 moment_dtype=jnp.bfloat16,
                                 backend=backend)
            s = opt.init(jax.tree_util.tree_map(jnp.copy, params))
            step = jax.jit(lambda s, g, opt=opt: opt.step(s, g))
            for t in range(steps):
                g = jax.tree_util.tree_map(
                    lambda x: 0.5 * x + 0.01 * (t + 1), opt.params_of(s))
                s = step(s, g)
            out[backend] = (opt, s)
        return out

    @pytest.mark.parametrize("kind", ["d-adam", "cd-adam"])
    def test_pallas_save_restores_under_reference(self, kind, tmp_path):
        """Save a packed state; restore into a reference-backend state:
        bit-identical params AND bfloat16 moments (and hat trees)."""
        states = self._stepped_states(kind, tmp_path)
        _, packed = states["pallas"]
        ref_opt, ref_state = states["reference"]
        path = str(tmp_path / "packed.npz")
        save(path, packed, step=3)
        restored, step = restore(path, ref_state)
        assert step == 3
        assert type(restored) is type(ref_state)
        assert_trees_equal(restored.params, packed.params)
        assert restored.moments.m["w"].dtype == jnp.bfloat16
        assert_trees_equal(restored.moments.m, packed.moments.m)
        assert_trees_equal(restored.moments.v, packed.moments.v)
        if kind == "cd-adam":
            assert_trees_equal(restored.hat_self, packed.hat_self)
            assert len(restored.hat_nbrs) == len(packed.hat_nbrs)
            for hr, hp in zip(restored.hat_nbrs, packed.hat_nbrs):
                assert_trees_equal(hr, hp)

    @pytest.mark.parametrize("kind", ["d-adam", "cd-adam"])
    def test_reference_save_restores_into_packed(self, kind, tmp_path):
        """The reverse direction: a reference-backend checkpoint restores
        into a packed like-state and the resident buffers reproduce it
        bit-for-bit (and the restored state still steps)."""
        states = self._stepped_states(kind, tmp_path)
        ref_opt, ref_state = states["reference"]
        pal_opt, packed = states["pallas"]
        path = str(tmp_path / "ref.npz")
        save(path, ref_state, step=3)
        restored, _ = restore(path, packed)
        assert is_packed_state(restored)
        assert_trees_equal(restored.params, ref_state.params)
        assert_trees_equal(restored.moments.m, ref_state.moments.m)
        assert int(restored.count) == int(ref_state.moments.count)
        restored = pal_opt.step(restored, 0.1 * restored.buf)  # still live
        assert int(restored.count) == 4

    def test_cdadam_reference_roundtrip_with_hat_nbrs(self, tmp_path):
        """Plain CDAdamState (tuple-of-pytrees hat_nbrs) round-trips —
        regression for the tuple flatten/ordering and bf16 moments."""
        _, state = self._stepped_states("cd-adam", tmp_path)["reference"]
        path = str(tmp_path / "cd.npz")
        save(path, state, step=7)
        like = jax.tree_util.tree_map(jnp.zeros_like, state)
        restored, step = restore(path, like)
        assert step == 7
        assert_trees_equal(restored, state)


# ------------------------ comm-bytes accounting -----------------------------


class TestCommBytesPerRound:
    def _params(self, K):
        return {"w": jnp.zeros((K, 10, 10)), "b": jnp.zeros((K, 3))}

    def test_torus_offsets_agree_with_weight_matrix_degree(self):
        """Regression (updated): torus(2x2) used to carry no shift offsets
        and fell back to weight-matrix-degree accounting; its wrap-aware
        GridShift offsets now drive both the roll lowering and the byte
        accounting, and the two countings must agree."""
        opt = make_optimizer("d-adam", K=4, topology="torus")
        params = self._params(4)
        per_worker_bytes = 103 * 4
        deg = len(opt.topo.neighbors_of(0))
        assert deg > 0 and len(opt.topo.offsets) == deg
        assert opt.comm_bytes_per_round(params) == deg * per_worker_bytes

    def test_dense_mixing_counts_weight_matrix_degree(self):
        """mixing='dense' ignores the shift offsets at runtime; the
        accounting must follow the weight matrix, not the offsets."""
        opt = make_optimizer("d-adam", K=6, topology="ring", mixing="dense")
        params = self._params(6)
        assert opt.comm_bytes_per_round(params) == 2 * 103 * 4

    def test_ring_roll_unchanged(self):
        opt = make_optimizer("d-adam", K=6, topology="ring")
        params = self._params(6)
        assert opt.comm_bytes_per_round(params) == \
            len(opt.topo.offsets) * 103 * 4

    def test_single_worker_sends_nothing(self):
        opt = make_optimizer("d-adam", K=1, topology="ring")
        assert opt.comm_bytes_per_round(self._params(1)) == 0

    def test_cdadam_compressed_bytes(self):
        opt = make_optimizer("cd-adam", K=4, topology="ring",
                             compressor="sign")
        params = self._params(4)
        # sign wire format: 1 byte/elem + 4-byte scale per leaf
        assert opt.comm_bytes_per_round(params) == \
            len(opt.topo.offsets) * (100 + 4 + 3 + 4)


# ------------------- trainer end-to-end (packed grads) ----------------------


class TestTrainerPackedPath:
    @pytest.mark.parametrize("kind", ["d-adam", "cd-adam"])
    def test_fit_matches_reference_backend(self, kind):
        """DecentralizedTrainer differentiates through unpack for packed
        states; the whole fit loop must track the reference backend."""
        from repro.train import DecentralizedTrainer

        K, d = 4, 37  # deliberately lane-hostile
        centers = jax.random.normal(KEY, (K, d))

        def loss_fn(params, batch):
            return jnp.sum((params["x"] - batch) ** 2)

        def batch_iter():
            t = 0
            while True:
                yield centers + 0.01 * t
                t += 1

        logs = {}
        for backend in ("reference", "pallas"):
            opt = make_optimizer(kind, K=K, eta=5e-2, period=2,
                                 backend=backend)
            trainer = DecentralizedTrainer(loss_fn, opt)
            state = trainer.init({"x": jnp.zeros((d,))})
            assert is_packed_state(state) == (backend == "pallas")
            state, log = trainer.fit(state, batch_iter(), 6, log_every=2)
            logs[backend] = (log, opt.params_of(state))
        ref_log, ref_params = logs["reference"]
        pal_log, pal_params = logs["pallas"]
        np.testing.assert_allclose(ref_log.loss, pal_log.loss,
                                   rtol=2e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(ref_params["x"]),
                                   np.asarray(pal_params["x"]),
                                   rtol=2e-5, atol=2e-6)
