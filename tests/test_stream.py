"""Device-prefetched streaming (``data.stream``)."""
import jax
import numpy as np
import pytest

from repro.data import ctr_stream, make_ctr_task, prefetch_to_device
from repro.data.synthetic import ctr_batch_stacked


class TestPrefetch:
    def test_order_and_values_preserved(self):
        batches = [{"x": np.full((3,), i)} for i in range(7)]
        out = list(prefetch_to_device(iter(batches), size=2))
        assert len(out) == 7
        for i, b in enumerate(out):
            np.testing.assert_array_equal(np.asarray(b["x"]),
                                          batches[i]["x"])
            assert isinstance(b["x"], jax.Array)

    def test_window_shorter_than_iterator(self):
        # size larger than the finite iterator must not hang or drop
        out = list(prefetch_to_device(iter([{"x": np.ones(2)}]), size=8))
        assert len(out) == 1

    def test_size_validated(self):
        with pytest.raises(ValueError, match="size"):
            list(prefetch_to_device(iter([]), size=0))

    def test_placer_wins_over_sharding(self):
        calls = []

        def placer(b):
            calls.append(1)
            return jax.device_put(b)

        shard = jax.sharding.SingleDeviceSharding(jax.devices()[0])
        out = list(prefetch_to_device(
            iter([{"x": np.ones(2)}] * 3), size=2, sharding=shard,
            placer=placer))
        assert len(out) == 3 and len(calls) == 3

    def test_prefetch_is_lazy_window(self):
        """Only ``size`` batches are pulled ahead of the consumer."""
        pulled = []

        def gen():
            for i in range(10):
                pulled.append(i)
                yield {"x": np.full((1,), i)}

        it = prefetch_to_device(gen(), size=2)
        first = next(it)
        # one consumed + one refill on top of the initial window of 2
        assert len(pulled) == 3
        np.testing.assert_array_equal(np.asarray(first["x"]), [0.0])


class TestCtrStream:
    def test_deterministic_in_seed_and_step(self):
        task = make_ctr_task(seed=0, n_fields=4, features_per_field=8)
        a = ctr_stream(task, K=2, per_worker=4, seed=5)
        b = ctr_stream(task, K=2, per_worker=4, seed=5)
        for _ in range(3):
            ba, bb = next(a), next(b)
            jax.tree_util.tree_map(
                lambda x, y: np.testing.assert_array_equal(
                    np.asarray(x), np.asarray(y)), ba, bb)

    def test_matches_fold_in_contract(self):
        """Step t equals ``ctr_batch_stacked`` under fold_in(seed, t) —
        prefetch depth can never change the data."""
        task = make_ctr_task(seed=0, n_fields=4, features_per_field=8)
        key = jax.random.PRNGKey(5)
        stream = prefetch_to_device(
            ctr_stream(task, K=2, per_worker=4, seed=5, skew=0.5), size=3)
        for t in range(4):
            got = next(stream)
            want = ctr_batch_stacked(task, jax.random.fold_in(key, t), 2,
                                     4, 0.5)
            jax.tree_util.tree_map(
                lambda x, y: np.testing.assert_array_equal(
                    np.asarray(x), np.asarray(y)), got, want)

    def test_shapes(self):
        task = make_ctr_task(seed=0, n_fields=4, features_per_field=8)
        batch = next(ctr_stream(task, K=3, per_worker=5))
        assert batch["feat_ids"].shape[:2] == (3, 5)
        assert batch["label"].shape == (3, 5)
