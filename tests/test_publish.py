"""The train→serve publish path: unpack-once decode + lock-free store.

Pins the hot-swap acceptance criteria:

* ``kernels.pack.unpack_worker`` / ``unpack_mean`` match the full K-way
  ``unpack`` bit-for-bit (flat and row-sharded layouts) — the publish
  never needs the K-tree materialization it replaces,
* ``publish_params`` ≡ ``opt.params_of(state)`` for BOTH backends after
  real training steps (and under a worker mesh when devices allow),
* ``ParamStore`` versions are monotone and readers always see a complete
  snapshot — every leaf of a concurrent read comes from ONE publish,
  never a mix.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_optimizer
from repro.kernels import pack as packing
from repro.serve import ParamStore, publish_from_state, publish_hbm_bytes, \
    publish_params

KEY = jax.random.PRNGKey(0)
K = 4


def ragged_tree(key, k, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "w": jax.random.normal(ks[0], (k, 13, 7), dtype),
        "b": jax.random.normal(ks[1], (k, 5), dtype),
        "nest": {"u": jax.random.normal(ks[2], (k, 3, 11, 2), dtype)},
    }


def assert_trees_equal(a, b):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(np.asarray(x),
                                                   np.asarray(y)), a, b)


def grads_like(params, seed):
    leaves, treedef = jax.tree_util.tree_flatten(params)
    ks = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    return jax.tree_util.tree_unflatten(
        treedef, [jax.random.normal(k, l.shape, l.dtype)
                  for k, l in zip(ks, leaves)])


# --------------------------- unpack-once parity ------------------------------


class TestUnpackOnce:
    @pytest.mark.parametrize("layout", ["flat", "leaf_align", "sharded"])
    def test_unpack_worker_matches_full_unpack(self, layout):
        tree = ragged_tree(KEY, K)
        kw = {"flat": {},
              "leaf_align": {"leaf_align": True, "block_rows": 2},
              "sharded": {"leaf_align": True, "block_rows": 2,
                          "row_shards": 2}}[layout]
        spec = packing.make_spec(tree, stacked=True, **kw)
        buf = packing.pack(tree, spec)
        full = packing.unpack(buf, spec)
        for k in range(K):
            one = packing.unpack_worker(buf, spec, k)
            assert_trees_equal(
                one, jax.tree_util.tree_map(lambda x: x[k], full))

    def test_unpack_mean_matches_mean_of_full_unpack(self):
        tree = ragged_tree(KEY, K)
        spec = packing.make_spec(tree, stacked=True, leaf_align=True,
                                 block_rows=2)
        buf = packing.pack(tree, spec)
        full = packing.unpack(buf, spec)
        mean = packing.unpack_mean(buf, spec)
        # f32 throughout: the packed-domain mean is the same sum in the
        # same order, so bitwise equality holds
        assert_trees_equal(
            mean, jax.tree_util.tree_map(lambda x: x.mean(axis=0), full))

    def test_unpack_worker_validates(self):
        tree = ragged_tree(KEY, K)
        spec = packing.make_spec(tree, stacked=True)
        buf = packing.pack(tree, spec)
        with pytest.raises(ValueError, match="worker"):
            packing.unpack_worker(buf, spec, K)
        flat_spec = packing.make_spec(
            jax.tree_util.tree_map(lambda x: x[0], tree))
        flat_buf = packing.pack(
            jax.tree_util.tree_map(lambda x: x[0], tree), flat_spec)
        with pytest.raises(ValueError, match="stacked"):
            packing.unpack_worker(flat_buf, flat_spec, 0)


# ------------------------ publish_params ≡ params_of -------------------------


class TestPublishParity:
    @pytest.mark.parametrize("backend", ["reference", "pallas"])
    def test_worker_mode_matches_params_of(self, backend):
        opt = make_optimizer("d-adam", K=K, eta=1e-2, period=2,
                             backend=backend)
        state = opt.init(ragged_tree(KEY, K))
        for t in range(3):
            state = opt.step(state, grads_like(opt.params_of(state), t))
        ref = opt.params_of(state)
        for k in range(K):
            assert_trees_equal(
                publish_params(state, mode="worker", worker=k),
                jax.tree_util.tree_map(lambda x: x[k], ref))

    @pytest.mark.parametrize("backend", ["reference", "pallas"])
    def test_mean_mode_matches_mean_of_params_of(self, backend):
        opt = make_optimizer("d-adam", K=K, eta=1e-2, period=2,
                             backend=backend)
        state = opt.init(ragged_tree(KEY, K))
        for t in range(3):
            state = opt.step(state, grads_like(opt.params_of(state), t))
        ref = jax.tree_util.tree_map(
            lambda x: x.astype(jnp.float32).mean(axis=0).astype(x.dtype),
            opt.params_of(state))
        assert_trees_equal(publish_params(state, mode="mean"), ref)

    @pytest.mark.skipif(jax.device_count() < K,
                        reason=f"needs >= {K} devices (tier1.sh forces 8)")
    def test_parity_under_worker_mesh(self):
        mesh = jax.make_mesh((K,), ("worker",))
        opt = make_optimizer("d-adam", K=K, eta=1e-2, period=2,
                             backend="pallas", comm="axis", mesh=mesh)
        state = opt.init(ragged_tree(KEY, K))
        for t in range(2):
            g = packing.pack(grads_like(opt.params_of(state), t),
                             state.spec, dtype=state.buf.dtype)
            state = opt.step(state, g)
        ref = opt.params_of(state)
        assert_trees_equal(
            publish_params(state, mode="worker", worker=1),
            jax.tree_util.tree_map(lambda x: x[1], ref))

    @pytest.mark.skipif(jax.device_count() < 4,
                        reason="needs >= 4 devices (tier1.sh forces 8)")
    def test_parity_under_2d_mesh(self):
        mesh = jax.make_mesh((2, 2), ("worker", "model"))
        opt = make_optimizer("d-adam", K=2, eta=1e-2, period=2,
                             backend="pallas", comm="axis", mesh=mesh)
        state = opt.init(ragged_tree(KEY, 2))
        for t in range(2):
            g = packing.pack(grads_like(opt.params_of(state), t),
                             state.spec, dtype=state.buf.dtype)
            state = opt.step(state, g)
        ref = opt.params_of(state)
        assert_trees_equal(
            publish_params(state, mode="worker", worker=0),
            jax.tree_util.tree_map(lambda x: x[0], ref))

    def test_plain_stacked_tree_and_reference_state(self):
        tree = ragged_tree(KEY, K)
        assert_trees_equal(
            publish_params(tree, mode="worker", worker=2),
            jax.tree_util.tree_map(lambda x: x[2], tree))

    def test_mode_validated(self):
        with pytest.raises(ValueError, match="mode"):
            publish_params(ragged_tree(KEY, K), mode="median")

    def test_hbm_accounting(self):
        opt = make_optimizer("d-adam", K=K, backend="pallas")
        state = opt.init(ragged_tree(KEY, K))
        w = publish_hbm_bytes(state, mode="worker")
        m = publish_hbm_bytes(state, mode="mean")
        # worker mode reads exactly 1/K of the resident buffer
        assert w["read_bytes"] * K == w["full_unpack_read_bytes"]
        assert w["read_bytes"] == state.buf.nbytes // K
        # both modes write ONE tree, not K
        assert w["write_bytes"] * K == w["full_unpack_write_bytes"]
        assert m["write_bytes"] == w["write_bytes"]


# -------------------------------- ParamStore ---------------------------------


class TestParamStore:
    def test_versions_monotone(self):
        store = ParamStore()
        assert store.version == 0
        with pytest.raises(ValueError, match="empty"):
            store.snapshot()
        versions = [store.publish({"w": jnp.full((3,), float(i))})
                    for i in range(5)]
        assert versions == [1, 2, 3, 4, 5]
        v, params = store.snapshot()
        assert v == 5 and float(params["w"][0]) == 4.0

    def test_publish_from_state_bumps_version(self):
        opt = make_optimizer("d-adam", K=K, backend="pallas")
        state = opt.init(ragged_tree(KEY, K))
        store = ParamStore()
        assert publish_from_state(store, state, mode="worker") == 1
        assert publish_from_state(store, state, mode="mean") == 2
        assert_trees_equal(store.snapshot()[1],
                           publish_params(state, mode="mean"))

    def test_reader_always_sees_complete_snapshot(self):
        """Concurrency property: under a publisher storm, every snapshot
        a reader takes is internally consistent — all leaves encode the
        SAME version, and versions never run backwards per reader."""
        store = ParamStore()

        def tree_for(v):
            return {"a": np.full((4,), v), "n": {"b": np.full((2,), v)}}

        store.publish(tree_for(1))
        stop = threading.Event()
        torn, regressions = [], []

        def reader():
            last = 0
            while not stop.is_set():
                version, params = store.snapshot()
                vals = {float(x) for x in
                        np.concatenate([params["a"], params["n"]["b"]])}
                if len(vals) != 1 or vals != {float(version)}:
                    torn.append((version, vals))
                if version < last:
                    regressions.append((last, version))
                last = version

        threads = [threading.Thread(target=reader) for _ in range(4)]
        for t in threads:
            t.start()
        for v in range(2, 200):
            store.publish(tree_for(v))
        stop.set()
        for t in threads:
            t.join()
        assert not torn, f"torn snapshots: {torn[:3]}"
        assert not regressions, f"version regressions: {regressions[:3]}"
        assert store.version == 199

    def test_concurrent_publishers_never_lose_versions(self):
        store = ParamStore()
        seen = []
        lock = threading.Lock()

        def publisher(i):
            for _ in range(50):
                v = store.publish({"w": np.zeros((1,))})
                with lock:
                    seen.append(v)

        threads = [threading.Thread(target=publisher, args=(i,))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert sorted(seen) == list(range(1, 201))

    def test_previous_version_stays_resident(self):
        """Two-slot ring: the buffers behind version v stay untouched
        while v+1 lands — a decode holding v keeps valid arrays."""
        store = ParamStore()
        store.publish({"w": np.full((3,), 1.0)})
        _, held = store.snapshot()
        store.publish({"w": np.full((3,), 2.0)})
        np.testing.assert_array_equal(held["w"], np.full((3,), 1.0))
