"""Time-varying topology schedules: every entry must satisfy the same
Definition-1 invariants a static graph does, the union machinery must
align per-edge state across entries, and the named constructors must
mix (one-peer-exponential reaches every peer within log2 K rounds)."""
import numpy as np
import pytest

from repro.core.schedule import (TopologySchedule, comm_offsets,
                                 make_schedule, one_peer_exponential,
                                 randomized_rings, static_schedule)
from repro.core.topology import make_topology, offset_perm, offsets_matrix, ring


def _schedules(K):
    out = [("one-peer-exp", one_peer_exponential(K)),
           ("rand-rings", randomized_rings(K, n_entries=4, seed=0)),
           ("static-ring", static_schedule(ring(K)))]
    return out


@pytest.mark.parametrize("K", [2, 3, 4, 8, 16, 32])
def test_every_entry_doubly_stochastic_and_offsets_consistent(K):
    """Each round's graph is a real Definition-1 mixing matrix AND its
    shift lowering hits the advertised neighbors (offsets == weights) —
    the schedule extension of the torus headline invariant."""
    for name, sched in _schedules(K):
        for topo in sched.entries:
            W = topo.weights
            assert np.allclose(W, W.T), name
            assert np.allclose(W.sum(0), 1.0), name
            assert np.all(W >= -1e-12), name
            assert np.allclose(offsets_matrix(topo), W, atol=1e-12), name


@pytest.mark.parametrize("K", [2, 4, 8, 16, 32])
def test_one_peer_exponential_covers_all_peers(K):
    """Within one cycle (log2 K rounds) every worker has exchanged with a
    set of peers whose union graph is connected."""
    sched = one_peer_exponential(K)
    assert sched.n_entries == max(int(np.log2(K)), 1)
    U = sum(t.weights for t in sched.entries) / sched.n_entries
    # connected union: the second-largest |eigenvalue| of the mean mixing
    # matrix is strictly below 1
    assert sched.spectral_gap > 1e-3
    assert np.allclose(U, sched.mean_weights)


def test_at_is_cyclic():
    sched = randomized_rings(8, n_entries=3, seed=1)
    for r in range(9):
        assert sched.at(r) is sched.entries[r % 3]


@pytest.mark.parametrize("K", [4, 8, 16])
def test_union_views_align_per_edge_state(K):
    """union_views re-expresses every entry over the union offset tuple:
    same offsets everywhere (so per-edge buffers line up), zero weight on
    an entry's inactive edges, and an unchanged mixing matrix."""
    sched = one_peer_exponential(K)
    union = sched.union_offsets()
    views = sched.union_views()
    assert len(views) == sched.n_entries
    for entry, view in zip(sched.entries, views):
        assert view.offsets == union
        assert np.allclose(view.weights, entry.weights)
        active = {tuple(offset_perm(o, K)) for o in entry.offsets}
        for o, w in zip(view.offsets, view.offset_weights):
            if tuple(offset_perm(o, K)) not in active:
                assert w == 0.0


def test_comm_offsets_static_and_schedule():
    topo = ring(8)
    assert comm_offsets(topo) == tuple(topo.offsets)
    sched = one_peer_exponential(8)
    assert comm_offsets(sched) == sched.union_offsets()


def test_make_schedule_parses_specs():
    s = make_schedule("one-peer-exponential", 8)
    assert isinstance(s, TopologySchedule)
    s2 = make_schedule("randomized-rings:5", 8)
    assert s2.n_entries == 5
    s3 = make_schedule("one_peer_exp", 16)  # underscore + short alias
    assert s3.n_entries == 4
    with pytest.raises(KeyError):
        make_schedule("no-such-schedule", 8)


def test_single_entry_schedule_mirrors_its_topology():
    topo = make_topology("torus", 16)
    sched = static_schedule(topo)
    assert sched.n_entries == 1
    assert sched.at(7) is topo
    assert sched.union_offsets() == tuple(topo.offsets)
    assert np.allclose(sched.mean_weights, topo.weights)
    assert abs(sched.spectral_gap - topo.spectral_gap) < 1e-12


def test_randomized_rings_entries_differ_and_are_seeded():
    a = randomized_rings(8, n_entries=4, seed=3)
    b = randomized_rings(8, n_entries=4, seed=3)
    c = randomized_rings(8, n_entries=4, seed=4)
    for ta, tb in zip(a.entries, b.entries):
        assert np.allclose(ta.weights, tb.weights)
    assert any(not np.allclose(ta.weights, tc.weights)
               for ta, tc in zip(a.entries, c.entries))
    # at least two distinct ring orderings across the cycle
    mats = [t.weights.tobytes() for t in a.entries]
    assert len(set(mats)) >= 2
