"""Model substrate: decode-vs-forward consistency per family, attention
implementations agree, MoE routing invariants."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig
from repro.models import build_model, hybrid, rwkv6, transformer, whisper
from repro.models.attention import flash_attention_xla, sdpa
from repro.models.moe import moe_forward, init_moe

KEY = jax.random.PRNGKey(0)
TOKS = jax.random.randint(KEY, (2, 17), 0, 97)


def dense_cfg(**kw):
    base = dict(arch_id="t", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97)
    base.update(kw)
    return ModelConfig(**base)


def max_err(a, b):
    return float(jnp.max(jnp.abs(a - b)))


class TestDense:
    @pytest.mark.slow
    def test_decode_matches_forward(self):
        cfg = dense_cfg()
        api = build_model(cfg)
        p = api.init(KEY)
        lf, _ = transformer.forward(p, TOKS, cfg)
        _, cache = api.prefill(p, {"tokens": TOKS[:, :16]}, cache_len=20)
        ld, _ = api.decode_step(p, cache, TOKS[:, 16])
        assert max_err(ld, lf[:, 16, :]) < 1e-4

    @pytest.mark.slow
    def test_sliding_window_decode_matches(self):
        cfg = dense_cfg(sliding_window=8)
        api = build_model(cfg)
        p = api.init(KEY)
        lf, _ = transformer.forward(p, TOKS, cfg)
        _, cache = api.prefill(p, {"tokens": TOKS[:, :16]})
        ld, _ = api.decode_step(p, cache, TOKS[:, 16])
        assert max_err(ld, lf[:, 16, :]) < 1e-4

    @pytest.mark.slow
    def test_multi_token_decode_chain(self):
        cfg = dense_cfg()
        api = build_model(cfg)
        p = api.init(KEY)
        toks = jax.random.randint(jax.random.fold_in(KEY, 9), (2, 21), 0, 97)
        lf, _ = transformer.forward(p, toks, cfg)
        _, c = api.prefill(p, {"tokens": toks[:, :17]}, cache_len=21)
        for i in range(17, 21):
            ld, c = api.decode_step(p, c, toks[:, i])
            assert max_err(ld, lf[:, i, :]) < 1e-4

    def test_qkv_bias_variant(self):
        cfg = dense_cfg(qkv_bias=True)
        api = build_model(cfg)
        p = api.init(KEY)
        assert "bq" in jax.tree_util.tree_map(lambda x: x,
                                              p["layers"]["attn"])
        loss = api.loss(p, {"tokens": TOKS})
        assert not bool(jnp.isnan(loss))


class TestChunkedAttention:
    pytestmark = pytest.mark.slow
    @pytest.mark.parametrize("causal,window", [(True, 0), (True, 24),
                                               (False, 0)])
    def test_matches_naive(self, causal, window):
        q = jax.random.normal(KEY, (2, 64, 4, 16))
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 64, 2, 16))
        v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 64, 2, 16))
        ref = sdpa(q, k, v, causal=causal, window=window, impl="naive")
        out = flash_attention_xla(q, k, v, causal=causal, window=window,
                                  chunk_q=16, chunk_kv=16)
        assert max_err(out.reshape(ref.shape), ref) < 1e-5

    @pytest.mark.slow
    def test_grad_matches_naive(self):
        q = jax.random.normal(KEY, (1, 32, 2, 8))
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 32, 2, 8))
        v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 32, 2, 8))
        g1 = jax.grad(lambda q: jnp.sum(flash_attention_xla(
            q, k, v, causal=True, chunk_q=8, chunk_kv=8) ** 2))(q)
        g2 = jax.grad(lambda q: jnp.sum(sdpa(
            q, k, v, causal=True, impl="naive") ** 2))(q)
        assert max_err(g1, g2.reshape(g1.shape)) < 1e-4


class TestMoE:
    @pytest.mark.slow
    def test_decode_matches_forward_with_ample_capacity(self):
        cfg = ModelConfig(arch_id="m", family="moe", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=97,
                          n_experts=4, experts_per_token=2,
                          capacity_factor=4.0, moe_group_size=8)
        api = build_model(cfg)
        p = api.init(KEY)
        lf, _ = transformer.forward(p, TOKS, cfg)
        _, cache = api.prefill(p, {"tokens": TOKS[:, :16]}, cache_len=20)
        ld, _ = api.decode_step(p, cache, TOKS[:, 16])
        assert max_err(ld, lf[:, 16, :]) < 1e-3

    def test_router_mass_conservation(self):
        """With ample capacity, output == weighted sum of expert outputs;
        a constant-function expert set must reproduce constants."""
        params = init_moe(KEY, 32, 64, 4, jnp.float32)
        # zero expert weights => expert output 0 => moe output 0
        zero = jax.tree_util.tree_map(jnp.zeros_like, params)
        zero["router"] = params["router"]
        x = jax.random.normal(KEY, (2, 8, 32))
        out, aux = moe_forward(zero, x, top_k=2, capacity_factor=4.0,
                               group_size=8)
        assert float(jnp.max(jnp.abs(out))) == 0.0
        assert float(aux) > 0.0

    @pytest.mark.slow
    def test_top1_vs_top2_flops_visible(self):
        params = init_moe(KEY, 32, 64, 8, jnp.float32)
        x = jax.random.normal(KEY, (1, 16, 32))
        o1, _ = moe_forward(params, x, top_k=1, group_size=16)
        o2, _ = moe_forward(params, x, top_k=2, group_size=16)
        assert o1.shape == o2.shape == x.shape
        assert max_err(o1, o2) > 1e-6  # different routing


class TestRWKV:
    pytestmark = pytest.mark.slow
    CFG = ModelConfig(arch_id="r", family="ssm", n_layers=2, d_model=64,
                      n_heads=2, n_kv_heads=2, d_ff=224, vocab_size=97,
                      rwkv_head_size=32, rwkv_decay_rank=8)

    def test_decode_matches_forward(self):
        api = build_model(self.CFG)
        p = api.init(KEY)
        lf, _ = rwkv6.forward(p, TOKS, self.CFG)
        _, c = api.prefill(p, {"tokens": TOKS[:, :16]})
        ld, _ = api.decode_step(p, c, TOKS[:, 16])
        assert max_err(ld, lf[:, 16, :]) < 1e-3

    def test_state_carries_context(self):
        """Same token, different history => different logits (the SSM state
        actually carries information)."""
        api = build_model(self.CFG)
        p = api.init(KEY)
        t1 = jax.random.randint(KEY, (1, 8), 0, 97)
        t2 = jax.random.randint(jax.random.fold_in(KEY, 3), (1, 8), 0, 97)
        _, c1 = api.prefill(p, {"tokens": t1})
        _, c2 = api.prefill(p, {"tokens": t2})
        tok = jnp.asarray([5], jnp.int32)
        l1, _ = api.decode_step(p, c1, tok)
        l2, _ = api.decode_step(p, c2, tok)
        assert max_err(l1, l2) > 1e-4

    def test_decay_in_unit_interval(self):
        p = rwkv6.init_layer(KEY, self.CFG)
        x = jax.random.normal(KEY, (2, 8, 64))
        w = rwkv6._decay(p, x)
        assert float(jnp.min(w)) > 0.0 and float(jnp.max(w)) < 1.0


class TestHybrid:
    pytestmark = pytest.mark.slow
    CFG = ModelConfig(arch_id="z", family="hybrid", n_layers=5, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=97,
                      ssm_state=16, ssm_heads=4, shared_attn_period=2)

    def test_decode_matches_forward(self):
        api = build_model(self.CFG)
        p = api.init(KEY)
        lf, _ = hybrid.forward(p, TOKS, self.CFG)
        _, c = api.prefill(p, {"tokens": TOKS[:, :16]}, cache_len=20)
        ld, _ = api.decode_step(p, c, TOKS[:, 16])
        assert max_err(ld, lf[:, 16, :]) < 1e-3

    def test_shared_block_weight_sharing(self):
        """All attn sites use the same parameters — perturbing the single
        shared block changes every insertion point's output."""
        api = build_model(self.CFG)
        p = api.init(KEY)
        assert hybrid.n_attn_sites(self.CFG) == 2
        l0, _ = hybrid.forward(p, TOKS, self.CFG)
        p2 = jax.tree_util.tree_map(lambda x: x, p)
        p2["shared"]["attn"]["wq"] = p2["shared"]["attn"]["wq"] + 0.1
        l1, _ = hybrid.forward(p2, TOKS, self.CFG)
        assert max_err(l0, l1) > 1e-5


class TestWhisper:
    pytestmark = pytest.mark.slow
    CFG = ModelConfig(arch_id="w", family="audio", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=97,
                      n_encoder_layers=2, n_audio_ctx=10, mlp_kind="gelu",
                      norm_kind="layer")

    def test_decode_matches_forward(self):
        api = build_model(self.CFG)
        p = api.init(KEY)
        ae = jax.random.normal(KEY, (2, 10, 64))
        lf = whisper.forward(p, TOKS, ae, self.CFG)
        _, c = api.prefill(p, {"tokens": TOKS[:, :16], "audio_embeds": ae},
                           cache_len=20)
        ld, _ = api.decode_step(p, c, TOKS[:, 16])
        assert max_err(ld, lf[:, 16, :]) < 1e-3

    def test_audio_conditioning_matters(self):
        api = build_model(self.CFG)
        p = api.init(KEY)
        a1 = jax.random.normal(KEY, (2, 10, 64))
        a2 = jax.random.normal(jax.random.fold_in(KEY, 7), (2, 10, 64))
        l1 = whisper.forward(p, TOKS, a1, self.CFG)
        l2 = whisper.forward(p, TOKS, a2, self.CFG)
        assert max_err(l1, l2) > 1e-4


class TestVLM:
    def test_loss_and_patch_conditioning(self):
        cfg = ModelConfig(arch_id="v", family="vlm", n_layers=2, d_model=64,
                          n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=97,
                          n_patches=4)
        api = build_model(cfg)
        p = api.init(KEY)
        pa = jax.random.normal(KEY, (2, 4, 1024))
        pb = jax.random.normal(jax.random.fold_in(KEY, 11), (2, 4, 1024))
        la = api.loss(p, {"tokens": TOKS, "patches": pa})
        lb = api.loss(p, {"tokens": TOKS, "patches": pb})
        assert not bool(jnp.isnan(la))
        assert abs(float(la) - float(lb)) > 1e-6


def test_remat_policies_equal_loss():
    cfg = dense_cfg()
    api = build_model(cfg)
    p = api.init(KEY)
    batch = {"tokens": TOKS}
    l0 = float(api.loss(p, batch, remat="none"))
    l1 = float(api.loss(p, batch, remat="dots"))
    l2 = float(api.loss(p, batch, remat="full"))
    assert abs(l0 - l1) < 1e-5 and abs(l0 - l2) < 1e-5
