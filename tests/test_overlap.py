"""The comm/compute-overlap machinery (``overlap=True``) and its pins.

Parity pins (the acceptance bar for the overlap wiring):

* CD-Adam ``overlap=True`` is BITWISE the explicit ``staleness=1`` path
  with an all-ones delay table — overlap IS the tau=1 wire schedule,
  over a 10-step trainer run, both backends, period 1 and 3;
* D-Adam overlap implements the uniform delay-1 schedule exactly: round
  r mixes the payloads issued at round r-1 (pure-gossip trace pinned
  against a hand-rolled two-round expectation), and the COLD first round
  is bitwise the synchronous step;
* the fused ``gossip_adam_mix`` kernel is BITWISE the two-pass
  ``fused_adam`` -> ``gossip_mix`` composition across the topology zoo
  (incl. bf16 moments, tau=0, weight decay), and the D-Adam stacked
  dispatch through it changes nothing vs. the two-pass step.

Behavioral pins: overlap composes with time-varying topology schedules
and elastic resize (cold buffers after a membership change), config
validation rejects the ambiguous/unsupported combinations, and
``repro.launch.env`` keeps its append-never-clobber contract.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cdadam, dadam, make_optimizer
from repro.train.loop import DecentralizedTrainer

K = 8


def loss_fn(p, batch):
    pred = batch["x"] @ p["w"] + p["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


def init_params():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    return {"w": jax.random.normal(k1, (6, 1)) * 0.3,
            "b": jax.random.normal(k2, (1,)) * 0.1}


def batches(K, seed=0):
    key = jax.random.PRNGKey(seed)
    while True:
        key, k1 = jax.random.split(key)
        x = jax.random.normal(k1, (K, 8, 6))
        y = jnp.sum(x, axis=-1, keepdims=True)
        yield {"x": x, "y": y}


def params_equal(a, b):
    flat_a = jax.tree_util.tree_leaves(a)
    flat_b = jax.tree_util.tree_leaves(b)
    return all(bool((x == y).all()) for x, y in zip(flat_a, flat_b))


def fit_params(opt, steps=10, seed=0):
    tr = DecentralizedTrainer(loss_fn, opt)
    state = tr.init(init_params())
    state, _ = tr.fit(state, batches(opt.K, seed), steps, log_every=steps)
    return tr.opt.params_of(state)


def all_late_seed(K, deg, tries=512):
    """A straggler seed whose tau=1 delay table is all-ones — the exact
    wire schedule overlap implements. Deterministic, found by search so
    the test never depends on a magic constant staying lucky."""
    for seed in range(tries):
        cfg = cdadam.CDAdamConfig(eta=1e-2, staleness=1,
                                  straggler_rate=0.97, straggler_seed=seed)
        if (cdadam._payload_delays(cfg, K, deg) == 1).all():
            return seed
    raise AssertionError(f"no all-late seed in {tries} tries")


# ------------------------- CD-Adam: overlap == tau=1 -------------------------


@pytest.mark.parametrize("backend", ["reference", "pallas"])
@pytest.mark.parametrize("period", [1, 3])
def test_cdadam_overlap_is_bitwise_tau1(backend, period):
    """overlap=True must be bit-for-bit the explicit staleness=1 path
    when every edge is exactly one round late — the tau=1 wire schedule
    is the overlap schedule, not an approximation of it."""
    kw = dict(eta=1e-2, period=period, backend=backend, topology="ring")
    seed = all_late_seed(K, deg=2)
    p_overlap = fit_params(make_optimizer("cd-adam", K, overlap=True, **kw))
    p_tau1 = fit_params(make_optimizer("cd-adam", K, staleness=1,
                                       straggler_rate=0.97,
                                       straggler_seed=seed, **kw))
    assert params_equal(p_overlap, p_tau1)


def test_cdadam_overlap_delay_table_is_all_ones():
    """The table the rings consume under overlap: every edge delayed by
    exactly one round, regardless of straggler knobs."""
    cfg = cdadam.CDAdamConfig(eta=1e-2, overlap=True)
    assert (cdadam._payload_delays(cfg, K, 2) == 1).all()
    assert cdadam._wire_tau(cfg) == 1


# ----------------------- D-Adam: delay-1 semantics ---------------------------


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_dadam_overlap_first_round_is_synchronous(backend):
    """Cold buffers fold the fresh payload, so a run containing exactly
    one comm round mixes the same payloads as the non-overlap run. The
    comparison is allclose, not bitwise: routing payloads through the
    cold-mask select perturbs XLA's FMA fusion by ~1 ulp (the same
    reason gossip_shift_stale short-circuits tau=0 to the literal
    synchronous mix)."""
    kw = dict(eta=1e-2, period=1, backend=backend, topology="ring")
    p_plain = fit_params(make_optimizer("d-adam", K, **kw), steps=1)
    p_over = fit_params(make_optimizer("d-adam", K, overlap=True, **kw),
                        steps=1)
    assert jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda a, b: jnp.allclose(a, b, rtol=1e-6, atol=1e-7),
        p_plain, p_over))


def test_dadam_overlap_mixes_previous_round_payloads():
    """The delay-1 pin: with zero grads (Adam moves nothing) and period
    1, round 2 must mix the SELF params of round 1 with the neighbor
    payloads ISSUED at round 1 — i.e. shifts of the round-0 params."""
    opt = make_optimizer("d-adam", K, eta=1e-2, period=1, overlap=True,
                         topology="ring", backend="reference")
    topo = opt.topo
    p0 = jax.tree_util.tree_map(
        lambda x: jax.random.normal(jax.random.PRNGKey(3), (K,) + x.shape),
        init_params())
    state = opt.init(p0)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, p0)
    step = jax.jit(opt.step)
    state = step(state, zeros)
    p1 = opt.params_of(state)
    state = step(state, zeros)
    p2 = opt.params_of(state)

    def mix(x, nbrs):
        acc = topo.self_weight * x.astype(jnp.float32)
        for w, nb in zip(topo.offset_weights, nbrs):
            acc = acc + w * nb.astype(jnp.float32)
        return acc.astype(x.dtype)

    def shifts(p):
        return [jax.tree_util.tree_map(
            lambda x, s=s: dadam.shift_worker(x, s, K, None), p)
            for s in topo.offsets]

    def close(a, b, tol=1e-6):
        return jax.tree_util.tree_all(jax.tree_util.tree_map(
            lambda x, y: jnp.allclose(x, y, rtol=tol, atol=tol), a, b))

    # round 1 is cold -> synchronous mix of p0 (up to jit FMA fusion)
    want1 = jax.tree_util.tree_map(
        lambda x, *nbrs: mix(x, nbrs), p0, *shifts(p0))
    assert close(p1, want1)
    # round 2 mixes p1 with the shifts issued at round 1 (of p0!), not
    # fresh shifts of p1 — that is the whole point of the eager schedule
    want2 = jax.tree_util.tree_map(
        lambda x, *nbrs: mix(x, nbrs), p1, *shifts(p0))
    assert close(p2, want2)
    # negative control: the synchronous schedule (fresh shifts of p1)
    # is measurably different, so the pin above really discriminates
    sync2 = jax.tree_util.tree_map(
        lambda x, *nbrs: mix(x, nbrs), p1, *shifts(p1))
    assert not close(p2, sync2)


@pytest.mark.parametrize("kind", ["d-adam", "cd-adam"])
@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_overlap_consensus_contracts(kind, backend):
    """Pure gossip rounds under the delay-1 schedule: consensus error
    must still contract by orders of magnitude — one round of payload
    lag must not destabilize the mixing contraction."""
    opt = make_optimizer(kind, K, topology="ring", eta=1e-2, period=1,
                         backend=backend, overlap=True)
    p0 = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (K,) + x.shape).copy() +
        jax.random.normal(jax.random.PRNGKey(1), (K,) + x.shape),
        init_params())
    state = opt.init(p0)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, p0)
    e0 = float(dadam.consensus_error(opt.params_of(state)))
    step = jax.jit(opt.step)
    for _ in range(60):
        state = step(state, zeros)
    e1 = float(dadam.consensus_error(opt.params_of(state)))
    assert np.isfinite(e1)
    tol = 1e-4 if kind == "d-adam" else 5e-1
    assert e1 < tol * max(e0, 1.0)


@pytest.mark.skipif(jax.device_count() < K,
                    reason="comm='axis' needs one device per worker "
                           "(tier1.sh forces 8 host devices)")
@pytest.mark.parametrize("kind", ["d-adam", "cd-adam"])
def test_overlap_axis_matches_stacked(kind):
    """The sharded comm='axis' execution of the overlap schedule must
    track the stacked simulation."""
    from repro.launch.mesh import make_worker_mesh
    mesh = make_worker_mesh(K)
    kw = dict(eta=1e-2, period=2, topology="ring", overlap=True,
              backend="pallas")
    p_stacked = fit_params(make_optimizer(kind, K, **kw))
    p_axis = fit_params(make_optimizer(kind, K, comm="axis", mesh=mesh,
                                       **kw))
    assert jax.tree_util.tree_all(jax.tree_util.tree_map(
        lambda a, b: jnp.allclose(a, b, atol=1e-6), p_stacked,
        jax.device_get(p_axis)))


# ----------------------- fused gossip+Adam kernel ----------------------------


ZOO = [("ring", 8), ("torus", 8), ("exponential", 8),
       ("fully_connected", 8)]


@pytest.mark.parametrize("name,zk", ZOO)
@pytest.mark.parametrize("weight_decay", [0.0, 1e-4])
def test_gossip_adam_mix_bitwise_two_pass(name, zk, weight_decay):
    """The single-VMEM-pass kernel must be bit-for-bit fused_adam
    followed by gossip_mix: the neighbor half-steps it recomputes
    in-VMEM round through the param dtype exactly like the two-pass
    composition's HBM round-trip."""
    from repro.core.topology import make_topology
    from repro.kernels import ops

    topo = make_topology(name, zk)
    rows = 8
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    p = jax.random.normal(ks[0], (zk, rows, 128), jnp.float32)
    g = jax.random.normal(ks[1], (zk, rows, 128), jnp.float32) * 0.1
    m = jax.random.normal(ks[2], (zk, rows, 128), jnp.float32) * 0.01
    v = jnp.abs(jax.random.normal(ks[3], (zk, rows, 128), jnp.float32)
                ) * 0.01
    kw = dict(eta=1e-2, beta1=0.9, beta2=0.999, tau=1e-6,
              weight_decay=weight_decay)
    p2, m2, v2 = ops.fused_adam(p, g, m, v, **kw)
    want = ops.gossip_mix(p2, topo.offsets, topo.offset_weights,
                          topo.self_weight, block_rows=rows)
    got_p, got_m, got_v = ops.gossip_adam_mix(
        p, g, m, v, topo.offsets, topo.offset_weights, topo.self_weight,
        block_rows=rows, **kw)
    assert bool((got_p == want).all())
    assert bool((got_m == m2).all())
    assert bool((got_v == v2).all())


def test_gossip_adam_mix_bf16_moments_tau0():
    """bf16 moment buffers + the tau=0 rsqrt step variant round-trip the
    kernel's internal f32 math exactly like the two-pass path."""
    from repro.core.topology import make_topology
    from repro.kernels import ops

    topo = make_topology("ring", 8)
    rows = 8
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    p = jax.random.normal(ks[0], (8, rows, 128), jnp.float32)
    g = jax.random.normal(ks[1], (8, rows, 128), jnp.float32) * 0.1
    m = (jax.random.normal(ks[2], (8, rows, 128)) * 0.01).astype(
        jnp.bfloat16)
    v = jnp.abs(jax.random.normal(ks[3], (8, rows, 128)) * 0.01).astype(
        jnp.bfloat16)
    kw = dict(eta=1e-2, tau=0.0)
    p2, m2, v2 = ops.fused_adam(p, g, m, v, **kw)
    want = ops.gossip_mix(p2, topo.offsets, topo.offset_weights,
                          topo.self_weight, block_rows=rows)
    got_p, got_m, got_v = ops.gossip_adam_mix(
        p, g, m, v, topo.offsets, topo.offset_weights, topo.self_weight,
        block_rows=rows, **kw)
    assert got_m.dtype == jnp.bfloat16 and got_v.dtype == jnp.bfloat16
    assert bool((got_p == want).all())
    assert bool((got_m == m2).all())
    assert bool((got_v == v2).all())


def test_gossip_adam_mix_degree_cap():
    from repro.kernels import gossip as gk

    p = jnp.zeros((16, 8, 128))
    too_many = tuple(range(1, gk.MAX_GOSSIP_ADAM_DEGREE + 2))
    with pytest.raises(ValueError, match="degree"):
        gk.gossip_adam_mix(p, p, p, p, too_many,
                           (0.05,) * len(too_many), 0.2, eta=1e-2,
                           block_rows=8, interpret=True)


@pytest.mark.parametrize("period", [1, 3])
def test_dadam_stacked_dispatch_through_fused_kernel(period, monkeypatch):
    """The D-Adam comm='stacked' pallas step dispatches through
    gossip_adam_mix when eligible; forcing the two-pass dispatch instead
    must not change a single bit of a 10-step run."""
    kw = dict(eta=1e-2, period=period, backend="pallas", topology="ring")
    opt = make_optimizer("d-adam", K, **kw)
    assert dadam._gossip_adam_eligible(opt.topo, opt.cfg)
    p_fused = fit_params(opt)
    monkeypatch.setattr(dadam, "_gossip_adam_eligible",
                        lambda topo, cfg: False)
    p_two_pass = fit_params(make_optimizer("d-adam", K, **kw))
    assert params_equal(p_fused, p_two_pass)


def test_fused_dispatch_ineligible_under_overlap_and_schedule():
    """Overlap, staleness, and schedules route through the payload-buffer
    machinery — the fused gossip+Adam shortcut must stand down."""
    opt = make_optimizer("d-adam", K, eta=1e-2, backend="pallas",
                         topology="ring", overlap=True)
    assert not dadam._gossip_adam_eligible(opt.topo, opt.cfg)
    opt = make_optimizer("d-adam", K, eta=1e-2, backend="pallas",
                         topology="one-peer-exponential")
    assert not dadam._gossip_adam_eligible(opt.topo, opt.cfg)
    opt = make_optimizer("d-adam", K, eta=1e-2, backend="pallas",
                         topology="ring", staleness=1, straggler_rate=0.1)
    assert not dadam._gossip_adam_eligible(opt.topo, opt.cfg)


# --------------------------- composition pins --------------------------------


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_overlap_with_schedule_runs_and_contracts(backend):
    opt = make_optimizer("d-adam", K, topology="one-peer-exponential",
                         eta=1e-2, period=1, backend=backend, overlap=True)
    p0 = jax.tree_util.tree_map(
        lambda x: jax.random.normal(jax.random.PRNGKey(2),
                                    (K,) + x.shape), init_params())
    state = opt.init(p0)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, p0)
    e0 = float(dadam.consensus_error(opt.params_of(state)))
    step = jax.jit(opt.step)
    for _ in range(40):
        state = step(state, zeros)
    e1 = float(dadam.consensus_error(opt.params_of(state)))
    assert e1 < 1e-3 * max(e0, 1.0)


@pytest.mark.parametrize("kind", ["d-adam", "cd-adam"])
@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_overlap_elastic_resize(kind, backend):
    """Membership changes under overlap: params/moments carry over, the
    rebuilt payload buffers start COLD (first post-resize round folds
    fresh), and training continues with one recompile."""
    from repro.core import resize_state
    kw = dict(topology="one-peer-exponential", eta=1e-2, period=1,
              backend=backend, overlap=True)
    opt = make_optimizer(kind, K, **kw)
    tr = DecentralizedTrainer(loss_fn, opt)
    state = tr.init(init_params())
    state, _ = tr.fit(state, batches(K), 5, log_every=5)
    p_old = np.asarray(tr.opt.params_of(state)["w"])

    grown = make_optimizer(kind, K + 4, **kw)
    st2 = resize_state(state, grown, strategy="clone")
    p_new = np.asarray(grown.params_of(st2)["w"])
    assert (p_new[:K] == p_old).all()
    assert (p_new[K:] == p_old[:4]).all()

    tr2 = DecentralizedTrainer(loss_fn, grown)
    st2, log = tr2.fit(st2, batches(K + 4), 4, log_every=4)
    assert tr2._step._cache_size() == 1
    assert np.isfinite(log.loss[-1])


# ------------------------------ validation -----------------------------------


def test_overlap_rejects_explicit_staleness():
    with pytest.raises(ValueError, match="tau=1 wire schedule"):
        make_optimizer("d-adam", K, eta=1e-2, overlap=True, staleness=2,
                       straggler_rate=0.1)
    with pytest.raises(ValueError):
        make_optimizer("cd-adam", K, eta=1e-2, overlap=True, staleness=1,
                       straggler_rate=0.1)


def test_overlap_rejects_dense_mixing_and_dpsgd():
    with pytest.raises(ValueError, match="shift lowering"):
        make_optimizer("d-adam", K, eta=1e-2, overlap=True, mixing="dense")
    with pytest.raises(ValueError, match="d-adam / cd-adam"):
        make_optimizer("d-psgd", K, eta=1e-2, overlap=True)


# --------------------------- repro.launch.env --------------------------------


def test_env_appends_never_clobbers():
    from repro.launch import env as lenv
    e = {"XLA_FLAGS": "--xla_foo=1"}
    out = lenv.ensure_xla_flags(["--xla_bar=2"], env=e)
    assert out == "--xla_foo=1 --xla_bar=2"
    assert e["XLA_FLAGS"] == out


def test_env_preset_flag_wins():
    from repro.launch import env as lenv
    e = {"XLA_FLAGS": f"{lenv.HOST_DEVICE_FLAG}=4"}
    assert lenv.ensure_host_devices(16, env=e) == 4
    assert e["XLA_FLAGS"] == f"{lenv.HOST_DEVICE_FLAG}=4"
    e2 = {}
    assert lenv.ensure_host_devices(16, env=e2) == 16
    assert lenv.host_device_count(e2) == 16
    e3 = {"REPRO_HOST_DEVICES": "12"}
    assert lenv.ensure_host_devices(env=e3) == 12


def test_env_async_flags_gated_on_gpu_support():
    from repro.launch import env as lenv
    # forced off: never installed (a CPU-only jaxlib ABORTS on unknown
    # --xla_gpu_* names, so the gate is load-bearing, not cosmetic)
    e = {"REPRO_ASYNC_COLLECTIVES": "0"}
    lenv.setup(8, env=e)
    assert "xla_gpu" not in e["XLA_FLAGS"]
    # forced on: all three flags appended after the host-device flag
    e2 = {"REPRO_ASYNC_COLLECTIVES": "1"}
    lenv.setup(8, env=e2)
    for flag in lenv.ASYNC_COLLECTIVE_FLAGS:
        assert flag in e2["XLA_FLAGS"]
    assert e2["XLA_FLAGS"].startswith(f"{lenv.HOST_DEVICE_FLAG}=8")
    # idempotent: a second setup adds nothing
    before = e2["XLA_FLAGS"]
    lenv.setup(8, env=e2)
    assert e2["XLA_FLAGS"] == before


def test_env_setup_platform_setdefault():
    from repro.launch import env as lenv
    e = {"JAX_PLATFORMS": "tpu", "REPRO_ASYNC_COLLECTIVES": "0"}
    lenv.setup(2, platform="cpu", env=e)
    assert e["JAX_PLATFORMS"] == "tpu"
    e2 = {"REPRO_ASYNC_COLLECTIVES": "0"}
    lenv.setup(2, platform="cpu", env=e2)
    assert e2["JAX_PLATFORMS"] == "cpu"
