"""comm='axis' device-parallel execution — in-process tests.

The unified comm dispatch runs the SAME optimizer step either stacked (one
program, worker shifts = rolls) or per-shard inside shard_map over a
'worker' mesh axis (worker shifts = ppermute). These tests pin the two
modes against each other for both backends and both optimizers.

Device-requiring tests skip when the process has fewer devices than
workers (plain ``pytest`` runs single-device; ``scripts/tier1.sh`` forces
8 host devices so the whole module executes there). Validation tests run
everywhere.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_optimizer
from repro.core.dadam import DAdamConfig

KEY = jax.random.PRNGKey(0)
K = 4


def ragged_tree(key, k):
    ks = jax.random.split(key, 3)
    return {
        "w": jax.random.normal(ks[0], (k, 13, 7)),
        "b": jax.random.normal(ks[1], (k, 5)),
        "nest": {"u": jax.random.normal(ks[2], (k, 3, 11, 2))},
    }


def needs_devices(n):
    return pytest.mark.skipif(
        jax.device_count() < n,
        reason=f"needs >= {n} devices (tier1.sh forces 8 host devices)")


@pytest.fixture(scope="module")
def worker_mesh():
    if jax.device_count() < K:
        pytest.skip(f"needs >= {K} devices")
    return jax.make_mesh((K,), ("worker",))


# ------------------------------ validation ----------------------------------


class TestValidation:
    def test_axis_without_mesh_rejected(self):
        with pytest.raises(ValueError, match="mesh"):
            make_optimizer("d-adam", K=4, comm="axis")

    def test_mesh_without_axis_comm_rejected(self):
        with pytest.raises(ValueError, match="comm='axis'"):
            make_optimizer("d-adam", K=4, mesh=object())

    def test_unknown_comm_rejected(self):
        with pytest.raises(ValueError, match="comm"):
            DAdamConfig(comm="bogus").validate()

    def test_dense_mixing_under_axis_rejected(self):
        with pytest.raises(ValueError, match="dense"):
            DAdamConfig(comm="axis", mixing="dense").validate()

    def test_dpsgd_axis_rejected(self):
        with pytest.raises(ValueError, match="d-psgd"):
            make_optimizer("d-psgd", K=4, comm="axis")


@needs_devices(K)
class TestMeshValidation:
    def test_wrong_axis_size_rejected(self, worker_mesh):
        with pytest.raises(ValueError, match="size K"):
            make_optimizer("d-adam", K=K + 1, comm="axis", mesh=worker_mesh)

    def test_wrong_axis_name_rejected(self, worker_mesh):
        with pytest.raises(ValueError, match="axis"):
            make_optimizer("d-adam", K=K, comm="axis", mesh=worker_mesh,
                           axis_name="pod")

    def test_non_shift_topology_rejected_at_construction(self, worker_mesh):
        """A topology without shift offsets must fail in make_optimizer,
        not at first step trace inside shard_map. (torus no longer
        qualifies — its wrap-aware GridShift offsets made it
        shift-expressible, see test_torus_now_accepted_under_axis — so
        build an offsets-free graph directly.)"""
        from repro.core.topology import Topology
        W = np.full((K, K), 1.0 / K)
        no_offsets = Topology(name="dense-no-offsets", weights=W,
                              offsets=(), offset_weights=(),
                              self_weight=1.0 / K)
        with pytest.raises(ValueError, match="shift-invariant"):
            make_optimizer("d-adam", K=K, topology=no_offsets, comm="axis",
                           mesh=worker_mesh)

    def test_torus_now_accepted_under_axis(self, worker_mesh):
        """The wrap-aware torus offsets lower under comm='axis' too: the
        sharded run must match the stacked run exactly."""
        kw = dict(eta=1e-2, period=1, topology="torus")
        opt_ax = make_optimizer("d-adam", K=K, comm="axis",
                                mesh=worker_mesh, **kw)
        opt_st = make_optimizer("d-adam", K=K, **kw)
        p0 = {"w": jax.random.normal(jax.random.PRNGKey(0), (K, 5, 7))}
        g = jax.tree_util.tree_map(jnp.ones_like, p0)
        sa, ss = opt_ax.init(p0), opt_st.init(p0)
        for _ in range(4):
            sa, ss = opt_ax.step(sa, g), opt_st.step(ss, g)
        pa = jax.device_get(opt_ax.params_of(sa))
        ps = opt_st.params_of(ss)
        assert bool(jnp.allclose(pa["w"], ps["w"], atol=1e-6))


# ------------------------- axis == stacked parity ---------------------------


@needs_devices(K)
class TestAxisMatchesStacked:
    @pytest.mark.parametrize("kind", ["d-adam", "cd-adam"])
    @pytest.mark.parametrize("backend", ["reference", "pallas"])
    def test_multi_step_parity(self, kind, backend, worker_mesh):
        """4 steps with period=2 (both cond branches) under shard_map ==
        the stacked single-program run, for both backends."""
        params = ragged_tree(KEY, K)
        base = make_optimizer(kind, K=K, eta=1e-2, period=2,
                              weight_decay=0.01, backend=backend)
        axis = make_optimizer(kind, K=K, eta=1e-2, period=2,
                              weight_decay=0.01, backend=backend,
                              comm="axis", mesh=worker_mesh)
        s0 = base.init(jax.tree_util.tree_map(jnp.copy, params))
        s1 = axis.init(jax.tree_util.tree_map(jnp.copy, params))
        step0 = jax.jit(lambda s, g: base.step(s, g))
        step1 = jax.jit(lambda s, g: axis.step(s, g))
        for t in range(4):
            g = jax.tree_util.tree_map(
                lambda x: 0.5 * x + 0.01 * (t + 1), base.params_of(s0))
            if backend == "pallas":
                from repro.kernels import pack as packing
                gb = packing.pack(g, s0.spec, dtype=s0.buf.dtype)
                s0, s1 = step0(s0, gb), step1(s1, gb)
            else:
                s0, s1 = step0(s0, g), step1(s1, g)
        for a, b in zip(jax.tree_util.tree_leaves(base.params_of(s0)),
                        jax.tree_util.tree_leaves(axis.params_of(s1))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=1e-6)

    def test_axis_state_is_sharded_over_workers(self, worker_mesh):
        """opt.init really partitions the resident buffer: one worker's
        (1, rows, 128) shard per mesh slot."""
        axis = make_optimizer("d-adam", K=K, eta=1e-2, backend="pallas",
                              comm="axis", mesh=worker_mesh)
        state = axis.init(ragged_tree(KEY, K))
        assert axis.mesh is worker_mesh
        shard_shapes = {s.data.shape for s in state.buf.addressable_shards}
        assert shard_shapes == {(1,) + state.buf.shape[1:]}
        # the scalar count stays replicated
        assert len(state.count.addressable_shards) == K

    @pytest.mark.parametrize("kind", ["d-adam", "cd-adam"])
    def test_round_step_parity_packed(self, kind, worker_mesh):
        """p local fused steps + one ppermute gossip inside shard_map ==
        the stacked round, with grad_fn on the resident buffer shard."""
        params = ragged_tree(KEY, K)
        base = make_optimizer(kind, K=K, eta=1e-2, period=3,
                              backend="pallas")
        axis = make_optimizer(kind, K=K, eta=1e-2, period=3,
                              backend="pallas", comm="axis",
                              mesh=worker_mesh)
        batches = jnp.zeros((3, K, 1))
        grad_fn = lambda buf, batch: 0.5 * buf
        s0 = base.round(base.init(jax.tree_util.tree_map(jnp.copy, params)),
                        grad_fn, batches)
        s1 = axis.round(axis.init(jax.tree_util.tree_map(jnp.copy, params)),
                        grad_fn, batches)
        assert int(s1.count) == 3
        for a, b in zip(jax.tree_util.tree_leaves(base.params_of(s0)),
                        jax.tree_util.tree_leaves(axis.params_of(s1))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=1e-6)


# ------------------------ trainer + checkpoint ------------------------------


@needs_devices(K)
class TestAxisTrainerAndCheckpoint:
    def test_trainer_fit_matches_stacked(self, worker_mesh):
        """End to end: the trainer's differentiate-through-unpack path on
        the sharded resident state tracks the stacked run."""
        from repro.train import DecentralizedTrainer

        d = 37
        centers = jax.random.normal(KEY, (K, d))

        def loss_fn(params, batch):
            return jnp.sum((params["x"] - batch) ** 2)

        def batch_iter():
            t = 0
            while True:
                yield centers + 0.01 * t
                t += 1

        logs = {}
        for comm in ("stacked", "axis"):
            opt = make_optimizer(
                "cd-adam", K=K, eta=5e-2, period=2, backend="pallas",
                comm=comm, mesh=worker_mesh if comm == "axis" else None)
            trainer = DecentralizedTrainer(loss_fn, opt)
            state = trainer.init({"x": jnp.zeros((d,))})
            state, log = trainer.fit(state, batch_iter(), 4, log_every=2)
            logs[comm] = (log, opt.params_of(state))
        np.testing.assert_allclose(logs["stacked"][0].loss,
                                   logs["axis"][0].loss,
                                   rtol=2e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(logs["stacked"][1]["x"]),
                                   np.asarray(logs["axis"][1]["x"]),
                                   rtol=2e-5, atol=2e-6)

    @pytest.mark.parametrize("kind", ["d-adam", "cd-adam"])
    def test_checkpoint_portable_across_comm_modes(self, kind, tmp_path,
                                                   worker_mesh):
        """stacked-pallas checkpoint -> axis-sharded state (placement of
        the like-state preserved) -> back to reference, bit-identically."""
        from repro.checkpoint import restore, save

        params = ragged_tree(KEY, K)
        stacked = make_optimizer(kind, K=K, eta=1e-2, backend="pallas")
        axis = make_optimizer(kind, K=K, eta=1e-2, backend="pallas",
                              comm="axis", mesh=worker_mesh)
        s = stacked.init(jax.tree_util.tree_map(jnp.copy, params))
        s = stacked.step(s, 0.3 * s.buf)
        path = str(tmp_path / "ck.npz")
        save(path, s, step=1)
        like = axis.init(jax.tree_util.tree_map(jnp.copy, params))
        restored, step = restore(path, like)
        assert step == 1
        assert restored.buf.sharding == like.buf.sharding
        np.testing.assert_array_equal(np.asarray(restored.buf),
                                      np.asarray(s.buf))
        # restored sharded state keeps stepping, in lockstep with stacked
        out_axis = axis.step(restored, 0.3 * restored.buf)
        out_stacked = stacked.step(s, 0.3 * s.buf)
        np.testing.assert_allclose(np.asarray(out_axis.buf),
                                   np.asarray(out_stacked.buf),
                                   rtol=2e-5, atol=1e-6)
