"""The three-pass shard-safety static analyzer (PR-7 tentpole).

Pins, through the same entry points CI uses
(``repro.analysis.check`` / ``scripts/check_invariants.py``):

* the **known-bug corpus** — the PR-5 raw-psum sharded loss trips JXL001
  (forward custom_vjp walk AND backward psum accounting) and RPR001; the
  PR-6 flat-circulant torus fails INV006 through ``check_topology``;
* the **invariant spec mechanics** on synthetic HLO (count/byte/single/
  trip bounds, min counts, "*" totals, InvariantViolation);
* the **jaxpr lint** on hand-built shard_map programs (raw vs protected
  collectives, wrong-axis binding);
* the **AST rules** RPR001–RPR004 including ``# noqa`` suppression, and
  that the shipped ``src/`` tree is clean;
* the **RecompileWatch** (JXL003) both standalone and wired into the
  trainer via ``recompile_limit=``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import astlint
from repro.analysis.invariants import (InvariantSpec, InvariantViolation,
                                       assert_invariants, assert_topology,
                                       check_topology, evaluate_hlo)
from repro.analysis.jaxpr_lint import (RecompileError, RecompileWatch,
                                       lint_fn)


def skip_unless_devices(n):
    if jax.device_count() < n:
        pytest.skip(f"needs >= {n} devices, have {jax.device_count()}")


# --------------------------- invariant mechanics -----------------------------


_SYNTH_HLO = """
HloModule test

ENTRY %main (p0: f32[128,8]) -> f32[128,8] {
  %p0 = f32[128,8]{1,0} parameter(0)
  %ar = f32[128,8]{1,0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
  %cp = f32[128,8]{1,0} collective-permute(%ar), source_target_pairs={{0,1},{1,0}}
  ROOT %out = f32[128,8]{1,0} add(%ar, %cp)
}
"""
_OP_BYTES = 128 * 8 * 4  # one f32[128,8] operand


class TestInvariantSpec:
    def test_pass(self):
        spec = InvariantSpec(
            collective_counts={"all-gather": 0, "all-reduce": 1},
            min_collective_counts={"collective-permute": 1},
            collective_bytes={"*": 2 * _OP_BYTES},
            single_collective_bytes={"all-reduce": _OP_BYTES})
        report = evaluate_hlo(_SYNTH_HLO, spec)
        assert report.ok, report.format()
        # informational summary always populated, all five kinds
        assert set(report.summary) == {
            "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute"}
        assert report.summary["all-reduce"]["count"] == 1

    @pytest.mark.parametrize("spec,rule", [
        (InvariantSpec(collective_counts={"all-reduce": 0}), "INV001"),
        (InvariantSpec(min_collective_counts={"all-gather": 1}), "INV001"),
        (InvariantSpec(collective_bytes={"*": _OP_BYTES}), "INV002"),
        (InvariantSpec(collective_bytes={"all-reduce": _OP_BYTES - 1}),
         "INV002"),
        (InvariantSpec(single_collective_bytes={
            "collective-permute": _OP_BYTES - 1}), "INV003"),
    ])
    def test_each_bound_fails_with_its_rule(self, spec, rule):
        report = evaluate_hlo(_SYNTH_HLO, spec)
        assert not report.ok
        assert report.failed_rules() == [rule]

    def test_assert_invariants_raises_with_report(self):
        def fn(x):
            return x * 2

        x = jnp.ones((8, 8))
        # impossible bound: demand a collective a single-device program
        # cannot have
        spec = InvariantSpec(min_collective_counts={"all-gather": 1})
        with pytest.raises(InvariantViolation) as ei:
            assert_invariants(fn, (x,), spec)
        assert "INV001" in str(ei.value)
        assert ei.value.report.failed_rules() == ["INV001"]
        # and a satisfiable spec returns the report
        report = assert_invariants(fn, (x,), InvariantSpec(
            collective_counts={"all-gather": 0}))
        assert report.ok


# --------------------------- topology invariants -----------------------------


class TestTopologyInvariants:
    def test_zoo_clean(self):
        from repro.analysis.check import topology_reports
        for report in topology_reports():
            assert report.ok, report.format()

    def test_corpus_bad_torus_fails_inv006(self):
        """PR-6 bug class: flat circulant offsets on a 2x4 torus wrap the
        ±1 hops across row boundaries — the lowered permutation matrix
        cannot equal the dense weights."""
        from repro.analysis.check import corpus_bad_torus
        report = corpus_bad_torus()
        assert not report.ok
        assert "INV006" in report.failed_rules()
        with pytest.raises(InvariantViolation):
            from repro.core.topology import make_topology
            bad = dataclasses.replace(
                make_topology("torus", 8), name="bad-flat-torus",
                offsets=(1, -1, 4, -4))
            assert_topology(bad)

    def test_good_torus_passes(self):
        from repro.core.topology import make_topology
        assert check_topology(make_topology("torus", 8)).ok

    def test_non_doubly_stochastic_fails_inv007(self):
        from repro.core.topology import make_topology
        import numpy as np
        ring = make_topology("ring", 4)
        W = np.asarray(ring.weights).copy()
        W[0, 0] += 0.25
        bad = dataclasses.replace(ring, weights=W)
        report = check_topology(bad)
        assert "INV007" in report.failed_rules()


# ------------------------------- jaxpr lint ----------------------------------


class TestJaxprLint:
    def _shard_mapped(self, body):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        import numpy as np
        mesh = Mesh(np.array(jax.devices()[:1]), ("worker",))
        return shard_map(body, mesh=mesh, in_specs=P(),
                         out_specs=P(), check_rep=False)

    def test_raw_psum_flagged(self):
        fn = self._shard_mapped(lambda x: jax.lax.psum(x, "worker"))
        findings = lint_fn(fn, jnp.ones(4),
                           gossip_axes=(), reduce_axes=("worker",))
        assert [f.rule for f in findings] == ["JXL001"]

    def test_protected_psum_clean(self):
        from repro.train.grad import psum_replicated
        fn = self._shard_mapped(lambda x: psum_replicated(x, "worker"))
        findings = lint_fn(fn, jnp.ones(4),
                           gossip_axes=(), reduce_axes=("worker",))
        assert findings == []

    def test_wrong_axis_reduce_flagged(self):
        # a psum over the GOSSIP axis is a wrong-axis reduction (JXL002);
        # check_raw off isolates the axis rule
        fn = self._shard_mapped(lambda x: jax.lax.psum(x, "worker"))
        findings = lint_fn(fn, jnp.ones(4), check_raw=False,
                           gossip_axes=("worker",), reduce_axes=("model",))
        assert [f.rule for f in findings] == ["JXL002"]

    def test_gossip_permute_on_gossip_axis_clean(self):
        fn = self._shard_mapped(
            lambda x: jax.lax.ppermute(x, "worker", [(0, 0)]))
        findings = lint_fn(fn, jnp.ones(4), check_raw=False,
                           gossip_axes=("worker",), reduce_axes=("model",))
        assert findings == []


class TestRawPsumCorpus:
    def test_corpus_raw_psum_trips_jxl001_both_modes(self):
        """The PR-5 bug class through the real pipeline: the forward
        custom_vjp-boundary walk AND the backward psum-shape accounting
        must both flag the raw-psum sharded loss."""
        skip_unless_devices(8)
        from repro.analysis.check import corpus_raw_psum
        rules = [f.rule for f in corpus_raw_psum()]
        assert rules.count("JXL001") >= 2

    def test_safe_pipeline_clean(self):
        skip_unless_devices(8)
        from repro.analysis.check import SweepConfig, check_config
        res = check_config(SweepConfig("axis2d", "d-adam", "plain", M=2))
        assert res.skipped is None
        assert res.lint == []
        assert res.report.ok, res.report.format()


# -------------------------------- AST rules ----------------------------------


class TestAstRules:
    def test_corpus_trips_all_rules(self):
        from repro.analysis.check import corpus_ast
        counts = astlint.rule_counts(corpus_ast())
        for rule in ("RPR001", "RPR002", "RPR003", "RPR004"):
            assert counts[rule] >= 1, (rule, counts)

    def test_noqa_suppression(self):
        src = ("import jax\n"
               "def f(chunks, batch, ctx):\n"
               "    return jax.lax.psum(chunks, ctx.axis_name)"
               "  # noqa: RPR001\n")
        assert astlint.lint_source(src) == []
        # a noqa for a different rule does not suppress
        src_wrong = src.replace("RPR001", "RPR002")
        assert [f.rule for f in astlint.lint_source(src_wrong)] == ["RPR001"]

    def test_ctx_psum_not_flagged(self):
        src = ("def f(chunks, batch, ctx):\n"
               "    return ctx.psum(chunks.sum())\n")
        assert astlint.lint_source(src) == []

    def test_pallas_interpret_kwarg_ok(self):
        src = ("from jax.experimental import pallas as pl\n"
               "def k(x, interp):\n"
               "    return pl.pallas_call(lambda r, o: None, out_shape=x,"
               " interpret=interp)(x)\n")
        assert astlint.lint_source(src) == []

    def test_static_blockspec_ok(self):
        src = ("from jax.experimental import pallas as pl\n"
               "def s(K):\n"
               "    return pl.BlockSpec((1, 8, 128),"
               " lambda k, i: (k // 2, i, 0))\n")
        assert astlint.lint_source(src) == []

    def test_src_tree_clean(self):
        """The shipped source must stay lint-clean — the same gate the CI
        static-analysis job enforces."""
        import pathlib
        src_root = pathlib.Path(__file__).resolve().parents[1] / "src"
        findings = astlint.lint_paths([str(src_root)])
        assert findings == [], "\n".join(str(f) for f in findings)

    def test_cli_exit_codes(self, tmp_path):
        clean = tmp_path / "clean.py"
        clean.write_text("x = 1\n")
        assert astlint.main([str(clean)]) == 0
        dirty = tmp_path / "dirty.py"
        dirty.write_text(
            "import jax\n"
            "def bad_sharded_loss(c, b, ctx):\n"
            "    return jax.lax.psum(c, ctx.axis_name)\n")
        assert astlint.main([str(dirty), "--summary"]) == 1


# --------------------------- JXL003: recompiles ------------------------------


class TestRecompileWatch:
    def test_limit_and_reset(self):
        w = RecompileWatch("f", limit=1)
        assert w.observe(jnp.ones((4,))) == 1
        assert w.observe(jnp.ones((4,))) == 1      # same signature
        w.check()                                   # within limit
        assert w.observe(jnp.ones((5,))) == 2       # shape churn
        assert [f.rule for f in w.findings()] == ["JXL003"]
        with pytest.raises(RecompileError):
            w.check()
        w.reset()
        assert w.findings() == []

    def test_dtype_and_structure_churn_counts(self):
        w = RecompileWatch(limit=1)
        w.observe({"a": jnp.ones((2,), jnp.float32)})
        w.observe({"a": jnp.ones((2,), jnp.int32)})
        w.observe({"a": jnp.ones((2,)), "b": jnp.ones((2,))})
        assert len(w.signatures) == 3

    def test_trainer_recompile_limit(self):
        """recompile_limit= wires the watch into fit(): a batch-shape
        change mid-run raises instead of silently recompiling."""
        from repro.core import make_optimizer
        from repro.train import DecentralizedTrainer

        def loss(p, batch):
            return jnp.mean((batch @ p["w"]) ** 2)

        K = 2
        opt = make_optimizer("d-adam", K=K, eta=1e-2, period=2)
        tr = DecentralizedTrainer(loss, opt, recompile_limit=1)
        assert tr.recompile_watch is not None
        state = tr.init({"w": jnp.ones((4, 2))})

        def batches(shapes):
            for s in shapes:
                yield jnp.ones((K,) + s)

        state, _ = tr.fit(state, batches([(3, 4)] * 4), 4, log_every=2)
        with pytest.raises(RecompileError):
            tr.fit(state, batches([(3, 4), (5, 4)]), 2, log_every=1)

    def test_trainer_default_no_watch(self):
        from repro.core import make_optimizer
        from repro.train import DecentralizedTrainer
        opt = make_optimizer("d-adam", K=2, eta=1e-2, period=2)
        tr = DecentralizedTrainer(lambda p, b: jnp.mean(p["w"] * b), opt)
        assert tr.recompile_watch is None


# ------------------------------ sweep surface --------------------------------


class TestSweep:
    def test_sweep_config_shape(self):
        from repro.analysis.check import sweep_configs
        cfgs = sweep_configs()
        names = {c.name for c in cfgs}
        # invalid combos excluded by construction
        assert "axis2d/d-adam/stale" not in names
        assert "axis/cd-adam/stale" not in names
        assert "reference/d-adam/plain" in names
        assert all(c.M == (2 if c.backend == "axis2d" else 1) for c in cfgs)

    def test_stacked_config_passes(self):
        from repro.analysis.check import SweepConfig, check_config
        res = check_config(SweepConfig("reference", "d-adam", "plain"))
        assert res.ok, (res.report and res.report.format(), res.lint)
