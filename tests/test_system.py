"""End-to-end system tests reproducing the paper's experimental CLAIMS on
CPU-scale synthetic tasks:

  1. D-Adam with p in {2, 4, 8} reaches (almost) the same final training
     loss as D-Adam-vanilla (p=1) — Fig. 1's observation.
  2. At matched quality, communication cost scales ~ 1/p — Fig. 2.
  3. CD-Adam (sign, gamma=0.4) matches full-precision quality at a
     fraction of the bytes — Figs. 3-4.
  4. D-PSGD (non-adaptive baseline) underperforms the adaptive methods on
     sparse/categorical CTR data at the paper's eta — Section 1's premise.
"""
import jax
import numpy as np
import pytest

from repro.core import make_optimizer

pytestmark = pytest.mark.slow  # multi-hundred-step training runs
from repro.data import ctr_batch_stacked, make_ctr_task
from repro.models.deepfm import deepfm_logits, deepfm_loss, init_deepfm
from repro.train import DecentralizedTrainer
from repro.train.metrics import auc

K = 8          # the paper's 8 workers
STEPS = 120
BATCH = 32     # per worker

TASK = make_ctr_task(seed=0, n_fields=8, features_per_field=32)
KEY = jax.random.PRNGKey(0)


def batch_iter(seed=1):
    key = jax.random.PRNGKey(seed)
    t = 0
    while True:
        yield ctr_batch_stacked(TASK, jax.random.fold_in(key, t), K, BATCH)
        t += 1


def run(kind, **kw):
    opt = make_optimizer(kind, K=K, eta=1e-3, topology="ring", **kw)
    trainer = DecentralizedTrainer(
        lambda p, b: deepfm_loss(p, b), opt)
    params = init_deepfm(KEY, TASK.n_features, TASK.n_fields,
                         hidden=(32, 32))
    state = trainer.init(params)
    state, log = trainer.fit(state, batch_iter(), STEPS, log_every=STEPS)
    # eval AUC with averaged params on held-out batch
    avg = trainer.averaged_params(state)
    test = ctr_batch_stacked(TASK, jax.random.PRNGKey(999), K, 256)
    flat = jax.tree_util.tree_map(
        lambda x: x.reshape((-1,) + x.shape[2:]), test)
    scores = deepfm_logits(avg, flat["feat_ids"])
    return log.loss[-1], auc(np.asarray(scores), np.asarray(flat["label"])), \
        log.comm_mb[-1]


@pytest.fixture(scope="module")
def vanilla():
    return run("d-adam", period=1)


def test_fig1_claim_period_matches_vanilla_quality(vanilla):
    loss_v, auc_v, mb_v = vanilla
    for p in (4, 8):
        loss_p, auc_p, mb_p = run("d-adam", period=p)
        assert loss_p < loss_v * 1.35 + 0.05, f"p={p} loss degraded"
        assert auc_p > auc_v - 0.05, f"p={p} AUC degraded"


def test_fig2_claim_comm_cost_scales_inverse_p(vanilla):
    _, _, mb_v = vanilla
    _, _, mb_p8 = run("d-adam", period=8)
    assert mb_p8 < mb_v / 6  # ~1/8 with rounding slack


def test_fig34_claim_cdadam_matches_at_fraction_of_bytes(vanilla):
    loss_v, auc_v, mb_v = vanilla
    loss_c, auc_c, mb_c = run("cd-adam", period=4, gamma=0.4,
                              compressor="sign")
    assert auc_c > auc_v - 0.06
    assert mb_c < mb_v / 12   # x4 from p, >x3 from sign bytes


def test_adaptivity_premise_beats_sgd_on_ctr(vanilla):
    """Same eta (paper's 1e-3): plain decentralized SGD barely moves on
    sparse CTR features where Adam adapts per-coordinate."""
    _, auc_adam, _ = vanilla
    _, auc_sgd, _ = run("d-psgd")
    assert auc_adam > auc_sgd + 0.03


def test_training_actually_learns(vanilla):
    _, auc_v, _ = vanilla
    assert auc_v > 0.62  # planted FM teacher is learnable
