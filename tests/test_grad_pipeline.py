"""The sharded-gradient pipeline (train/grad.py).

Pins the PR's tentpole: on a 2D (worker × model) mesh the trainer can
evaluate the loss model-parallel DIRECTLY from each device's local packed
row-shard block (``packing.unpack_local`` + a ``sharded_loss``), with

* loss/param parity against the PR-4 differentiate-through-full-unpack
  path and against the reference backend (10-step trainer runs, both
  optimizers, K×M = 4×2 and 2×4), and
* a compiled 2D step whose collectives contain **zero all-gathers** (and
  zero all-to-alls): nothing crosses the wire but the neighbor gossip
  ppermutes and the small per-shard activation psums —
  ``analysis.hlo.collective_summary`` is the regression instrument.

Also pins the pipeline's building blocks: ``unpack_local`` /
``mirror_local`` layout round-trips, the replicated-cotangent ``psum``
(a raw psum transpose would silently scale every gradient by M), the
dispatch modes, and microbatch gradient accumulation parity in every
mode.

The model is a real matmul (d_in=1600 × d_out=64 + bias), sized so the
weight leaf genuinely spans every model shard at both factorizations —
small single-shard leaves would let GSPMD dodge the gather this test
exists to rule out.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import collective_summary
from repro.analysis.invariants import InvariantSpec, evaluate_hlo
from repro.core import make_optimizer
from repro.kernels import pack as packing
from repro.launch.mesh import make_worker_mesh
from repro.train import (DecentralizedTrainer, make_grad_pipeline,
                         row_parallel_dot)

KEY = jax.random.PRNGKey(0)
KINDS = ["d-adam", "cd-adam"]
FACTORIZATIONS = [(4, 2), (2, 4)]  # K x M — both run on tier1.sh's 8 devices

DIN, DOUT, B = 1600, 64, 8  # w spans all shards at M=2 AND M=4


def skip_unless_devices(n):
    if jax.device_count() < n:
        pytest.skip(f"needs >= {n} devices, have {jax.device_count()}")


def mlp_params():
    return {"bias": jnp.zeros((DOUT,)),
            "w": jax.random.normal(KEY, (DIN, DOUT)) * 0.02}


def mlp_loss(p, batch):
    pred = batch["x"] @ p["w"] + p["bias"]
    return jnp.mean((pred - batch["y"]) ** 2)


def sharded_mlp_loss(chunks, batch, ctx):
    """The model-parallel spelling: the weight chunk feeds a row-parallel
    matmul (operand P('model', None), activation psum over 'model'), the
    bias — leaf 0 in spec order — assembles via one small psum."""
    h = row_parallel_dot(batch["x"], chunks["w"], DOUT, ctx)
    pred = h + ctx.full_leaf(chunks["bias"], 0)
    return jnp.mean((pred - batch["y"]) ** 2)


def quad_loss(p, batch):
    return jnp.mean((p["x"] - batch) ** 2)


def sharded_quad_loss(chunks, batch, ctx):
    """The elementwise spelling: mirror the target into the chunk layout
    and psum the partial sums (padding slots subtract 0 - 0)."""
    bl = ctx.mirror({"x": batch})
    d = batch.size
    return ctx.psum(jnp.sum((chunks["x"] - bl["x"]) ** 2)) / d


def mlp_batches(K):
    t = 0
    while True:
        kt = jax.random.fold_in(KEY, t)
        yield {"x": jax.random.normal(kt, (K, B, DIN)),
               "y": jax.random.normal(jax.random.fold_in(kt, 1),
                                      (K, B, DOUT))}
        t += 1


# ------------------------- layout building blocks ----------------------------


class TestUnpackLocal:
    def ragged_spec(self, M):
        tree = {"w": jax.random.normal(KEY, (4, 13, 7)),
                "b": jax.random.normal(KEY, (4, 5)),
                "n": {"u": jax.random.normal(KEY, (4, 3, 11, 2))}}
        spec = packing.make_spec(tree, stacked=True,
                                 block_rows=packing.BLOCK_ROWS,
                                 leaf_align=True, row_shards=M)
        return tree, spec, packing.pack(tree, spec)

    @pytest.mark.parametrize("M", [1, 2, 4])
    def test_chunks_concat_to_unpack(self, M):
        """Concatenating every shard's local slices reproduces the full
        leaves — the shard-invariant layout contract."""
        tree, spec, buf = self.ragged_spec(M)
        lr = spec.local_rows
        per_shard = [packing.unpack_local(buf[:, j * lr:(j + 1) * lr], spec)
                     for j in range(M)]
        leaves = jax.tree_util.tree_leaves(tree)
        for i, (lv, sz, shape) in enumerate(
                zip(leaves, spec.sizes, spec.shapes)):
            cat = jnp.concatenate(
                [jax.tree_util.tree_leaves(c)[i] for c in per_shard],
                axis=1)
            np.testing.assert_array_equal(
                np.asarray(cat[:, :sz].reshape(shape)), np.asarray(lv))

    @pytest.mark.parametrize("M", [2, 4])
    def test_mirror_local_matches_packed_slices(self, M):
        """mirror_local of a replicated per-worker tree lands exactly on
        the packed chunk layout, shard by shard."""
        tree, spec, buf = self.ragged_spec(M)
        per_worker = jax.tree_util.tree_map(lambda x: x[0], tree)
        lr = spec.local_rows
        for j in range(M):
            mirr = packing.mirror_local(per_worker, spec, j)
            loc = packing.unpack_local(buf[:1, j * lr:(j + 1) * lr], spec)
            for a, b in zip(jax.tree_util.tree_leaves(mirr),
                            jax.tree_util.tree_leaves(loc)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b[0]),
                                           rtol=1e-6)

    def test_rejections(self):
        tree = {"w": jnp.ones((4, 13, 7)), "b": jnp.ones((4, 5))}
        flat_spec = packing.make_spec(tree, stacked=True)
        with pytest.raises(ValueError, match="leaf_align"):
            packing.unpack_local(jnp.zeros((1, 1, 128)), flat_spec)
        _, spec, buf = self.ragged_spec(2)
        with pytest.raises(ValueError, match="row-shard block"):
            packing.unpack_local(buf, spec)  # full buffer, not one block
        with pytest.raises(ValueError, match="per-worker leaf shapes"):
            packing.mirror_local({"w": jnp.ones((4, 13, 7)),
                                  "b": jnp.ones((4, 5)),
                                  "n": {"u": jnp.ones((4, 3, 11, 2))}},
                                 spec, 0)


# ------------------------------ mode dispatch --------------------------------


class TestDispatch:
    def test_modes(self):
        K = 4
        ref = make_optimizer("d-adam", K=K, backend="reference")
        assert make_grad_pipeline(quad_loss, ref).mode == "reference"
        packed = make_optimizer("d-adam", K=K, backend="pallas")
        assert make_grad_pipeline(quad_loss, packed).mode == "packed"
        # sharded_loss without a 2D optimizer: graceful fallback
        assert make_grad_pipeline(
            quad_loss, packed, sharded_loss=sharded_quad_loss
        ).mode == "packed"
        skip_unless_devices(8)
        mesh2d = make_worker_mesh(4, model_parallel=2)
        ax2 = make_optimizer("d-adam", K=K, backend="pallas", comm="axis",
                             mesh=mesh2d)
        assert make_grad_pipeline(quad_loss, ax2).mode == "packed"
        assert make_grad_pipeline(
            quad_loss, ax2, sharded_loss=sharded_quad_loss
        ).mode == "sharded-packed"

    def test_bad_microbatch(self):
        opt = make_optimizer("d-adam", K=2, backend="reference")
        with pytest.raises(ValueError, match="microbatch"):
            make_grad_pipeline(quad_loss, opt, microbatch=0)


# --------------------------- microbatch parity -------------------------------


class TestMicrobatch:
    @pytest.mark.parametrize("backend", ["reference", "pallas"])
    def test_trainer_parity_vs_microbatch_1(self, backend):
        """microbatch=4 gradient accumulation == one full-batch step, in
        both the reference and the packed (AD-through-unpack) paths."""
        K = 4
        finals, losses = {}, {}
        for mb in (1, 4):
            opt = make_optimizer("d-adam", K=K, eta=1e-2, period=2,
                                 backend=backend)
            tr = DecentralizedTrainer(mlp_loss, opt, microbatch=mb)
            assert tr.pipeline.microbatch == mb
            state = tr.init(mlp_params())
            state, log = tr.fit(state, mlp_batches(K), 6, log_every=3)
            finals[mb] = np.asarray(opt.params_of(state)["w"])
            losses[mb] = log.loss
        np.testing.assert_allclose(losses[1], losses[4], rtol=1e-4,
                                   atol=1e-6)
        np.testing.assert_allclose(finals[1], finals[4], rtol=1e-4,
                                   atol=1e-6)

    def test_sharded_mode_microbatch(self):
        """Gradient accumulation inside the 2D shard_map: microbatch=2 ==
        microbatch=1 on the sharded-packed path."""
        skip_unless_devices(8)
        K, M = 4, 2
        mesh = make_worker_mesh(K, model_parallel=M)
        finals = {}
        for mb in (1, 2):
            opt = make_optimizer("d-adam", K=K, eta=1e-2, period=2,
                                 backend="pallas", comm="axis", mesh=mesh)
            tr = DecentralizedTrainer(mlp_loss, opt, microbatch=mb,
                                      sharded_loss=sharded_mlp_loss)
            assert tr.pipeline.mode == "sharded-packed"
            state = tr.init(mlp_params())
            state, _ = tr.fit(state, mlp_batches(K), 4, log_every=2)
            finals[mb] = np.asarray(opt.params_of(state)["w"])
        np.testing.assert_allclose(finals[1], finals[2], rtol=1e-4,
                                   atol=1e-6)

    def test_batch_not_divisible_raises(self):
        opt = make_optimizer("d-adam", K=2, backend="reference")
        tr = DecentralizedTrainer(mlp_loss, opt, microbatch=3)
        state = tr.init(mlp_params())
        with pytest.raises(Exception, match="divisible|reshape"):
            tr._step(state, next(mlp_batches(2)))  # B=8, mb=3


# --------------------- acceptance: parity + collectives ----------------------


def _trainer_for(kind, k, kw, extra):
    opt = make_optimizer(kind, K=k, eta=1e-2, period=2, **kw)
    return opt, DecentralizedTrainer(mlp_loss, opt, **extra)


class TestShardedParityChain:
    @pytest.mark.parametrize("kind", KINDS)
    @pytest.mark.parametrize("factor", FACTORIZATIONS,
                             ids=lambda f: f"K{f[0]}xM{f[1]}")
    def test_sharded_equals_unpack_equals_reference(self, kind, factor):
        """10-step trainer run: the sharded-packed pipeline ≡ the PR-4
        differentiate-through-unpack path ≡ reference, losses and final
        params, under both optimizers and both mesh factorizations."""
        k, m = factor
        skip_unless_devices(k * m)
        mesh = make_worker_mesh(k, model_parallel=m)
        configs = {
            "reference": (dict(backend="reference"), {}),
            "unpack2d": (dict(backend="pallas", comm="axis", mesh=mesh),
                         {}),
            "sharded2d": (dict(backend="pallas", comm="axis", mesh=mesh),
                          dict(sharded_loss=sharded_mlp_loss)),
        }
        logs, finals = {}, {}
        for name, (kw, extra) in configs.items():
            opt, tr = _trainer_for(kind, k, kw, extra)
            state = tr.init(mlp_params())
            state, log = tr.fit(state, mlp_batches(k), 10, log_every=5)
            logs[name] = log.loss
            finals[name] = np.asarray(opt.params_of(state)["w"])
        # the unpack path reproduces the reference trajectory tightly for
        # both optimizers (same grads up to GSPMD scheduling)
        np.testing.assert_allclose(logs["reference"], logs["unpack2d"],
                                   rtol=2e-4, atol=1e-5)
        np.testing.assert_allclose(finals["reference"], finals["unpack2d"],
                                   rtol=2e-4, atol=2e-5)
        if kind == "d-adam":
            np.testing.assert_allclose(logs["reference"], logs["sharded2d"],
                                       rtol=2e-4, atol=1e-5)
            np.testing.assert_allclose(finals["reference"],
                                       finals["sharded2d"],
                                       rtol=2e-4, atol=2e-5)
        else:
            # CD-Adam's sign compressor amplifies the sharded matmul's
            # ~1e-8 reduction-order differences into isolated sign flips
            # of delta elements near zero (each worth ~2*gamma*scale);
            # the trajectories track — pin losses plus a flip budget
            # instead of elementwise equality.
            np.testing.assert_allclose(logs["reference"], logs["sharded2d"],
                                       rtol=5e-3, atol=5e-3)
            d = np.abs(finals["reference"] - finals["sharded2d"])
            assert d.mean() < 1e-4, f"mean drift {d.mean():.2e}"
            assert (d > 1e-3).mean() < 0.01, \
                f"sign-flip fraction {(d > 1e-3).mean():.4f}"
            assert d.max() < 0.1

    def test_two_layer_row_parallel_grads_compose(self):
        """Stacked row-parallel layers: the lower layer's weight grads
        flow through the upper layer's input slice. Pins
        _slice_replicated's psum'd backward — with a raw dynamic_slice
        the cotangent entering layer 1 would be slice-shaped and most of
        W1's gradient would silently vanish."""
        skip_unless_devices(8)
        K, M = 4, 2
        d_h = 128  # hidden width: W1 is (DIN, d_h), W2 is (d_h, DOUT)
        mesh = make_worker_mesh(K, model_parallel=M)

        def two_layer_loss(p, batch):
            h = jnp.tanh(batch["x"] @ p["w1"])
            pred = h @ p["w2"]
            return jnp.mean((pred - batch["y"]) ** 2)

        def sharded_two_layer(chunks, batch, ctx):
            h = jnp.tanh(row_parallel_dot(batch["x"], chunks["w1"], d_h,
                                          ctx))
            pred = row_parallel_dot(h, chunks["w2"], DOUT, ctx)
            return jnp.mean((pred - batch["y"]) ** 2)

        params = {"w1": jax.random.normal(KEY, (DIN, d_h)) * 0.02,
                  "w2": jax.random.normal(jax.random.fold_in(KEY, 1),
                                          (d_h, DOUT)) * 0.05}
        opt_r = make_optimizer("d-adam", K=K, eta=1e-2, period=2,
                               backend="reference")
        tr_r = DecentralizedTrainer(two_layer_loss, opt_r)
        opt_s = make_optimizer("d-adam", K=K, eta=1e-2, period=2,
                               backend="pallas", comm="axis", mesh=mesh)
        tr_s = DecentralizedTrainer(two_layer_loss, opt_s,
                                    sharded_loss=sharded_two_layer)
        s_r = tr_r.init(jax.tree_util.tree_map(jnp.copy, params))
        s_s = tr_s.init(jax.tree_util.tree_map(jnp.copy, params))
        s_r, log_r = tr_r.fit(s_r, mlp_batches(K), 6, log_every=3)
        s_s, log_s = tr_s.fit(s_s, mlp_batches(K), 6, log_every=3)
        np.testing.assert_allclose(log_r.loss, log_s.loss, rtol=2e-4,
                                   atol=1e-5)
        for leaf in ("w1", "w2"):
            np.testing.assert_allclose(
                np.asarray(opt_r.params_of(s_r)[leaf]),
                np.asarray(opt_s.params_of(s_s)[leaf]),
                rtol=2e-4, atol=2e-5)

    def test_quadratic_sharded_loss_parity(self):
        """The elementwise (mirror + psum) spelling on the quadratic toy:
        pins ctx.mirror and the replicated-cotangent psum (a raw psum
        would run M× gradients through Adam)."""
        skip_unless_devices(8)
        K, M, d = 4, 2, 37
        mesh = make_worker_mesh(K, model_parallel=M)
        centers = jax.random.normal(KEY, (K, d))

        def batches():
            t = 0
            while True:
                yield centers + 0.01 * t
                t += 1

        finals = {}
        for name, kw, extra in [
            ("reference", dict(backend="reference"), {}),
            ("sharded2d", dict(backend="pallas", comm="axis", mesh=mesh),
             dict(sharded_loss=sharded_quad_loss)),
        ]:
            opt = make_optimizer("d-adam", K=K, eta=5e-2, period=2, **kw)
            tr = DecentralizedTrainer(quad_loss, opt, **extra)
            state = tr.init({"x": jnp.zeros((d,))})
            state, _ = tr.fit(state, batches(), 10, log_every=5)
            finals[name] = np.asarray(opt.params_of(state)["x"])
        np.testing.assert_allclose(finals["reference"], finals["sharded2d"],
                                   rtol=2e-4, atol=2e-5)


class TestNoFullParamAllGather:
    @pytest.mark.parametrize("kind", KINDS)
    @pytest.mark.parametrize("factor", FACTORIZATIONS,
                             ids=lambda f: f"K{f[0]}xM{f[1]}")
    def test_compiled_2d_step_collectives(self, kind, factor):
        """THE acceptance instrument: the compiled sharded-packed 2D step
        contains zero all-gathers (and zero all-to-alls) of any size; the
        only collectives are the neighbor-gossip permutes (bounded by one
        device's row-shard block per hop) and the per-shard activation
        psums (bounded by the activation size, orders of magnitude under
        the full per-worker parameter bytes)."""
        k, m = factor
        skip_unless_devices(k * m)
        mesh = make_worker_mesh(k, model_parallel=m)
        opt, tr = _trainer_for(
            kind, k, dict(backend="pallas", comm="axis", mesh=mesh),
            dict(sharded_loss=sharded_mlp_loss))
        assert tr.pipeline.mode == "sharded-packed"
        state = tr.init(mlp_params())
        batch = tr._place_batch(next(mlp_batches(k)))
        hlo = tr._step.lower(state, batch).compile().as_text()

        param_bytes = 4 * (DIN * DOUT + DOUT)      # full per-worker params
        block_bytes = state.buf.nbytes // (k * m)  # one device's row shard

        # Declarative form of the acceptance gate (shared with
        # scripts/check_invariants.py): no gather/reshard of parameters of
        # any size; gossip permutes bounded by one device's packed block;
        # the activation psums bounded by B×DOUT f32 (+ slack for bias
        # assembly and CD-Adam per-leaf scales), far below parameter size.
        spec = InvariantSpec(
            name=f"sharded2d/{kind}/K{k}xM{m}",
            collective_counts={"all-gather": 0, "all-to-all": 0,
                               "reduce-scatter": 0},
            min_collective_counts={"collective-permute": 1,
                                   "all-reduce": 1},
            single_collective_bytes={
                "all-gather": 0,
                "collective-permute": block_bytes,
                "all-reduce": min(4 * B * DOUT, param_bytes // 16 - 1)},
        )
        report = evaluate_hlo(hlo, spec)
        assert report.ok, report.format()

    def test_unpack_path_reshards_where_sharded_does_not(self):
        """Motivation pin (informational direction, robust assertion): the
        PR-4 GSPMD-through-unpack step moves strictly more reshard bytes
        (all-gather + all-to-all) than the sharded pipeline, whose total
        is exactly zero."""
        skip_unless_devices(8)
        k, m = 4, 2
        mesh = make_worker_mesh(k, model_parallel=m)
        totals = {}
        for name, extra in [("unpack2d", {}),
                            ("sharded2d",
                             dict(sharded_loss=sharded_mlp_loss))]:
            opt, tr = _trainer_for(
                "d-adam", k, dict(backend="pallas", comm="axis", mesh=mesh),
                extra)
            state = tr.init(mlp_params())
            batch = tr._place_batch(next(mlp_batches(k)))
            hlo = tr._step.lower(state, batch).compile().as_text()
            s = collective_summary(hlo)
            totals[name] = (s["all-gather"]["bytes"]
                            + s["all-to-all"]["bytes"])
            if name == "sharded2d":
                report = evaluate_hlo(hlo, InvariantSpec(
                    name="sharded2d-reshard",
                    collective_bytes={"all-gather": 0, "all-to-all": 0}))
                assert report.ok, report.format()
        assert totals["sharded2d"] == 0
        assert totals["unpack2d"] > totals["sharded2d"]
