"""Tier-1 smoke test for ``examples/``.

The examples are the README's advertised entry points, yet until this
file none of them were executed by any test — an API drift in the
optimizer facade or the trainer would land green and break every new
user's first command. Runs the two paper-facing examples as real
subprocesses (fresh interpreter, the documented ``PYTHONPATH=src``
invocation) with short step counts.
"""
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def run_example(script, *args, env_extra=None, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(ROOT / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env.setdefault("JAX_PLATFORMS", "cpu")
    env.update(env_extra or {})
    return subprocess.run(
        [sys.executable, str(ROOT / "examples" / script), *args],
        capture_output=True, text=True, timeout=timeout, env=env)


def test_quickstart_runs():
    res = run_example("quickstart.py",
                      env_extra={"QUICKSTART_STEPS": "4"})
    assert res.returncode == 0, res.stderr[-2000:]
    # prints per-log-step rows and the final params line
    assert "loss" in res.stdout
    assert "final averaged-model params ready" in res.stdout


def test_serve_lm_runs():
    res = run_example("serve_lm.py", env_extra={"SERVE_NEW_TOKENS": "4"})
    assert res.returncode == 0, res.stderr[-2000:]
    # both serving rounds print their ParamStore version — the second
    # after the hot-swap (the script asserts version 2 itself)
    assert "v1:" in res.stdout
    assert "v2: re-served after hot-swap" in res.stdout


def test_online_serve_runs():
    res = run_example("online_serve.py", "--steps", "4",
                      "--publish-every", "2")
    assert res.returncode == 0, res.stderr[-2000:]
    assert "published versions: [1, 2]" in res.stdout
    assert "serving v2:" in res.stdout
    assert "AUC=" in res.stdout


def test_deepfm_ctr_runs():
    res = run_example("deepfm_ctr.py", "--steps", "4")
    assert res.returncode == 0, res.stderr[-2000:]
    # one result row per optimizer configuration of the paper's figure
    for marker in ("d-adam-vanilla", "d-adam p=4", "d-adam p=16",
                   "cd-adam p=16", "d-psgd"):
        assert marker in res.stdout, \
            f"missing {marker!r} in:\n{res.stdout[-2000:]}"
    assert "AUC=" in res.stdout
