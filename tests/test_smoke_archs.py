"""Per-assigned-architecture smoke tests (the brief's requirement):
instantiate the REDUCED variant (<=2 layers, d_model<=512, <=4 experts),
run one forward/train step on CPU, assert output shapes + no NaNs.
Also exercises one prefill+decode serve step per arch."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced, list_archs
from repro.core import make_optimizer
from repro.models import build_model

pytestmark = pytest.mark.slow  # full arch sweep; minutes of compile time

KEY = jax.random.PRNGKey(0)
SEQ = 24
BATCH = 2
K_WORKERS = 2


def make_batch(cfg, batch=BATCH, seq=SEQ):
    toks = jax.random.randint(KEY, (batch, seq + 1), 0, cfg.vocab_size)
    b = {"tokens": toks}
    if cfg.family == "vlm":
        b["patches"] = jax.random.normal(KEY, (batch, cfg.n_patches, 1024),
                                         jnp.float32)
    if cfg.family == "audio":
        b["audio_embeds"] = jax.random.normal(
            KEY, (batch, cfg.n_audio_ctx, cfg.d_model), jnp.float32)
    return b


@pytest.mark.parametrize("arch_id", list_archs())
def test_reduced_constraints(arch_id):
    cfg = get_reduced(arch_id).model
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4


@pytest.mark.parametrize("arch_id", list_archs())
def test_forward_and_train_step(arch_id):
    cfg = get_reduced(arch_id).model
    api = build_model(cfg)
    params = api.init(KEY)
    batch = make_batch(cfg)

    loss = api.loss(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), f"{arch_id}: NaN loss"

    # one decentralized train step with K=2 workers
    opt = make_optimizer("d-adam", K=K_WORKERS, eta=1e-3, period=2)
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (K_WORKERS,) + x.shape), params)
    state = opt.init(stacked)
    sbatch = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (K_WORKERS,) + x.shape), batch)
    grads = jax.vmap(jax.grad(api.loss))(state.params, sbatch)
    new_state = opt.step(state, grads)

    moved = jax.tree_util.tree_reduce(
        lambda acc, ab: acc + float(jnp.sum(jnp.abs(
            ab[0].astype(jnp.float32) - ab[1].astype(jnp.float32)))),
        jax.tree_util.tree_map(lambda a, b: (a, b), new_state.params,
                               state.params),
        0.0, is_leaf=lambda t: isinstance(t, tuple))
    assert moved > 0.0, f"{arch_id}: params did not update"
    for leaf in jax.tree_util.tree_leaves(new_state.params):
        assert not bool(jnp.any(jnp.isnan(leaf.astype(jnp.float32)))), \
            f"{arch_id}: NaN params after step"


@pytest.mark.parametrize("arch_id", list_archs())
def test_serve_prefill_decode(arch_id):
    cfg = get_reduced(arch_id).model
    api = build_model(cfg)
    params = api.init(KEY)
    batch = make_batch(cfg)
    prompt = {**batch, "tokens": batch["tokens"][:, :SEQ]}
    extra = cfg.n_patches if cfg.family == "vlm" else 0
    logits, cache = api.prefill(params, prompt, cache_len=SEQ + extra + 4)
    ld, cache2 = api.decode_step(params, cache, batch["tokens"][:, SEQ])
    assert ld.shape == (BATCH, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(ld))), f"{arch_id}: NaN decode logits"


@pytest.mark.parametrize("arch_id", ["llama3.2-1b", "rwkv6-3b",
                                     "zamba2-7b", "phi3.5-moe-42b-a6.6b"])
def test_cdadam_train_step(arch_id):
    """CD-Adam (sign) one round on the reduced arch — the paper's Alg. 2
    applied to a real model pytree."""
    cfg = get_reduced(arch_id).model
    api = build_model(cfg)
    params = api.init(KEY)
    opt = make_optimizer("cd-adam", K=K_WORKERS, eta=1e-3, period=1,
                         compressor="sign")
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (K_WORKERS,) + x.shape), params)
    state = opt.init(stacked)
    batch = make_batch(cfg)
    sbatch = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (K_WORKERS,) + x.shape), batch)
    grads = jax.vmap(jax.grad(api.loss))(state.params, sbatch)
    new_state = opt.step(state, grads)
    for leaf in jax.tree_util.tree_leaves(new_state.hat_self):
        assert not bool(jnp.any(jnp.isnan(leaf.astype(jnp.float32))))
