"""Definition 2 (delta-contraction) property tests.

Formerly hypothesis-driven; now a seeded explicit case table (edge cases +
deterministic random draws) so the suite runs with stdlib pytest only.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import (identity, make_compressor, quantize,
                                    randk, sign, topk, tree_dense_bytes,
                                    tree_wire_bytes)

COMPRESSORS = {
    "identity": identity(),
    "sign": sign(),
    "topk": topk(0.25),
    "randk": randk(0.25),
    "quantize": quantize(16),
}


def _case_vectors():
    """Edge-case table + seeded draws standing in for the old hypothesis
    strategy (floats in [-100, 100], length 4..256)."""
    rng = np.random.default_rng(20260729)
    cases = [
        np.zeros(4, np.float32),                      # all-zero input
        np.full(7, 100.0, np.float32),                # constant at the bound
        np.full(129, -100.0, np.float32),             # negative, off-lane len
        np.array([100.0, -100.0, 1e-6, 0.0], np.float32),  # mixed magnitude
        np.array([-0.0, 0.0, 5e-7, -5e-7], np.float32),    # signed zeros/tiny
        np.linspace(-100, 100, 256).astype(np.float32),
        (np.arange(33) % 2 * 2 - 1).astype(np.float32) * 50.0,  # alternating
    ]
    for n in (4, 33, 128, 255):
        cases.append(rng.uniform(-100, 100, size=n).astype(np.float32))
    return cases


VECS = _case_vectors()


@pytest.mark.parametrize("name", ["identity", "sign", "topk", "quantize"])
@pytest.mark.parametrize("case", range(len(VECS)))
def test_delta_contraction(name, case):
    """||x - Q(x)||^2 <= (1 - delta) ||x||^2 with delta = delta_bound(d).
    (randk satisfies this only in expectation — tested separately.)"""
    comp = COMPRESSORS[name]
    x = jnp.asarray(VECS[case], jnp.float32)
    qx = comp.apply(x)
    lhs = float(jnp.sum((x - qx) ** 2))
    delta = comp.delta_bound(x.size)
    rhs = (1.0 - delta) * float(jnp.sum(x ** 2))
    assert lhs <= rhs + 1e-4 * max(1.0, float(jnp.sum(x ** 2)))


def test_randk_contraction_in_expectation():
    """E_x ||x - Q(x)||^2 = (1 - k/d) E||x||^2 for isotropic x (the form
    in which random sparsification is delta-contractive)."""
    comp = COMPRESSORS["randk"]
    d = 64
    xs = jax.random.normal(jax.random.PRNGKey(3), (200, d))
    errs = jax.vmap(lambda x: jnp.sum((x - comp.apply(x)) ** 2))(xs)
    norms = jax.vmap(lambda x: jnp.sum(x ** 2))(xs)
    ratio = float(jnp.mean(errs) / jnp.mean(norms))
    assert abs(ratio - (1 - comp.delta_bound(d))) < 0.1


@pytest.mark.parametrize("name", sorted(COMPRESSORS))
def test_wire_roundtrip_equals_apply(name):
    comp = COMPRESSORS[name]
    x = jax.random.normal(jax.random.PRNGKey(0), (133,))
    np.testing.assert_allclose(np.asarray(comp.roundtrip(x)),
                               np.asarray(comp.apply(x)), rtol=1e-6,
                               atol=1e-6)


def test_wire_bytes_ordering():
    """sign < quantize16 ~ sign < topk(1/4) < identity for f32 payloads."""
    shape, dtype = (4096,), jnp.float32
    b_id = COMPRESSORS["identity"].wire_bytes(shape, dtype)
    b_sign = COMPRESSORS["sign"].wire_bytes(shape, dtype)
    b_topk = COMPRESSORS["topk"].wire_bytes(shape, dtype)
    assert b_sign < b_topk < b_id
    assert b_sign <= shape[0] + 4
    # paper's headline: sign is ~4x smaller than f32 (32x in bits -> 8x
    # per byte granularity; 1 byte/elem here = 4x vs f32)
    assert b_id / b_sign >= 3.9


def test_sign_scale_is_l1_mean():
    x = jnp.asarray([1.0, -2.0, 3.0, -4.0])
    enc = COMPRESSORS["sign"].encode(x)
    assert abs(float(enc["scale"]) - 2.5) < 1e-6
    assert enc["bits"].dtype == jnp.int8


def test_topk_keeps_largest():
    x = jnp.asarray([0.1, -5.0, 0.2, 4.0, 0.0, 0.05, -0.3, 1.0])
    q = topk(0.25).apply(x)  # k = 2
    nz = np.nonzero(np.asarray(q))[0]
    assert set(nz) == {1, 3}


def test_tree_wire_accounting():
    tree = {"a": jnp.zeros((64, 64)), "b": jnp.zeros((128,))}
    dense = tree_dense_bytes(tree)
    wire = tree_wire_bytes(COMPRESSORS["sign"], tree)
    assert dense == (64 * 64 + 128) * 4
    assert wire == (64 * 64 + 4) + (128 + 4)


def test_unknown_compressor_raises():
    with pytest.raises(KeyError):
        make_compressor("nope")
