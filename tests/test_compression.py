"""Definition 2 (delta-contraction) property tests via hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.compression import (identity, make_compressor, quantize,
                                    randk, sign, topk, tree_dense_bytes,
                                    tree_wire_bytes)

COMPRESSORS = {
    "identity": identity(),
    "sign": sign(),
    "topk": topk(0.25),
    "randk": randk(0.25),
    "quantize": quantize(16),
}

vecs = st.lists(st.floats(min_value=-100, max_value=100,
                          allow_nan=False, allow_infinity=False,
                          width=32),
                min_size=4, max_size=256)


@pytest.mark.parametrize("name", ["identity", "sign", "topk", "quantize"])
@given(data=vecs)
@settings(max_examples=30, deadline=None)
def test_delta_contraction(name, data):
    """||x - Q(x)||^2 <= (1 - delta) ||x||^2 with delta = delta_bound(d).
    (randk satisfies this only in expectation — tested separately.)"""
    comp = COMPRESSORS[name]
    x = jnp.asarray(data, jnp.float32)
    qx = comp.apply(x)
    lhs = float(jnp.sum((x - qx) ** 2))
    delta = comp.delta_bound(x.size)
    rhs = (1.0 - delta) * float(jnp.sum(x ** 2))
    assert lhs <= rhs + 1e-4 * max(1.0, float(jnp.sum(x ** 2)))


def test_randk_contraction_in_expectation():
    """E_x ||x - Q(x)||^2 = (1 - k/d) E||x||^2 for isotropic x (the form
    in which random sparsification is delta-contractive)."""
    comp = COMPRESSORS["randk"]
    d = 64
    xs = jax.random.normal(jax.random.PRNGKey(3), (200, d))
    errs = jax.vmap(lambda x: jnp.sum((x - comp.apply(x)) ** 2))(xs)
    norms = jax.vmap(lambda x: jnp.sum(x ** 2))(xs)
    ratio = float(jnp.mean(errs) / jnp.mean(norms))
    assert abs(ratio - (1 - comp.delta_bound(d))) < 0.1


@pytest.mark.parametrize("name", sorted(COMPRESSORS))
def test_wire_roundtrip_equals_apply(name):
    comp = COMPRESSORS[name]
    x = jax.random.normal(jax.random.PRNGKey(0), (133,))
    np.testing.assert_allclose(np.asarray(comp.roundtrip(x)),
                               np.asarray(comp.apply(x)), rtol=1e-6,
                               atol=1e-6)


def test_wire_bytes_ordering():
    """sign < quantize16 ~ sign < topk(1/4) < identity for f32 payloads."""
    shape, dtype = (4096,), jnp.float32
    b_id = COMPRESSORS["identity"].wire_bytes(shape, dtype)
    b_sign = COMPRESSORS["sign"].wire_bytes(shape, dtype)
    b_topk = COMPRESSORS["topk"].wire_bytes(shape, dtype)
    assert b_sign < b_topk < b_id
    assert b_sign <= shape[0] + 4
    # paper's headline: sign is ~4x smaller than f32 (32x in bits -> 8x
    # per byte granularity; 1 byte/elem here = 4x vs f32)
    assert b_id / b_sign >= 3.9


def test_sign_scale_is_l1_mean():
    x = jnp.asarray([1.0, -2.0, 3.0, -4.0])
    enc = COMPRESSORS["sign"].encode(x)
    assert abs(float(enc["scale"]) - 2.5) < 1e-6
    assert enc["bits"].dtype == jnp.int8


def test_topk_keeps_largest():
    x = jnp.asarray([0.1, -5.0, 0.2, 4.0, 0.0, 0.05, -0.3, 1.0])
    q = topk(0.25).apply(x)  # k = 2
    nz = np.nonzero(np.asarray(q))[0]
    assert set(nz) == {1, 3}


def test_tree_wire_accounting():
    tree = {"a": jnp.zeros((64, 64)), "b": jnp.zeros((128,))}
    dense = tree_dense_bytes(tree)
    wire = tree_wire_bytes(COMPRESSORS["sign"], tree)
    assert dense == (64 * 64 + 128) * 4
    assert wire == (64 * 64 + 4) + (128 + 4)


def test_unknown_compressor_raises():
    with pytest.raises(KeyError):
        make_compressor("nope")
