"""Dense / non-shift topology parity for the packed gossip paths.

``dadam.gossip_packed`` has three lowerings for comm='stacked': the fused
Pallas mixing kernel (shift-invariant graphs within VMEM degree), the
mixing **einsum fallback** (``mixing='dense'``, graphs with no shift
structure, or degree > MAX_FUSED_DEGREE), and the ppermute path
(comm='axis'). The einsum fallback was previously untested against the
reference mixing — these tests pin it, per weight matrix, for the
standard zoo (ring / torus / fully-connected) and at the full optimizer
step for both optimizers.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cdadam, dadam
from repro.core.compression import sign
from repro.core.dadam import DAdamConfig
from repro.core.topology import fully_connected, make_topology, ring, torus
from repro.kernels import pack as packing

KEY = jax.random.PRNGKey(7)
FTOL = dict(rtol=2e-5, atol=2e-6)

# name -> topology with a NON-trivial weight matrix; torus(3, 3) keeps its
# shift offsets (so CD-Adam runs on it) while (2, 2) has none at all
TOPOLOGIES = {
    "ring": lambda: ring(6),
    "torus3x3": lambda: torus(3, 3),
    "torus2x2": lambda: torus(2, 2),        # no shift structure at all
    "fully_connected": lambda: fully_connected(6),
}


def ragged_tree(key, k):
    ks = jax.random.split(key, 3)
    return {
        "w": jax.random.normal(ks[0], (k, 13, 7)),
        "b": jax.random.normal(ks[1], (k, 5)),
        "nest": {"u": jax.random.normal(ks[2], (k, 3, 11, 2))},
    }


def assert_trees_close(a, b, **tol):
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32), **tol),
        a, b)


class TestEinsumFallbackMatchesReferenceMixing:
    @pytest.mark.parametrize("name", sorted(TOPOLOGIES))
    def test_gossip_packed_dense(self, name):
        """gossip_packed's einsum-over-the-buffer fallback == the
        reference dense mixing on the pytree, for each weight matrix."""
        topo = TOPOLOGIES[name]()
        tree = ragged_tree(KEY, topo.K)
        spec = packing.make_spec(tree, stacked=True,
                                 block_rows=packing.BLOCK_ROWS,
                                 leaf_align=True)
        buf = packing.pack(tree, spec)
        cfg = DAdamConfig(mixing="dense", backend="pallas")
        out = dadam.gossip_packed(buf, topo, cfg)
        ref = dadam.gossip_dense(tree, topo.weights)
        assert_trees_close(packing.unpack(out, spec), ref, **FTOL)
        # padding rows mix to zero (resident-layout soundness under the
        # einsum path too)
        pad_mask = np.asarray(
            packing.pack(jax.tree_util.tree_map(jnp.ones_like, tree),
                         spec)) == 0.0
        assert np.all(np.asarray(out)[pad_mask] == 0.0)

    @pytest.mark.parametrize("name", sorted(TOPOLOGIES))
    def test_gossip_packed_matches_pytree_dispatch(self, name):
        """The packed dispatch under mixing='roll' == the reference pytree
        dispatch with the same cfg: graphs without shift offsets (the 2x2
        torus) take the einsum fallback against W; shift-structured graphs
        take the fused kernel against the circulant offsets — in both
        cases the packed and pytree lowerings must agree, weight matrix by
        weight matrix."""
        topo = TOPOLOGIES[name]()
        tree = ragged_tree(KEY, topo.K)
        spec = packing.make_spec(tree, stacked=True,
                                 block_rows=packing.BLOCK_ROWS,
                                 leaf_align=True)
        buf = packing.pack(tree, spec)
        cfg = DAdamConfig(mixing="roll", backend="pallas")
        out = dadam.gossip_packed(buf, topo, cfg)
        assert_trees_close(packing.unpack(out, spec),
                           dadam.gossip(tree, topo, cfg), **FTOL)


class TestOptimizerStepParityOnDenseGraphs:
    @pytest.mark.parametrize("name", ["ring", "torus3x3", "fully_connected",
                                      "torus2x2"])
    def test_dadam_dense_mixing_pallas_vs_reference(self, name):
        """6 jitted D-Adam steps (period=2, both cond branches) with
        mixing='dense': the packed einsum round == the reference
        tree_map round, per weight matrix."""
        topo = TOPOLOGIES[name]()
        K = topo.K
        params = ragged_tree(KEY, K)
        states = {}
        for backend in ("reference", "pallas"):
            cfg = DAdamConfig(eta=1e-2, period=2, mixing="dense",
                              backend=backend)
            s = dadam.init(jax.tree_util.tree_map(jnp.copy, params), cfg)
            step = jax.jit(
                lambda s, g, cfg=cfg: dadam.step(s, g, topo, cfg))
            for t in range(6):
                p = s.params if hasattr(s, "params") else None
                g = jax.tree_util.tree_map(
                    lambda x: 0.5 * x + 0.01 * (t + 1), p)
                s = step(s, g)
            states[backend] = s
        assert_trees_close(states["reference"].params,
                           states["pallas"].params, **FTOL)
        assert_trees_close(states["reference"].moments.m,
                           states["pallas"].moments.m, **FTOL)

    @pytest.mark.parametrize("name", ["ring", "torus3x3", "fully_connected"])
    def test_cdadam_pallas_vs_reference(self, name):
        """6 jitted CD-Adam steps over the same weight-matrix zoo (the
        shift-structured members — CD-Adam's CHOCO state needs offsets):
        packed consensus + sign kernels == the reference path, incl. the
        per-(worker, leaf) hat copies."""
        topo = TOPOLOGIES[name]()
        K = topo.K
        params = ragged_tree(KEY, K)
        comp = sign()
        states = {}
        for backend in ("reference", "pallas"):
            from repro.core.cdadam import CDAdamConfig
            cfg = CDAdamConfig(eta=1e-2, period=2, backend=backend)
            s = cdadam.init(jax.tree_util.tree_map(jnp.copy, params), cfg,
                            topo)
            step = jax.jit(
                lambda s, g, cfg=cfg: cdadam.step(s, g, topo, cfg, comp))
            for t in range(6):
                g = jax.tree_util.tree_map(
                    lambda x: 0.5 * x + 0.01 * (t + 1), s.params)
                s = step(s, g)
            states[backend] = s
        ref, pal = states["reference"], states["pallas"]
        assert_trees_close(ref.params, pal.params, **FTOL)
        assert_trees_close(ref.hat_self, pal.hat_self, **FTOL)
        for hr, hp in zip(ref.hat_nbrs, pal.hat_nbrs):
            assert_trees_close(hr, hp, **FTOL)

    def test_dense_mixing_equals_roll_on_shift_invariant_graph(self):
        """Sanity tying the two lowerings together: on a ring the dense
        einsum and the shift path are the same operator."""
        topo = make_topology("ring", 6)
        tree = ragged_tree(KEY, 6)
        assert_trees_close(dadam.gossip_dense(tree, topo.weights),
                           dadam.gossip_shift(tree, topo), **FTOL)
