"""Infrastructure: checkpointing, data pipeline, metrics, train loop,
config registry."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore, save
from repro.configs import get_arch, get_reduced, list_archs
from repro.core import make_optimizer
from repro.data import (ctr_batch, image_batch, lm_batch, make_ctr_task)
from repro.models.deepfm import (deepfm_loss, init_deepfm, init_resnet20,
                                 resnet20_logits, resnet20_loss,
                                 init_widedeep, widedeep_loss)
from repro.train import DecentralizedTrainer
from repro.train.metrics import accuracy, auc

KEY = jax.random.PRNGKey(0)


class TestCheckpoint:
    def test_optimizer_state_roundtrip(self, tmp_path):
        opt = make_optimizer("cd-adam", K=4, compressor="sign")
        state = opt.init({"w": jnp.ones((4, 8, 3)),
                          "b": jnp.zeros((4, 5), jnp.bfloat16)})
        state = opt.step(state, {"w": jnp.ones((4, 8, 3)) * 0.1,
                                 "b": jnp.ones((4, 5), jnp.bfloat16)})
        path = str(tmp_path / "ck.npz")
        save(path, state, step=3)
        restored, step = restore(path, state)
        assert step == 3
        for a, b in zip(jax.tree_util.tree_leaves(state),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))

    def test_shape_mismatch_rejected(self, tmp_path):
        path = str(tmp_path / "ck.npz")
        save(path, {"a": jnp.ones((3,))})
        with pytest.raises(ValueError):
            restore(path, {"a": jnp.ones((4,))})


class TestData:
    def test_lm_batch_non_iid(self):
        b0 = lm_batch(KEY, 64, 32, 1000, worker=0, n_workers=8)
        b7 = lm_batch(KEY, 64, 32, 1000, worker=7, n_workers=8)
        assert b0.shape == (64, 33)
        # worker bands shift the token distribution
        assert abs(float(jnp.mean(b0)) - float(jnp.mean(b7))) > 20

    def test_ctr_batch_learnable_and_non_iid(self):
        task = make_ctr_task(0, n_fields=4, features_per_field=16)
        b0 = ctr_batch(task, KEY, 128, worker=0, n_workers=8)
        b7 = ctr_batch(task, KEY, 128, worker=7, n_workers=8)
        assert b0["feat_ids"].shape == (128, 4)
        assert 0.05 < float(jnp.mean(b0["label"])) < 0.95
        assert float(jnp.mean(b0["feat_ids"])) < float(
            jnp.mean(b7["feat_ids"]))

    def test_image_batch_class_skew(self):
        b = image_batch(KEY, 256, worker=2, n_workers=8, skew=1.0)
        counts = np.bincount(np.asarray(b["label"]), minlength=10)
        assert counts.argmax() == 2


class TestPaperModels:
    @pytest.mark.slow
    def test_deepfm_learns(self):
        task = make_ctr_task(0, n_fields=4, features_per_field=16)
        params = init_deepfm(KEY, task.n_features, task.n_fields,
                             hidden=(16,))
        batch = ctr_batch(task, KEY, 256)
        l0 = float(deepfm_loss(params, batch))
        g = jax.grad(deepfm_loss)(params, batch)
        params2 = jax.tree_util.tree_map(lambda p, g: p - 0.1 * g, params, g)
        assert float(deepfm_loss(params2, batch)) < l0

    def test_widedeep_forward(self):
        task = make_ctr_task(1, n_fields=4, features_per_field=16)
        params = init_widedeep(KEY, task.n_features, task.n_fields,
                               hidden=(16,))
        batch = ctr_batch(task, KEY, 32)
        assert not bool(jnp.isnan(widedeep_loss(params, batch)))

    @pytest.mark.slow
    def test_resnet20_shapes_and_grad(self):
        params = init_resnet20(KEY, width=8)
        images = jax.random.normal(KEY, (4, 32, 32, 3))
        logits = resnet20_logits(params, images)
        assert logits.shape == (4, 10)
        g = jax.grad(resnet20_loss)(params, {"images": images,
                                             "label": jnp.zeros(4, jnp.int32)})
        assert float(jnp.sum(jnp.abs(g["stem"]))) > 0


class TestMetrics:
    def test_auc_perfect_and_random(self):
        assert auc(np.array([.9, .8, .2, .1]), np.array([1, 1, 0, 0])) == 1.0
        assert abs(auc(np.arange(1000) % 7 / 7.0,
                       (np.arange(1000) % 2)) - 0.5) < 0.06

    def test_accuracy(self):
        logits = jnp.asarray([[1., 0.], [0., 1.]])
        assert accuracy(logits, jnp.asarray([0, 1])) == 1.0


# comm='axis' gossip/step parity lives in tests/test_comm_axis.py (in-
# process, multi-device) and tests/test_distributed*.py (subprocess).


class TestConfigs:
    def test_all_archs_have_source_citations(self):
        for a in list_archs():
            assert get_arch(a).source, a

    def test_full_configs_match_brief_dims(self):
        spec = {
            "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
            "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
            "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
            "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
            "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
            "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
            "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
            "yi-6b": (32, 4096, 32, 4, 11008, 64000),
            "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
            "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        }
        for a, (L, d, H, kv, ff, V) in spec.items():
            m = get_arch(a).model
            assert (m.n_layers, m.d_model, m.n_heads, m.n_kv_heads,
                    m.d_ff, m.vocab_size) == (L, d, H, kv, ff, V), a

    def test_moe_configs(self):
        m = get_arch("phi3.5-moe-42b-a6.6b").model
        assert (m.n_experts, m.experts_per_token) == (16, 2)
        m = get_arch("llama4-maverick-400b-a17b").model
        assert (m.n_experts, m.experts_per_token) == (128, 1)

    def test_zamba_ssm_state(self):
        assert get_arch("zamba2-7b").model.ssm_state == 64


class TestTrainerAccounting:
    def test_comm_mb_monotone_and_loss_logged(self):
        task = make_ctr_task(0, n_fields=4, features_per_field=8)
        opt = make_optimizer("d-adam", K=4, eta=1e-3, period=2)
        trainer = DecentralizedTrainer(lambda p, b: deepfm_loss(p, b), opt)
        params = init_deepfm(KEY, task.n_features, task.n_fields,
                             hidden=(8,))
        state = trainer.init(params)

        def it():
            t = 0
            while True:
                from repro.data import ctr_batch_stacked
                yield ctr_batch_stacked(task, jax.random.fold_in(KEY, t),
                                        4, 16)
                t += 1

        state, log = trainer.fit(state, it(), 8, log_every=2)
        assert len(log.loss) == 4
        assert log.comm_mb == sorted(log.comm_mb)
        assert log.comm_mb[-1] > 0


class TestMicrobatchGrad:
    @pytest.mark.slow
    def test_accumulated_equals_full_batch(self):
        """make_worker_grad(loss, M) must equal the full-batch gradient
        when the loss is a mean over the batch (CE losses are)."""
        from repro.train.grad import make_worker_grad
        from repro.configs import get_reduced
        from repro.models import build_model

        cfg = get_reduced("llama3.2-1b").model
        api = build_model(cfg)
        params = api.init(KEY)
        toks = jax.random.randint(KEY, (8, 17), 0, cfg.vocab_size)
        batch = {"tokens": toks}
        loss = lambda p, b: api.loss(p, b)
        g1 = make_worker_grad(loss, 1)(params, batch)
        g4 = make_worker_grad(loss, 4)(params, batch)
        for a, b in zip(jax.tree_util.tree_leaves(g1),
                        jax.tree_util.tree_leaves(g4)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=5e-2, atol=3e-3)

    def test_microbatch_must_divide(self):
        from repro.train.grad import make_worker_grad
        loss = lambda p, b: jnp.mean((b["x"] - p["w"]) ** 2)
        g = make_worker_grad(loss, 3)
        with pytest.raises(Exception):
            g({"w": jnp.zeros(())}, {"x": jnp.ones((8,))})  # 8 % 3 != 0
