"""The fused whole-buffer compressor: CD-Adam ``scales='worker'``.

Opt-in coarsening of the compression-scale granularity: ONE L1 scale per
worker (instead of one per (worker, leaf)), computed by a single
sign-compress kernel pass over the entire resident packed buffer. The
semantics are pinned by construction: a per-worker scale over a
multi-leaf tree must match the reference per-leaf compressor run on the
SAME parameters flattened into a single leaf (then the leaf L1 mean IS
the worker L1 mean). Plus wire-byte accounting (one 4-byte scale per
worker on the wire) and config validation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_optimizer
from repro.core.cdadam import CDAdamConfig
from repro.launch.mesh import make_worker_mesh

KEY = jax.random.PRNGKey(0)
K = 4


def ragged_tree(key, k):
    ks = jax.random.split(key, 3)
    return {
        "w": jax.random.normal(ks[0], (k, 13, 7)),
        "b": jax.random.normal(ks[1], (k, 5)),
        "nest": {"u": jax.random.normal(ks[2], (k, 3, 11, 2))},
    }


def flat_view(tree):
    """Per-worker flattened single-leaf view (pack leaf order)."""
    k = jax.tree_util.tree_leaves(tree)[0].shape[0]
    return {"all": jnp.concatenate(
        [l.reshape(k, -1) for l in jax.tree_util.tree_leaves(tree)],
        axis=1)}


def skip_unless_devices(n):
    if jax.device_count() < n:
        pytest.skip(f"needs >= {n} devices, have {jax.device_count()}")


class TestValidation:
    def test_worker_scales_require_pallas(self):
        with pytest.raises(ValueError, match="whole-buffer"):
            CDAdamConfig(scales="worker", backend="reference").validate()

    def test_unknown_scales_rejected(self):
        with pytest.raises(ValueError, match="scales"):
            CDAdamConfig(scales="both", backend="pallas").validate()

    def test_scales_meaningless_for_dadam(self):
        with pytest.raises(ValueError, match="scales"):
            make_optimizer("d-adam", K=K, scales="worker")

    def test_make_optimizer_threads_scales(self):
        opt = make_optimizer("cd-adam", K=K, backend="pallas",
                             scales="worker")
        assert opt.cfg.scales == "worker"


class TestParity:
    def test_worker_scales_equal_flat_leaf_reference(self):
        """5 steps (period=2, both cond branches): the fused whole-buffer
        compressor on a ragged multi-leaf tree == the reference per-leaf
        compressor on the flattened single-leaf view of the same state —
        per-worker scale semantics, bit-for-bit math."""
        params = ragged_tree(KEY, K)
        opt_w = make_optimizer("cd-adam", K=K, eta=1e-2, period=2,
                               gamma=0.5, backend="pallas",
                               scales="worker")
        opt_f = make_optimizer("cd-adam", K=K, eta=1e-2, period=2,
                               gamma=0.5, backend="reference",
                               compressor="sign")
        s_w = opt_w.init(jax.tree_util.tree_map(jnp.copy, params))
        s_f = opt_f.init(flat_view(params))
        step_w = jax.jit(lambda s, g: opt_w.step(s, g))
        step_f = jax.jit(lambda s, g: opt_f.step(s, g))
        for t in range(5):
            g = jax.tree_util.tree_map(
                lambda x: 0.5 * x + 0.01 * (t + 1), opt_w.params_of(s_w))
            s_w = step_w(s_w, g)
            s_f = step_f(s_f, flat_view(g))
        got = flat_view(opt_w.params_of(s_w))["all"]
        want = opt_f.params_of(s_f)["all"]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=1e-6)

    def test_worker_scales_differ_from_leaf_scales(self):
        """The granularity flag has teeth: on a ragged tree whose leaves
        have very different magnitudes the two scale modes must diverge."""
        params = ragged_tree(KEY, K)
        params["w"] = params["w"] * 100.0  # one big-magnitude leaf
        finals = {}
        for scales in ("leaf", "worker"):
            opt = make_optimizer("cd-adam", K=K, eta=1e-2, period=1,
                                 backend="pallas", scales=scales)
            s = opt.init(jax.tree_util.tree_map(jnp.copy, params))
            step = jax.jit(lambda s_, g_, o=opt: o.step(s_, g_))
            for t in range(3):
                g = jax.tree_util.tree_map(
                    lambda x: 0.5 * x + 0.01 * (t + 1), opt.params_of(s))
                s = step(s, g)
            finals[scales] = np.asarray(
                flat_view(opt.params_of(s))["all"])
        assert not np.allclose(finals["leaf"], finals["worker"],
                               rtol=1e-3, atol=1e-4)

    def test_axis_2d_worker_scales_parity(self):
        """The whole-buffer pass under the 2D mesh: per-shard |delta|
        partials psum over 'model' into the identical global per-worker
        scale — parity with the stacked worker-scales run."""
        skip_unless_devices(8)
        mesh = make_worker_mesh(K, model_parallel=2)
        params = ragged_tree(KEY, K)
        finals = {}
        for name, kw in [("stacked", {}),
                         ("axis2d", dict(comm="axis", mesh=mesh))]:
            opt = make_optimizer("cd-adam", K=K, eta=1e-2, period=2,
                                 backend="pallas", scales="worker", **kw)
            s = opt.init(jax.tree_util.tree_map(jnp.copy, params))
            step = jax.jit(lambda s_, g_, o=opt: o.step(s_, g_))
            for t in range(4):
                g = jax.tree_util.tree_map(
                    lambda x: 0.5 * x + 0.01 * (t + 1), opt.params_of(s))
                from repro.kernels import pack as packing
                s = step(s, packing.pack(g, s.spec, dtype=s.buf.dtype))
            finals[name] = np.asarray(flat_view(opt.params_of(s))["all"])
        np.testing.assert_allclose(finals["stacked"], finals["axis2d"],
                                   rtol=2e-5, atol=1e-6)


class TestCommBytes:
    def test_one_scale_per_worker_on_the_wire(self):
        params = ragged_tree(KEY, K)
        per_worker = jax.tree_util.tree_map(lambda x: x[0], params)
        n = sum(x.size for x in jax.tree_util.tree_leaves(per_worker))
        n_leaves = len(jax.tree_util.tree_leaves(per_worker))
        opt_l = make_optimizer("cd-adam", K=K, backend="pallas",
                               scales="leaf")
        opt_w = make_optimizer("cd-adam", K=K, backend="pallas",
                               scales="worker")
        deg = len(opt_l.topo.offsets)
        assert opt_l.comm_bytes_per_round(params) == deg * (n + 4 * n_leaves)
        assert opt_w.comm_bytes_per_round(params) == deg * (n + 4)
        assert opt_w.comm_bytes_per_round(params) < \
            opt_l.comm_bytes_per_round(params)
