"""Pallas kernel sweeps: interpret-mode execution vs ref.py oracles across
shapes and dtypes (the brief's per-kernel allclose requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def rnd(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


class TestFusedAdam:
    @pytest.mark.parametrize("n", [1, 128, 1000, 32768, 32768 + 17])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, n, dtype):
        p = rnd(KEY, (n,), dtype)
        g = rnd(jax.random.fold_in(KEY, 1), (n,), dtype)
        m = rnd(jax.random.fold_in(KEY, 2), (n,), jnp.float32, 0.1)
        v = jnp.abs(rnd(jax.random.fold_in(KEY, 3), (n,), jnp.float32, 0.1))
        po, mo, vo = ops.fused_adam(p, g, m, v, eta=1e-3, tau=1e-6)
        pr, mr, vr = ref.fused_adam_ref(p, g, m, v, eta=1e-3, beta1=0.9,
                                        beta2=0.999, tau=1e-6)
        tol = TOL[dtype]
        np.testing.assert_allclose(np.asarray(po, np.float32),
                                   np.asarray(pr, np.float32), **tol)
        np.testing.assert_allclose(np.asarray(vo), np.asarray(vr),
                                   rtol=2e-5, atol=2e-5)

    def test_2d_param_and_weight_decay(self):
        p = rnd(KEY, (37, 53))
        g = rnd(jax.random.fold_in(KEY, 1), (37, 53))
        m = jnp.zeros_like(p)
        v = jnp.zeros_like(p)
        po, _, _ = ops.fused_adam(p, g, m, v, eta=1e-2, weight_decay=0.1)
        pr, _, _ = ref.fused_adam_ref(p, g, m, v, eta=1e-2, beta1=0.9,
                                      beta2=0.999, tau=1e-6,
                                      weight_decay=0.1)
        np.testing.assert_allclose(np.asarray(po), np.asarray(pr),
                                   rtol=2e-5, atol=2e-5)


class TestSignCompress:
    @pytest.mark.parametrize("n", [4, 100, 32768, 40000])
    def test_sweep(self, n):
        x = rnd(KEY, (n,))
        hat = rnd(jax.random.fold_in(KEY, 1), (n,), scale=0.5)
        q, s, hn = ops.sign_compress(x, hat)
        qr, sr, hnr = ref.sign_compress_ref(x, hat)
        assert q.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
        np.testing.assert_allclose(float(s), float(sr), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(hn), np.asarray(hnr),
                                   rtol=1e-5, atol=1e-6)

    def test_padding_does_not_pollute_scale(self):
        """Scale must be mean over the TRUE n, not the padded size."""
        n = 100  # far from a (256*128) tile boundary
        x = jnp.ones((n,))
        hat = jnp.zeros((n,))
        _, s, _ = ops.sign_compress(x, hat)
        assert abs(float(s) - 1.0) < 1e-6

    def test_contraction_property_of_kernel_output(self):
        x = rnd(KEY, (4096,))
        hat = jnp.zeros((4096,))
        q, s, hn = ops.sign_compress(x, hat)
        err = float(jnp.sum((x - hn) ** 2))
        assert err <= float(jnp.sum(x ** 2))  # delta-contraction vs hat=0


class TestFlashAttention:
    @pytest.mark.parametrize("S,Hq,Hk,D,bq,bkv", [
        (128, 4, 4, 64, 64, 64),     # MHA
        (128, 4, 2, 64, 64, 32),     # GQA 2:1
        (256, 8, 1, 64, 128, 128),   # MQA
        (192, 4, 2, 128, 64, 64),    # 128-lane head dim
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, S, Hq, Hk, D, bq, bkv, dtype):
        q = rnd(KEY, (2, S, Hq, D), dtype)
        k = rnd(jax.random.fold_in(KEY, 1), (2, S, Hk, D), dtype)
        v = rnd(jax.random.fold_in(KEY, 2), (2, S, Hk, D), dtype)
        out = ops.flash_attention(q, k, v, causal=True, block_q=bq,
                                  block_kv=bkv)
        r = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(r, np.float32), **TOL[dtype])

    @pytest.mark.parametrize("window", [16, 64])
    def test_sliding_window(self, window):
        q = rnd(KEY, (1, 128, 2, 32))
        k = rnd(jax.random.fold_in(KEY, 1), (1, 128, 2, 32))
        v = rnd(jax.random.fold_in(KEY, 2), (1, 128, 2, 32))
        out = ops.flash_attention(q, k, v, causal=True, window=window,
                                  block_q=32, block_kv=32)
        r = ref.flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(r),
                                   rtol=2e-5, atol=2e-5)

    def test_non_causal(self):
        q = rnd(KEY, (1, 64, 2, 32))
        k = rnd(jax.random.fold_in(KEY, 1), (1, 64, 2, 32))
        v = rnd(jax.random.fold_in(KEY, 2), (1, 64, 2, 32))
        out = ops.flash_attention(q, k, v, causal=False, block_q=32,
                                  block_kv=32)
        r = ref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(r),
                                   rtol=2e-5, atol=2e-5)


class TestRWKVScan:
    @pytest.mark.parametrize("S,H,D,chunk", [
        (64, 2, 32, 16), (96, 3, 32, 32), (128, 1, 64, 128),
        (60, 2, 32, 16),  # chunk does not divide -> shrink
    ])
    def test_sweep(self, S, H, D, chunk):
        B = 2
        ks = [jax.random.fold_in(KEY, i) for i in range(6)]
        r = rnd(ks[0], (B, S, H, D), scale=0.3)
        k = rnd(ks[1], (B, S, H, D), scale=0.3)
        v = rnd(ks[2], (B, S, H, D), scale=0.3)
        w = jax.nn.sigmoid(rnd(ks[3], (B, S, H, D)))
        u = rnd(ks[4], (H, D), scale=0.1)
        s0 = rnd(ks[5], (B, H, D, D), scale=0.1)
        y, sf = ops.rwkv_scan(r, k, v, w, u, s0, chunk=chunk)
        yr, sfr = ref.rwkv_scan_ref(r, k, v, w, u, s0)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(sf), np.asarray(sfr),
                                   rtol=1e-4, atol=1e-4)

    def test_state_continuity_across_calls(self):
        """Two chunked calls == one long call (serving decode contract)."""
        B, S, H, D = 1, 64, 2, 32
        ks = [jax.random.fold_in(KEY, 10 + i) for i in range(5)]
        r = rnd(ks[0], (B, S, H, D), scale=0.3)
        k = rnd(ks[1], (B, S, H, D), scale=0.3)
        v = rnd(ks[2], (B, S, H, D), scale=0.3)
        w = jax.nn.sigmoid(rnd(ks[3], (B, S, H, D)))
        u = rnd(ks[4], (H, D), scale=0.1)
        s0 = jnp.zeros((B, H, D, D))
        y_full, s_full = ops.rwkv_scan(r, k, v, w, u, s0, chunk=32)
        y1, s1 = ops.rwkv_scan(r[:, :32], k[:, :32], v[:, :32], w[:, :32],
                               u, s0, chunk=32)
        y2, s2 = ops.rwkv_scan(r[:, 32:], k[:, 32:], v[:, 32:], w[:, 32:],
                               u, s1, chunk=32)
        np.testing.assert_allclose(np.asarray(y_full[:, 32:]),
                                   np.asarray(y2), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2),
                                   rtol=1e-4, atol=1e-4)
