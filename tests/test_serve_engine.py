"""Batched decode engine invariants (``serve.engine.DecodeEngine``).

Pins the padded-bucket exactness contract and the compile-cache economy:

* engine output through a bucket is BITWISE equal to the unbatched
  ``greedy_generate`` reference — for exact-length prompts, seq-padded
  prompts (the rewind + re-feed path), and batch-padded request lists,
* the compile cache holds exactly one prefill + one decode program per
  bucket, and a shape that escapes the bucket set raises,
* a bf16 KV cache stays within logits tolerance of the f32 cache and
  never changes dtype discipline (upcasts are rejected),
* hot-swap: a ``ParamStore`` publish between calls is picked up by the
  very next call with no recompilation.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import build_model
from repro.serve import DecodeEngine, ParamStore, cast_cache, \
    greedy_generate, select_bucket

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def lm():
    cfg = get_reduced("llama3.2-1b").model
    api = build_model(cfg)
    params = api.init(KEY)
    return cfg, api, params


def prompts_of(lengths, vocab, seed=1):
    key = jax.random.PRNGKey(seed)
    return [jax.random.randint(jax.random.fold_in(key, i), (L,), 0, vocab)
            for i, L in enumerate(lengths)]


# ------------------------------ exactness -----------------------------------


class TestExactness:
    def test_exact_seq_matches_greedy_generate(self, lm):
        cfg, api, params = lm
        toks = jax.random.randint(KEY, (4, 16), 0, cfg.vocab_size)
        eng = DecodeEngine(cfg, params, buckets=((4, 16),),
                           max_new_tokens=8)
        ref = greedy_generate(cfg, params, {"tokens": toks}, 8,
                              cache_len=eng.cache_len_for(16))
        np.testing.assert_array_equal(
            np.asarray(eng.generate_batch(toks, 8)), np.asarray(ref))

    def test_seq_padded_prompt_is_exact(self, lm):
        """The rewind + re-feed path: a 13-token prompt through a (2, 16)
        bucket must produce the SAME tokens as serving it unpadded."""
        cfg, api, params = lm
        toks = jax.random.randint(KEY, (2, 13), 0, cfg.vocab_size)
        eng = DecodeEngine(cfg, params, buckets=((2, 16),),
                           max_new_tokens=8)
        padded = jnp.pad(toks, ((0, 0), (0, 3)))
        ref = greedy_generate(cfg, params, {"tokens": toks}, 8,
                              cache_len=eng.cache_len_for(16))
        np.testing.assert_array_equal(
            np.asarray(eng.generate_batch(padded, 8, true_len=13)),
            np.asarray(ref))

    def test_generate_groups_and_drops_batch_padding(self, lm):
        """Ragged request list: per-request outputs equal the per-request
        unbatched reference — batch-pad rows never leak out."""
        cfg, api, params = lm
        lengths = (16, 9, 16, 12, 16)
        prompts = prompts_of(lengths, cfg.vocab_size)
        eng = DecodeEngine(cfg, params, buckets=((1, 16), (4, 16)),
                           max_new_tokens=6)
        outs = eng.generate(prompts, 6)
        assert len(outs) == len(prompts)
        for p, out in zip(prompts, outs):
            ref = greedy_generate(cfg, params, {"tokens": p[None]}, 6,
                                  cache_len=eng.cache_len_for(16))
            np.testing.assert_array_equal(np.asarray(out),
                                          np.asarray(ref[0]))

    def test_n_new_zero(self, lm):
        cfg, api, params = lm
        toks = jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size)
        eng = DecodeEngine(cfg, params, buckets=((1, 16),))
        assert eng.generate_batch(toks, 0).shape == (1, 0)


# --------------------------- compile-cache economy ---------------------------


class TestCompileCache:
    def test_one_program_per_bucket(self, lm):
        cfg, api, params = lm
        eng = DecodeEngine(cfg, params, buckets=((1, 16), (4, 16)),
                           max_new_tokens=4)
        for B in (1, 4, 1, 4):
            toks = jax.random.randint(KEY, (B, 16), 0, cfg.vocab_size)
            eng.generate_batch(toks, 4)
        assert eng.compile_counts == {"prefill": 2, "decode": 2}

    def test_bucket_escape_raises(self, lm):
        cfg, api, params = lm
        eng = DecodeEngine(cfg, params, buckets=((1, 16),))
        with pytest.raises(ValueError, match="bucket"):
            eng.generate_batch(
                jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size), 2)

    def test_select_bucket(self):
        buckets = ((1, 16), (4, 16), (8, 32))
        assert select_bucket(buckets, 3, 10) == (4, 16)
        assert select_bucket(buckets, 1, 16) == (1, 16)
        assert select_bucket(buckets, 8, 20) == (8, 32)
        # oversized batch: biggest fitting bucket (caller splits)
        assert select_bucket(buckets, 9, 16) == (4, 16)
        with pytest.raises(ValueError, match="bucket"):
            select_bucket(buckets, 1, 64)
        # pad_seq off: only exact seq matches qualify
        with pytest.raises(ValueError, match="bucket"):
            select_bucket(buckets, 1, 10, pad_seq=False)


# ------------------------------ KV-cache dtype -------------------------------


class TestCacheDtype:
    def test_bf16_cache_logits_parity(self, lm):
        """Satellite pin: bf16 KV storage under f32 compute stays within
        tolerance of the f32 cache on the same decode step."""
        cfg, _, _ = lm
        cfg32 = dataclasses.replace(cfg, compute_dtype=jnp.float32)
        api = build_model(cfg32)
        params = api.init(KEY)
        toks = jax.random.randint(KEY, (2, 12), 0, cfg32.vocab_size)
        _, cache = api.prefill(params, {"tokens": toks}, cache_len=20)
        tok = jnp.zeros((2,), jnp.int32)
        l_f32, _ = api.decode_step(params, cache, tok)
        l_bf16, _ = api.decode_step(params, cast_cache(cache, jnp.bfloat16),
                                    tok)
        assert l_f32.dtype == l_bf16.dtype
        np.testing.assert_allclose(np.asarray(l_f32), np.asarray(l_bf16),
                                   rtol=5e-2, atol=5e-2)

    def test_bf16_cache_end_to_end(self, lm):
        cfg, _, _ = lm
        cfg32 = dataclasses.replace(cfg, compute_dtype=jnp.float32)
        api = build_model(cfg32)
        params = api.init(KEY)
        toks = jax.random.randint(KEY, (2, 12), 0, cfg32.vocab_size)
        eng = DecodeEngine(cfg32, params, buckets=((2, 12),),
                           max_new_tokens=4, cache_dtype=jnp.bfloat16)
        out = eng.generate_batch(toks, 4)
        assert out.shape == (2, 4) and out.dtype == jnp.int32

    def test_upcast_cache_dtype_rejected(self, lm):
        cfg, api, params = lm
        assert jnp.dtype(cfg.compute_dtype) == jnp.bfloat16
        with pytest.raises(ValueError, match="wider"):
            DecodeEngine(cfg, params, cache_dtype=jnp.float32)

    def test_cast_cache_preserves_integer_leaves(self, lm):
        cfg, api, params = lm
        toks = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
        _, cache = api.prefill(params, {"tokens": toks}, cache_len=12)
        cast = cast_cache(cache, jnp.bfloat16)
        assert cast.index.dtype == cache.index.dtype
        assert cast.k.dtype == jnp.bfloat16


# -------------------------------- hot-swap -----------------------------------


class TestHotSwap:
    def test_version_pickup_without_recompile(self, lm):
        cfg, api, params = lm
        store = ParamStore()
        store.publish(params)
        eng = DecodeEngine(cfg, store, buckets=((2, 16),),
                           max_new_tokens=4)
        toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
        out1 = eng.generate_batch(toks, 4)
        assert eng.last_version == 1

        store.publish(api.init(jax.random.PRNGKey(7)))
        out2 = eng.generate_batch(toks, 4)
        assert eng.last_version == 2
        # new params actually served (same shapes, different values)
        assert not np.array_equal(np.asarray(out1), np.asarray(out2))
        # and the swap cost zero new programs
        assert eng.compile_counts == {"prefill": 1, "decode": 1}

    def test_plain_pytree_source_serves_version_zero(self, lm):
        cfg, api, params = lm
        eng = DecodeEngine(cfg, params, buckets=((1, 16),),
                           max_new_tokens=2)
        eng.generate_batch(
            jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size), 2)
        assert eng.last_version == 0


# ------------------------------- validation ----------------------------------


class TestValidation:
    def test_empty_buckets_rejected(self, lm):
        cfg, api, params = lm
        with pytest.raises(ValueError, match="bucket"):
            DecodeEngine(cfg, params, buckets=())

    def test_true_len_out_of_range(self, lm):
        cfg, api, params = lm
        eng = DecodeEngine(cfg, params, buckets=((1, 16),))
        toks = jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size)
        with pytest.raises(ValueError, match="true_len"):
            eng.generate_batch(toks, 2, true_len=17)
        with pytest.raises(ValueError, match="true_len"):
            eng.generate_batch(toks, 2, true_len=0)

    def test_n_new_beyond_headroom_rejected(self, lm):
        cfg, api, params = lm
        eng = DecodeEngine(cfg, params, buckets=((1, 16),),
                           max_new_tokens=4)
        toks = jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size)
        with pytest.raises(ValueError, match="max_new_tokens"):
            eng.generate_batch(toks, 5)

    def test_seq_padding_rejected_for_rotating_cache(self, lm):
        """A sliding-window config folds pad tokens into its rotating
        cache — the engine must refuse true_len < seq instead of serving
        silently wrong tokens."""
        cfg, _, _ = lm
        cfg_sw = dataclasses.replace(cfg, sliding_window=8)
        api = build_model(cfg_sw)
        params = api.init(KEY)
        eng = DecodeEngine(cfg_sw, params, buckets=((1, 16),),
                           max_new_tokens=2)
        assert eng.pad_seq is False
        toks = jax.random.randint(KEY, (1, 16), 0, cfg_sw.vocab_size)
        with pytest.raises(ValueError, match="pad_seq"):
            eng.generate_batch(toks, 2, true_len=10)

    def test_2d_prompts_rejected_by_generate(self, lm):
        cfg, api, params = lm
        eng = DecodeEngine(cfg, params, buckets=((1, 16),))
        with pytest.raises(ValueError, match="1-D"):
            eng.generate([jnp.zeros((1, 16), jnp.int32)], 2)
