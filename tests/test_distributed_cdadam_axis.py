"""CD-Adam axis variant (pods mode): comm_round_axis under shard_map must
match the stacked implementation — run in a subprocess with 4 host devices.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    from repro.core import cdadam
    from repro.core.cdadam import CDAdamConfig, CDAdamAxisState
    from repro.core.compression import sign
    from repro.core.topology import make_topology

    K, d = 4, 64
    mesh = jax.make_mesh((4,), ("pod",))
    topo = make_topology("ring", K)
    cfg = CDAdamConfig(eta=0.01, period=1, gamma=0.4, tau=1e-3)
    comp = sign()
    key = jax.random.PRNGKey(0)
    x_half = jax.random.normal(key, (K, d))
    hat_self = jax.random.normal(jax.random.fold_in(key, 1), (K, d)) * 0.3
    # stacked hat_nbrs convention: hat_nbrs[i][k] = hat_self[(k+s_i) % K]
    hat_nbrs = tuple(jnp.roll(hat_self, -s, axis=0) for s in topo.offsets)

    # ---- stacked reference --------------------------------------------------
    from repro.core.cdadam import CDAdamState, _comm_round
    from repro.core.dadam import AdamMoments
    mom = AdamMoments(jnp.zeros((K, d)), jnp.zeros((K, d)),
                      jnp.zeros((), jnp.int32))
    ref = _comm_round(CDAdamState({"x": x_half}, mom, {"x": hat_self},
                                  tuple({"x": hn} for hn in hat_nbrs)),
                      topo, cfg, comp)

    # ---- axis variant under shard_map --------------------------------------
    def axis_round(xh, hs, hn0, hn1):
        st = CDAdamAxisState({"x": xh[0]}, None, {"x": hs[0]},
                             ({"x": hn0[0]}, {"x": hn1[0]}))
        out = cdadam.comm_round_axis(st, topo, cfg, comp, "pod")
        return (out.params["x"][None], out.hat_self["x"][None],
                out.hat_nbrs[0]["x"][None], out.hat_nbrs[1]["x"][None])

    got = shard_map(axis_round, mesh=mesh,
                    in_specs=(P("pod"), P("pod"), P("pod"), P("pod")),
                    out_specs=(P("pod"), P("pod"), P("pod"), P("pod")))(
        x_half, hat_self, hat_nbrs[0], hat_nbrs[1])

    np.testing.assert_allclose(np.asarray(got[0]),
                               np.asarray(ref.params["x"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got[1]),
                               np.asarray(ref.hat_self["x"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got[2]),
                               np.asarray(ref.hat_nbrs[0]["x"]),
                               rtol=1e-5, atol=1e-6)
    print("OK cdadam_axis_matches_stacked")
""")


@pytest.mark.slow
def test_cdadam_axis_matches_stacked():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, (proc.stdout[-2000:], proc.stderr[-3000:])
    assert "OK cdadam_axis_matches_stacked" in proc.stdout
