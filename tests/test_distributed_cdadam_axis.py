"""CD-Adam comm='axis' (unified dispatch): the reference-backend step
under shard_map — encoded payload ppermuted over the worker mesh axis —
must match the stacked implementation. Runs in a subprocess with 4 forced
host devices. (The pre-unification ``comm_round_axis`` duplicate is gone;
this pins the single code path that replaced it.)
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import make_optimizer

    K, d = 4, 64
    mesh = jax.make_mesh((4,), ("worker",))
    params = {"x": jax.random.normal(jax.random.PRNGKey(0), (K, d))}

    stacked = make_optimizer("cd-adam", K=K, eta=0.01, period=1,
                             gamma=0.4, tau=1e-3, compressor="sign")
    axis = make_optimizer("cd-adam", K=K, eta=0.01, period=1,
                          gamma=0.4, tau=1e-3, compressor="sign",
                          comm="axis", mesh=mesh)
    s0 = stacked.init(jax.tree_util.tree_map(jnp.copy, params))
    s1 = axis.init(jax.tree_util.tree_map(jnp.copy, params))
    for t in range(3):
        g = jax.tree_util.tree_map(
            lambda x: 0.3 * x + 0.02 * (t + 1), stacked.params_of(s0))
        s0 = jax.jit(lambda s, g: stacked.step(s, g))(s0, g)
        s1 = jax.jit(lambda s, g: axis.step(s, g))(s1, g)

    np.testing.assert_allclose(np.asarray(s1.params["x"]),
                               np.asarray(s0.params["x"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(s1.hat_self["x"]),
                               np.asarray(s0.hat_self["x"]),
                               rtol=1e-5, atol=1e-6)
    for h1, h0 in zip(s1.hat_nbrs, s0.hat_nbrs):
        np.testing.assert_allclose(np.asarray(h1["x"]),
                                   np.asarray(h0["x"]),
                                   rtol=1e-5, atol=1e-6)
    print("OK cdadam_axis_matches_stacked")
""")


@pytest.mark.slow
def test_cdadam_axis_matches_stacked():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, (proc.stdout[-2000:], proc.stderr[-3000:])
    assert "OK cdadam_axis_matches_stacked" in proc.stdout
