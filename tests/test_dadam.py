"""D-Adam (Alg. 1) semantics: identities, mean preservation, convergence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dadam, make_optimizer, make_topology
from repro.core.dadam import (DAdamConfig, consensus_error, gossip_dense,
                              gossip_roll, mean_params)
from repro.optim import adam as ref_adam

KEY = jax.random.PRNGKey(0)


def quad_grads(params, centers):
    return {"x": 2.0 * (params["x"] - centers)}


def test_k1_equals_reference_adam():
    """With K=1 D-Adam must match the independent reference Adam exactly."""
    d = 16
    c = jax.random.normal(KEY, (1, d))
    opt = make_optimizer("d-adam", K=1, eta=0.01, tau=1e-6)
    state = opt.init({"x": jnp.zeros((1, d))})
    ref_p = {"x": jnp.zeros((1, d))}
    ref_s = ref_adam.init(ref_p)
    for t in range(25):
        g = quad_grads(opt.params_of(state), c)
        state = opt.step(state, g)
        ref_p, ref_s = ref_adam.step(ref_p, quad_grads(ref_p, c), ref_s,
                                     eta=0.01, tau=1e-6)
    np.testing.assert_allclose(np.asarray(state.params["x"]),
                               np.asarray(ref_p["x"]), rtol=1e-6, atol=1e-7)


def test_gossip_preserves_mean():
    """Eq. (16): x_bar is invariant under mixing with any doubly stochastic
    W — for both the dense and the roll lowering."""
    topo = make_topology("ring", 8)
    x = {"a": jax.random.normal(KEY, (8, 33)),
         "b": jax.random.normal(jax.random.fold_in(KEY, 1), (8, 5, 7))}
    for mixed in (gossip_dense(x, topo.weights), gossip_roll(x, topo)):
        for k in x:
            np.testing.assert_allclose(
                np.asarray(jnp.mean(mixed[k], 0)),
                np.asarray(jnp.mean(x[k], 0)), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("name,K", [("ring", 8), ("ring", 5),
                                    ("exponential", 8),
                                    ("fully_connected", 4)])
def test_roll_equals_dense(name, K):
    """The optimized roll/permute gossip must equal the paper-faithful
    dense mixing matmul bit-for-bit (up to float assoc.)."""
    topo = make_topology(name, K)
    x = {"w": jax.random.normal(KEY, (K, 17, 3))}
    a = gossip_dense(x, topo.weights)["w"]
    b = gossip_roll(x, topo)["w"]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-6)


def test_period_skips_communication():
    """With p=4, consensus error must stay EXACTLY constant except at
    communication rounds (skipping changes nothing locally: identical data
    => identical updates => disagreement frozen)."""
    K, d = 4, 8
    topo = make_topology("ring", K)
    cfg = DAdamConfig(eta=0.0, period=4)  # eta=0 isolates communication
    x0 = jax.random.normal(KEY, (K, d))
    state = dadam.init({"x": x0}, cfg)
    errs = []
    for t in range(8):
        state = dadam.step(state, {"x": jnp.zeros((K, d))}, topo, cfg)
        errs.append(float(consensus_error(state.params)))
    # steps 1-3 unchanged, step 4 (t+1 divisible) mixes => error drops
    assert errs[0] == errs[1] == errs[2]
    assert errs[3] < errs[2]
    assert errs[4] == errs[5] == errs[6]
    assert errs[7] < errs[6]


def test_round_equals_p_steps():
    """round_step(p batches) == p x step() with matching schedules."""
    K, d, p = 4, 6, 3
    topo = make_topology("ring", K)
    cfg = DAdamConfig(eta=0.05, period=p, tau=1e-3)
    centers = jax.random.normal(KEY, (K, d))
    batches = jax.random.normal(jax.random.fold_in(KEY, 2), (p, K, d))

    def grad_fn(params, batch):
        return {"x": 2.0 * (params["x"] - centers) + 0.0 * batch}

    s1 = dadam.init({"x": jnp.zeros((K, d))}, cfg)
    s1 = dadam.round_step(s1, grad_fn, batches, topo, cfg)

    s2 = dadam.init({"x": jnp.zeros((K, d))}, cfg)
    for t in range(p):
        s2 = dadam.step(s2, grad_fn(s2.params, batches[t]), topo, cfg)

    np.testing.assert_allclose(np.asarray(s1.params["x"]),
                               np.asarray(s2.params["x"]), rtol=1e-5,
                               atol=1e-6)


def test_convergence_homogeneous_quadratic():
    """Identical worker objectives: D-Adam with p>1 converges to optimum
    (the regime where Thm 1's sigma=0 floor vanishes)."""
    K, d = 8, 16
    c = jax.random.normal(KEY, (1, d))
    centers = jnp.broadcast_to(c, (K, d))
    opt = make_optimizer("d-adam", K=K, eta=0.05, tau=1e-3, period=4)
    state = opt.init({"x": jnp.zeros((K, d))})
    cfg = opt.cfg

    def many(state, cfg, n=400):
        step = jax.jit(lambda s: dadam.step(
            s, quad_grads(s.params, centers), opt.topo, cfg))
        for _ in range(n):
            state = step(state)
        return state

    state = many(state, cfg)
    state = many(state, dataclasses.replace(cfg, eta=cfg.eta / 10))
    state = many(state, dataclasses.replace(cfg, eta=cfg.eta / 100))
    xbar = mean_params(state.params)["x"]
    assert float(jnp.linalg.norm(xbar - c[0])) < 1e-2
    assert float(consensus_error(state.params)) < 1e-4


def test_eta_noise_floor_scales_with_eta():
    """Theorem 1's bound trades the 1/(eta T) term against eta^2 and sigma^2
    terms: under gradient NOISE the stationary error grows with eta.
    (A deterministic quadratic self-stabilizes at any eta — m decays — so
    the stochastic setting is the meaningful one.)"""
    K, d = 4, 8
    centers = jnp.broadcast_to(jax.random.normal(KEY, (1, d)), (K, d))

    def run(eta, steps=400, sigma=0.5):
        opt = make_optimizer("d-adam", K=K, eta=eta, tau=1e-2, period=2)
        state = opt.init({"x": centers + 1.0})

        def step(s, key):
            noise = sigma * jax.random.normal(key, (K, d))
            g = {"x": 2.0 * (s.params["x"] - centers) + noise}
            return opt.step(s, g)

        step = jax.jit(step)
        key = jax.random.PRNGKey(7)
        for t in range(steps):
            state = step(state, jax.random.fold_in(key, t))
        xbar = mean_params(state.params)["x"]
        return float(jnp.linalg.norm(xbar - centers[0]))

    lo, hi = run(0.003), run(0.3)
    assert lo < hi, (lo, hi)
    assert lo < 0.5


def test_moment_dtype_override():
    opt = make_optimizer("d-adam", K=2, eta=0.01,
                         moment_dtype=jnp.bfloat16)
    state = opt.init({"x": jnp.zeros((2, 8), jnp.float32)})
    assert state.moments.m["x"].dtype == jnp.bfloat16
    state = opt.step(state, {"x": jnp.ones((2, 8))})
    assert state.params["x"].dtype == jnp.float32


def test_weight_decay_shrinks_params():
    cfg_wd = DAdamConfig(eta=0.01, weight_decay=0.1)
    topo = make_topology("ring", 2)
    s = dadam.init({"x": jnp.ones((2, 4))}, cfg_wd)
    s = dadam.step(s, {"x": jnp.zeros((2, 4))}, topo, cfg_wd)
    assert float(jnp.max(s.params["x"])) < 1.0
