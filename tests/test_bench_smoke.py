"""Tier-1 smoke test for ``benchmarks/fused_step.py``.

The bench-smoke CI job only runs on pushes to main, so a PR that breaks
the benchmark script (an optimizer API drift, a renamed record field)
would land green and rot the benchmark trajectory. This non-slow test
imports the script as a module and runs one tiny config through every
timing path, pinning the record schema the CI summary and artifact
consumers read.
"""
import json

import jax
import pytest

from benchmarks import fused_step

REQUIRED_KEYS = [
    "reference_us_per_step",
    "pallas_resident_us_per_step",
    "pallas_axis_us_per_step",
    "pallas_axis2d_us_per_step",
    "pallas_repack_us_per_step",
    "resident_speedup_vs_repack",
    "adam_hbm_bytes_unfused",
    "adam_hbm_bytes_fused_resident",
    "adam_hbm_bytes_fused_repack",
    # compiled-step communication accounting (repro.analysis.hlo): the
    # bench trajectory captures what crosses the wire, not just latency
    "reference_collectives",
    "pallas_resident_collectives",
    "pallas_axis_collectives",
    "pallas_axis2d_collectives",
    # delay-1 overlap schedule on the same meshes, paired with the eager
    # numbers above so overlap regressions (latency or wire bytes) show
    "pallas_axis_overlap_us_per_step",
    "pallas_axis_overlap_collectives",
    "pallas_axis2d_overlap_us_per_step",
    "pallas_axis2d_overlap_collectives",
]

COLLECTIVE_FIELDS = {"count", "bytes", "max_bytes", "async_pairs"}


def check_collectives(summary):
    """Schema of one variant's collective summary: every kind carries
    count/bytes/max_bytes/async_pairs ints."""
    assert set(summary) >= {"all-gather", "all-reduce", "reduce-scatter",
                            "all-to-all", "collective-permute"}
    for kind, v in summary.items():
        assert set(v) == COLLECTIVE_FIELDS, (kind, v)
        for field in COLLECTIVE_FIELDS:
            assert isinstance(v[field], int) and v[field] >= 0


def test_fused_step_smoke(tmp_path, capsys):
    out = tmp_path / "bench.json"
    record = fused_step.main(workers=2, size=2048, period=1,
                             out=str(out), model_parallel=2)

    assert record["benchmark"] == "fused_step"
    assert record["jax_version"] == jax.__version__
    assert {r["kind"] for r in record["records"]} == {"d-adam", "cd-adam"}
    for rec in record["records"]:
        for key in REQUIRED_KEYS:
            assert key in rec, f"{rec['kind']} record lost {key!r}"
        # timed paths that cannot be skipped must have real numbers
        assert rec["reference_us_per_step"] > 0
        assert rec["pallas_resident_us_per_step"] > 0
        assert rec["pallas_repack_us_per_step"] > 0
        # non-sharded variants always compile -> always have collectives
        check_collectives(rec["reference_collectives"])
        check_collectives(rec["pallas_resident_collectives"])
        # device-gated paths: real numbers when the devices exist, an
        # explicit skip reason when not (never silently absent)
        if jax.device_count() >= 2:
            assert rec["pallas_axis_us_per_step"] > 0
            check_collectives(rec["pallas_axis_collectives"])
            assert rec["pallas_axis_overlap_us_per_step"] > 0
            check_collectives(rec["pallas_axis_overlap_collectives"])
        else:
            assert rec["pallas_axis_skipped"]
            assert rec["pallas_axis_collectives"] is None
            assert rec["pallas_axis_overlap_skipped"]
            assert rec["pallas_axis_overlap_collectives"] is None
        if jax.device_count() >= 4:
            assert rec["pallas_axis2d_us_per_step"] > 0
            check_collectives(rec["pallas_axis2d_collectives"])
            # the 2D-step regression the CI summary surfaces per push:
            # gossip crosses only 'worker' (permutes), never a gather —
            # and the overlap schedule must not reintroduce one either
            for field in ("pallas_axis2d_collectives",
                          "pallas_axis2d_overlap_collectives"):
                assert rec[field]["all-gather"]["count"] == 0, field
                assert rec[field]["collective-permute"]["count"] > 0, field
            assert rec["pallas_axis2d_overlap_us_per_step"] > 0
            check_collectives(rec["pallas_axis2d_overlap_collectives"])
        else:
            assert rec["pallas_axis2d_skipped"]
            assert rec["pallas_axis2d_collectives"] is None
            assert rec["pallas_axis2d_overlap_skipped"]
            assert rec["pallas_axis2d_overlap_collectives"] is None
    cd = next(r for r in record["records"] if r["kind"] == "cd-adam")
    assert cd["wire_bytes_per_round"] > 0

    # the --out artifact round-trips and the stdout JSON line parses (the
    # CI job summary scrapes both)
    assert json.loads(out.read_text()) == record
    stdout = capsys.readouterr().out
    json_lines = [ln for ln in stdout.splitlines() if ln.startswith("JSON ")]
    assert len(json_lines) == 1
    assert json.loads(json_lines[0][5:])["benchmark"] == "fused_step"


REQUIRED_HET_SCENARIOS = {"skew", "straggler", "schedule", "churn"}


def test_heterogeneity_smoke(tmp_path, capsys):
    """The heterogeneity benchmark (skew / straggler / schedule / churn)
    must keep producing the record schema the CI summary scrapes."""
    from benchmarks import heterogeneity

    out = tmp_path / "het.json"
    record = heterogeneity.main(steps=4, out=str(out))

    assert record["benchmark"] == "heterogeneity"
    assert record["jax_version"] == jax.__version__
    assert record["workers"] == heterogeneity.K
    assert record["steps"] == 4
    scenarios = {r["scenario"] for r in record["records"]}
    assert scenarios == REQUIRED_HET_SCENARIOS
    for rec in record["records"]:
        if rec["scenario"] == "churn":
            assert rec["compiles_per_membership"] == 1
            for key in ("loss_before", "loss_after", "consensus_after"):
                assert isinstance(rec[key], float)
        else:
            assert isinstance(rec["loss"], float)
            assert isinstance(rec["consensus"], float)
            assert rec["consensus"] >= 0
    assert {r["skew"] for r in record["records"]
            if r["scenario"] == "skew"} == {0.0, 0.5, 0.9}
    assert {r["topology"] for r in record["records"]
            if r["scenario"] == "schedule"} == {
                "ring", "one-peer-exponential"}
    straggler = [r for r in record["records"]
                 if r["scenario"] == "straggler"]
    assert all(r["staleness"] >= 1 and 0 < r["straggler_rate"] < 1
               for r in straggler)

    assert json.loads(out.read_text()) == record
    stdout = capsys.readouterr().out
    json_lines = [ln for ln in stdout.splitlines() if ln.startswith("JSON ")]
    assert len(json_lines) == 1
    assert json.loads(json_lines[0][5:])["benchmark"] == "heterogeneity"


def test_fused_step_axis_paths_execute_under_tier1():
    """tier1.sh forces 8 host devices, so both sharded paths must really
    run there — guard against the smoke silently degrading to
    single-device coverage."""
    if jax.device_count() < 4:
        pytest.skip("axis paths need >= 4 devices (tier1.sh forces 8)")
    record = fused_step.main(workers=2, size=2048, period=2,
                             model_parallel=2)
    for rec in record["records"]:
        assert rec["pallas_axis_us_per_step"] > 0
        assert rec["pallas_axis2d_us_per_step"] > 0


def test_damping_smoke(tmp_path, capsys):
    """The damping benchmark must keep producing its record schema AND
    its headline claim at smoke size: the damped run reaches the
    fixed-batch target loss on the DeepFM CTR task in fewer gradient
    evaluations, from ONE compiled step across every damping level."""
    from benchmarks import damping

    out = tmp_path / "damp.json"
    record = damping.main(steps=8, lm_steps=3, out=str(out))

    assert record["benchmark"] == "damping"
    assert record["jax_version"] == jax.__version__
    assert record["workers"] == damping.K
    assert {r["task"] for r in record["records"]} == {"ctr", "lm"}
    for rec in record["records"]:
        assert rec["policy"] == "adadamp"
        assert rec["max_chunks"] in (damping.CTR_CHUNKS, damping.LM_CHUNKS)
        assert isinstance(rec["target_loss"], float)
        for side in ("fixed", "damped"):
            assert rec[side]["steps"] > 0
            assert rec[side]["grad_evals"] > 0
            assert isinstance(rec[side]["final_loss"], float)
        # one XLA program serves every damping level (recompile_limit=1
        # is armed inside the benchmark, so >1 would have raised there —
        # this pins the field the CI summary scrapes)
        assert rec["damped"]["compiles"] == 1
    ctr = next(r for r in record["records"] if r["task"] == "ctr")
    assert ctr["per_worker"] is True
    # the acceptance pin: damped reaches the fixed-batch target on CTR
    # with strictly fewer gradient evaluations
    assert ctr["damped"]["reached"] is True
    assert ctr["damped"]["grad_evals"] < ctr["fixed"]["grad_evals"]

    assert json.loads(out.read_text()) == record
    stdout = capsys.readouterr().out
    json_lines = [ln for ln in stdout.splitlines() if ln.startswith("JSON ")]
    assert len(json_lines) == 1
    assert json.loads(json_lines[0][5:])["benchmark"] == "damping"


def test_serving_smoke(tmp_path, capsys):
    """The serving benchmark must keep producing its record schema AND
    its headline claims at smoke size: batched decode QPS beats
    single-request QPS, the compile cache is pinned at the bucket-set
    size, and the compiled decode step carries zero collectives."""
    from benchmarks import serving

    out = tmp_path / "serve.json"
    record = serving.main(calls=4, train_steps=1, out=str(out))

    assert record["benchmark"] == "serving"
    assert record["jax_version"] == jax.__version__
    assert record["arch"] == "llama3.2-1b"
    # compile-once cache pinned at the bucket-set size (the engine's
    # RecompileWatch would have raised on an escape before we got here)
    n_buckets = len(record["buckets"])
    assert record["compile_counts"] == {"prefill": n_buckets,
                                        "decode": n_buckets}
    # the batching acceptance pin: QPS through the (8, P) bucket strictly
    # above the (1, P) bucket
    assert record["batched"]["qps"] > record["single"]["qps"]
    assert record["batched_over_single"] is True
    for side in ("single", "batched"):
        assert record[side]["p50_s"] > 0
        assert record[side]["p99_s"] >= record[side]["p50_s"]
    # swap-phase fields present with real numbers (the <=1.5x latency
    # gate itself is asserted on the committed BENCH record, where the
    # full-size run is less noise-bound than this 4-call smoke)
    for key in ("p99_steady_s", "p99_during_swap_s", "ratio",
                "publish_p50_s"):
        assert record["swap"][key] > 0
    assert isinstance(record["swap"]["ratio_ok"], bool)
    # unpack-once accounting: a publish reads strictly less than the full
    # K-way unpack it replaces (worker mode reads 1/K of the buffer)
    hbm = record["publish_hbm_bytes"]
    assert hbm["worker"]["read_bytes"] * serving.K_TRAIN == \
        hbm["worker"]["full_unpack_read_bytes"]
    assert hbm["worker"]["read_bytes"] < \
        hbm["worker"]["full_unpack_read_bytes"]
    assert hbm["mean"]["write_bytes"] < \
        hbm["mean"]["full_unpack_write_bytes"]
    assert record["decode_collectives_ok"] is True

    assert json.loads(out.read_text()) == record
    stdout = capsys.readouterr().out
    json_lines = [ln for ln in stdout.splitlines() if ln.startswith("JSON ")]
    assert len(json_lines) == 1
    assert json.loads(json_lines[0][5:])["benchmark"] == "serving"


# ----------------------- committed bench trajectory --------------------------


def _newest_trajectory():
    """The highest-numbered committed BENCH_<pr>.json at the repo root."""
    import pathlib
    root = pathlib.Path(__file__).resolve().parents[1]
    paths = [p for p in root.glob("BENCH_*.json")
             if p.stem.split("_")[1].isdigit()]
    return max(paths, key=lambda p: int(p.stem.split("_")[1]), default=None)


def test_bench_trajectory_committed_and_schema_stable():
    """The per-PR trajectory file (scripts/bench_trajectory.py) must exist
    and its record schema must match what the benchmark code produces
    today — the same diff the bench-smoke CI job runs, so a field rename/
    drop/retype fails PRs before the push-time job ever sees it."""
    from benchmarks.common import schema_of

    path = _newest_trajectory()
    assert path is not None, \
        "no committed BENCH_<pr>.json; run scripts/bench_trajectory.py"
    committed = json.loads(path.read_text())
    assert {"pr", "jax_version", "fused_step", "heterogeneity",
            "damping", "serving"} <= set(committed)
    assert committed["pr"] == int(path.stem.split("_")[1])
    # the online-serving acceptance gates hold in the committed record:
    # batching wins and the hot-swap never costs more than 1.5x p99
    assert committed["serving"]["batched_over_single"] is True
    assert committed["serving"]["swap"]["ratio_ok"] is True
    assert committed["serving"]["decode_collectives_ok"] is True

    if jax.device_count() < 4:
        pytest.skip("schema comparison needs >= 4 devices so the fresh "
                    "record exercises the axis/axis2d paths the committed "
                    "file has (tier1.sh forces 8)")
    fresh = fused_step.main(workers=2, size=2048, period=1,
                            model_parallel=2)
    assert schema_of(fresh) == schema_of(committed["fused_step"]), \
        "fused_step record schema drifted from the committed trajectory"

    from benchmarks import heterogeneity
    fresh_het = heterogeneity.main(steps=4)
    assert schema_of(fresh_het) == schema_of(committed["heterogeneity"]), \
        "heterogeneity record schema drifted from the committed trajectory"

    from benchmarks import damping
    fresh_damp = damping.main(steps=6, lm_steps=2)
    assert schema_of(fresh_damp) == schema_of(committed["damping"]), \
        "damping record schema drifted from the committed trajectory"

    from benchmarks import serving
    fresh_serve = serving.main(calls=4, train_steps=1)
    assert schema_of(fresh_serve) == schema_of(committed["serving"]), \
        "serving record schema drifted from the committed trajectory"
