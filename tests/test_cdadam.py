"""CD-Adam (Alg. 2): error-feedback semantics + convergence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cdadam, make_optimizer, make_topology
from repro.core.cdadam import CDAdamConfig
from repro.core.compression import identity, sign
from repro.core.dadam import consensus_error, mean_params

KEY = jax.random.PRNGKey(0)


def quad_grads(params, centers):
    return {"x": 2.0 * (params["x"] - centers)}


def test_identity_compressor_hat_tracks_x():
    """With Q = identity, after every communication round xhat == x
    exactly (zero compression error)."""
    K, d = 4, 8
    topo = make_topology("ring", K)
    cfg = CDAdamConfig(eta=0.01, period=1, gamma=0.5, tau=1e-3)
    comp = identity()
    centers = jax.random.normal(KEY, (K, d))
    state = cdadam.init({"x": jnp.zeros((K, d))}, cfg, topo)
    step = jax.jit(lambda s: cdadam.step(
        s, quad_grads(s.params, centers), topo, cfg, comp))
    for _ in range(5):
        state = step(state)
        np.testing.assert_allclose(np.asarray(state.hat_self["x"]),
                                   np.asarray(state.params["x"]),
                                   rtol=1e-6, atol=1e-6)


def test_neighbor_hat_copies_consistent():
    """Worker k's copy of xhat^{(k+s)} must equal worker (k+s)'s own
    hat_self — the distributed-state invariant of Alg. 2 lines 10-11."""
    K, d = 6, 12
    topo = make_topology("ring", K)
    cfg = CDAdamConfig(eta=0.02, period=2, gamma=0.4, tau=1e-3)
    comp = sign()
    centers = jax.random.normal(KEY, (K, d))
    state = cdadam.init({"x": jnp.zeros((K, d))}, cfg, topo)
    step = jax.jit(lambda st: cdadam.step(
        st, quad_grads(st.params, centers), topo, cfg, comp))
    for _ in range(8):
        state = step(state)
    for s, hat_nbr in zip(topo.offsets, state.hat_nbrs):
        np.testing.assert_allclose(
            np.asarray(hat_nbr["x"]),
            np.asarray(jnp.roll(state.hat_self["x"], -s, axis=0)),
            rtol=1e-5, atol=1e-6)


def test_skip_rounds_freeze_hats():
    K, d = 4, 8
    topo = make_topology("ring", K)
    cfg = CDAdamConfig(eta=0.01, period=4, tau=1e-3)
    comp = sign()
    centers = jax.random.normal(KEY, (K, d))
    state = cdadam.init({"x": jnp.zeros((K, d))}, cfg, topo)
    state = cdadam.step(state, quad_grads(state.params, centers), topo, cfg,
                        comp)  # t=0: mod(1,4) != 0 -> skip
    assert float(jnp.sum(jnp.abs(state.hat_self["x"]))) == 0.0


@pytest.mark.parametrize("comp_name", [
    "sign",  # the paper's operator stays in tier-1
    pytest.param("topk", marks=pytest.mark.slow),
    pytest.param("quantize", marks=pytest.mark.slow),
])
def test_convergence_homogeneous(comp_name):
    K, d = 8, 16
    c = jax.random.normal(KEY, (1, d))
    centers = jnp.broadcast_to(c, (K, d))
    opt = make_optimizer("cd-adam", K=K, eta=0.05, tau=1e-3, period=4,
                         gamma=0.4, compressor=comp_name)
    state = opt.init({"x": jnp.zeros((K, d))})
    cfg = opt.cfg

    def many(state, cfg, n=400):
        step = jax.jit(lambda s: cdadam.step(
            s, quad_grads(s.params, centers), opt.topo, cfg,
            opt.compressor))
        for _ in range(n):
            state = step(state)
        return state

    state = many(state, cfg)
    state = many(state, dataclasses.replace(cfg, eta=cfg.eta / 10))
    state = many(state, dataclasses.replace(cfg, eta=cfg.eta / 100))
    xbar = mean_params(state.params)["x"]
    assert float(jnp.linalg.norm(xbar - c[0])) < 5e-2
    assert float(consensus_error(state.params)) < 1e-2


def test_comm_bytes_less_than_dadam():
    """The whole point: CD-Adam's per-round wire bytes << D-Adam's."""
    params = {"x": jnp.zeros((8, 4096), jnp.float32)}
    d_opt = make_optimizer("d-adam", K=8)
    c_opt = make_optimizer("cd-adam", K=8, compressor="sign")
    d_bytes = d_opt.comm_bytes_per_round(params)
    c_bytes = c_opt.comm_bytes_per_round(params)
    assert c_bytes < d_bytes / 3.5  # ~4x for f32 payloads


def test_mean_preserved_by_compressed_mixing():
    """Compressed gossip still preserves the worker mean of x: the mixing
    term sums to zero over k (W doubly stochastic) and q only moves hats."""
    K, d = 8, 32
    topo = make_topology("ring", K)
    cfg = CDAdamConfig(eta=0.0, period=1, gamma=0.4)
    comp = sign()
    x0 = jax.random.normal(KEY, (K, d))
    state = cdadam.init({"x": x0}, cfg, topo)
    before = jnp.mean(state.params["x"], 0)
    state = cdadam.step(state, {"x": jnp.zeros((K, d))}, topo, cfg, comp)
    # one more round so hats are non-trivial
    state = cdadam.step(state, {"x": jnp.zeros((K, d))}, topo, cfg, comp)
    after = jnp.mean(state.params["x"], 0)
    np.testing.assert_allclose(np.asarray(after), np.asarray(before),
                               rtol=1e-5, atol=1e-5)


def test_gamma_validation():
    with pytest.raises(ValueError):
        CDAdamConfig(gamma=0.0).validate()
