"""Adaptive batch damping (train/damping.py + the damped grad pipeline)
and the trainer accounting fixes that ride with it.

Pins:

* policy math — AdaDamp monotone loss-ratio growth, PadaDamp linear,
  GeoDamp staged doubling; the spec-string parser; config validation.
* masked-pipeline parity — a damped step with every chunk live is
  bitwise the ``microbatch=max_chunks`` accumulation step, in the
  reference AND packed modes; per-worker counts mask per worker.
* compile-once — one XLA program serves every damping level
  (``recompile_limit=1`` armed, ``_cache_size() == 1`` asserted), and a
  NaN in a masked-out chunk cannot poison the gradients.
* lr decay — once every worker sits at ``max_chunks``, the trainer
  rebuilds via ``opt.rebuild`` with a decayed eta.
* log continuation — ``TrainLog``'s cumulative counters resume across
  ``fit`` calls and an elastic ``resize``; schedule-entry comm bytes are
  accounted per round, not from a stale cached mean.
* error messages — ``stack_params(same_init=False, key=None)`` and the
  non-divisible ``_split_micro`` leaf-path error.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import make_optimizer
from repro.train import (DampingConfig, DecentralizedTrainer, make_damping,
                         make_grad_pipeline, stack_params)
from repro.train.damping import chunks_of, init_damping, resize_damp, update
from repro.train.grad import _split_micro

KEY = jax.random.PRNGKey(0)


def _loss(p, b):
    return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)


def _params():
    return {"w": jax.random.normal(KEY, (6, 2)) * 0.1}


def _batches(K=2, batch=8, seed=0):
    t = 0
    while True:
        kt = jax.random.fold_in(jax.random.PRNGKey(seed), t)
        x = jax.random.normal(kt, (K, batch, 6))
        yield {"x": x, "y": x @ jnp.ones((6, 2))}
        t += 1


# ------------------------------ policy math ----------------------------------


class TestPolicies:
    def test_adadamp_grows_with_loss_ratio_and_is_monotone(self):
        cfg = DampingConfig(policy="adadamp", max_chunks=8, ema=0.0)
        d = init_damping(cfg, K=2)
        d = update(d, jnp.array([4.0, 4.0]), cfg)   # seeds loss0=4
        assert [int(c) for c in chunks_of(d, cfg, 2)] == [1, 1]
        d = update(d, jnp.array([1.0, 1.0]), cfg)   # 4x drop -> 4 chunks
        assert [int(c) for c in chunks_of(d, cfg, 2)] == [4, 4]
        d = update(d, jnp.array([8.0, 8.0]), cfg)   # spike: never shrinks
        assert [int(c) for c in chunks_of(d, cfg, 2)] == [4, 4]
        d = update(d, jnp.array([0.25, 0.25]), cfg)
        assert [int(c) for c in chunks_of(d, cfg, 2)] == [8, 8]

    def test_adadamp_per_worker_signals_diverge(self):
        cfg = DampingConfig(policy="adadamp", max_chunks=8, ema=0.0,
                            per_worker=True)
        d = init_damping(cfg, K=2)
        d = update(d, jnp.array([4.0, 4.0]), cfg)
        d = update(d, jnp.array([1.0, 4.0]), cfg)  # only worker 0 improved
        assert [int(c) for c in chunks_of(d, cfg, 2)] == [4, 1]

    def test_padadamp_linear(self):
        cfg = DampingConfig(policy="padadamp", max_chunks=8, rate=1.0)
        d = init_damping(cfg, K=1)
        for want in (1, 2, 3, 4):
            assert int(chunks_of(d, cfg, 1)[0]) == want
            d = update(d, jnp.array([1.0]), cfg)

    def test_geodamp_staged_doubling(self):
        cfg = DampingConfig(policy="geodamp", max_chunks=8, factor=2.0,
                            delay=2)
        d, seen = init_damping(cfg, K=1), []
        for _ in range(8):
            seen.append(int(chunks_of(d, cfg, 1)[0]))
            d = update(d, jnp.array([1.0]), cfg)
        assert seen == [1, 1, 2, 2, 4, 4, 8, 8]

    def test_eval_and_ceiling_counters(self):
        cfg = DampingConfig(policy="geodamp", max_chunks=2, factor=2.0,
                            delay=1)
        d = init_damping(cfg, K=2)
        d = update(d, jnp.array([1.0, 1.0]), cfg)  # consumed 2x1 chunks
        assert int(d.evals) == 2 and int(d.at_max) == 0
        d = update(d, jnp.array([1.0, 1.0]), cfg)  # now at 2x2 (ceiling)
        assert int(d.evals) == 6 and int(d.at_max) == 1

    def test_parser_and_validation(self):
        assert make_damping("adadamp:8").max_chunks == 8
        assert make_damping("padadamp:4:0.5").rate == 0.5
        g = make_damping("geodamp:8:2:50")
        assert (g.factor, g.delay) == (2.0, 50)
        assert make_damping(None) is None
        cfg = DampingConfig()
        assert make_damping(cfg) is cfg
        with pytest.raises(ValueError, match="unknown damping policy"):
            make_damping("warp:4")
        with pytest.raises(ValueError, match="min_chunks"):
            DampingConfig(max_chunks=2, min_chunks=3)
        with pytest.raises(ValueError, match="rate"):
            DampingConfig(policy="padadamp", rate=0.0)
        with pytest.raises(ValueError, match="factor"):
            DampingConfig(policy="geodamp", factor=1.0)

    def test_resize_round_robin(self):
        cfg = DampingConfig(policy="adadamp", max_chunks=4,
                            per_worker=True)
        d = init_damping(cfg, K=2)
        d = d._replace(level=jnp.array([3.0, 1.0]))
        grown = resize_damp(d, cfg, 3)
        assert [float(x) for x in grown.level] == [3.0, 1.0, 3.0]
        assert int(grown.evals) == int(d.evals)
        # global signal passes through untouched
        gcfg = DampingConfig(policy="adadamp", max_chunks=4)
        gd = init_damping(gcfg, K=2)
        assert resize_damp(gd, gcfg, 5) is gd


# ------------------------- masked-pipeline parity ----------------------------


class TestDampedPipelineParity:
    def _batch(self, K=2, batch=8):
        return next(_batches(K, batch))

    @pytest.mark.parametrize("backend", ["reference", "pallas"])
    def test_all_chunks_live_equals_microbatch(self, backend):
        """n == max_chunks must reproduce the undamped microbatch
        accumulation exactly — same scan, mask all-true."""
        C, K = 4, 2
        opt = make_optimizer("d-adam", K=K, eta=1e-2, backend=backend)
        state = opt.init(stack_params(_params(), K))
        batch = self._batch(K)
        damped = make_grad_pipeline(_loss, opt, damping_chunks=C)
        plain = make_grad_pipeline(_loss, opt, microbatch=C)
        n = jnp.full((K,), C, jnp.int32)
        dl, dg = damped.value_and_grad(state, batch, n)
        pl, pg = plain.value_and_grad(state, batch)
        assert jnp.allclose(dl, pl, atol=1e-6)
        for a, b in zip(jax.tree_util.tree_leaves(dg),
                        jax.tree_util.tree_leaves(pg)):
            assert jnp.allclose(a, b, atol=1e-6)

    @pytest.mark.parametrize("backend", ["reference", "pallas"])
    def test_per_worker_counts_mask_per_worker(self, backend):
        """Worker k with n[k]=1 must get exactly its first-chunk grads
        while a worker at the ceiling gets the full-batch grads."""
        C, K = 4, 2
        opt = make_optimizer("d-adam", K=K, eta=1e-2, backend=backend)
        state = opt.init(stack_params(_params(), K))
        batch = self._batch(K)
        damped = make_grad_pipeline(_loss, opt, damping_chunks=C)
        losses, grads = damped.value_and_grad(
            state, batch, jnp.array([1, C], jnp.int32))
        # worker 0: first chunk only
        chunk0 = jax.tree_util.tree_map(lambda x: x[:1, :2], batch)
        l0, g0 = jax.value_and_grad(_loss)(
            jax.tree_util.tree_map(lambda x: x[0],
                                   opt.params_of(state)),
            jax.tree_util.tree_map(lambda x: x[0], chunk0))
        assert jnp.allclose(losses[0], l0, atol=1e-6)
        # worker 1: the full batch
        l1, g1 = jax.value_and_grad(_loss)(
            jax.tree_util.tree_map(lambda x: x[1],
                                   opt.params_of(state)),
            jax.tree_util.tree_map(lambda x: x[1], batch))
        assert jnp.allclose(losses[1], l1, atol=1e-6)
        if backend == "reference":
            assert jnp.allclose(grads["w"][0], g0["w"], atol=1e-6)
            assert jnp.allclose(grads["w"][1], g1["w"], atol=1e-5)

    def test_nan_in_masked_chunk_cannot_poison(self):
        """Masking is where-based, not multiply-based: a NaN in a chunk
        past the live count must not reach the grads."""
        C, K = 2, 1
        opt = make_optimizer("d-adam", K=K, eta=1e-2)
        state = opt.init(stack_params(_params(), K))
        batch = next(_batches(K, 8))
        # poison the second chunk (rows 4:)
        batch["x"] = batch["x"].at[:, 4:].set(jnp.nan)
        damped = make_grad_pipeline(_loss, opt, damping_chunks=C)
        losses, grads = damped.value_and_grad(
            state, batch, jnp.array([1], jnp.int32))
        assert jnp.isfinite(losses).all()
        assert all(jnp.isfinite(g).all()
                   for g in jax.tree_util.tree_leaves(grads))

    def test_damping_excludes_microbatch(self):
        opt = make_optimizer("d-adam", K=2, eta=1e-2)
        with pytest.raises(ValueError, match="not both"):
            make_grad_pipeline(_loss, opt, microbatch=2, damping_chunks=4)
        with pytest.raises(ValueError, match="not both"):
            DecentralizedTrainer(_loss, opt, microbatch=2,
                                 damping="adadamp:4")


# --------------------------- trainer integration -----------------------------


class TestDampedTrainer:
    def test_compile_once_across_levels(self):
        """GeoDamp walks through every level; the jitted step must stay
        at ONE compiled signature (JXL003 recompile watch armed)."""
        opt = make_optimizer("d-adam", K=2, eta=1e-2, period=2)
        tr = DecentralizedTrainer(
            _loss, opt, recompile_limit=1,
            damping=DampingConfig(policy="geodamp", max_chunks=4,
                                  factor=2.0, delay=2))
        state = tr.init(_params())
        state, log = tr.fit(state, _batches(), 8, log_every=2)
        assert tr._step._cache_size() == 1
        # evals: 2 workers x chunks/step walking 1,1,2,2,4,4,4,4
        assert log.grad_evals[-1] == 2 * (1 + 1 + 2 + 2 + 4 + 4 + 4 + 4)

    def test_damped_loss_decreases(self):
        opt = make_optimizer("d-adam", K=2, eta=1e-2, period=2)
        tr = DecentralizedTrainer(_loss, opt, damping="adadamp:4")
        state = tr.init(_params())
        state, log = tr.fit(state, _batches(), 30, log_every=10)
        assert log.loss[-1] < log.loss[0]

    def test_lr_decay_rebuilds_with_smaller_eta(self):
        """min==max chunks puts every step at the ceiling; after
        lr_decay_every such steps the trainer must rebuild with decayed
        eta via opt.rebuild."""
        opt = make_optimizer("d-adam", K=2, eta=1e-2, period=2)
        tr = DecentralizedTrainer(
            _loss, opt,
            damping=DampingConfig(policy="geodamp", max_chunks=2,
                                  min_chunks=2, factor=2.0, delay=1,
                                  lr_decay=0.5, lr_decay_every=4))
        state = tr.init(_params())
        state, _ = tr.fit(state, _batches(), 4, log_every=4)
        assert tr.opt.cfg.eta == pytest.approx(5e-3)
        state, _ = tr.fit(state, _batches(), 8, log_every=4)
        assert tr.opt.cfg.eta == pytest.approx(1.25e-3)

    def test_rebuild_hook_reproduces_config(self):
        opt = make_optimizer("cd-adam", K=4, eta=1e-3, period=2,
                             topology="ring", gamma=0.3)
        opt2 = opt.rebuild(eta=5e-4)
        assert opt2.cfg.eta == pytest.approx(5e-4)
        assert opt2.cfg.gamma == opt.cfg.gamma
        assert opt2.name == opt.name and opt2.K == opt.K

    @pytest.mark.skipif(jax.device_count() < 2,
                        reason="comm='axis' needs >= 2 devices")
    def test_axis_parity_with_stacked(self):
        """Damped training must give the same trajectory under
        comm='axis' as comm='stacked' (same masked accumulation, gossip
        lowered differently)."""
        from repro.launch.mesh import make_worker_mesh

        K = 2
        damp = DampingConfig(policy="geodamp", max_chunks=2, delay=2,
                             factor=2.0)
        runs = {}
        for comm, mesh in (("stacked", None),
                           ("axis", make_worker_mesh(K))):
            opt = make_optimizer("d-adam", K=K, eta=1e-2, period=2,
                                 backend="pallas", comm=comm, mesh=mesh)
            tr = DecentralizedTrainer(_loss, opt, damping=damp)
            state = tr.init(_params())
            state, log = tr.fit(state, _batches(), 6, log_every=2)
            runs[comm] = log.loss
        assert runs["stacked"] == pytest.approx(runs["axis"], rel=1e-4)


# ----------------------- log continuation + accounting -----------------------


class TestLogContinuation:
    def _trainer(self, K=2, **kw):
        opt = make_optimizer("d-adam", K=K, eta=1e-2, period=2, **kw)
        tr = DecentralizedTrainer(_loss, opt)
        return tr, tr.init(_params())

    def test_counters_resume_across_fits(self):
        """The satellite bugfix: continuing a log across fit calls used
        to reset comm_rounds and t0, making comm_mb / wall_s jump
        backwards. They must now be cumulative and monotone."""
        tr, state = self._trainer()
        it = _batches()
        state, log = tr.fit(state, it, 4, log_every=2)
        state, log = tr.fit(state, it, 4, log_every=2, log=log)
        assert log.step == [2, 4, 6, 8]
        assert log.steps_total == 8
        assert log.comm_rounds_total == 4
        assert log.comm_mb == sorted(log.comm_mb)
        assert log.comm_mb[-1] == pytest.approx(2 * log.comm_mb[1])
        assert log.wall_s == sorted(log.wall_s)
        assert log.grad_evals == [4, 8, 12, 16]
        # two separate fits == one double-length fit, counter for counter
        tr2, state2 = self._trainer()
        _, log2 = tr2.fit(state2, _batches(), 8, log_every=2)
        assert log2.comm_mb == pytest.approx(log.comm_mb)
        assert log2.step == log.step

    def test_schedule_entry_bytes_accounted_per_round(self):
        """Under a TopologySchedule the per-round bytes follow the
        entry's true degree — the cached-mean bug made every round cost
        the cycle average."""
        K = 4
        opt = make_optimizer("d-adam", K=K, eta=1e-2, period=1,
                             topology="rand-ring:3")
        degs = [len(e.offsets) for e in opt.topo.entries]
        assert len(set(degs)) >= 1  # schedule exists
        tr = DecentralizedTrainer(_loss, opt)
        state = tr.init(_params())
        state, log = tr.fit(state, _batches(K), len(degs), log_every=1)
        per_round = [log.comm_mb[0]] + [
            b - a for a, b in zip(log.comm_mb, log.comm_mb[1:])]
        bytes_list = opt.comm_bytes_round_list(opt.params_of(state))
        assert per_round == pytest.approx(
            [b / 1e6 for b in bytes_list])

    def test_comm_bytes_round_list_matches_mean(self):
        opt = make_optimizer("d-adam", K=8, eta=1e-2,
                             topology="one-peer-exp")
        params = stack_params(_params(), 8)
        per_round = opt.comm_bytes_round_list(params)
        assert len(per_round) == len(opt.topo.entries)
        assert sum(per_round) / len(per_round) == pytest.approx(
            opt.comm_bytes_per_round(params))
        # static topology: one uniform entry agreeing with the mean
        ring = make_optimizer("d-adam", K=8, eta=1e-2, topology="ring")
        assert ring.comm_bytes_round_list(params) == [
            ring.comm_bytes_per_round(params)]

    def test_resize_recomputes_per_round_bytes(self):
        """The mb_per_round cache must not survive an elastic resize —
        fewer workers means different per-worker bytes under cd-adam
        whole-graph accounting and a fresh pipeline either way."""
        K = 4
        opt = make_optimizer("d-adam", K=K, eta=1e-2, period=1)
        tr = DecentralizedTrainer(_loss, opt)
        state = tr.init(_params())
        it4, it2 = _batches(4), _batches(2)
        state, log = tr.fit(state, it4, 2, log_every=1)
        mb_k4 = log.comm_mb[0]
        opt2 = make_optimizer("d-adam", K=2, eta=1e-2, period=1)
        state = tr.resize(state, opt2)
        state, log = tr.fit(state, it2, 2, log_every=1, log=log)
        mb_k2 = log.comm_mb[-1] - log.comm_mb[-2]
        # ring degree 2 at K=4 vs degree 2 at K=2 — bytes per round drop
        # (K=2 ring has a single neighbor offset)
        assert mb_k2 != mb_k4
        assert log.comm_mb == sorted(log.comm_mb)
        assert log.steps_total == 4

    def test_fresh_log_callers_unchanged(self):
        """Callers that pass no log still get per-call accounting
        starting at zero (the pre-fix external-accumulation pattern)."""
        tr, state = self._trainer()
        it = _batches()
        state, log_a = tr.fit(state, it, 4, log_every=4)
        state, log_b = tr.fit(state, it, 4, log_every=4)
        assert log_a.step == log_b.step == [4]
        assert log_a.comm_mb == pytest.approx(log_b.comm_mb)


# ------------------------------ error messages -------------------------------


class TestErrorMessages:
    def test_stack_params_missing_key(self):
        with pytest.raises(ValueError, match="needs key="):
            stack_params(_params(), 4, same_init=False,
                         init_fn=lambda k: _params())

    def test_stack_params_with_key_works(self):
        out = stack_params(_params(), 3, same_init=False,
                           key=jax.random.PRNGKey(1),
                           init_fn=lambda k: {
                               "w": jax.random.normal(k, (6, 2))})
        assert out["w"].shape == (3, 6, 2)
        assert not jnp.allclose(out["w"][0], out["w"][1])

    def test_split_micro_names_leaf_and_suggests(self):
        with pytest.raises(ValueError) as ei:
            _split_micro({"inner": {"x": jnp.zeros((6, 3))}}, 4,
                         batch_dim=0)
        msg = str(ei.value)
        assert "['inner']['x']" in msg
        assert "nearest valid count is 3" in msg
