"""Multi-device EXECUTION tests (not just lowering): run in a subprocess
with 8 forced host devices so the main test process keeps 1 device.

Covers: stacked D-Adam train step really executing under a (4, 2) mesh with
the production sharding rules; gossip_axis (ppermute inside shard_map) ==
stacked roll gossip; numerical equality of the sharded step vs the
single-device step.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    assert jax.device_count() == 8, jax.device_count()
    mesh = jax.make_mesh((4, 2), ("data", "model"))

    from repro.configs import get_reduced
    from repro.core import make_optimizer
    from repro.core.dadam import gossip_axis, gossip_roll
    from repro.core.topology import make_topology
    from repro.models import build_model

    # ---- 1. sharded stacked train step == single-device step -------------
    arch = get_reduced("llama3.2-1b")
    cfg = arch.model
    api = build_model(cfg)
    K = 4
    opt = make_optimizer("d-adam", K=K, eta=1e-3, period=2)
    params = api.init(jax.random.PRNGKey(0))
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (K,) + x.shape), params)
    state = opt.init(stacked)
    toks = jax.random.randint(jax.random.PRNGKey(1), (K, 2, 17), 0,
                              cfg.vocab_size)

    def step(state, toks):
        grads = jax.vmap(jax.grad(api.loss))(state.params,
                                             {"tokens": toks})
        return opt.step(state, grads)

    # single device reference
    ref = jax.jit(step)(state, toks)

    # sharded: worker dim on 'data', largest inner dim on 'model'
    def shard_rule(x):
        spec = [None] * x.ndim
        if x.ndim >= 1 and x.shape[0] % 4 == 0:
            spec[0] = "data"
        for d in range(x.ndim - 1, 0, -1):
            if x.shape[d] % 2 == 0 and x.shape[d] >= 2:
                spec[d] = "model"
                break
        return NamedSharding(mesh, P(*spec))

    state_sh = jax.tree_util.tree_map(shard_rule, state)
    state_dev = jax.device_put(state, state_sh)
    toks_dev = jax.device_put(toks, NamedSharding(mesh, P("data")))
    with mesh:
        out = jax.jit(step)(state_dev, toks_dev)
    for a, b in zip(jax.tree_util.tree_leaves(ref.params),
                    jax.tree_util.tree_leaves(out.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=2e-2)
    print("OK sharded_step")

    # ---- 2. axis gossip (ppermute in shard_map) == stacked roll ----------
    from jax.experimental.shard_map import shard_map
    topo = make_topology("ring", 4)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 16))
    want = gossip_roll({"x": x}, topo)["x"]

    def gossip_fn(xs):
        return gossip_axis({"x": xs}, topo, "data")["x"]

    got = shard_map(gossip_fn, mesh=mesh,
                    in_specs=P("data", None),
                    out_specs=P("data", None))(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    print("OK axis_gossip")

    # ---- 3. CD-Adam sharded execution ------------------------------------
    copt = make_optimizer("cd-adam", K=K, eta=1e-3, period=1,
                          compressor="sign")
    cstate = copt.init(stacked)
    cref = jax.jit(lambda s: copt.step(s, jax.vmap(jax.grad(api.loss))(
        s.params, {"tokens": toks})))(cstate)
    cstate_sh = jax.tree_util.tree_map(shard_rule, cstate)
    cstate_dev = jax.device_put(cstate, cstate_sh)
    with mesh:
        cout = jax.jit(lambda s: copt.step(
            s, jax.vmap(jax.grad(api.loss))(
                s.params, {"tokens": toks_dev})))(cstate_dev)
    for a, b in zip(jax.tree_util.tree_leaves(cref.params),
                    jax.tree_util.tree_leaves(cout.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=2e-2)
    print("OK cdadam_sharded")
""")


@pytest.mark.slow
def test_multidevice_execution():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, (proc.stdout[-3000:], proc.stderr[-3000:])
    for marker in ("OK sharded_step", "OK axis_gossip", "OK cdadam_sharded"):
        assert marker in proc.stdout, (marker, proc.stdout[-2000:])
