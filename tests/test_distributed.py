"""Multi-device EXECUTION tests (not just lowering): run in a subprocess
with 8 forced host devices so the main test process keeps 1 device.

Covers: stacked D-Adam train step really executing under a (4, 2) mesh with
the production sharding rules; gossip_axis (ppermute inside shard_map) ==
stacked roll gossip; numerical equality of the sharded step vs the
single-device step; and the comm='axis' packed runtime — the resident
(K, rows, 128) buffer sharded one worker per device — matching both the
single-device packed step and the reference backend.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    assert jax.device_count() == 8, jax.device_count()
    mesh = jax.make_mesh((4, 2), ("data", "model"))

    from repro.configs import get_reduced
    from repro.core import make_optimizer
    from repro.core.dadam import gossip_axis, gossip_roll
    from repro.core.topology import make_topology
    from repro.models import build_model

    # ---- 1. sharded stacked train step == single-device step -------------
    arch = get_reduced("llama3.2-1b")
    cfg = arch.model
    api = build_model(cfg)
    K = 4
    opt = make_optimizer("d-adam", K=K, eta=1e-3, period=2)
    params = api.init(jax.random.PRNGKey(0))
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (K,) + x.shape), params)
    state = opt.init(stacked)
    toks = jax.random.randint(jax.random.PRNGKey(1), (K, 2, 17), 0,
                              cfg.vocab_size)

    def step(state, toks):
        grads = jax.vmap(jax.grad(api.loss))(state.params,
                                             {"tokens": toks})
        return opt.step(state, grads)

    # single device reference
    ref = jax.jit(step)(state, toks)

    # sharded: worker dim on 'data', largest inner dim on 'model'
    def shard_rule(x):
        spec = [None] * x.ndim
        if x.ndim >= 1 and x.shape[0] % 4 == 0:
            spec[0] = "data"
        for d in range(x.ndim - 1, 0, -1):
            if x.shape[d] % 2 == 0 and x.shape[d] >= 2:
                spec[d] = "model"
                break
        return NamedSharding(mesh, P(*spec))

    state_sh = jax.tree_util.tree_map(shard_rule, state)
    state_dev = jax.device_put(state, state_sh)
    toks_dev = jax.device_put(toks, NamedSharding(mesh, P("data")))
    with mesh:
        out = jax.jit(step)(state_dev, toks_dev)
    for a, b in zip(jax.tree_util.tree_leaves(ref.params),
                    jax.tree_util.tree_leaves(out.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=2e-2)
    print("OK sharded_step")

    # ---- 2. axis gossip (ppermute in shard_map) == stacked roll ----------
    from jax.experimental.shard_map import shard_map
    topo = make_topology("ring", 4)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 16))
    want = gossip_roll({"x": x}, topo)["x"]

    def gossip_fn(xs):
        return gossip_axis({"x": xs}, topo, "data")["x"]

    got = shard_map(gossip_fn, mesh=mesh,
                    in_specs=P("data", None),
                    out_specs=P("data", None))(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    print("OK axis_gossip")

    # ---- 3. CD-Adam sharded execution ------------------------------------
    copt = make_optimizer("cd-adam", K=K, eta=1e-3, period=1,
                          compressor="sign")
    cstate = copt.init(stacked)
    cref = jax.jit(lambda s: copt.step(s, jax.vmap(jax.grad(api.loss))(
        s.params, {"tokens": toks})))(cstate)
    cstate_sh = jax.tree_util.tree_map(shard_rule, cstate)
    cstate_dev = jax.device_put(cstate, cstate_sh)
    with mesh:
        cout = jax.jit(lambda s: copt.step(
            s, jax.vmap(jax.grad(api.loss))(
                s.params, {"tokens": toks_dev})))(cstate_dev)
    for a, b in zip(jax.tree_util.tree_leaves(cref.params),
                    jax.tree_util.tree_leaves(cout.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=2e-2)
    print("OK cdadam_sharded")
""")


@pytest.mark.slow
def test_multidevice_execution():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, (proc.stdout[-3000:], proc.stderr[-3000:])
    for marker in ("OK sharded_step", "OK axis_gossip", "OK cdadam_sharded"):
        assert marker in proc.stdout, (marker, proc.stdout[-2000:])


_PACKED_AXIS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np

    assert jax.device_count() == 8, jax.device_count()
    from repro.core import make_optimizer
    from repro.kernels import pack as packing

    K = 8
    mesh = jax.make_mesh((K,), ("worker",))
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    params = {
        "w": jax.random.normal(ks[0], (K, 13, 7)),
        "b": jax.random.normal(ks[1], (K, 5)),
        "nest": {"u": jax.random.normal(ks[2], (K, 3, 11, 2))},
    }

    for kind in ("d-adam", "cd-adam"):
        # three runtimes, one trajectory: reference (pytree math),
        # single-device packed, and the packed state sharded one worker
        # per mesh slot (shard_map + ppermute gossip).
        ref = make_optimizer(kind, K=K, eta=1e-2, period=2,
                             weight_decay=0.01)
        pal = make_optimizer(kind, K=K, eta=1e-2, period=2,
                             weight_decay=0.01, backend="pallas")
        axs = make_optimizer(kind, K=K, eta=1e-2, period=2,
                             weight_decay=0.01, backend="pallas",
                             comm="axis", mesh=mesh)
        cp = lambda: jax.tree_util.tree_map(jnp.copy, params)
        s_ref, s_pal, s_axs = ref.init(cp()), pal.init(cp()), axs.init(cp())
        # the sharded state really is one (1, rows, 128) block per device
        assert {sh.data.shape for sh in s_axs.buf.addressable_shards} \\
            == {(1,) + s_axs.buf.shape[1:]}
        step_ref = jax.jit(lambda s, g: ref.step(s, g))
        step_pal = jax.jit(lambda s, g: pal.step(s, g))
        step_axs = jax.jit(lambda s, g: axs.step(s, g))
        for t in range(4):
            g = jax.tree_util.tree_map(
                lambda x: 0.5 * x + 0.01 * (t + 1), ref.params_of(s_ref))
            gbuf = packing.pack(g, s_pal.spec, dtype=s_pal.buf.dtype)
            s_ref = step_ref(s_ref, g)
            s_pal = step_pal(s_pal, gbuf)
            s_axs = step_axs(s_axs, gbuf)
        leaves = lambda o, s: jax.tree_util.tree_leaves(o.params_of(s))
        for a, b in zip(leaves(pal, s_pal), leaves(axs, s_axs)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=1e-6)
        for a, b in zip(leaves(ref, s_ref), leaves(axs, s_axs)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=1e-5)
        print(f"OK packed_axis_{kind}")
""")


@pytest.mark.slow
def test_packed_axis_matches_packed_and_reference():
    """Tentpole pin: shard_map-sharded backend='pallas' D-Adam and CD-Adam
    steps == the single-device packed step == the reference backend, under
    8 forced host devices (one worker per device)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _PACKED_AXIS_SCRIPT],
                          env=env, capture_output=True, text=True,
                          timeout=1200,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))))
    assert proc.returncode == 0, (proc.stdout[-3000:], proc.stderr[-3000:])
    for marker in ("OK packed_axis_d-adam", "OK packed_axis_cd-adam"):
        assert marker in proc.stdout, (marker, proc.stdout[-2000:])
