#!/usr/bin/env python
"""Docs gate: the documentation must not rot.

Two passes over README.md, ROADMAP.md, and docs/*.md:

1. **link check** — every relative markdown link target must exist on
   disk (anchors are stripped; http(s) links are left to humans), so a
   renamed file or section page fails the PR that renamed it;
2. **fenced-block execution** — every ```python block in docs/ is
   executed, blocks within one file sharing a namespace in order (so a
   later block can build on an earlier import). A doc that drifts from
   the real API fails here instead of misleading the next reader.
   ```bash blocks and other languages are not executed.

The python blocks in docs/ call repro.launch.env.setup() themselves
before importing jax (that is part of what they document); this script
only needs PYTHONPATH to resolve `repro`.

    PYTHONPATH=src python scripts/check_docs.py [--root DIR]
"""
import argparse
import os
import re
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^```(\w*)\s*$")


def doc_files(root: str):
    out = [p for p in (os.path.join(root, "README.md"),
                       os.path.join(root, "ROADMAP.md"))
           if os.path.exists(p)]
    docs = os.path.join(root, "docs")
    if os.path.isdir(docs):
        out += sorted(os.path.join(docs, f) for f in os.listdir(docs)
                      if f.endswith(".md"))
    return out


def check_links(path: str, root: str):
    """Relative link targets that do not exist on disk."""
    bad = []
    base = os.path.dirname(path)
    text = open(path).read()
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        target = target.split("#", 1)[0]
        if not target:          # pure in-page anchor
            continue
        resolved = os.path.normpath(os.path.join(base, target))
        if not os.path.exists(resolved):
            bad.append((os.path.relpath(path, root), target))
    return bad


def python_blocks(path: str):
    """(start_line, source) for each ```python fence in the file."""
    blocks, buf, start, lang = [], None, 0, None
    for i, line in enumerate(open(path).read().splitlines(), 1):
        m = FENCE_RE.match(line)
        if m and buf is None:
            lang, start, buf = m.group(1).lower(), i + 1, []
        elif line.strip() == "```" and buf is not None:
            if lang == "python":
                blocks.append((start, "\n".join(buf)))
            buf = None
        elif buf is not None:
            buf.append(line)
    return blocks


def run_blocks(path: str, root: str):
    """Execute the file's python blocks in one shared namespace."""
    failures = []
    ns = {"__name__": f"docs:{os.path.basename(path)}"}
    for start, src in python_blocks(path):
        try:
            code = compile(src, f"{path}:{start}", "exec")
            exec(code, ns)  # noqa: S102 - executing our own docs is the gate
        except Exception as e:  # noqa: BLE001 - report, don't crash the gate
            failures.append((os.path.relpath(path, root), start,
                             f"{type(e).__name__}: {e}"))
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--root", default=os.path.join(
        os.path.dirname(__file__), ".."))
    ap.add_argument("--no-exec", action="store_true",
                    help="link check only (no jax, fast)")
    ns = ap.parse_args(argv)
    root = os.path.abspath(ns.root)

    ok = True
    files = doc_files(root)
    for path in files:
        bad = check_links(path, root)
        for where, target in bad:
            ok = False
            print(f"[FAIL] {where}: broken link -> {target}")
    print(f"link check: {len(files)} files"
          + ("" if ok else " (broken links above)"))

    if not ns.no_exec:
        docs_dir = os.path.join(root, "docs")
        exec_files = [p for p in files
                      if os.path.dirname(p) == docs_dir]
        n_blocks = 0
        for path in exec_files:
            blocks = python_blocks(path)
            n_blocks += len(blocks)
            for where, line, err in run_blocks(path, root):
                ok = False
                print(f"[FAIL] {where}:{line}: {err}")
        print(f"executed {n_blocks} python blocks from "
              f"{len(exec_files)} docs files")

    print("check_docs: " + ("PASS" if ok else "FAIL"))
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
