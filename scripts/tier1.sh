#!/usr/bin/env bash
# Tier-1 verification: the fast, CPU-only slice of the suite.
#
#   bash scripts/tier1.sh             # pytest -x -q, slow tests deselected
#   bash scripts/tier1.sh -m ""       # override: run everything
#
# Forces the host-CPU backend with 8 virtual devices (override the count
# with REPRO_HOST_DEVICES — the CI device matrix runs 8 and 16 so both
# square and rectangular worker x model mesh factorizations are
# exercised) so the sharding / collective paths (shard_map, ppermute
# gossip, comm='axis', the 2D worker x model mesh) run without
# accelerators; Pallas kernels run via interpret mode.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS=cpu
export XLA_FLAGS="--xla_force_host_platform_device_count=${REPRO_HOST_DEVICES:-8}${XLA_FLAGS:+ $XLA_FLAGS}"

# Persistent jit-compile cache: the suite's wall clock is dominated by
# per-test XLA compiles, which are identical run to run. CI persists this
# directory via actions/cache (keyed on jax version + runner platform);
# locally it just makes the second run fast. Threshold 0 caches even
# sub-second compiles — there are hundreds of small ones.
export JAX_COMPILATION_CACHE_DIR="${JAX_COMPILATION_CACHE_DIR:-$PWD/.jax_cache}"
export JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS="${JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS:-0}"
mkdir -p "$JAX_COMPILATION_CACHE_DIR"

# Parallelize across cores when pytest-xdist is available (CI installs it;
# falls back to serial where it isn't). The wall clock is dominated by
# per-test jit compiles, which parallelize embarrassingly well.
# -x still aborts the whole session on first failure under xdist;
# --max-worker-restart 0 keeps a crashed worker from respawning past it,
# and the cache provider is disabled so workers don't race on .pytest_cache.
XDIST_ARGS=()
if python -c "import xdist" >/dev/null 2>&1; then
  XDIST_ARGS=(-n auto --max-worker-restart 0 -p no:cacheprovider)
fi

# Doctests of the documented public API. Scoped to the nine modules
# with runnable examples — --doctest-modules over all of src/ would
# import every module (some gate on devices/deps) and execute every
# stray example. set -e aborts the run if any example drifted.
python -m pytest -q --doctest-modules \
  src/repro/core/api.py \
  src/repro/core/topology.py \
  src/repro/core/schedule.py \
  src/repro/train/loop.py \
  src/repro/train/grad.py \
  src/repro/train/damping.py \
  src/repro/checkpoint/io.py \
  src/repro/analysis/invariants.py \
  src/repro/serve/publish.py

exec python -m pytest -x -q "${XDIST_ARGS[@]}" "$@"
