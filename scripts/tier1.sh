#!/usr/bin/env bash
# Tier-1 verification: the fast, CPU-only slice of the suite.
#
#   bash scripts/tier1.sh             # pytest -x -q, slow tests deselected
#   bash scripts/tier1.sh -m ""       # override: run everything
#
# Forces the host-CPU backend with 8 virtual devices so the sharding /
# collective paths (shard_map, ppermute gossip) are exercised without
# accelerators; Pallas kernels run via interpret mode.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS=cpu
export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"

# Parallelize across cores when pytest-xdist is available (CI installs it;
# falls back to serial where it isn't). The wall clock is dominated by
# per-test jit compiles, which parallelize embarrassingly well.
# -x still aborts the whole session on first failure under xdist;
# --max-worker-restart 0 keeps a crashed worker from respawning past it,
# and the cache provider is disabled so workers don't race on .pytest_cache.
XDIST_ARGS=()
if python -c "import xdist" >/dev/null 2>&1; then
  XDIST_ARGS=(-n auto --max-worker-restart 0 -p no:cacheprovider)
fi

exec python -m pytest -x -q "${XDIST_ARGS[@]}" "$@"
