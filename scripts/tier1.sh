#!/usr/bin/env bash
# Tier-1 verification: the fast, CPU-only slice of the suite.
#
#   bash scripts/tier1.sh             # pytest -x -q, slow tests deselected
#   bash scripts/tier1.sh -m ""       # override: run everything
#
# Forces the host-CPU backend with 8 virtual devices so the sharding /
# collective paths (shard_map, ppermute gossip) are exercised without
# accelerators; Pallas kernels run via interpret mode.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS=cpu
export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"

exec python -m pytest -x -q "$@"
