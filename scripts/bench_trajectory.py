#!/usr/bin/env python
"""Generate the committed per-PR bench trajectory file ``BENCH_<n>.json``.

One file per PR, committed at the repo root, holding the fused-step,
heterogeneity, damping, and serving records at the same smoke sizes the
bench-smoke CI job runs (workers=4, size=8192, model_parallel=2;
heterogeneity steps=60; damping steps=40; serving calls=12). The CI
job diffs the *schema* of its freshly produced records against the newest
committed file (``benchmarks.common.schema_of``), so a field rename/drop/
retype fails the push even though absolute CPU timings drift run to run.

    PYTHONPATH=src:. python scripts/bench_trajectory.py --pr 7
"""
import argparse
import json
import os
import sys

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

# fused_step's axis2d path needs workers x model_parallel devices; force
# them BEFORE jax initializes (same convention as scripts/tier1.sh —
# repro.launch.env appends to a pre-set XLA_FLAGS instead of skipping)
from repro.launch import env as _env  # noqa: E402

_env.setup(platform="cpu")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--pr", type=int, required=True,
                    help="PR number; writes BENCH_<pr>.json at the repo root")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--size", type=int, default=8192)
    ap.add_argument("--model-parallel", type=int, default=2)
    ap.add_argument("--het-steps", type=int, default=60)
    ap.add_argument("--damp-steps", type=int, default=40)
    ap.add_argument("--damp-lm-steps", type=int, default=12)
    ap.add_argument("--serve-calls", type=int, default=12)
    ns = ap.parse_args(argv)

    import jax
    from benchmarks import damping, fused_step, heterogeneity, serving

    record = {
        "pr": ns.pr,
        "jax_version": jax.__version__,
        "fused_step": fused_step.main(
            workers=ns.workers, size=ns.size,
            model_parallel=ns.model_parallel),
        "heterogeneity": heterogeneity.main(steps=ns.het_steps),
        "damping": damping.main(steps=ns.damp_steps,
                                lm_steps=ns.damp_lm_steps),
        "serving": serving.main(calls=ns.serve_calls),
    }
    out = os.path.abspath(os.path.join(_ROOT, f"BENCH_{ns.pr}.json"))
    with open(out, "w") as fh:
        json.dump(record, fh, indent=1)
        fh.write("\n")
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
