#!/usr/bin/env python
"""CI gate: the three-pass shard-safety analyzer over the shipped configs.

Sweeps reference/packed/axis/axis2d x D-Adam/CD-Adam x plain/schedule/
staleness/overlap, evaluates each compiled step against its derived
InvariantSpec,
lints the jaxprs, checks the topology zoo, and runs the known-bug corpus
(which must FAIL with the expected rule IDs). Exit code 0 iff everything
holds.

    PYTHONPATH=src python scripts/check_invariants.py [--backends ...]
        [--kinds ...] [--variants ...] [--no-corpus] [--verbose]
        [--summary FILE]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# the axis2d configs need K x M = 8 devices; force host devices BEFORE jax
# imports. repro.launch.env APPENDS to a pre-set XLA_FLAGS (a caller-
# forced count wins) instead of skipping the flag whenever XLA_FLAGS was
# set at all, which used to leave the sweep device-starved under e.g. a
# user-exported dump flag.
from repro.launch import env as _env  # noqa: E402

_env.setup(platform="cpu")

from repro.analysis import check as check_mod  # noqa: E402


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--backends", nargs="+", default=list(check_mod.BACKENDS),
                   choices=list(check_mod.BACKENDS))
    p.add_argument("--kinds", nargs="+", default=list(check_mod.KINDS),
                   choices=list(check_mod.KINDS))
    p.add_argument("--variants", nargs="+", default=list(check_mod.VARIANTS),
                   choices=list(check_mod.VARIANTS))
    p.add_argument("--no-corpus", action="store_true",
                   help="skip the known-bug corpus (it must normally FAIL "
                        "with the expected rule IDs)")
    p.add_argument("--verbose", action="store_true",
                   help="print full per-config invariant reports")
    p.add_argument("--summary", default="",
                   help="also append the log to this file (e.g. "
                        "$GITHUB_STEP_SUMMARY)")
    ns = p.parse_args(argv)

    lines = []

    def log(msg: str) -> None:
        print(msg)
        lines.append(msg)

    ok = check_mod.run(ns.backends, ns.kinds, ns.variants,
                       corpus=not ns.no_corpus, verbose=ns.verbose, log=log)
    if ns.summary:
        with open(ns.summary, "a") as fh:
            fh.write("```\n" + "\n".join(lines) + "\n```\n")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
