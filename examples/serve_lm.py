"""Serving example: batched bucket decode through the DecodeEngine with a
lock-free ParamStore hot-swap mid-stream.

    PYTHONPATH=src python examples/serve_lm.py

Mixed-length prompts are grouped into the engine's compiled (batch, seq)
buckets — right-padded to the bucket seq with exact-logit rewind, so the
padding never changes the output. A second publish() between requests
swaps the served params without recompiling or blocking the decode.
Set SERVE_NEW_TOKENS to shrink the run (tests use 4).
"""
import os
import time

import jax

from repro.configs import get_reduced
from repro.models import build_model
from repro.serve import DecodeEngine, ParamStore

N_NEW = int(os.environ.get("SERVE_NEW_TOKENS", "16"))

cfg = get_reduced("llama3.2-1b").model
api = build_model(cfg)
store = ParamStore()
store.publish(api.init(jax.random.PRNGKey(0)))

engine = DecodeEngine(cfg, store, buckets=((1, 16), (4, 16)),
                      max_new_tokens=max(N_NEW, 4))
key = jax.random.PRNGKey(1)
prompts = [jax.random.randint(jax.random.fold_in(key, i), (L,), 0,
                              cfg.vocab_size)
           for i, L in enumerate((16, 9, 16, 12, 16))]

t0 = time.perf_counter()
outs = engine.generate(prompts, N_NEW)
dt = time.perf_counter() - t0
tokens = sum(o.size for o in outs)
print(f"v{engine.last_version}: {len(prompts)} prompts "
      f"(lens {[int(p.size) for p in prompts]}) -> {tokens} tokens "
      f"in {dt:.2f}s ({tokens / dt:.0f} tok/s)")

# hot-swap: publish new params; the very next call serves them —
# same compiled buckets, no reader stall
store.publish(api.init(jax.random.PRNGKey(2)))
t0 = time.perf_counter()
outs = engine.generate(prompts, N_NEW)
dt = time.perf_counter() - t0
print(f"v{engine.last_version}: re-served after hot-swap in {dt:.2f}s "
      f"(compiles: {engine.compile_counts})")
assert engine.last_version == 2
