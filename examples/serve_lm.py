"""Serving example: batched greedy generation with prefill + KV-cache
decode, across three architecture families (dense / SSM / hybrid).

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax

from repro.configs import get_reduced
from repro.models import build_model
from repro.serve import greedy_generate

for arch in ("llama3.2-1b", "rwkv6-3b", "zamba2-7b"):
    cfg = get_reduced(arch).model
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    prompt = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16),
                                           0, cfg.vocab_size)}
    t0 = time.perf_counter()
    out = greedy_generate(cfg, params, prompt, n_new=16)
    dt = time.perf_counter() - t0
    print(f"{arch:14s} generated {out.shape} tokens in {dt:.2f}s "
          f"({out.size / dt:.0f} tok/s, batch=4)")
