"""Quickstart: decentralized Adam (the paper's Alg. 1) in ~40 lines.

Trains an 8-worker ring on a synthetic non-IID CTR task with DeepFM —
the paper's own motivating application (sparse categorical features where
adaptivity matters) — and prints loss / consensus / communication cost.

    PYTHONPATH=src python examples/quickstart.py
"""
import os

import jax

from repro.core import make_optimizer
from repro.data import ctr_batch_stacked, make_ctr_task
from repro.models.deepfm import deepfm_loss, init_deepfm
from repro.train import DecentralizedTrainer

K = 8  # workers in a ring, as in the paper's experiments
STEPS = int(os.environ.get("QUICKSTART_STEPS", "100"))  # CI smoke shrinks

task = make_ctr_task(seed=0, n_fields=8, features_per_field=32)

# D-Adam: adaptive learning rates per worker, gossip every p=4 steps
opt = make_optimizer("d-adam", K=K, eta=1e-3, period=4, topology="ring")
trainer = DecentralizedTrainer(lambda p, b: deepfm_loss(p, b), opt)

params = init_deepfm(jax.random.PRNGKey(0), task.n_features, task.n_fields,
                     hidden=(64, 64))
state = trainer.init(params)


def batches():
    key = jax.random.PRNGKey(1)
    t = 0
    while True:  # each worker draws from its own skewed distribution
        yield ctr_batch_stacked(task, jax.random.fold_in(key, t), K, 32)
        t += 1


state, log = trainer.fit(state, batches(), steps=STEPS, log_every=20)
for s, l, c, mb in zip(log.step, log.loss, log.consensus, log.comm_mb):
    print(f"step {s:4d}  loss {l:.4f}  consensus {c:.2e}  comm {mb:.1f} MB")
print("final averaged-model params ready:",
      sum(x.size for x in jax.tree_util.tree_leaves(
          trainer.averaged_params(state))), "weights")
