"""CD-Adam compressor study: quality vs wire bytes for every registered
delta-contraction operator, on the paper's CTR setting.

    PYTHONPATH=src python examples/compressed_comm.py
"""
import jax
import numpy as np

from repro.core import make_optimizer
from repro.data import ctr_batch_stacked, make_ctr_task
from repro.models.deepfm import deepfm_logits, deepfm_loss, init_deepfm
from repro.train import DecentralizedTrainer
from repro.train.metrics import auc

K, STEPS = 8, 150
task = make_ctr_task(seed=0, n_fields=8, features_per_field=32)


def run(kind, label, **kw):
    opt = make_optimizer(kind, K=K, eta=1e-3, period=4, **kw)
    trainer = DecentralizedTrainer(lambda p, b: deepfm_loss(p, b), opt)
    params = init_deepfm(jax.random.PRNGKey(0), task.n_features,
                         task.n_fields, hidden=(64, 64))
    state = trainer.init(params)

    def it():
        key = jax.random.PRNGKey(1)
        t = 0
        while True:
            yield ctr_batch_stacked(task, jax.random.fold_in(key, t), K, 32)
            t += 1

    state, log = trainer.fit(state, it(), STEPS, log_every=STEPS)
    avg = trainer.averaged_params(state)
    test = ctr_batch_stacked(task, jax.random.PRNGKey(99), K, 512)
    flat = jax.tree_util.tree_map(lambda x: x.reshape((-1,) + x.shape[2:]),
                                  test)
    a = auc(np.asarray(deepfm_logits(avg, flat["feat_ids"])),
            np.asarray(flat["label"]))
    print(f"{label:24s} loss={log.loss[-1]:.4f} AUC={a:.4f} "
          f"comm={log.comm_mb[-1]:8.2f} MB")


if __name__ == "__main__":
    run("d-adam", "full precision")
    run("cd-adam", "sign (paper)", compressor="sign", gamma=0.4)
    run("cd-adam", "topk 1/16", compressor="topk", gamma=0.4, fraction=1/16)
    run("cd-adam", "quantize 16 levels", compressor="quantize", gamma=0.4)
