"""Online train->serve, end to end: D-Adam on the streaming non-IID CTR
task with periodic lock-free publishes, scored live from the store.

    PYTHONPATH=src python examples/online_serve.py [--steps 60]

The trainer owns the packed-resident pallas state; every ``--publish-every``
steps the consensus mean is decoded straight from the packed buffer
(unpack-once, no full K-way unpack) and swapped into a ParamStore. The
serving side scores a held-out CTR batch against each published version —
AUC should drift upward as fresher models land.
"""
import argparse

import jax
import numpy as np

from repro.core import make_optimizer
from repro.data import ctr_batch_stacked, ctr_stream, make_ctr_task, \
    prefetch_to_device
from repro.models.deepfm import deepfm_logits, deepfm_loss, init_deepfm
from repro.serve import ParamStore
from repro.train import DecentralizedTrainer, train_online
from repro.train.metrics import auc

K = 8


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--publish-every", type=int, default=20)
    args = ap.parse_args()

    task = make_ctr_task(seed=0, n_fields=8, features_per_field=32)
    opt = make_optimizer("d-adam", K=K, eta=1e-3, period=4,
                         backend="pallas")
    trainer = DecentralizedTrainer(lambda p, b: deepfm_loss(p, b), opt)
    params = init_deepfm(jax.random.PRNGKey(0), task.n_features,
                         task.n_fields, hidden=(64, 64))
    state = trainer.init(params)

    test = ctr_batch_stacked(task, jax.random.PRNGKey(99), K, 512)
    flat = jax.tree_util.tree_map(lambda x: x.reshape((-1,) + x.shape[2:]),
                                  test)

    store = ParamStore()
    stream = prefetch_to_device(ctr_stream(task, K, 32, seed=1))
    result = train_online(trainer, state, stream, args.steps, store=store,
                          publish_every=args.publish_every, mode="mean",
                          log_every=args.steps)

    version, served = store.snapshot()
    a = auc(np.asarray(deepfm_logits(served, flat["feat_ids"])),
            np.asarray(flat["label"]))
    print(f"published versions: {result.versions} "
          f"(at steps {[s for s, _ in result.published]})")
    print(f"serving v{version}: loss={result.log.loss[-1]:.4f} AUC={a:.4f}")


if __name__ == "__main__":
    main()
