"""The paper's experiment, end to end: DeepFM / Wide&Deep CTR training with
D-Adam vs CD-Adam vs D-Adam-vanilla vs D-PSGD, reporting train loss, test
AUC and communication MB — the quantities in Figs. 1-6.

    PYTHONPATH=src python examples/deepfm_ctr.py [--steps 200]
"""
import argparse

import jax
import numpy as np

from repro.core import make_optimizer
from repro.data import ctr_batch_stacked, make_ctr_task
from repro.models.deepfm import (deepfm_logits, deepfm_loss, init_deepfm,
                                 init_widedeep, widedeep_logits,
                                 widedeep_loss)
from repro.train import DecentralizedTrainer
from repro.train.metrics import auc

K = 8


def run(name, model, kind, steps, **kw):
    task = make_ctr_task(seed=0, n_fields=8, features_per_field=32)
    if model == "deepfm":
        init_fn, loss_fn, logits_fn = (init_deepfm, deepfm_loss,
                                       deepfm_logits)
    else:
        init_fn, loss_fn, logits_fn = (init_widedeep, widedeep_loss,
                                       widedeep_logits)
    opt = make_optimizer(kind, K=K, eta=1e-3, topology="ring", **kw)
    trainer = DecentralizedTrainer(lambda p, b: loss_fn(p, b), opt)
    params = init_fn(jax.random.PRNGKey(0), task.n_features, task.n_fields,
                     hidden=(64, 64))
    state = trainer.init(params)

    def it():
        key = jax.random.PRNGKey(1)
        t = 0
        while True:
            yield ctr_batch_stacked(task, jax.random.fold_in(key, t), K, 32)
            t += 1

    state, log = trainer.fit(state, it(), steps, log_every=steps)
    avg = trainer.averaged_params(state)
    test = ctr_batch_stacked(task, jax.random.PRNGKey(99), K, 512)
    flat = jax.tree_util.tree_map(lambda x: x.reshape((-1,) + x.shape[2:]),
                                  test)
    a = auc(np.asarray(logits_fn(avg, flat["feat_ids"])),
            np.asarray(flat["label"]))
    print(f"{name:28s} loss={log.loss[-1]:.4f} AUC={a:.4f} "
          f"comm={log.comm_mb[-1]:8.1f} MB")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--model", default="deepfm",
                    choices=["deepfm", "widedeep"])
    args = ap.parse_args()
    print(f"== {args.model} on synthetic Criteo-style CTR, {K} workers ==")
    run("d-adam-vanilla (p=1)", args.model, "d-adam", args.steps, period=1)
    for p in (4, 16):
        run(f"d-adam p={p}", args.model, "d-adam", args.steps, period=p)
    run("cd-adam p=16 + sign", args.model, "cd-adam", args.steps,
        period=16, gamma=0.4, compressor="sign")
    run("d-psgd (non-adaptive)", args.model, "d-psgd", args.steps)


if __name__ == "__main__":
    main()
