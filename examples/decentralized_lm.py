"""End-to-end driver: decentralized training of a transformer LM.

Default preset trains a ~7M-param llama-style model for a few hundred
steps across 4 simulated workers on CPU; ``--preset 100m`` selects the
~100M configuration (sized for real hardware, runs on CPU too — slowly).

    PYTHONPATH=src python examples/decentralized_lm.py --steps 200
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import make_optimizer
from repro.data import lm_batch
from repro.models import build_model
from repro.train import DecentralizedTrainer

PRESETS = {
    "7m": ModelConfig(arch_id="lm7m", family="dense", n_layers=4,
                      d_model=256, n_heads=8, n_kv_heads=4, d_ff=688,
                      vocab_size=2048, tie_embeddings=True),
    "100m": ModelConfig(arch_id="lm100m", family="dense", n_layers=12,
                        d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                        vocab_size=32768, tie_embeddings=True),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="7m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--optimizer", default="d-adam")
    ap.add_argument("--period", type=int, default=4)
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    api = build_model(cfg)
    K = args.workers
    opt = make_optimizer(args.optimizer, K=K, eta=1e-3, period=args.period)
    trainer = DecentralizedTrainer(lambda p, b: api.loss(p, b), opt)
    params = api.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"model {cfg.arch_id}: {n / 1e6:.1f}M params, K={K} workers, "
          f"{args.optimizer} p={args.period}")
    state = trainer.init(params)

    def it():
        key = jax.random.PRNGKey(3)
        t = 0
        while True:
            yield {"tokens": jnp.stack([
                lm_batch(jax.random.fold_in(key, t), args.batch, args.seq,
                         cfg.vocab_size, k, K, skew=0.5)
                for k in range(K)])}
            t += 1

    t0 = time.perf_counter()
    done = 0
    comm_total = 0.0
    batches = it()
    while done < args.steps:
        chunk = min(50, args.steps - done)
        state, log = trainer.fit(state, batches, chunk, log_every=chunk)
        done += chunk
        comm_total += log.comm_mb[-1]
        print(f"step {done:4d}  loss {log.loss[-1]:.4f}  "
              f"consensus {log.consensus[-1]:.2e}  "
              f"comm {comm_total:.1f} MB  "
              f"({(time.perf_counter() - t0) / done * 1e3:.0f} ms/step)")


if __name__ == "__main__":
    main()
