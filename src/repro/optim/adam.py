"""Reference (centralized) Adam — the oracle the K=1 identity tests pin
D-Adam against, written independently of repro.core to catch shared bugs.
Matches the paper's update exactly (no bias correction, sqrt(v)+tau guard).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class RefAdamState(NamedTuple):
    m: PyTree
    v: PyTree


def init(params: PyTree) -> RefAdamState:
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return RefAdamState(z, jax.tree_util.tree_map(jnp.zeros_like, params))


def step(params: PyTree, grads: PyTree, state: RefAdamState, *,
         eta: float, beta1: float = 0.9, beta2: float = 0.999,
         tau: float = 1e-6) -> Tuple[PyTree, RefAdamState]:
    new_m = jax.tree_util.tree_map(
        lambda m, g: beta1 * m + (1 - beta1) * g, state.m, grads)
    new_v = jax.tree_util.tree_map(
        lambda v, g: beta2 * v + (1 - beta2) * g * g, state.v, grads)
    new_p = jax.tree_util.tree_map(
        lambda x, m, v: x - eta * m / (jnp.sqrt(v) + tau),
        params, new_m, new_v)
    return new_p, RefAdamState(new_m, new_v)
