from repro.optim import adam, schedules

__all__ = ["adam", "schedules"]
