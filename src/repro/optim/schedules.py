"""LR schedules (the paper divides eta by 10 at fixed epochs for CIFAR)."""
from __future__ import annotations

from typing import Sequence


def step_decay(base: float, boundaries: Sequence[int], factor: float = 0.1):
    def schedule(step: int) -> float:
        lr = base
        for b in boundaries:
            if step >= b:
                lr *= factor
        return lr
    return schedule


def constant(base: float):
    return lambda step: base
