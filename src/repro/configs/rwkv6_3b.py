"""rwkv6-3b [ssm] — Finch, data-dependent decay [arXiv:2404.05892].

32L, d_model=2560 (attention-free), channel-mix d_ff=8960, vocab=65536,
head_size=64 (40 WKV heads). O(1) state => native long_500k decode.
"""
import dataclasses

from repro.configs.base import ArchConfig, ModelConfig, ParallelConfig

FULL = ArchConfig(
    model=ModelConfig(
        arch_id="rwkv6-3b", family="ssm",
        n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
        d_ff=8960, vocab_size=65536,
        rwkv_head_size=64, rwkv_decay_rank=64,
    ),
    parallel=ParallelConfig(worker_mode="stacked"),
    source="arXiv:2404.05892 (RWKV-6 Finch 3B)",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        FULL,
        model=dataclasses.replace(
            FULL.model, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
            d_ff=448, vocab_size=512, rwkv_head_size=32, rwkv_decay_rank=16),
    )
