"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242].

81 Mamba2 layers (d_model=3584, ssm_state=64, expand 2 => d_inner=7168,
112 SSM heads) with one SHARED attention(32H, kv=32)+MLP(d_ff=14336) block
re-applied every 14 layers (6 sites; Zamba2's weight sharing — LoRA deltas
omitted, see DESIGN.md). O(1) SSM state => native long_500k decode; the
shared-attn KV sites use a 4096 rotating window for long_500k.
"""
import dataclasses

from repro.configs.base import ArchConfig, ModelConfig, ParallelConfig

FULL = ArchConfig(
    model=ModelConfig(
        arch_id="zamba2-7b", family="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
        d_ff=14336, vocab_size=32000,
        ssm_state=64, ssm_expand=2, ssm_conv=4,
        shared_attn_period=14,
        long_context_window=4096,
    ),
    parallel=ParallelConfig(worker_mode="stacked"),
    source="arXiv:2411.15242 (Zamba2-7B)",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        FULL,
        model=dataclasses.replace(
            FULL.model, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
            d_ff=256, vocab_size=512, ssm_state=16, ssm_heads=4,
            shared_attn_period=1, long_context_window=32),
    )
