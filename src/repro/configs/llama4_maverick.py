"""llama4-maverick-400b-a17b [moe] — 128 experts top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E family].

48L, d_model=5120, 40 heads (GQA kv=8), expert d_ff=8192, vocab=202048,
MoE 128e top-1. ~770B total params: per-worker replicas are physically
impossible inside 512 v5e chips, so worker mode is 'global' (K=1 FSDP
Adam — the paper's centralized baseline) with bf16 moments; decentralized
D-Adam for this arch needs >= 2 full pods per worker (DESIGN.md §6).
long_500k uses an 8192-token chunked/rotating window (Llama-4 style
chunked attention).
"""
import dataclasses

import jax.numpy as jnp

from repro.configs.base import ArchConfig, ModelConfig, ParallelConfig

FULL = ArchConfig(
    model=ModelConfig(
        arch_id="llama4-maverick-400b-a17b", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=8192, vocab_size=202048,
        n_experts=128, experts_per_token=1,
        rope_theta=500000.0,
        moe_group_size=512,
        long_context_window=8192,
    ),
    parallel=ParallelConfig(worker_mode="global", moment_dtype=jnp.bfloat16,
                            remat="full"),
    source="hf:meta-llama/Llama-4-Scout-17B-16E (family; maverick dims)",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        FULL,
        model=dataclasses.replace(
            FULL.model, n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
            d_ff=256, vocab_size=512, n_experts=4, experts_per_token=1,
            moe_group_size=64, long_context_window=64),
        parallel=dataclasses.replace(FULL.parallel, worker_mode="stacked",
                                     moment_dtype=None, remat="dots"),
    )
