"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2
[hf:microsoft/Phi-3.5-MoE-instruct].

32L, d_model=4096, 32 heads (GQA kv=8), expert d_ff=6400, vocab=32064,
MoE 16e top-2. Worker mode 'pods': 42B params + moments exceed a 16-chip
group, and expert-parallel sharding wants the whole in-pod 'model' axis.
"""
import dataclasses

import jax.numpy as jnp

from repro.configs.base import ArchConfig, ModelConfig, ParallelConfig

FULL = ArchConfig(
    model=ModelConfig(
        arch_id="phi3.5-moe-42b-a6.6b", family="moe",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=6400, vocab_size=32064,
        n_experts=16, experts_per_token=2,
        long_context_window=16384,
    ),
    parallel=ParallelConfig(worker_mode="pods", moment_dtype=jnp.bfloat16),
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        FULL,
        model=dataclasses.replace(
            FULL.model, n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
            d_ff=320, vocab_size=512, n_experts=4, experts_per_token=2,
            moe_group_size=64, long_context_window=64),
        parallel=dataclasses.replace(FULL.parallel, worker_mode="stacked",
                                     moment_dtype=None),
    )
