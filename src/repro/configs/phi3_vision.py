"""phi-3-vision-4.2b [vlm] — phi3-mini + CLIP (stubbed)
[hf:microsoft/Phi-3-vision-128k-instruct].

32L, d_model=3072, 32 heads (kv=32), d_ff=8192, vocab=32064; CLIP ViT-L/14
image encoder STUBBED (input_specs provides (B, 576, 1024) patch features);
the 1024->3072 projector and the language backbone are real.
"""
import dataclasses

from repro.configs.base import ArchConfig, ModelConfig, ParallelConfig

FULL = ArchConfig(
    model=ModelConfig(
        arch_id="phi-3-vision-4.2b", family="vlm",
        n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=32064,
        n_patches=576,
        long_context_window=16384,
    ),
    parallel=ParallelConfig(worker_mode="stacked"),
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        FULL,
        model=dataclasses.replace(
            FULL.model, n_layers=2, d_model=256, n_heads=8, n_kv_heads=8,
            d_ff=512, vocab_size=512, n_patches=8, long_context_window=64),
    )
