"""yi-6b [dense] — llama-arch GQA [arXiv:2403.04652].

32L, d_model=4096, 32 heads (GQA kv=4), d_ff=11008, vocab=64000,
rope theta 5e6 (Yi's long-base RoPE).
"""
import dataclasses

from repro.configs.base import ArchConfig, ModelConfig, ParallelConfig

FULL = ArchConfig(
    model=ModelConfig(
        arch_id="yi-6b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4,
        d_ff=11008, vocab_size=64000,
        rope_theta=5000000.0,
        long_context_window=16384,
    ),
    parallel=ParallelConfig(worker_mode="stacked"),
    source="arXiv:2403.04652 (Yi-6B)",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        FULL,
        model=dataclasses.replace(
            FULL.model, n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
            d_ff=512, vocab_size=512, long_context_window=64),
    )
