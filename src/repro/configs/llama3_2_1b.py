"""llama3.2-1b [dense] — small llama3 [hf:meta-llama/Llama-3.2-1B].

16L, d_model=2048, 32 heads (GQA kv=8), d_ff=8192, vocab=128256,
head_dim=64, rope theta 500k, tied embeddings.
"""
import dataclasses


from repro.configs.base import ArchConfig, ModelConfig, ParallelConfig

FULL = ArchConfig(
    model=ModelConfig(
        arch_id="llama3.2-1b", family="dense",
        n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8,
        d_ff=8192, vocab_size=128256, head_dim=64,
        rope_theta=500000.0, tie_embeddings=True,
        long_context_window=16384,
    ),
    parallel=ParallelConfig(worker_mode="stacked"),
    source="hf:meta-llama/Llama-3.2-1B",
)


def reduced() -> ArchConfig:
    """<=2 layers, d_model<=512 CPU smoke variant (same family/features)."""
    return dataclasses.replace(
        FULL,
        model=dataclasses.replace(
            FULL.model, n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
            head_dim=32, d_ff=512, vocab_size=512, long_context_window=64),
    )
