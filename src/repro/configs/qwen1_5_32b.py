"""qwen1.5-32b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B family scaled].

64L, d_model=5120, 40 heads (kv=40, MHA), d_ff=27392, vocab=152064,
QKV bias (the Qwen1.5 signature), rope theta 1e6.
Per-worker state ~32B params x (4+4+4)B exceeds a 16-chip group's HBM, so
the worker mode is 'pods' (gossip between pods, FSDP within).
"""
import dataclasses

import jax.numpy as jnp

from repro.configs.base import ArchConfig, ModelConfig, ParallelConfig

FULL = ArchConfig(
    model=ModelConfig(
        arch_id="qwen1.5-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40,
        d_ff=27392, vocab_size=152064, qkv_bias=True,
        rope_theta=1000000.0,
        long_context_window=16384,
    ),
    parallel=ParallelConfig(worker_mode="pods", moment_dtype=jnp.bfloat16),
    source="hf:Qwen/Qwen1.5-0.5B (arch family; 32B dims per brief)",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        FULL,
        model=dataclasses.replace(
            FULL.model, n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
            d_ff=640, vocab_size=512, long_context_window=64),
        parallel=dataclasses.replace(FULL.parallel, worker_mode="stacked",
                                     moment_dtype=None),
    )
