"""Architecture config registry: ``get_arch(id)`` / ``get_reduced(id)``.

Every assigned architecture is a selectable config (``--arch <id>``); each
module cites its source in the docstring and carries a ``reduced()``
CPU-smoke variant (<=2 layers, d_model<=512, <=4 experts).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (ArchConfig, InputShape, INPUT_SHAPES,
                                ModelConfig, ParallelConfig)

_MODULES: Dict[str, str] = {
    "llama3.2-1b": "repro.configs.llama3_2_1b",
    "qwen1.5-32b": "repro.configs.qwen1_5_32b",
    "starcoder2-15b": "repro.configs.starcoder2_15b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi3_5_moe",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "yi-6b": "repro.configs.yi_6b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick",
    "phi-3-vision-4.2b": "repro.configs.phi3_vision",
}

# (arch, shape) combos that are skipped by design — see DESIGN.md §6.
SKIPS = {
    ("whisper-large-v3", "long_500k"):
        "enc-dec decoder positionally capped; 524k-token decode is "
        "architecturally meaningless and whisper has no sub-quadratic "
        "decoder variant",
}


def list_archs() -> List[str]:
    return sorted(_MODULES)


def get_arch(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {list_archs()}")
    return importlib.import_module(_MODULES[arch_id]).FULL


def get_reduced(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {list_archs()}")
    return importlib.import_module(_MODULES[arch_id]).reduced()


__all__ = ["ArchConfig", "ModelConfig", "ParallelConfig", "InputShape",
           "INPUT_SHAPES", "SKIPS", "list_archs", "get_arch", "get_reduced"]
