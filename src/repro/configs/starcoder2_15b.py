"""starcoder2-15b [dense] — GQA + RoPE + sliding window [arXiv:2402.19173].

40L, d_model=6144, 48 heads (GQA kv=4), d_ff=24576, vocab=49152.
StarCoder2 trains with a 4096 sliding window (its long-context mechanism),
LayerNorm + GELU MLP. The window makes long_500k natively sub-quadratic.
"""
import dataclasses

import jax.numpy as jnp

from repro.configs.base import ArchConfig, ModelConfig, ParallelConfig

FULL = ArchConfig(
    model=ModelConfig(
        arch_id="starcoder2-15b", family="dense",
        n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4,
        d_ff=24576, vocab_size=49152,
        rope_theta=100000.0, mlp_kind="gelu", norm_kind="layer",
        sliding_window=4096,
    ),
    parallel=ParallelConfig(worker_mode="stacked",
                            moment_dtype=jnp.bfloat16),
    source="arXiv:2402.19173 (StarCoder2)",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        FULL,
        model=dataclasses.replace(
            FULL.model, n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
            d_ff=512, vocab_size=512, sliding_window=16),
        parallel=dataclasses.replace(FULL.parallel, moment_dtype=None),
    )
