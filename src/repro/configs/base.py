"""Config dataclasses: model architecture + parallelism/runtime.

One ``ArchConfig`` per assigned architecture lives in
``src/repro/configs/<arch>.py`` with the exact published dimensions, plus a
``reduced()`` variant (<= 2 layers, d_model <= 512, <= 4 experts) used by the
CPU smoke tests. The FULL configs are only ever lowered via
ShapeDtypeStruct in the dry-run — never allocated.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 => d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    mlp_kind: str = "swiglu"       # swiglu | gelu
    norm_kind: str = "rms"         # rms | layer
    # moe
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 1024
    router_aux_weight: float = 0.01
    # rwkv6
    rwkv_head_size: int = 64
    rwkv_decay_rank: int = 64
    # mamba2 / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_heads: int = 0             # 0 => d_inner // 64
    shared_attn_period: int = 0    # hybrid: shared attn block every N layers
    # audio (whisper): encoder consuming stubbed frame embeddings
    n_encoder_layers: int = 0
    n_audio_ctx: int = 1500
    # vlm: stubbed projected patch embeddings prepended to text
    n_patches: int = 0
    # serving
    sliding_window: int = 0        # 0 = full attention; >0 enables the
                                   # sub-quadratic rotating-cache decode path
    long_context_window: int = 0   # window substituted for long_500k decode
                                   # (dense archs); 0 => native long context
                                   # (SSM/hybrid) or skip (see DESIGN.md)
    # numerics
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def resolved_ssm_heads(self) -> int:
        return self.ssm_heads or max(1, self.d_inner // 64)

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, L, V = self.d_model, self.n_layers, self.vocab_size
        hd = self.resolved_head_dim
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * hd * d
        if self.family in ("dense", "vlm"):
            ffn = 3 * d * self.d_ff if self.mlp_kind == "swiglu" \
                else 2 * d * self.d_ff
            per_layer = attn + ffn
            body = L * per_layer
        elif self.family == "moe":
            ffn = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
            body = L * (attn + ffn)
        elif self.family == "ssm":  # rwkv6
            H = d // self.rwkv_head_size
            tm = 4 * d * d + d * self.rwkv_decay_rank * 2 + 6 * d \
                + H * self.rwkv_head_size
            cm = 2 * d * int(3.5 * d)
            body = L * (tm + cm)
        elif self.family == "hybrid":
            di, N = self.d_inner, self.ssm_state
            Hs = self.resolved_ssm_heads
            in_proj = d * (2 * di + 2 * N + Hs)
            per_mamba = in_proj + di * d + (di + 2 * N) * self.ssm_conv \
                + 2 * Hs + di
            n_shared = (L // self.shared_attn_period
                        if self.shared_attn_period else 0)
            shared = attn + 3 * d * self.d_ff
            body = L * per_mamba + shared  # shared block params counted once
        elif self.family == "audio":
            ffn = 2 * d * self.d_ff
            enc = self.n_encoder_layers * (attn + ffn)
            dec = L * (2 * attn + ffn)   # self + cross attention
            body = enc + dec
        else:
            raise ValueError(self.family)
        emb = V * d * (1 if self.tie_embeddings else 2)
        return int(body + emb)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        total = self.param_count()
        all_exp = L * self.n_experts * 3 * d * self.d_ff
        act_exp = L * self.experts_per_token * 3 * d * self.d_ff
        return int(total - all_exp + act_exp)


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    worker_mode: str = "stacked"   # stacked | pods | global
    topology: str = "ring"
    optimizer: str = "d-adam"      # d-adam | cd-adam | d-psgd
    period: int = 4                # p
    gamma: float = 0.4
    compressor: str = "sign"
    eta: float = 1e-3
    tau: float = 1e-6
    weight_decay: float = 0.0
    moment_dtype: Optional[Any] = None   # e.g. jnp.bfloat16 for big models
    remat: str = "dots"            # none | dots | full
    mixing: str = "roll"           # dense (paper-faithful) | roll (optimized)
    microbatch: int = 1            # grad-accumulation splits per local step
                                   # (activation memory / microbatch)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    model: ModelConfig
    parallel: ParallelConfig
    source: str = ""               # citation for the architecture numbers

    @property
    def arch_id(self) -> str:
        return self.model.arch_id


# ------------------------------ input shapes --------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
