"""whisper-large-v3 [audio] — enc-dec, conv frontend stubbed
[arXiv:2212.04356].

32 decoder layers (+32 encoder layers over stubbed frame embeddings),
d_model=1280, 20 heads (kv=20), d_ff=5120, vocab=51866, LayerNorm + GELU.
long_500k is SKIPPED for this arch (decoder positionally capped; see
DESIGN.md §skips). Decoder learned positions extended to 4608 so the
assigned train_4k shape fits (real cap 448 — documented deviation).
"""
import dataclasses

from repro.configs.base import ArchConfig, ModelConfig, ParallelConfig

FULL = ArchConfig(
    model=ModelConfig(
        arch_id="whisper-large-v3", family="audio",
        n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
        d_ff=5120, vocab_size=51866,
        n_encoder_layers=32, n_audio_ctx=1500,
        mlp_kind="gelu", norm_kind="layer",
    ),
    parallel=ParallelConfig(worker_mode="stacked"),
    source="arXiv:2212.04356 (Whisper large-v3)",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        FULL,
        model=dataclasses.replace(
            FULL.model, n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
            d_ff=256, vocab_size=512, n_encoder_layers=2, n_audio_ctx=16),
    )
