"""Lock-free param publishing: packed-state → serving replicas.

The train→serve bridge. A :class:`ParamStore` holds the live serving
params behind a double-buffered slot pair plus a monotonically increasing
version counter; :func:`publish_params` materializes a single per-worker
param pytree straight out of a packed-resident optimizer state
(``kernels.pack.unpack_worker`` / ``unpack_mean`` — 1/K of the buffer
read, no full K-way unpack), and :func:`publish_from_state` composes the
two into the one-call hot-swap the online training driver
(``train/online.py``) uses.

Swap semantics (the stall-free claim ``benchmarks/serving.py`` measures):

* **Readers never block and never see a torn tree.** ``snapshot()`` is a
  single attribute read of an immutable ``(version, params)`` pair; the
  writer replaces the whole pair in one reference assignment, so a reader
  gets either the old complete snapshot or the new complete snapshot.
* **The writer never blocks in-flight decode.** ``publish`` stages the
  new tree into the *inactive* slot of a two-slot ring — the previous
  version's buffers stay resident until the NEXT publish retires them,
  so a decode that grabbed version v keeps valid arrays while v+1 lands.
* **Versions are monotone.** Every successful ``publish`` returns
  ``version + 1``; readers can detect a swap by comparing versions
  across snapshots.

Placement reuses the checkpoint layer's ``place_like`` machinery
(``_placed_like``): pass ``like=`` a resident param tree (or any leaf
pytree with the target sharding) and every published leaf is
``device_put`` onto its counterpart's sharding before the swap — the
swap itself then never triggers a transfer on the reader side.
"""
from __future__ import annotations

import threading
from typing import Any, Optional, Tuple

import jax

from repro.checkpoint.io import _placed_like
from repro.kernels import pack as packing

PyTree = Any


class ParamStore:
    """Double-buffered, versioned, lock-free param store.

    Two resident slots + a monotonically increasing version counter.
    ``publish(params)`` writes the inactive slot and swaps an immutable
    ``(version, params)`` pair in one reference assignment; ``snapshot()``
    reads that pair in one attribute load. Readers always decode against
    a complete snapshot and a swap never blocks an in-flight decode.

    The writer-side lock only serializes concurrent *publishers* (version
    assignment + slot rotation); readers never take it.

    Example:
      >>> import jax.numpy as jnp
      >>> store = ParamStore()
      >>> store.publish({"w": jnp.zeros((2,))})
      1
      >>> version, params = store.snapshot()
      >>> version
      1
    """

    def __init__(self):
        self._slots: list = [None, None]
        self._write_idx = 0
        self._current: Optional[Tuple[int, PyTree]] = None
        self._version = 0
        self._write_lock = threading.Lock()

    @property
    def version(self) -> int:
        """Version of the current snapshot (0 before the first publish)."""
        cur = self._current
        return 0 if cur is None else cur[0]

    def publish(self, params: PyTree, *, like: Optional[PyTree] = None
                ) -> int:
        """Swap ``params`` in as the new current snapshot; returns its
        version. With ``like=`` every leaf is first placed onto its
        counterpart's sharding (``checkpoint.place_like`` semantics)."""
        if like is not None:
            params = jax.tree_util.tree_map(_placed_like, params, like)
        with self._write_lock:
            slot = self._write_idx
            self._slots[slot] = params
            self._version += 1
            # the swap: one reference assignment of an immutable pair —
            # concurrent snapshot() sees the old or the new pair, whole
            self._current = (self._version, params)
            self._write_idx = 1 - slot
            return self._version

    def snapshot(self) -> Tuple[int, PyTree]:
        """The current ``(version, params)`` pair — one atomic read."""
        cur = self._current
        if cur is None:
            raise ValueError(
                "ParamStore is empty: publish() params before serving")
        return cur


def publish_params(state: Any, *, mode: str = "mean", worker: int = 0,
                   like: Optional[PyTree] = None) -> PyTree:
    """One per-worker param pytree out of an optimizer state (or a
    stacked param tree), without a full K-way unpack for packed states.

    Args:
      state: a packed-resident state (``PackedDAdamState`` /
        ``PackedCDAdamState`` — decoded straight from its ``(K, rows,
        128)`` buffer), a reference NamedTuple state (``.params``), or a
        plain stacked param pytree (leading K dim on every leaf).
      mode: ``"mean"`` publishes the consensus mean; ``"worker"``
        publishes worker ``worker``'s replica.
      worker: which replica ``mode="worker"`` reads.
      like: optional placement template — each published leaf is
        ``device_put`` with its counterpart's sharding.

    Returns:
      The per-worker param pytree (no leading K dim).
    """
    if mode not in ("mean", "worker"):
        raise ValueError(f"mode must be 'mean' or 'worker', got {mode!r}")
    buf = getattr(state, "buf", None)
    spec = getattr(state, "spec", None)
    if buf is not None and isinstance(spec, packing.PackSpec):
        # packed-resident: decode ONE row block, never K trees
        if mode == "worker":
            params = packing.unpack_worker(buf, spec, worker)
        else:
            params = packing.unpack_mean(buf, spec)
    else:
        stacked = getattr(state, "params", state)
        if mode == "worker":
            params = jax.tree_util.tree_map(lambda x: x[worker], stacked)
        else:
            from repro.core.dadam import mean_params
            params = mean_params(stacked)
    if like is not None:
        params = jax.tree_util.tree_map(_placed_like, params, like)
    return params


def publish_from_state(store: ParamStore, state: Any, *,
                       mode: str = "mean", worker: int = 0,
                       like: Optional[PyTree] = None) -> int:
    """``publish_params`` → ``store.publish`` in one call; returns the
    new version. The hook ``train/online.py`` installs on the trainer."""
    return store.publish(
        publish_params(state, mode=mode, worker=worker, like=like))


def publish_hbm_bytes(state: Any, *, mode: str = "mean") -> dict:
    """HBM traffic accounting for one publish from a packed state.

    Returns read/write byte counts of the unpack-once path next to what
    the full K-way ``unpack`` + slice would have moved — the numbers
    ``benchmarks/serving.py`` records to back the no-full-unpack claim.
    """
    buf, spec = state.buf, state.spec
    item = buf.dtype.itemsize
    row_bytes = spec.rows * packing.LANE * item
    out_bytes = sum(sz * jax.numpy.dtype(dt).itemsize
                    for sz, dt in zip(spec.sizes, spec.dtypes))
    read = row_bytes if mode == "worker" else spec.k * row_bytes
    return {
        "mode": mode,
        "read_bytes": int(read),
        "write_bytes": int(out_bytes),
        # the path this replaces: decode all K per-worker trees, keep one
        "full_unpack_read_bytes": int(spec.k * row_bytes),
        "full_unpack_write_bytes": int(spec.k * out_bytes),
    }
