from repro.serve.engine import (cache_spec, effective_config,
                                greedy_generate, make_prefill_step,
                                make_serve_step)

__all__ = ["cache_spec", "effective_config", "make_serve_step",
           "make_prefill_step", "greedy_generate"]
