from repro.serve.engine import (DecodeEngine, cache_spec, cast_cache,
                                effective_config, greedy_generate,
                                make_prefill_step, make_serve_step,
                                select_bucket)
from repro.serve.publish import (ParamStore, publish_from_state,
                                 publish_hbm_bytes, publish_params)

__all__ = ["cache_spec", "effective_config", "make_serve_step",
           "make_prefill_step", "greedy_generate", "DecodeEngine",
           "cast_cache", "select_bucket", "ParamStore", "publish_params",
           "publish_from_state", "publish_hbm_bytes"]
