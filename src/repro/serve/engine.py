"""Serving engine: prefill / decode steps and cache specs per family.

``cache_spec(cfg, batch, seq_len)`` returns the ShapeDtypeStruct pytree of
the KV/SSM cache for the dry-run (no allocation); ``make_serve_step``
returns the jit-able one-token decode function the decode shapes lower.

Long-context rule (DESIGN.md §6): for ``long_500k`` dense archs substitute
``cfg.long_context_window`` as a rotating sliding window — the cache is
window-sized and the step cost O(window) (sub-quadratic); SSM/hybrid archs
decode against their O(1) recurrent state natively.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import attention, build_model, hybrid, rwkv6, whisper

PyTree = Any


def effective_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Apply the long-context window substitution for long_500k."""
    if (shape.name == "long_500k" and cfg.long_context_window
            and cfg.family in ("dense", "moe", "vlm")):
        return dataclasses.replace(cfg,
                                   sliding_window=cfg.long_context_window)
    if (shape.name == "long_500k" and cfg.family == "hybrid"
            and cfg.long_context_window):
        return dataclasses.replace(cfg,
                                   sliding_window=cfg.long_context_window)
    return cfg


def kv_cache_len(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.sliding_window:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def cache_spec(cfg: ModelConfig, batch: int, seq_len: int,
               cache_dtype=jnp.bfloat16) -> PyTree:
    """ShapeDtypeStruct stand-in of the decode-input cache."""
    L = cfg.n_layers
    hd = cfg.resolved_head_dim
    idx = jax.ShapeDtypeStruct((), jnp.int32)
    if cfg.family in ("dense", "moe", "vlm"):
        S = kv_cache_len(cfg, seq_len)
        kv = jax.ShapeDtypeStruct((L, batch, S, cfg.n_kv_heads, hd),
                                  cache_dtype)
        return attention.KVCache(kv, kv, idx)
    if cfg.family == "ssm":
        d = cfg.d_model
        H = d // cfg.rwkv_head_size
        hs = cfg.rwkv_head_size
        return rwkv6.RWKVCache(
            jax.ShapeDtypeStruct((L, batch, d), cfg.compute_dtype),
            jax.ShapeDtypeStruct((L, batch, d), cfg.compute_dtype),
            jax.ShapeDtypeStruct((L, batch, H, hs, hs), jnp.float32), idx)
    if cfg.family == "hybrid":
        di, N = cfg.d_inner, cfg.ssm_state
        H = cfg.resolved_ssm_heads
        P = di // H
        A = hybrid.n_attn_sites(cfg)
        S = kv_cache_len(cfg, seq_len)
        return hybrid.HybridCache(
            jax.ShapeDtypeStruct((L, batch, cfg.ssm_conv - 1, di + 2 * N),
                                 cfg.compute_dtype),
            jax.ShapeDtypeStruct((L, batch, H, P, N), jnp.float32),
            jax.ShapeDtypeStruct((A, batch, S, cfg.n_kv_heads, hd),
                                 cache_dtype),
            jax.ShapeDtypeStruct((A, batch, S, cfg.n_kv_heads, hd),
                                 cache_dtype), idx)
    if cfg.family == "audio":
        S = seq_len
        kv = jax.ShapeDtypeStruct((L, batch, S, cfg.n_kv_heads, hd),
                                  cache_dtype)
        xkv = jax.ShapeDtypeStruct((L, batch, cfg.n_audio_ctx,
                                    cfg.n_kv_heads, hd), cache_dtype)
        return whisper.WhisperCache(kv, kv, xkv, xkv, idx)
    raise KeyError(cfg.family)


def make_serve_step(cfg: ModelConfig) -> Callable:
    """(params, cache, token) -> (logits, cache) — the decode-shape target."""
    api = build_model(cfg)

    def serve_step(params, cache, token):
        return api.decode_step(params, cache, token)

    return serve_step


def make_prefill_step(cfg: ModelConfig, cache_len: int) -> Callable:
    api = build_model(cfg)

    def prefill_step(params, batch):
        return api.prefill(params, batch, cache_len=cache_len)

    return prefill_step


# ----------------------------- request serving ------------------------------


def greedy_generate(cfg: ModelConfig, params: PyTree, batch: PyTree,
                    n_new: int, *, cache_len: Optional[int] = None
                    ) -> jax.Array:
    """Batched greedy decoding used by the serving example: prefill the
    prompt, then n_new jit-compiled decode steps."""
    api = build_model(cfg)
    prompt = batch["tokens"]
    B = prompt.shape[0]
    if n_new < 0:
        raise ValueError(f"n_new must be >= 0, got {n_new}")
    if n_new == 0:
        return jnp.zeros((B, 0), jnp.int32)
    need = prompt.shape[1] + n_new + (cfg.n_patches or 0)
    # `cache_len or need` would silently treat an explicit 0 as unset
    if cache_len is None:
        cache_len = need
    elif cache_len < need:
        raise ValueError(
            f"cache_len={cache_len} cannot hold prompt + {n_new} new "
            f"tokens (need >= {need})")
    logits, cache = api.prefill(params, batch, cache_len=cache_len)
    tok = jnp.argmax(logits[:, -1, :] if logits.ndim == 3 else logits,
                     axis=-1).astype(jnp.int32)
    step = jax.jit(api.decode_step)
    out = [tok]
    for _ in range(n_new - 1):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    return jnp.stack(out, axis=1)
