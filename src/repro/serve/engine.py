"""Serving engine: cache specs, decode steps, and the batched bucket engine.

``cache_spec(cfg, batch, seq_len)`` returns the ShapeDtypeStruct pytree of
the KV/SSM cache for the dry-run (no allocation); ``make_serve_step``
returns the jit-able one-token decode function the decode shapes lower;
:class:`DecodeEngine` is the high-throughput serving path — padded-bucket
batching over a compile-once shape cache, batched prefill + KV-cache
decode, optional bf16 cache storage, and lock-free param hot-swap via a
``serve.publish.ParamStore``.

Long-context rule (DESIGN.md §6): for ``long_500k`` dense archs substitute
``cfg.long_context_window`` as a rotating sliding window — the cache is
window-sized and the step cost O(window) (sub-quadratic); SSM/hybrid archs
decode against their O(1) recurrent state natively.

Why seq padding is exact (the bucket contract): decode attention masks
cache slots with ``slot <= index`` and writes the new token at ``index``.
So a prompt of true length L right-padded to a bucket length S prefills
pad K/V into slots [L, S), but the engine then REWINDS the cache index to
L-1 and re-feeds the last real token: that decode step recomputes slot
L-1's K/V bit-identically (same token, same rope position), attends only
to slots <= L-1, and yields exactly the logits an unpadded prefill would
have produced — and every later step overwrites one pad slot before the
mask can reach it. This holds for positionally-indexed, non-rotating KV
caches (dense/moe/vlm without a sliding window); recurrent families
(ssm/hybrid/audio) and rotating windows fold pads into state, so for
those the engine pads only the batch dim and requires an exact seq match.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import attention, build_model, hybrid, rwkv6, whisper

PyTree = Any


def effective_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Apply the long-context window substitution for long_500k."""
    if (shape.name == "long_500k" and cfg.long_context_window
            and cfg.family in ("dense", "moe", "vlm")):
        return dataclasses.replace(cfg,
                                   sliding_window=cfg.long_context_window)
    if (shape.name == "long_500k" and cfg.family == "hybrid"
            and cfg.long_context_window):
        return dataclasses.replace(cfg,
                                   sliding_window=cfg.long_context_window)
    return cfg


def kv_cache_len(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.sliding_window:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def cache_spec(cfg: ModelConfig, batch: int, seq_len: int,
               cache_dtype=jnp.bfloat16) -> PyTree:
    """ShapeDtypeStruct stand-in of the decode-input cache."""
    L = cfg.n_layers
    hd = cfg.resolved_head_dim
    idx = jax.ShapeDtypeStruct((), jnp.int32)
    if cfg.family in ("dense", "moe", "vlm"):
        S = kv_cache_len(cfg, seq_len)
        kv = jax.ShapeDtypeStruct((L, batch, S, cfg.n_kv_heads, hd),
                                  cache_dtype)
        return attention.KVCache(kv, kv, idx)
    if cfg.family == "ssm":
        d = cfg.d_model
        H = d // cfg.rwkv_head_size
        hs = cfg.rwkv_head_size
        return rwkv6.RWKVCache(
            jax.ShapeDtypeStruct((L, batch, d), cfg.compute_dtype),
            jax.ShapeDtypeStruct((L, batch, d), cfg.compute_dtype),
            jax.ShapeDtypeStruct((L, batch, H, hs, hs), jnp.float32), idx)
    if cfg.family == "hybrid":
        di, N = cfg.d_inner, cfg.ssm_state
        H = cfg.resolved_ssm_heads
        P = di // H
        A = hybrid.n_attn_sites(cfg)
        S = kv_cache_len(cfg, seq_len)
        return hybrid.HybridCache(
            jax.ShapeDtypeStruct((L, batch, cfg.ssm_conv - 1, di + 2 * N),
                                 cfg.compute_dtype),
            jax.ShapeDtypeStruct((L, batch, H, P, N), jnp.float32),
            jax.ShapeDtypeStruct((A, batch, S, cfg.n_kv_heads, hd),
                                 cache_dtype),
            jax.ShapeDtypeStruct((A, batch, S, cfg.n_kv_heads, hd),
                                 cache_dtype), idx)
    if cfg.family == "audio":
        S = seq_len
        kv = jax.ShapeDtypeStruct((L, batch, S, cfg.n_kv_heads, hd),
                                  cache_dtype)
        xkv = jax.ShapeDtypeStruct((L, batch, cfg.n_audio_ctx,
                                    cfg.n_kv_heads, hd), cache_dtype)
        return whisper.WhisperCache(kv, kv, xkv, xkv, idx)
    raise KeyError(cfg.family)


def make_serve_step(cfg: ModelConfig) -> Callable:
    """(params, cache, token) -> (logits, cache) — the decode-shape target."""
    api = build_model(cfg)

    def serve_step(params, cache, token):
        return api.decode_step(params, cache, token)

    return serve_step


def make_prefill_step(cfg: ModelConfig, cache_len: int) -> Callable:
    api = build_model(cfg)

    def prefill_step(params, batch):
        return api.prefill(params, batch, cache_len=cache_len)

    return prefill_step


# ----------------------------- request serving ------------------------------


def greedy_generate(cfg: ModelConfig, params: PyTree, batch: PyTree,
                    n_new: int, *, cache_len: Optional[int] = None
                    ) -> jax.Array:
    """Batched greedy decoding used by the serving example: prefill the
    prompt, then n_new jit-compiled decode steps."""
    api = build_model(cfg)
    prompt = batch["tokens"]
    B = prompt.shape[0]
    if n_new < 0:
        raise ValueError(f"n_new must be >= 0, got {n_new}")
    if n_new == 0:
        return jnp.zeros((B, 0), jnp.int32)
    need = prompt.shape[1] + n_new + (cfg.n_patches or 0)
    # `cache_len or need` would silently treat an explicit 0 as unset
    if cache_len is None:
        cache_len = need
    elif cache_len < need:
        raise ValueError(
            f"cache_len={cache_len} cannot hold prompt + {n_new} new "
            f"tokens (need >= {need})")
    logits, cache = api.prefill(params, batch, cache_len=cache_len)
    tok = jnp.argmax(logits[:, -1, :] if logits.ndim == 3 else logits,
                     axis=-1).astype(jnp.int32)
    step = jax.jit(api.decode_step)
    out = [tok]
    for _ in range(n_new - 1):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    return jnp.stack(out, axis=1)


# --------------------------- batched decode engine ---------------------------


def cast_cache(cache: PyTree, cache_dtype) -> PyTree:
    """Cast a decode cache's float leaves to ``cache_dtype`` (bf16 halves
    KV HBM and decode read bandwidth); integer leaves (the write index)
    pass through. ``None`` is the identity."""
    if cache_dtype is None:
        return cache
    return jax.tree_util.tree_map(
        lambda x: x.astype(cache_dtype)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, cache)


def select_bucket(buckets: Sequence[Tuple[int, int]], batch: int, seq: int,
                  *, pad_seq: bool = True) -> Tuple[int, int]:
    """The tightest ``(batch, seq)`` bucket that holds a request group.

    Seq is padded up to the nearest bucket seq (exact match required when
    ``pad_seq`` is False — recurrent caches); batch is padded up to the
    smallest bucket batch >= ``batch``, falling back to the largest
    available (the caller then splits the group across calls)."""
    fits = [b for b in buckets if (b[1] >= seq if pad_seq else b[1] == seq)]
    if not fits:
        raise ValueError(
            f"no bucket holds seq={seq} (pad_seq={pad_seq}); "
            f"buckets={list(buckets)}")
    best_seq = min(s for _, s in fits)
    fits = [b for b in fits if b[1] == best_seq]
    exact = [b for b in fits if b[0] >= batch]
    return min(exact) if exact else max(fits)


class DecodeEngine:
    """Padded-bucket batched serving engine with compile-once shapes.

    Requests are grouped by prompt length and padded — batch dim up to
    the bucket's batch size, seq dim (where exact; see the module
    docstring) up to the bucket's seq — so every prefill/decode lowers to
    one of ``len(buckets)`` compiled ``(batch, seq)`` shapes. The shape
    cache is pinned by two ``RecompileWatch``es (JXL003): a request mix
    that escapes the bucket set raises instead of silently compiling per
    shape. Params come from a ``serve.publish.ParamStore`` (lock-free
    hot-swap: each generate call decodes one complete versioned snapshot)
    or a plain param pytree.

    Args:
      cfg: the model config (any registry family).
      source: a ``ParamStore`` or a param pytree.
      buckets: the compiled ``(batch, seq)`` shape set.
      max_new_tokens: per-bucket decode cache headroom. The cache length
        is ``seq + max_new_tokens`` (a static per-bucket constant), so
        every ``n_new <= max_new_tokens`` reuses the same compiled step.
      cache_dtype: optional storage dtype for the decode cache (e.g.
        ``jnp.bfloat16``); ``None`` keeps the prefill dtype. Must not be
        wider than ``cfg.compute_dtype``.
      recompile_limit: distinct-signature budget per watch; defaults to
        ``len(buckets)``.
    """

    def __init__(self, cfg: ModelConfig, source: Any, *,
                 buckets: Sequence[Tuple[int, int]] = ((1, 32), (8, 32)),
                 max_new_tokens: int = 32,
                 cache_dtype: Any = None,
                 recompile_limit: Optional[int] = None):
        from repro.analysis.jaxpr_lint import RecompileWatch

        if not buckets:
            raise ValueError("DecodeEngine needs at least one bucket")
        self.cfg = cfg
        self.api = build_model(cfg)
        self.buckets = tuple(sorted({(int(b), int(s)) for b, s in buckets}))
        self.max_new_tokens = int(max_new_tokens)
        if cache_dtype is not None and (jnp.dtype(cache_dtype).itemsize
                                        > jnp.dtype(cfg.compute_dtype).itemsize):
            # decode_attention promotes scores to the wider of (q, cache)
            # dtype, so an upcast cache would widen the hidden-state scan
            # carry mid-decode; only storage downcasts are meaningful.
            raise ValueError(
                f"cache_dtype {jnp.dtype(cache_dtype).name} is wider than "
                f"compute_dtype {jnp.dtype(cfg.compute_dtype).name}; the KV "
                "cache dtype may only narrow storage")
        self.cache_dtype = cache_dtype
        self._source = source
        # exact-seq-padding contract: positional, non-rotating KV caches
        self.pad_seq = (cfg.family in ("dense", "moe", "vlm")
                        and not cfg.sliding_window)
        self._prefill = jax.jit(self.api.prefill,
                                static_argnames=("cache_len",))
        self._decode = jax.jit(self.api.decode_step)
        limit = (len(self.buckets) if recompile_limit is None
                 else recompile_limit)
        self._watch_prefill = RecompileWatch("engine.prefill", limit=limit)
        self._watch_decode = RecompileWatch("engine.decode", limit=limit)
        self.last_version = 0

    # ------------------------------ internals ------------------------------

    def _params(self) -> Tuple[int, PyTree]:
        snap = getattr(self._source, "snapshot", None)
        if snap is not None:
            return snap()
        return 0, self._source

    def cache_len_for(self, seq: int) -> int:
        """Static per-bucket cache length: prompt slots + decode headroom
        (+ the vlm patch prefix the prefill prepends)."""
        extra = self.cfg.n_patches or 0
        return kv_cache_len(self.cfg, seq + extra + self.max_new_tokens)

    @property
    def compile_counts(self) -> dict:
        """Distinct compiled signatures per phase — pinned at the bucket-
        set size (the serving bench records and asserts this)."""
        return {"prefill": len(self._watch_prefill.signatures),
                "decode": len(self._watch_decode.signatures)}

    # ------------------------------ execution ------------------------------

    def generate_batch(self, tokens: jax.Array, n_new: int, *,
                       true_len: Optional[int] = None,
                       extras: Optional[dict] = None) -> jax.Array:
        """Greedy-decode one bucket-shaped batch.

        ``tokens``: (B, S) int32 with (B, S) in the bucket set, right-
        padded past ``true_len`` (the shared real prompt length; defaults
        to S). ``extras`` carries family-specific prefill inputs
        (``patches`` / ``audio_embeds``). Returns (B, n_new) int32.
        """
        B, S = tokens.shape
        if (B, S) not in self.buckets:
            raise ValueError(
                f"batch shape ({B}, {S}) is not in the bucket set "
                f"{list(self.buckets)} — pad requests with generate()")
        if n_new < 0:
            raise ValueError(f"n_new must be >= 0, got {n_new}")
        if n_new > self.max_new_tokens:
            raise ValueError(
                f"n_new={n_new} exceeds max_new_tokens="
                f"{self.max_new_tokens} (the per-bucket cache headroom)")
        if n_new == 0:
            return jnp.zeros((B, 0), jnp.int32)
        L = S if true_len is None else int(true_len)
        if not 0 < L <= S:
            raise ValueError(f"true_len={L} out of range for seq {S}")
        if L < S and not self.pad_seq:
            raise ValueError(
                f"family {self.cfg.family!r} (or a rotating window) folds "
                "pad tokens into its decode state; seq must match a "
                "bucket exactly (pad_seq=False)")
        version, params = self._params()
        batch = {"tokens": tokens, **(extras or {})}
        cl = self.cache_len_for(S)
        # cache_len is a pure function of the bucket, so the batch shapes
        # fully determine the compiled program — observe/check pins the
        # shape cache at the bucket-set size
        self._watch_prefill.observe(params, batch)
        self._watch_prefill.check()
        logits, cache = self._prefill(params, batch, cache_len=cl)
        cache = cast_cache(cache, self.cache_dtype)
        if L == S:
            tok = jnp.argmax(
                logits[:, -1, :] if logits.ndim == 3 else logits,
                axis=-1).astype(jnp.int32)
        else:
            # rewind + re-feed: recompute slot L-1 (bit-identical K/V),
            # attend only to real slots, recover the true last-position
            # logits the padded prefill did not return
            extra = self.cfg.n_patches or 0
            cache = cache._replace(
                index=jnp.asarray(L - 1 + extra, jnp.int32))
            tok = tokens[:, L - 1]
            self._watch_decode.observe(params, cache, tok)
            self._watch_decode.check()
            logits, cache = self._decode(params, cache, tok)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = [tok]
        for _ in range(n_new - 1):
            self._watch_decode.observe(params, cache, tok)
            self._watch_decode.check()
            logits, cache = self._decode(params, cache, tok)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(tok)
        self.last_version = version
        return jnp.stack(out, axis=1)

    def generate(self, prompts: Sequence[jax.Array], n_new: int
                 ) -> List[jax.Array]:
        """Serve a ragged request list: group by prompt length, pad each
        group to its bucket (batch rows replicate the first request; pad
        rows are dropped on the way out), split groups larger than the
        biggest bucket. Returns one (n_new,) int32 array per request, in
        request order."""
        prompts = [jnp.asarray(p) for p in prompts]
        if any(p.ndim != 1 for p in prompts):
            raise ValueError("generate() takes 1-D token prompts; use "
                             "generate_batch() for pre-batched input")
        groups: dict = {}
        for i, p in enumerate(prompts):
            groups.setdefault(int(p.shape[0]), []).append(i)
        results: List[Optional[jax.Array]] = [None] * len(prompts)
        for L, idxs in sorted(groups.items()):
            pending = idxs
            while pending:
                B, S = select_bucket(self.buckets, len(pending), L,
                                     pad_seq=self.pad_seq)
                take = pending[:B]
                pending = pending[B:]
                rows = [jnp.pad(prompts[i], (0, S - L)) for i in take]
                while len(rows) < B:          # batch-dim padding
                    rows.append(rows[0])
                out = self.generate_batch(
                    jnp.stack(rows).astype(jnp.int32), n_new, true_len=L)
                for r, i in enumerate(take):
                    results[i] = out[r]
        return results  # type: ignore[return-value]
