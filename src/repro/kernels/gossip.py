"""Gossip mixing over the resident packed optimizer state, as Pallas
kernels.

Both kernels operate on the stacked packed (K, rows, LANE) buffer that is
the persistent representation of ``backend='pallas'`` optimizer state —
no per-step pack/unpack, no per-leaf tree_map launches:

``gossip_mix``
    D-Adam's shift-invariant mixing  out[k] = w_self * x[k] +
    sum_s w_s * x[(k + s) % K].  The reference path materializes one full
    rolled copy of the parameter stack per offset (deg extra HBM
    round-trips for the intermediates); here every grid step accumulates
    all neighbor blocks in VMEM and writes the mixed block ONCE. The
    neighbor blocks are expressed as extra input BlockSpecs over the SAME
    buffer whose index maps shift the worker coordinate by the (static)
    topology offset — the Pallas pipeline turns each into exactly the
    neighbor-block DMA the ring actually needs.

``payload_mix``
    The staleness-tolerant twin of ``gossip_mix``: the neighbor payloads
    were already selected (fresh vs buffered, outside the kernel) into
    per-offset (K, rows, LANE) buffers aligned with the destination
    worker, so every operand reads block (k, i) — same accumulation order
    and f32 arithmetic as ``gossip_mix``, which is what makes the tau=0
    path bit-for-bit identical to the synchronous round.

``consensus_mix``
    CD-Adam's consensus update  out[k] = x[k] + gamma * sum_s w_s *
    (hat_s[k] - hat_self[k])  (Alg. 2 line 8) — a (deg + 2)-operand
    elementwise pass, fused into a single VMEM visit per block.

``gossip_adam_mix``
    D-Adam's whole communication step — fused_adam THEN gossip_mix — as a
    single VMEM pass: each grid cell recomputes the Adam half-step for
    its own block AND each neighbor block straight from (p, g, m, v) and
    mixes them in registers, so the half-stepped parameter stack is never
    written to (or re-read from) HBM at all. The half-step result is
    rounded through the parameter dtype before mixing, which keeps the
    output bit-for-bit identical to the stored-then-reloaded two-pass
    sequence. The Adam math for neighbor blocks is redundant compute
    ((deg + 1)× per block), but the kernel is memory-bound: trading VPU
    flops for one full HBM round-trip of the parameter stack wins.

Hyperparameters (offsets, weights, gamma) are compile-time constants: the
optimizer jits one step per config, matching fused_adam / sign_compress.
Zero-filled padding rows mix to zero under both kernels (all-zero inputs
=> zero output), so resident buffer padding stays zero across steps.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.topology import GridShift
from repro.kernels.pack import BLOCK_ROWS, LANE  # shared tile quantum

# VMEM is ~16 MiB/core; cap the operand count so (deg + 2) blocks of
# 128 KiB (plus pipeline double-buffering) stay comfortably inside it.
# Denser graphs fall back to the XLA einsum path in the dispatcher.
MAX_FUSED_DEGREE = 32

# gossip_adam_mix reads FOUR operands (p, g, m, v) per worker block —
# 4 * (deg + 1) inputs + 3 outputs of 128 KiB, double-buffered — so its
# degree cap is tighter; denser graphs take the two-pass sequence.
MAX_GOSSIP_ADAM_DEGREE = 8


def _check_buf(x: jax.Array, block_rows: int) -> Tuple[int, int]:
    if x.ndim != 3 or x.shape[-1] != LANE:
        raise ValueError(f"expected a stacked (K, rows, {LANE}) packed "
                         f"buffer; got shape {x.shape}")
    K, rows = x.shape[0], x.shape[1]
    if rows % block_rows:
        raise ValueError(f"rows={rows} not a multiple of block_rows="
                         f"{block_rows}; pack with block_rows={block_rows}")
    return K, rows


def _mix_kernel(*refs, self_weight: float, weights: Tuple[float, ...]):
    ins, out_ref = refs[:-1], refs[-1]
    acc = self_weight * ins[0][...].astype(jnp.float32)
    for w, r in zip(weights, ins[1:]):
        acc = acc + w * r[...].astype(jnp.float32)
    out_ref[...] = acc.astype(out_ref.dtype)


def gossip_mix(x: jax.Array, offsets: Sequence[int],
               offset_weights: Sequence[float], self_weight: float, *,
               block_rows: int = BLOCK_ROWS,
               interpret: bool = False) -> jax.Array:
    """Shift-invariant gossip over a stacked packed buffer, one VMEM pass.

    ``x`` is (K, rows, LANE); row-block i of output worker k reads row-block
    i of workers k and ``src(k)`` for each static offset — plain ints are
    the circulant ``(k + s) % K``, :class:`GridShift` offsets compute the
    row-wrap-aware torus neighbor right in the BlockSpec index map (its
    ``src`` uses only ``//`` and ``%``, so it traces).
    """
    K, rows = _check_buf(x, block_rows)
    offsets = tuple(s if isinstance(s, GridShift) else int(s)
                    for s in offsets)
    weights = tuple(float(w) for w in offset_weights)
    if len(offsets) != len(weights):
        raise ValueError("offsets and offset_weights must align")
    for s in offsets:
        if isinstance(s, GridShift) and s.rows * s.cols != K:
            raise ValueError(f"GridShift {s} does not cover K={K}")
    if not offsets:
        return x

    def spec_for(shift) -> pl.BlockSpec:
        if isinstance(shift, GridShift):
            return pl.BlockSpec((1, block_rows, LANE),
                                lambda k, i, s=shift: (s.src(k), i, 0))
        return pl.BlockSpec((1, block_rows, LANE),
                            lambda k, i, s=shift: ((k + s) % K, i, 0))

    kernel = functools.partial(_mix_kernel, self_weight=float(self_weight),
                               weights=weights)
    return pl.pallas_call(
        kernel,
        grid=(K, rows // block_rows),
        in_specs=[spec_for(0)] + [spec_for(s) for s in offsets],
        out_specs=spec_for(0),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, *([x] * len(offsets)))


def payload_mix(x: jax.Array, payloads: Sequence[jax.Array],
                offset_weights: Sequence[float], self_weight: float, *,
                block_rows: int = BLOCK_ROWS,
                interpret: bool = False) -> jax.Array:
    """Mix pre-aligned neighbor payloads into the resident packed buffer:

        out[k] = w_self * x[k] + sum_i w_i * payloads[i][k]

    ``payloads[i]`` already holds offset i's neighbor value for every
    destination worker (the staleness runtime selects fresh-vs-buffered
    copies before the kernel), so all operands use identity index maps —
    same kernel body, weight order and f32 accumulation as ``gossip_mix``.
    """
    K, rows = _check_buf(x, block_rows)
    payloads = tuple(payloads)
    weights = tuple(float(w) for w in offset_weights)
    if len(payloads) != len(weights):
        raise ValueError("payloads and offset_weights must align")
    for p in payloads:
        if p.shape != x.shape:
            raise ValueError(f"payload shape {p.shape} != x {x.shape}")
    if not payloads:
        return x

    spec = pl.BlockSpec((1, block_rows, LANE), lambda k, i: (k, i, 0))
    kernel = functools.partial(_mix_kernel, self_weight=float(self_weight),
                               weights=weights)
    return pl.pallas_call(
        kernel,
        grid=(K, rows // block_rows),
        in_specs=[spec] * (1 + len(payloads)),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, *payloads)


def _gossip_adam_kernel(*refs, self_weight: float,
                        weights: Tuple[float, ...], eta: float,
                        beta1: float, beta2: float, tau: float,
                        weight_decay: float):
    ins, (po_ref, mo_ref, vo_ref) = refs[:-3], refs[-3:]

    def half_step(p_ref, g_ref, m_ref, v_ref):
        # identical ops, order and constants as fused_adam._adam_kernel —
        # that is what pins the fused path bitwise to the two-pass one
        g = g_ref[...].astype(jnp.float32)
        p = p_ref[...]
        if weight_decay:
            g = g + weight_decay * p.astype(jnp.float32)
        m = beta1 * m_ref[...].astype(jnp.float32) + (1.0 - beta1) * g
        v = beta2 * v_ref[...].astype(jnp.float32) + (1.0 - beta2) * g * g
        step = eta * m * jax.lax.rsqrt(v + 1e-30) \
            if tau == 0.0 else eta * m / (jnp.sqrt(v) + tau)
        # round through the parameter dtype BEFORE mixing: the two-pass
        # sequence stores the half-step and reloads it for the mix
        po = (p.astype(jnp.float32) - step).astype(po_ref.dtype)
        return po, m, v

    po_self, m_self, v_self = half_step(*ins[0:4])
    acc = self_weight * po_self.astype(jnp.float32)
    for j, w in enumerate(weights):
        po_nbr, _, _ = half_step(*ins[4 * (j + 1):4 * (j + 2)])
        acc = acc + w * po_nbr.astype(jnp.float32)
    po_ref[...] = acc.astype(po_ref.dtype)
    mo_ref[...] = m_self.astype(mo_ref.dtype)
    vo_ref[...] = v_self.astype(vo_ref.dtype)


def gossip_adam_mix(p: jax.Array, g: jax.Array, m: jax.Array,
                    v: jax.Array, offsets: Sequence[int],
                    offset_weights: Sequence[float], self_weight: float, *,
                    eta: float, beta1: float = 0.9, beta2: float = 0.999,
                    tau: float = 1e-6, weight_decay: float = 0.0,
                    block_rows: int = BLOCK_ROWS, interpret: bool = False
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused Adam half-step + shift-invariant gossip over resident packed
    buffers: ``fused_adam`` followed by ``gossip_mix``, in ONE VMEM pass.

    All four operands are stacked (K, rows, LANE) buffers; returns
    (mixed params, m, v). Each output block's neighbor half-steps are
    recomputed in VMEM from the neighbor's (p, g, m, v) blocks via
    shifted BlockSpec index maps (same shift arithmetic as
    ``gossip_mix``), with the half-step rounded through the parameter
    dtype before the f32 mix — bit-for-bit the two-pass result.
    """
    K, rows = _check_buf(p, block_rows)
    for name, b in (("g", g), ("m", m), ("v", v)):
        if b.shape != p.shape:
            raise ValueError(f"{name} shape {b.shape} != p {p.shape}")
    offsets = tuple(s if isinstance(s, GridShift) else int(s)
                    for s in offsets)
    weights = tuple(float(w) for w in offset_weights)
    if len(offsets) != len(weights):
        raise ValueError("offsets and offset_weights must align")
    if not offsets:
        raise ValueError("gossip_adam_mix needs at least one offset; "
                         "offset-free topologies have no mix to fuse "
                         "(use fused_adam)")
    if len(offsets) > MAX_GOSSIP_ADAM_DEGREE:
        raise ValueError(
            f"degree {len(offsets)} > MAX_GOSSIP_ADAM_DEGREE="
            f"{MAX_GOSSIP_ADAM_DEGREE}; the dispatcher should take the "
            "two-pass sequence for denser graphs")
    for s in offsets:
        if isinstance(s, GridShift) and s.rows * s.cols != K:
            raise ValueError(f"GridShift {s} does not cover K={K}")

    def spec_for(shift) -> pl.BlockSpec:
        if isinstance(shift, GridShift):
            return pl.BlockSpec((1, block_rows, LANE),
                                lambda k, i, s=shift: (s.src(k), i, 0))
        return pl.BlockSpec((1, block_rows, LANE),
                            lambda k, i, s=shift: ((k + s) % K, i, 0))

    kernel = functools.partial(
        _gossip_adam_kernel, self_weight=float(self_weight),
        weights=weights, eta=float(eta), beta1=float(beta1),
        beta2=float(beta2), tau=float(tau),
        weight_decay=float(weight_decay))
    in_specs, operands = [], []
    for s in (0,) + offsets:
        in_specs.extend([spec_for(s)] * 4)
        operands.extend([p, g, m, v])
    return pl.pallas_call(
        kernel,
        grid=(K, rows // block_rows),
        in_specs=in_specs,
        out_specs=[spec_for(0)] * 3,
        out_shape=[
            jax.ShapeDtypeStruct(p.shape, p.dtype),
            jax.ShapeDtypeStruct(m.shape, m.dtype),
            jax.ShapeDtypeStruct(v.shape, v.dtype),
        ],
        interpret=interpret,
    )(*operands)


def _consensus_kernel(*refs, gamma: float, weights: Tuple[float, ...]):
    x_ref, hs_ref = refs[0], refs[1]
    hn_refs, out_ref = refs[2:-1], refs[-1]
    hs = hs_ref[...].astype(jnp.float32)
    acc = jnp.zeros_like(hs)
    for w, hn in zip(weights, hn_refs):
        acc = acc + w * (hn[...].astype(jnp.float32) - hs)
    out_ref[...] = (x_ref[...].astype(jnp.float32)
                    + gamma * acc).astype(out_ref.dtype)


def consensus_mix(x: jax.Array, hat_self: jax.Array,
                  hat_nbrs: Sequence[jax.Array],
                  offset_weights: Sequence[float], gamma: float, *,
                  block_rows: int = BLOCK_ROWS,
                  interpret: bool = False) -> jax.Array:
    """CD-Adam consensus update on resident packed buffers, one VMEM pass.

    All operands are (K, rows, LANE); no communication happens here — the
    neighbor xhat copies are CHOCO-style local state.
    """
    K, rows = _check_buf(x, block_rows)
    hat_nbrs = tuple(hat_nbrs)
    weights = tuple(float(w) for w in offset_weights)
    if len(hat_nbrs) != len(weights):
        raise ValueError("hat_nbrs and offset_weights must align")
    for h in (hat_self,) + hat_nbrs:
        if h.shape != x.shape:
            raise ValueError(f"hat buffer shape {h.shape} != x {x.shape}")
    if not hat_nbrs:
        return x

    spec = pl.BlockSpec((1, block_rows, LANE), lambda k, i: (k, i, 0))
    kernel = functools.partial(_consensus_kernel, gamma=float(gamma),
                               weights=weights)
    return pl.pallas_call(
        kernel,
        grid=(K, rows // block_rows),
        in_specs=[spec] * (2 + len(hat_nbrs)),
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, hat_self, *hat_nbrs)
