"""Flash attention (prefill/train) as a Pallas TPU kernel with GQA.

Grid: (B, Hq, n_q_blocks, n_kv_blocks) with the KV dim innermost and
``arbitrary`` semantics so the (acc, m, l) online-softmax state persists in
VMEM scratch across KV iterations — the score tile never leaves VMEM (the
insight flash attention brings to the TPU memory hierarchy: HBM->VMEM
streaming of K/V tiles against a resident Q tile, MXU-shaped (block, 128)
tiles).

Causal/window masking is applied per-tile from block indices; fully-masked
tiles still iterate (static grid) but skip the dot via ``pl.when``.
"""
from __future__ import annotations

import functools
import math
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed across jax versions: CompilerParams (new) vs TPUCompilerParams (old)
_COMPILER_PARAMS = getattr(pltpu, 'CompilerParams', None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_kv: int, n_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_kv
    # static-shape tile positions
    q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                               (block_q, block_kv), 0)
    k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                               (block_q, block_kv), 1)
    needed = jnp.bool_(True)
    if causal:
        needed = needed & (k_start <= q_start + block_q - 1)
    if window and window > 0:
        needed = needed & (k_start + block_kv - 1 >= q_start - window + 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (block_q, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (block_kv, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        ok = jnp.ones((block_q, block_kv), bool)
        if causal:
            ok = ok & (k_pos <= q_pos)
        if window and window > 0:
            ok = ok & (q_pos - k_pos < window)
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        v_t = v_ref[0, 0].astype(jnp.float32)        # (block_kv, D)
        pv = jax.lax.dot_general(p, v_t, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _final():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 512, block_kv: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: (B, S, Hq, D); k, v: (B, T, Hk, D) -> (B, S, Hq, D).

    D should be a multiple of 128 lanes for MXU alignment (64 works via
    padding by Mosaic); block_q/block_kv are sublane-aligned tile heights.
    """
    B, S, Hq, D = q.shape
    T, Hk = k.shape[1], k.shape[2]
    G = Hq // Hk
    bq = min(block_q, S)
    while S % bq:
        bq -= 1
    bkv = min(block_kv, T)
    while T % bkv:
        bkv -= 1
    n_q, n_kv = S // bq, T // bkv
    scale = 1.0 / math.sqrt(D)

    # layout: (B, H, S, D) so tiles are (bq, D) matrices
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_kv=bkv, n_kv=n_kv)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bkv, D),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bkv, D),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, D), q.dtype),
        scratch_shapes=[
            # VMEM scratch: acc (bq, D), running max/denominator (bq, 1)
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out, 1, 2)
