"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute with ``interpret=True`` —
the kernel body runs step-by-step in Python/XLA, which is how the tests
validate them against the ref.py oracles. On a real TPU the same calls
compile to Mosaic. ``interpret`` is resolved once per process from the
backend unless overridden.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import fused_adam as _adam
from repro.kernels import gossip as _gossip
from repro.kernels import rwkv_scan as _wkv
from repro.kernels import sign_compress as _sc


def _interpret(override: Optional[bool]) -> bool:
    if override is not None:
        return override
    return jax.default_backend() != "tpu"


def fused_adam(p, g, m, v, *, eta, beta1=0.9, beta2=0.999, tau=1e-6,
               weight_decay=0.0, interpret: Optional[bool] = None):
    return _adam.fused_adam(p, g, m, v, eta=eta, beta1=beta1, beta2=beta2,
                            tau=tau, weight_decay=weight_decay,
                            interpret=_interpret(interpret))


def sign_compress(x, hat, *, interpret: Optional[bool] = None):
    return _sc.sign_compress(x, hat, interpret=_interpret(interpret))


def sign_compress_stacked(x, hat, *, n_true: Optional[int] = None,
                          reduce_axis: Optional[str] = None,
                          interpret: Optional[bool] = None):
    return _sc.sign_compress_stacked(x, hat, n_true=n_true,
                                     reduce_axis=reduce_axis,
                                     interpret=_interpret(interpret))


def gossip_mix(x, offsets, offset_weights, self_weight, *,
               block_rows: Optional[int] = None,
               interpret: Optional[bool] = None):
    kw = {} if block_rows is None else {"block_rows": block_rows}
    return _gossip.gossip_mix(x, offsets, offset_weights, self_weight,
                              interpret=_interpret(interpret), **kw)


def gossip_adam_mix(p, g, m, v, offsets, offset_weights, self_weight, *,
                    eta, beta1=0.9, beta2=0.999, tau=1e-6,
                    weight_decay=0.0, block_rows: Optional[int] = None,
                    interpret: Optional[bool] = None):
    kw = {} if block_rows is None else {"block_rows": block_rows}
    return _gossip.gossip_adam_mix(p, g, m, v, offsets, offset_weights,
                                   self_weight, eta=eta, beta1=beta1,
                                   beta2=beta2, tau=tau,
                                   weight_decay=weight_decay,
                                   interpret=_interpret(interpret), **kw)


def payload_mix(x, payloads, offset_weights, self_weight, *,
                block_rows: Optional[int] = None,
                interpret: Optional[bool] = None):
    kw = {} if block_rows is None else {"block_rows": block_rows}
    return _gossip.payload_mix(x, payloads, offset_weights, self_weight,
                               interpret=_interpret(interpret), **kw)


def consensus_mix(x, hat_self, hat_nbrs, offset_weights, gamma, *,
                  block_rows: Optional[int] = None,
                  interpret: Optional[bool] = None):
    kw = {} if block_rows is None else {"block_rows": block_rows}
    return _gossip.consensus_mix(x, hat_self, hat_nbrs, offset_weights,
                                 gamma, interpret=_interpret(interpret), **kw)


def flash_attention(q, k, v, *, causal=True, window=0, block_q=512,
                    block_kv=512, interpret: Optional[bool] = None):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_kv=block_kv,
                               interpret=_interpret(interpret))


def rwkv_scan(r, k, v, w, u, state, *, chunk=128,
              interpret: Optional[bool] = None):
    return _wkv.rwkv_scan(r, k, v, w, u, state, chunk=chunk,
                          interpret=_interpret(interpret))
