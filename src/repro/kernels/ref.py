"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are intentionally the simplest correct implementations — the kernel
sweeps in tests/test_kernels.py assert each Pallas kernel (interpret mode on
CPU) matches these across shapes and dtypes.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp


def fused_adam_ref(p, g, m, v, *, eta: float, beta1: float, beta2: float,
                   tau: float, weight_decay: float = 0.0
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """The paper's Alg. 1 lines 4-6 (no bias correction)."""
    g = g.astype(m.dtype)
    if weight_decay:
        g = g + weight_decay * p.astype(m.dtype)
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    p_new = p - (eta * m_new / (jnp.sqrt(v_new) + tau)).astype(p.dtype)
    return p_new, m_new, v_new


def sign_compress_ref(x, hat, *, gamma_scale: float = 1.0
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """CHOCO error-feedback sign compression:
        delta = x - hat
        scale = mean(|delta|)
        q     = int8 sign(delta)
        hat'  = hat + scale * q
    Returns (q int8, scale f32 scalar, hat')."""
    delta = (x - hat).astype(jnp.float32)
    scale = jnp.mean(jnp.abs(delta)) * gamma_scale
    q = jnp.sign(delta).astype(jnp.int8)
    hat_new = (hat.astype(jnp.float32)
               + scale * q.astype(jnp.float32)).astype(hat.dtype)
    return q, scale, hat_new


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int = 0) -> jax.Array:
    """Naive attention with GQA. q (B,S,Hq,D), k/v (B,T,Hk,D) ->
    (B,S,Hq,D), f32 accumulation."""
    B, S, Hq, D = q.shape
    T, Hk = k.shape[1], k.shape[2]
    G = Hq // Hk
    qg = q.reshape(B, S, Hk, G, D)
    s = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok = ok & (k_pos <= q_pos)
    if window and window > 0:
        ok = ok & (q_pos - k_pos < window)
    s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, Hq, D).astype(q.dtype)


def rwkv_scan_ref(r, k, v, w, u, state) -> Tuple[jax.Array, jax.Array]:
    """RWKV6 WKV recurrence. r,k,v,w: (B,S,H,D); u: (H,D);
    state: (B,H,D,D) [key x value]. Returns (y (B,S,H,D) f32, state')."""
    r, k, v, w = (t.astype(jnp.float32) for t in (r, k, v, w))
    u = u.astype(jnp.float32)

    def step(S_, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhi,bhij->bhj", r_t, S_ + u[None, :, :, None] * kv)
        S_ = w_t[..., :, None] * S_ + kv
        return S_, y

    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, ys = jax.lax.scan(step, state.astype(jnp.float32), inputs)
    return jnp.moveaxis(ys, 0, 1), state
