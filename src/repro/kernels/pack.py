"""Pytree <-> lane-aligned buffer packing for the Pallas optimizer kernels.

The fused-Adam / sign-compress kernels operate on (rows, 128) VMEM-tileable
buffers; optimizer state lives as ragged parameter pytrees. This module is
the bridge: a ``PackSpec`` captures the leaf layout of a tree once, and
``pack`` / ``unpack`` move congruent trees in and out of a single flat
buffer.

Two layouts:

* **flat** (``make_spec(tree)``): every element of every leaf — including a
  stacked worker dim — is concatenated into one (rows, LANE) buffer, so the
  whole parameter vector is ONE kernel launch. This is what the fused-Adam
  dispatch uses: the update is elementwise, so worker/leaf boundaries don't
  affect the math.
* **stacked** (``make_spec(tree, stacked=True)``): the leading worker dim K
  is preserved; per-worker contents are concatenated and padded to a
  (K, rows, LANE) buffer whose row k holds exactly worker k's elements.

  NOTE: CD-Adam's pallas comm round does NOT pack — it launches
  ``sign_compress_stacked`` per leaf, because the reference semantics put
  one compression scale per (worker, leaf) and whole-tree packing would
  coarsen that to one scale per worker (different math, no parity). The
  stacked layout is for worker-dim-preserving buffer transport (e.g. a
  future whole-vector compressor that deliberately opts into per-worker
  scales).

Padding is to whole (block_rows, LANE) tiles so the kernels never re-pad.
Mixed-dtype trees are packed in the widest participating float dtype
(``jnp.result_type``) and cast back per leaf on unpack, which is lossless
for the bf16-in-f32 case; the pack/unpack pair is an exact inverse.

All sizes in the spec are Python ints — specs are hashable static data,
safe to close over in jitted functions.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

LANE = 128


class PackSpec(NamedTuple):
    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]   # full leaf shapes (incl. K if stacked)
    dtypes: Tuple[Any, ...]
    sizes: Tuple[int, ...]                # per-(worker-)leaf element counts
    n: int                                # true elements per worker (sum sizes)
    rows: int                             # padded row count: rows*LANE >= n
    k: Optional[int]                      # worker count; None in flat mode

    @property
    def stacked(self) -> bool:
        return self.k is not None

    @property
    def padded(self) -> int:
        return self.rows * LANE


def make_spec(tree: PyTree, *, stacked: bool = False,
              block_rows: int = 1) -> PackSpec:
    """Record the layout of ``tree``; pad up to whole (block_rows, LANE)
    tiles. Any tree congruent with ``tree`` (same treedef + leaf shapes) can
    then be packed against this spec, regardless of leaf dtypes."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        raise ValueError("cannot pack an empty pytree")
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    k: Optional[int] = None
    if stacked:
        ks = {s[0] if s else None for s in shapes}
        if len(ks) != 1 or None in ks:
            raise ValueError(
                f"stacked pack needs a shared leading worker dim; got {shapes}")
        (k,) = ks
        sizes = tuple(int(np.prod(s[1:], dtype=np.int64)) for s in shapes)
    else:
        sizes = tuple(int(np.prod(s, dtype=np.int64)) for s in shapes)
    n = sum(sizes)
    per_tile = block_rows * LANE
    padded = n + (-n) % per_tile
    return PackSpec(treedef=treedef, shapes=shapes, dtypes=dtypes,
                    sizes=sizes, n=n, rows=padded // LANE, k=k)


def _check_congruent(leaves, spec: PackSpec) -> None:
    got = tuple(tuple(l.shape) for l in leaves)
    if got != spec.shapes:
        raise ValueError(f"tree does not match spec: {got} vs {spec.shapes}")


def pack(tree: PyTree, spec: PackSpec, dtype: Any = None) -> jax.Array:
    """Flatten ``tree`` into a (rows, LANE) — or (K, rows, LANE) — buffer.

    ``dtype`` defaults to the widest dtype among the leaves; padding is
    zeros (the kernels' reductions are pad-safe for zero fill)."""
    leaves = jax.tree_util.tree_leaves(tree)
    _check_congruent(leaves, spec)
    dt = jnp.dtype(dtype) if dtype is not None else jnp.result_type(*leaves)
    if spec.stacked:
        parts = [l.reshape(spec.k, -1).astype(dt) for l in leaves]
        flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
        if spec.padded != spec.n:
            flat = jnp.pad(flat, ((0, 0), (0, spec.padded - spec.n)))
        return flat.reshape(spec.k, spec.rows, LANE)
    parts = [l.reshape(-1).astype(dt) for l in leaves]
    flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    if spec.padded != spec.n:
        flat = jnp.pad(flat, (0, spec.padded - spec.n))
    return flat.reshape(spec.rows, LANE)


def unpack(buf: jax.Array, spec: PackSpec) -> PyTree:
    """Exact inverse of ``pack``: strip padding, split, restore per-leaf
    shape and dtype."""
    offsets = np.cumsum((0,) + spec.sizes)[:-1]
    if spec.stacked:
        flat = buf.reshape(spec.k, -1)
        leaves = [
            flat[:, o:o + sz].astype(dt).reshape(shape)
            for o, sz, dt, shape in zip(offsets, spec.sizes, spec.dtypes,
                                        spec.shapes)
        ]
    else:
        flat = buf.reshape(-1)
        leaves = [
            flat[o:o + sz].astype(dt).reshape(shape)
            for o, sz, dt, shape in zip(offsets, spec.sizes, spec.dtypes,
                                        spec.shapes)
        ]
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)
