"""Pytree <-> lane-aligned buffer packing for the Pallas optimizer kernels.

The fused-Adam / sign-compress kernels operate on (rows, 128) VMEM-tileable
buffers; optimizer state lives as ragged parameter pytrees. This module is
the bridge: a ``PackSpec`` captures the leaf layout of a tree once, and
``pack`` / ``unpack`` move congruent trees in and out of a single flat
buffer.

Three layouts:

* **flat** (``make_spec(tree)``): every element of every leaf — including a
  stacked worker dim — is concatenated into one (rows, LANE) buffer, so the
  whole parameter vector is ONE kernel launch. This is what the fused-Adam
  dispatch uses: the update is elementwise, so worker/leaf boundaries don't
  affect the math.
* **stacked** (``make_spec(tree, stacked=True)``): the leading worker dim K
  is preserved; per-worker contents are concatenated and padded to a
  (K, rows, LANE) buffer whose row k holds exactly worker k's elements.
* **stacked + leaf-aligned** (``make_spec(tree, stacked=True,
  leaf_align=True)``): additionally every leaf segment is padded up to
  whole (block_rows, LANE) tiles, so each leaf occupies a contiguous,
  tile-aligned row range of the buffer (``leaf_row_ranges``). This is the
  *resident* layout of the packed optimizer states: per-(worker, leaf)
  kernels — e.g. CD-Adam's sign compression, whose reference semantics put
  one scale per (worker, leaf) — run directly on buffer *slices*, with no
  per-step pack/unpack and no coarsening of the per-leaf math.

Padding is to whole (block_rows, LANE) tiles so the kernels never re-pad,
and is zero-filled — the optimizer kernels preserve zeros in padding, so a
resident buffer's padding stays zero across arbitrarily many steps.
Mixed-dtype trees are packed in the widest participating float dtype
(``jnp.result_type``) and cast back per leaf on unpack, which is lossless
for the bf16-in-f32 case; the pack/unpack pair is an exact inverse.
Integer-dtype leaves are rejected outright: packing them through the float
buffer would silently corrupt them in the kernels' ``sqrt``/``sign`` math.

All sizes in the spec are Python ints — specs are hashable static data,
safe to close over in jitted functions and to carry as static aux_data of
a registered pytree (how the packed optimizer states hold them).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

LANE = 128
# Shared VMEM tile quantum: (BLOCK_ROWS, LANE) f32 = 128 KiB/operand. The
# resident packed layout aligns to it so fused_adam / gossip /
# sign_compress (which import it from here) never re-pad a buffer.
BLOCK_ROWS = 256


class PackSpec(NamedTuple):
    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]   # full leaf shapes (incl. K if stacked)
    dtypes: Tuple[Any, ...]
    sizes: Tuple[int, ...]                # per-(worker-)leaf element counts
    offsets: Tuple[int, ...]              # per-leaf start offset in the
    #                                       padded flat (per-worker) buffer
    n: int                                # true elements per worker (sum sizes)
    rows: int                             # padded row count: rows*LANE >= n
    k: Optional[int]                      # worker count; None in flat mode

    @property
    def stacked(self) -> bool:
        return self.k is not None

    @property
    def padded(self) -> int:
        return self.rows * LANE

    @property
    def leaf_aligned(self) -> bool:
        """True when every leaf segment starts on a LANE boundary (the
        leaf_align layout), i.e. per-leaf buffer slices are row ranges."""
        return all(o % LANE == 0 for o in self.offsets) and \
            self.padded % LANE == 0

    def buf_shape(self) -> Tuple[int, ...]:
        return ((self.k, self.rows, LANE) if self.stacked
                else (self.rows, LANE))


def _require_float(dtypes, what: str) -> None:
    for dt in dtypes:
        if not jnp.issubdtype(dt, jnp.floating):
            raise ValueError(
                f"{what} requires float leaves; got dtype {dt} — packing "
                "integer data through the float buffer would corrupt it in "
                "the kernels' sqrt/sign math (cast it explicitly first, or "
                "keep it out of the packed tree)")


def make_spec(tree: PyTree, *, stacked: bool = False,
              block_rows: int = 1, leaf_align: bool = False) -> PackSpec:
    """Record the layout of ``tree``; pad up to whole (block_rows, LANE)
    tiles. With ``leaf_align`` every *leaf segment* is padded to whole
    tiles, so each leaf occupies a contiguous tile-aligned row range. Any
    tree congruent with ``tree`` (same treedef + leaf shapes) can then be
    packed against this spec, regardless of (float) leaf dtypes."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        raise ValueError("cannot pack an empty pytree")
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    _require_float(dtypes, "pack()")
    k: Optional[int] = None
    if stacked:
        ks = {s[0] if s else None for s in shapes}
        if len(ks) != 1 or None in ks:
            raise ValueError(
                f"stacked pack needs a shared leading worker dim; got {shapes}")
        (k,) = ks
        sizes = tuple(int(np.prod(s[1:], dtype=np.int64)) for s in shapes)
    else:
        sizes = tuple(int(np.prod(s, dtype=np.int64)) for s in shapes)
    per_tile = block_rows * LANE
    if leaf_align:
        seg = tuple(sz + (-sz) % per_tile for sz in sizes)
        offsets = tuple(int(o) for o in np.cumsum((0,) + seg)[:-1])
        padded = int(sum(seg))
    else:
        offsets = tuple(int(o) for o in np.cumsum((0,) + sizes)[:-1])
        n_true = sum(sizes)
        padded = n_true + (-n_true) % per_tile
    return PackSpec(treedef=treedef, shapes=shapes, dtypes=dtypes,
                    sizes=sizes, offsets=offsets, n=sum(sizes),
                    rows=padded // LANE, k=k)


def leaf_row_ranges(spec: PackSpec) -> Tuple[Tuple[int, int], ...]:
    """Per-leaf (row_start, row_end) within the buffer. Requires the
    leaf-aligned layout (each segment a whole number of rows)."""
    if not spec.leaf_aligned:
        raise ValueError("leaf_row_ranges needs a leaf_align=True spec")
    ends = spec.offsets[1:] + (spec.padded,)
    return tuple((o // LANE, e // LANE)
                 for o, e in zip(spec.offsets, ends))


def _check_congruent(leaves, spec: PackSpec) -> None:
    got = tuple(tuple(l.shape) for l in leaves)
    if got != spec.shapes:
        raise ValueError(f"tree does not match spec: {got} vs {spec.shapes}")


def _segment_pads(spec: PackSpec) -> Tuple[int, ...]:
    """Zero-fill element count after each leaf's true data."""
    ends = spec.offsets[1:] + (spec.padded,)
    return tuple(e - o - sz
                 for o, e, sz in zip(spec.offsets, ends, spec.sizes))


def pack(tree: PyTree, spec: PackSpec, dtype: Any = None) -> jax.Array:
    """Flatten ``tree`` into a (rows, LANE) — or (K, rows, LANE) — buffer.

    ``dtype`` defaults to the widest dtype among the leaves; padding is
    zeros (the kernels' reductions are pad-safe for zero fill, and the
    optimizer kernels map zeros to zeros so resident padding stays zero)."""
    leaves = jax.tree_util.tree_leaves(tree)
    _check_congruent(leaves, spec)
    _require_float([l.dtype for l in leaves], "pack()")
    dt = jnp.dtype(dtype) if dtype is not None else jnp.result_type(*leaves)
    pads = _segment_pads(spec)
    if spec.stacked:
        parts = []
        for l, pad in zip(leaves, pads):
            flat = l.reshape(spec.k, -1).astype(dt)
            parts.append(jnp.pad(flat, ((0, 0), (0, pad))) if pad else flat)
        flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
        return flat.reshape(spec.k, spec.rows, LANE)
    parts = []
    for l, pad in zip(leaves, pads):
        flat = l.reshape(-1).astype(dt)
        parts.append(jnp.pad(flat, (0, pad)) if pad else flat)
    flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    return flat.reshape(spec.rows, LANE)


def unpack(buf: jax.Array, spec: PackSpec) -> PyTree:
    """Exact inverse of ``pack``: strip padding, split, restore per-leaf
    shape and dtype."""
    if spec.stacked:
        flat = buf.reshape(spec.k, -1)
        leaves = [
            flat[:, o:o + sz].astype(dt).reshape(shape)
            for o, sz, dt, shape in zip(spec.offsets, spec.sizes,
                                        spec.dtypes, spec.shapes)
        ]
    else:
        flat = buf.reshape(-1)
        leaves = [
            flat[o:o + sz].astype(dt).reshape(shape)
            for o, sz, dt, shape in zip(spec.offsets, spec.sizes,
                                        spec.dtypes, spec.shapes)
        ]
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)
