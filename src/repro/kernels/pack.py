"""Pytree <-> lane-aligned buffer packing for the Pallas optimizer kernels.

The fused-Adam / sign-compress kernels operate on (rows, 128) VMEM-tileable
buffers; optimizer state lives as ragged parameter pytrees. This module is
the bridge: a ``PackSpec`` captures the leaf layout of a tree once, and
``pack`` / ``unpack`` move congruent trees in and out of a single flat
buffer.

Three layouts:

* **flat** (``make_spec(tree)``): every element of every leaf — including a
  stacked worker dim — is concatenated into one (rows, LANE) buffer, so the
  whole parameter vector is ONE kernel launch. This is what the fused-Adam
  dispatch uses: the update is elementwise, so worker/leaf boundaries don't
  affect the math.
* **stacked** (``make_spec(tree, stacked=True)``): the leading worker dim K
  is preserved; per-worker contents are concatenated and padded to a
  (K, rows, LANE) buffer whose row k holds exactly worker k's elements.
* **stacked + leaf-aligned** (``make_spec(tree, stacked=True,
  leaf_align=True)``): additionally every leaf segment is padded up to
  whole (block_rows, LANE) tiles, so each leaf occupies a contiguous,
  tile-aligned row range of the buffer (``leaf_row_ranges``). This is the
  *resident* layout of the packed optimizer states: per-(worker, leaf)
  kernels — e.g. CD-Adam's sign compression, whose reference semantics put
  one scale per (worker, leaf) — run directly on buffer *slices*, with no
  per-step pack/unpack and no coarsening of the per-leaf math.
* **row-sharded** (``make_spec(..., leaf_align=True, row_shards=M)``): the
  2D (worker × model) mesh layout. Every leaf segment is padded to a whole
  multiple of ``M`` tiles and *split round-robin across M equal row
  shards*: the buffer's row dim is organized as M contiguous shard blocks,
  and shard block j holds the j-th 1/M chunk of EVERY leaf, in leaf order.
  Sharding the row dim over a 'model' mesh axis with ``PartitionSpec
  ('worker', 'model')`` therefore gives each device 1/M of every leaf at
  *static, shard-invariant* local row ranges — ``leaf_row_ranges`` returns
  those per-shard local ranges, so the per-(worker, leaf) kernels run
  unchanged on each model shard (the scale reduction psums over the model
  axis; see ``sign_compress_stacked(reduce_axis=...)``).

Padding is to whole (block_rows, LANE) tiles so the kernels never re-pad,
and is zero-filled — the optimizer kernels preserve zeros in padding, so a
resident buffer's padding stays zero across arbitrarily many steps.
Mixed-dtype trees are packed in the widest participating float dtype
(``jnp.result_type``) and cast back per leaf on unpack, which is lossless
for the bf16-in-f32 case; the pack/unpack pair is an exact inverse.
Integer-dtype leaves are rejected outright: packing them through the float
buffer would silently corrupt them in the kernels' ``sqrt``/``sign`` math.

All sizes in the spec are Python ints — specs are hashable static data,
safe to close over in jitted functions and to carry as static aux_data of
a registered pytree (how the packed optimizer states hold them).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

LANE = 128
# Shared VMEM tile quantum: (BLOCK_ROWS, LANE) f32 = 128 KiB/operand. The
# resident packed layout aligns to it so fused_adam / gossip /
# sign_compress (which import it from here) never re-pad a buffer.
BLOCK_ROWS = 256


class PackSpec(NamedTuple):
    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]   # full leaf shapes (incl. K if stacked)
    dtypes: Tuple[Any, ...]
    sizes: Tuple[int, ...]                # per-(worker-)leaf element counts
    offsets: Tuple[int, ...]              # per-leaf start offset in the
    #                                       padded flat (per-worker) buffer;
    #                                       PER-SHARD offsets when row_shards>1
    n: int                                # true elements per worker (sum sizes)
    rows: int                             # padded row count: rows*LANE >= n
    k: Optional[int]                      # worker count; None in flat mode
    row_shards: int = 1                   # model-axis row shards (2D layout)

    @property
    def stacked(self) -> bool:
        return self.k is not None

    @property
    def padded(self) -> int:
        return self.rows * LANE

    @property
    def local_rows(self) -> int:
        """Rows of one model shard (== ``rows`` when not row-sharded)."""
        return self.rows // self.row_shards

    @property
    def leaf_aligned(self) -> bool:
        """True when every leaf segment starts on a LANE boundary (the
        leaf_align layout), i.e. per-leaf buffer slices are row ranges."""
        return all(o % LANE == 0 for o in self.offsets) and \
            self.padded % LANE == 0

    def buf_shape(self) -> Tuple[int, ...]:
        return ((self.k, self.rows, LANE) if self.stacked
                else (self.rows, LANE))


def is_packed_buffer_shape(shape, k: Optional[int] = None) -> bool:
    """True when ``shape`` is a stacked packed-buffer shape
    ``(K, rows, LANE)`` — THE shared recognition rule the 2D sharding
    helpers use to decide which leaves of a state/grads tree get their
    row dim placed on a 'model' mesh axis (everything else — scalars,
    batch stacks, reference pytree leaves — replicates over it)."""
    return (len(shape) == 3 and shape[-1] == LANE
            and (k is None or shape[0] == k))


def _require_float(dtypes, what: str) -> None:
    for dt in dtypes:
        if not jnp.issubdtype(dt, jnp.floating):
            raise ValueError(
                f"{what} requires float leaves; got dtype {dt} — packing "
                "integer data through the float buffer would corrupt it in "
                "the kernels' sqrt/sign math (cast it explicitly first, or "
                "keep it out of the packed tree)")


def make_spec(tree: PyTree, *, stacked: bool = False,
              block_rows: int = 1, leaf_align: bool = False,
              row_shards: int = 1) -> PackSpec:
    """Record the layout of ``tree``; pad up to whole (block_rows, LANE)
    tiles. With ``leaf_align`` every *leaf segment* is padded to whole
    tiles, so each leaf occupies a contiguous tile-aligned row range. With
    ``row_shards=M`` (requires stacked + leaf_align) every segment is
    additionally padded to a multiple of M tiles and split across M equal
    row-shard blocks — the 2D (worker × model) mesh layout. Any tree
    congruent with ``tree`` (same treedef + leaf shapes) can then be
    packed against this spec, regardless of (float) leaf dtypes."""
    if row_shards < 1:
        raise ValueError(f"row_shards must be >= 1, got {row_shards}")
    if row_shards > 1 and not (stacked and leaf_align):
        raise ValueError(
            "row_shards > 1 needs stacked=True and leaf_align=True (the "
            "row-sharded layout is defined over leaf-aligned shard blocks)")
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        raise ValueError("cannot pack an empty pytree")
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.dtype(l.dtype) for l in leaves)
    _require_float(dtypes, "pack()")
    k: Optional[int] = None
    if stacked:
        ks = {s[0] if s else None for s in shapes}
        if len(ks) != 1 or None in ks:
            raise ValueError(
                f"stacked pack needs a shared leading worker dim; got {shapes}")
        (k,) = ks
        sizes = tuple(int(np.prod(s[1:], dtype=np.int64)) for s in shapes)
    else:
        sizes = tuple(int(np.prod(s, dtype=np.int64)) for s in shapes)
    per_tile = block_rows * LANE
    if leaf_align:
        quantum = per_tile * row_shards
        seg = tuple(sz + (-sz) % quantum for sz in sizes)
        # offsets are within ONE shard block (the whole buffer when
        # row_shards == 1): cumulative per-shard chunk starts
        chunks = tuple(s // row_shards for s in seg)
        offsets = tuple(int(o) for o in np.cumsum((0,) + chunks)[:-1])
        padded = int(sum(seg))
    else:
        offsets = tuple(int(o) for o in np.cumsum((0,) + sizes)[:-1])
        n_true = sum(sizes)
        padded = n_true + (-n_true) % per_tile
    return PackSpec(treedef=treedef, shapes=shapes, dtypes=dtypes,
                    sizes=sizes, offsets=offsets, n=sum(sizes),
                    rows=padded // LANE, k=k, row_shards=row_shards)


def leaf_row_ranges(spec: PackSpec) -> Tuple[Tuple[int, int], ...]:
    """Per-leaf (row_start, row_end) within the buffer. Requires the
    leaf-aligned layout (each segment a whole number of rows).

    For a row-sharded spec (``row_shards=M``) the ranges are *local to one
    shard block* — identical on every shard, which is exactly what SPMD
    code inside a 2D ``shard_map`` needs for static per-leaf slicing."""
    if not spec.leaf_aligned:
        raise ValueError("leaf_row_ranges needs a leaf_align=True spec")
    ends = spec.offsets[1:] + (spec.local_rows * LANE,)
    return tuple((o // LANE, e // LANE)
                 for o, e in zip(spec.offsets, ends))


def _check_congruent(leaves, spec: PackSpec) -> None:
    got = tuple(tuple(l.shape) for l in leaves)
    if got != spec.shapes:
        raise ValueError(f"tree does not match spec: {got} vs {spec.shapes}")


def local_chunk_elems(spec: PackSpec) -> Tuple[int, ...]:
    """Per-leaf element count of ONE row-shard block's slice of the leaf
    (the whole padded segment when ``row_shards == 1``). Requires the
    leaf-aligned layout. These are the static slice lengths every shard
    shares — the shard-invariance the 2D grad pipeline is built on."""
    if not spec.leaf_aligned:
        raise ValueError("local_chunk_elems needs a leaf_align=True spec")
    return _shard_chunks(spec)


def unpack_local(buf: jax.Array, spec: PackSpec) -> PyTree:
    """Per-leaf *local slices* of one row-shard block of a (row-sharded)
    packed buffer — the model-parallel counterpart of :func:`unpack`.

    ``buf`` is one shard's ``(K_local, local_rows, LANE)`` block (what a
    device holds inside a 2D ``shard_map``; ``K_local`` is usually 1).
    Returns a pytree congruent with the spec's treedef whose leaf ``i`` is
    the flat ``(K_local, local_chunk_elems(spec)[i])`` slice of that leaf's
    local row range, cast to the leaf's dtype. Padding slots are KEPT
    (zero-filled by ``pack``), so chunk ``j`` is exactly elements
    ``[j*c, (j+1)*c)`` of the padded flat leaf: the layout is
    shard-invariant, no cross-device dependence, and concatenating the M
    chunks reproduces :func:`unpack`.

    Built from plain slicing, so it is linear and jax-differentiable: the
    AD transpose of ``unpack_local`` deposits cotangents straight back
    into the local block (zeros in the inter-leaf padding) — gradients of
    a loss evaluated on local slices arrive packed, per shard, for free.
    """
    if not spec.stacked:
        raise ValueError("unpack_local needs a stacked spec")
    chunks = local_chunk_elems(spec)
    if buf.ndim != 3 or buf.shape[1] * buf.shape[2] != spec.local_rows * LANE:
        raise ValueError(
            f"unpack_local expects one (K_local, {spec.local_rows}, {LANE}) "
            f"row-shard block; got {tuple(buf.shape)}")
    flat = buf.reshape(buf.shape[0], -1)
    leaves = [flat[:, o:o + c].astype(dt)
              for o, c, dt in zip(spec.offsets, chunks, spec.dtypes)]
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


def mirror_local(tree: PyTree, spec: PackSpec, shard_idx) -> PyTree:
    """Slice a *replicated per-worker* pytree into the local-chunk layout
    of shard ``shard_idx`` — the congruence partner of :func:`unpack_local`
    for data that is NOT packed (batch targets, masks, regularizer
    anchors). Leaf shapes are the per-worker shapes (no leading K dim).

    Returns flat ``(local_chunk_elems[i],)`` leaves, zero-padded exactly
    like the packed layout, so elementwise losses can be evaluated
    chunk-against-chunk with a single psum over the model axis.
    ``shard_idx`` may be a traced value (``jax.lax.axis_index``) — the
    slice start is dynamic but the slice length is static."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if treedef != spec.treedef:
        raise ValueError(f"tree does not match spec treedef: {treedef} "
                         f"vs {spec.treedef}")
    chunks = local_chunk_elems(spec)
    got = tuple(tuple(l.shape) for l in leaves)
    want = tuple(s[1:] for s in spec.shapes)
    if got != want:
        raise ValueError(
            f"mirror_local needs per-worker leaf shapes {want}; got {got}")
    idx = jnp.asarray(shard_idx, jnp.int32)
    out = []
    for leaf, c, sz in zip(leaves, chunks, spec.sizes):
        flat = leaf.reshape(-1)
        seg = c * spec.row_shards
        if seg > sz:
            flat = jnp.pad(flat, (0, seg - sz))
        out.append(jax.lax.dynamic_slice(flat, (idx * c,), (c,)))
    return jax.tree_util.tree_unflatten(spec.treedef, out)


def _shard_chunks(spec: PackSpec) -> Tuple[int, ...]:
    """Per-leaf element count within one shard block (== full segment when
    row_shards == 1)."""
    ends = spec.offsets[1:] + (spec.local_rows * LANE,)
    return tuple(e - o for o, e in zip(spec.offsets, ends))


def _segment_pads(spec: PackSpec) -> Tuple[int, ...]:
    """Zero-fill element count after each leaf's true data (whole segment
    across all row shards)."""
    return tuple(c * spec.row_shards - sz
                 for c, sz in zip(_shard_chunks(spec), spec.sizes))


def pack(tree: PyTree, spec: PackSpec, dtype: Any = None) -> jax.Array:
    """Flatten ``tree`` into a (rows, LANE) — or (K, rows, LANE) — buffer.

    ``dtype`` defaults to the widest dtype among the leaves; padding is
    zeros (the kernels' reductions are pad-safe for zero fill, and the
    optimizer kernels map zeros to zeros so resident padding stays zero)."""
    leaves = jax.tree_util.tree_leaves(tree)
    _check_congruent(leaves, spec)
    _require_float([l.dtype for l in leaves], "pack()")
    dt = jnp.dtype(dtype) if dtype is not None else jnp.result_type(*leaves)
    pads = _segment_pads(spec)
    if spec.stacked:
        M = spec.row_shards
        parts = []
        for l, pad in zip(leaves, pads):
            flat = l.reshape(spec.k, -1).astype(dt)
            if pad:
                flat = jnp.pad(flat, ((0, 0), (0, pad)))
            # row-sharded layout: split this leaf's segment into M equal
            # chunks so concatenation below interleaves leaves per shard
            parts.append(flat.reshape(spec.k, M, -1) if M > 1 else flat)
        axis = 2 if M > 1 else 1
        flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts,
                                                                axis=axis)
        return flat.reshape(spec.k, spec.rows, LANE)
    parts = []
    for l, pad in zip(leaves, pads):
        flat = l.reshape(-1).astype(dt)
        parts.append(jnp.pad(flat, (0, pad)) if pad else flat)
    flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    return flat.reshape(spec.rows, LANE)


def _unpack_one_row(row: jax.Array, spec: PackSpec) -> PyTree:
    """Decode ONE worker row — a ``(rows, LANE)`` slice of a stacked
    buffer — into the per-worker param pytree (leaf shapes without the
    leading K dim). Shared by :func:`unpack_worker` / :func:`unpack_mean`."""
    per_worker = tuple(s[1:] for s in spec.shapes)
    if spec.row_shards > 1:
        flat = row.reshape(spec.row_shards, -1)
        leaves = [
            flat[:, o:o + c].reshape(-1)[:sz].astype(dt).reshape(shape)
            for o, c, sz, dt, shape in zip(spec.offsets,
                                           _shard_chunks(spec),
                                           spec.sizes, spec.dtypes,
                                           per_worker)
        ]
    else:
        flat = row.reshape(-1)
        leaves = [
            flat[o:o + sz].astype(dt).reshape(shape)
            for o, sz, dt, shape in zip(spec.offsets, spec.sizes,
                                        spec.dtypes, per_worker)
        ]
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


def unpack_worker(buf: jax.Array, spec: PackSpec, k: int) -> PyTree:
    """Worker ``k``'s param pytree straight from the stacked buffer.

    The unpack-once publish path: materializes ONE worker's per-worker
    tree (leaf shapes WITHOUT the leading K dim) by slicing its
    ``(rows, LANE)`` row block — reading 1/K of the buffer — instead of
    the full K-way :func:`unpack` followed by a per-worker slice.
    Handles both the leaf-aligned and the row-sharded (``row_shards=M``)
    resident layouts; under GSPMD a sharded buffer contributes only the
    addressed worker's shards.
    """
    if not spec.stacked:
        raise ValueError("unpack_worker needs a stacked spec")
    k = int(k)
    if not 0 <= k < spec.k:
        raise ValueError(f"worker index {k} out of range for K={spec.k}")
    if buf.shape != spec.buf_shape():
        raise ValueError(
            f"buffer shape {tuple(buf.shape)} does not match spec "
            f"{spec.buf_shape()}")
    return _unpack_one_row(buf[k], spec)


def unpack_mean(buf: jax.Array, spec: PackSpec) -> PyTree:
    """The consensus-mean param pytree straight from the stacked buffer.

    Reduces the worker dim IN THE PACKED DOMAIN (one ``(rows, LANE)``
    mean buffer, computed in the buffer's storage dtype — the widest
    participating float) and decodes that single row block, so exactly
    one per-worker tree is materialized. Bit-identical to
    ``mean_params(unpack(buf, spec))`` for f32 trees, without unpacking
    K per-worker copies first.
    """
    if not spec.stacked:
        raise ValueError("unpack_mean needs a stacked spec")
    if buf.shape != spec.buf_shape():
        raise ValueError(
            f"buffer shape {tuple(buf.shape)} does not match spec "
            f"{spec.buf_shape()}")
    return _unpack_one_row(jnp.mean(buf, axis=0), spec)


def unpack(buf: jax.Array, spec: PackSpec) -> PyTree:
    """Exact inverse of ``pack``: strip padding, split, restore per-leaf
    shape and dtype."""
    if spec.stacked:
        if spec.row_shards > 1:
            # inverse of the row-sharded layout: gather each leaf's M
            # chunks (one per shard block), re-join, strip padding
            flat = buf.reshape(spec.k, spec.row_shards, -1)
            leaves = [
                flat[:, :, o:o + c].reshape(spec.k, -1)[:, :sz]
                .astype(dt).reshape(shape)
                for o, c, sz, dt, shape in zip(spec.offsets,
                                               _shard_chunks(spec),
                                               spec.sizes, spec.dtypes,
                                               spec.shapes)
            ]
            return jax.tree_util.tree_unflatten(spec.treedef, leaves)
        flat = buf.reshape(spec.k, -1)
        leaves = [
            flat[:, o:o + sz].astype(dt).reshape(shape)
            for o, sz, dt, shape in zip(spec.offsets, spec.sizes,
                                        spec.dtypes, spec.shapes)
        ]
    else:
        flat = buf.reshape(-1)
        leaves = [
            flat[o:o + sz].astype(dt).reshape(shape)
            for o, sz, dt, shape in zip(spec.offsets, spec.sizes,
                                        spec.dtypes, spec.shapes)
        ]
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)
