"""Pallas TPU kernels for the perf-critical compute layers:

  fused_adam      — the paper's per-worker adaptive update, one VMEM pass
  sign_compress   — CD-Adam's error-feedback compression + int8 payload
  gossip          — shift-invariant mixing + CD-Adam consensus update over
                    the resident packed (K, rows, 128) optimizer state
  flash_attention — prefill/train attention (VMEM-resident online softmax)
  rwkv_scan       — RWKV6 WKV recurrence (state resident in VMEM)

pack.py is the pytree <-> (rows, 128) bridge; with backend='pallas' the
packed buffer is the *persistent* optimizer state (pack once at init,
unpack only at eval/checkpoint boundaries), so every kernel above composes
on the same resident layout. ops.py holds the jit'd wrappers
(interpret=True on CPU); ref.py the pure jnp oracles the tests pin each
kernel against.
"""
import importlib
from typing import Any

__all__ = ["ops", "pack", "ref"]


def __getattr__(name: str) -> Any:
    # Lazy submodule access (PEP 562): `repro.kernels.ops` etc. resolve on
    # first touch, so importing the pack layer — or repro.core for the
    # reference backend — does not pull the whole Pallas kernel stack.
    if name in ("ops", "ref", "pack", "fused_adam", "sign_compress",
                "gossip", "flash_attention", "rwkv_scan"):
        return importlib.import_module(f"repro.kernels.{name}")
    raise AttributeError(f"module 'repro.kernels' has no attribute {name!r}")
