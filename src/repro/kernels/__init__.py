"""Pallas TPU kernels for the perf-critical compute layers:

  fused_adam      — the paper's per-worker adaptive update, one VMEM pass
  sign_compress   — CD-Adam's error-feedback compression + int8 payload
  flash_attention — prefill/train attention (VMEM-resident online softmax)
  rwkv_scan       — RWKV6 WKV recurrence (state resident in VMEM)

ops.py holds the jit'd wrappers (interpret=True on CPU); ref.py the pure
jnp oracles the tests pin each kernel against.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
