"""RWKV6 WKV recurrence as a sequence-chunked Pallas TPU kernel.

The WKV scan is the compute hot spot of the rwkv6-3b assigned arch: per
(batch, head) it carries a (D, D) state through S sequential steps

    y_t = r_t . (S + (u * k_t) (x) v_t)
    S  <- diag(w_t) S + k_t (x) v_t

TPU adaptation: the state lives in VMEM scratch for the whole sequence —
grid (B, H, n_chunks) with the chunk dim ``arbitrary`` — and each grid step
streams one (C, D) chunk of r/k/v/w from HBM, runs the C sequential updates
entirely in VMEM (fori_loop over rows; D=64 head matrices are VPU-friendly),
and writes the (C, D) output chunk. HBM traffic is exactly one read of
r,k,v,w and one write of y — the recurrence itself never leaves VMEM
(the XLA scan path round-trips the (D, D) state per step).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed across jax versions: CompilerParams (new) vs TPUCompilerParams (old)
_COMPILER_PARAMS = getattr(pltpu, 'CompilerParams', None) \
    or pltpu.TPUCompilerParams


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sf_ref,
                state_ref, *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)   # (C, D)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)      # (D,)

    def body(t, y):
        r_t = r[t]                         # (D,)
        kv = k[t][:, None] * v[t][None, :]             # (D, D)
        S = state_ref[...]
        y_t = (r_t[None, :] @ (S + u[:, None] * kv))[0]  # (D,)
        state_ref[...] = w[t][:, None] * S + kv
        return y.at[t].set(y_t)

    y = jax.lax.fori_loop(0, chunk, body,
                          jnp.zeros((chunk, r.shape[1]), jnp.float32))
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _final():
        sf_ref[0, 0] = state_ref[...]


def rwkv_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
              u: jax.Array, state: jax.Array, *, chunk: int = 128,
              interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """r,k,v,w: (B, S, H, D); u: (H, D); state: (B, H, D, D) f32.
    Returns (y (B, S, H, D) f32, final state (B, H, D, D) f32)."""
    B, S, H, D = r.shape
    c = min(chunk, S)
    while S % c:
        c -= 1
    n_chunks = S // c

    # layout: (B, H, S, D) chunk tiles
    rt, kt, vt, wt = (jnp.moveaxis(t, 1, 2) for t in (r, k, v, w))

    kernel = functools.partial(_wkv_kernel, chunk=c, n_chunks=n_chunks)
    y, sf = pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, c, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, c, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, c, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, c, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, D), lambda b, h, i: (h, 0)),
            pl.BlockSpec((1, 1, D, D), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, D), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, D, D), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, D), jnp.float32),
            jax.ShapeDtypeStruct((B, H, D, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(rt, kt, vt, wt, u, state)
    return jnp.moveaxis(y, 2, 1), sf
