"""Fused D-Adam local update as a Pallas TPU kernel.

The paper's local step (Alg. 1 lines 4-6) touches the full parameter vector
every iteration: read p, g, m, v; write p, m, v. Unfused XLA emits separate
m-update / v-update / rsqrt / axpy passes (~11 HBM round-trips); this
kernel performs the whole update in ONE pass over (8k, 128)-aligned VMEM
tiles — 4 reads + 3 writes, the memory-bound optimum.

Grid: 1-D over row-blocks of the (rows, 128) reshaped parameter; block
shape (BLOCK_ROWS, 128) in VMEM. Hyperparameters are compile-time constants
(closure), matching how the optimizer jits one step per config.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pack import BLOCK_ROWS, LANE  # shared tile quantum:
# (256, 128) f32 tile = 128 KiB/operand; 7 operands < 1 MiB, and the
# resident packed layout is aligned to it (zero re-padding here)


def _adam_kernel(p_ref, g_ref, m_ref, v_ref, po_ref, mo_ref, vo_ref, *,
                 eta: float, beta1: float, beta2: float, tau: float,
                 weight_decay: float):
    g = g_ref[...].astype(jnp.float32)
    p = p_ref[...]
    if weight_decay:
        g = g + weight_decay * p.astype(jnp.float32)
    m = beta1 * m_ref[...].astype(jnp.float32) + (1.0 - beta1) * g
    v = beta2 * v_ref[...].astype(jnp.float32) + (1.0 - beta2) * g * g
    step = eta * m * jax.lax.rsqrt(v + 1e-30) \
        if tau == 0.0 else eta * m / (jnp.sqrt(v) + tau)
    po_ref[...] = (p.astype(jnp.float32) - step).astype(po_ref.dtype)
    mo_ref[...] = m.astype(mo_ref.dtype)
    vo_ref[...] = v.astype(vo_ref.dtype)


def fused_adam(p: jax.Array, g: jax.Array, m: jax.Array, v: jax.Array, *,
               eta: float, beta1: float = 0.9, beta2: float = 0.999,
               tau: float = 1e-6, weight_decay: float = 0.0,
               block_rows: int = BLOCK_ROWS, interpret: bool = False
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Apply the fused update to a flat (or any-shape) tensor."""
    shape, dtype = p.shape, p.dtype
    n = p.size
    # pad to a whole number of (block_rows, LANE) tiles
    per_block = block_rows * LANE
    n_pad = (-n) % per_block
    def prep(x):
        flat = x.reshape(-1)
        if n_pad:
            flat = jnp.pad(flat, (0, n_pad))
        return flat.reshape(-1, LANE)
    pp, gg, mm, vv = prep(p), prep(g), prep(m), prep(v)
    rows = pp.shape[0]
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    kernel = functools.partial(_adam_kernel, eta=eta, beta1=beta1,
                               beta2=beta2, tau=tau,
                               weight_decay=weight_decay)
    po, mo, vo = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec] * 4,
        out_specs=[spec] * 3,
        out_shape=[
            jax.ShapeDtypeStruct(pp.shape, dtype),
            jax.ShapeDtypeStruct(mm.shape, m.dtype),
            jax.ShapeDtypeStruct(vv.shape, v.dtype),
        ],
        interpret=interpret,
    )(pp, gg, mm, vv)

    def unprep(x, like):
        flat = x.reshape(-1)
        if n_pad:
            flat = flat[:n]
        return flat.reshape(like.shape)

    return unprep(po, p), unprep(mo, m), unprep(vo, v)
