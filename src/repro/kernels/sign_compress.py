"""CHOCO error-feedback sign compression as Pallas TPU kernels.

CD-Adam's communication round compresses the residual delta = x - xhat to
``q = int8 sign(delta)`` with a single fp32 scale = mean|delta| (the paper's
sign operator [4], made delta-contractive by the L1 scale), then applies
``xhat += scale * q`` locally. Two kernels:

  1. ``_absmean_kernel`` — grid reduction producing per-block |delta| sums
     (one VMEM pass over x, xhat);
  2. ``_apply_kernel``   — given the final scale, emits the int8 payload and
     the updated xhat in one fused pass (the int8 tensor is what the
     runtime ppermutes to neighbors — 1 byte/elem on the wire).

The scale reduction stays exact: block partials are summed in fp32 by XLA
between the two kernels.

``sign_compress_stacked`` is the same pair of kernels lifted to a stacked
(K, ...) worker dim with a 2-D grid: one scale per worker, matching the
vmap-per-worker semantics of the reference CD-Adam encode path.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pack import BLOCK_ROWS, LANE


def _absmean_kernel(x_ref, h_ref, out_ref):
    d = x_ref[...].astype(jnp.float32) - h_ref[...].astype(jnp.float32)
    out_ref[0, 0] = jnp.sum(jnp.abs(d))


def _apply_kernel(x_ref, h_ref, scale_ref, q_ref, ho_ref):
    d = x_ref[...].astype(jnp.float32) - h_ref[...].astype(jnp.float32)
    s = jnp.sign(d)
    q_ref[...] = s.astype(jnp.int8)
    ho_ref[...] = (h_ref[...].astype(jnp.float32)
                   + scale_ref[0, 0] * s).astype(ho_ref.dtype)


def sign_compress(x: jax.Array, hat: jax.Array, *,
                  block_rows: int = BLOCK_ROWS, interpret: bool = False
                  ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (q int8 [x.shape], scale f32 [], hat_new [hat.dtype])."""
    n = x.size
    per_block = block_rows * LANE
    n_pad = (-n) % per_block

    def prep(t):
        flat = t.reshape(-1)
        if n_pad:
            flat = jnp.pad(flat, (0, n_pad))
        return flat.reshape(-1, LANE)

    xx, hh = prep(x), prep(hat)
    rows = xx.shape[0]
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))

    partials = pl.pallas_call(
        _absmean_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0], 1), jnp.float32),
        interpret=interpret,
    )(xx, hh)
    # padded entries are x=0, hat=0 -> contribute 0 to the sum; divide by
    # the true element count.
    scale = jnp.sum(partials) / n
    scale2d = scale.reshape(1, 1)

    q, hat_new = pl.pallas_call(
        _apply_kernel,
        grid=grid,
        in_specs=[spec, spec,
                  # scalar operand: SMEM, not ANY — Mosaic can't load
                  # directly from an ANY-space ref on real TPUs
                  pl.BlockSpec((1, 1), lambda i: (0, 0),
                               memory_space=pltpu.SMEM)],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(xx.shape, jnp.int8),
            jax.ShapeDtypeStruct(hh.shape, hat.dtype),
        ],
        interpret=interpret,
    )(xx, hh, scale2d)

    def unprep(t, shape):
        flat = t.reshape(-1)
        if n_pad:
            flat = flat[:n]
        return flat.reshape(shape)

    return unprep(q, x.shape), scale, unprep(hat_new, hat.shape)


# --------------------------- stacked-K variant ------------------------------


def _absmean_stacked_kernel(x_ref, h_ref, out_ref):
    d = x_ref[...].astype(jnp.float32) - h_ref[...].astype(jnp.float32)
    out_ref[0, 0] = jnp.sum(jnp.abs(d))


def _apply_stacked_kernel(x_ref, h_ref, scale_ref, q_ref, ho_ref):
    d = x_ref[...].astype(jnp.float32) - h_ref[...].astype(jnp.float32)
    s = jnp.sign(d)
    q_ref[...] = s.astype(jnp.int8)
    ho_ref[...] = (h_ref[...].astype(jnp.float32)
                   + scale_ref[0, 0] * s).astype(ho_ref.dtype)


def sign_compress_stacked(x: jax.Array, hat: jax.Array, *,
                          n_true: Optional[int] = None,
                          block_rows: int = BLOCK_ROWS,
                          interpret: bool = False,
                          reduce_axis: Optional[str] = None
                          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-worker sign compression over a stacked (K, ...) tensor.

    Returns (q int8 [x.shape], scale f32 [K], hat_new [hat.dtype]); row k
    of every output depends only on row k of the inputs — identical to
    vmapping :func:`sign_compress` over the worker dim, but lowered as one
    (K, blocks)-grid kernel pair so the worker dim can stay sharded.

    ``n_true`` overrides the scale divisor (mean |delta| denominator) when
    ``x`` is a zero-padded slice of a resident packed buffer: the padding
    contributes 0 to the |delta| sum but must not inflate the element
    count, or the per-leaf scale would diverge from the reference
    compressor's mean over the leaf's true elements.

    ``reduce_axis`` names a mesh axis to ``psum`` the |delta| partial sums
    over before dividing — the 2D (worker × model) mesh path, where ``x``
    is one model shard's slice of the leaf and the scale must still be the
    L1 mean over the *whole* (worker, leaf): every shard then computes the
    identical global scale and a consistent local ``hat`` update. With
    ``reduce_axis`` set, ``n_true`` is the leaf's GLOBAL true element
    count and may exceed this shard's slot count."""
    if x.ndim < 1:
        raise ValueError("stacked sign compress needs a leading worker dim")
    K = x.shape[0]
    n = x.size // max(K, 1)
    if n == 0:  # zero-element leaves: nothing to compress (reference path
        #         is a no-op on empties too; avoid a 0-row pallas grid)
        return (jnp.zeros(x.shape, jnp.int8), jnp.zeros((K,), jnp.float32),
                hat)
    if n_true is None:
        n_true = n
    if reduce_axis is None:
        if not 0 < n_true <= n:
            raise ValueError(f"n_true={n_true} out of range (0, {n}]")
    elif n_true <= 0:
        raise ValueError(f"n_true={n_true} must be positive")
    per_block = block_rows * LANE
    n_pad = (-n) % per_block

    def prep(t):
        flat = t.reshape(K, -1)
        if n_pad:
            flat = jnp.pad(flat, ((0, 0), (0, n_pad)))
        return flat.reshape(K, -1, LANE)

    xx, hh = prep(x), prep(hat)
    rows = xx.shape[1]
    grid = (K, rows // block_rows)
    spec = pl.BlockSpec((1, block_rows, LANE), lambda k, i: (k, i, 0))

    partials = pl.pallas_call(
        _absmean_stacked_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=pl.BlockSpec((1, 1), lambda k, i: (k, i)),
        out_shape=jax.ShapeDtypeStruct((K, grid[1]), jnp.float32),
        interpret=interpret,
    )(xx, hh)
    # padded entries are x=0, hat=0 -> contribute 0; divide by the true
    # per-worker element count. On a 2D mesh the partial sums of the other
    # model shards join via psum, so the scale is the global per-leaf L1
    # mean on every shard.
    local = jnp.sum(partials, axis=1)
    if reduce_axis is not None:
        local = jax.lax.psum(local, reduce_axis)
    scale = local / n_true
    scale2d = scale.reshape(K, 1)

    q, hat_new = pl.pallas_call(
        _apply_stacked_kernel,
        grid=grid,
        in_specs=[spec, spec,
                  pl.BlockSpec((1, 1), lambda k, i: (k, 0),
                               memory_space=pltpu.SMEM)],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(xx.shape, jnp.int8),
            jax.ShapeDtypeStruct(hh.shape, hat.dtype),
        ],
        interpret=interpret,
    )(xx, hh, scale2d)

    def unprep(t, shape):
        flat = t.reshape(K, -1)
        if n_pad:
            flat = flat[:, :n]
        return flat.reshape(shape)

    return unprep(q, x.shape), scale, unprep(hat_new, hat.shape)
