"""Production meshes (functions — importing this module never touches jax
device state; jax.make_mesh is only called when the launcher asks).

single-pod: (16, 16)    -> ('data', 'model')      256 chips
multi-pod : (2, 16, 16) -> ('pod', 'data', 'model') 512 chips

Hardware model (TPU v5e-like, used by the roofline):
  197 TFLOP/s bf16 / chip, 819 GB/s HBM / chip, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import jax

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — used by tests."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_worker_mesh(workers: int, *, model: int = 1,
                     axis_name: str = "worker"):
    """Mesh for comm='axis' decentralized execution: one slot of
    ``axis_name`` per worker (the optimizer's ppermute gossip runs over
    it), optionally crossed with an inner 'model' axis for tensor
    sharding within each worker."""
    if model > 1:
        return jax.make_mesh((workers, model), (axis_name, "model"))
    return jax.make_mesh((workers,), (axis_name,))


def n_chips(mesh) -> int:
    return mesh.devices.size
