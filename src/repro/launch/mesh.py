"""Production meshes (functions — importing this module never touches jax
device state; jax.make_mesh is only called when the launcher asks).

single-pod: (16, 16)    -> ('data', 'model')      256 chips
multi-pod : (2, 16, 16) -> ('pod', 'data', 'model') 512 chips

Hardware model (TPU v5e-like, used by the roofline):
  197 TFLOP/s bf16 / chip, 819 GB/s HBM / chip, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import warnings

import jax

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — used by tests."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_worker_mesh(workers: int, *, model_parallel: int = 1,
                     axis_name: str = "worker", model: int | None = None):
    """Mesh for comm='axis' decentralized execution: one slot of
    ``axis_name`` per worker (the optimizer's ppermute gossip runs over
    it), optionally crossed with an inner 'model' axis
    (``model_parallel=M``) so each worker is itself an M-device
    model-parallel group — the packed optimizer state is then sharded
    ``P('worker', 'model')``, gossip still crosses only the worker axis,
    and grads are computed model-parallel within each worker
    (``make_optimizer(comm='axis', mesh=...)`` picks M up from the mesh).
    Needs ``workers * model_parallel`` devices. ``model=`` is the
    deprecated spelling of ``model_parallel``."""
    if model is not None:
        if model_parallel != 1:
            raise ValueError(
                "pass either model_parallel= or the deprecated model=, "
                f"not both (got model_parallel={model_parallel}, "
                f"model={model})")
        warnings.warn("make_worker_mesh(model=...) is deprecated; use "
                      "model_parallel=", DeprecationWarning, stacklevel=2)
    m = model_parallel if model is None else model
    if m > 1:
        return jax.make_mesh((workers, m), (axis_name, "model"))
    return jax.make_mesh((workers,), (axis_name,))


def n_chips(mesh) -> int:
    return mesh.devices.size
