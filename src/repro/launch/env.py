"""Process-level XLA environment setup shared by every entrypoint.

jax reads ``XLA_FLAGS`` exactly once, at backend initialization, so any
flag this module manages must be installed BEFORE the first jax import in
the process. The module itself imports nothing heavier than ``os`` — it
is safe (and intended) to import at the very top of a driver script:

    from repro.launch import env
    env.setup()          # then `import jax`

Two rules govern every helper here:

* **append, never clobber** — a pre-set ``XLA_FLAGS`` survives intact;
  new flags are appended after it (the Python port of tier1.sh's
  ``${XLA_FLAGS:+ $XLA_FLAGS}`` idiom), and
* **first writer wins per flag** — a flag whose name is already present
  in ``XLA_FLAGS`` is never added again, so callers (CI, tier1.sh, a
  user shell) keep full control by exporting it themselves.
"""
from __future__ import annotations

import os
from typing import Mapping, MutableMapping, Optional, Sequence

HOST_DEVICE_FLAG = "--xla_force_host_platform_device_count"

# Async-collective + latency-hiding-scheduler flags: let XLA issue
# collective-permute-start early and schedule independent fused-Adam
# compute between start and done — the compiler-side half of the overlap
# story (`overlap=True` in make_optimizer is the algorithm-side half).
# CPU-only jaxlib builds ABORT at startup on unknown XLA_FLAGS names, so
# these are only installed when a GPU plugin is importable (see
# gpu_flags_supported) or the caller forces REPRO_ASYNC_COLLECTIVES=1.
ASYNC_COLLECTIVE_FLAGS = (
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)


def _flag_name(flag: str) -> str:
    return flag.split("=", 1)[0]


def _present_names(xla_flags: str) -> set:
    return {_flag_name(tok) for tok in xla_flags.split()}


def ensure_xla_flags(flags: Sequence[str], *,
                     env: Optional[MutableMapping[str, str]] = None) -> str:
    """Append each of ``flags`` to ``XLA_FLAGS`` unless a flag of the
    same name is already present (pre-set values always win). Returns the
    resulting ``XLA_FLAGS`` string."""
    env = os.environ if env is None else env
    current = env.get("XLA_FLAGS", "")
    have = _present_names(current)
    add = [f for f in flags if _flag_name(f) not in have]
    if add:
        current = " ".join(([current] if current else []) + add)
        env["XLA_FLAGS"] = current
    return current


def host_device_count(env: Optional[Mapping[str, str]] = None
                      ) -> Optional[int]:
    """The forced host-device count currently in ``XLA_FLAGS``, or None
    when the flag is absent/unparsable."""
    env = os.environ if env is None else env
    for tok in env.get("XLA_FLAGS", "").split():
        if _flag_name(tok) == HOST_DEVICE_FLAG and "=" in tok:
            try:
                return int(tok.split("=", 1)[1])
            except ValueError:
                return None
    return None


def ensure_host_devices(n: Optional[int] = None, *,
                        env: Optional[MutableMapping[str, str]] = None
                        ) -> int:
    """Force ``n`` virtual host CPU devices unless the caller already
    forced a count via ``XLA_FLAGS``. ``n`` defaults to the
    ``REPRO_HOST_DEVICES`` env var, then 8 (the tier1.sh convention).
    Returns the count actually in effect."""
    env = os.environ if env is None else env
    existing = host_device_count(env)
    if existing is not None:
        return existing
    if n is None:
        n = int(env.get("REPRO_HOST_DEVICES", "8"))
    ensure_xla_flags([f"{HOST_DEVICE_FLAG}={int(n)}"], env=env)
    return int(n)


def gpu_flags_supported(env: Optional[Mapping[str, str]] = None) -> bool:
    """Whether this process's XLA will accept ``--xla_gpu_*`` flags.

    CPU-only jaxlib builds treat unknown ``XLA_FLAGS`` names as a FATAL
    parse error at backend init, so the async flags must never reach them.
    A GPU plugin being importable is the pre-jax-import signal that the
    flags are registered; ``REPRO_ASYNC_COLLECTIVES=1`` / ``=0`` forces
    the answer either way (e.g. for a TPU pod driver or a broken probe).
    """
    env = os.environ if env is None else env
    force = env.get("REPRO_ASYNC_COLLECTIVES")
    if force is not None:
        return force.lower() not in ("0", "false", "")
    import importlib.util
    return any(importlib.util.find_spec(mod) is not None
               for mod in ("jax_cuda12_plugin", "jax_cuda11_plugin",
                           "jax_rocm60_plugin"))


def enable_async_collectives(*, env: Optional[MutableMapping[str, str]]
                             = None) -> str:
    """Install the async-collective / latency-hiding-scheduler flags when
    the backend supports them (appended, never clobbering). Returns the
    resulting ``XLA_FLAGS`` (unchanged when unsupported)."""
    env = os.environ if env is None else env
    if not gpu_flags_supported(env):
        return env.get("XLA_FLAGS", "")
    return ensure_xla_flags(ASYNC_COLLECTIVE_FLAGS, env=env)


def setup(host_devices: Optional[int] = None, *,
          async_collectives: bool = True,
          platform: Optional[str] = None,
          env: Optional[MutableMapping[str, str]] = None) -> int:
    """One-call environment setup for drivers and benchmarks. Must run
    before jax initializes. Returns the host-device count in effect."""
    env = os.environ if env is None else env
    if platform is not None:
        env.setdefault("JAX_PLATFORMS", platform)
    n = ensure_host_devices(host_devices, env=env)
    if async_collectives:
        enable_async_collectives(env=env)
    return n
