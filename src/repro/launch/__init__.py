"""Launchers: production mesh, multi-pod dry-run, training/serving drivers.

NOTE: repro.launch.dryrun sets XLA_FLAGS at import — import it only in a
fresh process (python -m repro.launch.dryrun).
"""
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS,
                               make_host_mesh, make_production_mesh,
                               make_worker_mesh, n_chips)

__all__ = ["make_production_mesh", "make_host_mesh", "make_worker_mesh",
           "n_chips", "PEAK_FLOPS", "HBM_BW", "ICI_BW"]
