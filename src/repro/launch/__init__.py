"""Launchers: production mesh, multi-pod dry-run, training/serving drivers.

Mesh exports resolve lazily (PEP 562) so ``repro.launch.env`` — which
must configure ``XLA_FLAGS`` BEFORE jax initializes — can be imported
without this package pulling in jax first.

NOTE: repro.launch.dryrun sets XLA_FLAGS at import — import it only in a
fresh process (python -m repro.launch.dryrun).
"""
_MESH_EXPORTS = ("make_production_mesh", "make_host_mesh",
                 "make_worker_mesh", "n_chips",
                 "PEAK_FLOPS", "HBM_BW", "ICI_BW")

__all__ = list(_MESH_EXPORTS) + ["env"]


def __getattr__(name):
    import importlib

    if name in _MESH_EXPORTS:
        mesh = importlib.import_module("repro.launch.mesh")
        return getattr(mesh, name)
    if name == "env":
        return importlib.import_module("repro.launch.env")
    raise AttributeError(f"module 'repro.launch' has no attribute {name!r}")
