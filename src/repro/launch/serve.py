"""Serving driver: bucketed batch decode through the DecodeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --buckets 1x32,8x32 --new-tokens 32

Uses the reduced config on CPU (--full for real hardware). Params are
served from a ParamStore behind the lock-free version pointer, prompts are
grouped into the compiled (batch, seq) bucket set, and the compile cache
is pinned at the bucket count — a bucket escape raises instead of silently
recompiling. Reports prefill latency, per-token decode latency, tokens/s
and the compile counts — the serving-side counterpart of launch/train.py.
"""
from __future__ import annotations

import argparse
import time

if __name__ == "__main__":
    # env flags (device count, async collectives) BEFORE jax initializes
    from repro.launch import env as _env
    _env.setup()

import jax
import jax.numpy as jnp

from repro.configs import get_arch, get_reduced, list_archs
from repro.models import build_model
from repro.serve import DecodeEngine, ParamStore, select_bucket


def parse_buckets(spec: str):
    """``"1x32,8x32"`` -> ((1, 32), (8, 32))."""
    out = []
    for part in spec.split(","):
        b, s = part.lower().split("x")
        out.append((int(b), int(s)))
    return tuple(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=list_archs())
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--buckets", default="1x32,8x32",
                    help="comma-separated batchxseq compile buckets")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--cache-dtype", default=None,
                    choices=[None, "bfloat16", "float32"],
                    help="KV-cache storage dtype (default: prefill dtype)")
    args = ap.parse_args()

    arch = get_arch(args.arch) if args.full else get_reduced(args.arch)
    cfg = arch.model
    api = build_model(cfg)
    store = ParamStore()
    store.publish(api.init(jax.random.PRNGKey(0)))

    cache_dtype = (None if args.cache_dtype is None
                   else jnp.dtype(args.cache_dtype))
    engine = DecodeEngine(cfg, store, buckets=parse_buckets(args.buckets),
                          max_new_tokens=args.new_tokens,
                          cache_dtype=cache_dtype)
    # pad the request into the tightest compiled bucket: seq right-padded
    # (true_len drives the exact rewind+re-feed path), batch filled by
    # replicating row 0, real rows sliced back out below
    B, S = select_bucket(engine.buckets, args.batch, args.prompt_len,
                         pad_seq=engine.pad_seq)
    if args.batch > B:
        raise SystemExit(
            f"--batch {args.batch} exceeds the largest bucket batch {B}; "
            f"add a bigger bucket to --buckets (got {args.buckets})")
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    tokens = jnp.pad(tokens, ((0, B - args.batch),
                              (0, S - args.prompt_len)))
    extras = {}
    if cfg.family == "vlm":
        extras["patches"] = jax.random.normal(
            key, (B, cfg.n_patches, 1024))
    if cfg.family == "audio":
        extras["audio_embeds"] = jax.random.normal(
            key, (B, cfg.n_audio_ctx, cfg.d_model))

    t0 = time.perf_counter()
    out = engine.generate_batch(tokens, args.new_tokens,
                                true_len=args.prompt_len,
                                extras=extras or None)
    jax.block_until_ready(out)
    t_warm = time.perf_counter() - t0

    t0 = time.perf_counter()
    out = engine.generate_batch(tokens, args.new_tokens,
                                true_len=args.prompt_len,
                                extras=extras or None)
    jax.block_until_ready(out)
    t_steady = time.perf_counter() - t0

    out = out[:args.batch]
    total = out.size
    print(f"[serve] {args.arch} ({'full' if args.full else 'reduced'}) "
          f"batch={args.batch} prompt={args.prompt_len} "
          f"buckets={engine.buckets} v{engine.last_version}")
    print(f"[serve] warm {t_warm * 1e3:.0f} ms | steady "
          f"{t_steady / args.new_tokens * 1e3:.1f} ms/tok | "
          f"{total / t_steady:.1f} tok/s | compiles {engine.compile_counts}")


if __name__ == "__main__":
    main()
