"""Serving driver: batched greedy generation for any registered arch.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b \
        --batch 4 --prompt-len 32 --new-tokens 32

Uses the reduced config on CPU (--full for real hardware). Reports
prefill latency, per-token decode latency and tokens/s — the serving-side
counterpart of launch/train.py.
"""
from __future__ import annotations

import argparse
import time

if __name__ == "__main__":
    # env flags (device count, async collectives) BEFORE jax initializes
    from repro.launch import env as _env
    _env.setup()

import jax
import jax.numpy as jnp

from repro.configs import get_arch, get_reduced, list_archs
from repro.models import build_model
from repro.serve.engine import kv_cache_len


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=list_archs())
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    arch = get_arch(args.arch) if args.full else get_reduced(args.arch)
    cfg = arch.model
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (args.batch, cfg.n_patches, 1024))
    if cfg.family == "audio":
        batch["audio_embeds"] = jax.random.normal(
            key, (args.batch, cfg.n_audio_ctx, cfg.d_model))

    extra = cfg.n_patches if cfg.family == "vlm" else 0
    cache_len = kv_cache_len(cfg, args.prompt_len + extra + args.new_tokens)

    t0 = time.perf_counter()
    logits, cache = api.prefill(params, batch, cache_len=cache_len)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    step = jax.jit(api.decode_step)
    tok = jnp.argmax(logits[:, -1, :] if logits.ndim == 3 else logits,
                     axis=-1).astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.new_tokens - 1):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    total = args.batch * args.new_tokens
    print(f"[serve] {args.arch} ({'full' if args.full else 'reduced'}) "
          f"batch={args.batch} prompt={args.prompt_len}")
    print(f"[serve] prefill {t_prefill * 1e3:.0f} ms | decode "
          f"{t_decode / max(args.new_tokens - 1, 1) * 1e3:.1f} ms/tok | "
          f"{total / (t_prefill + t_decode):.1f} tok/s")


if __name__ == "__main__":
    main()
