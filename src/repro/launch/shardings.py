"""Sharding plans: map (arch, worker mode, mesh) to PartitionSpecs.

Worker-mapping modes (DESIGN.md §3) share ONE runtime representation —
stacked parameters with a leading worker dim K — they differ only in which
mesh axes carry the worker dim and which carry the inner (tensor/FSDP)
sharding:

  mode      worker dim axes          inner param axis groups
  stacked   ('data',) | ('pod','data')   [('model',)]
  pods      () | ('pod',)                [('data',), ('model',)]   (FSDP in-pod)
  global    ()                           [('pod','data'), ('model',)] (full FSDP)
  axis      ('worker',)                  [('model',)] when present

'axis' is the comm='axis' device-parallel optimizer mode: the mesh carries
a dedicated 'worker' axis (launch.mesh.make_worker_mesh) and the optimizer
step runs per-shard inside shard_map, gossiping with ppermute over it —
``worker_state_shardings`` below places an optimizer-state pytree (packed
or reference layout) on such a mesh.

Inner dims are assigned greedily: largest axis group gets the largest
still-unassigned dim divisible by its size (megatron column/row sharding
falls out of this for the standard matrices). Per-layer stacks
('layers'/'enc_layers'/'dec_layers' in the path) keep their layer dim
unsharded so lax.scan stays local.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.kernels.pack import is_packed_buffer_shape

PyTree = Any

_LAYER_STACK_KEYS = ("layers", "enc_layers", "dec_layers")

WORKER_AXIS = "worker"  # the comm='axis' mesh axis name


def worker_state_shardings(mesh: Mesh, tree: PyTree, K: int, *,
                           axis_name: str = WORKER_AXIS,
                           model_axis: str = "model") -> PyTree:
    """NamedShardings for a comm='axis' optimizer state (or grads/batch
    stack): every leaf whose leading dim is the worker count K goes on the
    worker mesh axis; scalars (e.g. the step counter) and worker-free
    leaves are replicated. Works for both the reference pytree layout and
    the packed-resident (K, rows, 128) buffers.

    On a 2D worker × model mesh (``make_worker_mesh(K, model_parallel=M)``)
    packed buffers — 3-D lane-aligned (K, rows, 128) leaves with rows
    divisible by M — additionally put their row dim on ``model_axis``:
    the worker × model state sharding of the 2D packed backend. Non-buffer
    leaves replicate over the model axis."""
    msz = dict(mesh.shape).get(model_axis, 1)

    def one(leaf):
        shape = getattr(leaf, "shape", ())
        if len(shape) >= 1 and shape[0] == K:
            if (msz > 1 and is_packed_buffer_shape(shape, K)
                    and shape[1] % msz == 0):
                return NamedSharding(mesh, P(axis_name, model_axis))
            return NamedSharding(mesh, P(axis_name))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map(one, tree)


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    mesh: Mesh
    mode: str                 # stacked | pods | global
    multi_pod: bool
    worker_axes: Tuple[str, ...]
    inner_groups: Tuple[Tuple[str, ...], ...]
    batch_axes: Tuple[str, ...]       # sharding of the per-worker batch dim
    serve_groups: Tuple[Tuple[str, ...], ...]
    serve_batch_axes: Tuple[str, ...]
    model_cfg: Any = None             # head-aware sharding rules (see below)

    @property
    def K(self) -> int:
        k = 1
        for a in self.worker_axes:
            k *= self.mesh.shape[a]
        return k

    def axis_size(self, axes: Tuple[str, ...]) -> int:
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n


def make_plan(arch: ArchConfig, mesh: Mesh, *, multi_pod: bool,
              mode: Optional[str] = None) -> ShardingPlan:
    mode = mode or arch.parallel.worker_mode
    if mode == "stacked":
        worker = ("pod", "data") if multi_pod else ("data",)
        inner: Tuple[Tuple[str, ...], ...] = (("model",),)
        batch_axes: Tuple[str, ...] = ()
    elif mode == "pods":
        worker = ("pod",) if multi_pod else ()
        inner = (("data",), ("model",))
        batch_axes = ("data",)
    elif mode == "global":
        worker = ()
        inner = ((("pod", "data") if multi_pod else ("data",)), ("model",))
        inner = tuple(tuple(g) if isinstance(g, tuple) else (g,)
                      for g in inner)
        batch_axes = ("pod", "data") if multi_pod else ("data",)
    elif mode == "axis":
        # comm='axis': a dedicated worker axis; inner tensor sharding on
        # 'model' when the mesh has one (make_worker_mesh(model=...))
        if WORKER_AXIS not in mesh.shape:
            raise ValueError(
                f"mode='axis' needs a {WORKER_AXIS!r} mesh axis; "
                f"mesh has {tuple(mesh.shape)}")
        worker = (WORKER_AXIS,)
        inner = ((("model",),) if "model" in mesh.shape else ())
        batch_axes = ()
    else:
        raise ValueError(f"unknown worker mode {mode!r}")
    # serving: no worker dim; small archs keep params TP-only, big archs FSDP
    if mode == "stacked":
        serve_groups: Tuple[Tuple[str, ...], ...] = (("model",),)
    elif mode == "axis":
        serve_groups = (("model",),) if "model" in mesh.shape else ()
    else:
        serve_groups = ((("pod", "data") if multi_pod else ("data",)),
                        ("model",))
        serve_groups = tuple(tuple(g) if isinstance(g, tuple) else (g,)
                             for g in serve_groups)
    if mode == "axis":
        serve_batch: Tuple[str, ...] = (WORKER_AXIS,)
    else:
        serve_batch = ("pod", "data") if multi_pod else ("data",)
    return ShardingPlan(mesh, mode, multi_pod, worker, inner, batch_axes,
                        serve_groups, serve_batch, arch.model)


# ------------------------------ rule engine ----------------------------------


def _assign_groups(shape: Sequence[int],
                   groups: Sequence[Tuple[str, ...]],
                   mesh: Mesh,
                   skip: Sequence[int] = ()) -> List[Any]:
    """Greedy dim->axis-group assignment. Returns PartitionSpec entries."""
    entries: List[Any] = [None] * len(shape)
    taken = set(skip)
    sizes = {g: int(np.prod([mesh.shape[a] for a in g])) for g in groups}
    for g in sorted(groups, key=lambda g: -sizes[g]):
        cand = [(d, shape[d]) for d in range(len(shape))
                if d not in taken and shape[d] % sizes[g] == 0
                and shape[d] >= sizes[g] and sizes[g] > 1]
        if not cand:
            continue
        d = max(cand, key=lambda t: t[1])[0]
        entries[d] = g if len(g) > 1 else g[0]
        taken.add(d)
    return entries


def _path_names(path) -> List[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return out


def _head_aware_rule(plan: ShardingPlan, leaf: str) -> str:
    """'col' (default greedy), 'row' (shard the input dim on 'model'), or
    'replicate' for the 'model' axis component of this leaf.

    Column-sharding an attention projection's fused (heads x head_dim)
    output dim is only sound when the head count divides the model axis —
    otherwise GSPMD splits head_dim and every score contraction becomes a
    partial-sum ALL-REDUCE OF THE SCORE TENSOR (measured 2.3 TB/step on
    llama3.2-1b prefill_32k; see EXPERIMENTS.md perf iteration 1). Same
    story for RWKV's per-head projections and Mamba's segmented in_proj
    (whose z/xBC/dt split crosses shard boundaries).
    """
    cfg = plan.model_cfg
    if cfg is None:
        return "col"
    msz = plan.mesh.shape.get("model", 1)
    if msz <= 1:
        return "col"
    hd = cfg.resolved_head_dim if cfg.n_heads else 0
    # Only intervene where measured to help (§Perf iterations 1 and 7):
    # 1. small GQA K/V projections with kv_heads not dividing the model
    #    axis are REPLICATED (removes the per-layer partial-sum all-reduce
    #    and, crucially, the head_dim-split that turned every score
    #    contraction into a TB-scale all-reduce);
    # 2. everything else keeps the greedy layout — forcing row-parallel on
    #    MHA-sized projections measurably regressed qwen1.5-32b (Tc 19->50,
    #    iteration 7's refuted branch, kept in the log).
    if leaf in ("wk", "wv"):
        if cfg.n_kv_heads % msz == 0:
            return "col"
        return ("replicate" if cfg.n_kv_heads * hd * 2 <= cfg.d_model
                else "col")
    if leaf in ("bk", "bv") and cfg.n_kv_heads % msz != 0:
        return "replicate"
    if leaf in ("u", "gn", "gn_b") and cfg.family == "ssm":
        return "replicate"
    if leaf in ("in_proj", "out_proj") and cfg.family in ("hybrid",):
        return "row"
    if leaf in ("conv_w", "conv_b", "A_log", "D", "dt_bias") \
            and cfg.family in ("hybrid",):
        return "replicate"
    return "col"


def param_pspec(plan: ShardingPlan, path, shape: Tuple[int, ...],
                *, stacked: bool, serve: bool = False) -> P:
    """PartitionSpec for a parameter/optimizer-state leaf.

    stacked=True: leaf has a leading worker dim (training state).
    serve=True: use the serving groups and no worker dim.
    """
    if len(shape) == 0:
        return P()
    names = _path_names(path)
    entries: List[Any] = []
    skip = []
    d0 = 0
    if stacked and not serve:
        wa = plan.worker_axes
        if wa and shape[0] % plan.K == 0 and plan.K > 1:
            entries.append(tuple(wa) if len(wa) > 1 else wa[0])
        else:
            entries.append(None)
        d0 = 1
    if any(k in names for k in _LAYER_STACK_KEYS) and len(shape) > d0:
        skip.append(d0)
    groups = plan.serve_groups if serve else plan.inner_groups
    rule = _head_aware_rule(plan, names[-1] if names else "")
    inner_shape = shape[d0:]
    inner_skip = [s - d0 for s in skip]
    if rule == "replicate":
        groups = tuple(g for g in groups if "model" not in g)
        inner = _assign_groups(inner_shape, groups, plan.mesh,
                               skip=inner_skip)
    elif rule == "row" and len(inner_shape) - len(inner_skip) >= 2:
        # force 'model' onto the matrix input dim (first non-skipped dim)
        msz = plan.mesh.shape.get("model", 1)
        row_dim = next(i for i in range(len(inner_shape))
                       if i not in inner_skip)
        inner = [None] * len(inner_shape)
        extra_skip = list(inner_skip)
        if inner_shape[row_dim] % msz == 0 and msz > 1:
            inner[row_dim] = "model"
            extra_skip.append(row_dim)
        rest_groups = tuple(g for g in groups if "model" not in g)
        rest = _assign_groups(inner_shape, rest_groups, plan.mesh,
                              skip=extra_skip)
        inner = [a if a is not None else b for a, b in zip(inner, rest)]
    else:
        inner = _assign_groups(inner_shape, groups, plan.mesh,
                               skip=inner_skip)
    return P(*(entries + list(inner)))


def tree_shardings(plan: ShardingPlan, sds_tree: PyTree, *, stacked: bool,
                   serve: bool = False) -> PyTree:
    """NamedShardings for a whole state/param ShapeDtypeStruct tree."""

    def rule(path, leaf):
        spec = param_pspec(plan, path, leaf.shape, stacked=stacked,
                           serve=serve)
        return NamedSharding(plan.mesh, spec)

    return jax.tree_util.tree_map_with_path(rule, sds_tree)


def loss_param_constraints(plan: ShardingPlan, params: PyTree) -> PyTree:
    """Thread the plan's head-aware ``param_pspec`` rules into a loss:
    apply each stacked parameter leaf's PartitionSpec as an in-graph
    sharding constraint. This is how the grad pipeline's packed-GSPMD 2D
    path (``train.grad``, ``mode='axis'`` plans) keeps matmul operands
    ``P(..., 'model')`` through the differentiate-through-unpack loss
    instead of letting GSPMD replicate whole per-worker parameter sets."""

    def one(path, leaf):
        spec = param_pspec(plan, path, tuple(leaf.shape), stacked=True)
        return jax.lax.with_sharding_constraint(
            leaf, NamedSharding(plan.mesh, spec))

    return jax.tree_util.tree_map_with_path(one, params)


# ------------------------------ batch specs ----------------------------------


def train_batch_pspec(plan: ShardingPlan, shape: Tuple[int, ...]) -> P:
    """Batch leaves are (p, K, b, ...): p unsharded, K on worker axes, b on
    plan.batch_axes (when divisible)."""
    entries: List[Any] = [None]
    wa = plan.worker_axes
    if wa and plan.K > 1 and shape[1] % plan.K == 0:
        entries.append(tuple(wa) if len(wa) > 1 else wa[0])
    else:
        entries.append(None)
    ba = tuple(a for a in plan.batch_axes if a not in wa)
    bsz = int(np.prod([plan.mesh.shape[a] for a in ba])) if ba else 1
    if ba and shape[2] % bsz == 0 and bsz > 1:
        entries.append(tuple(ba) if len(ba) > 1 else ba[0])
    else:
        entries.append(None)
    entries.extend([None] * (len(shape) - 3))
    return P(*entries)


def serve_batch_pspec(plan: ShardingPlan, shape: Tuple[int, ...],
                      *, seq_dim: Optional[int] = None) -> P:
    """Serve-side tensors: batch dim 0 over serve axes; if batch is too
    small (long-context B=1), shard ``seq_dim`` over the 'data' axes
    instead (sequence-parallel cache)."""
    entries: List[Any] = [None] * len(shape)
    ba = plan.serve_batch_axes
    bsz = plan.axis_size(ba)
    if shape and shape[0] % bsz == 0 and shape[0] >= bsz and bsz > 1:
        entries[0] = tuple(ba) if len(ba) > 1 else ba[0]
    elif seq_dim is not None and shape[seq_dim] % bsz == 0:
        entries[seq_dim] = tuple(ba) if len(ba) > 1 else ba[0]
    return P(*entries)


def cache_shardings(plan: ShardingPlan, cache_sds: PyTree) -> PyTree:
    """KV/SSM cache shardings: leaves are (L_or_sites, B, S_or_state...).

    dim0 (layer stack) stays local; batch (dim1) over serve axes when
    divisible, else the sequence dim (dim2, when present) is sharded —
    the sequence-parallel long-context decode path. A trailing dim
    divisible by 'model' is sharded over 'model'."""
    mesh = plan.mesh
    ba = plan.serve_batch_axes
    bsz = plan.axis_size(ba)
    model = mesh.shape["model"]

    def rule(path, leaf):
        shape = leaf.shape
        if len(shape) <= 1:
            return NamedSharding(mesh, P())
        entries: List[Any] = [None] * len(shape)
        batch_ok = shape[1] % bsz == 0 and shape[1] >= bsz and bsz > 1
        if batch_ok:
            entries[1] = tuple(ba) if len(ba) > 1 else ba[0]
        elif len(shape) >= 3:
            # sequence-parallel: shard the biggest middle dim on 'data'
            data_axes = tuple(a for a in ba if a != "model")
            dsz = plan.axis_size(data_axes)
            if len(shape) >= 3 and shape[2] % dsz == 0 and dsz > 1:
                entries[2] = (tuple(data_axes) if len(data_axes) > 1
                              else data_axes[0])
        # one trailing dim on 'model'
        for d in range(len(shape) - 1, 1, -1):
            if entries[d] is None and shape[d] % model == 0 \
                    and shape[d] >= model and model > 1 and d != 2:
                entries[d] = "model"
                break
        return NamedSharding(mesh, P(*entries))

    return jax.tree_util.tree_map_with_path(rule, cache_sds)
