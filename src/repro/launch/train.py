"""Training driver: decentralized D-Adam / CD-Adam training of any
registered architecture on host devices.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --reduced --workers 4 --steps 50 --optimizer cd-adam --period 4

Uses the reduced config by default on CPU; pass --full on real hardware.
Checkpoints every --ckpt-every steps via repro.checkpoint.
"""
from __future__ import annotations

import argparse
import time

if __name__ == "__main__":
    # host-device count + async-collective XLA flags must land BEFORE
    # jax initializes; repro.launch.env appends to any pre-set XLA_FLAGS
    from repro.launch import env as _env
    _env.setup()

import jax
import jax.numpy as jnp

from repro.checkpoint import save
from repro.configs import get_arch, get_reduced, list_archs
from repro.core import make_optimizer
from repro.data import lm_batch
from repro.launch.mesh import make_worker_mesh
from repro.launch.shardings import make_plan
from repro.models import build_model
from repro.train import DecentralizedTrainer


def make_batch_iter(cfg, K: int, per_worker: int, seq: int, skew: float):
    key = jax.random.PRNGKey(42)
    t = 0
    while True:
        kt = jax.random.fold_in(key, t)
        toks = jnp.stack([
            lm_batch(kt, per_worker, seq, cfg.vocab_size, k, K, skew)
            for k in range(K)])
        batch = {"tokens": toks}
        if cfg.family == "vlm":
            batch["patches"] = jax.random.normal(
                kt, (K, per_worker, cfg.n_patches, 1024), jnp.float32)
        if cfg.family == "audio":
            batch["audio_embeds"] = jax.random.normal(
                kt, (K, per_worker, cfg.n_audio_ctx, cfg.d_model),
                jnp.float32)
        yield batch
        t += 1


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=list_archs())
    ap.add_argument("--full", action="store_true",
                    help="full config (needs real hardware)")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=2, help="per worker")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--optimizer", default="d-adam",
                    choices=["d-adam", "cd-adam", "d-psgd"])
    ap.add_argument("--period", type=int, default=4)
    ap.add_argument("--compressor", default="sign")
    ap.add_argument("--gamma", type=float, default=0.4)
    ap.add_argument("--eta", type=float, default=1e-3)
    ap.add_argument("--topology", default="ring",
                    help="static graph (ring/torus/full/...) or a "
                         "time-varying schedule spec: "
                         "'one-peer-exponential', 'randomized-rings:N'")
    ap.add_argument("--staleness", type=int, default=None,
                    help="straggler tolerance tau: gossip may consume "
                         "payloads up to tau rounds old before blocking "
                         "on a fresh exchange (0 = synchronous semantics "
                         "with the buffers wired in)")
    ap.add_argument("--straggler-rate", type=float, default=0.0,
                    help="simulated straggler probability per edge per "
                         "round (requires --staleness >= 1)")
    ap.add_argument("--straggler-seed", type=int, default=0)
    ap.add_argument("--overlap", action="store_true",
                    help="overlap gossip with the local Adam steps: round "
                         "r's exchange is issued eagerly and folded in at "
                         "round r+1 (a delay-1 wire schedule, i.e. "
                         "staleness tau=1 on the wire with every edge "
                         "exactly one round late); mutually exclusive "
                         "with --staleness")
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "pallas"],
                    help="optimizer execution backend (pallas = fused "
                         "kernels; interpret mode off-TPU)")
    ap.add_argument("--comm", default="stacked",
                    choices=["stacked", "axis"],
                    help="worker execution: 'stacked' runs the worker dim "
                         "in one program; 'axis' shards it over a "
                         "'worker' mesh axis (one device group per "
                         "worker) and gossips with ppermute inside "
                         "shard_map — needs >= --workers devices")
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="with --comm axis --backend pallas: inner "
                         "model-parallel group size M per worker (2D "
                         "worker x model mesh; the packed state's row dim "
                         "is sharded M-ways, gossip still crosses only "
                         "the worker axis) — needs workers * M devices")
    ap.add_argument("--microbatch", type=int, default=1,
                    help="gradient-accumulation microbatches per step "
                         "(must divide --batch); divides activation "
                         "memory by this factor in every backend")
    ap.add_argument("--damping", default="",
                    help="adaptive batch damping policy spec: "
                         "'adadamp:MAX[:EMA]', 'padadamp:MAX[:RATE]' or "
                         "'geodamp:MAX[:FACTOR[:DELAY]]' — grows the "
                         "gradient-accumulation chunk count as the loss "
                         "falls (MAX must divide --batch); one compiled "
                         "step serves every damping level. Mutually "
                         "exclusive with --microbatch > 1")
    ap.add_argument("--damping-per-worker", action="store_true",
                    help="one damping signal per worker (non-IID shards) "
                         "instead of the global mean-loss signal")
    ap.add_argument("--damping-lr-decay", type=float, default=0.5,
                    help="eta decay factor applied once the batch hits "
                         "the damping ceiling (with --damping-lr-decay-"
                         "every > 0)")
    ap.add_argument("--damping-lr-decay-every", type=int, default=0,
                    help="decay eta every N steps spent with every "
                         "worker at max_chunks (0 = off)")
    ap.add_argument("--skew", type=float, default=0.5,
                    help="non-IID-ness of worker shards")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    arch = get_arch(args.arch) if args.full else get_reduced(args.arch)
    cfg = arch.model
    api = build_model(cfg)
    mesh = None
    if args.model_parallel > 1 and args.comm != "axis":
        raise SystemExit("--model-parallel > 1 requires --comm axis "
                         "(the 2D worker x model mesh)")
    if args.model_parallel > 1 and args.backend != "pallas":
        raise SystemExit("--model-parallel > 1 requires --backend pallas "
                         "(it shards the packed row dim)")
    if args.comm == "axis":
        need = args.workers * args.model_parallel
        if jax.device_count() < need:
            raise SystemExit(
                f"--comm axis needs workers * model_parallel devices: "
                f"have {jax.device_count()} devices for --workers "
                f"{args.workers} x --model-parallel "
                f"{args.model_parallel} (on CPU, set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={need})")
        mesh = make_worker_mesh(args.workers,
                                model_parallel=args.model_parallel)
    opt = make_optimizer(args.optimizer, K=args.workers, eta=args.eta,
                         period=args.period, topology=args.topology,
                         gamma=args.gamma, compressor=args.compressor,
                         backend=args.backend, comm=args.comm, mesh=mesh,
                         staleness=args.staleness,
                         straggler_rate=args.straggler_rate,
                         straggler_seed=args.straggler_seed,
                         overlap=args.overlap)
    # 2D mesh: thread the head-aware mode='axis' sharding rules into the
    # loss (grad pipeline packed-GSPMD path) so matmul operands stay
    # P(..., 'model') instead of replicating whole per-worker param sets
    plan = (make_plan(arch, mesh, multi_pod=False, mode="axis")
            if args.model_parallel > 1 else None)
    damping = None
    if args.damping:
        import dataclasses as _dc

        from repro.train import make_damping
        damping = _dc.replace(
            make_damping(args.damping),
            per_worker=args.damping_per_worker,
            lr_decay=args.damping_lr_decay,
            lr_decay_every=args.damping_lr_decay_every)
        if args.batch % damping.max_chunks:
            raise SystemExit(
                f"--damping max_chunks {damping.max_chunks} must divide "
                f"--batch {args.batch}")
    trainer = DecentralizedTrainer(lambda p, b: api.loss(p, b), opt,
                                   microbatch=args.microbatch, plan=plan,
                                   damping=damping,
                                   sharded_loss=getattr(api, "sharded_loss",
                                                        None))
    params = api.init(jax.random.PRNGKey(0))
    state = trainer.init(params)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"[train] {args.arch} ({'full' if args.full else 'reduced'}) "
          f"N={n_params/1e6:.1f}M x {args.workers} workers "
          f"opt={args.optimizer} p={args.period} "
          f"topo={args.topology} backend={args.backend} comm={args.comm}"
          + (" overlap" if args.overlap else ""))
    if args.comm == "axis":
        print(f"[train] worker mesh: {tuple(mesh.shape.items())} — state "
              f"sharded one worker per slot; gossip = ppermute over "
              f"'worker'")
        if args.model_parallel > 1:
            print(f"[train] 2D execution: each worker = "
                  f"{args.model_parallel}-device model-parallel group; "
                  f"packed rows sharded P('worker', 'model'); compression "
                  f"scales psum over 'model'")
    if args.backend == "pallas":
        # packed-resident state: params + moments live in the stacked
        # (K, rows, 128) kernel layout across steps; grads are produced
        # packed by differentiating through the unpack view, and
        # checkpoints are stored in the portable (backend-agnostic) form.
        spec = state.spec
        print(f"[train] resident packed state: K={spec.k} "
              f"rows={spec.rows} ({spec.rows * 128 / 1e6:.2f}M slots/"
              f"worker, {spec.n / 1e6:.2f}M live; "
              f"{(spec.rows * 128 - spec.n) / max(spec.rows * 128, 1):.1%} "
              f"tile padding)")

    if damping is not None:
        print(f"[train] batch damping: {damping.policy} chunks "
              f"{damping.min_chunks}..{damping.max_chunks} "
              f"({'per-worker' if damping.per_worker else 'global'} "
              f"signal); one compiled step across all levels")

    it = make_batch_iter(cfg, args.workers, args.batch, args.seq, args.skew)
    t0 = time.perf_counter()
    done = 0
    log = None
    while done < args.steps:
        n = min(args.log_every, args.steps - done)
        # the log CONTINUES across fit calls: comm_mb / wall_s / grad
        # evals are cumulative, and schedule-entry comm accounting stays
        # aligned round to round
        state, log = trainer.fit(state, it, n, log_every=n, log=log)
        done += n
        print(f"[train] step {done:5d} loss={log.loss[-1]:.4f} "
              f"consensus={log.consensus[-1]:.3e} "
              f"comm={log.comm_mb[-1]:.1f}MB "
              f"evals={log.grad_evals[-1]} "
              f"({(time.perf_counter() - t0) / done * 1e3:.0f} ms/step)")
        if args.ckpt and args.ckpt_every and done % args.ckpt_every == 0:
            save(args.ckpt, state, step=done,
                 meta={"arch": args.arch, "optimizer": args.optimizer})
            print(f"[train] checkpointed -> {args.ckpt}")
    if args.ckpt:
        save(args.ckpt, state, step=done,
             meta={"arch": args.arch, "optimizer": args.optimizer})
        print(f"[train] final checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
