from repro.launch import env as _env

_env.setup(512)

# NOTE: the lines above MUST run before any jax-importing module — jax
# locks the device count at first init. Do not reorder. A pre-set
# XLA_FLAGS host-device count wins (repro.launch.env appends, never
# clobbers); without one the multi-pod dry-run gets 512 virtual devices.

import argparse
import os
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.analysis.hlo import analyze
from repro.analysis.invariants import (InvariantSpec, InvariantViolation,
                                       evaluate_hlo)
from repro.analysis.roofline import from_artifact, model_flops_for
from repro.configs import (INPUT_SHAPES, SKIPS, get_arch, list_archs)
from repro.configs.base import ArchConfig, InputShape
from repro.core import make_optimizer
from repro.launch.mesh import make_production_mesh, n_chips
from repro.launch.shardings import (ShardingPlan, cache_shardings, make_plan,
                                    serve_batch_pspec, train_batch_pspec,
                                    tree_shardings)
from repro.models import build_model
from repro.serve.engine import cache_spec, effective_config, kv_cache_len

PyTree = Any


# ------------------------------ input specs ----------------------------------


def train_batch_sds(arch: ArchConfig, shape: InputShape, K: int,
                    p: int) -> PyTree:
    """ShapeDtypeStruct stand-ins for one communication round of batches:
    every leaf is (p, K, per_worker, ...)."""
    cfg = arch.model
    b = shape.global_batch // K
    assert b * K == shape.global_batch, (
        f"global_batch {shape.global_batch} not divisible by K={K}")
    S = shape.seq_len
    tok = lambda s: jax.ShapeDtypeStruct((p, K, b, s), jnp.int32)
    if cfg.family == "vlm":
        s_txt = S - cfg.n_patches
        return {"tokens": tok(s_txt + 1),
                "patches": jax.ShapeDtypeStruct(
                    (p, K, b, cfg.n_patches, 1024), jnp.bfloat16)}
    if cfg.family == "audio":
        return {"tokens": tok(S + 1),
                "audio_embeds": jax.ShapeDtypeStruct(
                    (p, K, b, cfg.n_audio_ctx, cfg.d_model), jnp.bfloat16)}
    return {"tokens": tok(S + 1)}


def serve_batch_sds(arch: ArchConfig, shape: InputShape) -> PyTree:
    cfg = arch.model
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        return {"tokens": jax.ShapeDtypeStruct((B, S - cfg.n_patches),
                                               jnp.int32),
                "patches": jax.ShapeDtypeStruct((B, cfg.n_patches, 1024),
                                                jnp.bfloat16)}
    if cfg.family == "audio":
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "audio_embeds": jax.ShapeDtypeStruct(
                    (B, cfg.n_audio_ctx, cfg.d_model), jnp.bfloat16)}
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}


def input_specs(arch: ArchConfig, shape_name: str, *, K: int = 1,
                p: int = 1) -> PyTree:
    """Public helper (brief step 2): ShapeDtypeStruct stand-ins for every
    model input of the given input shape."""
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "train":
        return train_batch_sds(arch, shape, K, p)
    if shape.kind == "prefill":
        return serve_batch_sds(arch, shape)
    cfg = effective_config(arch.model, shape)
    B = shape.global_batch
    return {
        "token": jax.ShapeDtypeStruct((B,), jnp.int32),
        "cache": cache_spec(cfg, B, shape.seq_len),
    }


# ------------------------------ step builders --------------------------------


def build_train(arch: ArchConfig, plan: ShardingPlan, shape: InputShape,
                optimizer: Optional[str] = None,
                mixing: Optional[str] = None,
                microbatch: Optional[int] = None):
    cfg = arch.model
    par = arch.parallel
    api = build_model(cfg)
    K = max(plan.K, 1)
    opt = make_optimizer(
        optimizer or par.optimizer, K=K, topology=par.topology,
        period=par.period, eta=par.eta, tau=par.tau, gamma=par.gamma,
        compressor=par.compressor, mixing=mixing or par.mixing,
        moment_dtype=par.moment_dtype, weight_decay=par.weight_decay)

    def init_all():
        params = api.init(jax.random.PRNGKey(0))
        stacked = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (K,) + x.shape), params)
        return opt.init(stacked)

    state_sds = jax.eval_shape(init_all)
    state_sh = tree_shardings(plan, state_sds, stacked=True)
    batch_sds = train_batch_sds(arch, shape, K, par.period)
    batch_sh = jax.tree_util.tree_map(
        lambda l: jax.sharding.NamedSharding(
            plan.mesh, train_batch_pspec(plan, l.shape)), batch_sds)

    remat = par.remat

    def loss(params, batch):
        return api.loss(params, batch, remat=remat)

    # spmd_axis_name lets with_sharding_constraint inside the per-worker
    # loss lift across the vmapped worker dim (activation sharding hints).
    wa = plan.worker_axes if plan.K > 1 else ()
    spmd = (tuple(wa) if len(wa) > 1 else wa[0]) if wa else None
    from repro.train.grad import make_worker_grad
    worker_grad = make_worker_grad(loss, microbatch or par.microbatch)

    if spmd is not None:
        vgrad = jax.vmap(worker_grad, spmd_axis_name=spmd)
    else:
        vgrad = jax.vmap(worker_grad)

    def grad_fn(params_stacked, batch):
        return vgrad(params_stacked, batch)

    def train_round(state, batches):
        return opt.round(state, grad_fn, batches)

    return train_round, (state_sds, batch_sds), (state_sh, batch_sh), state_sh


def build_prefill(arch: ArchConfig, plan: ShardingPlan, shape: InputShape):
    cfg = effective_config(arch.model, shape)
    api = build_model(cfg)
    cache_len = kv_cache_len(cfg, shape.seq_len)

    def init_params():
        return api.init(jax.random.PRNGKey(0))

    params_sds = jax.eval_shape(init_params)
    params_sh = tree_shardings(plan, params_sds, stacked=False, serve=True)
    batch_sds = serve_batch_sds(arch, shape)
    batch_sh = jax.tree_util.tree_map(
        lambda l: jax.sharding.NamedSharding(
            plan.mesh, serve_batch_pspec(plan, l.shape)), batch_sds)

    def prefill_fn(params, batch):
        return api.prefill(params, batch, cache_len=cache_len)

    # output shardings: logits + cache
    out_sds = jax.eval_shape(prefill_fn, params_sds, batch_sds)
    logits_sh = jax.tree_util.tree_map(
        lambda l: jax.sharding.NamedSharding(
            plan.mesh, serve_batch_pspec(plan, l.shape)), out_sds[0])
    cache_sh = cache_shardings(plan, out_sds[1])
    return (prefill_fn, (params_sds, batch_sds), (params_sh, batch_sh),
            (logits_sh, cache_sh))


def build_decode(arch: ArchConfig, plan: ShardingPlan, shape: InputShape):
    cfg = effective_config(arch.model, shape)
    api = build_model(cfg)
    B = shape.global_batch

    def init_params():
        return api.init(jax.random.PRNGKey(0))

    params_sds = jax.eval_shape(init_params)
    params_sh = tree_shardings(plan, params_sds, stacked=False, serve=True)
    cache_sds = cache_spec(cfg, B, shape.seq_len)
    cache_sh = cache_shardings(plan, cache_sds)
    token_sds = jax.ShapeDtypeStruct((B,), jnp.int32)
    token_sh = jax.sharding.NamedSharding(
        plan.mesh, serve_batch_pspec(plan, token_sds.shape))

    def serve_step(params, cache, token):
        return api.decode_step(params, cache, token)

    out_sds = jax.eval_shape(serve_step, params_sds, cache_sds, token_sds)
    logits_sh = jax.sharding.NamedSharding(
        plan.mesh, serve_batch_pspec(plan, out_sds[0].shape))
    out_cache_sh = cache_shardings(plan, out_sds[1])
    return (serve_step, (params_sds, cache_sds, token_sds),
            (params_sh, cache_sh, token_sh), (logits_sh, out_cache_sh))


# --------------------------------- driver ------------------------------------


def dryrun_one(arch_id: str, shape_name: str, *, multi_pod: bool = False,
               optimizer: Optional[str] = None, mixing: Optional[str] = None,
               mode: Optional[str] = None, period: Optional[int] = None,
               remat: Optional[str] = None, microbatch: Optional[int] = None,
               out_dir: str = "artifacts/dryrun",
               tag: str = "", verbose: bool = True,
               budget_mb: Optional[float] = None,
               strict_invariants: bool = False) -> Dict[str, Any]:
    if (arch_id, shape_name) in SKIPS:
        return {"arch": arch_id, "shape": shape_name, "skipped": True,
                "reason": SKIPS[(arch_id, shape_name)]}
    t0 = time.time()
    arch = get_arch(arch_id)
    if period is not None or remat is not None:
        par = arch.parallel
        if period is not None:
            par = dataclasses.replace(par, period=period)
        if remat is not None:
            par = dataclasses.replace(par, remat=remat)
        arch = dataclasses.replace(arch, parallel=par)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = make_plan(arch, mesh, multi_pod=multi_pod, mode=mode)

    if shape.kind == "train":
        fn, sds, in_sh, out_sh = build_train(arch, plan, shape,
                                             optimizer=optimizer,
                                             mixing=mixing,
                                             microbatch=microbatch)
    elif shape.kind == "prefill":
        fn, sds, in_sh, out_sh = build_prefill(arch, plan, shape)
    else:
        fn, sds, in_sh, out_sh = build_decode(arch, plan, shape)

    from repro.models import attention as _attn
    act_ctx = (_attn.activation_sharding(mesh, plan.serve_batch_axes)
               if shape.kind != "train"
               else _attn.activation_sharding(mesh, ()))
    with mesh, act_ctx:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*sds)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    # cost_analysis() returns a per-module list of dicts on some jax
    # versions and a flat dict on others
    cost_raw = compiled.cost_analysis() or {}
    if isinstance(cost_raw, (list, tuple)):
        cost_raw = cost_raw[0] if cost_raw else {}
    cost_raw = dict(cost_raw)
    hlo = compiled.as_text()
    hc = analyze(hlo)
    coll = hc.as_dict()
    counts = {k: int(v) for k, v in hc.coll_counts.items()}

    # Declarative invariant report over the compiled HLO: always checks
    # the byte-accounting dtype coverage (INV005); --budget-mb adds a
    # total-collective-bytes budget (INV002, "*" kind). Informational
    # per-kind summary rows print under --verbose either way.
    spec = InvariantSpec(
        name=f"{arch_id}/{shape_name}",
        collective_bytes=({"*": int(budget_mb * 1e6)}
                          if budget_mb is not None else {}),
        allow_unknown_dtypes=False)
    inv = evaluate_hlo(hlo, spec)

    cfg = arch.model
    if shape.kind == "train":
        tokens = arch.parallel.period * shape.global_batch * shape.seq_len
        mflops = model_flops_for(cfg.active_param_count(), tokens, "train")
    elif shape.kind == "prefill":
        mflops = model_flops_for(cfg.active_param_count(),
                                 shape.global_batch * shape.seq_len, "serve")
    else:
        mflops = model_flops_for(cfg.active_param_count(),
                                 shape.global_batch, "serve")

    def _mem_attr(name):
        v = getattr(mem, name, None)
        return int(v) if v is not None else None

    art = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips(mesh),
        "mode": plan.mode, "K": plan.K,
        "optimizer": optimizer or arch.parallel.optimizer,
        "mixing": mixing or arch.parallel.mixing,
        "period": arch.parallel.period,
        "remat": arch.parallel.remat,
        # trip-count-aware analyzer values (see repro.analysis.hlo);
        # cost_raw keeps XLA's cost_analysis (undercounts while bodies).
        "cost": {"flops": float(hc.flops), "bytes accessed": float(hc.bytes),
                 "unknown_trip_counts": hc.unknown_trip_counts},
        "cost_raw": {k: float(v) for k, v in cost_raw.items()
                     if isinstance(v, (int, float))
                     and k in ("flops", "bytes accessed",
                               "bytes accessed output", "utilization")},
        "collectives": coll,
        "collective_counts": counts,
        "memory": {
            "argument_bytes": _mem_attr("argument_size_in_bytes"),
            "output_bytes": _mem_attr("output_size_in_bytes"),
            "temp_bytes": _mem_attr("temp_size_in_bytes"),
            "generated_code_bytes": _mem_attr(
                "generated_code_size_in_bytes"),
        },
        "model_flops": mflops,
        "invariants": {"ok": inv.ok, "failed_rules": inv.failed_rules(),
                       "summary": inv.summary},
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "tag": tag,
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        fname = (f"{arch_id.replace('.', '_')}_{shape_name}_"
                 f"{art['mesh'].replace('x', '')}{suffix}.json")
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(art, f, indent=1)
    if verbose:
        r = from_artifact(art)
        print(f"[dryrun] {arch_id} x {shape_name} ({art['mesh']}, "
              f"mode={plan.mode}, K={plan.K}) OK  "
              f"lower={t_lower:.0f}s compile={t_compile:.0f}s  "
              f"Tc={r.t_compute:.2e} Tm={r.t_memory:.2e} "
              f"Tcoll={r.t_collective:.2e} bound={r.bottleneck} "
              f"useful={r.usefulness:.2f}")
        print(inv.format(verbose=True))
    if strict_invariants and not inv.ok:
        raise InvariantViolation(inv)
    return art


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None, choices=list_archs() + [None])
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all (arch x shape) combos")
    ap.add_argument("--optimizer", default=None)
    ap.add_argument("--mixing", default=None, choices=[None, "roll", "dense"])
    ap.add_argument("--mode", default=None,
                    choices=[None, "stacked", "pods", "global"])
    ap.add_argument("--period", type=int, default=None)
    ap.add_argument("--remat", default=None,
                    choices=[None, "none", "dots", "full"])
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--budget-mb", type=float, default=None,
                    help="total collective-byte budget per step (MB); "
                         "violations fail the run (INV002)")
    ap.add_argument("--strict-invariants", action="store_true",
                    help="fail the run on any invariant violation "
                         "(otherwise the report is informational)")
    args = ap.parse_args()

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for a in archs:
        for s in shapes:
            for mp in meshes:
                try:
                    dryrun_one(a, s, multi_pod=mp, optimizer=args.optimizer,
                               mixing=args.mixing, mode=args.mode,
                               period=args.period, remat=args.remat,
                               microbatch=args.microbatch,
                               out_dir=args.out, tag=args.tag,
                               budget_mb=args.budget_mb,
                               strict_invariants=(args.strict_invariants or
                                                  args.budget_mb is not None))
                except Exception as e:  # noqa: BLE001 — report-all driver
                    failures.append((a, s, mp, repr(e)))
                    print(f"[dryrun] {a} x {s} multi_pod={mp} FAILED: {e}")
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")
    print("[dryrun] all combinations lowered + compiled successfully")


if __name__ == "__main__":
    main()
