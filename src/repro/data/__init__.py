from repro.data.stream import ctr_stream, prefetch_to_device
from repro.data.synthetic import (CTRTask, ctr_batch, ctr_batch_stacked,
                                  image_batch, image_batch_stacked, lm_batch,
                                  lm_batches_stacked, make_ctr_task)

__all__ = ["CTRTask", "make_ctr_task", "ctr_batch", "ctr_batch_stacked",
           "lm_batch", "lm_batches_stacked", "image_batch",
           "image_batch_stacked", "ctr_stream", "prefetch_to_device"]
