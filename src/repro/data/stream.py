"""Device-prefetched streaming batches for online training and serving.

``prefetch_to_device`` is a double-buffered host→device pipeline:
``jax.device_put`` is asynchronous, so keeping ``size`` batches in flight
overlaps the next batches' host→device copies (and any host-side batch
synthesis) with the compute consuming the current one. ``ctr_stream`` is
the endless non-IID CTR stream the online train→serve loop and the
serving benchmark draw from — deterministic in ``(seed, step)``.
"""
from __future__ import annotations

import collections
from typing import Any, Iterator, Optional

import jax

from repro.data.synthetic import CTRTask, ctr_batch_stacked

PyTree = Any


def prefetch_to_device(it: Iterator[PyTree], size: int = 2, *,
                       sharding: Optional[Any] = None,
                       placer: Optional[Any] = None) -> Iterator[PyTree]:
    """Wrap a host batch iterator with an async device-transfer window.

    Pulls up to ``size`` batches ahead of the consumer and issues their
    ``jax.device_put`` immediately — the copies (and the host-side work
    of producing the next batches) run while the consumer computes on the
    current one. ``size=2`` is classic double buffering: one batch in
    use, one in flight.

    Args:
      it: host-side batch iterator (finite or endless).
      size: transfer window depth (>= 1).
      sharding: optional target sharding forwarded to ``device_put``
        (e.g. a worker-axis ``NamedSharding`` for comm='axis' batches).
      placer: alternative to ``sharding`` — a callable ``batch ->
        placed_batch`` (e.g. the trainer's ``_place_batch``); wins when
        both are given.

    Yields:
      The batches of ``it``, in order, already on device.
    """
    if size < 1:
        raise ValueError(f"prefetch size must be >= 1, got {size}")

    def put(batch: PyTree) -> PyTree:
        if placer is not None:
            return placer(batch)
        if sharding is not None:
            return jax.device_put(batch, sharding)
        return jax.device_put(batch)

    window: collections.deque = collections.deque()
    it = iter(it)
    try:
        while len(window) < size:
            window.append(put(next(it)))
    except StopIteration:
        pass
    while window:
        batch = window.popleft()
        try:
            window.append(put(next(it)))
        except StopIteration:
            pass
        yield batch


def ctr_stream(task: CTRTask, K: int, per_worker: int, *, seed: int = 1,
               skew: float = 0.5) -> Iterator[PyTree]:
    """Endless stacked non-IID CTR batches, deterministic in
    ``(seed, step)`` — step ``t`` is ``ctr_batch_stacked`` under
    ``fold_in(PRNGKey(seed), t)`` regardless of prefetch depth."""
    key = jax.random.PRNGKey(seed)
    t = 0
    while True:
        yield ctr_batch_stacked(task, jax.random.fold_in(key, t), K,
                                per_worker, skew)
        t += 1
