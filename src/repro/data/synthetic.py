"""Synthetic data generators with non-IID worker sharding.

The paper's setting: K workers, each with its *own* data distribution
D^(k) (Section 3.1). We provide:

* token streams for LM training — a mixture of per-worker Markov chains so
  worker distributions genuinely differ (Dirichlet-controlled skew);
* CTR-style sparse categorical data (Criteo/MovieLens analogue) with a
  planted factorization-machine teacher so AUC is meaningful;
* CIFAR-like images with a planted linear-ish teacher.

Everything is jax.random-based, deterministic in (seed, worker, step).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


# ----------------------------- LM token streams -----------------------------


def lm_batch(key: jax.Array, batch: int, seq_len: int, vocab: int,
             worker: int = 0, n_workers: int = 1,
             skew: float = 1.0) -> jax.Array:
    """(batch, seq_len+1) int32 tokens from a worker-specific bigram chain.

    Each worker's chain prefers a distinct vocab band — mild non-IID-ness
    controlled by ``skew`` (0 = IID uniform)."""
    k1, k2 = jax.random.split(jax.random.fold_in(key, worker))
    base = jax.random.randint(k1, (batch, seq_len + 1), 0, vocab)
    if skew <= 0 or n_workers <= 1:
        return base
    # shift a fraction of tokens into the worker's band
    band = vocab // n_workers
    lo = worker * band
    mask = jax.random.bernoulli(k2, 0.5 * min(skew, 1.0), base.shape)
    banded = lo + (base % jnp.maximum(band, 1))
    return jnp.where(mask, banded, base).astype(jnp.int32)


def lm_batches_stacked(key: jax.Array, p: int, K: int, per_worker: int,
                       seq_len: int, vocab: int,
                       skew: float = 1.0) -> jax.Array:
    """(p, K, per_worker, seq_len+1) — one communication round of batches."""
    out = np.zeros((p, K, per_worker, seq_len + 1), np.int32)
    for t in range(p):
        kt = jax.random.fold_in(key, t)
        for k in range(K):
            out[t, k] = np.asarray(lm_batch(kt, per_worker, seq_len, vocab,
                                            k, K, skew))
    return jnp.asarray(out)


# --------------------------- CTR sparse features -----------------------------


@dataclasses.dataclass(frozen=True)
class CTRTask:
    """A planted DeepFM-style teacher over sparse categorical fields."""
    n_features: int
    n_fields: int
    embed_dim: int
    teacher_embed: np.ndarray   # (n_features, embed_dim)
    teacher_linear: np.ndarray  # (n_features,)
    field_offsets: np.ndarray   # (n_fields,) feature-id range starts
    field_sizes: np.ndarray


def make_ctr_task(seed: int, n_fields: int = 13,
                  features_per_field: int = 100,
                  embed_dim: int = 10) -> CTRTask:
    rng = np.random.default_rng(seed)
    n_features = n_fields * features_per_field
    return CTRTask(
        n_features=n_features,
        n_fields=n_fields,
        embed_dim=embed_dim,
        teacher_embed=rng.normal(0, 0.3, (n_features, embed_dim)),
        teacher_linear=rng.normal(0, 0.3, (n_features,)),
        field_offsets=np.arange(n_fields) * features_per_field,
        field_sizes=np.full(n_fields, features_per_field),
    )


def ctr_batch(task: CTRTask, key: jax.Array, batch: int, worker: int = 0,
              n_workers: int = 1, skew: float = 0.5
              ) -> Dict[str, jax.Array]:
    """{'feat_ids': (B, F), 'label': (B,)}. Non-IID: each worker draws field
    values from a Zipf-reweighted slice of each field's vocabulary."""
    k1, k2 = jax.random.split(jax.random.fold_in(key, worker))
    F = task.n_fields
    u = jax.random.uniform(k1, (batch, F))
    if n_workers > 1 and skew > 0:
        # workers concentrate on different parts of each field's range
        center = (worker + 0.5) / n_workers
        u = (1 - skew) * u + skew * jnp.clip(
            center + 0.15 * jax.random.normal(k2, u.shape), 0, 0.999)
    sizes = jnp.asarray(task.field_sizes)
    offs = jnp.asarray(task.field_offsets)
    ids = (offs[None, :] + (u * sizes[None, :]).astype(jnp.int32))
    # teacher logit: FM(ids)
    emb = jnp.asarray(task.teacher_embed)[ids]
    lin = jnp.sum(jnp.asarray(task.teacher_linear)[ids], axis=-1)
    s = jnp.sum(emb, axis=1)
    s2 = jnp.sum(emb * emb, axis=1)
    logit = lin + 0.5 * jnp.sum(s * s - s2, axis=-1)
    prob = jax.nn.sigmoid(logit)
    label = jax.random.bernoulli(jax.random.fold_in(k2, 1), prob)
    return {"feat_ids": ids.astype(jnp.int32),
            "label": label.astype(jnp.int32)}


def ctr_batch_stacked(task: CTRTask, key: jax.Array, K: int,
                      per_worker: int, skew: float = 0.5
                      ) -> Dict[str, jax.Array]:
    batches = [ctr_batch(task, key, per_worker, k, K, skew)
               for k in range(K)]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *batches)


# ------------------------------ vision images --------------------------------


def image_batch(key: jax.Array, batch: int, n_classes: int = 10,
                worker: int = 0, n_workers: int = 1,
                skew: float = 0.5) -> Dict[str, jax.Array]:
    """CIFAR-shaped synthetic classification with class-prior skew per
    worker (Dirichlet-style non-IID-ness)."""
    k1, k2, k3 = jax.random.split(jax.random.fold_in(key, worker), 3)
    if n_workers > 1 and skew > 0:
        # worker k over-samples classes near (k mod n_classes)
        logits = -skew * 2.0 * jnp.square(
            (jnp.arange(n_classes) - (worker % n_classes) + n_classes / 2)
            % n_classes - n_classes / 2)
        label = jax.random.categorical(k1, logits, shape=(batch,))
    else:
        label = jax.random.randint(k1, (batch,), 0, n_classes)
    # class-conditional mean patterns + noise
    patterns = jax.random.normal(jax.random.PRNGKey(7),
                                 (n_classes, 32, 32, 3)) * 0.5
    images = patterns[label] + jax.random.normal(k2, (batch, 32, 32, 3))
    return {"images": images, "label": label.astype(jnp.int32)}


def image_batch_stacked(key: jax.Array, K: int, per_worker: int,
                        skew: float = 0.5) -> Dict[str, jax.Array]:
    batches = [image_batch(key, per_worker, 10, k, K, skew)
               for k in range(K)]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *batches)
