"""Checkpointing: dependency-free pytree save/restore with metadata.

Format: one ``.npz`` holding flattened leaves keyed by their tree path +
a JSON sidecar with the treedef / step / config hash. Atomic via
write-to-temp + rename. Works for optimizer states (NamedTuples) too.

Packed-resident optimizer states (``backend='pallas'``'s
``PackedDAdamState`` / ``PackedCDAdamState``) are transparently
**unpacked to their portable NamedTuple form on save and repacked on
restore**: the bytes on disk are always the backend-agnostic pytree
layout, so a checkpoint written under ``backend='pallas'`` restores
bit-identically under ``backend='reference'`` and vice versa. The
pack/unpack here is a checkpoint *boundary* — the steady-state training
loop never touches it.

Checkpoints are also **mesh/comm-portable**: ``save`` gathers sharded
leaves to host (a comm='axis' state sharded over a worker mesh writes the
same bytes as its single-device twin), and ``restore`` places every
restored leaf with the sharding of the corresponding ``like`` leaf — so a
stacked-comm checkpoint restores straight onto a comm='axis' worker mesh
and vice versa. Packed states are repacked into the *like-state's layout*
(including the row-sharded ``row_shards=M`` layout of a 2D worker × model
mesh), so a 1D-mesh checkpoint restores onto a 2D mesh and back,
bit-identically in the portable leaf values.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _packed_types() -> tuple:
    # lazy: keeps checkpoint importable without pulling the kernel stack
    from repro.core.cdadam import PackedCDAdamState
    from repro.core.dadam import PackedDAdamState
    return (PackedDAdamState, PackedCDAdamState)


def _is_packed(x: Any) -> bool:
    return isinstance(x, _packed_types())


def _has_transient(x: Any) -> bool:
    """Reference optimizer states carrying live straggler-comm buffers
    (D-Adam ``stale`` / CD-Adam ``pending``): transient by contract —
    stripped on save, rebuilt cold on restore."""
    from repro.core.cdadam import CDAdamState
    from repro.core.dadam import DAdamState
    return (isinstance(x, (DAdamState, CDAdamState))
            and x[-1] is not None)


def _needs_adapt(x: Any) -> bool:
    return _is_packed(x) or _has_transient(x)


def _sans_transient(x: Any) -> Any:
    from repro.core import cdadam, dadam
    if isinstance(x, dadam.PackedDAdamState):
        return x.with_stale(None)
    if isinstance(x, cdadam.PackedCDAdamState):
        return x.with_pending(None)
    if isinstance(x, dadam.DAdamState):
        return x._replace(stale=None)
    if isinstance(x, cdadam.CDAdamState):
        return x._replace(pending=None)
    return x


def _portable_of(x: Any) -> Any:
    """The backend-agnostic checkpoint form of one optimizer state:
    packed-resident states unpack (which drops transient buffers),
    reference states shed their transient field."""
    return x.unpacked() if _is_packed(x) else _sans_transient(x)


def _to_portable(tree: PyTree) -> PyTree:
    """Replace packed-resident optimizer states by their unpacked
    (backend-portable) NamedTuple equivalents and strip transient
    straggler-comm buffers, leaving the rest alone."""
    return jax.tree_util.tree_map(_portable_of, tree, is_leaf=_needs_adapt)


def _placed_like(arr: Any, ref: Any) -> Any:
    """Give a restored leaf the placement of its ``like`` counterpart, so
    restoring onto a sharded state (e.g. comm='axis' over a worker mesh)
    lands the data where the live state keeps it."""
    if isinstance(ref, jax.Array):
        return jax.device_put(arr, ref.sharding)
    return arr


def _cold_stale(st: Any) -> Any:
    """A COLD D-Adam staleness buffer shaped/placed like ``st``: zero
    payloads and ``COLD_AGE`` ages, so the first gossip round refuses the
    buffer and falls through to whatever arrives fresh."""
    from repro.core import dadam
    bufs = jax.tree_util.tree_map(
        lambda b: _placed_like(jnp.zeros_like(b), b), st.bufs)
    age = _placed_like(jnp.full_like(st.age, dadam.COLD_AGE), st.age)
    return dadam.StaleBufs(bufs, age)


def _cold_pending(pending: Any) -> Any:
    """COLD CD-Adam delay rings: all-zero payload slots, which decode to
    zero hat updates (sign(0) scale 0) until real traffic refills them."""
    return jax.tree_util.tree_map(
        lambda r: _placed_like(jnp.zeros_like(r), r), pending)


def _with_cold_transient(out: Any, orig: Any) -> Any:
    from repro.core import cdadam, dadam
    if isinstance(orig, dadam.PackedDAdamState) and orig.stale is not None:
        return out.with_stale(_cold_stale(orig.stale))
    if isinstance(orig, cdadam.PackedCDAdamState) and orig.pending is not None:
        return out.with_pending(_cold_pending(orig.pending))
    if isinstance(orig, dadam.DAdamState) and orig.stale is not None:
        return out._replace(stale=_cold_stale(orig.stale))
    if isinstance(orig, cdadam.CDAdamState) and orig.pending is not None:
        return out._replace(pending=_cold_pending(orig.pending))
    return out


def place_like(portable: PyTree, like: PyTree) -> PyTree:
    """Adapt a portable (backend-agnostic) state tree into ``like``'s
    backend layout, device placement and transient-comm structure.

    Packed-resident optimizer states in ``like`` are repacked INTO THE
    LIKE-STATE'S LAYOUT (a 2D worker x model state keeps its packed rows
    row-sharded M-ways) and every buffer is re-placed with the live
    state's sharding. Live straggler-comm buffers (D-Adam ``stale`` /
    CD-Adam ``pending``) are rebuilt COLD — zero payloads with COLD_AGE
    ages, all-zero delay rings — rather than copied from ``like``: a
    restored or resized worker holds no valid in-flight neighbor traffic.
    Plain array leaves are re-placed with their ``like`` counterpart's
    sharding. Shared by ``restore`` and the elastic-membership resize
    path (``repro.core.elastic``).

    Args:
      portable: the backend-agnostic tree (what ``save`` writes /
        ``load`` returns): packed states in their unpacked NamedTuple
        form, no transient comm buffers.
      like: a live state tree of the SAME structure at the adapt
        boundary — typically ``opt.init(params)`` of the optimizer the
        values are being restored onto. Decides backend layout,
        ``row_shards``, sharding, and which transient buffers to
        rebuild cold.

    Returns:
      ``portable``'s values in ``like``'s layout and placement.

    Raises:
      ValueError / TypeError: structural mismatch between the trees
        (propagated from the underlying flatten/repack).

    Example:
      >>> import jax, jax.numpy as jnp
      >>> from repro.checkpoint.io import place_like
      >>> from repro.core import make_optimizer
      >>> params = {"w": jnp.ones((2, 8, 2))}
      >>> ref = make_optimizer("d-adam", K=2, backend="reference")
      >>> pal = make_optimizer("d-adam", K=2, backend="pallas")
      >>> portable = ref.init(params)            # reference NamedTuple
      >>> packed = place_like(portable, pal.init(params))
      >>> bool(jnp.all(pal.params_of(packed)["w"]
      ...              == ref.params_of(portable)["w"]))
      True
    """
    outer_leaves, outer_td = jax.tree_util.tree_flatten(
        like, is_leaf=_needs_adapt)
    slots = outer_td.flatten_up_to(portable)

    def adapt(orig, slot):
        if _is_packed(orig):
            repack = type(orig).from_unpacked(
                slot, row_shards=getattr(orig.spec, "row_shards", 1))
            out = jax.tree_util.tree_map(
                _placed_like, repack, _sans_transient(orig))
        elif _has_transient(orig):
            out = jax.tree_util.tree_map(
                _placed_like, slot, _sans_transient(orig))
        else:
            return _placed_like(slot, orig)
        return _with_cold_transient(out, orig)

    return outer_td.unflatten(
        [adapt(orig, slot) for orig, slot in zip(outer_leaves, slots)])


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save(path: str, tree: PyTree, *, step: int = 0,
         meta: Optional[Dict[str, Any]] = None) -> None:
    tree = _to_portable(tree)
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    arrays = {}
    order = []
    for i, (p, leaf) in enumerate(leaves):
        key = f"{i:05d}|{_path_str(p)}"
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            arrays[key] = arr.view(np.uint16)
            order.append((key, "bfloat16"))
        else:
            arrays[key] = arr
            order.append((key, str(arr.dtype)))
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    side = {"step": step, "meta": meta or {}, "leaves": order}
    with open(path + ".json", "w") as f:
        json.dump(side, f)


def restore(path: str, like: PyTree) -> Tuple[PyTree, int]:
    """Restore into the structure of ``like`` (shape/dtype validated).

    ``like`` may contain packed-resident optimizer states or reference
    states with live straggler-comm buffers: the checkpoint (always
    stored portable) is restored into the portable structure and adapted
    back via ``place_like``, so the same file serves both backends and
    comm state restarts COLD."""
    outer_leaves, outer_td = jax.tree_util.tree_flatten(
        like, is_leaf=_needs_adapt)
    if any(_needs_adapt(l) for l in outer_leaves):
        portable_like = outer_td.unflatten(
            [_portable_of(l) for l in outer_leaves])
        restored, step = restore(path, portable_like)
        return place_like(restored, like), step
    with open(path + ".json") as f:
        side = json.load(f)
    data = np.load(path)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    if len(side["leaves"]) != len(leaves_like):
        raise ValueError(
            f"checkpoint has {len(side['leaves'])} leaves, expected "
            f"{len(leaves_like)}")
    out = []
    for (key, dtype_name), ref in zip(side["leaves"], leaves_like):
        arr = data[key]
        if dtype_name == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"shape mismatch at {key}: "
                             f"{arr.shape} vs {ref.shape}")
        out.append(_placed_like(jnp.asarray(arr, dtype=ref.dtype), ref))
    return jax.tree_util.tree_unflatten(treedef, out), side["step"]
