"""Checkpointing: dependency-free pytree save/restore with metadata.

Format: one ``.npz`` holding flattened leaves keyed by their tree path +
a JSON sidecar with the treedef / step / config hash. Atomic via
write-to-temp + rename. Works for optimizer states (NamedTuples) too.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save(path: str, tree: PyTree, *, step: int = 0,
         meta: Optional[Dict[str, Any]] = None) -> None:
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    arrays = {}
    order = []
    for i, (p, leaf) in enumerate(leaves):
        key = f"{i:05d}|{_path_str(p)}"
        arr = np.asarray(leaf)
        if arr.dtype == jnp.bfloat16:
            arrays[key] = arr.view(np.uint16)
            order.append((key, "bfloat16"))
        else:
            arrays[key] = arr
            order.append((key, str(arr.dtype)))
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                               suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    side = {"step": step, "meta": meta or {}, "leaves": order}
    with open(path + ".json", "w") as f:
        json.dump(side, f)


def restore(path: str, like: PyTree) -> Tuple[PyTree, int]:
    """Restore into the structure of ``like`` (shape/dtype validated)."""
    with open(path + ".json") as f:
        side = json.load(f)
    data = np.load(path)
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    if len(side["leaves"]) != len(leaves_like):
        raise ValueError(
            f"checkpoint has {len(side['leaves'])} leaves, expected "
            f"{len(leaves_like)}")
    out = []
    for (key, dtype_name), ref in zip(side["leaves"], leaves_like):
        arr = data[key]
        if dtype_name == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"shape mismatch at {key}: "
                             f"{arr.shape} vs {ref.shape}")
        out.append(jnp.asarray(arr, dtype=ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, out), side["step"]
