from repro.checkpoint.io import place_like, restore, save

__all__ = ["save", "restore", "place_like"]
