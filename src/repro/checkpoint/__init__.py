from repro.checkpoint.io import restore, save

__all__ = ["save", "restore"]
