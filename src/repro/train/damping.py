"""Adaptive batch damping: map the running loss to a gradient-accumulation
count.

The paper's theme is adaptivity computed from data — it adapts the step
size; this module extends that to the *batch size* in the AdaDamp /
PadaDamp / GeoDamp style: grow the effective batch as the loss falls, so
early steps stay cheap (few gradient evaluations, high tolerable
variance) and late steps stay low-variance (large batch near the
optimum). The knob is the number of gradient-accumulation **chunks** the
grad pipeline consumes per step:

* ``adadamp``  — chunks proportional to ``initial_loss / running_loss``
  (the loss-ratio rule; monotone non-decreasing so a noisy loss spike
  never shrinks the batch back down).
* ``padadamp`` — linear growth ``min_chunks + rate * t`` (the practical
  approximation: no loss feedback needed, just a slope).
* ``geodamp``  — geometric growth ``min_chunks * factor ** (t // delay)``
  (double every ``delay`` steps, the staged schedule).

jit shapes stay **static**: the pipeline always scans over
``max_chunks`` fixed-shape chunks and masks the unused tail
(``train.grad``'s damped pipelines), so one XLA program serves every
damping level — the JXL003 recompile watch pins this. What varies is
only the *accounting*: chunks beyond the current level contribute
nothing, cost no gradient-evaluation budget (the serverless billing
unit ``DampingState.evals`` tracks), and the loss/grad means divide by
the live count.

Per-worker damping (``per_worker=True``) keeps one signal per worker —
under non-IID skew each worker's loss (hence gradient variance) differs,
so its batch should too (the D² argument). The EMA state is a stacked
``(K,)`` vector; the trainer updates it from the pipeline's per-worker
losses, which are already psum'd/gathered to a global ``(K,)`` at the
jit level in every comm mode.

Once every worker sits at ``max_chunks`` the batch can no longer grow;
``lr_decay`` / ``lr_decay_every`` then hands adaptivity back to the step
size (the trainer decays eta once per ``lr_decay_every`` steps spent at
the ceiling — see ``DecentralizedTrainer``).

Example — AdaDamp grows the chunk count as the loss falls (``ema=0``
makes the signal instantaneous for the doctest):

    >>> import jax.numpy as jnp
    >>> from repro.train.damping import (DampingConfig, chunks_of,
    ...                                  init_damping, update)
    >>> cfg = DampingConfig(policy="adadamp", max_chunks=4, ema=0.0)
    >>> d = init_damping(cfg, K=2)
    >>> [int(c) for c in chunks_of(d, cfg, K=2)]
    [1, 1]
    >>> d = update(d, jnp.array([2.0, 2.0]), cfg)  # seeds loss0 = 2.0
    >>> d = update(d, jnp.array([0.5, 0.5]), cfg)  # loss fell 4x
    >>> [int(c) for c in chunks_of(d, cfg, K=2)]
    [4, 4]
    >>> int(d.evals)                               # 2 steps x (1+1) chunks
    4

GeoDamp doubles every ``delay`` update calls, loss-free:

    >>> cfg = DampingConfig(policy="geodamp", max_chunks=8, factor=2.0,
    ...                     delay=2)
    >>> d, ns = init_damping(cfg, K=1), []
    >>> for _ in range(6):
    ...     ns.append(int(chunks_of(d, cfg, K=1)[0]))
    ...     d = update(d, jnp.array([1.0]), cfg)
    >>> ns
    [1, 1, 2, 2, 4, 4]
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

_POLICIES = ("adadamp", "padadamp", "geodamp")


@dataclasses.dataclass(frozen=True)
class DampingConfig:
    """Static damping policy config (hashable; safe to close over in jit).

    Attributes:
      policy: ``'adadamp'`` | ``'padadamp'`` | ``'geodamp'``.
      max_chunks: accumulation-chunk ceiling — the pipeline's static scan
        length; the per-worker batch dim must be divisible by it.
      min_chunks: floor (the starting batch), >= 1.
      ema: loss-EMA decay for the adadamp signal (0 = instantaneous).
      per_worker: one damping signal per worker (non-IID skew) instead of
        one global mean-loss signal.
      rate: padadamp chunks gained per step.
      factor, delay: geodamp multiplies the count by ``factor`` every
        ``delay`` steps.
      lr_decay, lr_decay_every: once ALL workers sit at ``max_chunks``,
        decay eta by ``lr_decay`` for every ``lr_decay_every`` steps
        spent at the ceiling (0 disables; needs ``opt.rebuild``).
    """

    policy: str = "adadamp"
    max_chunks: int = 4
    min_chunks: int = 1
    ema: float = 0.9
    per_worker: bool = False
    rate: float = 0.25
    factor: float = 2.0
    delay: int = 100
    lr_decay: float = 0.5
    lr_decay_every: int = 0

    def __post_init__(self) -> None:
        if self.policy not in _POLICIES:
            raise ValueError(f"unknown damping policy {self.policy!r} "
                             f"(use one of {list(_POLICIES)})")
        if not 1 <= self.min_chunks <= self.max_chunks:
            raise ValueError(
                f"need 1 <= min_chunks <= max_chunks, got "
                f"min_chunks={self.min_chunks} max_chunks={self.max_chunks}")
        if not 0.0 <= self.ema < 1.0:
            raise ValueError(f"ema must be in [0, 1), got {self.ema}")
        if self.policy == "padadamp" and self.rate <= 0:
            raise ValueError("padadamp needs rate > 0 (chunks per step)")
        if self.policy == "geodamp" and (self.factor <= 1.0
                                         or self.delay < 1):
            raise ValueError("geodamp needs factor > 1 and delay >= 1, "
                             f"got factor={self.factor} delay={self.delay}")
        if not 0.0 < self.lr_decay <= 1.0:
            raise ValueError(f"lr_decay must be in (0, 1], "
                             f"got {self.lr_decay}")
        if self.lr_decay_every < 0:
            raise ValueError("lr_decay_every must be >= 0 (0 disables)")


class DampingState(NamedTuple):
    """Traced damping state (a pytree of arrays; lives inside the jitted
    step). ``S`` = K when ``per_worker`` else 1."""

    ema_loss: jax.Array   # (S,) f32 running loss signal
    loss0: jax.Array      # (S,) f32 seed loss (first observed)
    t: jax.Array          # ()  i32 update count
    level: jax.Array      # (S,) f32 continuous chunk level
    at_max: jax.Array     # ()  i32 steps with every worker at the ceiling
    evals: jax.Array      # ()  i32 cumulative worker-chunk gradient evals


def init_damping(cfg: DampingConfig, K: int) -> DampingState:
    """Fresh damping state for ``K`` workers at the ``min_chunks`` floor."""
    S = K if cfg.per_worker else 1
    return DampingState(
        ema_loss=jnp.zeros((S,), jnp.float32),
        loss0=jnp.zeros((S,), jnp.float32),
        t=jnp.zeros((), jnp.int32),
        level=jnp.full((S,), float(cfg.min_chunks), jnp.float32),
        at_max=jnp.zeros((), jnp.int32),
        evals=jnp.zeros((), jnp.int32))


def chunks_of(state: DampingState, cfg: DampingConfig,
              K: int) -> jax.Array:
    """Per-worker accumulation-chunk counts for the NEXT step: ``(K,)``
    int32 in ``[min_chunks, max_chunks]`` (broadcast from the global
    signal when ``per_worker=False``)."""
    n = jnp.clip(jnp.ceil(state.level), cfg.min_chunks,
                 cfg.max_chunks).astype(jnp.int32)
    return jnp.broadcast_to(n, (K,))


def update(state: DampingState, losses: jax.Array,
           cfg: DampingConfig) -> DampingState:
    """Fold one step's per-worker losses ``(K,)`` into the damping state.

    Pure and traced — called inside the jitted trainer step, after the
    grad pipeline. The first call seeds ``loss0`` and the EMA; the
    adadamp level is monotone non-decreasing (a noisy spike never shrinks
    the batch). ``evals`` accrues the chunks the step just consumed and
    ``at_max`` the steps spent with every worker at the ceiling — the
    trainer's lr-decay trigger."""
    K = losses.shape[0]
    losses = losses.astype(jnp.float32)
    sig = losses if cfg.per_worker else jnp.mean(losses, keepdims=True)
    first = state.t == 0
    ema = jnp.where(first, sig,
                    cfg.ema * state.ema_loss + (1.0 - cfg.ema) * sig)
    loss0 = jnp.where(first, sig, state.loss0)
    t1 = state.t + 1
    if cfg.policy == "adadamp":
        lvl = cfg.min_chunks * loss0 / jnp.maximum(ema, 1e-12)
        lvl = jnp.maximum(state.level, lvl)
    elif cfg.policy == "padadamp":
        lvl = jnp.full_like(state.level,
                            cfg.min_chunks + cfg.rate * t1.astype(
                                jnp.float32))
    else:  # geodamp
        lvl = jnp.full_like(state.level, float(cfg.min_chunks)) * jnp.power(
            cfg.factor, (t1 // cfg.delay).astype(jnp.float32))
    lvl = jnp.clip(lvl, float(cfg.min_chunks), float(cfg.max_chunks))
    n_used = chunks_of(state, cfg, K)  # chunks THIS step consumed
    return DampingState(
        ema_loss=ema, loss0=loss0, t=t1, level=lvl,
        at_max=state.at_max + jnp.all(
            n_used >= cfg.max_chunks).astype(jnp.int32),
        evals=state.evals + jnp.sum(n_used))


def resize_damp(state: DampingState, cfg: DampingConfig,
                new_K: int) -> DampingState:
    """Carry damping state across an elastic membership change: global
    signals pass through; per-worker signals map onto the new worker set
    round-robin (joiners inherit a live worker's signal, mirroring
    ``elastic.resize_state``'s 'clone' strategy)."""
    if not cfg.per_worker:
        return state
    S = state.level.shape[0]
    idx = jnp.arange(new_K) % S
    return state._replace(ema_loss=jnp.take(state.ema_loss, idx),
                          loss0=jnp.take(state.loss0, idx),
                          level=jnp.take(state.level, idx))


def make_damping(spec: Union[None, str, DampingConfig]
                 ) -> Optional[DampingConfig]:
    """Parse a damping spec: a built config passes through, ``None``
    disables, and a string is ``'policy:max_chunks[:extra...]'`` —

    * ``'adadamp:MAX[:EMA]'``
    * ``'padadamp:MAX[:RATE]'``
    * ``'geodamp:MAX[:FACTOR[:DELAY]]'``

    >>> from repro.train.damping import make_damping
    >>> make_damping("adadamp:8").max_chunks
    8
    >>> make_damping("geodamp:8:2:50").delay
    50
    >>> make_damping(None) is None
    True
    """
    if spec is None or isinstance(spec, DampingConfig):
        return spec
    parts = spec.split(":")
    policy = parts[0].lower().replace("_", "-").replace("-", "")
    if policy not in _POLICIES:
        raise ValueError(f"unknown damping policy {parts[0]!r} "
                         f"(use one of {list(_POLICIES)})")
    kw: dict = {"policy": policy}
    if len(parts) > 1:
        kw["max_chunks"] = int(parts[1])
    extras = parts[2:]
    if extras:
        if policy == "adadamp":
            kw["ema"] = float(extras[0])
        elif policy == "padadamp":
            kw["rate"] = float(extras[0])
        else:
            kw["factor"] = float(extras[0])
            if len(extras) > 1:
                kw["delay"] = int(extras[1])
    return DampingConfig(**kw)
