"""Decentralized training loop.

Couples a per-worker loss function to a DecentralizedOptimizer: stacks K
parameter replicas, vmaps per-worker gradients, jits one step (with the
in-graph communication-skip cond), tracks loss / consensus / communication
cost. Works for any model in the registry and for the paper's own DeepFM /
Wide&Deep / ResNet20 models.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import DecentralizedOptimizer
from repro.core.api import shard_over_workers
from repro.core.dadam import consensus_error, mean_params
from repro.train import damping as damping_mod
from repro.train.damping import DampingConfig, DampingState
from repro.train.grad import make_grad_pipeline

PyTree = Any


def stack_params(params: PyTree, K: int, *, same_init: bool = True,
                 key: Optional[jax.Array] = None,
                 init_fn: Optional[Callable] = None) -> PyTree:
    """Replicate (or independently re-draw) params across the worker dim."""
    if same_init or init_fn is None:
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (K,) + x.shape).copy(), params)
    if key is None:
        raise ValueError(
            "stack_params(same_init=False, init_fn=...) draws K "
            "independent inits and needs key= (a jax PRNG key) to split "
            "across workers")
    keys = jax.random.split(key, K)
    per = [init_fn(k) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per)


@dataclasses.dataclass
class TrainLog:
    """Training log. The list fields are one entry per log point; the
    ``*_total`` scalars are cumulative counters carried ACROSS ``fit``
    calls — pass the same log back in (``trainer.fit(..., log=log)``)
    and steps, comm volume, wall time, and gradient-evaluation counts
    resume from where the previous call left off instead of restarting
    at zero (the streaming / damping / elastic-resize use case)."""

    step: List[int] = dataclasses.field(default_factory=list)
    loss: List[float] = dataclasses.field(default_factory=list)
    consensus: List[float] = dataclasses.field(default_factory=list)
    comm_mb: List[float] = dataclasses.field(default_factory=list)
    wall_s: List[float] = dataclasses.field(default_factory=list)
    # cumulative worker-chunk gradient evaluations (the serverless
    # billing unit adaptive batch damping economizes; see train.damping)
    grad_evals: List[int] = dataclasses.field(default_factory=list)
    # cumulative counters resumed by the next fit(log=...) call
    steps_total: int = 0
    comm_rounds_total: int = 0
    comm_mb_total: float = 0.0
    wall_s_total: float = 0.0
    grad_evals_total: int = 0

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


class DecentralizedTrainer:
    """Stacked-K decentralized trainer.

    loss_fn(params, batch) -> scalar, evaluated per worker; the batch
    carries a leading K dim on every leaf. Gradients are produced by the
    grad pipeline (``train.grad.make_grad_pipeline``): the reference vmap
    path for pytree states, the differentiate-through-``packing.unpack``
    path for packed-resident states (grads arrive packed, zero explicit
    pack/unpack in the step), or — on a 2D worker × model mesh with a
    ``sharded_loss`` — the model-parallel path that evaluates the loss
    inside the shard_map directly from each device's local
    (1, rows/M, 128) row-shard block, with no full-parameter all-gather.
    ``microbatch`` > 1 turns on gradient accumulation in every mode.

    With a comm='axis' optimizer (``make_optimizer(comm='axis', mesh=...)``)
    the state lives sharded over the worker mesh axis: ``opt.init`` places
    it there, the jitted step's shard_map keeps it there, and ``fit``
    device_puts each batch's worker dim onto the axis so the per-worker
    grads are computed where the state shard lives. On a 2D mesh the batch
    replicates over the 'model' axis (every device of a worker's model
    group sees the worker's whole microbatch). Without a ``sharded_loss``
    the 2D grad path falls back to GSPMD through the row-sharded unpack —
    pass ``plan`` (``launch.shardings.make_plan(mode='axis')``) to thread
    its head-aware ``param_pspec`` rules into that loss as sharding
    constraints.

    Args (constructor):
      loss_fn: per-worker scalar loss ``(params, batch) -> float``;
        sees ONE worker's params and batch (no K dim) — the pipeline
        vmaps / shard_maps it.
      opt: a ``DecentralizedOptimizer`` from ``make_optimizer``.
      microbatch: > 1 turns on gradient accumulation (the batch's
        per-worker dim is split into this many chunks).
      sharded_loss: model-parallel loss over local row shards (2D mesh
        only; see ``make_grad_pipeline``).
      plan: ``launch.shardings.ShardingPlan`` for the 2D GSPMD fallback.
      recompile_limit: arm the JXL003 recompile gate — ``fit`` raises
        once the jitted step has compiled for more than this many
        distinct abstract signatures (elastic resizes and lr-decay
        rebinds excluded).
      damping: adaptive batch damping — a ``train.damping.DampingConfig``
        or a spec string (``'adadamp:8'``, ``'geodamp:8:2:50'``; see
        ``train.damping.make_damping``). The grad pipeline then scans
        over ``max_chunks`` fixed-shape accumulation chunks and masks
        the tail past the policy's current per-worker count, so ONE
        compiled step serves every damping level; the damping state
        (loss EMA, level, eval counter) threads through the jitted step.
        Mutually exclusive with ``microbatch`` > 1. Once every worker
        sits at ``max_chunks``, ``lr_decay``/``lr_decay_every`` decay
        eta via ``opt.rebuild`` (one legitimate recompile per decay,
        like an elastic resize).

    Example:
      >>> import jax.numpy as jnp
      >>> from repro.core import make_optimizer
      >>> from repro.train.loop import DecentralizedTrainer
      >>> def loss(p, b):                    # ONE worker's view
      ...     return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)
      >>> opt = make_optimizer("d-adam", K=2, eta=1e-2)
      >>> tr = DecentralizedTrainer(loss, opt)
      >>> state = tr.init({"w": jnp.zeros((3, 1))})  # stacked to K inside
      >>> def batches():
      ...     while True:                    # leading K dim on each leaf
      ...         yield {"x": jnp.ones((2, 4, 3)), "y": jnp.ones((2, 4, 1))}
      >>> state, log = tr.fit(state, batches(), steps=3)
      >>> opt.params_of(state)["w"].shape
      (2, 3, 1)
      >>> len(log.loss)                      # logged on the final step
      1
    """

    def __init__(self, loss_fn: Callable[[PyTree, PyTree], jax.Array],
                 opt: DecentralizedOptimizer, *, microbatch: int = 1,
                 sharded_loss: Optional[Callable] = None,
                 plan: Any = None, recompile_limit: Optional[int] = None,
                 damping: "None | str | DampingConfig" = None):
        self.loss_fn = loss_fn
        self._microbatch = microbatch
        self._sharded_loss = sharded_loss
        self._plan = plan
        self._recompile_limit = recompile_limit
        self._damping = damping_mod.make_damping(damping)
        if self._damping is not None and microbatch > 1:
            raise ValueError(
                "damping owns the accumulation loop (max_chunks IS the "
                "chunk count); pass damping= OR microbatch=, not both")
        self.damp_state: Optional[DampingState] = None
        self._lr_decays = 0
        self.recompile_watch = None
        self._build(opt)

    def _build(self, opt: DecentralizedOptimizer) -> None:
        """(Re)bind the trainer to an optimizer: rebuild the grad pipeline
        and the jitted step. Called once at construction and again on each
        elastic membership change (``resize``) or damping lr decay."""
        self.opt = opt
        dcfg = self._damping
        self.pipeline = make_grad_pipeline(
            self.loss_fn, opt, microbatch=self._microbatch,
            sharded_loss=self._sharded_loss, plan=self._plan,
            damping_chunks=dcfg.max_chunks if dcfg is not None else 0)
        # per-round comm bytes are rebind-dependent (schedule entries,
        # elastic K): recompute lazily against the bound optimizer
        self._mb_rounds: Optional[List[float]] = None

        if dcfg is not None:
            if self.damp_state is None:
                self.damp_state = damping_mod.init_damping(dcfg, opt.K)

            def step(state, dstate, batch):
                n = damping_mod.chunks_of(dstate, dcfg, self.opt.K)
                losses, grads = self.pipeline.value_and_grad(
                    state, batch, n)
                new_state = self.opt.step(state, grads)
                # the damping signal updates OUTSIDE the comm shard_maps,
                # from the global (K,) per-worker losses — stacked and
                # axis comm modes see the identical EMA
                new_dstate = damping_mod.update(dstate, losses, dcfg)
                return new_state, new_dstate, jnp.mean(losses)
        else:
            def step(state, batch):
                losses, grads = self.pipeline.value_and_grad(state, batch)
                return self.opt.step(state, grads), jnp.mean(losses)

        self._step = jax.jit(step)
        if self._recompile_limit is not None:
            # JXL003 gate: every fit() call's abstract signature is hashed;
            # exceeding the limit raises. Built fresh here so an elastic
            # resize or damping lr decay (one legitimate recompile per
            # membership change / decay event) does not count against the
            # budget — damping LEVEL changes reuse the cache and do.
            from repro.analysis.jaxpr_lint import RecompileWatch
            self.recompile_watch = RecompileWatch(
                "trainer.step", limit=self._recompile_limit)

    def init(self, params: PyTree) -> Any:
        stacked = stack_params(params, self.opt.K)
        return self.opt.init(stacked)

    def resize(self, state: Any, new_opt: DecentralizedOptimizer, *,
               strategy: str = "clone") -> Any:
        """Elastic membership change: carry ``state`` over to ``new_opt``
        (built for the new K / topology) and rebind the trainer to it.

        Exactly ONE recompile per membership change: the jitted step is
        rebuilt here, and subsequent ``fit`` steps at the new K reuse its
        cache. Params and Adam moments survive per ``strategy`` ("clone"
        bootstraps joiners from live workers round-robin, "mean" from the
        consensus mean); hats and straggler buffers restart cold."""
        from repro.core.elastic import resize_state
        new_state = resize_state(state, new_opt, strategy=strategy)
        if self._damping is not None and self.damp_state is not None:
            # per-worker damping signals follow the membership change
            # (joiners inherit signals round-robin); the eval counter and
            # ceiling clock carry through
            self.damp_state = damping_mod.resize_damp(
                self.damp_state, self._damping, new_opt.K)
        self._build(new_opt)
        return new_state

    def _place_batch(self, batch: PyTree) -> PyTree:
        """comm='axis': ship each leaf's worker dim onto the worker mesh
        axis (no-op for stacked-comm optimizers). On a 2D mesh the batch
        deliberately replicates over the model axis — data parallelism
        stays between workers, tensor parallelism within them."""
        if self.opt.mesh is None:
            return batch
        return shard_over_workers(batch, self.opt.mesh, self.opt.K,
                                  getattr(self.opt.cfg, "axis_name",
                                          "worker"))

    def comm_mb_per_round(self, state) -> float:
        return self.opt.comm_bytes_per_round(
            self.opt.params_of(state)) / 1e6

    def _round_mb(self, state, round_index: int) -> float:
        """MB this worker sends in communication round ``round_index``
        (cumulative across resumed fits). Recomputed on every rebind —
        an elastic resize changes K and per-worker bytes, a
        TopologySchedule changes the per-entry degree round to round."""
        if self._mb_rounds is None:
            params = self.opt.params_of(state)
            self._mb_rounds = [
                b / 1e6 for b in self.opt.comm_bytes_round_list(params)]
        return self._mb_rounds[round_index % len(self._mb_rounds)]

    def _maybe_decay_lr(self) -> None:
        """Damping's hand-off back to the step size: once every worker
        sits at ``max_chunks``, decay eta by ``lr_decay`` per
        ``lr_decay_every`` steps spent at the ceiling. Checked at log
        boundaries (one host sync per check, not per step); each decay
        rebinds via ``opt.rebuild`` — one legitimate recompile, like an
        elastic resize."""
        dcfg = self._damping
        if (dcfg is None or not dcfg.lr_decay_every
                or getattr(self.opt, "rebuild", None) is None):
            return
        due = int(self.damp_state.at_max) // dcfg.lr_decay_every
        if due > self._lr_decays:
            factor = dcfg.lr_decay ** (due - self._lr_decays)
            self._lr_decays = due
            self._build(self.opt.rebuild(
                eta=float(self.opt.cfg.eta) * factor))

    def fit(self, state, batch_iter: Iterator[PyTree], steps: int, *,
            log_every: int = 50, log: Optional[TrainLog] = None,
            hook: Optional[Callable[[int, Any], None]] = None,
            hook_every: int = 0) -> Tuple[Any, TrainLog]:
        """Run ``steps`` optimizer steps, logging every ``log_every``.

        Pass the previous call's ``log`` back in to CONTINUE it: the
        cumulative ``*_total`` counters on :class:`TrainLog` make
        ``log.step`` / ``log.comm_mb`` / ``log.wall_s`` resume instead of
        restarting at zero, and under a ``TopologySchedule`` the
        schedule-entry round index stays aligned across calls (a fresh
        log restarts the entry accounting at the cycle head).

        ``hook(global_step, state)`` is called every ``hook_every`` steps
        (cumulative step count, aligned with ``log.step``) — the online
        train→serve publish point (``train.online`` installs a
        ``ParamStore`` publish here). The hook runs on the host between
        jitted steps: it must not mutate ``state``, and anything it
        launches (a ``device_put``, an unpack-once publish) is async, so
        training does not stall on it."""
        log = log or TrainLog()
        comm_rounds = log.comm_rounds_total
        comm_mb = log.comm_mb_total
        step0 = log.steps_total
        evals0_dev = (int(self.damp_state.evals)
                      if self._damping is not None else 0)
        evals_per_step = self.opt.K * self.pipeline.microbatch
        t0 = time.perf_counter()
        for t in range(steps):
            batch = self._place_batch(next(batch_iter))
            if self._damping is not None:
                if self.recompile_watch is not None:
                    self.recompile_watch.observe(state, self.damp_state,
                                                 batch)
                    self.recompile_watch.check()
                state, self.damp_state, loss = self._step(
                    state, self.damp_state, batch)
            else:
                if self.recompile_watch is not None:
                    self.recompile_watch.observe(state, batch)
                    self.recompile_watch.check()
                state, loss = self._step(state, batch)
            if (t + 1) % self.opt.cfg.period == 0:
                comm_mb += self._round_mb(state, comm_rounds)
                comm_rounds += 1
            if hook is not None and hook_every > 0 \
                    and (t + 1) % hook_every == 0:
                hook(step0 + t + 1, state)
            if (t + 1) % log_every == 0 or t == steps - 1:
                if self._damping is not None:
                    evals = (log.grad_evals_total
                             + int(self.damp_state.evals) - evals0_dev)
                else:
                    evals = log.grad_evals_total + (t + 1) * evals_per_step
                log.step.append(step0 + t + 1)
                log.loss.append(float(loss))
                log.consensus.append(
                    float(consensus_error(self.opt.params_of(state))))
                log.comm_mb.append(comm_mb)
                log.wall_s.append(log.wall_s_total
                                  + time.perf_counter() - t0)
                log.grad_evals.append(evals)
                self._maybe_decay_lr()
        log.steps_total = step0 + steps
        log.comm_rounds_total = comm_rounds
        log.comm_mb_total = comm_mb
        log.wall_s_total += time.perf_counter() - t0
        if steps:
            if self._damping is not None:
                log.grad_evals_total += (int(self.damp_state.evals)
                                         - evals0_dev)
            else:
                log.grad_evals_total += steps * evals_per_step
        return state, log

    def averaged_params(self, state) -> PyTree:
        return mean_params(self.opt.params_of(state))
