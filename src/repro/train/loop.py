"""Decentralized training loop.

Couples a per-worker loss function to a DecentralizedOptimizer: stacks K
parameter replicas, vmaps per-worker gradients, jits one step (with the
in-graph communication-skip cond), tracks loss / consensus / communication
cost. Works for any model in the registry and for the paper's own DeepFM /
Wide&Deep / ResNet20 models.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import DecentralizedOptimizer
from repro.core.api import shard_over_workers
from repro.core.dadam import consensus_error, mean_params
from repro.train.grad import make_grad_pipeline

PyTree = Any


def stack_params(params: PyTree, K: int, *, same_init: bool = True,
                 key: Optional[jax.Array] = None,
                 init_fn: Optional[Callable] = None) -> PyTree:
    """Replicate (or independently re-draw) params across the worker dim."""
    if same_init or init_fn is None:
        return jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (K,) + x.shape).copy(), params)
    keys = jax.random.split(key, K)
    per = [init_fn(k) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per)


@dataclasses.dataclass
class TrainLog:
    step: List[int] = dataclasses.field(default_factory=list)
    loss: List[float] = dataclasses.field(default_factory=list)
    consensus: List[float] = dataclasses.field(default_factory=list)
    comm_mb: List[float] = dataclasses.field(default_factory=list)
    wall_s: List[float] = dataclasses.field(default_factory=list)

    def as_dict(self) -> Dict[str, list]:
        return dataclasses.asdict(self)


class DecentralizedTrainer:
    """Stacked-K decentralized trainer.

    loss_fn(params, batch) -> scalar, evaluated per worker; the batch
    carries a leading K dim on every leaf. Gradients are produced by the
    grad pipeline (``train.grad.make_grad_pipeline``): the reference vmap
    path for pytree states, the differentiate-through-``packing.unpack``
    path for packed-resident states (grads arrive packed, zero explicit
    pack/unpack in the step), or — on a 2D worker × model mesh with a
    ``sharded_loss`` — the model-parallel path that evaluates the loss
    inside the shard_map directly from each device's local
    (1, rows/M, 128) row-shard block, with no full-parameter all-gather.
    ``microbatch`` > 1 turns on gradient accumulation in every mode.

    With a comm='axis' optimizer (``make_optimizer(comm='axis', mesh=...)``)
    the state lives sharded over the worker mesh axis: ``opt.init`` places
    it there, the jitted step's shard_map keeps it there, and ``fit``
    device_puts each batch's worker dim onto the axis so the per-worker
    grads are computed where the state shard lives. On a 2D mesh the batch
    replicates over the 'model' axis (every device of a worker's model
    group sees the worker's whole microbatch). Without a ``sharded_loss``
    the 2D grad path falls back to GSPMD through the row-sharded unpack —
    pass ``plan`` (``launch.shardings.make_plan(mode='axis')``) to thread
    its head-aware ``param_pspec`` rules into that loss as sharding
    constraints.

    Args (constructor):
      loss_fn: per-worker scalar loss ``(params, batch) -> float``;
        sees ONE worker's params and batch (no K dim) — the pipeline
        vmaps / shard_maps it.
      opt: a ``DecentralizedOptimizer`` from ``make_optimizer``.
      microbatch: > 1 turns on gradient accumulation (the batch's
        per-worker dim is split into this many chunks).
      sharded_loss: model-parallel loss over local row shards (2D mesh
        only; see ``make_grad_pipeline``).
      plan: ``launch.shardings.ShardingPlan`` for the 2D GSPMD fallback.
      recompile_limit: arm the JXL003 recompile gate — ``fit`` raises
        once the jitted step has compiled for more than this many
        distinct abstract signatures (elastic resizes excluded).

    Example:
      >>> import jax.numpy as jnp
      >>> from repro.core import make_optimizer
      >>> from repro.train.loop import DecentralizedTrainer
      >>> def loss(p, b):                    # ONE worker's view
      ...     return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)
      >>> opt = make_optimizer("d-adam", K=2, eta=1e-2)
      >>> tr = DecentralizedTrainer(loss, opt)
      >>> state = tr.init({"w": jnp.zeros((3, 1))})  # stacked to K inside
      >>> def batches():
      ...     while True:                    # leading K dim on each leaf
      ...         yield {"x": jnp.ones((2, 4, 3)), "y": jnp.ones((2, 4, 1))}
      >>> state, log = tr.fit(state, batches(), steps=3)
      >>> opt.params_of(state)["w"].shape
      (2, 3, 1)
      >>> len(log.loss)                      # logged on the final step
      1
    """

    def __init__(self, loss_fn: Callable[[PyTree, PyTree], jax.Array],
                 opt: DecentralizedOptimizer, *, microbatch: int = 1,
                 sharded_loss: Optional[Callable] = None,
                 plan: Any = None, recompile_limit: Optional[int] = None):
        self.loss_fn = loss_fn
        self._microbatch = microbatch
        self._sharded_loss = sharded_loss
        self._plan = plan
        self._recompile_limit = recompile_limit
        self.recompile_watch = None
        self._build(opt)

    def _build(self, opt: DecentralizedOptimizer) -> None:
        """(Re)bind the trainer to an optimizer: rebuild the grad pipeline
        and the jitted step. Called once at construction and again on each
        elastic membership change (``resize``)."""
        self.opt = opt
        self.pipeline = make_grad_pipeline(
            self.loss_fn, opt, microbatch=self._microbatch,
            sharded_loss=self._sharded_loss, plan=self._plan)

        def step(state, batch):
            losses, grads = self.pipeline.value_and_grad(state, batch)
            return self.opt.step(state, grads), jnp.mean(losses)

        self._step = jax.jit(step)
        if self._recompile_limit is not None:
            # JXL003 gate: every fit() call's abstract signature is hashed;
            # exceeding the limit raises. Built fresh here so an elastic
            # resize (one legitimate recompile per membership change) does
            # not count against the budget.
            from repro.analysis.jaxpr_lint import RecompileWatch
            self.recompile_watch = RecompileWatch(
                "trainer.step", limit=self._recompile_limit)

    def init(self, params: PyTree) -> Any:
        stacked = stack_params(params, self.opt.K)
        return self.opt.init(stacked)

    def resize(self, state: Any, new_opt: DecentralizedOptimizer, *,
               strategy: str = "clone") -> Any:
        """Elastic membership change: carry ``state`` over to ``new_opt``
        (built for the new K / topology) and rebind the trainer to it.

        Exactly ONE recompile per membership change: the jitted step is
        rebuilt here, and subsequent ``fit`` steps at the new K reuse its
        cache. Params and Adam moments survive per ``strategy`` ("clone"
        bootstraps joiners from live workers round-robin, "mean" from the
        consensus mean); hats and straggler buffers restart cold."""
        from repro.core.elastic import resize_state
        new_state = resize_state(state, new_opt, strategy=strategy)
        self._build(new_opt)
        return new_state

    def _place_batch(self, batch: PyTree) -> PyTree:
        """comm='axis': ship each leaf's worker dim onto the worker mesh
        axis (no-op for stacked-comm optimizers). On a 2D mesh the batch
        deliberately replicates over the model axis — data parallelism
        stays between workers, tensor parallelism within them."""
        if self.opt.mesh is None:
            return batch
        return shard_over_workers(batch, self.opt.mesh, self.opt.K,
                                  getattr(self.opt.cfg, "axis_name",
                                          "worker"))

    def comm_mb_per_round(self, state) -> float:
        return self.opt.comm_bytes_per_round(
            self.opt.params_of(state)) / 1e6

    def fit(self, state, batch_iter: Iterator[PyTree], steps: int, *,
            log_every: int = 50, log: Optional[TrainLog] = None) -> Tuple[
                Any, TrainLog]:
        log = log or TrainLog()
        comm_rounds = 0
        mb_per_round = None
        t0 = time.perf_counter()
        for t in range(steps):
            batch = self._place_batch(next(batch_iter))
            if self.recompile_watch is not None:
                self.recompile_watch.observe(state, batch)
                self.recompile_watch.check()
            state, loss = self._step(state, batch)
            if (t + 1) % self.opt.cfg.period == 0:
                comm_rounds += 1
            if (t + 1) % log_every == 0 or t == steps - 1:
                if mb_per_round is None:
                    mb_per_round = self.comm_mb_per_round(state)
                log.step.append(t + 1)
                log.loss.append(float(loss))
                log.consensus.append(
                    float(consensus_error(self.opt.params_of(state))))
                log.comm_mb.append(comm_rounds * mb_per_round)
                log.wall_s.append(time.perf_counter() - t0)
        return state, log

    def averaged_params(self, state) -> PyTree:
        return mean_params(self.opt.params_of(state))
