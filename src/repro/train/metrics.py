"""Evaluation metrics used by the paper: ACC (CIFAR) and AUC (CTR)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def accuracy(logits, labels) -> float:
    return float(jnp.mean(jnp.argmax(logits, -1) == labels))


def auc(scores, labels) -> float:
    """Area under the ROC curve (rank-based, ties handled by midranks)."""
    scores = np.asarray(scores, np.float64)
    labels = np.asarray(labels)
    order = np.argsort(scores, kind="mergesort")
    ranks = np.empty_like(order, np.float64)
    ranks[order] = np.arange(1, len(scores) + 1)
    # midranks for ties
    s_sorted = scores[order]
    i = 0
    while i < len(s_sorted):
        j = i
        while j + 1 < len(s_sorted) and s_sorted[j + 1] == s_sorted[i]:
            j += 1
        if j > i:
            ranks[order[i:j + 1]] = 0.5 * (i + j) + 1
        i = j + 1
    n_pos = int(labels.sum())
    n_neg = len(labels) - n_pos
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return float((ranks[labels == 1].sum()
                  - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg))
