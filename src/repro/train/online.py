"""Continuous train→serve driver: stream in, versioned params out.

Interleaves :class:`~repro.train.loop.DecentralizedTrainer` steps on a
(non-IID) data stream with periodic lock-free publishes into a
``serve.publish.ParamStore`` — the online-learning loop the paper's
serverless CTR scenario runs: the trainer owns the packed-resident state,
serving replicas decode against the store's latest complete snapshot, and
a publish is an unpack-once slice of the resident buffer plus a pointer
swap (never a full K-way unpack, never a reader stall).

    store = ParamStore()
    result = train_online(trainer, state, stream, steps=500, store=store,
                          publish_every=50, mode="mean")
    version, params = store.snapshot()      # serving side, any time
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator, List, Optional, Tuple

from repro.serve.publish import ParamStore, publish_params
from repro.train.loop import DecentralizedTrainer, TrainLog

PyTree = Any


@dataclasses.dataclass
class OnlineResult:
    """What one online run produced: the final state, the (continued)
    train log, and the ``(global_step, version)`` publish history."""
    state: Any
    log: TrainLog
    published: List[Tuple[int, int]]

    @property
    def versions(self) -> List[int]:
        return [v for _, v in self.published]


def train_online(trainer: DecentralizedTrainer, state: Any,
                 stream: Iterator[PyTree], steps: int, *,
                 store: ParamStore, publish_every: int,
                 mode: str = "mean", worker: int = 0,
                 like: Optional[PyTree] = None,
                 final_publish: bool = True,
                 log_every: int = 50,
                 log: Optional[TrainLog] = None) -> OnlineResult:
    """Run ``steps`` trainer steps on ``stream``, publishing every
    ``publish_every`` steps (and once at the end unless the last step
    already published, or ``final_publish`` is off).

    The publish is :func:`~repro.serve.publish.publish_params` on the
    LIVE optimizer state — for packed-resident states an unpack-once
    decode of one ``(rows, 128)`` row block (``mode="worker"``) or the
    packed-domain consensus mean (``mode="mean"``) — pushed into
    ``store`` behind its version counter. ``like=`` places published
    leaves onto a serving-side sharding before the swap.

    Returns an :class:`OnlineResult`; pass ``result.log`` back in as
    ``log=`` to continue counters across calls (the streaming contract
    ``TrainLog`` documents).
    """
    if publish_every <= 0:
        raise ValueError(
            f"publish_every must be >= 1, got {publish_every}")
    published: List[Tuple[int, int]] = []

    def hook(global_step: int, live_state: Any) -> None:
        params = publish_params(live_state, mode=mode, worker=worker,
                                like=like)
        published.append((global_step, store.publish(params)))

    state, log = trainer.fit(state, stream, steps, log_every=log_every,
                             log=log, hook=hook, hook_every=publish_every)
    if final_publish and (not published
                          or published[-1][0] != log.steps_total):
        hook(log.steps_total, state)
    return OnlineResult(state=state, log=log, published=published)
