from repro.train.loop import DecentralizedTrainer, TrainLog, stack_params

__all__ = ["DecentralizedTrainer", "TrainLog", "stack_params"]
