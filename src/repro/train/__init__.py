from repro.train.damping import DampingConfig, DampingState, make_damping
from repro.train.grad import (GradPipeline, ShardCtx, make_grad_pipeline,
                              make_worker_grad, row_parallel_dot)
from repro.train.loop import DecentralizedTrainer, TrainLog, stack_params
from repro.train.online import OnlineResult, train_online

__all__ = ["DecentralizedTrainer", "TrainLog", "stack_params",
           "GradPipeline", "ShardCtx", "make_grad_pipeline",
           "make_worker_grad", "row_parallel_dot",
           "DampingConfig", "DampingState", "make_damping",
           "OnlineResult", "train_online"]
