"""Gradient helpers shared by the trainer and the dry-run launcher.

``make_worker_grad(loss, microbatch)`` builds the per-worker gradient
function: plain ``jax.grad`` for microbatch=1, or a lax.scan of
gradient-accumulation steps that divides activation memory by the
microbatch count (EXPERIMENTS.md §Perf iteration 9)."""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


def make_worker_grad(loss: Callable[[PyTree, PyTree], jax.Array],
                     microbatch: int = 1) -> Callable[[PyTree, PyTree],
                                                      PyTree]:
    if microbatch <= 1:
        return jax.grad(loss)

    def worker_grad(params: PyTree, batch: PyTree) -> PyTree:
        micro = jax.tree_util.tree_map(
            lambda x: x.reshape((microbatch, x.shape[0] // microbatch)
                                + x.shape[1:]), batch)
        zeros = jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params)

        def body(acc, mb):
            g = jax.grad(loss)(params, mb)
            return jax.tree_util.tree_map(
                lambda a, b: a + b.astype(a.dtype), acc, g), ()

        acc, _ = jax.lax.scan(body, zeros, micro)
        return jax.tree_util.tree_map(lambda g: g / microbatch, acc)

    return worker_grad
