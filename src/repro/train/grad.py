"""The gradient pipeline: one dispatch for how per-worker gradients are
computed, shared by the trainer and the dry-run launcher.

``make_grad_pipeline(loss, opt, ...)`` inspects the optimizer's config and
returns a :class:`GradPipeline` in one of three modes:

* **reference** — pytree state: ``vmap(value_and_grad(loss))`` over the
  stacked worker dim, with optional microbatch gradient accumulation
  (a lax.scan that divides activation memory by the microbatch count).
* **packed** — packed-resident state (``backend='pallas'``): the stacked
  per-worker losses are differentiated THROUGH ``packing.unpack`` w.r.t.
  the resident ``(K, rows, 128)`` buffer, so AD's transpose deposits the
  grads straight into the buffer — grads arrive packed with zero explicit
  pack/unpack. On a 2D (worker × model) mesh a ``plan`` threads
  ``launch.shardings.make_plan(mode='axis')``'s head-aware ``param_pspec``
  rules into the loss as sharding constraints, so GSPMD keeps matmul
  operands ``P(..., 'model')`` instead of replicating whole leaves per
  worker.
* **sharded-packed** — the 2D mesh with an explicitly model-parallel loss:
  the loss is evaluated INSIDE the optimizer's 2D shard_map, directly from
  each device's local ``(1, rows/M, 128)`` row-shard block via
  ``packing.unpack_local``. No collective can appear that the loss does
  not spell out — the compiled step provably contains **no full-parameter
  all-gather**, only the neighbor gossip and whatever psums the loss
  performs over the model axis (``analysis.hlo.collective_summary`` is
  the regression instrument; see ``tests/test_grad_pipeline.py``).

A model-parallel loss has the signature ``sharded_loss(chunks, batch,
ctx)`` where ``chunks`` are this shard's flat per-leaf slices (spec leaf
order, padding slots kept), ``batch`` is this worker's batch (replicated
over the model axis) and ``ctx`` is a :class:`ShardCtx` carrying the pack
spec plus the model-axis helpers: ``ctx.psum`` for activations that tie
shards together, ``ctx.mirror`` to slice congruent full-shape data into
the chunk layout, ``row_parallel_dot`` for matmuls whose weight rows live
in the chunk, and ``ctx.full_leaf`` to assemble a *small* leaf (a bias, a
scale vector) via one psum. It must return the worker's full loss
(replicated across its model group).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.kernels import pack as packing

PyTree = Any


# ------------------------- per-worker value+grad ----------------------------


def make_worker_grad(loss: Callable[[PyTree, PyTree], jax.Array],
                     microbatch: int = 1) -> Callable[[PyTree, PyTree],
                                                      PyTree]:
    """Per-worker gradient function: plain ``jax.grad`` for microbatch=1,
    or a lax.scan of gradient-accumulation steps that divides activation
    memory by the microbatch count (EXPERIMENTS.md §Perf iteration 9)."""
    if microbatch <= 1:
        return jax.grad(loss)
    vag = make_worker_value_and_grad(loss, microbatch)

    def worker_grad(params: PyTree, batch: PyTree) -> PyTree:
        return vag(params, batch)[1]

    return worker_grad


def make_worker_value_and_grad(loss: Callable[[PyTree, PyTree], jax.Array],
                               microbatch: int = 1) -> Callable:
    """(loss, grads) per worker, averaging both over the microbatches."""
    if microbatch <= 1:
        return jax.value_and_grad(loss)

    def worker_vag(params: PyTree, batch: PyTree):
        micro = _split_micro(batch, microbatch, batch_dim=0)
        zeros = jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params)

        def body(carry, mb):
            lsum, acc = carry
            l, g = jax.value_and_grad(loss)(params, mb)
            acc = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(a.dtype), acc, g)
            return (lsum + l, acc), ()

        (lsum, acc), _ = jax.lax.scan(body, (jnp.zeros(()), zeros), micro)
        return lsum / microbatch, jax.tree_util.tree_map(
            lambda g: g / microbatch, acc)

    return worker_vag


def _split_micro(batch: PyTree, microbatch: int, batch_dim: int) -> PyTree:
    """Reshape every leaf's batch dim b into a leading scan dim:
    (..., b, ...) -> (microbatch, ..., b/microbatch, ...)."""
    def split(path, x):
        b = x.shape[batch_dim]
        if b % microbatch:
            divisors = [d for d in range(1, b + 1) if b % d == 0]
            nearest = min(divisors, key=lambda d: (abs(d - microbatch), -d))
            raise ValueError(
                f"batch leaf {jax.tree_util.keystr(path) or '<root>'}: "
                f"per-worker batch dim {b} is not divisible into "
                f"{microbatch} accumulation chunks (microbatch / damping "
                f"max_chunks); nearest valid count is {nearest}")
        shape = (x.shape[:batch_dim] + (microbatch, b // microbatch)
                 + x.shape[batch_dim + 1:])
        return jnp.moveaxis(x.reshape(shape), batch_dim, 0)

    return jax.tree_util.tree_map_with_path(split, batch)


# ------------------------------ shard context -------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def psum_replicated(x: jax.Array, axis_name: str) -> jax.Array:
    """``lax.psum`` whose transpose assumes a REPLICATED cotangent — the
    invariant of a sharded loss, whose final scalar is identical on every
    shard of the model group.

    Under ``shard_map(check_rep=False)`` replication is untracked, so the
    transpose of a plain ``lax.psum`` is another psum: with the replicated
    cotangent of a loss that silently multiplies every gradient by the
    model-group size M. This wrapper's backward pass is the identity
    (each shard keeps its own cotangent), which is the correct adjoint for
    the replicated-loss pattern — it is what ``ShardCtx.psum`` uses, and
    what every sharded loss must reduce with."""
    return jax.lax.psum(x, axis_name)


def _psum_rep_fwd(x, axis_name):
    return jax.lax.psum(x, axis_name), None


def _psum_rep_bwd(axis_name, _, ct):
    return (ct,)


psum_replicated.defvjp(_psum_rep_fwd, _psum_rep_bwd)


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """What a model-parallel loss gets to know about its shard: the pack
    spec (leaf layout), the model mesh axis and its size. Built by the
    pipeline; only meaningful inside the 2D shard_map."""

    spec: packing.PackSpec
    axis_name: str           # the model mesh axis ('model')
    n_shards: int            # M

    @property
    def index(self) -> jax.Array:
        """This device's model-shard index (traced)."""
        return jax.lax.axis_index(self.axis_name)

    def psum(self, x: jax.Array) -> jax.Array:
        """Reduce over the model axis — the ONLY way shards may be tied
        together inside a sharded loss. Backward pass is the identity
        (see :func:`psum_replicated`); a raw ``lax.psum`` here would
        over-count every gradient by the model-group size."""
        return psum_replicated(x, self.axis_name)

    def mirror(self, tree: PyTree) -> PyTree:
        """Slice a congruent per-worker full-shape pytree (targets,
        anchors) into this shard's chunk layout — elementwise losses then
        work chunk-against-chunk with one final ``psum``."""
        return packing.mirror_local(tree, self.spec, self.index)

    def full_leaf(self, chunk: jax.Array, leaf_idx: int) -> jax.Array:
        """Assemble leaf ``leaf_idx``'s full per-worker value from this
        shard's chunk via ONE psum of the leaf's TRUE element count — for
        *small* leaves only (biases, norms, scales): the psum bytes are
        the leaf size, so using this on a big matrix would re-create the
        all-gather the pipeline exists to remove."""
        spec = self.spec
        sz = spec.sizes[leaf_idx]
        c = int(chunk.size)
        flat = chunk.reshape(-1)
        # each global element i lives on shard i // c at local offset
        # i % c; gather this shard's overlap with the true range and psum
        local = jnp.arange(sz) - self.index * c
        mine = (local >= 0) & (local < c)
        vals = jnp.where(mine, flat[jnp.clip(local, 0, c - 1)], 0)
        return self.psum(vals).reshape(spec.shapes[leaf_idx][1:])


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def _slice_replicated(x: jax.Array, rows_local: int, axis_name: str
                      ) -> jax.Array:
    """This shard's ``rows_local`` slice of a REPLICATED activation's last
    dim. Backward pass scatters the cotangent into the full width and
    psums it over the model axis, so the cotangent leaving this op is
    replicated again — the invariant :func:`psum_replicated`'s identity
    transpose relies on. With a raw ``dynamic_slice`` instead, stacking
    two row-parallel layers would feed a partial (slice-shaped) cotangent
    into the lower layer and silently zero most of its weight grads."""
    idx = jax.lax.axis_index(axis_name)
    return jax.lax.dynamic_slice_in_dim(x, idx * rows_local, rows_local,
                                        axis=x.ndim - 1)


def _slice_rep_fwd(x, rows_local, axis_name):
    return _slice_replicated(x, rows_local, axis_name), x.shape


def _slice_rep_bwd(rows_local, axis_name, x_shape, ct):
    idx = jax.lax.axis_index(axis_name)
    full = jnp.zeros(x_shape, ct.dtype)
    full = jax.lax.dynamic_update_slice_in_dim(full, ct, idx * rows_local,
                                               axis=len(x_shape) - 1)
    return (jax.lax.psum(full, axis_name),)


_slice_replicated.defvjp(_slice_rep_fwd, _slice_rep_bwd)


def row_parallel_dot(x: jax.Array, w_chunk: jax.Array, d_out: int,
                     ctx: ShardCtx) -> jax.Array:
    """``x @ W`` with W's rows living in this shard's flat chunk — the
    Megatron row-parallel linear over the packed layout.

    The chunk is a contiguous slice of the flattened (d_in, d_out) matrix;
    when the per-shard chunk is a whole number of rows (any power-of-two
    ``d_out`` up to the tile quantum, since chunks are multiples of
    BLOCK_ROWS*LANE elements) it reshapes to a (rows_local, d_out)
    operand — effectively ``P('model', None)`` — and the activation psums
    over the model axis. Padding rows are zero, so the columns of ``x``
    beyond d_in contribute nothing.

    ``x`` must be replicated over the model axis (a batch, or a previous
    layer's psum'd activation); the output is replicated again, so
    row-parallel layers COMPOSE — the input slice re-replicates its
    cotangent (one activation-sized psum in backward, mirroring the
    forward psum; see :func:`_slice_replicated`)."""
    c = int(w_chunk.size)
    if c % d_out:
        raise ValueError(
            f"chunk of {c} elements is not whole rows of d_out={d_out}; "
            "pick a d_out dividing the tile quantum "
            f"({packing.BLOCK_ROWS * packing.LANE} elements)")
    rows_local = c // d_out
    W = w_chunk.reshape(rows_local, d_out)
    rows_total = rows_local * ctx.n_shards
    d_in = x.shape[-1]
    if rows_total < d_in:
        raise ValueError(f"chunked rows {rows_total} < d_in {d_in}")
    pad = [(0, 0)] * (x.ndim - 1) + [(0, rows_total - d_in)]
    xl = _slice_replicated(jnp.pad(x, pad), rows_local, ctx.axis_name)
    return ctx.psum(xl @ W.astype(x.dtype))


# ------------------------------- the pipeline -------------------------------


@dataclasses.dataclass(frozen=True)
class GradPipeline:
    """A ``value_and_grad(state, batch) -> (losses (K,), grads)`` where
    ``grads`` is in the optimizer's native form: a stacked pytree
    (reference), a packed ``(K, rows, 128)`` buffer (packed), or a buffer
    sharded ``P('worker', 'model')`` (sharded-packed).

    With ``damping_chunks`` > 0 the signature grows a third argument:
    ``value_and_grad(state, batch, n)`` where ``n`` is a traced ``(K,)``
    int32 of per-worker live-chunk counts — the pipeline always scans
    over ``damping_chunks`` fixed-shape chunks and masks the tail beyond
    each worker's ``n[k]``, so every damping level shares ONE compiled
    program (see ``train.damping``)."""

    mode: str                 # 'reference' | 'packed' | 'sharded-packed'
    value_and_grad: Callable[..., Any]
    microbatch: int = 1
    damping_chunks: int = 0   # 0 = undamped 2-arg pipeline


def make_grad_pipeline(loss: Callable[[PyTree, PyTree], jax.Array],
                       opt: Any, *, microbatch: int = 1,
                       sharded_loss: Optional[Callable] = None,
                       plan: Any = None,
                       damping_chunks: int = 0) -> GradPipeline:
    """Build the gradient pipeline for ``opt`` (a DecentralizedOptimizer).

    Dispatch: ``backend='pallas'`` states are packed-resident → the
    differentiate-through-unpack path; with a 2D (worker × model) mesh AND
    a ``sharded_loss``, the loss instead runs model-parallel inside the
    shard_map on local row shards (no full-param all-gather). Everything
    else takes the reference vmap path. ``plan`` (a
    ``launch.shardings.ShardingPlan``) only affects the packed-GSPMD 2D
    fallback: the plan's ``param_pspec`` rules are applied to the unpacked
    leaves as sharding constraints.

    Args:
      loss: per-worker scalar loss ``(params, batch) -> float`` (no K
        dim on either argument; the pipeline adds the worker dim).
      opt: a ``DecentralizedOptimizer``; its config decides the mode.
      microbatch: gradient-accumulation chunks per step (>= 1).
      sharded_loss: ``(local_block, batch) -> scalar`` evaluated inside
        the shard_map on each device's ``(1, rows/M, 128)`` row shard;
        selects the ``'sharded-packed'`` mode on a 2D mesh.
      plan: sharding constraints for the 2D GSPMD fallback only.
      damping_chunks: > 0 builds the adaptive-batch-damping variant of
        the mode: a 3-arg ``value_and_grad(state, batch, n)`` that scans
        over this many fixed-shape chunks and masks chunks past each
        worker's traced live count ``n[k]`` (``train.damping``). One
        compiled program serves every damping level. Mutually exclusive
        with ``microbatch`` > 1 (damping owns the accumulation loop).

    Returns:
      A :class:`GradPipeline` — ``mode`` in ``('reference', 'packed',
      'sharded-packed')`` and ``value_and_grad(state, batch) ->
      (losses (K,), grads)`` with ``grads`` in the optimizer's native
      form (stacked pytree / packed buffer / sharded packed buffer).

    Raises:
      ValueError: ``microbatch < 1``, or ``sharded_loss`` given without
        a 2D comm='axis' optimizer to host it.

    Example:
      >>> import jax.numpy as jnp
      >>> from repro.core import make_optimizer
      >>> from repro.train.grad import make_grad_pipeline
      >>> opt = make_optimizer("d-adam", K=2, eta=1e-2)
      >>> pipe = make_grad_pipeline(
      ...     lambda p, b: jnp.mean((p["w"] - b) ** 2), opt)
      >>> pipe.mode
      'reference'
      >>> losses, grads = pipe.value_and_grad(
      ...     opt.init({"w": jnp.zeros((2, 3))}), jnp.ones((2, 3)))
      >>> losses.shape, grads["w"].shape
      ((2,), (2, 3))
    """
    cfg = opt.cfg
    packed = getattr(cfg, "backend", "reference") == "pallas"
    M = int(getattr(cfg, "model_parallel", 1))
    if microbatch < 1:
        raise ValueError(f"microbatch must be >= 1, got {microbatch}")
    if damping_chunks:
        if damping_chunks < 1:
            raise ValueError(
                f"damping_chunks must be >= 1, got {damping_chunks}")
        if microbatch > 1:
            raise ValueError(
                "damping owns the accumulation loop (its max_chunks IS "
                "the chunk count); microbatch > 1 alongside "
                "damping_chunks is ambiguous — set one, not both")

    if packed and M > 1 and sharded_loss is not None:
        if opt.sharded_value_and_grad is None:
            raise ValueError(
                "sharded_loss needs a 2D comm='axis' optimizer (mesh with "
                "a 'model' axis); this one has no sharded execution hook")
        if damping_chunks:
            vag = _sharded_packed_damped_vag(sharded_loss, opt,
                                             damping_chunks)
            return GradPipeline("sharded-packed", vag, 1, damping_chunks)
        vag = _sharded_packed_vag(sharded_loss, opt, microbatch)
        return GradPipeline("sharded-packed", vag, microbatch)
    if packed:
        if damping_chunks:
            vag = _packed_damped_vag(loss, opt, damping_chunks, plan)
            return GradPipeline("packed", vag, 1, damping_chunks)
        vag = _packed_vag(loss, opt, microbatch, plan)
        return GradPipeline("packed", vag, microbatch)
    if damping_chunks:
        worker_vag = _damped_worker_vag(loss, damping_chunks)

        def reference_damped_vag(state, batch, n):
            return jax.vmap(worker_vag)(opt.params_of(state), batch, n)

        return GradPipeline("reference", reference_damped_vag, 1,
                            damping_chunks)
    worker_vag = make_worker_value_and_grad(loss, microbatch)

    def reference_vag(state, batch):
        return jax.vmap(worker_vag)(opt.params_of(state), batch)

    return GradPipeline("reference", reference_vag, microbatch)


def _loss_constraints(plan: Any, tree: PyTree) -> PyTree:
    """Thread the plan's head-aware ``param_pspec`` rules into the loss
    (lazy import: the launch layer depends on configs the core trainer
    users may not touch)."""
    from repro.launch.shardings import loss_param_constraints

    return loss_param_constraints(plan, tree)


def _packed_vag(loss, opt, microbatch: int, plan: Any):
    """Differentiate-through-unpack, w.r.t. the resident buffer."""

    def vag(state, batch):
        spec = state.spec

        def one(buf, b):
            def stacked_loss(bf):
                params = packing.unpack(bf, spec)
                if plan is not None:
                    params = _loss_constraints(plan, params)
                losses = jax.vmap(loss)(params, b)
                return jnp.sum(losses), losses

            (_, losses), g = jax.value_and_grad(
                stacked_loss, has_aux=True)(buf)
            return losses, g

        if microbatch <= 1:
            return one(state.buf, batch)
        micro = _split_micro(batch, microbatch, batch_dim=1)
        K = state.buf.shape[0]

        def body(carry, mb):
            lsum, acc = carry
            losses, g = one(state.buf, mb)
            return (lsum + losses, acc + g), ()

        init = (jnp.zeros((K,)), jnp.zeros_like(state.buf))
        (lsum, acc), _ = jax.lax.scan(body, init, micro)
        return lsum / microbatch, acc / microbatch

    return vag


# --------------------- adaptive-batch-damped variants ------------------------
#
# Same three modes, scanning over ``C = damping_chunks`` FIXED-shape
# chunks with a mask ``i < n[k]`` on each worker's contribution — the
# chunk count is a traced int, the shapes are static, so one compiled
# program serves every damping level. Masking is ``jnp.where`` (not a
# multiply) so a NaN in an unused chunk's loss/grads cannot poison the
# sum through ``0 * nan``; loss and grads divide by the LIVE count.


def _damped_worker_vag(loss, C: int):
    """Per-worker damped value+grad: ``(params, batch, n_k) ->
    (loss, grads)`` averaged over the first ``n_k`` of ``C`` chunks."""

    def worker_vag(params: PyTree, batch: PyTree, n_k: jax.Array):
        micro = _split_micro(batch, C, batch_dim=0)
        zeros = jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), params)

        def body(carry, xs):
            mb, i = xs
            lsum, acc = carry
            l, g = jax.value_and_grad(loss)(params, mb)
            use = i < n_k
            acc = jax.tree_util.tree_map(
                lambda a, b: a + jnp.where(use, b.astype(a.dtype), 0), acc,
                g)
            return (lsum + jnp.where(use, l, 0.0), acc), ()

        (lsum, acc), _ = jax.lax.scan(body, (jnp.zeros(()), zeros),
                                      (micro, jnp.arange(C)))
        nf = n_k.astype(jnp.float32)
        return lsum / nf, jax.tree_util.tree_map(lambda g: g / nf, acc)

    return worker_vag


def _packed_damped_vag(loss, opt, C: int, plan: Any):
    """Damped differentiate-through-unpack: the per-worker mask
    ``i < n (K,)`` zeroes whole workers' chunk contributions."""

    def vag(state, batch, n):
        spec = state.spec

        def one(buf, b):
            def stacked_loss(bf):
                params = packing.unpack(bf, spec)
                if plan is not None:
                    params = _loss_constraints(plan, params)
                losses = jax.vmap(loss)(params, b)
                return jnp.sum(losses), losses

            (_, losses), g = jax.value_and_grad(
                stacked_loss, has_aux=True)(buf)
            return losses, g

        micro = _split_micro(batch, C, batch_dim=1)
        K = state.buf.shape[0]

        def body(carry, xs):
            mb, i = xs
            lsum, acc = carry
            losses, g = one(state.buf, mb)
            use = i < n  # (K,) bool
            losses = jnp.where(use, losses, 0.0)
            g = jnp.where(use[:, None, None], g, 0.0)
            return (lsum + losses, acc + g), ()

        init = (jnp.zeros((K,)), jnp.zeros_like(state.buf))
        (lsum, acc), _ = jax.lax.scan(body, init,
                                      (micro, jnp.arange(C)))
        nf = n.astype(jnp.float32)
        return lsum / nf, acc / nf[:, None, None]

    return vag


def _sharded_packed_damped_vag(sharded_loss, opt, C: int):
    """Damped model-parallel path. The per-worker count ``n (K,)`` rides
    INTO the 2D shard_map as part of the batch argument —
    ``worker_pspec_tree`` gives any leading-K leaf ``P('worker')``, so
    each worker's shard sees its own ``(1,)`` slice. The mask lives
    inside the shard_map; no new collectives, the zero-all-gather
    property is untouched (``analysis.check``'s 'damping' variant pins
    it)."""
    cfg = opt.cfg
    ctx_axis = cfg.model_axis_name
    M = int(cfg.model_parallel)

    def vag(state, batch, n):
        spec = state.spec
        ctx = ShardCtx(spec=spec, axis_name=ctx_axis, n_shards=M)

        def local_vag(buf_local, batch_n):
            batch_local, n_local = batch_n
            n_k = n_local[0]
            one_batch = jax.tree_util.tree_map(lambda x: x[0], batch_local)

            def local_loss(bl, b):
                chunks = jax.tree_util.tree_map(
                    lambda x: x[0], packing.unpack_local(bl, spec))
                return sharded_loss(chunks, b, ctx)

            micro = _split_micro(one_batch, C, batch_dim=0)

            def body(carry, xs):
                mb, i = xs
                lsum, acc = carry
                l, g = jax.value_and_grad(local_loss)(buf_local, mb)
                use = i < n_k
                lsum = lsum + jnp.where(use, l, 0.0)
                acc = acc + jnp.where(use, g, 0.0)
                return (lsum, acc), ()

            init = (jnp.zeros(()), jnp.zeros_like(buf_local))
            (lsum, acc), _ = jax.lax.scan(body, init,
                                          (micro, jnp.arange(C)))
            nf = n_k.astype(jnp.float32)
            return (lsum / nf)[None], acc / nf

        return opt.sharded_value_and_grad(local_vag, state,
                                          (batch, n))

    return vag


def _sharded_packed_vag(sharded_loss, opt, microbatch: int):
    """The model-parallel path: evaluate the loss inside the 2D shard_map
    from each device's local row-shard block (``packing.unpack_local``);
    AD's transpose of the local slicing deposits the grads straight into
    the local block, so the grads buffer comes out sharded exactly like
    the state — zero resharding, zero all-gather."""
    cfg = opt.cfg
    ctx_axis = cfg.model_axis_name
    M = int(cfg.model_parallel)

    def vag(state, batch):
        spec = state.spec  # static pytree aux — fixed per trace
        ctx = ShardCtx(spec=spec, axis_name=ctx_axis, n_shards=M)

        def local_vag(buf_local, batch_local):
            # buf_local: (1, rows/M, LANE); batch_local leaves: (1, b, ...)
            one_batch = jax.tree_util.tree_map(lambda x: x[0], batch_local)

            def local_loss(bl, b):
                chunks = jax.tree_util.tree_map(
                    lambda x: x[0], packing.unpack_local(bl, spec))
                return sharded_loss(chunks, b, ctx)

            def one(b):
                return jax.value_and_grad(local_loss)(buf_local, b)

            if microbatch <= 1:
                l, g = one(one_batch)
                return l[None], g
            micro = _split_micro(one_batch, microbatch, batch_dim=0)

            def body(carry, mb):
                lsum, acc = carry
                l, g = one(mb)
                return (lsum + l, acc + g), ()

            init = (jnp.zeros(()), jnp.zeros_like(buf_local))
            (lsum, acc), _ = jax.lax.scan(body, init, micro)
            return (lsum / microbatch)[None], acc / microbatch

        return opt.sharded_value_and_grad(local_vag, state, batch)

    return vag


def sharded_loss_probe(sharded_loss, opt):
    """Forward-only twin of the sharded-packed pipeline, for the static
    analyzer (``repro.analysis.jaxpr_lint``).

    AD *inlines* custom_vjp bodies, so a grad trace of a protected and a
    raw-psum loss are structurally indistinguishable. This probe evaluates
    ``sharded_loss`` inside the SAME 2D shard_map the pipeline uses but
    without differentiating, so the ``psum_replicated`` /
    ``_slice_replicated`` boundaries stay visible as
    ``custom_vjp_call_jaxpr`` equations — the forward JXL001 rule and the
    backward psum-count check both key off this trace."""
    cfg = opt.cfg
    ctx_axis = cfg.model_axis_name
    M = int(cfg.model_parallel)
    if opt.sharded_value_and_grad is None:
        raise ValueError(
            "sharded_loss_probe needs a 2D comm='axis' optimizer (mesh "
            "with a 'model' axis); this one has no sharded execution hook")

    def fwd(state, batch):
        spec = state.spec
        ctx = ShardCtx(spec=spec, axis_name=ctx_axis, n_shards=M)

        def local_fwd(buf_local, batch_local):
            b = jax.tree_util.tree_map(lambda x: x[0], batch_local)
            chunks = jax.tree_util.tree_map(
                lambda x: x[0], packing.unpack_local(buf_local, spec))
            # identity second output satisfies the (losses, grads-buffer)
            # out_specs contract of the sharded execution hook
            return sharded_loss(chunks, b, ctx)[None], buf_local

        return opt.sharded_value_and_grad(local_fwd, state, batch)

    return fwd
