"""repro: Adaptive Serverless Learning (Gao & Huang, 2020) — D-Adam and
CD-Adam as a production multi-pod JAX/TPU framework.

Public entry points:
    repro.core       — make_optimizer / topologies / compressors (the paper)
    repro.models     — build_model over six architecture families
    repro.train      — DecentralizedTrainer
    repro.serve      — prefill/decode engine
    repro.launch     — production meshes, dry-run, train/serve drivers
    repro.kernels    — Pallas TPU kernels (+ interpret-mode CPU validation)
    repro.analysis   — trip-count-aware HLO cost model + roofline
"""
__version__ = "1.0.0"
