"""Trip-count-aware HLO text analysis.

``compiled.cost_analysis()`` visits every ``while`` body ONCE — for a
scan-over-layers model that undercounts flops/bytes/collectives by the trip
count (verified in tests). This module re-derives the three roofline inputs
from the partitioned HLO text with loop multipliers applied:

  * flops            — dot ops (2 * prod(result) * contracted), plus 1/elem
                       for elementwise math inside fusions;
  * bytes accessed   — per top-level instruction: operand + result bytes
                       (fusions opaque, views skipped) — the HBM-traffic
                       approximation HloCostAnalysis itself uses;
  * collective bytes — operand bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute,
                       derived from result shapes per collective semantics.

Loop trip counts are read from each while's condition computation (the
`compare(iter, constant)` pattern JAX scans produce); conditionals count
each branch once (upper bound); unknown trip counts fall back to 1 and are
flagged in the result.
"""
from __future__ import annotations

import dataclasses
import re
import warnings
from typing import Any, Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "s2": 1, "u2": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "f8e8m0fnu": 1, "f4e2m1fn": 1,
    # shape-only placeholders that carry no data bytes
    "token": 0, "opaque": 0,
}

# A dtype the table does not know is counted at this width and WARNED about
# (once per dtype per process) instead of being silently dropped — an
# invariant gate built on byte accounting that quietly zeroes unknown
# dtypes is a false pass. ``HloCost.unknown_dtypes`` carries the per-dtype
# element counts so spec gates can fail hard on them.
_UNKNOWN_DTYPE_BYTES = 4
_WARNED_DTYPES: set = set()

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# dtype tokens follow the XLA grammar (pred/token/opaque/bf16/cNN plus
# [fsu]<digits><suffix> families); matching any lowercase word would pick
# up identifiers like `bufs[1]` out of op metadata and miscount them as
# unknown-dtype shapes
_SHAPE_RE = re.compile(
    r"\b(pred|token|opaque|bf16|c64|c128|[fsu][0-9][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s([\w\-]+)\((.*)$")
_REF_RE = re.compile(r"%([\w.\-]+)")
_CONST_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*[su]\d+\[\]\s+constant\((\d+)\)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")

# elementwise ops that cost ~1 flop/element (transcendentals cost more on
# real hardware; HloCostAnalysis also counts 1)
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "tanh", "exponential", "log", "rsqrt", "sqrt", "negate", "abs", "sign",
    "cosine", "sine", "logistic", "expm1", "log1p", "atan2", "remainder",
}


def _warn_unknown_dtype(dtype: str) -> None:
    if dtype in _WARNED_DTYPES:
        return
    _WARNED_DTYPES.add(dtype)
    warnings.warn(
        f"HLO dtype {dtype!r} missing from analysis table; counting "
        f"{_UNKNOWN_DTYPE_BYTES} bytes/element. Extend "
        "repro.analysis.hlo._DTYPE_BYTES to make byte budgets exact.",
        RuntimeWarning, stacklevel=3)


def _elem_count(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _shape_list_bytes(text: str) -> int:
    return sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(text))


def _shape_bytes(dtype: str, dims: str) -> int:
    n = _elem_count(dims)
    if dtype not in _DTYPE_BYTES:
        _warn_unknown_dtype(dtype)
        return n * _UNKNOWN_DTYPE_BYTES
    return n * _DTYPE_BYTES[dtype]


def _shape_elems(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            _warn_unknown_dtype(dtype)
        total += _elem_count(dims)
    return total


def unknown_dtypes_in(text: str) -> Dict[str, int]:
    """dtype -> total element count for every HLO shape whose dtype the
    byte table does not know. Non-empty means every byte figure derived
    from this HLO is an estimate, not an account — spec gates fail on it
    unless explicitly allowed."""
    out: Dict[str, int] = {}
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            out[dtype] = out.get(dtype, 0) + _elem_count(dims)
    return out


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    op: str
    operands: List[str]
    attrs: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr] = dataclasses.field(default_factory=list)
    by_name: Dict[str, Instr] = dataclasses.field(default_factory=dict)
    constants: Dict[str, int] = dataclasses.field(default_factory=dict)


def _split_operands_attrs(rest: str) -> Tuple[str, str]:
    """rest = everything after 'op(' — split at the matching ')'."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i], rest[i + 1:]
    return rest, ""


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for line in text.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr and (" {" in line or line.rstrip().endswith("{")):
            cur = Computation(hdr.group(2))
            comps[cur.name] = cur
            if hdr.group(1):
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        cm = _CONST_RE.match(line)
        if cm:
            cur.constants[cm.group(1)] = int(cm.group(2))
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rtype, op, rest = m.groups()
        operands_text, attrs = _split_operands_attrs(rest)
        operands = _REF_RE.findall(operands_text)
        ins = Instr(name, rtype, op, operands, attrs, line)
        cur.instrs.append(ins)
        cur.by_name[name] = ins
    return comps, entry


def _trip_count(comps: Dict[str, Computation], cond_name: str
                ) -> Optional[int]:
    cond = comps.get(cond_name)
    if cond is None:
        return None
    for ins in cond.instrs:
        if ins.op == "compare":
            for o in ins.operands:
                if o in cond.constants:
                    return cond.constants[o]
    # fallback: single integer constant in the condition
    if len(cond.constants) == 1:
        return next(iter(cond.constants.values()))
    return None


def _group_size(attrs: str, default: int = 1) -> int:
    m = _GROUPS_IOTA_RE.search(attrs)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(attrs)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return default


def _dot_flops(ins: Instr, comp: Computation) -> float:
    result_elems = _shape_elems(ins.result_type)
    # contracted size from lhs shape + lhs_contracting_dims
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    contract = 1
    if m and ins.operands:
        lhs = comp.by_name.get(ins.operands[0])
        if lhs is not None:
            shapes = _SHAPE_RE.findall(lhs.result_type)
            if shapes:
                dims = shapes[0][1].split(",") if shapes[0][1] else []
                for idx in (m.group(1).split(",") if m.group(1) else []):
                    i = int(idx)
                    if i < len(dims):
                        contract *= int(dims[i])
    return 2.0 * result_elems * contract


_VIEW_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "custom-call", "partition-id",
             "replica-id", "iota", "rng-bit-generator"}

# ops that fuse into neighbors on TPU (no independent HBM round-trip)
_FUSABLE = {"convert", "broadcast", "reshape", "transpose", "select",
            "compare", "slice", "clamp", "and", "or", "not", "xor",
            "shift-left", "shift-right-logical", "shift-right-arithmetic",
            "is-finite", "floor", "ceil", "round-nearest-afz",
            "round-nearest-even", "reduce-precision", "map", "exponential-minus-one"}


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})
    # largest single collective instruction per kind (operand bytes, NOT
    # multiplied by loop trip counts) — the "is there an all-gather of
    # full-parameter size in this step?" regression instrument
    coll_max: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})
    # matched async start/done pairs per kind: XLA splits a collective
    # into <kind>-start / <kind>-done exactly when it can overlap the
    # wire with independent compute (async collectives / latency-hiding
    # scheduler, repro.launch.env) — each -done closes one pair, so
    # counting them counts the collectives that actually ran async
    coll_async: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVE_KINDS})
    unknown_trip_counts: int = 0
    # largest single resolved while-loop trip count (not nested-multiplied)
    max_trip_count: int = 0
    # dtype -> element count for shapes the byte table can't account
    unknown_dtypes: Dict[str, int] = dataclasses.field(default_factory=dict)

    def total_coll(self) -> float:
        return sum(self.coll.values())

    def as_dict(self) -> Dict[str, Any]:
        d = {k: int(v) for k, v in self.coll.items()}
        d["total"] = int(self.total_coll())
        return d


def _operand_bytes(ins: Instr, comp: Computation) -> int:
    total = 0
    for o in ins.operands:
        src = comp.by_name.get(o)
        if src is not None:
            total += _shape_list_bytes(src.result_type)
    return total


def _collective_operand_bytes(ins: Instr, kind: str,
                              comp: Computation) -> float:
    result = _shape_list_bytes(ins.result_type)
    g = _group_size(ins.attrs)
    if kind == "all-gather":
        return result / max(g, 1)
    if kind == "reduce-scatter":
        return result * g
    return float(result)  # all-reduce / permute / all-to-all


def analyze(text: str) -> HloCost:
    comps, entry = parse_hlo(text)
    cost = HloCost()
    if entry is None:
        return cost
    visited_stack: List[str] = []

    def visit(comp_name: str, mult: float) -> None:
        comp = comps.get(comp_name)
        if comp is None or comp_name in visited_stack:
            return
        visited_stack.append(comp_name)
        for ins in comp.instrs:
            base_kind = re.sub(r"-(start|done)$", "", ins.op)
            if base_kind in COLLECTIVE_KINDS:
                if ins.op.endswith("-done"):
                    cost.coll_async[base_kind] += mult
                    continue
                one = _collective_operand_bytes(ins, base_kind, comp)
                cost.coll[base_kind] += mult * one
                cost.coll_counts[base_kind] += mult
                cost.coll_max[base_kind] = max(cost.coll_max[base_kind],
                                               one)
                cost.bytes += mult * _shape_list_bytes(ins.result_type)
                continue
            if ins.op == "while":
                m = re.search(r"condition=%?([\w.\-]+)", ins.attrs)
                b = re.search(r"body=%?([\w.\-]+)", ins.attrs)
                trip = _trip_count(comps, m.group(1)) if m else None
                if trip is None:
                    trip = 1
                    cost.unknown_trip_counts += 1
                else:
                    cost.max_trip_count = max(cost.max_trip_count, trip)
                if b:
                    visit(b.group(1), mult * trip)
                continue
            if ins.op == "conditional":
                for bname in re.findall(r"%([\w.\-]+)",
                                        ins.attrs.split("branch_computations="
                                                        )[-1]) \
                        if "branch_computations" in ins.attrs else []:
                    visit(bname, mult)
                m = re.search(r"true_computation=%?([\w.\-]+)", ins.attrs)
                if m:
                    visit(m.group(1), mult)
                m = re.search(r"false_computation=%?([\w.\-]+)", ins.attrs)
                if m:
                    visit(m.group(1), mult)
                continue
            if ins.op in ("call", "async-start"):
                m = re.search(r"to_apply=%?([\w.\-]+)", ins.attrs)
                if m:
                    visit(m.group(1), mult)
                continue
            if ins.op == "fusion":
                # TPU-target model: fusions do not round-trip HBM beyond
                # what their producing/consuming dots and slices already
                # account for. (Counting every CPU kLoop micro-fusion's
                # operands overstates the memory term ~10x — verified
                # against the per-op profile in EXPERIMENTS.md.)
                m = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
                if m:
                    _visit_fusion_flops(m.group(1), mult)
                continue
            if ins.op == "dot":
                cost.flops += mult * _dot_flops(ins, comp)
                cost.bytes += mult * (_shape_list_bytes(ins.result_type)
                                      + _operand_bytes(ins, comp))
                continue
            if ins.op == "convolution":
                # rough: 2 * result_elems * (kernel elems / output channels)
                cost.flops += mult * 2.0 * _shape_elems(ins.result_type)
                cost.bytes += mult * (_shape_list_bytes(ins.result_type)
                                      + _operand_bytes(ins, comp))
                continue
            if ins.op in _VIEW_OPS:
                continue
            if ins.op in _ELEMENTWISE or ins.op in _FUSABLE:
                # flops only: these fuse into neighbors on TPU.
                if ins.op in _ELEMENTWISE:
                    cost.flops += mult * _shape_elems(ins.result_type)
                continue
            if ins.op in ("dynamic-update-slice", "dynamic-slice", "gather",
                          "pad", "copy", "concatenate", "sort", "copy-start"):
                cost.bytes += mult * _shape_list_bytes(ins.result_type)
                continue
            if ins.op in ("reduce", "reduce-window", "scatter",
                          "select-and-scatter"):
                cost.bytes += mult * _operand_bytes(ins, comp)
                continue
            cost.bytes += mult * _shape_list_bytes(ins.result_type)
        visited_stack.pop()

    def _visit_fusion_flops(comp_name: str, mult: float) -> None:
        comp = comps.get(comp_name)
        if comp is None:
            return
        for ins in comp.instrs:
            if ins.op == "dot":
                cost.flops += mult * _dot_flops(ins, comp)
            elif ins.op == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", ins.attrs)
                if m:
                    _visit_fusion_flops(m.group(1), mult)
            elif ins.op in _ELEMENTWISE:
                cost.flops += mult * _shape_elems(ins.result_type)

    visit(entry, 1.0)
    cost.unknown_dtypes = unknown_dtypes_in(text)
    return cost


# ------------------------------ public API -----------------------------------


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-kind collective operand bytes with while-loop multipliers."""
    return analyze(hlo_text).as_dict()


def collective_counts(hlo_text: str) -> Dict[str, int]:
    c = analyze(hlo_text)
    return {k: int(v) for k, v in c.coll_counts.items()}


def collective_summary(hlo_text: str) -> Dict[str, Dict[str, int]]:
    """Per-kind {count, bytes, max_bytes, async_pairs} — the
    communication regression
    instrument. ``count``/``bytes`` carry while-loop trip multipliers;
    ``max_bytes`` is the largest SINGLE collective of that kind, which is
    what "no all-gather of full-parameter size" assertions compare against
    (a trip-multiplied total would flag many small collectives as one big
    one)."""
    c = analyze(hlo_text)
    return {k: {"count": int(c.coll_counts[k]),
                "bytes": int(c.coll[k]),
                "max_bytes": int(c.coll_max[k]),
                "async_pairs": int(c.coll_async[k])}
            for k in COLLECTIVE_KINDS}


def full_cost(hlo_text: str) -> Dict[str, float]:
    c = analyze(hlo_text)
    d = {"flops": c.flops, "bytes": c.bytes,
         "unknown_trip_counts": c.unknown_trip_counts,
         "max_trip_count": c.max_trip_count,
         "unknown_dtype_elems": sum(c.unknown_dtypes.values())}
    d.update({f"coll_{k}": v for k, v in c.coll.items()})
    d["coll_total"] = c.total_coll()
    return d
