"""``repro.analysis.check`` — the three-pass shard-safety analyzer.

One entry point (:func:`run`) sweeps the shipped execution configs
(reference / packed / axis / axis2d × D-Adam / CD-Adam × plain / schedule
/ staleness / overlap variants) and, per config:

1. **jaxpr lint** — wrong-axis collectives on the full compiled step
   (JXL002), raw-collective rules (JXL001, forward + backward psum
   accounting) on the sharded-loss probe where one exists;
2. **HLO invariant gates** — an :class:`~.invariants.InvariantSpec`
   derived from the config (zero all-gathers everywhere, permute byte
   budgets from ``comm_bytes_per_round``-style block accounting, small
   activation all-reduces, bounded trips, no unknown dtypes) evaluated on
   the compiled step;
3. **topology invariants** — INV006/INV007 over the zoo + the schedule
   entries the sweep uses.

plus a **known-bug corpus** (:func:`run_corpus`) that must FAIL with the
expected rule IDs — a deliberately raw-psum sharded loss (PR-5 bug class,
JXL001 + RPR001) and a circulant-where-GridShift-needed torus mixing
matrix (PR-6 bug class, INV006). The corpus failing to fail fails the
gate: an analyzer that can't see the bugs it was built for is broken.

Used by ``scripts/check_invariants.py`` (the CI gate) and importable from
tests. Requires enough host devices for the axis configs (the script
forces 8 via XLA_FLAGS before importing jax).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.analysis import astlint
from repro.analysis.invariants import (InvariantReport, InvariantSpec,
                                       check_topology, evaluate_hlo)
from repro.analysis.jaxpr_lint import Finding, lint_fn, lint_grad_psums

# ------------------------- the sweep model/loss ------------------------------

# sized so the weight leaf spans both model shards at M=2 (rows_total ==
# d_in through the packed tile quantum; see row_parallel_dot)
DIN, DOUT, B = 512, 64, 8
_KEY = jax.random.PRNGKey(7)


def _params():
    return {"bias": jnp.zeros((DOUT,)),
            "w": jax.random.normal(_KEY, (DIN, DOUT)) * 0.02}


def _loss(p, batch):
    pred = batch["x"] @ p["w"] + p["bias"]
    return jnp.mean((pred - batch["y"]) ** 2)


def _sharded_loss(chunks, batch, ctx):
    from repro.train.grad import row_parallel_dot

    h = row_parallel_dot(batch["x"], chunks["w"], DOUT, ctx)
    pred = h + ctx.full_leaf(chunks["bias"], 0)
    return jnp.mean((pred - batch["y"]) ** 2)


def _batch(K):
    return {"x": jax.random.normal(_KEY, (K, B, DIN)),
            "y": jax.random.normal(jax.random.fold_in(_KEY, 1),
                                   (K, B, DOUT))}


# ------------------------------ sweep configs --------------------------------


@dataclasses.dataclass(frozen=True)
class SweepConfig:
    backend: str            # 'reference' | 'packed' | 'axis' | 'axis2d'
    kind: str               # 'd-adam' | 'cd-adam'
    variant: str    # 'plain' | 'schedule' | 'stale' | 'overlap' | 'damping'
    K: int = 4
    M: int = 1

    @property
    def name(self) -> str:
        return f"{self.backend}/{self.kind}/{self.variant}"

    @property
    def devices_needed(self) -> int:
        if self.backend == "axis2d":
            return self.K * self.M
        if self.backend == "axis":
            return self.K
        return 1


BACKENDS = ("reference", "packed", "axis", "axis2d")
KINDS = ("d-adam", "cd-adam")
VARIANTS = ("plain", "schedule", "stale", "overlap", "damping")


def sweep_configs(backends: Sequence[str] = BACKENDS,
                  kinds: Sequence[str] = KINDS,
                  variants: Sequence[str] = VARIANTS) -> List[SweepConfig]:
    out = []
    for b in backends:
        for k in kinds:
            for v in variants:
                # config validation rejects these combinations: staleness
                # buffers are per-worker payload copies (no row-sharding,
                # so no model_parallel), and CD-Adam's per-edge delay
                # rings have no per-shard addressing under comm='axis'
                if v == "stale" and (b == "axis2d"
                                     or (k == "cd-adam" and b == "axis")):
                    continue
                out.append(SweepConfig(b, k, v,
                                       M=2 if b == "axis2d" else 1))
    return out


def _build(cfg: SweepConfig):
    """(trainer, opt, state, placed batch) for one sweep config."""
    from repro.core import make_optimizer
    from repro.train import DecentralizedTrainer

    kw: Dict[str, Any] = dict(eta=1e-2, period=2)
    if cfg.variant == "schedule":
        kw["topology"] = "one-peer-exp"
    if cfg.variant == "stale":
        kw.update(staleness=1, straggler_rate=0.25)
    if cfg.variant == "overlap":
        # the delay-1 eager wire schedule: must satisfy the SAME spec as
        # the plain config (no all-gathers, block-bounded permute bytes)
        # on every backend incl. the 2D mesh
        kw["overlap"] = True
    extra: Dict[str, Any] = {}
    if cfg.variant == "damping":
        # adaptive batch damping: the masked accumulation scan + the
        # traced per-worker chunk counts must satisfy the SAME spec as
        # the plain config on every backend — in particular zero
        # all-gathers in the sharded 2D mode, where the counts ride into
        # the shard_map as a P('worker') batch leaf
        from repro.train import DampingConfig

        extra["damping"] = DampingConfig(policy="adadamp", max_chunks=2,
                                         per_worker=True)
    if cfg.backend in ("packed", "axis", "axis2d"):
        kw["backend"] = "pallas"
    if cfg.backend in ("axis", "axis2d"):
        from repro.launch.mesh import make_worker_mesh

        kw.update(comm="axis",
                  mesh=make_worker_mesh(cfg.K, model_parallel=cfg.M))
    if cfg.backend == "axis2d":
        extra["sharded_loss"] = _sharded_loss
    opt = make_optimizer(cfg.kind, K=cfg.K, **kw)
    tr = DecentralizedTrainer(_loss, opt, **extra)
    state = tr.init(_params())
    batch = tr._place_batch(_batch(cfg.K))
    return tr, opt, state, batch


def spec_for(cfg: SweepConfig, state: Any) -> InvariantSpec:
    """The invariant spec a config's compiled step must satisfy. Budgets
    are per-device operand bytes (partitioned HLO): a gossip permute moves
    at most one device's row-shard block; activation all-reduces stay
    orders of magnitude under parameter size."""
    if cfg.backend in ("reference", "packed"):
        # stacked execution: everything is one device's program
        return InvariantSpec(
            name=cfg.name,
            collective_counts={k: 0 for k in
                               ("all-gather", "all-reduce", "reduce-scatter",
                                "all-to-all", "collective-permute")},
            max_trip_count=1024)
    block_bytes = int(state.buf.nbytes) // (cfg.K * cfg.M)
    # gossip degree x payload per hop, x2 for staleness double-buffering
    # and per-edge age/metadata, x4 slack for GSPMD scheduling copies
    permute_budget = 8 * 4 * block_bytes
    return InvariantSpec(
        name=cfg.name,
        collective_counts={"all-gather": 0, "all-to-all": 0,
                           "reduce-scatter": 0},
        min_collective_counts={"collective-permute": 1},
        collective_bytes={"collective-permute": permute_budget},
        single_collective_bytes={"all-gather": 0,
                                 "collective-permute": block_bytes,
                                 "all-reduce": max(4 * B * DOUT, 4096)},
        max_trip_count=1024)


@dataclasses.dataclass
class ConfigResult:
    config: str
    report: Optional[InvariantReport] = None
    lint: List[Finding] = dataclasses.field(default_factory=list)
    skipped: Optional[str] = None

    @property
    def ok(self) -> bool:
        return (self.skipped is not None
                or ((self.report is None or self.report.ok)
                    and not self.lint))


def check_config(cfg: SweepConfig) -> ConfigResult:
    if jax.device_count() < cfg.devices_needed:
        return ConfigResult(cfg.name,
                            skipped=f"needs {cfg.devices_needed} devices, "
                                    f"have {jax.device_count()}")
    tr, opt, state, batch = _build(cfg)
    res = ConfigResult(cfg.name)

    # pass 1: jaxpr lint. Wrong-axis rules on the full step (raw-psum
    # rules stay off: non-AD optimizer code psums compression scales
    # legitimately); raw-collective rules on the sharded-loss probe.
    step = tr.pipeline.value_and_grad
    if cfg.variant == "damping":
        # the damped pipeline takes the traced per-worker chunk counts as
        # a third argument; lint and lower with the trainer's live state
        from repro.train.damping import chunks_of

        n = chunks_of(tr.damp_state, tr._damping, opt.K)
        vag = lambda s, b: step(s, b, n)  # noqa: E731
        step_args: Tuple = (state, tr.damp_state, batch)
    else:
        vag = lambda s, b: step(s, b)  # noqa: E731
        step_args = (state, batch)
    res.lint += lint_fn(vag, state, batch,
                        check_raw=False,
                        gossip_axes=(opt.cfg.axis_name,),
                        reduce_axes=(getattr(opt.cfg, "model_axis_name",
                                             "model"),))
    if cfg.backend == "axis2d":
        from repro.train.grad import sharded_loss_probe

        probe = sharded_loss_probe(_sharded_loss, opt)
        if cfg.variant == "damping":
            # the damped pipeline evaluates the loss per CHUNK (B /
            # max_chunks rows), so the probe must see chunk-shaped
            # activations for the psum shape accounting to line up
            C = tr._damping.max_chunks

            def probe_c(s, b):
                return probe(s, jax.tree_util.tree_map(
                    lambda x: x[:, :x.shape[1] // C], b))

            res.lint += lint_fn(probe_c, state, batch)
            res.lint += lint_grad_psums(probe_c, vag, (state, batch))
        else:
            res.lint += lint_fn(probe, state, batch)
            res.lint += lint_grad_psums(probe, vag, (state, batch))

    # pass 2: HLO invariants on the compiled step
    hlo = tr._step.lower(*step_args).compile().as_text()
    res.report = evaluate_hlo(hlo, spec_for(cfg, state))
    return res


# --------------------------- topology sweep ----------------------------------


def topology_reports() -> List[InvariantReport]:
    """INV006/INV007 across the zoo + the sweep's schedule entries."""
    from repro.core.schedule import make_schedule
    from repro.core.topology import make_topology

    reports = []
    for name, K in [("ring", 4), ("ring", 5), ("ring", 8),
                    ("exponential", 8), ("fully_connected", 6),
                    ("torus", 8), ("torus", 9)]:
        reports.append(check_topology(make_topology(name, K)))
    for entry in make_schedule("one-peer-exp", 8).entries:
        reports.append(check_topology(entry))
    return reports


# ----------------------------- serve path ------------------------------------


def serve_decode_report(arch: str = "llama3.2-1b") -> InvariantReport:
    """The serving-side gate: one compiled single-token decode step must
    contain ZERO collectives of any kind. Serving replicas are
    independent — a collective sneaking into the decode path (e.g. a
    sharding constraint leaking from the training mesh through a
    published param) would stall every replica on its slowest peer."""
    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.serve.engine import kv_cache_len

    cfg = get_reduced(arch).model
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    toks = jnp.zeros((4, 8), jnp.int32)
    _, cache = api.prefill(params, {"tokens": toks},
                           cache_len=kv_cache_len(cfg, 16))
    tok = jnp.zeros((4,), jnp.int32)
    hlo = jax.jit(api.decode_step).lower(params, cache,
                                         tok).compile().as_text()
    spec = InvariantSpec(
        name=f"serve.decode[{arch}]",
        collective_counts={k: 0 for k in
                           ("all-reduce", "all-gather", "reduce-scatter",
                            "all-to-all", "collective-permute")})
    return evaluate_hlo(hlo, spec)


# ---------------------------- known-bug corpus -------------------------------


def _raw_psum_loss(chunks, batch, ctx):
    """PR-5 bug class, reconstructed: ties shards with a raw psum whose
    transpose replicates the cotangent (grads silently scaled by M)."""
    from repro.train.grad import row_parallel_dot

    h = row_parallel_dot(batch["x"], chunks["w"], DOUT, ctx)
    pred = h + ctx.full_leaf(chunks["bias"], 0)
    mse = jnp.mean((pred - batch["y"]) ** 2)
    return jax.lax.psum(mse, ctx.axis_name) / ctx.n_shards  # noqa: RPR001


def corpus_raw_psum() -> List[Finding]:
    """The raw-psum loss through the real pipeline: both JXL001 detection
    modes must fire (forward custom_vjp-boundary walk AND backward psum
    shape accounting)."""
    from repro.core import make_optimizer
    from repro.launch.mesh import make_worker_mesh
    from repro.train import DecentralizedTrainer
    from repro.train.grad import sharded_loss_probe

    K, M = 4, 2
    mesh = make_worker_mesh(K, model_parallel=M)
    opt = make_optimizer("d-adam", K=K, eta=1e-2, period=2,
                         backend="pallas", comm="axis", mesh=mesh)
    tr = DecentralizedTrainer(_loss, opt, sharded_loss=_raw_psum_loss)
    state = tr.init(_params())
    batch = tr._place_batch(_batch(K))
    probe = sharded_loss_probe(_raw_psum_loss, opt)
    fwd = lint_fn(probe, state, batch)
    bwd = lint_grad_psums(probe, tr.pipeline.value_and_grad, (state, batch))
    return fwd + bwd


def corpus_bad_torus() -> InvariantReport:
    """PR-6 bug class, reconstructed: torus weights with FLAT circulant
    offsets — ±1 wraps across row boundaries, mixing wrong neighbors; the
    typed GridShift offsets are the fix. INV006 must fail."""
    from repro.core.topology import make_topology

    torus = make_topology("torus", 8)  # 2 x 4 grid
    bad = dataclasses.replace(torus, name="bad-flat-torus",
                              offsets=(1, -1, 4, -4))
    return check_topology(bad)


_CORPUS_SRC = '''
import jax
import numpy as np
from jax.experimental import pallas as pl

def bad_sharded_loss(chunks, batch, ctx):
    return jax.lax.psum(chunks[0].sum(), ctx.axis_name)

@jax.jit
def step(state, batch):
    return np.asarray(state), state.loss.item()

def kernel(x):
    return pl.pallas_call(lambda r, o: None, out_shape=x)(x)

def spec(K):
    return pl.BlockSpec((1, 8, 128), lambda k, i: (k / 2, i, 0))
'''


def corpus_ast() -> List[astlint.AstFinding]:
    return astlint.lint_source(_CORPUS_SRC, "<corpus>")


def run_corpus() -> Tuple[bool, List[str]]:
    """Every corpus case must trip its expected rule. Returns (ok, log)."""
    lines: List[str] = []
    ok = True

    def expect(label: str, rules_found: Sequence[str],
               required: Sequence[str]) -> None:
        nonlocal ok
        missing = [r for r in required if r not in rules_found]
        good = not missing
        ok = ok and good
        mark = "ok  " if good else "FAIL"
        lines.append(f"[{mark}] corpus {label}: expected {list(required)}, "
                     f"found {sorted(set(rules_found))}")

    if jax.device_count() >= 8:
        expect("raw-psum sharded loss (PR-5 class)",
               [f.rule for f in corpus_raw_psum()], ["JXL001"])
    else:
        lines.append("[skip] corpus raw-psum: needs 8 devices")
    report = corpus_bad_torus()
    expect("flat-circulant torus (PR-6 class)", report.failed_rules(),
           ["INV006"])
    expect("AST rules", [f.rule for f in corpus_ast()],
           ["RPR001", "RPR002", "RPR003", "RPR004"])
    return ok, lines


# --------------------------------- driver ------------------------------------


def run(backends: Sequence[str] = BACKENDS,
        kinds: Sequence[str] = KINDS,
        variants: Sequence[str] = VARIANTS,
        *, corpus: bool = True, verbose: bool = False,
        log: Callable[[str], None] = print) -> bool:
    """The CI gate: sweep + topology zoo + known-bug corpus. Returns
    overall pass/fail; prints per-config reports and per-rule counts."""
    ok = True
    rule_counts: Dict[str, int] = {}

    for cfg in sweep_configs(backends, kinds, variants):
        res = check_config(cfg)
        if res.skipped:
            log(f"[skip] {res.config}: {res.skipped}")
            continue
        ok = ok and res.ok
        mark = "ok  " if res.ok else "FAIL"
        log(f"[{mark}] {res.config}")
        for f in res.lint:
            rule_counts[f.rule] = rule_counts.get(f.rule, 0) + 1
            log(f"       {f}")
        if res.report is not None:
            for c in res.report.failures:
                rule_counts[c.rule] = rule_counts.get(c.rule, 0) + 1
            if verbose or not res.report.ok:
                for line in res.report.format(
                        verbose=verbose).splitlines()[1:]:
                    log(f"     {line}")

    for report in topology_reports():
        if not report.ok:
            ok = False
            for c in report.failures:
                rule_counts[c.rule] = rule_counts.get(c.rule, 0) + 1
            log(report.format(verbose=False))
    log("[ok  ] topology zoo + schedule entries (INV006/INV007)"
        if ok else "[    ] topology zoo checked")

    serve_rep = serve_decode_report()
    if not serve_rep.ok:
        ok = False
        for c in serve_rep.failures:
            rule_counts[c.rule] = rule_counts.get(c.rule, 0) + 1
        log(serve_rep.format(verbose=False))
    log(("[ok  ] " if serve_rep.ok else "[FAIL] ")
        + "serve decode step: zero collectives")

    if corpus:
        corpus_ok, lines = run_corpus()
        ok = ok and corpus_ok
        for line in lines:
            log(line)

    if rule_counts:
        log("per-rule findings: " + ", ".join(
            f"{r}={n}" for r, n in sorted(rule_counts.items())))
    log("check_invariants: " + ("PASS" if ok else "FAIL"))
    return ok
