"""Repo-specific AST lint (rule namespace ``RPR``).

Source-level companions to the jaxpr/HLO passes — these catch the bug
classes *before* anything is traced:

``RPR001``  raw ``lax.psum`` inside a sharded-loss function (third
            positional arg named ``ctx`` or name containing
            ``sharded_loss``). Inside the pipeline's
            ``shard_map(check_rep=False)`` region its transpose scales
            gradients by the model-axis size; use ``ctx.psum`` /
            ``psum_replicated`` instead.
``RPR002``  host synchronization (``.item()``, ``np.asarray``,
            ``device_get``) inside a function that is jit-compiled in the
            same module — a silent device->host round-trip per step.
``RPR003``  ``pl.pallas_call`` without an ``interpret=`` argument: the
            kernel cannot run on CPU CI and the call site has no
            plumb-through for it.
``RPR004``  non-static math (float constants, true division, jnp/np calls)
            in a ``BlockSpec`` index map — index maps must stay integer
            grid arithmetic (``//``/``%``) or the lowering silently
            misindexes blocks.

Suppression: ``# noqa: RPR001`` (or bare ``# noqa``) on the flagged line;
the rule-ID namespace is registered with ruff via ``external`` in
pyproject.toml so suppressions stay greppable.

CLI: ``python -m repro.analysis.astlint src/ [--summary]`` — exits 1 on
findings and prints per-rule counts.
"""
from __future__ import annotations

import ast
import dataclasses
import re
import sys
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence

RULES = {
    "RPR001": "raw lax.psum in a sharded loss (use ctx.psum/psum_replicated)",
    "RPR002": "host sync (.item()/np.asarray/device_get) in a jitted function",
    "RPR003": "pl.pallas_call without an interpret= plumb-through",
    "RPR004": "non-static indexing math in a BlockSpec index map",
}

_NOQA_RE = re.compile(r"#\s*noqa(?::\s*(?P<codes>[A-Z]+\d+(?:[,\s]+[A-Z]+\d+)*))?",
                      re.IGNORECASE)


@dataclasses.dataclass(frozen=True)
class AstFinding:
    rule: str
    path: str
    line: int
    col: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


def _dotted(node: ast.AST) -> str:
    """'jax.lax.psum' for Attribute/Name chains, '' otherwise."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_jit_expr(node: ast.AST) -> bool:
    """jax.jit / jit, possibly wrapped in functools.partial(jax.jit, ...)."""
    d = _dotted(node)
    if d in ("jax.jit", "jit"):
        return True
    if isinstance(node, ast.Call):
        fd = _dotted(node.func)
        if fd in ("functools.partial", "partial") and node.args:
            return _is_jit_expr(node.args[0])
        return _is_jit_expr(node.func)
    return False


def _is_sharded_loss(fn: ast.AST) -> bool:
    if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
        return False
    args = fn.args.posonlyargs + fn.args.args
    if len(args) >= 3 and args[2].arg == "ctx":
        return True
    return "sharded_loss" in fn.name


# float()/int()/bool() on traced values are sync points too, but flagging
# every builtin call would drown real findings — restrict to the explicit
# device->host APIs plus .item()
_HOST_SYNC_EXPLICIT = {"np.asarray", "numpy.asarray", "jax.device_get",
                       "device_get", "np.array", "numpy.array"}


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str, source: str):
        self.path = path
        self.lines = source.splitlines()
        self.findings: List[AstFinding] = []
        self.jit_names: set = set()
        self._fn_stack: List[ast.AST] = []

    # -- pass 1 collected jit-ed function names (module-scoped) --

    def _suppressed(self, rule: str, lineno: int) -> bool:
        if 1 <= lineno <= len(self.lines):
            m = _NOQA_RE.search(self.lines[lineno - 1])
            if m:
                codes = m.group("codes")
                if not codes:
                    return True
                return rule in re.split(r"[,\s]+", codes.upper())
        return False

    def _add(self, rule: str, node: ast.AST, message: str) -> None:
        lineno = getattr(node, "lineno", 1)
        if self._suppressed(rule, lineno):
            return
        self.findings.append(AstFinding(
            rule, self.path, lineno, getattr(node, "col_offset", 0), message))

    # ------------------------------ visitors ------------------------------

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._visit_fn(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._visit_fn(node)

    def _visit_fn(self, node) -> None:
        for dec in node.decorator_list:
            if _is_jit_expr(dec):
                self.jit_names.add(node.name)
        in_jit = node.name in self.jit_names or any(
            getattr(f, "name", None) in self.jit_names
            for f in self._fn_stack)
        self._fn_stack.append(node)
        try:
            if _is_sharded_loss(node):
                self._check_sharded_loss(node)
            if in_jit or node.name in self.jit_names:
                self._check_host_sync(node)
            self.generic_visit(node)
        finally:
            self._fn_stack.pop()

    def visit_Call(self, node: ast.Call) -> None:
        d = _dotted(node.func)
        if d == "pallas_call" or d.endswith(".pallas_call"):
            self._check_pallas_call(node)
        elif d == "BlockSpec" or d.endswith(".BlockSpec"):
            self._check_blockspec(node)
        self.generic_visit(node)

    # ------------------------------- rules --------------------------------

    def _check_sharded_loss(self, fn) -> None:
        for sub in ast.walk(fn):
            if isinstance(sub, ast.Call):
                d = _dotted(sub.func)
                if d in ("jax.lax.psum", "lax.psum"):
                    self._add("RPR001", sub,
                              "raw lax.psum in sharded loss "
                              f"`{fn.name}`; its transpose under "
                              "check_rep=False scales gradients — use "
                              "ctx.psum / psum_replicated")

    def _check_host_sync(self, fn) -> None:
        for sub in ast.walk(fn):
            if not isinstance(sub, ast.Call):
                continue
            d = _dotted(sub.func)
            if d in _HOST_SYNC_EXPLICIT:
                self._add("RPR002", sub,
                          f"`{d}` inside jitted `{fn.name}` forces a "
                          "device->host sync per step")
            elif (isinstance(sub.func, ast.Attribute)
                  and sub.func.attr == "item" and not sub.args):
                self._add("RPR002", sub,
                          f"`.item()` inside jitted `{fn.name}` forces a "
                          "device->host sync per step")

    def _check_pallas_call(self, node: ast.Call) -> None:
        kw_names = {k.arg for k in node.keywords}
        if "interpret" in kw_names or None in kw_names:  # None = **kwargs
            return
        self._add("RPR003", node,
                  "pl.pallas_call without interpret=: plumb an "
                  "`interpret` flag through so the kernel runs on CPU CI")

    def _check_blockspec(self, node: ast.Call) -> None:
        index_map: Optional[ast.AST] = None
        for k in node.keywords:
            if k.arg == "index_map":
                index_map = k.value
        if index_map is None and len(node.args) >= 2:
            index_map = node.args[1]
        if not isinstance(index_map, ast.Lambda):
            return
        for sub in ast.walk(index_map.body):
            bad = None
            if isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Div):
                bad = "true division (use //)"
            elif isinstance(sub, ast.Constant) and isinstance(sub.value,
                                                             float):
                bad = f"float constant {sub.value!r}"
            elif isinstance(sub, ast.Call):
                d = _dotted(sub.func)
                root = d.split(".")[0]
                if root in ("jnp", "np", "numpy", "jax", "math"):
                    bad = f"`{d}(...)` call"
            if bad is not None:
                self._add("RPR004", sub,
                          f"non-static math in BlockSpec index map: {bad}; "
                          "index maps must stay integer grid arithmetic")


class _JitCollector(ast.NodeVisitor):
    """Names bound via `x = jax.jit(fn)` / decorated defs, module-scoped."""

    def __init__(self):
        self.jit_names: set = set()

    def visit_Assign(self, node: ast.Assign) -> None:
        if isinstance(node.value, ast.Call) and _is_jit_expr(node.value.func):
            if node.value.args and isinstance(node.value.args[0], ast.Name):
                self.jit_names.add(node.value.args[0].id)
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        for dec in node.decorator_list:
            if _is_jit_expr(dec):
                self.jit_names.add(node.name)
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef


def lint_source(source: str, path: str = "<memory>") -> List[AstFinding]:
    tree = ast.parse(source, filename=path)
    collector = _JitCollector()
    collector.visit(tree)
    linter = _Linter(path, source)
    linter.jit_names = collector.jit_names
    linter.visit(tree)
    return sorted(linter.findings, key=lambda f: (f.path, f.line, f.rule))


def lint_paths(paths: Iterable[str]) -> List[AstFinding]:
    findings: List[AstFinding] = []
    for p in paths:
        root = Path(p)
        files = sorted(root.rglob("*.py")) if root.is_dir() else [root]
        for f in files:
            findings.extend(lint_source(f.read_text(), str(f)))
    return findings


def rule_counts(findings: Sequence[AstFinding]) -> Dict[str, int]:
    counts = {rule: 0 for rule in RULES}
    for f in findings:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    return counts


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    parser = argparse.ArgumentParser(
        description="repo AST lint (RPR001-RPR004)")
    parser.add_argument("paths", nargs="+")
    parser.add_argument("--summary", action="store_true",
                        help="print per-rule counts (markdown)")
    ns = parser.parse_args(argv)
    findings = lint_paths(ns.paths)
    for f in findings:
        print(f)
    if ns.summary:
        print("| rule | description | findings |")
        print("| --- | --- | --- |")
        for rule, n in rule_counts(findings).items():
            print(f"| {rule} | {RULES[rule]} | {n} |")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
