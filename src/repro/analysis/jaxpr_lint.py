"""Jaxpr-level shard-safety lint (rule namespace ``JXL``).

The PR-5 bug class: a raw ``lax.psum`` inside a ``shard_map(...,
check_rep=False)`` region transposes to *another* psum applied to an
already-replicated cotangent, silently scaling every gradient by the
mesh-axis size. The safe patterns (``train.grad.psum_replicated`` /
``_slice_replicated``) route the collective through a ``custom_vjp`` whose
backward rule is shaped by hand. This module makes the distinction
checkable:

``JXL001``  raw ``psum`` / ``all_gather`` inside a ``check_rep=False``
            shard_map region that is not under a ``custom_vjp`` boundary.
            Two detection modes, because AD *inlines* custom_vjp bodies
            (a grad trace of a protected and a raw loss are structurally
            indistinguishable):

            * forward — :func:`lint_jaxpr` on a *pre-AD* trace, where
              ``custom_vjp_call_jaxpr`` equations are still visible;
            * backward — :func:`lint_grad_psums` compares the psum count
              of the grad trace against what the forward trace predicts
              (every forward psum replays, plus exactly one transpose
              psum per slice-like custom_vjp). A surplus psum is a raw
              collective's transpose.

``JXL002``  collective bound to the wrong mesh axis: a ``ppermute``
            (neighbor gossip) over a reduce axis, or a ``psum`` /
            ``all_gather`` (reduction) over a gossip axis.

``JXL003``  recompilation: :class:`RecompileWatch` hashes abstract call
            signatures (tree structure + leaf shape/dtype) and flags when
            distinct signatures exceed a limit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
from jax import core as jax_core

RULES = {
    "JXL001": ("raw collective under shard_map(check_rep=False) outside a "
               "custom_vjp boundary (gradient-scaling bug class)"),
    "JXL002": "collective bound to the wrong mesh axis",
    "JXL003": "abstract call signature churn (recompilation)",
}

# primitives whose transpose under check_rep=False replicated cotangents
# produces the M-times gradient scaling
_RAW_COLLECTIVES = ("psum", "all_gather")
# reduction-flavored vs neighbor-shift-flavored collectives for JXL002
_REDUCE_PRIMS = ("psum", "pmax", "pmin", "all_gather", "all_to_all")
_SHIFT_PRIMS = ("ppermute", "pshuffle")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    message: str
    path: Tuple[str, ...] = ()

    def __str__(self) -> str:
        where = " > ".join(self.path) if self.path else "<top>"
        return f"{self.rule} [{where}]: {self.message}"


@dataclasses.dataclass(frozen=True)
class _Ctx:
    in_norep_shardmap: bool = False
    protected: bool = False
    path: Tuple[str, ...] = ()


def _as_jaxpr(obj: Any) -> Optional[jax_core.Jaxpr]:
    if isinstance(obj, jax_core.ClosedJaxpr):
        return obj.jaxpr
    if isinstance(obj, jax_core.Jaxpr):
        return obj
    return None


def _sub_jaxprs(params: Dict[str, Any]) -> Iterable[Tuple[str, jax_core.Jaxpr]]:
    """Every Jaxpr reachable from an equation's params, generically —
    sub-jaxprs hide under many param names (jaxpr, call_jaxpr, fun_jaxpr,
    branches, ...) and sometimes inside tuples."""
    for key, val in params.items():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for v in vals:
            j = _as_jaxpr(v)
            if j is not None:
                yield key, j


def _axis_names(params: Dict[str, Any]) -> Tuple[str, ...]:
    names: List[str] = []
    for key in ("axes", "axis_name", "axis_index_groups_axis"):
        v = params.get(key)
        if v is None:
            continue
        for n in v if isinstance(v, (tuple, list)) else (v,):
            if isinstance(n, str):
                names.append(n)
    return tuple(names)


def _eqn_is_norep_shardmap(eqn) -> bool:
    return (eqn.primitive.name == "shard_map"
            and eqn.params.get("check_rep") is False)


def _eqn_is_custom_vjp(eqn) -> bool:
    return eqn.primitive.name.startswith("custom_vjp_call")


def lint_jaxpr(jaxpr: Any, *,
               gossip_axes: Sequence[str] = ("worker",),
               reduce_axes: Sequence[str] = ("model",),
               check_raw: bool = True,
               check_axes: bool = True) -> List[Finding]:
    """Walk a (closed) jaxpr and report JXL001/JXL002 findings.

    ``check_raw`` must only be enabled on traces of *differentiated* code
    (a loss / grad pipeline): a raw psum in non-AD code (e.g. a compressor
    psum-ing scale factors inside the optimizer step) is legitimate.
    Wrong-axis checks apply everywhere.
    """
    findings: List[Finding] = []
    root = _as_jaxpr(jaxpr)
    if root is None:
        raise TypeError(f"expected a Jaxpr/ClosedJaxpr, got {type(jaxpr)!r}")

    def walk(j: jax_core.Jaxpr, ctx: _Ctx) -> None:
        for eqn in j.eqns:
            name = eqn.primitive.name
            axes = _axis_names(eqn.params)
            if ctx.in_norep_shardmap:
                if (check_raw and name in _RAW_COLLECTIVES
                        and not ctx.protected):
                    findings.append(Finding(
                        "JXL001",
                        f"raw `{name}` over {axes or '<?>'} inside "
                        "shard_map(check_rep=False); route it through "
                        "psum_replicated / a custom_vjp or its transpose "
                        "will scale gradients by the axis size",
                        ctx.path))
                if check_axes:
                    bad_shift = (name in _SHIFT_PRIMS
                                 and any(a in reduce_axes for a in axes))
                    bad_reduce = (name in _REDUCE_PRIMS
                                  and any(a in gossip_axes for a in axes))
                    if bad_shift or bad_reduce:
                        role = "gossip" if bad_shift else "reduction"
                        findings.append(Finding(
                            "JXL002",
                            f"`{name}` ({role} collective) bound to mesh "
                            f"axes {axes}; gossip belongs on "
                            f"{tuple(gossip_axes)}, reductions on "
                            f"{tuple(reduce_axes)}",
                            ctx.path))
            sub_ctx = _Ctx(
                in_norep_shardmap=(ctx.in_norep_shardmap
                                   or _eqn_is_norep_shardmap(eqn)),
                protected=ctx.protected or _eqn_is_custom_vjp(eqn),
                path=ctx.path + (name,))
            for _, sub in _sub_jaxprs(eqn.params):
                walk(sub, sub_ctx)

    walk(root, _Ctx())
    return findings


def lint_fn(fn: Callable, *args: Any, **lint_kwargs: Any) -> List[Finding]:
    """Trace ``fn(*args)`` (pre-AD) and lint the jaxpr."""
    return lint_jaxpr(jax.make_jaxpr(fn)(*args), **lint_kwargs)


def _psum_accounting(jaxpr: Any) -> Tuple[Dict[Tuple, int], Dict[Tuple, int]]:
    """Shape-multiset accounting of psums inside check_rep=False regions:

    returns ``(psum_shapes, slice_input_shapes)`` — output-shape -> count
    for every psum, and input-shape -> count for every *slice-like*
    custom_vjp (forward body contains a ``dynamic_slice``; its hand-written
    backward contributes at most one psum of the FULL input shape — see
    train.grad._slice_replicated)."""
    psums: Dict[Tuple, int] = {}
    slices: Dict[Tuple, int] = {}
    root = _as_jaxpr(jaxpr)

    def has_dynamic_slice(j: jax_core.Jaxpr) -> bool:
        for eqn in j.eqns:
            if eqn.primitive.name == "dynamic_slice":
                return True
            for _, sub in _sub_jaxprs(eqn.params):
                if has_dynamic_slice(sub):
                    return True
        return False

    def walk(j: jax_core.Jaxpr, norep: bool) -> None:
        for eqn in j.eqns:
            if norep and eqn.primitive.name == "psum":
                for v in eqn.outvars:
                    s = tuple(getattr(v.aval, "shape", ()))
                    psums[s] = psums.get(s, 0) + 1
            if norep and _eqn_is_custom_vjp(eqn):
                if any(has_dynamic_slice(sub)
                       for _, sub in _sub_jaxprs(eqn.params)):
                    for v in eqn.invars:
                        s = tuple(getattr(v.aval, "shape", ()))
                        slices[s] = slices.get(s, 0) + 1
                        break
            sub_norep = norep or _eqn_is_norep_shardmap(eqn)
            for _, sub in _sub_jaxprs(eqn.params):
                walk(sub, sub_norep)

    walk(root, False)
    return psums, slices


def lint_grad_psums(forward_fn: Callable, grad_fn: Callable,
                    args: Sequence[Any]) -> List[Finding]:
    """JXL001 on the *backward* jaxpr, by psum shape accounting.

    ``forward_fn`` is a pre-AD forward-only twin of ``grad_fn`` (same
    shard_map structure, no differentiation — see
    ``train.grad.sharded_loss_probe``). In the grad trace every legitimate
    psum is either a replay of a forward psum (same output shape) or the
    transpose of a slice-like custom_vjp (a psum of the slice's FULL input
    shape, which AD may also dead-code away when the sliced operand does
    not depend on params). A *raw* forward psum transposes into one extra
    psum of its own output shape — so for some shape the grad count
    exceeds forward-count + slice-count, and that surplus flags the bug
    class even though AD has erased the custom_vjp boundaries.
    """
    fwd = jax.make_jaxpr(forward_fn)(*args)
    grad = jax.make_jaxpr(grad_fn)(*args)
    f_psums, f_slices = _psum_accounting(fwd)
    g_psums, _ = _psum_accounting(grad)
    findings: List[Finding] = []
    for shape, g in sorted(g_psums.items()):
        allowed = f_psums.get(shape, 0) + f_slices.get(shape, 0)
        if g > allowed:
            findings.append(Finding(
                "JXL001",
                f"grad trace has {g} psum(s) of shape {shape} inside "
                f"check_rep=False regions but the forward trace only "
                f"accounts for {allowed} (forward replays + slice "
                "transposes); the surplus is a raw collective's transpose "
                "replicating cotangents (gradient-scaling bug)"))
    return findings


# ---------------------------- JXL003: recompiles -----------------------------


def _abstract_signature(args: Tuple[Any, ...], kwargs: Dict[str, Any]):
    leaves, treedef = jax.tree_util.tree_flatten((args, kwargs))

    def leaf_sig(x: Any):
        shape = getattr(x, "shape", None)
        dtype = getattr(x, "dtype", None)
        if shape is not None and dtype is not None:
            return (tuple(shape), str(dtype))
        # python scalars etc. retrigger tracing by value type
        return (type(x).__name__,)

    return (str(treedef), tuple(leaf_sig(x) for x in leaves))


class RecompileWatch:
    """Hash abstract call signatures across trainer calls; more than
    ``limit`` distinct signatures means jit is recompiling (JXL003).

    ``limit`` defaults to 1: one signature per build. Elastic resize is a
    *legitimate* recompile — reset the watch (or build a fresh one) at
    rebuild points rather than raising the limit.
    """

    def __init__(self, name: str = "fn", limit: int = 1):
        self.name = name
        self.limit = int(limit)
        self.signatures: Dict[Any, int] = {}

    def reset(self) -> None:
        self.signatures.clear()

    def observe(self, *args: Any, **kwargs: Any) -> int:
        """Record one call; returns the number of distinct signatures."""
        sig = _abstract_signature(args, kwargs)
        self.signatures[sig] = self.signatures.get(sig, 0) + 1
        return len(self.signatures)

    def findings(self) -> List[Finding]:
        n = len(self.signatures)
        if n > self.limit:
            return [Finding(
                "JXL003",
                f"`{self.name}` saw {n} distinct abstract signatures "
                f"(limit {self.limit}): each one is a fresh XLA compile. "
                "Pin shapes/dtypes (pad batches, static microbatch "
                "counts) or reset the watch at legitimate rebuild points")]
        return []

    def check(self) -> None:
        f = self.findings()
        if f:
            raise RecompileError(str(f[0]))


class RecompileError(RuntimeError):
    pass
