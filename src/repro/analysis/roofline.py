"""Three-term roofline model from a compiled dry-run artifact.

    T_compute    = HLO_FLOPs / (chips * PEAK_FLOPS)
    T_memory     = HLO_bytes / (chips * HBM_BW)
    T_collective = collective_bytes / (chips * ICI_BW)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``; collective bytes
from the partitioned HLO text (repro.analysis.hlo). cost_analysis on the
CPU backend reports *per-device* numbers for the partitioned module, so the
per-chip terms divide by the per-device values directly; we normalize both
conventions via the ``per_device`` flag.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float              # per-device HLO flops
    hbm_bytes: float          # per-device bytes accessed
    coll_bytes: float         # per-device collective operand bytes
    model_flops: float        # 6 * N_active * tokens (whole step, global)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    coll_breakdown: Optional[Dict[str, int]] = None

    def finalize(self) -> "Roofline":
        self.t_compute = self.flops / PEAK_FLOPS
        self.t_memory = self.hbm_bytes / HBM_BW
        self.t_collective = self.coll_bytes / ICI_BW
        return self

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def usefulness(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs (global)."""
        total_hlo = self.flops * self.chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def step_time(self) -> float:
        """No-overlap estimate: max of the three terms (s)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d.update(bottleneck=self.bottleneck, usefulness=self.usefulness,
                 step_time=self.step_time)
        return d


def from_artifact(art: Dict[str, Any]) -> Roofline:
    """Build from a dryrun JSON artifact (see launch/dryrun.py)."""
    r = Roofline(
        arch=art["arch"], shape=art["shape"], mesh=art["mesh"],
        chips=art["chips"],
        flops=art["cost"].get("flops", 0.0),
        hbm_bytes=art["cost"].get("bytes accessed", 0.0),
        coll_bytes=art["collectives"]["total"],
        model_flops=art.get("model_flops", 0.0),
        coll_breakdown=art["collectives"],
    )
    return r.finalize()


def model_flops_for(n_active_params: int, tokens: int, kind: str) -> float:
    """6ND for a train step (fwd+bwd), 2ND for inference forward."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active_params * tokens
