"""Declarative HLO + topology invariant gates (rule namespace ``INV``).

The scattered per-test assertions over ``collective_summary`` output
("the 2D step has zero all-gathers", "a permute moves at most one block")
become one declarative object:

    spec = InvariantSpec(
        name="sharded-2d-step",
        collective_counts={"all-gather": 0, "all-to-all": 0},
        min_collective_counts={"collective-permute": 1},
        collective_bytes={"collective-permute": budget},
        single_collective_bytes={"all-reduce": 4 * batch},
        max_trip_count=64,
    )
    assert_invariants(step, (state, batch), spec)

evaluated against the compiled (partitioned) HLO through the existing
trip-count-aware parser. Byte figures are per-device operand bytes with
while-loop multipliers (``bytes``) or per single instruction
(``single_collective_bytes`` / ``max_bytes``).

Rules:

=======  ====================================================
INV001   per-kind collective count bound (max and min)
INV002   per-kind collective byte budget ("*" = total)
INV003   max single-collective operand bytes
INV004   while-loop trip counts bounded / resolvable
INV005   no unknown dtypes in the byte accounting
INV006   mixing-matrix lowering: offsets_matrix(topo) == weights
INV007   mixing weights doubly stochastic
=======  ====================================================

INV006 pins the PR-6 bug class: a flat circulant offset list on a torus
mixes wrong neighbors at row boundaries; the typed ``GridShift`` offsets
must reproduce the dense weights exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.analysis import hlo as hlo_mod

RULES = {
    "INV001": "collective count out of bounds",
    "INV002": "collective byte budget exceeded",
    "INV003": "single collective larger than bound",
    "INV004": "while-loop trip count unbounded or unresolved",
    "INV005": "unknown dtype in byte accounting",
    "INV006": "mixing-matrix lowering mismatch (offsets vs weights)",
    "INV007": "mixing weights not doubly stochastic",
}


@dataclasses.dataclass(frozen=True)
class InvariantSpec:
    """Bounds evaluated against one compiled program's HLO.

    Absent keys are unchecked; kinds are the five of
    ``hlo.COLLECTIVE_KINDS``; ``"*"`` in ``collective_bytes`` bounds the
    total across kinds.
    """
    name: str = "step"
    collective_counts: Mapping[str, int] = dataclasses.field(
        default_factory=dict)
    min_collective_counts: Mapping[str, int] = dataclasses.field(
        default_factory=dict)
    collective_bytes: Mapping[str, int] = dataclasses.field(
        default_factory=dict)
    single_collective_bytes: Mapping[str, int] = dataclasses.field(
        default_factory=dict)
    max_trip_count: Optional[int] = None
    allow_unknown_trip_counts: bool = True
    allow_unknown_dtypes: bool = False


@dataclasses.dataclass(frozen=True)
class Check:
    rule: str
    desc: str
    observed: Any
    bound: Any
    ok: bool

    def __str__(self) -> str:
        mark = "ok  " if self.ok else "FAIL"
        return f"[{mark}] {self.rule} {self.desc}: observed={self.observed} bound={self.bound}"


@dataclasses.dataclass
class InvariantReport:
    name: str
    checks: List[Check] = dataclasses.field(default_factory=list)
    # informational per-kind {count, bytes, max_bytes}, for printing
    summary: Dict[str, Dict[str, int]] = dataclasses.field(
        default_factory=dict)

    @property
    def ok(self) -> bool:
        return all(c.ok for c in self.checks)

    @property
    def failures(self) -> List[Check]:
        return [c for c in self.checks if not c.ok]

    def failed_rules(self) -> List[str]:
        return sorted({c.rule for c in self.failures})

    def format(self, *, verbose: bool = True) -> str:
        lines = [f"invariants[{self.name}]: "
                 + ("PASS" if self.ok else "FAIL")]
        if self.summary:
            for kind, s in self.summary.items():
                lines.append(
                    f"  {kind:<19} count={s['count']:<4} "
                    f"bytes={s['bytes']:<12} max_bytes={s['max_bytes']}")
        for c in self.checks:
            if verbose or not c.ok:
                lines.append(f"  {c}")
        return "\n".join(lines)


class InvariantViolation(AssertionError):
    def __init__(self, report: InvariantReport):
        self.report = report
        super().__init__(report.format(verbose=False))


def evaluate_hlo(hlo_text: str, spec: InvariantSpec) -> InvariantReport:
    cost = hlo_mod.analyze(hlo_text)
    report = InvariantReport(spec.name)
    report.summary = {
        k: {"count": int(cost.coll_counts[k]), "bytes": int(cost.coll[k]),
            "max_bytes": int(cost.coll_max[k])}
        for k in hlo_mod.COLLECTIVE_KINDS}
    add = report.checks.append

    for kind, bound in spec.collective_counts.items():
        n = int(cost.coll_counts.get(kind, 0))
        add(Check("INV001", f"{kind} count <=", n, bound, n <= bound))
    for kind, bound in spec.min_collective_counts.items():
        n = int(cost.coll_counts.get(kind, 0))
        add(Check("INV001", f"{kind} count >=", n, bound, n >= bound))
    for kind, bound in spec.collective_bytes.items():
        b = (int(cost.total_coll()) if kind == "*"
             else int(cost.coll.get(kind, 0)))
        add(Check("INV002", f"{kind} bytes <=", b, bound, b <= bound))
    for kind, bound in spec.single_collective_bytes.items():
        b = int(cost.coll_max.get(kind, 0))
        add(Check("INV003", f"{kind} max single <=", b, bound, b <= bound))
    if spec.max_trip_count is not None:
        add(Check("INV004", "max while trip <=", cost.max_trip_count,
                  spec.max_trip_count,
                  cost.max_trip_count <= spec.max_trip_count))
    if not spec.allow_unknown_trip_counts:
        add(Check("INV004", "unresolved while trips ==",
                  cost.unknown_trip_counts, 0,
                  cost.unknown_trip_counts == 0))
    if not spec.allow_unknown_dtypes:
        add(Check("INV005", "unknown-dtype elements ==",
                  dict(cost.unknown_dtypes) or 0, 0,
                  not cost.unknown_dtypes))
    return report


def compiled_hlo(fn: Callable, args: Sequence[Any]) -> str:
    """Partitioned post-optimization HLO of ``jit(fn)(*args)``."""
    import jax
    return jax.jit(fn).lower(*args).compile().as_text()


def check_invariants(fn: Callable, args: Sequence[Any],
                     spec: InvariantSpec) -> InvariantReport:
    return evaluate_hlo(compiled_hlo(fn, args), spec)


def assert_invariants(fn: Callable, args: Sequence[Any],
                      spec: InvariantSpec) -> InvariantReport:
    """Compile ``fn(*args)`` and gate its HLO against ``spec``.

    The single entry point tests, ``launch/dryrun.py`` and
    ``scripts/check_invariants.py`` share: lowers ``jit(fn)`` for
    ``args``, runs the trip-count-aware collective accounting over the
    partitioned post-optimization HLO, and evaluates every bound the
    spec declares (INV001-INV005; absent keys are unchecked).

    Args:
      fn: the function under test (NOT pre-jitted; this compiles it).
      args: example arguments — their shapes/shardings decide what is
        compiled, exactly like a ``jit`` call's.
      spec: the :class:`InvariantSpec` bounds to enforce.

    Returns:
      The passing :class:`InvariantReport` (per-kind collective summary
      plus every evaluated check), for logging.

    Raises:
      InvariantViolation: any bound fails; the exception message is the
        report's failure lines and ``.report`` carries the full object.

    Example:
      >>> import jax.numpy as jnp
      >>> from repro.analysis.invariants import (InvariantSpec,
      ...                                        assert_invariants)
      >>> spec = InvariantSpec(name="elementwise",
      ...                      collective_counts={"all-gather": 0})
      >>> assert_invariants(lambda x: x * 2, (jnp.ones(8),), spec).ok
      True
    """
    report = check_invariants(fn, args, spec)
    if not report.ok:
        raise InvariantViolation(report)
    return report


# --------------------------- topology invariants -----------------------------


def check_topology(topo: Any, *, atol: float = 1e-8) -> InvariantReport:
    """INV006/INV007 on one Topology: the typed-offset lowering must
    reproduce the dense mixing matrix (the PR-6 wrong-neighbor bug class),
    and the matrix must be doubly stochastic."""
    import numpy as np
    from repro.core import topology as topo_mod

    report = InvariantReport(f"topology:{getattr(topo, 'name', '?')}")
    W = np.asarray(topo.weights, dtype=np.float64)
    lowered = topo_mod.offsets_matrix(topo)
    diff = float(np.max(np.abs(W - lowered))) if W.size else 0.0
    report.checks.append(Check(
        "INV006", "max |offsets_matrix - weights| <=", diff, atol,
        diff <= atol))
    row = float(np.max(np.abs(W.sum(axis=1) - 1.0))) if W.size else 0.0
    col = float(np.max(np.abs(W.sum(axis=0) - 1.0))) if W.size else 0.0
    neg = float(-min(0.0, float(W.min()))) if W.size else 0.0
    report.checks.append(Check(
        "INV007", "doubly-stochastic defect <=", max(row, col, neg), atol,
        max(row, col, neg) <= atol))
    return report


def check_schedule(schedule: Any, *, atol: float = 1e-8
                   ) -> List[InvariantReport]:
    """Per-entry topology invariants of a TopologySchedule."""
    return [check_topology(e, atol=atol) for e in schedule.entries]


def assert_topology(topo: Any, *, atol: float = 1e-8) -> InvariantReport:
    report = check_topology(topo, atol=atol)
    if not report.ok:
        raise InvariantViolation(report)
    return report
