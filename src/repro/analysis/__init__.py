from repro.analysis.astlint import lint_paths, lint_source
from repro.analysis.hlo import (collective_bytes, collective_counts,
                                collective_summary)
from repro.analysis.invariants import (InvariantReport, InvariantSpec,
                                       InvariantViolation, assert_invariants,
                                       assert_topology, check_topology,
                                       evaluate_hlo)
from repro.analysis.jaxpr_lint import (RecompileWatch, lint_fn,
                                       lint_grad_psums, lint_jaxpr)
from repro.analysis.roofline import Roofline, from_artifact, model_flops_for

# repro.analysis.check (the config-sweep orchestrator) is deliberately NOT
# imported here: it pulls in the train/launch layers, which import this
# package — use `from repro.analysis import check` directly.

__all__ = ["collective_bytes", "collective_counts", "collective_summary",
           "Roofline", "from_artifact", "model_flops_for",
           "InvariantSpec", "InvariantReport", "InvariantViolation",
           "assert_invariants", "assert_topology", "check_topology",
           "evaluate_hlo",
           "lint_jaxpr", "lint_fn", "lint_grad_psums", "RecompileWatch",
           "lint_source", "lint_paths"]
