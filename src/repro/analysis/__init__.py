from repro.analysis.hlo import collective_bytes, collective_counts
from repro.analysis.roofline import Roofline, from_artifact, model_flops_for

__all__ = ["collective_bytes", "collective_counts", "Roofline",
           "from_artifact", "model_flops_for"]
