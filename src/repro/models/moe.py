"""Mixture-of-Experts FFN with top-k routing and grouped capacity dispatch.

GShard-style: tokens are split into groups of ``group_size``; each group
dispatches independently to per-expert capacity buffers via one-hot einsums,
so dispatch memory is O(N * group_size * top_k * capacity_factor) — linear
in token count — and every shape is static. Expert weights are stacked
(E, d, d_ff) and sharded over the 'model' mesh axis on the expert dim
(expert parallelism); the dispatch/combine einsums lower to all-to-all under
GSPMD. Routing returns a Switch-style auxiliary load-balance loss.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.models import common

PyTree = Any


def init_moe(key, d_model: int, d_ff: int, n_experts: int, dtype) -> PyTree:
    ks = jax.random.split(key, 4)
    def ew(k, di, do):
        return (jax.random.normal(k, (n_experts, di, do), jnp.float32)
                * (1.0 / jnp.sqrt(di))).astype(dtype)
    return {
        "router": common.dense_init(ks[0], d_model, n_experts, jnp.float32),
        "w_gate": ew(ks[1], d_model, d_ff),
        "w_up": ew(ks[2], d_model, d_ff),
        "w_down": ew(ks[3], d_ff, d_model),
    }


def moe_forward(params: PyTree, x: jax.Array, *, top_k: int,
                capacity_factor: float = 1.25, group_size: int = 1024
                ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    E = params["router"].shape[-1]
    N = B * S
    g = min(group_size, N)
    while N % g:           # static: shrink group size to divide token count
        g -= 1
    G = N // g
    C = max(4, int(g * top_k * capacity_factor / E))
    C = min(C, g)
    xf = x.reshape(G, g, d)

    logits = jnp.einsum("Gnd,dE->GnE", xf.astype(jnp.float32),
                        params["router"])                          # (G,g,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)              # (G,g,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # rank of each (token, choice) within its expert, per group
    exp_oh_i = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)        # (G,g,k,E)
    flat = exp_oh_i.reshape(G, g * top_k, E)
    pos = jnp.cumsum(flat, axis=1) - flat
    pos = jnp.sum(pos * flat, axis=-1)                             # (G, g*k)
    keep = pos < C

    slot_oh = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                             dtype=xf.dtype)[..., :C]              # (G,g*k,C)
    exp_oh = flat.astype(xf.dtype)                                 # (G,g*k,E)
    pair = exp_oh[..., :, None] * slot_oh[..., None, :]            # (G,gk,E,C)
    disp = pair.reshape(G, g, top_k, E, C).sum(axis=2)             # (G,g,E,C)

    expert_in = jnp.einsum("Gnec,Gnd->Gecd", disp, xf)             # (G,E,C,d)
    h = common.swiglu(
        jnp.einsum("Gecd,edf->Gecf", expert_in, params["w_gate"].astype(xf.dtype)),
        jnp.einsum("Gecd,edf->Gecf", expert_in, params["w_up"].astype(xf.dtype)))
    expert_out = jnp.einsum("Gecf,efd->Gecd", h, params["w_down"].astype(xf.dtype))  # (G,E,C,d)

    gates_flat = (gate_vals.reshape(G, g * top_k)
                  * keep.astype(gate_vals.dtype)).astype(xf.dtype)
    comb = (pair * gates_flat[..., None, None]
            ).reshape(G, g, top_k, E, C).sum(axis=2)               # (G,g,E,C)
    out = jnp.einsum("Gnec,Gecd->Gnd", comb, expert_out).reshape(B, S, d)

    # Switch-style load-balance loss: E * sum_e f_e * p_e
    frac_tokens = jnp.mean(jax.nn.one_hot(
        gate_idx[..., 0].reshape(-1), E, dtype=jnp.float32), axis=0)
    mean_probs = jnp.mean(probs.reshape(-1, E), axis=0)
    aux = E * jnp.sum(frac_tokens * mean_probs)
    return out.astype(x.dtype), aux
