"""Grouped-query attention with RoPE, sliding windows, KV caches.

The einsum ('xla') path is what the production dry-run lowers — GSPMD
partitions it over the ('data','model') mesh (heads on 'model'; for the
long-context decode shapes the cache *sequence* dim is sharded and XLA
inserts the stable partial-softmax collectives). A Pallas flash-attention
kernel targeting TPU VMEM tiling lives in ``repro.kernels.flash_attention``
and is selected with ``impl='pallas'`` (validated in interpret mode).
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common

PyTree = Any
NEG_INF = -1e30


# ------------------------ activation sharding hints -------------------------
# The serving launcher scopes this context while TRACING prefill/decode so
# q/k/v get explicit head-sharded (or replicated) constraints — without it
# GSPMD may split head_dim for GQA head counts that don't divide the model
# axis and partial-sum the SCORE tensor (measured 2.3 TB/step; EXPERIMENTS.md
# perf iteration 1). Outside the context (tests, CPU training) it is a no-op.

import contextlib as _contextlib

import numpy as _np

_ACT_CTX: dict = {"mesh": None, "batch_axes": None}


@_contextlib.contextmanager
def activation_sharding(mesh, batch_axes=()):
    old = dict(_ACT_CTX)
    _ACT_CTX.update(mesh=mesh, batch_axes=tuple(batch_axes or ()))
    try:
        yield
    finally:
        _ACT_CTX.update(old)


def _shard_heads(x: jax.Array, allow_replicate: bool = False) -> jax.Array:
    """Constrain (B, S, H, D): batch over the serve data axes, heads over
    'model' when divisible. When heads do NOT divide the axis: explicitly
    replicate only if the caller says redundant compute is cheap
    (allow_replicate — small GQA K/V); otherwise leave GSPMD free (forcing
    replication of full-width q for 40-head MHA costs 16x redundant
    attention compute — measured on qwen1.5-32b, §Perf iteration 7)."""
    mesh = _ACT_CTX["mesh"]
    if mesh is None or x.ndim != 4:
        return x
    msz = dict(mesh.shape).get("model", 1)
    if msz <= 1:
        return x
    if x.shape[2] % msz != 0 and not allow_replicate:
        return x
    ba = _ACT_CTX["batch_axes"]
    b_entry = None
    if ba:
        bsz = int(_np.prod([dict(mesh.shape)[a] for a in ba]))
        if bsz > 1 and x.shape[0] % bsz == 0 and x.shape[0] >= bsz:
            b_entry = tuple(ba) if len(ba) > 1 else ba[0]
    h_entry = "model" if x.shape[2] % msz == 0 else None
    from jax.sharding import NamedSharding, PartitionSpec
    spec = PartitionSpec(b_entry, None, h_entry, None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, dtype, qkv_bias: bool = False) -> PyTree:
    ks = jax.random.split(key, 4)
    p = {
        "wq": common.dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": common.dense_init(ks[1], d_model, n_kv_heads * head_dim, dtype),
        "wv": common.dense_init(ks[2], d_model, n_kv_heads * head_dim, dtype),
        "wo": common.dense_init(ks[3], n_heads * head_dim, d_model, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    return p


def _project_qkv(params: PyTree, x: jax.Array, n_heads: int, n_kv: int,
                 head_dim: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, S, _ = x.shape
    dt = x.dtype
    q = x @ params["wq"].astype(dt)
    k = x @ params["wk"].astype(dt)
    v = x @ params["wv"].astype(dt)
    if "bq" in params:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    d_in = x.shape[-1]
    kv_cheap = n_kv * head_dim * 2 <= d_in
    return (_shard_heads(q.reshape(B, S, n_heads, head_dim)),
            _shard_heads(k.reshape(B, S, n_kv, head_dim),
                         allow_replicate=kv_cheap),
            _shard_heads(v.reshape(B, S, n_kv, head_dim),
                         allow_replicate=kv_cheap))


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q (B,S,Hq,D), k (B,T,Hk,D) -> scores (B,Hk,G,S,T)."""
    B, S, Hq, D = q.shape
    Hk = k.shape[2]
    G = Hq // Hk
    qg = q.reshape(B, S, Hk, G, D)
    return jnp.einsum("bskgd,btkd->bkgst", qg, k,
                      preferred_element_type=jnp.float32) / math.sqrt(D)


def _gqa_out(probs: jax.Array, v: jax.Array) -> jax.Array:
    """probs (B,Hk,G,S,T), v (B,T,Hk,D) -> (B,S,Hq*D)."""
    B, Hk, G, S, T = probs.shape
    D = v.shape[-1]
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v)
    return out.reshape(B, S, Hk * G * D)


def _mask_scores(scores: jax.Array, q_pos: jax.Array, k_pos: jax.Array,
                 causal: bool, window: int,
                 k_valid: Optional[jax.Array] = None) -> jax.Array:
    """Apply causal / sliding-window / validity masks in f32 score space.

    q_pos (S,), k_pos (T,) absolute positions; window > 0 keeps keys with
    q_pos - k_pos < window (plus causality).
    """
    S, T = scores.shape[-2], scores.shape[-1]
    dq = q_pos[:, None]
    dk = k_pos[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok = ok & (dk <= dq)
    if window and window > 0:
        ok = ok & (dq - dk < window)
    mask = jnp.where(ok, 0.0, NEG_INF)
    scores = scores + mask
    if k_valid is not None:  # (B, T) per-batch validity (cache fill level)
        scores = scores + jnp.where(k_valid, 0.0,
                                    NEG_INF)[:, None, None, None, :]
    return scores


def flash_attention_xla(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        q_offset: int = 0,
                        chunk_q: int = 2048, chunk_kv: int = 2048
                        ) -> jax.Array:
    """Online-softmax attention tiled in pure XLA ("flash-in-XLA").

    Never materializes the (S, T) score matrix: a python loop tiles the
    query dim (static, HLO size O(S/chunk_q)); a ``lax.scan`` tiles the KV
    dim with carried (acc, max, sumexp). Causal/window structure prunes KV
    chunks *statically*, so the compiled HLO's flop and byte counts reflect
    the sparsity. The scan body is rematerialized so the backward pass
    recomputes per-tile scores instead of saving them.

    q: (B, S, Hq, D); k, v: (B, T, Hk, D). Returns (B, S, Hq, D) in q.dtype.
    """
    B, S, Hq, D = q.shape
    T, Hk = k.shape[1], k.shape[2]
    G = Hq // Hk
    cq = min(chunk_q, S)
    while S % cq:
        cq -= 1
    ckv = min(chunk_kv, T)
    while T % ckv:
        ckv -= 1
    n_kv = T // ckv
    scale = 1.0 / math.sqrt(D)

    def q_chunk_attn(qc: jax.Array, q_pos0: int):
        """qc: (B, cq, Hk, G, D) -> (B, cq, Hk, G, D)."""
        q_pos = q_pos0 + jnp.arange(cq)
        # static KV-chunk range for this q chunk
        lo_chunk = 0
        hi_chunk = n_kv
        if causal:
            hi_chunk = min(n_kv, (q_pos0 + cq + ckv - 1) // ckv)
        if window and window > 0:
            lo_chunk = max(0, (q_pos0 - window + 1) // ckv)
        idxs = jnp.arange(lo_chunk, hi_chunk)

        def body(carry, j):
            acc, m, l = carry
            k_c = jax.lax.dynamic_slice_in_dim(k, j * ckv, ckv, axis=1)
            v_c = jax.lax.dynamic_slice_in_dim(v, j * ckv, ckv, axis=1)
            s = jnp.einsum("bqkgd,btkd->bkgqt", qc, k_c,
                           preferred_element_type=jnp.float32) * scale
            k_pos = j * ckv + jnp.arange(ckv)
            ok = jnp.ones((cq, ckv), bool)
            if causal:
                ok = ok & (k_pos[None, :] <= q_pos[:, None])
            if window and window > 0:
                ok = ok & (q_pos[:, None] - k_pos[None, :] < window)
            s = s + jnp.where(ok, 0.0, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqt,btkd->bkgqd", p.astype(v_c.dtype), v_c)
            acc_new = acc * corr[..., None] + pv.astype(jnp.float32)
            return (acc_new, m_new, l_new), ()

        body = jax.checkpoint(body)
        acc0 = jnp.zeros((B, Hk, G, cq, D), jnp.float32)
        m0 = jnp.full((B, Hk, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hk, G, cq), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), idxs)
        out = acc / jnp.maximum(l[..., None], 1e-30)
        # (B, Hk, G, cq, D) -> (B, cq, Hk, G, D)
        return jnp.moveaxis(out, 3, 1).astype(q.dtype)

    qg = q.reshape(B, S, Hk, G, D)
    outs = []
    for i in range(S // cq):
        qc = jax.lax.slice_in_dim(qg, i * cq, (i + 1) * cq, axis=1)
        outs.append(q_chunk_attn(qc, q_offset + i * cq))
    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return out.reshape(B, S, Hq, D)


# S*T threshold above which 'auto' picks the tiled online-softmax path
AUTO_CHUNK_THRESHOLD = 2048 * 2048


def sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
         window: int = 0, q_offset: int = 0, impl: str = "auto"
         ) -> jax.Array:
    """Scaled-dot-product attention dispatcher.

    impl: 'naive' (materialized scores), 'chunked' (flash-in-XLA, never
    materializes S x T), 'pallas' (TPU kernel), 'auto' (chunked when the
    score matrix would exceed AUTO_CHUNK_THRESHOLD elements per head).
    Returns (B, S, Hq*D)."""
    B, S, Hq, D = q.shape
    T = k.shape[1]
    if impl == "auto":
        impl = "chunked" if S * T >= AUTO_CHUNK_THRESHOLD else "naive"
    if impl == "pallas":
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=causal, window=window)
    elif impl == "chunked":
        out = flash_attention_xla(q, k, v, causal=causal, window=window,
                                  q_offset=q_offset)
    else:
        q_pos = q_offset + jnp.arange(S)
        k_pos = jnp.arange(T)
        scores = _gqa_scores(q, k)
        scores = _mask_scores(scores, q_pos, k_pos, causal, window)
        probs = jax.nn.softmax(scores, axis=-1)
        out = _gqa_out(probs, v).reshape(B, S, Hq, D)
    return out.reshape(B, S, Hq * D)


def attention_forward(params: PyTree, x: jax.Array, *, n_heads: int,
                      n_kv_heads: int, head_dim: int, rope_theta: float,
                      causal: bool = True, window: int = 0,
                      positions: Optional[jax.Array] = None,
                      use_rope: bool = True,
                      impl: str = "auto") -> jax.Array:
    """Full-sequence attention (training / prefill). x: (B, S, d_model)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim)
    pos = positions if positions is not None else jnp.arange(S)
    if use_rope:
        q = common.apply_rope(q, jnp.broadcast_to(pos, (B, S)), rope_theta)
        k = common.apply_rope(k, jnp.broadcast_to(pos, (B, S)), rope_theta)
    out = sdpa(q, k, v, causal=causal, window=window, impl=impl)
    return out @ params["wo"].astype(out.dtype)


def cross_attention_forward(params: PyTree, x: jax.Array, kv: jax.Array, *,
                            n_heads: int, n_kv_heads: int, head_dim: int
                            ) -> jax.Array:
    """Encoder-decoder cross attention (whisper). kv: (B, T, d_model)."""
    B, S, _ = x.shape
    T = kv.shape[1]
    dt = x.dtype
    q = (x @ params["wq"].astype(dt)).reshape(B, S, n_heads, head_dim)
    k = (kv @ params["wk"].astype(dt)).reshape(B, T, n_kv_heads, head_dim)
    v = (kv @ params["wv"].astype(dt)).reshape(B, T, n_kv_heads, head_dim)
    out = sdpa(q, k, v, causal=False)
    return out @ params["wo"].astype(out.dtype)


# ------------------------------ KV cache ------------------------------------


class KVCache(NamedTuple):
    """Per-layer-stacked KV cache.

    k, v: (L, B, S_max, n_kv, head_dim). ``index``: next write position
    (scalar). For sliding-window archs S_max = window and writes wrap
    (rotating cache), keeping the decode cost sub-quadratic and the cache
    O(window).
    """
    k: jax.Array
    v: jax.Array
    index: jax.Array  # scalar int32: number of tokens already cached

    @property
    def max_len(self) -> int:
        return self.k.shape[2]


def init_kv_cache(n_layers: int, batch: int, max_len: int, n_kv: int,
                  head_dim: int, dtype) -> KVCache:
    shape = (n_layers, batch, max_len, n_kv, head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32))


def decode_attention(params: PyTree, x: jax.Array, layer_k: jax.Array,
                     layer_v: jax.Array, index: jax.Array, *, n_heads: int,
                     n_kv_heads: int, head_dim: int, rope_theta: float,
                     window: int = 0, rotating: bool = False,
                     use_rope: bool = True
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode against a cache slice.

    x: (B, 1, d_model); layer_k/v: (B, S_max, n_kv, hd). Returns
    (out (B,1,d_model), new_k, new_v). ``index`` is the absolute position of
    the new token; with ``rotating`` the write slot is index % S_max.
    """
    B = x.shape[0]
    S_max = layer_k.shape[1]
    q, k_new, v_new = _project_qkv(params, x, n_heads, n_kv_heads, head_dim)
    pos_new = jnp.full((B, 1), index, dtype=jnp.int32)
    if use_rope:
        q = common.apply_rope(q, pos_new, rope_theta)
        k_new = common.apply_rope(k_new, pos_new, rope_theta)
    slot = (index % S_max) if rotating else index
    layer_k = jax.lax.dynamic_update_slice(
        layer_k, k_new.astype(layer_k.dtype), (0, slot, 0, 0))
    layer_v = jax.lax.dynamic_update_slice(
        layer_v, v_new.astype(layer_v.dtype), (0, slot, 0, 0))

    # absolute positions held in each cache slot
    slots = jnp.arange(S_max)
    if rotating:
        # slot s holds absolute position: the largest q <= index with
        # q % S_max == s
        cur = index
        abs_pos = cur - ((cur - slots) % S_max)
        valid = abs_pos >= jnp.maximum(0, cur - S_max + 1)
    else:
        abs_pos = slots
        valid = slots <= index
    if window and window > 0:
        valid = valid & (index - abs_pos < window)

    scores = _gqa_scores(q, layer_k)  # (B, Hk, G, 1, S_max)
    scores = scores + jnp.where(valid, 0.0, NEG_INF)[None, None, None, None, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, layer_v)
    out = out @ params["wo"].astype(out.dtype)
    return out, layer_k, layer_v
