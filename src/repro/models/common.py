"""Shared model building blocks: norms, RoPE, embeddings, initializers.

All modules are pure functions over explicit parameter pytrees (dicts of
jnp arrays). Parameters are created by ``init_*`` helpers and consumed by
the matching ``apply`` functions; there is no module framework — this keeps
pytrees trivially stackable over the decentralized worker dim and over the
layer dim (for scan).
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any


# ------------------------------- init --------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None
               ) -> jax.Array:
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
            ).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
            ).astype(dtype)


# ------------------------------- norms -------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    # statistics in f32, but the HIDDEN tensor itself stays in its compute
    # dtype: upcasting it lets XLA hoist converts past the TP partial-sum
    # boundary and doubles every activation all-reduce to f32 bytes
    # (EXPERIMENTS.md perf iteration on train_4k).
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                   keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return (x * inv) * weight.astype(x.dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return (x - mu.astype(x.dtype)) * inv * weight.astype(x.dtype) \
        + bias.astype(x.dtype)


# -------------------------------- RoPE --------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    dt = x.dtype
    freqs = rope_frequencies(x.shape[-1], theta)              # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (.., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                    # (.., S, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(dt)


def sinusoidal_positions(n_ctx: int, d: int) -> jax.Array:
    """Whisper-style fixed sinusoidal embeddings."""
    pos = jnp.arange(n_ctx, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-math.log(10000.0) * dim / max(d // 2 - 1, 1))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ------------------------------ activations ---------------------------------


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x.astype(jnp.float32), approximate=True).astype(x.dtype)


# ------------------------------- losses -------------------------------------


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token CE. logits (..., S, V), labels (..., S) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def stable_softmax(x: jax.Array, axis: int = -1) -> jax.Array:
    x = x.astype(jnp.float32)
    return jax.nn.softmax(x, axis=axis)
