"""Phi-3-vision backbone (hf:microsoft/Phi-3-vision-128k-instruct).

Early-fusion VLM: the CLIP ViT-L/14 image encoder is a STUB per the brief —
``input_specs`` provides (B, n_patches=576, 1024) patch features. The real
pieces implemented here are the projector (1024 -> d_model) and the
phi3-mini language backbone (32L dense GQA transformer) consuming
[projected image tokens ; text tokens] with full causal attention.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax

from repro.configs.base import ModelConfig
from repro.models import attention, common, transformer

PyTree = Any

CLIP_DIM = 1024


def init_params(key, cfg: ModelConfig) -> PyTree:
    k_lm, k_proj = jax.random.split(key)
    p = transformer.init_params(k_lm, cfg)
    p["projector"] = common.dense_init(k_proj, CLIP_DIM, cfg.d_model,
                                       cfg.param_dtype)
    return p


def project_patches(params: PyTree, patches: jax.Array,
                    cfg: ModelConfig) -> jax.Array:
    """(B, P, 1024) stub CLIP features -> (B, P, d_model)."""
    return patches.astype(cfg.compute_dtype) @ params["projector"].astype(
        cfg.compute_dtype)


def forward(params: PyTree, tokens: jax.Array, patches: jax.Array,
            cfg: ModelConfig, *, remat: str = "none"
            ) -> Tuple[jax.Array, jax.Array]:
    embeds = project_patches(params, patches, cfg)
    return transformer.forward(params, tokens, cfg, extra_embeds=embeds,
                               remat=remat)


def loss_fn(params: PyTree, batch: PyTree, cfg: ModelConfig, *,
            remat: str = "none") -> jax.Array:
    tokens = batch["tokens"]
    logits, aux = forward(params, tokens[:, :-1], batch["patches"], cfg,
                          remat=remat)
    n_img = batch["patches"].shape[1]
    logits = logits[:, n_img:]
    return common.cross_entropy_loss(logits, tokens[:, 1:],
                                     batch.get("mask"))


def prefill(params: PyTree, tokens: jax.Array, patches: jax.Array,
            cfg: ModelConfig, *, cache_len: Optional[int] = None
            ) -> Tuple[jax.Array, attention.KVCache]:
    embeds = project_patches(params, patches, cfg)
    return transformer.prefill(params, tokens, cfg, cache_len=cache_len,
                               extra_embeds=embeds)


def decode_step(params: PyTree, cache: attention.KVCache, token: jax.Array,
                cfg: ModelConfig) -> Tuple[jax.Array, attention.KVCache]:
    return transformer.decode_step(params, cache, token, cfg)
