"""Feed-forward blocks: SwiGLU (llama family) and GELU (starcoder/whisper)."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import common

PyTree = Any


def init_swiglu(key, d_model: int, d_ff: int, dtype) -> PyTree:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": common.dense_init(ks[0], d_model, d_ff, dtype),
        "w_up": common.dense_init(ks[1], d_model, d_ff, dtype),
        "w_down": common.dense_init(ks[2], d_ff, d_model, dtype),
    }


def swiglu_forward(params: PyTree, x: jax.Array) -> jax.Array:
    dt = x.dtype
    gate = x @ params["w_gate"].astype(dt)
    up = x @ params["w_up"].astype(dt)
    return common.swiglu(gate, up) @ params["w_down"].astype(dt)


def init_gelu_mlp(key, d_model: int, d_ff: int, dtype,
                  bias: bool = True) -> PyTree:
    ks = jax.random.split(key, 2)
    p = {
        "w_in": common.dense_init(ks[0], d_model, d_ff, dtype),
        "w_out": common.dense_init(ks[1], d_ff, d_model, dtype),
    }
    if bias:
        p["b_in"] = jnp.zeros((d_ff,), dtype)
        p["b_out"] = jnp.zeros((d_model,), dtype)
    return p


def gelu_mlp_forward(params: PyTree, x: jax.Array) -> jax.Array:
    dt = x.dtype
    h = x @ params["w_in"].astype(dt)
    if "b_in" in params:
        h = h + params["b_in"].astype(dt)
    h = common.gelu(h)
    out = h @ params["w_out"].astype(dt)
    if "b_out" in params:
        out = out + params["b_out"].astype(dt)
    return out
