"""The paper's own experiment models: DeepFM [8], Wide&Deep [6], ResNet20 [9].

These are the models the paper trains with D-Adam / CD-Adam (Criteo CTR,
MovieLens-20M, CIFAR-10). Hyperparameters match Section 6.1: embedding dim
10, MLP 400-400-400, dropout 0.5 (we expose the rate; benchmarks run
deterministic eval-mode unless a key is passed).

All parameters are float32 (these are small models; the paper's setting).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import common

PyTree = Any


# ------------------------------- DeepFM --------------------------------------


def init_deepfm(key, n_features: int, n_fields: int, embed_dim: int = 10,
                hidden: Tuple[int, ...] = (400, 400, 400)) -> PyTree:
    ks = jax.random.split(key, 4 + len(hidden))
    p = {
        "embed": (jax.random.normal(ks[0], (n_features, embed_dim))
                  * 0.01),
        "linear": jax.random.normal(ks[1], (n_features,)) * 0.01,
        "bias": jnp.zeros(()),
        "mlp": [],
    }
    d_in = n_fields * embed_dim
    mlp = []
    for i, h in enumerate(hidden):
        mlp.append({
            "w": common.dense_init(ks[2 + i], d_in, h, jnp.float32),
            "b": jnp.zeros((h,)),
        })
        d_in = h
    mlp.append({
        "w": common.dense_init(ks[2 + len(hidden)], d_in, 1, jnp.float32),
        "b": jnp.zeros((1,)),
    })
    p["mlp"] = mlp
    return p


def deepfm_logits(params: PyTree, feat_ids: jax.Array,
                  dropout_key: Optional[jax.Array] = None,
                  dropout_rate: float = 0.5) -> jax.Array:
    """feat_ids: (B, n_fields) int32 — one active feature id per field."""
    emb = params["embed"][feat_ids]                   # (B, F, E)
    # first order
    first = jnp.sum(params["linear"][feat_ids], axis=-1) + params["bias"]
    # FM second order: 0.5 * ((sum e)^2 - sum e^2)
    s = jnp.sum(emb, axis=1)
    s2 = jnp.sum(emb * emb, axis=1)
    second = 0.5 * jnp.sum(s * s - s2, axis=-1)
    # deep part
    h = emb.reshape(emb.shape[0], -1)
    for i, layer in enumerate(params["mlp"]):
        h = h @ layer["w"] + layer["b"]
        if i < len(params["mlp"]) - 1:
            h = jax.nn.relu(h)
            if dropout_key is not None:
                dropout_key, sub = jax.random.split(dropout_key)
                mask = jax.random.bernoulli(sub, 1 - dropout_rate, h.shape)
                h = h * mask / (1 - dropout_rate)
    return first + second + h[:, 0]


def deepfm_loss(params: PyTree, batch: PyTree,
                dropout_key: Optional[jax.Array] = None) -> jax.Array:
    """batch: {'feat_ids': (B, F) int32, 'label': (B,) in {0,1}}."""
    logits = deepfm_logits(params, batch["feat_ids"], dropout_key)
    y = batch["label"].astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


# ------------------------------ Wide&Deep ------------------------------------


def init_widedeep(key, n_features: int, n_fields: int, embed_dim: int = 10,
                  hidden: Tuple[int, ...] = (400, 400, 400)) -> PyTree:
    p = init_deepfm(key, n_features, n_fields, embed_dim, hidden)
    return p  # wide part = 'linear'; deep part = 'mlp'; no FM term


def widedeep_logits(params: PyTree, feat_ids: jax.Array,
                    dropout_key: Optional[jax.Array] = None,
                    dropout_rate: float = 0.5) -> jax.Array:
    emb = params["embed"][feat_ids]
    wide = jnp.sum(params["linear"][feat_ids], axis=-1) + params["bias"]
    h = emb.reshape(emb.shape[0], -1)
    for i, layer in enumerate(params["mlp"]):
        h = h @ layer["w"] + layer["b"]
        if i < len(params["mlp"]) - 1:
            h = jax.nn.relu(h)
            if dropout_key is not None:
                dropout_key, sub = jax.random.split(dropout_key)
                mask = jax.random.bernoulli(sub, 1 - dropout_rate, h.shape)
                h = h * mask / (1 - dropout_rate)
    return wide + h[:, 0]


def widedeep_loss(params: PyTree, batch: PyTree,
                  dropout_key: Optional[jax.Array] = None) -> jax.Array:
    logits = widedeep_logits(params, batch["feat_ids"], dropout_key)
    y = batch["label"].astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


# ------------------------------- ResNet20 ------------------------------------


def _conv_init(key, k, c_in, c_out):
    fan_in = k * k * c_in
    return jax.random.normal(key, (k, k, c_in, c_out)) * jnp.sqrt(
        2.0 / fan_in)


def init_resnet20(key, n_classes: int = 10, width: int = 16) -> PyTree:
    """He et al. CIFAR ResNet: 3 stages x 3 blocks x 2 convs + stem + fc."""
    ks = iter(jax.random.split(key, 64))
    p = {"stem": _conv_init(next(ks), 3, 3, width), "stages": []}
    c_in = width
    stages = []
    for stage, c_out in enumerate([width, 2 * width, 4 * width]):
        blocks = []
        for b in range(3):
            blk = {
                "conv1": _conv_init(next(ks), 3, c_in, c_out),
                "conv2": _conv_init(next(ks), 3, c_out, c_out),
                "scale1": jnp.ones((c_out,)), "bias1": jnp.zeros((c_out,)),
                "scale2": jnp.ones((c_out,)), "bias2": jnp.zeros((c_out,)),
            }
            if c_in != c_out:
                blk["proj"] = _conv_init(next(ks), 1, c_in, c_out)
            blocks.append(blk)
            c_in = c_out
        stages.append(blocks)
    p["stages"] = stages
    p["fc_w"] = common.dense_init(next(ks), c_in, n_classes, jnp.float32)
    p["fc_b"] = jnp.zeros((n_classes,))
    return p


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _norm_act(x, scale, bias):
    # GroupNorm(8) stand-in for BatchNorm — batch-stat-free so the per-worker
    # loss stays a pure function (decentralized workers have no shared BN
    # stats; the paper syncs none either).
    B, H, W, C = x.shape
    g = min(8, C)
    xg = x.reshape(B, H, W, g, C // g)
    mu = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + 1e-5)
    return jax.nn.relu(xg.reshape(B, H, W, C) * scale + bias)


def resnet20_logits(params: PyTree, images: jax.Array) -> jax.Array:
    """images: (B, 32, 32, 3) float32."""
    x = _conv(images, params["stem"])
    for stage, blocks in enumerate(params["stages"]):
        for b, blk in enumerate(blocks):
            stride = 2 if (stage > 0 and b == 0) else 1
            h = _conv(x, blk["conv1"], stride)
            h = _norm_act(h, blk["scale1"], blk["bias1"])
            h = _conv(h, blk["conv2"])
            sc = x
            if "proj" in blk:
                sc = _conv(x, blk["proj"], stride)
            x = _norm_act(h + sc, blk["scale2"], blk["bias2"])
    x = jnp.mean(x, axis=(1, 2))
    return x @ params["fc_w"] + params["fc_b"]


def resnet20_loss(params: PyTree, batch: PyTree) -> jax.Array:
    logits = resnet20_logits(params, batch["images"])
    labels = batch["label"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)
