"""Zamba2-style hybrid (arXiv:2411.15242): a Mamba2 backbone with a single
*shared* attention+MLP block invoked periodically.

Assigned config zamba2-7b: 81 Mamba2 layers (d_model=3584, ssm_state=64),
shared GQA attention block (32 heads) + SwiGLU MLP (d_ff=14336) re-applied
every ``shared_attn_period`` layers with the same weights (Zamba2's weight
sharing; we omit the per-invocation LoRA deltas — noted in DESIGN.md).

Layers run as segment-wise ``lax.scan``s (segments split at shared-block
insertion points) so an 81-layer model compiles as a handful of scan bodies.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, common, mamba2, mlp

PyTree = Any


def init_params(key, cfg: ModelConfig) -> PyTree:
    k_emb, k_layers, k_attn, k_mlp, k_head = jax.random.split(key, 5)
    dt = cfg.param_dtype
    layers = jax.vmap(lambda k: mamba2.init_layer(k, cfg))(
        jax.random.split(k_layers, cfg.n_layers))
    shared = {
        "attn": attention.init_attention(
            k_attn, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.resolved_head_dim, dt),
        "mlp": mlp.init_swiglu(k_mlp, cfg.d_model, cfg.d_ff, dt),
        "norm1": jnp.ones((cfg.d_model,), dt),
        "norm2": jnp.ones((cfg.d_model,), dt),
    }
    return {
        "embed": common.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dt),
        "layers": layers,
        "shared": shared,
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "lm_head": common.dense_init(k_head, cfg.d_model, cfg.vocab_size, dt),
    }


def _segments(n_layers: int, period: int):
    """Split [0, n_layers) into chunks; a shared attn block follows each
    chunk except possibly the last partial one."""
    if period <= 0:
        return [(0, n_layers, False)]
    segs = []
    start = 0
    while start < n_layers:
        end = min(start + period, n_layers)
        segs.append((start, end, end - start == period))
        start = end
    return segs


class HybridCache(NamedTuple):
    conv: jax.Array          # (L, B, k-1, di+2N)
    ssm: jax.Array           # (L, B, H, P, N)
    attn_k: jax.Array        # (A, B, S_max, n_kv, hd) — per shared-attn site
    attn_v: jax.Array
    index: jax.Array


def n_attn_sites(cfg: ModelConfig) -> int:
    return sum(1 for s in _segments(cfg.n_layers, cfg.shared_attn_period)
               if s[2])


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> HybridCache:
    st = mamba2.init_state(cfg, batch)
    L = cfg.n_layers
    A = n_attn_sites(cfg)
    hd = cfg.resolved_head_dim
    return HybridCache(
        jnp.broadcast_to(st.conv, (L,) + st.conv.shape),
        jnp.broadcast_to(st.ssm, (L,) + st.ssm.shape),
        jnp.zeros((A, batch, max_len, cfg.n_kv_heads, hd), dtype),
        jnp.zeros((A, batch, max_len, cfg.n_kv_heads, hd), dtype),
        jnp.zeros((), jnp.int32))


def _shared_block(shared: PyTree, h: jax.Array, cfg: ModelConfig,
                  positions) -> jax.Array:
    hn = common.rms_norm(h, shared["norm1"], cfg.norm_eps)
    h = h + attention.attention_forward(
        shared["attn"], hn, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
        causal=True, positions=positions)
    hn = common.rms_norm(h, shared["norm2"], cfg.norm_eps)
    return h + mlp.swiglu_forward(shared["mlp"], hn)


def forward(params: PyTree, tokens: jax.Array, cfg: ModelConfig, *,
            cache: Optional[HybridCache] = None, remat: str = "none"
            ) -> Tuple[jax.Array, PyTree]:
    """Training/prefill forward over the full sequence."""
    B, S = tokens.shape
    h = params["embed"][tokens].astype(cfg.compute_dtype)
    if cache is None:
        st = mamba2.init_state(cfg, B)
        conv_all = jnp.broadcast_to(st.conv, (cfg.n_layers,) + st.conv.shape)
        ssm_all = jnp.broadcast_to(st.ssm, (cfg.n_layers,) + st.ssm.shape)
        start = 0
    else:
        conv_all, ssm_all = cache.conv, cache.ssm
        start = cache.index

    positions = jnp.arange(S) + (0 if cache is None else start)

    def seg_body(carry, xs):
        h = carry
        layer, cs, ss = xs
        h, new_state = mamba2.layer_forward(
            layer, h, cfg, mamba2.MambaState(cs, ss))
        return h, (new_state.conv, new_state.ssm)

    if remat != "none":
        seg_body = jax.checkpoint(seg_body)

    new_conv, new_ssm = [], []
    for (s0, s1, has_attn) in _segments(cfg.n_layers,
                                        cfg.shared_attn_period):
        seg_layers = jax.tree_util.tree_map(lambda a: a[s0:s1],
                                            params["layers"])
        h, (cseg, sseg) = jax.lax.scan(
            seg_body, h, (seg_layers, conv_all[s0:s1], ssm_all[s0:s1]))
        new_conv.append(cseg)
        new_ssm.append(sseg)
        if has_attn:
            h = _shared_block(params["shared"], h, cfg, positions)

    h = common.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = h @ params["lm_head"].astype(h.dtype)
    states = (jnp.concatenate(new_conv), jnp.concatenate(new_ssm))
    return logits, states


def loss_fn(params: PyTree, batch: PyTree, cfg: ModelConfig, *,
            remat: str = "none") -> jax.Array:
    tokens = batch["tokens"]
    logits, _ = forward(params, tokens[:, :-1], cfg, remat=remat)
    return common.cross_entropy_loss(logits, tokens[:, 1:],
                                     batch.get("mask"))


# --------------------------- prefill / decode -------------------------------


def prefill(params: PyTree, tokens: jax.Array, cfg: ModelConfig, *,
            cache_len: Optional[int] = None
            ) -> Tuple[jax.Array, HybridCache]:
    """Full-sequence prefill that also fills the shared-attn KV sites."""
    B, S = tokens.shape
    cache_len = cache_len or S
    h = params["embed"][tokens].astype(cfg.compute_dtype)
    st = mamba2.init_state(cfg, B)
    positions = jnp.arange(S)

    def seg_body(carry, xs):
        h = carry
        layer, cs, ss = xs
        h, new_state = mamba2.layer_forward(
            layer, h, cfg, mamba2.MambaState(cs, ss))
        return h, (new_state.conv, new_state.ssm)

    new_conv, new_ssm, aks, avs = [], [], [], []
    for (s0, s1, has_attn) in _segments(cfg.n_layers,
                                        cfg.shared_attn_period):
        seg_layers = jax.tree_util.tree_map(lambda a: a[s0:s1],
                                            params["layers"])
        conv0 = jnp.broadcast_to(st.conv, (s1 - s0,) + st.conv.shape)
        ssm0 = jnp.broadcast_to(st.ssm, (s1 - s0,) + st.ssm.shape)
        h, (cseg, sseg) = jax.lax.scan(seg_body, h, (seg_layers, conv0, ssm0))
        new_conv.append(cseg)
        new_ssm.append(sseg)
        if has_attn:
            sh = params["shared"]
            hn = common.rms_norm(h, sh["norm1"], cfg.norm_eps)
            q, k, v = attention._project_qkv(
                sh["attn"], hn, cfg.n_heads, cfg.n_kv_heads,
                cfg.resolved_head_dim)
            pos_b = jnp.broadcast_to(positions, (B, S))
            q = common.apply_rope(q, pos_b, cfg.rope_theta)
            k = common.apply_rope(k, pos_b, cfg.rope_theta)
            ao = attention.sdpa(q, k, v, causal=True,
                                window=cfg.sliding_window)
            h = h + ao @ sh["attn"]["wo"].astype(ao.dtype)
            hn = common.rms_norm(h, sh["norm2"], cfg.norm_eps)
            h = h + mlp.swiglu_forward(sh["mlp"], hn)
            pad = cache_len - S
            aks.append(jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))))
            avs.append(jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))))

    h = common.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = (h[:, -1:, :] @ params["lm_head"].astype(h.dtype))
    cache = HybridCache(
        jnp.concatenate(new_conv), jnp.concatenate(new_ssm),
        jnp.stack(aks) if aks else jnp.zeros(
            (0, B, cache_len, cfg.n_kv_heads, cfg.resolved_head_dim),
            cfg.compute_dtype),
        jnp.stack(avs) if avs else jnp.zeros(
            (0, B, cache_len, cfg.n_kv_heads, cfg.resolved_head_dim),
            cfg.compute_dtype),
        jnp.asarray(S, jnp.int32))
    return logits, cache


def decode_step(params: PyTree, cache: HybridCache, token: jax.Array,
                cfg: ModelConfig) -> Tuple[jax.Array, HybridCache]:
    B = token.shape[0]
    h = params["embed"][token[:, None]].astype(cfg.compute_dtype)
    index = cache.index

    def seg_body(carry, xs):
        h = carry
        layer, cs, ss = xs
        h, new_state = mamba2.layer_forward(
            layer, h, cfg, mamba2.MambaState(cs, ss))
        return h, (new_state.conv, new_state.ssm)

    new_conv, new_ssm = [], []
    new_ak, new_av = [], []
    site = 0
    for (s0, s1, has_attn) in _segments(cfg.n_layers,
                                        cfg.shared_attn_period):
        seg_layers = jax.tree_util.tree_map(lambda a: a[s0:s1],
                                            params["layers"])
        h, (cseg, sseg) = jax.lax.scan(
            seg_body, h, (seg_layers, cache.conv[s0:s1], cache.ssm[s0:s1]))
        new_conv.append(cseg)
        new_ssm.append(sseg)
        if has_attn:
            sh = params["shared"]
            hn = common.rms_norm(h, sh["norm1"], cfg.norm_eps)
            ao, nk, nv = attention.decode_attention(
                sh["attn"], hn, cache.attn_k[site], cache.attn_v[site],
                index, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta)
            h = h + ao
            hn = common.rms_norm(h, sh["norm2"], cfg.norm_eps)
            h = h + mlp.swiglu_forward(sh["mlp"], hn)
            new_ak.append(nk)
            new_av.append(nv)
            site += 1

    h = common.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = (h @ params["lm_head"].astype(h.dtype))[:, 0, :]
    new_cache = HybridCache(
        jnp.concatenate(new_conv), jnp.concatenate(new_ssm),
        jnp.stack(new_ak) if new_ak else cache.attn_k,
        jnp.stack(new_av) if new_av else cache.attn_v,
        index + 1)
    return logits, new_cache
