"""Whisper-large-v3 backbone (arXiv:2212.04356): encoder-decoder transformer.

Per the brief, the mel-spectrogram + conv feature extractor frontend is a
STUB: ``input_specs`` supplies precomputed frame embeddings
(B, n_audio_ctx=1500, d_model) and this module implements the real encoder
transformer over them plus the causal decoder with cross-attention.

Deviations (documented): learned decoder positions are allocated to
``max_text_positions`` (33024) so the assigned train_4k AND prefill_32k
shapes fit (the real model caps at 448); long_500k is skipped for this
arch entirely (see DESIGN.md §skips).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, common, mlp

PyTree = Any

MAX_TEXT_POSITIONS = 33024


def _init_block(key, cfg: ModelConfig, cross: bool) -> PyTree:
    d = cfg.d_model
    dt = cfg.param_dtype
    n = 3 if cross else 2
    ks = jax.random.split(key, n + 1)
    p = {
        "self_attn": attention.init_attention(
            ks[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, dt,
            qkv_bias=True),
        "mlp": mlp.init_gelu_mlp(ks[1], d, cfg.d_ff, dt),
        "ln1": jnp.ones((d,), dt), "ln1_b": jnp.zeros((d,), dt),
        "ln2": jnp.ones((d,), dt), "ln2_b": jnp.zeros((d,), dt),
    }
    if cross:
        p["cross_attn"] = attention.init_attention(
            ks[2], d, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim, dt,
            qkv_bias=True)
        p["ln_x"] = jnp.ones((d,), dt)
        p["ln_x_b"] = jnp.zeros((d,), dt)
    return p


def init_params(key, cfg: ModelConfig) -> PyTree:
    k_enc, k_dec, k_emb, k_pos = jax.random.split(key, 4)
    dt = cfg.param_dtype
    enc = jax.vmap(lambda k: _init_block(k, cfg, cross=False))(
        jax.random.split(k_enc, cfg.n_encoder_layers))
    dec = jax.vmap(lambda k: _init_block(k, cfg, cross=True))(
        jax.random.split(k_dec, cfg.n_layers))
    return {
        "enc_layers": enc,
        "dec_layers": dec,
        "embed": common.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dt),
        "dec_pos": (jax.random.normal(k_pos, (MAX_TEXT_POSITIONS,
                                               cfg.d_model), jnp.float32)
                    * 0.01).astype(dt),
        "enc_ln": jnp.ones((cfg.d_model,), dt),
        "enc_ln_b": jnp.zeros((cfg.d_model,), dt),
        "dec_ln": jnp.ones((cfg.d_model,), dt),
        "dec_ln_b": jnp.zeros((cfg.d_model,), dt),
    }


def encode(params: PyTree, audio_embeds: jax.Array,
           cfg: ModelConfig) -> jax.Array:
    """audio_embeds: (B, T, d) stubbed conv-frontend output."""
    h = audio_embeds.astype(cfg.compute_dtype)
    T = h.shape[1]
    h = h + common.sinusoidal_positions(T, cfg.d_model).astype(h.dtype)

    def body(carry, layer):
        h = carry
        hn = common.layer_norm(h, layer["ln1"], layer["ln1_b"], cfg.norm_eps)
        h = h + attention.attention_forward(
            layer["self_attn"], hn, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
            rope_theta=cfg.rope_theta, causal=False, use_rope=False)
        hn = common.layer_norm(h, layer["ln2"], layer["ln2_b"], cfg.norm_eps)
        return h + mlp.gelu_mlp_forward(layer["mlp"], hn), ()

    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return common.layer_norm(h, params["enc_ln"], params["enc_ln_b"],
                             cfg.norm_eps)


def _decoder_block(layer: PyTree, h: jax.Array, enc_out: jax.Array,
                   cfg: ModelConfig, positions) -> jax.Array:
    hn = common.layer_norm(h, layer["ln1"], layer["ln1_b"], cfg.norm_eps)
    h = h + attention.attention_forward(
        layer["self_attn"], hn, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
        rope_theta=cfg.rope_theta, causal=True, use_rope=False,
        positions=positions)
    hn = common.layer_norm(h, layer["ln_x"], layer["ln_x_b"], cfg.norm_eps)
    h = h + attention.cross_attention_forward(
        layer["cross_attn"], hn, enc_out, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim)
    hn = common.layer_norm(h, layer["ln2"], layer["ln2_b"], cfg.norm_eps)
    return h + mlp.gelu_mlp_forward(layer["mlp"], hn)


def forward(params: PyTree, tokens: jax.Array, audio_embeds: jax.Array,
            cfg: ModelConfig, *, remat: str = "none") -> jax.Array:
    """Teacher-forced decode over the full text sequence."""
    enc_out = encode(params, audio_embeds, cfg)
    B, S = tokens.shape
    h = params["embed"][tokens].astype(cfg.compute_dtype)
    h = h + params["dec_pos"][:S][None].astype(h.dtype)
    positions = jnp.arange(S)

    def body(carry, layer):
        return _decoder_block(layer, carry, enc_out, cfg, positions), ()

    if remat != "none":
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["dec_layers"])
    h = common.layer_norm(h, params["dec_ln"], params["dec_ln_b"],
                          cfg.norm_eps)
    return h @ params["embed"].T.astype(h.dtype)   # tied output head


def loss_fn(params: PyTree, batch: PyTree, cfg: ModelConfig, *,
            remat: str = "none") -> jax.Array:
    tokens = batch["tokens"]
    logits = forward(params, tokens[:, :-1], batch["audio_embeds"], cfg,
                     remat=remat)
    return common.cross_entropy_loss(logits, tokens[:, 1:],
                                     batch.get("mask"))


# --------------------------- prefill / decode -------------------------------


class WhisperCache(NamedTuple):
    self_k: jax.Array   # (L, B, S_max, n_kv, hd)
    self_v: jax.Array
    cross_k: jax.Array  # (L, B, T_audio, n_kv, hd) — precomputed, static
    cross_v: jax.Array
    index: jax.Array


def prefill(params: PyTree, tokens: jax.Array, audio_embeds: jax.Array,
            cfg: ModelConfig, *, cache_len: Optional[int] = None
            ) -> Tuple[jax.Array, WhisperCache]:
    enc_out = encode(params, audio_embeds, cfg)
    B, S = tokens.shape
    cache_len = cache_len or S
    T = enc_out.shape[1]
    h = params["embed"][tokens].astype(cfg.compute_dtype)
    h = h + params["dec_pos"][:S][None].astype(h.dtype)
    positions = jnp.arange(S)
    hd = cfg.resolved_head_dim

    def body(carry, layer):
        h = carry
        hn = common.layer_norm(h, layer["ln1"], layer["ln1_b"], cfg.norm_eps)
        q, k, v = attention._project_qkv(layer["self_attn"], hn, cfg.n_heads,
                                         cfg.n_kv_heads, hd)
        ao = attention.sdpa(q, k, v, causal=True)
        h = h + ao @ layer["self_attn"]["wo"].astype(ao.dtype)
        hn = common.layer_norm(h, layer["ln_x"], layer["ln_x_b"],
                               cfg.norm_eps)
        h = h + attention.cross_attention_forward(
            layer["cross_attn"], hn, enc_out, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=hd)
        # cross K/V are static per request — precompute once for decode
        ck = (enc_out @ layer["cross_attn"]["wk"].astype(enc_out.dtype)
              + layer["cross_attn"]["bk"].astype(enc_out.dtype)
              ).reshape(B, T, cfg.n_kv_heads, hd)
        cv = (enc_out @ layer["cross_attn"]["wv"].astype(enc_out.dtype)
              + layer["cross_attn"]["bv"].astype(enc_out.dtype)
              ).reshape(B, T, cfg.n_kv_heads, hd)
        hn = common.layer_norm(h, layer["ln2"], layer["ln2_b"], cfg.norm_eps)
        h = h + mlp.gelu_mlp_forward(layer["mlp"], hn)
        pad = cache_len - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return h, (k, v, ck, cv)

    h, (ks, vs, cks, cvs) = jax.lax.scan(body, h, params["dec_layers"])
    h = common.layer_norm(h, params["dec_ln"], params["dec_ln_b"],
                          cfg.norm_eps)
    logits = h[:, -1:, :] @ params["embed"].T.astype(h.dtype)
    return logits, WhisperCache(ks, vs, cks, cvs,
                                jnp.asarray(S, jnp.int32))


def decode_step(params: PyTree, cache: WhisperCache, token: jax.Array,
                cfg: ModelConfig) -> Tuple[jax.Array, WhisperCache]:
    B = token.shape[0]
    index = cache.index
    h = params["embed"][token[:, None]].astype(cfg.compute_dtype)
    h = h + jax.lax.dynamic_slice_in_dim(
        params["dec_pos"], index, 1, axis=0)[None].astype(h.dtype)
    hd = cfg.resolved_head_dim

    def body(carry, xs):
        h = carry
        layer, lk, lv, ck, cv = xs
        hn = common.layer_norm(h, layer["ln1"], layer["ln1_b"], cfg.norm_eps)
        ao, lk, lv = attention.decode_attention(
            layer["self_attn"], hn, lk, lv, index, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=hd,
            rope_theta=cfg.rope_theta, use_rope=False)
        h = h + ao
        hn = common.layer_norm(h, layer["ln_x"], layer["ln_x_b"],
                               cfg.norm_eps)
        q = (hn @ layer["cross_attn"]["wq"].astype(hn.dtype)
             + layer["cross_attn"]["bq"].astype(hn.dtype)
             ).reshape(B, 1, cfg.n_heads, hd)
        scores = attention._gqa_scores(q, ck)
        probs = jax.nn.softmax(scores, axis=-1)
        ao = attention._gqa_out(probs, cv)
        h = h + ao @ layer["cross_attn"]["wo"].astype(ao.dtype)
        hn = common.layer_norm(h, layer["ln2"], layer["ln2_b"], cfg.norm_eps)
        h = h + mlp.gelu_mlp_forward(layer["mlp"], hn)
        return h, (lk, lv)

    h, (ks, vs) = jax.lax.scan(
        body, h, (params["dec_layers"], cache.self_k, cache.self_v,
                  cache.cross_k, cache.cross_v))
    h = common.layer_norm(h, params["dec_ln"], params["dec_ln_b"],
                          cfg.norm_eps)
    logits = (h @ params["embed"].T.astype(h.dtype))[:, 0, :]
    return logits, WhisperCache(ks, vs, cache.cross_k, cache.cross_v,
                                index + 1)
