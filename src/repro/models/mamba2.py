"""Mamba2 (SSD) block — substrate for the zamba2-7b hybrid arch.

Per layer (n_groups = 1, faithful to the Mamba2 structure):

  [z, xBC, dt] = x @ W_in
  xBC = silu(causal_depthwise_conv(xBC, k=4))
  x_s (H, P), B (N), C (N);  dt = softplus(dt + dt_bias);  a = exp(-exp(A)dt)
  h_t = a_t * h_{t-1} + (dt_t * x_t) (x) B_t          h: (H, P, N)
  y_t = h_t . C_t + D * x_t
  out = W_out( rmsnorm(y) * silu(z) )

State is O(H*P*N) independent of context — zamba2 runs long_500k natively.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common

PyTree = Any


def init_layer(key, cfg: ModelConfig) -> PyTree:
    d = cfg.d_model
    di = cfg.d_inner
    N = cfg.ssm_state
    H = cfg.resolved_ssm_heads
    ck = cfg.ssm_conv
    dt = cfg.param_dtype
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * di + 2 * N + H
    return {
        "norm": jnp.ones((d,), dt),
        "in_proj": common.dense_init(ks[0], d, d_in_proj, dt),
        "conv_w": (jax.random.normal(ks[1], (ck, di + 2 * N), jnp.float32)
                   * 0.1).astype(dt),
        "conv_b": jnp.zeros((di + 2 * N,), dt),
        "A_log": jnp.zeros((H,), jnp.float32),      # a = exp(-exp(A_log)*dt)
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "gn": jnp.ones((di,), dt),
        "out_proj": common.dense_init(ks[2], di, d, dt),
    }


class MambaState(NamedTuple):
    conv: jax.Array   # (B, k-1, di + 2N) — trailing conv inputs
    ssm: jax.Array    # (B, H, P, N) f32


def init_state(cfg: ModelConfig, batch: int) -> MambaState:
    di, N = cfg.d_inner, cfg.ssm_state
    H = cfg.resolved_ssm_heads
    P = di // H
    return MambaState(
        jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * N), cfg.compute_dtype),
        jnp.zeros((batch, H, P, N), jnp.float32))


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 prev: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d. x: (B, S, C); w: (k, C); prev: (B, k-1, C).
    Returns (out (B,S,C), new_prev)."""
    k = w.shape[0]
    xp = jnp.concatenate([prev.astype(x.dtype), x], axis=1)  # (B, S+k-1, C)
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i:i + x.shape[1], :] * w[i][None, None, :].astype(
            x.dtype)
    new_prev = xp[:, -(k - 1):, :] if k > 1 else prev
    return out + b.astype(x.dtype), new_prev


def ssd_scan(x, dt, a, Bm, Cm, state):
    """x: (B,S,H,P); dt,a: (B,S,H); Bm,Cm: (B,S,N); state: (B,H,P,N)."""
    x, dt, a, Bm, Cm = (t.astype(jnp.float32) for t in (x, dt, a, Bm, Cm))

    def step(h, inp):
        x_t, dt_t, a_t, b_t, c_t = inp
        upd = (dt_t[..., None] * x_t)[..., None] * b_t[:, None, None, :]
        h = a_t[..., None, None] * h + upd
        y = jnp.einsum("bhpn,bn->bhp", h, c_t)
        return h, y

    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (x, dt, a, Bm, Cm))
    state, ys = jax.lax.scan(step, state, inputs)
    return jnp.moveaxis(ys, 0, 1), state  # (B,S,H,P), (B,H,P,N)


def layer_forward(layer: PyTree, h: jax.Array, cfg: ModelConfig,
                  state: MambaState) -> Tuple[jax.Array, MambaState]:
    """Pre-norm residual Mamba2 block. h: (B, S, d)."""
    B, S, d = h.shape
    di, N = cfg.d_inner, cfg.ssm_state
    H = cfg.resolved_ssm_heads
    P = di // H
    dtype = h.dtype

    hn = common.rms_norm(h, layer["norm"], cfg.norm_eps)
    zxbcdt = hn @ layer["in_proj"].astype(dtype)
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + di + 2 * N]
    dt_raw = zxbcdt[..., -H:]

    xBC, new_conv = _causal_conv(xBC, layer["conv_w"], layer["conv_b"],
                                 state.conv)
    xBC = jax.nn.silu(xBC.astype(jnp.float32)).astype(dtype)
    x_s = xBC[..., :di].reshape(B, S, H, P)
    Bm = xBC[..., di:di + N]
    Cm = xBC[..., di + N:]

    dt_v = jax.nn.softplus(dt_raw.astype(jnp.float32)
                           + layer["dt_bias"][None, None, :])
    a = jnp.exp(-jnp.exp(layer["A_log"])[None, None, :] * dt_v)

    y, new_ssm = ssd_scan(x_s, dt_v, a, Bm, Cm, state.ssm)
    y = y + layer["D"][None, None, :, None] * x_s.astype(jnp.float32)
    y = y.reshape(B, S, di)
    y = common.rms_norm(y.astype(dtype), layer["gn"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(dtype)
    out = y @ layer["out_proj"].astype(dtype)
    return h + out, MambaState(new_conv, new_ssm)
