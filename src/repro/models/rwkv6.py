"""RWKV6 "Finch" (arXiv:2404.05892): attention-free LM with data-dependent
per-channel decay. Assigned arch: rwkv6-3b (32L, d_model=2560, d_ff=8960,
vocab=65536).

Structure per layer (faithful to the paper, with the low-rank 'token-shift
lerp' simplified to static mix coefficients + the low-rank *decay* kept
data-dependent, which is RWKV6's defining feature):

  time-mix:  r,k,v,g,w projections of lerp(x, x_{t-1}); decay
             w_t = exp(-exp(w0 + tanh(x_w A) B)) in (0,1)^d;
             WKV state S in R^{H x D x D}:
                 y_t = r_t . (S + (u*k_t) (x) v_t)
                 S  <- diag(w_t) S + k_t (x) v_t
             y -> per-head groupnorm -> * silu(g) -> W_o
  channel-mix: k = relu(lerp @ W_k)^2 ; out = sigmoid(lerp @ W_r) * (k W_v)

The sequential scan is O(S) — this arch runs `long_500k` natively (state is
O(1) in context length). The time scan is the perf hot spot; a chunked
Pallas kernel lives in repro.kernels.rwkv_scan (`wkv_impl='pallas'`).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common

PyTree = Any


# ------------------------------- params -------------------------------------


def init_layer(key, cfg: ModelConfig) -> PyTree:
    d = cfg.d_model
    hs = cfg.rwkv_head_size
    H = d // hs
    rank = cfg.rwkv_decay_rank
    dt = cfg.param_dtype
    ks = jax.random.split(key, 12)
    return {
        "ln1": jnp.ones((d,), dt), "ln1_b": jnp.zeros((d,), dt),
        "ln2": jnp.ones((d,), dt), "ln2_b": jnp.zeros((d,), dt),
        "mix": 0.5 * jnp.ones((5, d), dt),          # r, k, v, w, g lerps
        "w_r": common.dense_init(ks[0], d, d, dt),
        "w_k": common.dense_init(ks[1], d, d, dt),
        "w_v": common.dense_init(ks[2], d, d, dt),
        "w_g": common.dense_init(ks[3], d, d, dt),
        "w_o": common.dense_init(ks[4], d, d, dt),
        "w0": jnp.full((d,), -5.0, dt),             # base decay (slow)
        "w_A": common.dense_init(ks[5], d, rank, dt, scale=0.01),
        "w_B": common.dense_init(ks[6], rank, d, dt, scale=0.01),
        "u": (jax.random.normal(ks[7], (H, hs), jnp.float32) * 0.1
              ).astype(dt),                          # per-head bonus
        "gn": jnp.ones((d,), dt), "gn_b": jnp.zeros((d,), dt),
        "cm_mix": 0.5 * jnp.ones((2, d), dt),        # channel-mix lerps (k, r)
        "cm_k": common.dense_init(ks[8], d, cfg.d_ff, dt),
        "cm_v": common.dense_init(ks[9], cfg.d_ff, d, dt),
        "cm_r": common.dense_init(ks[10], d, d, dt),
    }


def init_params(key, cfg: ModelConfig) -> PyTree:
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    dt = cfg.param_dtype
    layers = jax.vmap(lambda k: init_layer(k, cfg))(
        jax.random.split(k_layers, cfg.n_layers))
    return {
        "embed": common.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dt),
        "layers": layers,
        "ln_out": jnp.ones((cfg.d_model,), dt),
        "ln_out_b": jnp.zeros((cfg.d_model,), dt),
        "lm_head": common.dense_init(k_head, cfg.d_model, cfg.vocab_size, dt),
    }


# ------------------------------ primitives ----------------------------------


def _token_shift(x: jax.Array, prev: Optional[jax.Array] = None) -> jax.Array:
    """x: (B, S, d) -> previous-token features; prev (B, d) seeds t=0."""
    if prev is None:
        prev = jnp.zeros_like(x[:, 0])
    return jnp.concatenate([prev[:, None, :].astype(x.dtype),
                            x[:, :-1, :]], axis=1)


def _lerp(x, xp, mu):
    return x + mu.astype(x.dtype) * (xp - x)


def _decay(layer: PyTree, xw: jax.Array) -> jax.Array:
    """Data-dependent decay w_t in (0,1): exp(-exp(w0 + tanh(x A) B))."""
    low = jnp.tanh(xw.astype(jnp.float32) @ layer["w_A"].astype(jnp.float32))
    logw = layer["w0"].astype(jnp.float32) \
        + low @ layer["w_B"].astype(jnp.float32)
    return jnp.exp(-jnp.exp(logw))


def wkv_scan(r, k, v, w, u, state):
    """Sequential WKV recurrence (the jnp reference path).

    r,k,v,w: (B, S, H, D); u: (H, D); state: (B, H, D, D) [key x value].
    Returns (y (B,S,H,D), final_state). f32 accumulation.
    """
    r, k, v, w = (t.astype(jnp.float32) for t in (r, k, v, w))
    u = u.astype(jnp.float32)

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp          # (B, H, D)
        kv = k_t[..., :, None] * v_t[..., None, :]           # (B,H,D,D)
        y = jnp.einsum("bhi,bhij->bhj", r_t,
                       S + u[None, :, :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, y

    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    state, ys = jax.lax.scan(step, state.astype(jnp.float32), inputs)
    return jnp.moveaxis(ys, 0, 1), state


def _group_norm(y: jax.Array, w, b, H: int, eps: float = 64e-5) -> jax.Array:
    """Per-head LayerNorm over the head dim. y: (B, S, H*D)."""
    B, S, d = y.shape
    yh = y.reshape(B, S, H, d // H).astype(jnp.float32)
    mu = jnp.mean(yh, axis=-1, keepdims=True)
    var = jnp.var(yh, axis=-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + eps)
    return (yh.reshape(B, S, d) * w.astype(jnp.float32)
            + b.astype(jnp.float32))


# ------------------------------- blocks -------------------------------------


def time_mix(layer: PyTree, x: jax.Array, cfg: ModelConfig,
             prev_x: Optional[jax.Array], state: jax.Array,
             wkv_impl: str = "xla") -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (out, last_x, new_state). x: (B, S, d) post-ln."""
    B, S, d = x.shape
    hs = cfg.rwkv_head_size
    H = d // hs
    dt = x.dtype
    xp = _token_shift(x, prev_x)
    mix = layer["mix"]
    xr, xk, xv, xw, xg = (_lerp(x, xp, mix[i]) for i in range(5))
    r = (xr @ layer["w_r"].astype(dt)).reshape(B, S, H, hs)
    k = (xk @ layer["w_k"].astype(dt)).reshape(B, S, H, hs)
    v = (xv @ layer["w_v"].astype(dt)).reshape(B, S, H, hs)
    g = jax.nn.silu((xg @ layer["w_g"].astype(dt)).astype(jnp.float32))
    w = _decay(layer, xw).reshape(B, S, H, hs)
    if wkv_impl == "pallas":
        from repro.kernels import ops as kops
        y, new_state = kops.rwkv_scan(r, k, v, w, layer["u"], state)
    else:
        y, new_state = wkv_scan(r, k, v, w, layer["u"], state)
    y = _group_norm(y.reshape(B, S, d), layer["gn"], layer["gn_b"], H)
    out = (y * g).astype(dt) @ layer["w_o"].astype(dt)
    return out, x[:, -1, :], new_state.astype(state.dtype)


def channel_mix(layer: PyTree, x: jax.Array,
                prev_x: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    dt = x.dtype
    xp = _token_shift(x, prev_x)
    xk = _lerp(x, xp, layer["cm_mix"][0])
    xr = _lerp(x, xp, layer["cm_mix"][1])
    k = jnp.square(jax.nn.relu(xk @ layer["cm_k"].astype(dt)))
    out = jax.nn.sigmoid((xr @ layer["cm_r"].astype(dt)
                          ).astype(jnp.float32)).astype(dt) \
        * (k @ layer["cm_v"].astype(dt))
    return out, x[:, -1, :]


def _layer(layer: PyTree, h: jax.Array, cfg: ModelConfig,
           tm_prev, cm_prev, state, wkv_impl="xla"):
    hn = common.layer_norm(h, layer["ln1"], layer["ln1_b"], cfg.norm_eps)
    out, tm_x, state = time_mix(layer, hn, cfg, tm_prev, state, wkv_impl)
    h = h + out
    hn = common.layer_norm(h, layer["ln2"], layer["ln2_b"], cfg.norm_eps)
    out, cm_x = channel_mix(layer, hn, cm_prev)
    return h + out, tm_x, cm_x, state


# ----------------------------- full forward ---------------------------------


class RWKVCache(NamedTuple):
    tm_x: jax.Array    # (L, B, d)   last token-shift input, time-mix
    cm_x: jax.Array    # (L, B, d)   last token-shift input, channel-mix
    wkv: jax.Array     # (L, B, H, D, D) WKV state
    index: jax.Array


def init_cache(cfg: ModelConfig, batch: int,
               dtype=None) -> RWKVCache:
    d = cfg.d_model
    H = d // cfg.rwkv_head_size
    hs = cfg.rwkv_head_size
    L = cfg.n_layers
    dtype = dtype or cfg.compute_dtype
    return RWKVCache(
        jnp.zeros((L, batch, d), dtype), jnp.zeros((L, batch, d), dtype),
        jnp.zeros((L, batch, H, hs, hs), jnp.float32),
        jnp.zeros((), jnp.int32))


def forward(params: PyTree, tokens: jax.Array, cfg: ModelConfig, *,
            cache: Optional[RWKVCache] = None, remat: str = "none",
            wkv_impl: str = "xla"
            ) -> Tuple[jax.Array, RWKVCache]:
    """Full-sequence forward (train / prefill). Returns (logits, cache)."""
    B, S = tokens.shape
    h = params["embed"][tokens].astype(cfg.compute_dtype)
    if cache is None:
        cache = init_cache(cfg, B)

    def body(carry, xs):
        h = carry
        layer, tm_p, cm_p, st = xs
        h, tm_x, cm_x, st = _layer(layer, h, cfg, tm_p, cm_p, st, wkv_impl)
        return h, (tm_x, cm_x, st)

    if remat != "none":
        body = jax.checkpoint(body)
    h, (tm, cm, wkv) = jax.lax.scan(
        body, h, (params["layers"], cache.tm_x, cache.cm_x, cache.wkv))
    h = common.layer_norm(h, params["ln_out"], params["ln_out_b"],
                          cfg.norm_eps)
    logits = h @ params["lm_head"].astype(h.dtype)
    new_cache = RWKVCache(tm, cm, wkv, cache.index + S)
    return logits, new_cache


def loss_fn(params: PyTree, batch: PyTree, cfg: ModelConfig, *,
            remat: str = "none") -> jax.Array:
    tokens = batch["tokens"]
    logits, _ = forward(params, tokens[:, :-1], cfg, remat=remat)
    return common.cross_entropy_loss(logits, tokens[:, 1:],
                                     batch.get("mask"))


def prefill(params: PyTree, tokens: jax.Array, cfg: ModelConfig,
            **kw) -> Tuple[jax.Array, RWKVCache]:
    logits, cache = forward(params, tokens, cfg)
    return logits[:, -1:, :], cache


def decode_step(params: PyTree, cache: RWKVCache, token: jax.Array,
                cfg: ModelConfig) -> Tuple[jax.Array, RWKVCache]:
    logits, cache = forward(params, token[:, None], cfg, cache=cache)
    return logits[:, 0, :], cache
