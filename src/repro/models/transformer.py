"""Unified decoder-only transformer LM (dense + MoE families).

Layers are *stacked*: every per-layer parameter leaf carries a leading
``n_layers`` dim and the forward pass is a single ``lax.scan`` over it —
HLO size and compile time stay O(1) in depth (essential for the 64-81 layer
assigned configs), and remat policies wrap the scan body.

Three entry points per the serving/training split:
  forward(params, tokens, cfg, extra_embeds=None)  -> logits (train shapes)
  prefill(params, tokens, cfg, ...)                -> (logits, KVCache)
  decode_step(params, cache, token, cfg)           -> (logits, KVCache)
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, common, mlp, moe

PyTree = Any


# ------------------------------- params -------------------------------------


def init_layer(key, cfg: ModelConfig) -> PyTree:
    k_attn, k_ffn = jax.random.split(key)
    dt = cfg.param_dtype
    p = {
        "attn": attention.init_attention(
            k_attn, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.resolved_head_dim, dt, cfg.qkv_bias),
        "norm1": jnp.ones((cfg.d_model,), dt),
        "norm2": jnp.ones((cfg.d_model,), dt),
    }
    if cfg.norm_kind == "layer":
        p["norm1_b"] = jnp.zeros((cfg.d_model,), dt)
        p["norm2_b"] = jnp.zeros((cfg.d_model,), dt)
    if cfg.family == "moe" or (cfg.n_experts and cfg.experts_per_token):
        p["moe"] = moe.init_moe(k_ffn, cfg.d_model, cfg.d_ff, cfg.n_experts,
                                dt)
    elif cfg.mlp_kind == "gelu":
        p["mlp"] = mlp.init_gelu_mlp(k_ffn, cfg.d_model, cfg.d_ff, dt)
    else:
        p["mlp"] = mlp.init_swiglu(k_ffn, cfg.d_model, cfg.d_ff, dt)
    return p


def init_params(key, cfg: ModelConfig) -> PyTree:
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    dt = cfg.param_dtype
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    p = {
        "embed": common.embed_init(k_emb, cfg.vocab_size, cfg.d_model, dt),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if cfg.norm_kind == "layer":
        p["final_norm_b"] = jnp.zeros((cfg.d_model,), dt)
    if not cfg.tie_embeddings:
        p["lm_head"] = common.dense_init(k_head, cfg.d_model, cfg.vocab_size,
                                         dt)
    return p


# ------------------------------- forward ------------------------------------


def _norm(x, w, b, kind, eps):
    if kind == "layer":
        return common.layer_norm(x, w, b, eps)
    return common.rms_norm(x, w, eps)


def _layer_forward(layer: PyTree, h: jax.Array, cfg: ModelConfig,
                   positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Returns (h, aux_loss)."""
    hn = _norm(h, layer["norm1"], layer.get("norm1_b"), cfg.norm_kind,
               cfg.norm_eps)
    h = h + attention.attention_forward(
        layer["attn"], hn, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
        causal=True, window=cfg.sliding_window, positions=positions)
    hn = _norm(h, layer["norm2"], layer.get("norm2_b"), cfg.norm_kind,
               cfg.norm_eps)
    if "moe" in layer:
        ffn_out, aux = moe.moe_forward(
            layer["moe"], hn, top_k=cfg.experts_per_token,
            capacity_factor=cfg.capacity_factor,
            group_size=cfg.moe_group_size)
    elif cfg.mlp_kind == "gelu":
        ffn_out, aux = mlp.gelu_mlp_forward(layer["mlp"], hn), 0.0
    else:
        ffn_out, aux = mlp.swiglu_forward(layer["mlp"], hn), 0.0
    return h + ffn_out, jnp.asarray(aux, jnp.float32)


def _remat_wrap(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)  # 'full'


def backbone(params: PyTree, h: jax.Array, cfg: ModelConfig,
             positions: jax.Array, remat: str = "none") -> Tuple[jax.Array,
                                                                 jax.Array]:
    """Embed-space in, embed-space out. Returns (h, total_aux)."""

    def body(carry, layer):
        h = carry
        h, aux = _layer_forward(layer, h, cfg, positions)
        return h, aux

    body = _remat_wrap(body, remat)
    h, auxes = jax.lax.scan(body, h, params["layers"])
    return h, jnp.sum(auxes)


def embed_tokens(params: PyTree, tokens: jax.Array,
                 cfg: ModelConfig) -> jax.Array:
    return params["embed"][tokens].astype(cfg.compute_dtype)


def unembed(params: PyTree, h: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = _norm(h, params["final_norm"], params.get("final_norm_b"),
              cfg.norm_kind, cfg.norm_eps)
    if cfg.tie_embeddings:
        return h @ params["embed"].T.astype(h.dtype)
    return h @ params["lm_head"].astype(h.dtype)


def forward(params: PyTree, tokens: jax.Array, cfg: ModelConfig, *,
            extra_embeds: Optional[jax.Array] = None,
            remat: str = "none") -> Tuple[jax.Array, jax.Array]:
    """Training forward. tokens: (B, S) int32. extra_embeds (VLM stub):
    (B, P, d) prepended before the token embeddings. Returns
    (logits (B, S', V), aux_loss) where S' includes prepended positions."""
    h = embed_tokens(params, tokens, cfg)
    if extra_embeds is not None:
        h = jnp.concatenate([extra_embeds.astype(h.dtype), h], axis=1)
    S = h.shape[1]
    positions = jnp.arange(S)
    h, aux = backbone(params, h, cfg, positions, remat)
    return unembed(params, h, cfg), aux


def loss_fn(params: PyTree, batch: PyTree, cfg: ModelConfig, *,
            remat: str = "none") -> jax.Array:
    """batch: {'tokens': (B, S+1)} (+ optional 'extra_embeds', 'mask')."""
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    logits, aux = forward(params, inputs, cfg,
                          extra_embeds=batch.get("extra_embeds"),
                          remat=remat)
    if batch.get("extra_embeds") is not None:
        logits = logits[:, batch["extra_embeds"].shape[1]:]
    ce = common.cross_entropy_loss(logits, labels, batch.get("mask"))
    return ce + cfg.router_aux_weight * aux


# ----------------------------- prefill/decode -------------------------------


def _layer_prefill(layer: PyTree, h: jax.Array, cfg: ModelConfig,
                   positions: jax.Array, cache_len: int
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Like _layer_forward but also emits this layer's rope'd K/V padded to
    cache_len (pad at the tail; slot i holds absolute position i)."""
    B, S, _ = h.shape
    hn = _norm(h, layer["norm1"], layer.get("norm1_b"), cfg.norm_kind,
               cfg.norm_eps)
    q, k, v = attention._project_qkv(
        layer["attn"], hn, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim)
    pos_b = jnp.broadcast_to(positions, (B, S))
    q = common.apply_rope(q, pos_b, cfg.rope_theta)
    k = common.apply_rope(k, pos_b, cfg.rope_theta)
    attn_out = attention.sdpa(q, k, v, causal=True,
                              window=cfg.sliding_window)
    attn_out = attn_out @ layer["attn"]["wo"].astype(attn_out.dtype)
    h = h + attn_out
    hn = _norm(h, layer["norm2"], layer.get("norm2_b"), cfg.norm_kind,
               cfg.norm_eps)
    if "moe" in layer:
        ffn_out, _ = moe.moe_forward(layer["moe"], hn,
                                     top_k=cfg.experts_per_token,
                                     capacity_factor=cfg.capacity_factor,
                                     group_size=cfg.moe_group_size)
    elif cfg.mlp_kind == "gelu":
        ffn_out = mlp.gelu_mlp_forward(layer["mlp"], hn)
    else:
        ffn_out = mlp.swiglu_forward(layer["mlp"], hn)
    h = h + ffn_out

    pad = cache_len - S
    if pad > 0:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    elif pad < 0:  # rotating (sliding-window) cache keeps the last slots
        k = k[:, -cache_len:]
        v = v[:, -cache_len:]
    return h, k, v


def prefill(params: PyTree, tokens: jax.Array, cfg: ModelConfig, *,
            cache_len: Optional[int] = None,
            extra_embeds: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, attention.KVCache]:
    """Run the full prompt, build the KV cache, return last-position logits.

    Sliding-window archs get a rotating cache of size ``sliding_window``;
    note the rotating layout (slot = pos % window) matches decode_step.
    """
    h = embed_tokens(params, tokens, cfg)
    if extra_embeds is not None:
        h = jnp.concatenate([extra_embeds.astype(h.dtype), h], axis=1)
    B, S, _ = h.shape
    if cache_len is None:
        cache_len = cfg.sliding_window if cfg.sliding_window else S
    positions = jnp.arange(S)

    def body(carry, layer):
        h = carry
        h, k, v = _layer_prefill(layer, h, cfg, positions, cache_len)
        return h, (k, v)

    h, (ks, vs) = jax.lax.scan(body, h, params["layers"])
    logits = unembed(params, h[:, -1:, :], cfg)
    if cfg.sliding_window and S > cfg.sliding_window:
        # rotate so slot layout matches decode's (pos % window) convention
        shift = S % cache_len
        ks = jnp.roll(ks, shift, axis=2)
        vs = jnp.roll(vs, shift, axis=2)
    cache = attention.KVCache(ks, vs, jnp.asarray(S, jnp.int32))
    return logits, cache


def decode_step(params: PyTree, cache: attention.KVCache, token: jax.Array,
                cfg: ModelConfig) -> Tuple[jax.Array, attention.KVCache]:
    """One-token decode. token: (B,) int32; returns (logits (B, V), cache)."""
    h = embed_tokens(params, token[:, None], cfg)
    rotating = bool(cfg.sliding_window)
    index = cache.index

    def body(carry, xs):
        h = carry
        layer, lk, lv = xs
        hn = _norm(h, layer["norm1"], layer.get("norm1_b"), cfg.norm_kind,
                   cfg.norm_eps)
        attn_out, lk, lv = attention.decode_attention(
            layer["attn"], hn, lk, lv, index, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=cfg.resolved_head_dim,
            rope_theta=cfg.rope_theta, window=cfg.sliding_window,
            rotating=rotating)
        h = h + attn_out
        hn = _norm(h, layer["norm2"], layer.get("norm2_b"), cfg.norm_kind,
                   cfg.norm_eps)
        if "moe" in layer:
            ffn_out, _ = moe.moe_forward(layer["moe"], hn,
                                         top_k=cfg.experts_per_token,
                                         capacity_factor=cfg.capacity_factor,
                                         group_size=cfg.moe_group_size)
        elif cfg.mlp_kind == "gelu":
            ffn_out = mlp.gelu_mlp_forward(layer["mlp"], hn)
        else:
            ffn_out = mlp.swiglu_forward(layer["mlp"], hn)
        return h + ffn_out, (lk, lv)

    h, (ks, vs) = jax.lax.scan(body, h, (params["layers"], cache.k, cache.v))
    logits = unembed(params, h, cfg)[:, 0, :]
    return logits, attention.KVCache(ks, vs, index + 1)
