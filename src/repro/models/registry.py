"""Model registry: one uniform interface over the six architecture families.

    api = build_model(cfg)
    params = api.init(key)
    loss   = api.loss(params, batch)            # train shapes
    logits, cache = api.prefill(params, batch, cache_len=...)
    logits, cache = api.decode_step(params, cache, token)

Batch layouts by family (all int32 tokens):
    dense/moe/ssm/hybrid: {'tokens': (B, S+1)}
    vlm:   {'tokens': (B, S_txt+1), 'patches': (B, n_patches, 1024)}
    audio: {'tokens': (B, S+1), 'audio_embeds': (B, n_audio_ctx, d_model)}
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax

from repro.configs.base import ModelConfig
from repro.models import hybrid, rwkv6, transformer, vlm, whisper

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ModelConfig
    init: Callable[[jax.Array], PyTree]
    loss: Callable[..., jax.Array]
    prefill: Callable[..., Tuple[jax.Array, Any]]
    decode_step: Callable[..., Tuple[jax.Array, Any]]
    # Optional explicitly model-parallel loss for the 2D (worker x model)
    # grad pipeline: ``sharded_loss(chunks, batch, ctx)`` evaluated per
    # shard from local packed row-shard slices (see train/grad.py
    # ShardCtx). Families that leave this None fall back to the
    # packed-GSPMD path — the trainer threads the sharding plan's
    # head-aware param_pspec rules into ``loss`` instead.
    sharded_loss: Callable[..., jax.Array] = None


def build_model(cfg: ModelConfig) -> ModelAPI:
    fam = cfg.family
    if fam in ("dense", "moe"):
        return ModelAPI(
            cfg=cfg,
            init=lambda key: transformer.init_params(key, cfg),
            loss=lambda p, b, remat="none": transformer.loss_fn(
                p, b, cfg, remat=remat),
            prefill=lambda p, b, cache_len=None: transformer.prefill(
                p, b["tokens"], cfg, cache_len=cache_len),
            decode_step=lambda p, c, t: transformer.decode_step(p, c, t, cfg),
        )
    if fam == "vlm":
        return ModelAPI(
            cfg=cfg,
            init=lambda key: vlm.init_params(key, cfg),
            loss=lambda p, b, remat="none": vlm.loss_fn(p, b, cfg,
                                                        remat=remat),
            prefill=lambda p, b, cache_len=None: vlm.prefill(
                p, b["tokens"], b["patches"], cfg, cache_len=cache_len),
            decode_step=lambda p, c, t: vlm.decode_step(p, c, t, cfg),
        )
    if fam == "audio":
        return ModelAPI(
            cfg=cfg,
            init=lambda key: whisper.init_params(key, cfg),
            loss=lambda p, b, remat="none": whisper.loss_fn(p, b, cfg,
                                                            remat=remat),
            prefill=lambda p, b, cache_len=None: whisper.prefill(
                p, b["tokens"], b["audio_embeds"], cfg, cache_len=cache_len),
            decode_step=lambda p, c, t: whisper.decode_step(p, c, t, cfg),
        )
    if fam == "ssm":
        return ModelAPI(
            cfg=cfg,
            init=lambda key: rwkv6.init_params(key, cfg),
            loss=lambda p, b, remat="none": rwkv6.loss_fn(p, b, cfg,
                                                          remat=remat),
            prefill=lambda p, b, cache_len=None: rwkv6.prefill(
                p, b["tokens"], cfg),
            decode_step=lambda p, c, t: rwkv6.decode_step(p, c, t, cfg),
        )
    if fam == "hybrid":
        return ModelAPI(
            cfg=cfg,
            init=lambda key: hybrid.init_params(key, cfg),
            loss=lambda p, b, remat="none": hybrid.loss_fn(p, b, cfg,
                                                           remat=remat),
            prefill=lambda p, b, cache_len=None: hybrid.prefill(
                p, b["tokens"], cfg, cache_len=cache_len),
            decode_step=lambda p, c, t: hybrid.decode_step(p, c, t, cfg),
        )
    raise KeyError(f"unknown family {fam!r}")
