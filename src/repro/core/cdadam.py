"""CD-Adam (Algorithm 2): D-Adam with compressed gossip + error feedback.

At a communication round (mod(t+1, p) == 0), worker k:

    x_{t+1}   = x_{t+1/2} + gamma * sum_j w_kj (xhat_j - xhat_k)     (local)
    q_k       = Q(x_{t+1} - xhat_k)                                  (compress)
    send q_k to neighbors / receive q_j                              (wire)
    xhat_j   += q_j   for j in N_k ∪ {k}                             (update)

Every worker stores xhat copies of itself and each neighbor (CHOCO-style
state), so the mixing step needs *no* communication; only the compressed
residual q travels. The neighbor exchange of the *encoded* payload (int8
sign bits / top-k pairs) is a worker shift (:func:`repro.core.dadam
.shift_worker`): under comm='stacked' a ``jnp.roll`` over the (possibly
sharded) worker dim, under comm='axis' a ``jax.lax.ppermute`` over the
worker mesh axis inside shard_map — either way the lowered
collective-permute genuinely carries the compressed byte count.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dadam
from repro.core.compression import Compressor
from repro.core.dadam import AdamMoments, DAdamConfig, init_moments, local_update
from repro.core.schedule import TopologySchedule, comm_offsets
from repro.core.topology import Topology
# light import only — the Pallas kernel stack (repro.kernels.ops) loads
# lazily inside the pallas-only paths
from repro.kernels import pack as packing

PyTree = Any


@dataclasses.dataclass(frozen=True)
class CDAdamConfig(DAdamConfig):
    gamma: float = 0.4  # paper's consensus step size
    scales: str = "leaf"  # compression-scale granularity: 'leaf' keeps the
    #                       reference per-(worker, leaf) L1 scales;
    #                       'worker' opts into ONE scale per worker,
    #                       computed by a single fused kernel pass over the
    #                       whole resident buffer (backend='pallas' only)

    def validate(self) -> None:  # type: ignore[override]
        super().validate()
        if not 0 < self.gamma <= 1:
            raise ValueError("gamma must be in (0, 1]")
        if self.scales not in ("leaf", "worker"):
            raise ValueError(f"unknown scales {self.scales!r} "
                             "(use 'leaf' or 'worker')")
        if self.scales == "worker" and self.backend != "pallas":
            raise ValueError(
                "scales='worker' is the fused whole-buffer compressor: one "
                "kernel pass over the resident packed buffer; it requires "
                "backend='pallas' (the reference path compresses per leaf)")
        if (self.staleness or 0) > 0 and self.comm == "axis":
            raise ValueError(
                "CD-Adam staleness delays payloads through per-edge ring "
                "buffers indexed by the static delay table; the sharded "
                "comm='axis' lowering is not wired yet — use comm='stacked' "
                "(D-Adam supports staleness under comm='axis')")


class CDAdamState(NamedTuple):
    params: PyTree                 # x,     stacked (K, ...)
    moments: AdamMoments
    hat_self: PyTree               # xhat^{(k)},         stacked (K, ...)
    hat_nbrs: Tuple[PyTree, ...]   # xhat^{((k+s)%K)} per topology offset s
    # transient straggler-tolerant payload ring buffers (cfg.staleness > 0):
    # one ring per offset, encoded-payload pytrees with a T = tau + 1 time
    # dim at axis 1. Stripped from checkpoints, rebuilt cold on restore.
    pending: Optional[Tuple[PyTree, ...]] = None


@jax.tree_util.register_pytree_node_class
class PackedCDAdamState:
    """Resident packed CD-Adam state for ``backend='pallas'``.

    Everything CHOCO-style state touches per step — params, both moments,
    xhat_self and one xhat copy per topology offset — lives as stacked,
    leaf-aligned ``(K, rows, 128)`` buffers across steps: the fused-Adam,
    consensus-mix and sign-compress kernels consume them directly (zero
    per-step pack/unpack; leaf-aligned row slices keep the compression
    scale per (worker, leaf), exactly the reference semantics). Unpacked
    pytree views (``.params`` / ``.moments`` / ``.hat_self`` /
    ``.hat_nbrs``) materialize only at eval/checkpoint boundaries."""

    __slots__ = ("buf", "m", "v", "count", "hat_buf", "hat_nbr_bufs",
                 "spec", "spec_m", "pending")

    def __init__(self, buf, m, v, count, hat_buf, hat_nbr_bufs, spec,
                 spec_m, pending=None):
        self.buf, self.m, self.v, self.count = buf, m, v, count
        self.hat_buf, self.hat_nbr_bufs = hat_buf, tuple(hat_nbr_bufs)
        self.spec, self.spec_m = spec, spec_m
        self.pending = pending

    def tree_flatten(self):
        return ((self.buf, self.m, self.v, self.count, self.hat_buf,
                 self.hat_nbr_bufs, self.pending), (self.spec, self.spec_m))

    @classmethod
    def tree_unflatten(cls, aux, children):
        buf, m, v, count, hat_buf, hat_nbr_bufs, pending = children
        return cls(buf, m, v, count, hat_buf, hat_nbr_bufs, *aux, pending)

    def with_pending(self, pending) -> "PackedCDAdamState":
        return PackedCDAdamState(self.buf, self.m, self.v, self.count,
                                 self.hat_buf, self.hat_nbr_bufs, self.spec,
                                 self.spec_m, pending)

    # ------- unpacked views: boundary use only (eval/log/checkpoint) -------

    @property
    def params(self) -> PyTree:
        return packing.unpack(self.buf, self.spec)

    @property
    def moments(self) -> AdamMoments:
        return AdamMoments(packing.unpack(self.m, self.spec_m),
                           packing.unpack(self.v, self.spec_m), self.count)

    @property
    def hat_self(self) -> PyTree:
        return packing.unpack(self.hat_buf, self.spec)

    @property
    def hat_nbrs(self) -> Tuple[PyTree, ...]:
        return tuple(packing.unpack(h, self.spec)
                     for h in self.hat_nbr_bufs)

    def unpacked(self) -> CDAdamState:
        """Portable NamedTuple state — the checkpoint wire format,
        leaf-for-leaf identical to a reference-backend state."""
        return CDAdamState(self.params, self.moments, self.hat_self,
                           self.hat_nbrs)

    @classmethod
    def from_unpacked(cls, state: CDAdamState, *,
                      row_shards: int = 1) -> "PackedCDAdamState":
        """``row_shards=M`` packs into the 2D-mesh row-sharded layout
        (each leaf split across M shard blocks; see kernels/pack.py)."""
        spec = packing.make_spec(state.params, stacked=True,
                                 block_rows=packing.BLOCK_ROWS,
                                 leaf_align=True, row_shards=row_shards)
        spec_m = packing.make_spec(state.moments.m, stacked=True,
                                   block_rows=packing.BLOCK_ROWS,
                                   leaf_align=True, row_shards=row_shards)
        return cls(packing.pack(state.params, spec),
                   packing.pack(state.moments.m, spec_m),
                   packing.pack(state.moments.v, spec_m),
                   state.moments.count,
                   packing.pack(state.hat_self, spec),
                   tuple(packing.pack(h, spec) for h in state.hat_nbrs),
                   spec, spec_m)


# --------------------- stacked encode/decode helpers -----------------------


def _encode_stacked(comp: Compressor, tree: PyTree) -> PyTree:
    """vmap Q.encode over the leading worker dim of every leaf (per-worker
    scales!), producing payload leaves that keep the leading K dim.

    Leaves are NOT flattened: elementwise payloads (sign bits, quantized
    levels) keep the leaf's full shape so the tensor-parallel 'model'
    sharding of the parameter survives onto the payload — flattening would
    force each device to hold and ppermute the whole worker's payload
    (measured 16x wire inflation; EXPERIMENTS.md §Perf iteration 4)."""
    return jax.tree_util.tree_map(
        lambda x: jax.vmap(comp.encode)(x), tree
    )


def _decode_stacked(comp: Compressor, payload: PyTree, like: PyTree) -> PyTree:
    def dec(p, x):
        return jax.vmap(lambda q: comp.decode(q, x.shape[1:], x.dtype))(p)

    return jax.tree_util.tree_map(
        dec, payload, like,
        is_leaf=lambda t: isinstance(t, dict) and ("bits" in t or "values" in t
                                                   or "q" in t),
    )


def _shift_payload(payload: PyTree, s: int, topo: Topology,
                   cfg: CDAdamConfig) -> PyTree:
    """Worker k receives worker (k + s) % K's encoded message — the wire
    hop of Alg. 2 line 10, carrying only the compressed payload. A roll
    over the stacked worker dim (comm='stacked'; per-worker scale scalars
    roll along axis 0 too) or a ppermute over the worker mesh axis
    (comm='axis')."""
    axis = cfg.axis_name if cfg.comm == "axis" else None
    return jax.tree_util.tree_map(
        lambda a: dadam.shift_worker(a, s, topo.K, axis), payload
    )


# ---------------- straggler-tolerant payload delay rings --------------------
#
# CD-Adam's staleness model differs from D-Adam's: a CHOCO hat copy is a
# running SUM of residual payloads, so dropping (or re-applying) a payload
# permanently desyncs worker k's copy of its neighbor's hat. Stragglers
# therefore DELAY payloads, never drop them: each edge (k, offset i) has a
# static delay d <= tau, incoming encoded payloads enter a ring buffer with
# T = tau + 1 slots, and round r applies the payload pushed at round r - d —
# in order, exactly once, at most tau rounds late.


def _wire_tau(cfg: CDAdamConfig) -> int:
    """Rounds of wire delay the payload rings implement: the explicit
    staleness bound, or EXACTLY one round under ``cfg.overlap`` — the
    eager-issue schedule is the tau=1 ring path with an all-ones delay
    table, which is what pins overlap ≡ staleness(1) bitwise."""
    if cfg.overlap:
        return 1
    return int(cfg.staleness or 0)


def _payload_delays(cfg: CDAdamConfig, K: int, deg: int) -> np.ndarray:
    """Static (K, deg) per-edge delay table, reproducible from the seed.
    A fraction ``straggler_rate`` of edges is persistently slow (delay
    uniform in [1, tau]); the rest deliver same-round. Under
    ``cfg.overlap`` EVERY edge is exactly one round late: round r issues
    its payload and round r+1 applies it, so the wire exchange overlaps
    the p local Adam steps in between."""
    if cfg.overlap:
        return np.ones((K, deg), np.int32)
    tau = _wire_tau(cfg)
    if tau == 0 or cfg.straggler_rate <= 0.0:
        return np.zeros((K, deg), np.int32)
    rs = np.random.RandomState(cfg.straggler_seed)
    slow = rs.rand(K, deg) < cfg.straggler_rate
    d = np.where(slow, rs.randint(1, tau + 1, size=(K, deg)), 0)
    return d.astype(np.int32)


def _ring_like(payload_like: PyTree, T: int) -> PyTree:
    """A cold (zero) ring: every leaf gains a T-slot time dim at axis 1
    (axis 0 stays the worker dim). Zero payloads decode to zero residuals,
    so warm-up rounds apply no hat update — 'no message yet'."""
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros((p.shape[0], T) + p.shape[1:], p.dtype),
        payload_like)


def _ring_push(ring: PyTree, payload: PyTree, slot: jax.Array) -> PyTree:
    return jax.tree_util.tree_map(
        lambda rb, p: rb.at[:, slot].set(p.astype(rb.dtype)), ring, payload)


def _ring_gather(ring: PyTree, sel: jax.Array) -> PyTree:
    """Per-worker slot read: leaf (K, T, ...) + sel (K,) -> (K, ...)."""
    def g(rb):
        s = sel.reshape((-1,) + (1,) * (rb.ndim - 1)).astype(jnp.int32)
        return jnp.take_along_axis(rb, s, axis=1)[:, 0]

    return jax.tree_util.tree_map(g, ring)


def _delayed_recv(recv: PyTree, ring: Optional[PyTree], d_col: np.ndarray,
                  r: jax.Array, tau: int) -> Tuple[PyTree, Optional[PyTree]]:
    """Push this round's received payload, pop each worker's delayed one."""
    if ring is None:
        return recv, None
    T = tau + 1
    new_ring = _ring_push(ring, recv, r % T)
    sel = (r - jnp.asarray(d_col)) % T
    return _ring_gather(new_ring, sel), new_ring


# ---------------------- schedule round dispatch -----------------------------


def _round_dispatch(operand: Any, topo: "Topology | TopologySchedule",
                    r: jax.Array, fn: Callable[[Any, Topology], Any]) -> Any:
    """Run ``fn(operand, view)`` for round r's topology. Schedules switch
    over their union views — every branch sees the SAME offset tuple (per-
    edge hat/ring state stays aligned), only the static mixing weights
    change — so the whole cycle compiles into one step."""
    if isinstance(topo, TopologySchedule):
        views = topo.union_views()
        if len(views) == 1:
            return fn(operand, views[0])
        return jax.lax.switch(
            r % len(views),
            [(lambda op, v=v: fn(op, v)) for v in views],
            operand)
    return fn(operand, topo)


# ------------------------------- algorithm ---------------------------------


def init(params_stacked: PyTree, cfg: CDAdamConfig,
         topo: "Topology | TopologySchedule",
         comp: Optional[Compressor] = None
         ) -> "CDAdamState | PackedCDAdamState":
    cfg.validate()
    offs = comm_offsets(topo)
    if not offs and topo.K > 1:
        raise ValueError("CD-Adam runtime requires a shift-invariant topology")
    tau = _wire_tau(cfg)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params_stacked)
    # xhat_0 = 0 (CHOCO convention); neighbor copies likewise — one per
    # offset that can EVER be active (a schedule's union edge set).
    hat_nbrs = tuple(jax.tree_util.tree_map(jnp.zeros_like, params_stacked)
                     for _ in offs)
    state = CDAdamState(params_stacked, init_moments(params_stacked, cfg),
                        zeros, hat_nbrs)
    if cfg.backend == "pallas":
        packed = PackedCDAdamState.from_unpacked(
            state, row_shards=cfg.model_parallel)
        if tau > 0:
            K = topo.K
            rows = packed.buf.shape[1]
            sc_shape = ((K,) if cfg.scales == "worker"
                        else (K, len(packed.spec.sizes)))
            like = {"q": jnp.zeros((K, rows, packing.LANE), jnp.int8),
                    "scale": jnp.zeros(sc_shape, jnp.float32)}
            packed = packed.with_pending(
                tuple(_ring_like(like, tau + 1) for _ in offs))
        return packed
    if tau > 0:
        if comp is None:
            raise ValueError(
                "cfg.staleness > 0 rings buffer ENCODED payloads; the "
                "reference backend needs the compressor at init (pass "
                "comp=, as make_optimizer does)")
        payload_like = _encode_stacked(comp, zeros)
        state = state._replace(
            pending=tuple(_ring_like(payload_like, tau + 1) for _ in offs))
    return state


def _mix_with_hats(x_half: PyTree, hat_self: PyTree,
                   hat_nbrs: Tuple[PyTree, ...], topo: Topology,
                   cfg: CDAdamConfig) -> PyTree:
    """(8) local mixing using stored neighbor copies — no communication."""

    def mixed(xh, hs, *hns):
        acc = jnp.zeros_like(hs, dtype=jnp.float32)
        for w, hn in zip(topo.offset_weights, hns):
            acc = acc + w * (hn.astype(jnp.float32) - hs.astype(jnp.float32))
        return (xh.astype(jnp.float32) + cfg.gamma * acc).astype(xh.dtype)

    return jax.tree_util.tree_map(mixed, x_half, hat_self, *hat_nbrs)


def _comm_round(state_half: CDAdamState, topo: Topology, cfg: CDAdamConfig,
                comp: Compressor, r: jax.Array) -> CDAdamState:
    """Lines 8-11 of Alg. 2 on the half-step parameters."""
    x_half, mom, hat_self, hat_nbrs, pending = state_half

    x_new = _mix_with_hats(x_half, hat_self, hat_nbrs, topo, cfg)

    # (9) compress the residual against our own xhat.
    resid = jax.tree_util.tree_map(lambda a, b: a - b, x_new, hat_self)
    q_enc = _encode_stacked(comp, resid)
    q_dec = _decode_stacked(comp, q_enc, resid)

    # (11a) update own copy: xhat_k += q_k
    new_hat_self = jax.tree_util.tree_map(
        lambda h, q: h + q.astype(h.dtype), hat_self, q_dec)

    # (10)+(11b) neighbors: worker k needs q_{(k+s)%K}; the *encoded* payload
    # travels (worker shift => compressed-size collective-permute in either
    # comm mode), then is decoded locally. Under cfg.staleness > 0 the
    # received payload detours through the per-edge delay ring: slow edges
    # apply it up to tau rounds late, in order, never dropped.
    tau = _wire_tau(cfg)
    delays = _payload_delays(cfg, topo.K, len(topo.offsets))
    new_hat_nbrs = []
    new_pending = []
    for i, (s, hn) in enumerate(zip(topo.offsets, hat_nbrs)):
        recv_enc = _shift_payload(q_enc, s, topo, cfg)
        ring = None if pending is None else pending[i]
        d_col = dadam._local_worker_rows(jnp.asarray(delays[:, i]), cfg)
        use_enc, ring = _delayed_recv(recv_enc, ring, d_col, r, tau)
        recv = _decode_stacked(comp, use_enc, resid)
        new_hat_nbrs.append(jax.tree_util.tree_map(
            lambda h, q: h + q.astype(h.dtype), hn, recv))
        new_pending.append(ring)

    return CDAdamState(x_new, mom, new_hat_self, tuple(new_hat_nbrs),
                       None if pending is None else tuple(new_pending))


def _comm_round_pallas(state_half: CDAdamState, topo: Topology,
                       cfg: CDAdamConfig) -> CDAdamState:
    """Lines 8-11 of Alg. 2 with the sign compressor fused into Pallas
    kernels (interpret mode off-TPU).

    Per leaf, one (K, blocks)-grid kernel pair computes the int8 sign
    payload, the per-worker L1 scale AND the ``xhat_k += q_k`` update in a
    single VMEM pass over (x_new, xhat) — fusing reference steps (9) and
    (11a). Compression stays per-(worker, leaf), so the math is identical
    to the reference path with ``compressor='sign'``. Neighbor copies
    (10)+(11b) are then updated from the *payload* — the int8 q and the
    (K,) scales roll over the worker dim, which is exactly the compressed
    byte count on the wire when the dim is sharded."""
    from repro.kernels import ops

    if cfg.scales == "worker":
        raise ValueError(
            "scales='worker' is the whole-buffer pass over the RESIDENT "
            "packed state; the pytree (repack) pallas path compresses per "
            "leaf — use the packed-resident runtime (opt.init's default)")

    x_half, mom, hat_self, hat_nbrs, pending = state_half
    if pending is not None or cfg.overlap:
        raise ValueError(
            "staleness > 0 / overlap are wired for the packed-resident "
            "pallas runtime and the reference backend; the pytree (repack) "
            "pallas path does not thread payload rings")
    x_new = _mix_with_hats(x_half, hat_self, hat_nbrs, topo, cfg)

    enc = jax.tree_util.tree_map(
        lambda xl, hl: ops.sign_compress_stacked(xl, hl), x_new, hat_self)
    is_enc = lambda t: isinstance(t, tuple)
    q = jax.tree_util.tree_map(lambda t: t[0], enc, is_leaf=is_enc)
    scale = jax.tree_util.tree_map(lambda t: t[1], enc, is_leaf=is_enc)
    new_hat_self = jax.tree_util.tree_map(lambda t: t[2], enc, is_leaf=is_enc)

    axis = cfg.axis_name if cfg.comm == "axis" else None
    new_hat_nbrs = []
    for s, hn in zip(topo.offsets, hat_nbrs):
        def upd(h, qb, sc, s=s):
            q_recv = dadam.shift_worker(qb, s, topo.K, axis)
            sc_recv = dadam.shift_worker(sc, s, topo.K, axis)
            sc_recv = sc_recv.reshape((-1,) + (1,) * (qb.ndim - 1))
            return h + (sc_recv * q_recv.astype(jnp.float32)).astype(h.dtype)
        new_hat_nbrs.append(jax.tree_util.tree_map(upd, hn, q, scale))

    return CDAdamState(x_new, mom, new_hat_self, tuple(new_hat_nbrs))


def _comm_round_packed(state_half: PackedCDAdamState, topo: Topology,
                       cfg: CDAdamConfig, r: jax.Array) -> PackedCDAdamState:
    """Lines 8-11 of Alg. 2 entirely on resident packed buffers.

    (8) is ONE fused consensus-mix kernel pass over the stacked buffer
    (``kernels/gossip.py``). (9)+(11a) run the sign-compress kernel pair on
    the *leaf-aligned row slices* of the resident buffers — compression
    stays per (worker, leaf) with the true-element-count divisor, so the
    math is bit-for-bit the reference semantics, with zero pack/unpack.
    (10)+(11b) update the neighbor copies from the payload: the int8 q
    buffer and the (K, L) per-leaf scales travel by worker shift — a roll
    over the stacked dim (comm='stacked') or a ppermute over the worker
    mesh axis (comm='axis', where the local buffers are one worker's
    (1, rows, 128) shard) — still exactly the compressed byte count on
    the wire.

    On a 2D (worker × model) mesh (``cfg.model_parallel`` = M > 1, traced
    inside shard_map with both axes bound) the local buffers are one
    (worker, model) shard's (1, rows/M, 128) block of the row-sharded
    layout: ``leaf_row_ranges`` hands out the shard-invariant local leaf
    slices, and the sign-compress scale reduction psums its |delta|
    partial sums over the model axis — compression stays per
    (worker, leaf) with the exact reference semantics, while the ppermute
    payload per device shrinks to that device's 1/M row block."""
    from repro.kernels import ops

    x_new = ops.consensus_mix(state_half.buf, state_half.hat_buf,
                              state_half.hat_nbr_bufs, topo.offset_weights,
                              cfg.gamma)

    spec = state_half.spec
    # local view: the per-shard row ranges / row count (== the full buffer
    # when not row-sharded)
    ranges = packing.leaf_row_ranges(spec)
    lrows = spec.local_rows
    maxis = (cfg.model_axis_name
             if getattr(cfg, "model_parallel", 1) > 1 else None)
    axis = cfg.axis_name if cfg.comm == "axis" else None
    tau = _wire_tau(cfg)
    pending = state_half.pending
    delays = _payload_delays(cfg, topo.K, len(topo.offsets))

    def recv_payload(i, shift, q_buf, scales):
        """Shift (wire hop) then, under staleness, detour through offset
        i's delay ring; returns the payload to apply plus the new ring."""
        q_recv = dadam.shift_worker(q_buf, shift, topo.K, axis)
        sc_recv = dadam.shift_worker(scales, shift, topo.K, axis)
        ring = None if pending is None else pending[i]
        d_col = dadam._local_worker_rows(jnp.asarray(delays[:, i]), cfg)
        recv, ring = _delayed_recv({"q": q_recv, "scale": sc_recv}, ring,
                                   d_col, r, tau)
        return recv["q"], recv["scale"], ring

    if cfg.scales == "worker":
        # Fused whole-buffer compressor: ONE kernel-pair pass over the
        # entire resident buffer with a single scale per worker — the
        # mean |delta| over the worker's whole true parameter vector
        # (padding contributes 0 to the sum and is excluded from the
        # divisor; on a 2D mesh the |delta| partials psum over 'model' so
        # every shard computes the identical global scale). Deliberately
        # coarser than the reference per-(worker, leaf) semantics — the
        # opt-in trade: one kernel launch and a 4-byte scale payload
        # instead of L of each.
        q_buf, w_scales, new_hat_buf = ops.sign_compress_stacked(
            x_new, state_half.hat_buf, n_true=spec.n, reduce_axis=maxis)

        new_hat_nbrs, new_pending = [], []
        for i, (s, hn) in enumerate(zip(topo.offsets,
                                        state_half.hat_nbr_bufs)):
            q_recv, sc_recv, ring = recv_payload(i, s, q_buf, w_scales)
            new_hat_nbrs.append(hn + (sc_recv[:, None, None]
                                      * q_recv.astype(jnp.float32)
                                      ).astype(hn.dtype))
            new_pending.append(ring)
        return PackedCDAdamState(
            x_new, state_half.m, state_half.v, state_half.count,
            new_hat_buf, tuple(new_hat_nbrs), spec, state_half.spec_m,
            None if pending is None else tuple(new_pending))

    q_parts, scale_cols, hat_parts = [], [], []
    for (r0, r1), size in zip(ranges, spec.sizes):
        q_l, s_l, h_l = ops.sign_compress_stacked(
            x_new[:, r0:r1], state_half.hat_buf[:, r0:r1],
            n_true=size if size else None, reduce_axis=maxis)
        q_parts.append(q_l)
        scale_cols.append(s_l)
        hat_parts.append(h_l)
    q_buf = jnp.concatenate(q_parts, axis=1)           # (K, local rows, 128)
    scales = jnp.stack(scale_cols, axis=1)             # (K, L)
    new_hat_buf = jnp.concatenate(hat_parts, axis=1)

    # broadcast the per-(worker, leaf) scale over each leaf's row range
    rows_per_leaf = np.array([r1 - r0 for r0, r1 in ranges])

    new_hat_nbrs, new_pending = [], []
    for i, (s, hn) in enumerate(zip(topo.offsets, state_half.hat_nbr_bufs)):
        q_recv, sc_recv, ring = recv_payload(i, s, q_buf, scales)
        sc_rows = jnp.repeat(sc_recv, rows_per_leaf, axis=1,
                             total_repeat_length=lrows)       # (K, rows)
        new_hat_nbrs.append(hn + (sc_rows[:, :, None]
                                  * q_recv.astype(jnp.float32)
                                  ).astype(hn.dtype))
        new_pending.append(ring)
    return PackedCDAdamState(
        x_new, state_half.m, state_half.v, state_half.count, new_hat_buf,
        tuple(new_hat_nbrs), spec, state_half.spec_m,
        None if pending is None else tuple(new_pending))


def _step_packed(state: PackedCDAdamState, grads: Any,
                 topo: "Topology | TopologySchedule",
                 cfg: CDAdamConfig) -> PackedCDAdamState:
    po, mo, vo, count = dadam._fused_local_packed(state, grads, cfg)
    half = PackedCDAdamState(po, mo, vo, count, state.hat_buf,
                             state.hat_nbr_bufs, state.spec, state.spec_m,
                             state.pending)
    if topo.K == 1:
        return half
    r = dadam._round_index(count, cfg.period)
    comm = lambda s: _round_dispatch(
        s, topo, r, lambda sh, v: _comm_round_packed(sh, v, cfg, r))
    if cfg.period == 1:
        return comm(half)
    do_comm = (count % cfg.period) == 0
    return jax.lax.cond(do_comm, comm, lambda s: s, half)


def step(state: "CDAdamState | PackedCDAdamState", grads: PyTree,
         topo: "Topology | TopologySchedule", cfg: CDAdamConfig,
         comp: Compressor) -> "CDAdamState | PackedCDAdamState":
    """One iteration of Alg. 2 (stacked mode).

    Packed-resident states (pallas backend) stay in the (K, rows, 128)
    layout end to end; ``grads`` may be a congruent pytree (packed once at
    this boundary) or an already packed buffer (zero pack/unpack)."""
    if isinstance(state, PackedCDAdamState):
        return _step_packed(state, grads, topo, cfg)
    half, mom = local_update(state.params, grads, state.moments, cfg)
    half_state = CDAdamState(half, mom, state.hat_self, state.hat_nbrs,
                             state.pending)
    if topo.K == 1:
        return half_state
    r = dadam._round_index(mom.count, cfg.period)
    if cfg.backend == "pallas":
        once = lambda sh, v: _comm_round_pallas(sh, v, cfg)
    else:
        once = lambda sh, v: _comm_round(sh, v, cfg, comp, r)
    comm = lambda s: _round_dispatch(s, topo, r, once)
    if cfg.period == 1:
        return comm(half_state)
    do_comm = (mom.count % cfg.period) == 0
    return jax.lax.cond(do_comm, comm, lambda s: s, half_state)


def round_step(state: "CDAdamState | PackedCDAdamState",
               grad_fn: Callable[[PyTree, Any], PyTree],
               batches: Any, topo: Topology, cfg: CDAdamConfig,
               comp: Compressor) -> "CDAdamState | PackedCDAdamState":
    """One communication round: p local Adam steps + one compressed gossip.

    For packed-resident states ``grad_fn`` receives the raw (K, rows, 128)
    parameter buffer (differentiate through ``packing.unpack`` for the
    zero-pack steady state; a returned pytree is packed at the boundary).
    """
    if isinstance(state, PackedCDAdamState):
        def body_packed(carry: PackedCDAdamState, batch):
            grads = grad_fn(carry.buf, batch)
            po, mo, vo, count = dadam._fused_local_packed(carry, grads, cfg)
            return PackedCDAdamState(po, mo, vo, count, carry.hat_buf,
                                     carry.hat_nbr_bufs, carry.spec,
                                     carry.spec_m, carry.pending), ()

        inner, _ = jax.lax.scan(body_packed, state, batches)
        if topo.K == 1:
            return inner
        r = dadam._round_index(inner.count, cfg.period)
        return _round_dispatch(
            inner, topo, r, lambda sh, v: _comm_round_packed(sh, v, cfg, r))

    def body(carry: CDAdamState, batch):
        grads = grad_fn(carry.params, batch)
        half, mom = local_update(carry.params, grads, carry.moments, cfg)
        return CDAdamState(half, mom, carry.hat_self, carry.hat_nbrs,
                           carry.pending), ()

    inner, _ = jax.lax.scan(body, state, batches)
    if topo.K == 1:
        return inner
    r = dadam._round_index(inner.moments.count, cfg.period)
    if cfg.backend == "pallas":
        once = lambda sh, v: _comm_round_pallas(sh, v, cfg)
    else:
        once = lambda sh, v: _comm_round(sh, v, cfg, comp, r)
    return _round_dispatch(inner, topo, r, once)


# The pre-unification ``CDAdamAxisState`` / ``comm_round_axis`` duplicate
# of this algorithm is gone: comm='axis' now runs the SAME ``step`` /
# ``round_step`` code inside shard_map (``make_optimizer(comm='axis',
# mesh=...)`` installs the wrapper), with the worker shifts lowering to
# ppermute via ``_shift_payload`` / ``dadam.shift_worker``.
