"""CD-Adam (Algorithm 2): D-Adam with compressed gossip + error feedback.

At a communication round (mod(t+1, p) == 0), worker k:

    x_{t+1}   = x_{t+1/2} + gamma * sum_j w_kj (xhat_j - xhat_k)     (local)
    q_k       = Q(x_{t+1} - xhat_k)                                  (compress)
    send q_k to neighbors / receive q_j                              (wire)
    xhat_j   += q_j   for j in N_k ∪ {k}                             (update)

Every worker stores xhat copies of itself and each neighbor (CHOCO-style
state), so the mixing step needs *no* communication; only the compressed
residual q travels. In the stacked-K runtime the neighbor exchange of the
*encoded* payload (int8 sign bits / top-k pairs) is a ``jnp.roll`` over the
sharded worker dim — i.e. the lowered collective-permute genuinely carries
the compressed byte count.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import dadam
from repro.core.compression import Compressor
from repro.core.dadam import AdamMoments, DAdamConfig, init_moments, local_update
from repro.core.topology import Topology

PyTree = Any


@dataclasses.dataclass(frozen=True)
class CDAdamConfig(DAdamConfig):
    gamma: float = 0.4  # paper's consensus step size

    def validate(self) -> None:  # type: ignore[override]
        super().validate()
        if not 0 < self.gamma <= 1:
            raise ValueError("gamma must be in (0, 1]")


class CDAdamState(NamedTuple):
    params: PyTree                 # x,     stacked (K, ...)
    moments: AdamMoments
    hat_self: PyTree               # xhat^{(k)},         stacked (K, ...)
    hat_nbrs: Tuple[PyTree, ...]   # xhat^{((k+s)%K)} per topology offset s


# --------------------- stacked encode/decode helpers -----------------------


def _encode_stacked(comp: Compressor, tree: PyTree) -> PyTree:
    """vmap Q.encode over the leading worker dim of every leaf (per-worker
    scales!), producing payload leaves that keep the leading K dim.

    Leaves are NOT flattened: elementwise payloads (sign bits, quantized
    levels) keep the leaf's full shape so the tensor-parallel 'model'
    sharding of the parameter survives onto the payload — flattening would
    force each device to hold and ppermute the whole worker's payload
    (measured 16x wire inflation; EXPERIMENTS.md §Perf iteration 4)."""
    return jax.tree_util.tree_map(
        lambda x: jax.vmap(comp.encode)(x), tree
    )


def _decode_stacked(comp: Compressor, payload: PyTree, like: PyTree) -> PyTree:
    def dec(p, x):
        return jax.vmap(lambda q: comp.decode(q, x.shape[1:], x.dtype))(p)

    return jax.tree_util.tree_map(
        dec, payload, like,
        is_leaf=lambda t: isinstance(t, dict) and ("bits" in t or "values" in t
                                                   or "q" in t),
    )


def _roll_payload(payload: PyTree, shift: int) -> PyTree:
    """Shift the per-worker payload along the worker dim: worker k receives
    worker (k + s) % K's message. Scalars-per-worker roll too (axis 0)."""
    return jax.tree_util.tree_map(
        lambda a: jnp.roll(a, shift, axis=0) if a.ndim >= 1 else a, payload
    )


# ------------------------------- algorithm ---------------------------------


def init(params_stacked: PyTree, cfg: CDAdamConfig,
         topo: Topology) -> CDAdamState:
    cfg.validate()
    if not topo.offsets and topo.K > 1:
        raise ValueError("CD-Adam runtime requires a shift-invariant topology")
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params_stacked)
    # xhat_0 = 0 (CHOCO convention); neighbor copies likewise.
    hat_nbrs = tuple(jax.tree_util.tree_map(jnp.zeros_like, params_stacked)
                     for _ in topo.offsets)
    return CDAdamState(params_stacked, init_moments(params_stacked, cfg),
                       zeros, hat_nbrs)


def _mix_with_hats(x_half: PyTree, hat_self: PyTree,
                   hat_nbrs: Tuple[PyTree, ...], topo: Topology,
                   cfg: CDAdamConfig) -> PyTree:
    """(8) local mixing using stored neighbor copies — no communication."""

    def mixed(xh, hs, *hns):
        acc = jnp.zeros_like(hs, dtype=jnp.float32)
        for w, hn in zip(topo.offset_weights, hns):
            acc = acc + w * (hn.astype(jnp.float32) - hs.astype(jnp.float32))
        return (xh.astype(jnp.float32) + cfg.gamma * acc).astype(xh.dtype)

    return jax.tree_util.tree_map(mixed, x_half, hat_self, *hat_nbrs)


def _comm_round(state_half: CDAdamState, topo: Topology, cfg: CDAdamConfig,
                comp: Compressor) -> CDAdamState:
    """Lines 8-11 of Alg. 2 on the half-step parameters."""
    x_half, mom, hat_self, hat_nbrs = state_half

    x_new = _mix_with_hats(x_half, hat_self, hat_nbrs, topo, cfg)

    # (9) compress the residual against our own xhat.
    resid = jax.tree_util.tree_map(lambda a, b: a - b, x_new, hat_self)
    q_enc = _encode_stacked(comp, resid)
    q_dec = _decode_stacked(comp, q_enc, resid)

    # (11a) update own copy: xhat_k += q_k
    new_hat_self = jax.tree_util.tree_map(
        lambda h, q: h + q.astype(h.dtype), hat_self, q_dec)

    # (10)+(11b) neighbors: worker k needs q_{(k+s)%K}; the *encoded* payload
    # travels (roll over the sharded worker dim => compressed-size
    # collective-permute), then is decoded locally.
    new_hat_nbrs = []
    for s, hn in zip(topo.offsets, hat_nbrs):
        recv_enc = _roll_payload(q_enc, -s)
        recv = _decode_stacked(comp, recv_enc, resid)
        new_hat_nbrs.append(jax.tree_util.tree_map(
            lambda h, q: h + q.astype(h.dtype), hn, recv))

    return CDAdamState(x_new, mom, new_hat_self, tuple(new_hat_nbrs))


def _comm_round_pallas(state_half: CDAdamState, topo: Topology,
                       cfg: CDAdamConfig) -> CDAdamState:
    """Lines 8-11 of Alg. 2 with the sign compressor fused into Pallas
    kernels (interpret mode off-TPU).

    Per leaf, one (K, blocks)-grid kernel pair computes the int8 sign
    payload, the per-worker L1 scale AND the ``xhat_k += q_k`` update in a
    single VMEM pass over (x_new, xhat) — fusing reference steps (9) and
    (11a). Compression stays per-(worker, leaf), so the math is identical
    to the reference path with ``compressor='sign'``. Neighbor copies
    (10)+(11b) are then updated from the *payload* — the int8 q and the
    (K,) scales roll over the worker dim, which is exactly the compressed
    byte count on the wire when the dim is sharded."""
    from repro.kernels import ops

    x_half, mom, hat_self, hat_nbrs = state_half
    x_new = _mix_with_hats(x_half, hat_self, hat_nbrs, topo, cfg)

    enc = jax.tree_util.tree_map(
        lambda xl, hl: ops.sign_compress_stacked(xl, hl), x_new, hat_self)
    is_enc = lambda t: isinstance(t, tuple)
    q = jax.tree_util.tree_map(lambda t: t[0], enc, is_leaf=is_enc)
    scale = jax.tree_util.tree_map(lambda t: t[1], enc, is_leaf=is_enc)
    new_hat_self = jax.tree_util.tree_map(lambda t: t[2], enc, is_leaf=is_enc)

    new_hat_nbrs = []
    for s, hn in zip(topo.offsets, hat_nbrs):
        def upd(h, qb, sc, s=s):
            q_recv = jnp.roll(qb, -s, axis=0)
            sc_recv = jnp.roll(sc, -s).reshape((-1,) + (1,) * (qb.ndim - 1))
            return h + (sc_recv * q_recv.astype(jnp.float32)).astype(h.dtype)
        new_hat_nbrs.append(jax.tree_util.tree_map(upd, hn, q, scale))

    return CDAdamState(x_new, mom, new_hat_self, tuple(new_hat_nbrs))


def step(state: CDAdamState, grads: PyTree, topo: Topology,
         cfg: CDAdamConfig, comp: Compressor) -> CDAdamState:
    """One iteration of Alg. 2 (stacked mode)."""
    half, mom = local_update(state.params, grads, state.moments, cfg)
    half_state = CDAdamState(half, mom, state.hat_self, state.hat_nbrs)
    if topo.K == 1:
        return half_state
    if cfg.backend == "pallas":
        comm = lambda s: _comm_round_pallas(s, topo, cfg)
    else:
        comm = lambda s: _comm_round(s, topo, cfg, comp)
    if cfg.period == 1:
        return comm(half_state)
    do_comm = (mom.count % cfg.period) == 0
    return jax.lax.cond(do_comm, comm, lambda s: s, half_state)


def round_step(state: CDAdamState,
               grad_fn: Callable[[PyTree, Any], PyTree],
               batches: Any, topo: Topology, cfg: CDAdamConfig,
               comp: Compressor) -> CDAdamState:
    """One communication round: p local Adam steps + one compressed gossip."""

    def body(carry: CDAdamState, batch):
        grads = grad_fn(carry.params, batch)
        half, mom = local_update(carry.params, grads, carry.moments, cfg)
        return CDAdamState(half, mom, carry.hat_self, carry.hat_nbrs), ()

    inner, _ = jax.lax.scan(body, state, batches)
    if topo.K == 1:
        return inner
    if cfg.backend == "pallas":
        return _comm_round_pallas(inner, topo, cfg)
    return _comm_round(inner, topo, cfg, comp)


# ----------------------------- axis variant --------------------------------


class CDAdamAxisState(NamedTuple):
    params: PyTree
    moments: AdamMoments
    hat_self: PyTree
    hat_nbrs: Tuple[PyTree, ...]


def comm_round_axis(state_half: CDAdamAxisState, topo: Topology,
                    cfg: CDAdamConfig, comp: Compressor,
                    axis_name: str) -> CDAdamAxisState:
    """Alg. 2 communication step inside ``shard_map`` over ``axis_name``.

    Parameters here are the *local shard* of one worker (= one pod); the
    encoded q payload is ppermuted to graph neighbors so the inter-pod link
    carries only compressed bytes.
    """
    x_half, mom, hat_self, hat_nbrs = state_half
    K = topo.K

    def mixed(xh, hs, *hns):
        acc = jnp.zeros_like(hs, dtype=jnp.float32)
        for w, hn in zip(topo.offset_weights, hns):
            acc = acc + w * (hn.astype(jnp.float32) - hs.astype(jnp.float32))
        return (xh.astype(jnp.float32) + cfg.gamma * acc).astype(xh.dtype)

    x_new = jax.tree_util.tree_map(mixed, x_half, hat_self, *hat_nbrs)
    resid = jax.tree_util.tree_map(lambda a, b: a - b, x_new, hat_self)
    q_enc = jax.tree_util.tree_map(
        lambda x: comp.encode(x.reshape(-1)), resid)

    def dec(payload, like):
        return jax.tree_util.tree_map(
            lambda p, x: comp.decode(p, (x.size,), x.dtype).reshape(x.shape),
            payload, like,
            is_leaf=lambda t: isinstance(t, dict)
            and ("bits" in t or "values" in t or "q" in t),
        )

    new_hat_self = jax.tree_util.tree_map(
        lambda h, q: h + q.astype(h.dtype), hat_self, dec(q_enc, resid))

    new_hat_nbrs = []
    for s, hn in zip(topo.offsets, hat_nbrs):
        perm = [((k + s) % K, k) for k in range(K)]
        recv_enc = jax.tree_util.tree_map(
            lambda a: jax.lax.ppermute(a, axis_name, perm), q_enc)
        recv = dec(recv_enc, resid)
        new_hat_nbrs.append(jax.tree_util.tree_map(
            lambda h, q: h + q.astype(h.dtype), hn, recv))

    return CDAdamAxisState(x_new, mom, new_hat_self, tuple(new_hat_nbrs))
