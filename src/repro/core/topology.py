"""Gossip topologies: doubly-stochastic mixing matrices W (Definition 1).

The paper requires W symmetric, doubly stochastic, with spectral gap
rho = 1 - |lambda_2| in (0, 1].  The experiments use a ring of 8 workers.

We provide the standard zoo (ring, torus, hypercube, exponential,
fully-connected) plus helpers for neighbor lists so the distributed
runtime can lower gossip as sparse ``ppermute`` exchanges instead of a
dense mixing matmul.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Topology:
    """A gossip graph over K workers.

    Attributes:
      name: human-readable id.
      weights: (K, K) symmetric doubly-stochastic mixing matrix.
      neighbors: for each worker, the list of (neighbor_rank, weight) pairs
        with neighbor != self. Self weight is ``self_weights[k]``.
      offsets: ring-style permutation offsets covering all edges, i.e. a set
        of integers s such that every (k, (k+s) % K) is an edge with a
        *uniform* weight. Only populated for shift-invariant graphs (ring,
        exponential, fully-connected); used to lower gossip as ppermutes.
    """

    name: str
    weights: np.ndarray
    offsets: Tuple[int, ...]
    offset_weights: Tuple[float, ...]
    self_weight: float

    @property
    def K(self) -> int:
        return self.weights.shape[0]

    @property
    def spectral_gap(self) -> float:
        return spectral_gap(self.weights)

    def neighbors_of(self, k: int) -> List[Tuple[int, float]]:
        row = self.weights[k]
        return [(j, float(row[j])) for j in np.nonzero(row)[0] if j != k]


def _check_doubly_stochastic(W: np.ndarray, atol: float = 1e-8) -> None:
    K = W.shape[0]
    assert W.shape == (K, K)
    if not np.allclose(W, W.T, atol=atol):
        raise ValueError("W must be symmetric")
    if not np.allclose(W.sum(axis=0), 1.0, atol=atol):
        raise ValueError("W columns must sum to 1")
    if not np.allclose(W.sum(axis=1), 1.0, atol=atol):
        raise ValueError("W rows must sum to 1")
    if np.any(W < -atol):
        raise ValueError("W must be non-negative")


def spectral_gap(W: np.ndarray) -> float:
    """rho = 1 - |lambda_2| (Definition 1)."""
    eig = np.linalg.eigvalsh(W)
    eig = np.sort(np.abs(eig))[::-1]
    if not np.isclose(eig[0], 1.0, atol=1e-6):
        raise ValueError(f"largest |eigenvalue| must be 1, got {eig[0]}")
    if len(eig) == 1:
        return 1.0
    return float(1.0 - eig[1])


def ring(K: int, self_weight: float | None = None) -> Topology:
    """Ring topology (the paper's experimental setup).

    Each worker mixes with its left and right neighbor. Default weights are
    the canonical 1/3-1/3-1/3 (for K >= 3).
    """
    if K <= 0:
        raise ValueError("K must be positive")
    if K == 1:
        return Topology("ring", np.ones((1, 1)), (), (), 1.0)
    if K == 2:
        W = np.array([[0.5, 0.5], [0.5, 0.5]])
        return Topology("ring", W, (1,), (0.5,), 0.5)
    sw = 1.0 / 3.0 if self_weight is None else self_weight
    nw = (1.0 - sw) / 2.0
    W = np.zeros((K, K))
    for k in range(K):
        W[k, k] = sw
        W[k, (k + 1) % K] = nw
        W[k, (k - 1) % K] = nw
    _check_doubly_stochastic(W)
    return Topology("ring", W, (1, K - 1), (nw, nw), sw)


def fully_connected(K: int) -> Topology:
    """W = (1/K) 11^T — gossip == exact averaging (rho = 1)."""
    W = np.full((K, K), 1.0 / K)
    offsets = tuple(range(1, K))
    return Topology(
        "fully_connected", W, offsets, tuple([1.0 / K] * (K - 1)), 1.0 / K
    )


def exponential(K: int) -> Topology:
    """One-peer-per-power-of-two exponential graph (static union version).

    Worker k is connected to k +/- 2^i for all 2^i < K. Well-conditioned
    (rho ~ O(1/log K)) while keeping degree log K.
    """
    if K == 1:
        return Topology("exponential", np.ones((1, 1)), (), (), 1.0)
    hops = []
    i = 1
    while i < K:
        hops.append(i)
        i *= 2
    # union of +/- hops; uniform weights over self + distinct neighbors
    offs = sorted({h % K for h in hops} | {(-h) % K for h in hops} - {0})
    deg = len(offs)
    w = 1.0 / (deg + 1)
    W = np.zeros((K, K))
    for k in range(K):
        W[k, k] = w
        for s in offs:
            W[k, (k + s) % K] += w
    _check_doubly_stochastic(W)
    return Topology("exponential", W, tuple(offs), tuple([w] * deg), w)


def torus(rows: int, cols: int) -> Topology:
    """2-D torus: 4 neighbors each, weight 1/5."""
    K = rows * cols
    W = np.zeros((K, K))
    w = 1.0 / 5.0

    def rank(r: int, c: int) -> int:
        return (r % rows) * cols + (c % cols)

    for r in range(rows):
        for c in range(cols):
            k = rank(r, c)
            W[k, k] = w
            for dr, dc in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                W[k, rank(r + dr, c + dc)] += w
    _check_doubly_stochastic(W)
    # torus over a flattened axis is shift-invariant with offsets
    # {+-1 (mod cols wrap folded in), +-cols}; exact only when rows>2, cols>2
    offs: Tuple[int, ...] = ()
    offw: Tuple[float, ...] = ()
    if rows > 2 and cols > 2:
        offs = (1, K - 1, cols, K - cols)
        offw = (w, w, w, w)
    return Topology("torus", W, offs, offw, w)


_REGISTRY = {
    "ring": ring,
    "fully_connected": fully_connected,
    "exponential": exponential,
}


def make_topology(name: str, K: int, **kw) -> Topology:
    if name == "torus":
        r = int(np.sqrt(K))
        while K % r:
            r -= 1
        return torus(r, K // r)
    if name not in _REGISTRY:
        raise KeyError(f"unknown topology {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](K, **kw)


def effective_rho(topo: Topology) -> float:
    """Convenience used by convergence-bound reporting (Theorem 1)."""
    return topo.spectral_gap
