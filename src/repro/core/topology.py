"""Gossip topologies: doubly-stochastic mixing matrices W (Definition 1).

The paper requires W symmetric, doubly stochastic, with spectral gap
rho = 1 - |lambda_2| in (0, 1].  The experiments use a ring of 8 workers.

We provide the standard zoo (ring, torus, hypercube, exponential,
fully-connected) plus helpers for neighbor lists so the distributed
runtime can lower gossip as sparse ``ppermute`` exchanges instead of a
dense mixing matmul.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import List, Tuple, Union

import numpy as np


@dataclasses.dataclass(frozen=True)
class GridShift:
    """Row-wrap-aware shift on a ``rows x cols`` grid flattened to
    ``K = rows * cols``: worker ``k`` reads ``src(k)``, the grid neighbor
    ``(r + dr, c + dc)`` with both coordinates wrapping independently.

    This is NOT a flat circulant offset — ``(r, cols-1) + (0, 1)`` wraps to
    ``(r, 0)``, not to the next row — which is exactly the torus lowering
    bug the plain-int offsets had. ``src`` uses only ``//`` and ``%`` so it
    works on traced ints (Pallas BlockSpec index maps)."""

    dr: int
    dc: int
    rows: int
    cols: int

    def src(self, k):
        r, c = k // self.cols, k % self.cols
        return (((r + self.dr) % self.rows) * self.cols
                + (c + self.dc) % self.cols)


@dataclasses.dataclass(frozen=True)
class PermShift:
    """An explicit worker permutation: worker ``k`` reads ``perm[k]``.

    Used by topology schedules with no shift structure at all (randomized
    rings). ``perm`` must be a bijection of range(K)."""

    perm: Tuple[int, ...]

    def __post_init__(self):
        if sorted(self.perm) != list(range(len(self.perm))):
            raise ValueError("PermShift.perm must be a permutation of "
                             f"range({len(self.perm)})")


Offset = Union[int, GridShift, PermShift]


def offset_perm(off: Offset, K: int) -> np.ndarray:
    """The source-worker index per destination worker: ``out[k]`` is the
    worker whose value worker ``k`` reads under this offset."""
    if isinstance(off, (int, np.integer)):
        return (np.arange(K) + int(off)) % K
    if isinstance(off, GridShift):
        if off.rows * off.cols != K:
            raise ValueError(f"GridShift {off} does not cover K={K}")
        return np.array([off.src(k) for k in range(K)])
    if isinstance(off, PermShift):
        if len(off.perm) != K:
            raise ValueError(f"PermShift has {len(off.perm)} entries, "
                             f"expected K={K}")
        return np.asarray(off.perm)
    raise TypeError(f"unknown offset type {type(off).__name__}")


@dataclasses.dataclass(frozen=True)
class Topology:
    """A gossip graph over K workers.

    Attributes:
      name: human-readable id.
      weights: (K, K) symmetric doubly-stochastic mixing matrix.
      neighbors: for each worker, the list of (neighbor_rank, weight) pairs
        with neighbor != self. Self weight is ``self_weights[k]``.
      offsets: permutation offsets covering all edges with a *uniform*
        weight each: plain ints (ring-style circulant shifts,
        ``k -> (k+s) % K``), :class:`GridShift` (torus row/col wrap), or
        :class:`PermShift` (explicit permutations). Populated whenever the
        graph decomposes into uniform-weight permutations; used to lower
        gossip as rolls / ppermutes. ``offsets_matrix`` must equal
        ``weights`` — the zoo-wide property test pins this.
    """

    name: str
    weights: np.ndarray
    offsets: Tuple[Offset, ...]
    offset_weights: Tuple[float, ...]
    self_weight: float

    @property
    def K(self) -> int:
        return self.weights.shape[0]

    @property
    def spectral_gap(self) -> float:
        return spectral_gap(self.weights)

    def neighbors_of(self, k: int) -> List[Tuple[int, float]]:
        row = self.weights[k]
        return [(j, float(row[j])) for j in np.nonzero(row)[0] if j != k]


def offsets_matrix(topo: "Topology") -> np.ndarray:
    """The mixing matrix the shift lowering actually applies:
    ``W[k, src] += w`` for every offset. Must equal ``topo.weights`` for the
    roll/ppermute gossip to mix the right neighbors — the invariant the
    torus lowering violated before offsets became wrap-aware."""
    K = topo.K
    W = np.zeros((K, K))
    np.fill_diagonal(W, topo.self_weight)
    for off, w in zip(topo.offsets, topo.offset_weights):
        src = offset_perm(off, K)
        for k in range(K):
            W[k, src[k]] += w
    return W


def _check_doubly_stochastic(W: np.ndarray, atol: float = 1e-8) -> None:
    K = W.shape[0]
    assert W.shape == (K, K)
    if not np.allclose(W, W.T, atol=atol):
        raise ValueError("W must be symmetric")
    if not np.allclose(W.sum(axis=0), 1.0, atol=atol):
        raise ValueError("W columns must sum to 1")
    if not np.allclose(W.sum(axis=1), 1.0, atol=atol):
        raise ValueError("W rows must sum to 1")
    if np.any(W < -atol):
        raise ValueError("W must be non-negative")


def spectral_gap(W: np.ndarray) -> float:
    """rho = 1 - |lambda_2| (Definition 1)."""
    eig = np.linalg.eigvalsh(W)
    eig = np.sort(np.abs(eig))[::-1]
    if not np.isclose(eig[0], 1.0, atol=1e-6):
        raise ValueError(f"largest |eigenvalue| must be 1, got {eig[0]}")
    if len(eig) == 1:
        return 1.0
    return float(1.0 - eig[1])


def ring(K: int, self_weight: float | None = None) -> Topology:
    """Ring topology (the paper's experimental setup).

    Each worker mixes with its left and right neighbor. Default weights are
    the canonical 1/3-1/3-1/3 (for K >= 3).
    """
    if K <= 0:
        raise ValueError("K must be positive")
    if K == 1:
        return Topology("ring", np.ones((1, 1)), (), (), 1.0)
    if K == 2:
        W = np.array([[0.5, 0.5], [0.5, 0.5]])
        return Topology("ring", W, (1,), (0.5,), 0.5)
    sw = 1.0 / 3.0 if self_weight is None else self_weight
    nw = (1.0 - sw) / 2.0
    W = np.zeros((K, K))
    for k in range(K):
        W[k, k] = sw
        W[k, (k + 1) % K] = nw
        W[k, (k - 1) % K] = nw
    _check_doubly_stochastic(W)
    return Topology("ring", W, (1, K - 1), (nw, nw), sw)


def fully_connected(K: int) -> Topology:
    """W = (1/K) 11^T — gossip == exact averaging (rho = 1)."""
    W = np.full((K, K), 1.0 / K)
    offsets = tuple(range(1, K))
    return Topology(
        "fully_connected", W, offsets, tuple([1.0 / K] * (K - 1)), 1.0 / K
    )


def exponential(K: int) -> Topology:
    """One-peer-per-power-of-two exponential graph (static union version).

    Worker k is connected to k +/- 2^i for all 2^i < K. Well-conditioned
    (rho ~ O(1/log K)) while keeping degree log K.
    """
    if K == 1:
        return Topology("exponential", np.ones((1, 1)), (), (), 1.0)
    hops = []
    i = 1
    while i < K:
        hops.append(i)
        i *= 2
    # union of +/- hops; uniform weights over self + distinct neighbors
    offs = sorted({h % K for h in hops} | {(-h) % K for h in hops} - {0})
    deg = len(offs)
    w = 1.0 / (deg + 1)
    W = np.zeros((K, K))
    for k in range(K):
        W[k, k] = w
        for s in offs:
            W[k, (k + s) % K] += w
    _check_doubly_stochastic(W)
    return Topology("exponential", W, tuple(offs), tuple([w] * deg), w)


def torus(rows: int, cols: int) -> Topology:
    """2-D torus: 4 neighbors each, weight 1/5."""
    K = rows * cols
    W = np.zeros((K, K))
    w = 1.0 / 5.0

    def rank(r: int, c: int) -> int:
        return (r % rows) * cols + (c % cols)

    for r in range(rows):
        for c in range(cols):
            k = rank(r, c)
            W[k, k] = w
            for dr, dc in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                W[k, rank(r + dr, c + dc)] += w
    _check_doubly_stochastic(W)
    # The shift lowering: each of the four directed grid steps is a
    # GridShift whose column wrap stays within the row (a flat +-1
    # circulant would leak across row boundaries — the wrong-neighbor bug).
    # Degenerate extents merge: at rows == 2 the +-row steps are the SAME
    # permutation (weight 2w), at rows == 1 they are the identity and fold
    # into the self weight; likewise for cols. The offsets-implied matrix
    # therefore equals W for EVERY (rows, cols).
    merged: dict = {}
    for dr, dc in ((1, 0), (-1, 0), (0, 1), (0, -1)):
        key = (dr % rows, dc % cols)
        merged[key] = merged.get(key, 0.0) + w
    sw = w
    offs: List[Offset] = []
    offw: List[float] = []
    for (dr, dc), wt in merged.items():
        if dr == 0 and dc == 0:
            sw += wt
        else:
            offs.append(GridShift(dr, dc, rows, cols))
            offw.append(wt)
    return Topology("torus", W, tuple(offs), tuple(offw), sw)


_REGISTRY = {
    "ring": ring,
    "fully_connected": fully_connected,
    "exponential": exponential,
}


def make_topology(name: str, K: int, **kw) -> Topology:
    """Build a static gossip graph from the zoo by name.

    Args:
      name: ``"ring"``, ``"torus"``, ``"exponential"``, or
        ``"fully_connected"``. ``"torus"`` picks the most-square
        ``rows x cols`` factorization of K and falls back to ``ring(K)``
        (with a ``RuntimeWarning``) when K only factors as ``1 x K``.
      K: number of workers.
      **kw: forwarded to the zoo constructor (e.g. ``ring(K, weight=...)``).

    Returns:
      A :class:`Topology` — symmetric doubly-stochastic ``weights``
      plus the uniform-weight permutation ``offsets`` the roll/ppermute
      gossip lowers through (``offsets_matrix(topo) == topo.weights``).

    Raises:
      KeyError: unknown topology name.

    Example:
      >>> topo = make_topology("ring", 8)
      >>> topo.K, sorted(topo.offsets)     # +-1 ring shifts (mod K)
      (8, [1, 7])
      >>> float(topo.weights.sum(axis=1).max())   # doubly stochastic
      1.0
      >>> 0.0 < topo.spectral_gap <= 1.0
      True
    """
    if name == "torus":
        r = int(np.sqrt(K))
        while K % r:
            r -= 1
        if r == 1 and K > 1:
            # prime (or 2): the only factorization is 1 x K, whose
            # degenerate row edges collapse into a 3/5 self-loop — a worse-
            # conditioned ring in disguise. Use the honest ring instead.
            warnings.warn(
                f"torus needs a non-trivial rows x cols factorization; "
                f"K={K} only factors as 1 x {K} (self-loop absorbs the row "
                f"edges) — falling back to ring({K})", RuntimeWarning,
                stacklevel=2)
            return ring(K)
        return torus(r, K // r)
    if name not in _REGISTRY:
        raise KeyError(f"unknown topology {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](K, **kw)


def effective_rho(topo: Topology) -> float:
    """Convenience used by convergence-bound reporting (Theorem 1)."""
    return topo.spectral_gap
