"""Time-varying gossip topologies (the serverless runtime's round schedule).

A :class:`TopologySchedule` is a finite cycle of static topologies over the
SAME worker set: communication round ``r`` gossips with ``entries[r % n]``.
"Scaling Up Data Parallelism in Decentralized Deep Learning" shows the
one-peer time-varying families (exponential graphs, randomized rings) are
what make decentralized training scale — each round touches O(1) peers,
yet the round-robin union mixes like the dense static graph.

Every entry must be shift-invariant (carry offsets): the runtime lowers a
schedule as a ``lax.switch`` over per-entry gossip bodies, each with its
*static* offsets/weights — rolls under comm='stacked', round-indexed
ppermutes under comm='axis' — so the whole schedule still compiles to ONE
jitted step.

State-carrying consumers (CD-Adam's per-offset CHOCO hat copies, the
staleness ring buffers) need one slot per edge that can EVER be active, so
they are built over ``union_offsets()`` and each round runs a
``union_views()`` entry: the same offset tuple everywhere, with weight 0 on
the edges the round leaves idle.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple, Union

import numpy as np

from repro.core.topology import (Offset, PermShift, Topology,
                                 _check_doubly_stochastic, make_topology,
                                 ring)


@dataclasses.dataclass(frozen=True)
class TopologySchedule:
    """A cyclic round schedule of shift-invariant topologies over K workers.

    ``at(r)`` is round r's graph; ``union_offsets()`` / ``union_views()``
    serve consumers that keep per-edge state across rounds."""

    name: str
    entries: Tuple[Topology, ...]

    def __post_init__(self):
        if not self.entries:
            raise ValueError("a TopologySchedule needs at least one entry")
        K = self.entries[0].K
        for e in self.entries:
            if e.K != K:
                raise ValueError(
                    f"all schedule entries must share K; got {e.K} != {K}")
            if K > 1 and not e.offsets:
                raise ValueError(
                    f"schedule entry {e.name!r} has no shift structure; "
                    "time-varying gossip lowers per-entry rolls/ppermutes "
                    "and has no dense fallback")

    @property
    def K(self) -> int:
        return self.entries[0].K

    @property
    def n_entries(self) -> int:
        return len(self.entries)

    def at(self, r: int) -> Topology:
        """The static topology of communication round ``r``."""
        return self.entries[r % len(self.entries)]

    def union_offsets(self) -> Tuple[Offset, ...]:
        """Every offset that is active in ANY entry, first-seen order."""
        out: List[Offset] = []
        for e in self.entries:
            for s in e.offsets:
                if s not in out:
                    out.append(s)
        return tuple(out)

    @property
    def offsets(self) -> Tuple[Offset, ...]:
        """Duck-compatibility with ``Topology`` for degree/validation
        checks: the union edge set."""
        return self.union_offsets()

    def union_views(self) -> Tuple[Topology, ...]:
        """Each entry rebuilt over the union offset tuple, zero weight on
        its inactive edges — identical offset structure for every round, so
        per-edge state (hat copies, staleness buffers) aligns across the
        whole cycle."""
        union = self.union_offsets()
        views = []
        for e in self.entries:
            by_off = dict(zip(e.offsets, e.offset_weights))
            views.append(Topology(
                e.name, e.weights, union,
                tuple(float(by_off.get(s, 0.0)) for s in union),
                e.self_weight))
        return tuple(views)

    @property
    def mean_weights(self) -> np.ndarray:
        """The cycle-averaged mixing matrix (summary/accounting only)."""
        return np.mean([e.weights for e in self.entries], axis=0)

    @property
    def spectral_gap(self) -> float:
        from repro.core.topology import spectral_gap
        return spectral_gap(self.mean_weights)


def static_schedule(topo: Topology) -> TopologySchedule:
    """A single-entry schedule — by construction identical to the static
    topology round for round (the parity the tests pin)."""
    return TopologySchedule(f"static[{topo.name}]", (topo,))


def one_peer_exponential(K: int) -> TopologySchedule:
    """One-peer exponential graphs: round ``i`` pairs ``k`` with
    ``k +/- 2^i (mod K)`` only — degree <= 2 per round, while the cycle's
    union is the static exponential graph."""
    if K == 1:
        return TopologySchedule("one_peer_exponential", (ring(1),))
    entries = []
    h = 1
    while h < K:
        s = h % K
        if s == (K - s) % K:          # +h and -h are the same permutation
            offs: Tuple[Offset, ...] = (s,)
            offw: Tuple[float, ...] = (2.0 / 3.0,)
        else:
            offs = (s, K - s)
            offw = (1.0 / 3.0, 1.0 / 3.0)
        sw = 1.0 / 3.0
        W = np.zeros((K, K))
        for k in range(K):
            W[k, k] += sw
            for o, w in zip(offs, offw):
                W[k, (k + o) % K] += w
        _check_doubly_stochastic(W)
        entries.append(Topology(f"one_peer_exp[{h}]", W, offs, offw, sw))
        h *= 2
    return TopologySchedule("one_peer_exponential", tuple(entries))


def randomized_rings(K: int, n_entries: int = 4,
                     seed: int = 0) -> TopologySchedule:
    """Each round is a ring over a seeded random worker permutation
    (successor + predecessor edges, weights 1/3) — no circulant structure,
    so the offsets are explicit :class:`PermShift` permutations."""
    if K == 1:
        return TopologySchedule("randomized_rings", (ring(1),))
    rs = np.random.RandomState(seed)
    entries = []
    for e in range(n_entries):
        pi = rs.permutation(K)
        succ = np.empty(K, dtype=int)
        pred = np.empty(K, dtype=int)
        for i in range(K):
            succ[pi[i]] = pi[(i + 1) % K]
            pred[pi[i]] = pi[(i - 1) % K]
        if K == 2:                    # succ == pred: one edge, weight 1/2
            offs: Tuple[Offset, ...] = (PermShift(tuple(succ.tolist())),)
            offw: Tuple[float, ...] = (0.5,)
            sw = 0.5
        else:
            offs = (PermShift(tuple(succ.tolist())),
                    PermShift(tuple(pred.tolist())))
            offw = (1.0 / 3.0, 1.0 / 3.0)
            sw = 1.0 / 3.0
        W = np.zeros((K, K))
        for k in range(K):
            W[k, k] += sw
            for off, w in zip(offs, offw):
                W[k, off.perm[k]] += w
        _check_doubly_stochastic(W)
        entries.append(Topology(f"rand_ring[{e}]", W, offs, offw, sw))
    return TopologySchedule("randomized_rings", tuple(entries))


def comm_offsets(topo: Union[Topology, TopologySchedule]
                 ) -> Tuple[Offset, ...]:
    """The edge set per-edge state must cover: a static topology's offsets,
    or a schedule's union."""
    if isinstance(topo, TopologySchedule):
        return topo.union_offsets()
    return topo.offsets


_SCHEDULES = {
    "one-peer-exponential": one_peer_exponential,
    "one-peer-exp": one_peer_exponential,
    "randomized-rings": randomized_rings,
    "rand-ring": randomized_rings,
}


def make_schedule(spec: str, K: int, **kw) -> TopologySchedule:
    """Build a time-varying topology schedule from a string spec.

    Gossip round r uses entry ``r % len(entries)``; the optimizer
    dispatches per-round graphs with ``lax.switch`` and sizes payload
    buffers to the schedule's union edge set.

    Args:
      spec: a named family — ``"one-peer-exp"`` /
        ``"one-peer-exponential"`` (log2(K) one-peer rounds) or
        ``"rand-ring"`` (optionally ``"rand-ring:N"`` for N randomized
        ring permutations) — or any static-zoo topology name, which
        wraps as a single-entry (constant) schedule.
      K: number of workers.
      **kw: forwarded to the family constructor (e.g. ``seed=`` for
        ``rand-ring``; an explicit ``n_entries=`` loses to a ``:N``
        suffix in the spec).

    Returns:
      A :class:`TopologySchedule` whose every entry is a zoo-grade
      :class:`Topology` (doubly stochastic, offsets == weights).

    Raises:
      KeyError: the spec names neither a family nor a zoo topology.

    Example:
      >>> sched = make_schedule("one-peer-exp", 8)
      >>> len(sched.entries), sched.K
      (3, 8)
      >>> len(make_schedule("rand-ring:4", 8).entries)
      4
      >>> make_schedule("ring", 8).entries[0].name
      'ring'
    """
    name, _, arg = spec.partition(":")
    name = name.replace("_", "-")
    if name in _SCHEDULES:
        if arg:
            kw.setdefault("n_entries", int(arg))
        fn = _SCHEDULES[name]
        if fn is one_peer_exponential:
            kw.pop("n_entries", None)
        return fn(K, **kw)
    return static_schedule(make_topology(spec, K))
