"""Baselines the paper compares against (we implement every one).

* D-Adam-vanilla — Alg. 1 with p = 1 (gossip every iteration), the paper's
  primary comparison point. Constructed by config, no extra code path.
* D-PSGD [15] — decentralized *SGD* with gossip averaging (the non-adaptive
  predecessor): local step  x_{t+1/2} = x_t - eta * g_t, gossip identical.
* C-Adam — centralized Adam (C-PSGD with adaptive server step): one global
  parameter copy, gradients all-reduced every step. Equivalent to K = 1
  Adam on the averaged gradient; used for quality parity checks and the
  'global' worker mode of huge configs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import dadam
from repro.core.dadam import DAdamConfig
from repro.core.topology import Topology

PyTree = Any


# ------------------------------- D-PSGD ------------------------------------


@dataclasses.dataclass(frozen=True)
class DPSGDConfig:
    eta: float = 1e-2
    momentum: float = 0.0
    weight_decay: float = 0.0
    period: int = 1
    mixing: str = "roll"


class DPSGDState(NamedTuple):
    params: PyTree
    velocity: PyTree
    count: jax.Array


def dpsgd_init(params_stacked: PyTree, cfg: DPSGDConfig) -> DPSGDState:
    return DPSGDState(
        params_stacked,
        jax.tree_util.tree_map(jnp.zeros_like, params_stacked),
        jnp.zeros((), jnp.int32),
    )


def dpsgd_step(state: DPSGDState, grads: PyTree, topo: Topology,
               cfg: DPSGDConfig) -> DPSGDState:
    count = state.count + 1

    def upd(x, v, g):
        g = g.astype(x.dtype)
        if cfg.weight_decay:
            g = g + cfg.weight_decay * x
        v_new = cfg.momentum * v + g
        return x - cfg.eta * v_new, v_new

    out = jax.tree_util.tree_map(upd, state.params, state.velocity, grads)
    half = jax.tree_util.tree_map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
    vel = jax.tree_util.tree_map(lambda t: t[1], out,
                                 is_leaf=lambda t: isinstance(t, tuple))

    d_cfg = DAdamConfig(mixing=cfg.mixing, period=cfg.period)
    if cfg.period == 1:
        return DPSGDState(dadam.gossip_stacked(half, topo, d_cfg), vel, count)
    new_params = jax.lax.cond(
        (count % cfg.period) == 0,
        lambda x: dadam.gossip_stacked(x, topo, d_cfg),
        lambda x: x,
        half,
    )
    return DPSGDState(new_params, vel, count)


# ------------------------------- C-Adam ------------------------------------


class CAdamState(NamedTuple):
    params: PyTree            # single copy (no worker dim)
    moments: dadam.AdamMoments


def cadam_init(params: PyTree, cfg: DAdamConfig) -> CAdamState:
    return CAdamState(params, dadam.init_moments(params, cfg))


def cadam_step(state: CAdamState, mean_grads: PyTree,
               cfg: DAdamConfig) -> CAdamState:
    """Centralized Adam on the all-reduced mean gradient."""
    new_params, mom = dadam.local_update(
        state.params, mean_grads, state.moments, cfg)
    return CAdamState(new_params, mom)
