"""delta-contraction compression operators (Definition 2).

A compressor Q satisfies  ||x - Q(x)||^2 <= (1 - delta) ||x||^2  with
delta in (0, 1].  The paper's experiments use the (scaled) sign operator.

Each operator is exposed in two forms:

* ``apply(x) -> Q(x)``: the mathematical operator used by CD-Adam's update
  and by the property tests.
* ``encode(x) -> payload`` / ``decode(payload) -> Q(x)``: the *wire format*
  — payload tensors use narrow dtypes (int8 sign bits, top-k value/index
  pairs) so that when the runtime ppermutes the payload between neighbor
  workers the lowered collective is genuinely smaller.  This is the TPU
  adaptation of the paper's "communication cost in MB" accounting.

All functions are jit-safe (shape-static).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Payload = Any  # pytree of arrays


@dataclasses.dataclass(frozen=True)
class Compressor:
    """A delta-contraction operator with an explicit wire format."""

    name: str
    apply: Callable[[jax.Array], jax.Array]
    encode: Callable[[jax.Array], Payload]
    decode: Callable[[Payload, Tuple[int, ...], Any], jax.Array]
    # lower bound on delta for a d-dim input (used in reports / Thm 2 terms)
    delta_bound: Callable[[int], float]
    # bytes on the wire for a given (shape, dtype)
    wire_bytes: Callable[[Tuple[int, ...], Any], int]

    def roundtrip(self, x: jax.Array) -> jax.Array:
        return self.decode(self.encode(x), x.shape, x.dtype)


def _nbytes(shape, dtype) -> int:
    return int(np.prod(shape)) * jnp.dtype(dtype).itemsize


# ---------------------------------------------------------------------------
# identity (delta = 1): CD-Adam degenerates towards D-Adam
# ---------------------------------------------------------------------------


def identity() -> Compressor:
    return Compressor(
        name="identity",
        apply=lambda x: x,
        encode=lambda x: x,
        decode=lambda p, shape, dtype: p.astype(dtype).reshape(shape),
        delta_bound=lambda d: 1.0,
        wire_bytes=_nbytes,
    )


# ---------------------------------------------------------------------------
# scaled sign (the paper's choice, [4] Bernstein et al.)
#   Q(x) = (||x||_1 / d) * sign(x)
# delta = ||x||_1^2 / (d ||x||_2^2) >= 1/d  (Cauchy-Schwarz)
# Wire: int8 sign tensor + one f32 scale  => ~1 byte/elem vs 2-4.
# ---------------------------------------------------------------------------


def sign() -> Compressor:
    def _apply(x):
        # float literal: leaves can exceed 2**31 elements (32B-param models)
        scale = jnp.sum(jnp.abs(x)) / float(x.size)
        return (scale * jnp.sign(x)).astype(x.dtype)

    def _encode(x):
        scale = (jnp.sum(jnp.abs(x)) / float(x.size)).astype(jnp.float32)
        bits = jnp.sign(x).astype(jnp.int8)
        return {"bits": bits, "scale": scale}

    def _decode(p, shape, dtype):
        return (p["scale"] * p["bits"].astype(jnp.float32)).astype(dtype).reshape(shape)

    return Compressor(
        name="sign",
        apply=_apply,
        encode=_encode,
        decode=_decode,
        delta_bound=lambda d: 1.0 / max(d, 1),
        wire_bytes=lambda shape, dtype: int(np.prod(shape)) * 1 + 4,
    )


# ---------------------------------------------------------------------------
# top-k sparsification: keep the k largest-magnitude coords. delta = k/d.
# Wire: k values (input dtype) + k int32 indices.
# ---------------------------------------------------------------------------


def topk(fraction: float = 1.0 / 16.0) -> Compressor:
    if not 0.0 < fraction <= 1.0:
        raise ValueError("fraction must be in (0, 1]")

    def _k(d: int) -> int:
        return max(1, int(round(d * fraction)))

    def _encode(x):
        flat = x.reshape(-1)
        k = _k(flat.size)
        vals, idx = jax.lax.top_k(jnp.abs(flat), k)
        del vals
        return {"values": flat[idx], "indices": idx.astype(jnp.int32)}

    def _decode(p, shape, dtype):
        d = int(np.prod(shape))
        out = jnp.zeros((d,), dtype=dtype)
        out = out.at[p["indices"]].set(p["values"].astype(dtype))
        return out.reshape(shape)

    def _apply(x):
        return _decode(_encode(x), x.shape, x.dtype)

    return Compressor(
        name=f"topk{fraction:g}",
        apply=_apply,
        encode=_encode,
        decode=_decode,
        # exact: k coords kept out of d; round(d*fraction) can land BELOW
        # d*fraction, in which case an equal-magnitude input achieves the
        # bound with equality (so reporting plain `fraction` would be wrong)
        delta_bound=lambda d: _k(d) / max(d, 1),
        wire_bytes=lambda shape, dtype: _k(int(np.prod(shape)))
        * (jnp.dtype(dtype).itemsize + 4),
    )


# ---------------------------------------------------------------------------
# random-k sparsification (unbiased up to scaling; delta = k/d in expectation)
# Deterministic per-step key is threaded by the caller; here we use a
# counter-free variant: a fixed pseudo-random permutation derived from shape,
# rotated by a step index the caller folds in. For the contraction *property*
# tests we use the keyed form.
# ---------------------------------------------------------------------------


def randk(fraction: float = 1.0 / 16.0, seed: int = 0) -> Compressor:
    if not 0.0 < fraction <= 1.0:
        raise ValueError("fraction must be in (0, 1]")

    def _k(d: int) -> int:
        return max(1, int(round(d * fraction)))

    def _idx(d: int) -> jax.Array:
        key = jax.random.PRNGKey(seed)
        return jax.random.permutation(key, d)[: _k(d)].astype(jnp.int32)

    def _encode(x):
        flat = x.reshape(-1)
        idx = _idx(flat.size)
        return {"values": flat[idx], "indices": idx}

    def _decode(p, shape, dtype):
        d = int(np.prod(shape))
        out = jnp.zeros((d,), dtype=dtype)
        out = out.at[p["indices"]].set(p["values"].astype(dtype))
        return out.reshape(shape)

    def _apply(x):
        return _decode(_encode(x), x.shape, x.dtype)

    return Compressor(
        name=f"randk{fraction:g}",
        apply=_apply,
        encode=_encode,
        decode=_decode,
        delta_bound=lambda d: _k(d) / max(d, 1),
        wire_bytes=lambda shape, dtype: _k(int(np.prod(shape)))
        * (jnp.dtype(dtype).itemsize + 4),
    )


# ---------------------------------------------------------------------------
# qsgd-style stochastic-free deterministic quantization to s levels
# (we use the deterministic midpoint variant so Q is a contraction, not
#  merely unbiased). Wire: int8 levels + f32 scale.
# ---------------------------------------------------------------------------


def quantize(levels: int = 16) -> Compressor:
    if not 2 <= levels <= 127:
        raise ValueError("levels must be in [2, 127]")

    def _encode(x):
        scale = (jnp.max(jnp.abs(x)) + 1e-30).astype(jnp.float32)
        q = jnp.round(x.astype(jnp.float32) / scale * levels).astype(jnp.int8)
        return {"q": q, "scale": scale}

    def _decode(p, shape, dtype):
        return (p["q"].astype(jnp.float32) * p["scale"] / levels).astype(
            dtype
        ).reshape(shape)

    def _apply(x):
        return _decode(_encode(x), x.shape, x.dtype)

    # |x - Q(x)| <= scale/(2 levels) per coord; worst case when |x|~scale/2L
    # everywhere gives delta >= 1 - 1/(1 + ...) — we report a conservative
    # bound delta = 3/4 for levels >= 2 based on relative error <= 1/(2L)
    # of the max coordinate (exact delta is data-dependent).
    def _delta(d: int) -> float:
        rel = 1.0 / (2.0 * levels)
        return max(1e-6, 1.0 - d * rel * rel)  # conservative for small d

    return Compressor(
        name=f"q{levels}",
        apply=_apply,
        encode=_encode,
        decode=_decode,
        delta_bound=_delta,
        wire_bytes=lambda shape, dtype: int(np.prod(shape)) * 1 + 4,
    )


_REGISTRY: Dict[str, Callable[..., Compressor]] = {
    "identity": identity,
    "sign": sign,
    "topk": topk,
    "randk": randk,
    "quantize": quantize,
}


def make_compressor(name: str, **kw) -> Compressor:
    if name not in _REGISTRY:
        raise KeyError(f"unknown compressor {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kw)


# -------------------------- pytree-level helpers ---------------------------


def tree_apply(comp: Compressor, tree) -> Any:
    """Q applied leaf-wise to a parameter pytree."""
    return jax.tree_util.tree_map(comp.apply, tree)


def tree_wire_bytes(comp: Compressor, tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(comp.wire_bytes(l.shape, l.dtype) for l in leaves)


def tree_dense_bytes(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(_nbytes(l.shape, l.dtype) for l in leaves)
