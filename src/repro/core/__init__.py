"""Core: the paper's contribution — D-Adam / CD-Adam decentralized adaptive
optimization with periodic + compressed gossip, time-varying topology
schedules, straggler-tolerant gossip and elastic worker membership."""
from repro.core.api import (
    DecentralizedOptimizer,
    is_packed_state,
    make_optimizer,
    resolve_topology,
)
from repro.core.cdadam import CDAdamConfig, CDAdamState, PackedCDAdamState
from repro.core.compression import Compressor, make_compressor
from repro.core.dadam import AdamMoments, DAdamConfig, DAdamState, PackedDAdamState
from repro.core.elastic import resize_state
from repro.core.schedule import (
    TopologySchedule,
    make_schedule,
    one_peer_exponential,
    randomized_rings,
    static_schedule,
)
from repro.core.topology import (
    GridShift,
    PermShift,
    Topology,
    make_topology,
    offsets_matrix,
    spectral_gap,
)

__all__ = [
    "DecentralizedOptimizer", "make_optimizer", "is_packed_state",
    "resolve_topology",
    "DAdamConfig", "DAdamState", "PackedDAdamState", "AdamMoments",
    "CDAdamConfig", "CDAdamState", "PackedCDAdamState",
    "Compressor", "make_compressor",
    "Topology", "make_topology", "spectral_gap",
    "GridShift", "PermShift", "offsets_matrix",
    "TopologySchedule", "make_schedule", "static_schedule",
    "one_peer_exponential", "randomized_rings",
    "resize_state",
]
