"""Core: the paper's contribution — D-Adam / CD-Adam decentralized adaptive
optimization with periodic + compressed gossip."""
from repro.core.api import DecentralizedOptimizer, is_packed_state, make_optimizer
from repro.core.cdadam import CDAdamConfig, CDAdamState, PackedCDAdamState
from repro.core.compression import Compressor, make_compressor
from repro.core.dadam import AdamMoments, DAdamConfig, DAdamState, PackedDAdamState
from repro.core.topology import Topology, make_topology, spectral_gap

__all__ = [
    "DecentralizedOptimizer", "make_optimizer", "is_packed_state",
    "DAdamConfig", "DAdamState", "PackedDAdamState", "AdamMoments",
    "CDAdamConfig", "CDAdamState", "PackedCDAdamState",
    "Compressor", "make_compressor",
    "Topology", "make_topology", "spectral_gap",
]
