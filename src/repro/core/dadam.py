"""D-Adam (Algorithm 1): decentralized Adam with periodic gossip.

Per worker k and iteration t:

    m_t = b1 * m_{t-1} + (1 - b1) * g_t
    v_t = b2 * v_{t-1} + (1 - b2) * g_t ** 2
    x_{t+1/2} = x_t - eta * m_t / (sqrt(v_t) + tau)
    if (t + 1) % p == 0:   x_{t+1} = sum_j W[k, j] * x_{t+1/2}^{(j)}
    else:                  x_{t+1} = x_{t+1/2}

Two equivalent runtime realizations, selected by ``DAdamConfig.comm`` and
sharing one code path (the only difference is how "worker k reads worker
(k + s) % K" is expressed — see :func:`shift_worker`):

* **comm='stacked'**: every pytree leaf carries a leading worker dim ``K``
  and the whole step runs as one program. Gossip is either a dense mixing
  einsum (paper-faithful baseline: lowered by XLA as gather-style
  collectives) or a sum of ``jnp.roll`` shifts over the worker dim for
  shift-invariant graphs (optimized: lowered as collective-permutes that
  only touch ring neighbors when the dim is sharded).
* **comm='axis'**: the SAME stacked state is partitioned over a named mesh
  axis (``cfg.axis_name``, one worker per mesh slot) and the step runs
  per-shard inside ``shard_map``; every worker shift is a
  ``jax.lax.ppermute`` over the axis, so the wire carries exactly one
  neighbor block per offset. ``make_optimizer(comm='axis', mesh=...)``
  installs the shard_map wrapper; the functions here only assume they are
  traced with ``cfg.axis_name`` bound.

Both share the same math; tests pin them against each other and against the
K=1 == Adam identity. The pallas backend composes with either comm mode:
the resident packed (K, rows, 128) buffer is sharded along its leading dim
and the fused kernels run on each worker's (1, rows, 128) shard.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedule import TopologySchedule, comm_offsets
from repro.core.topology import GridShift, Topology, offset_perm
# the pack layer is dependency-light (no Pallas import); the kernel stack
# itself (repro.kernels.ops) is imported lazily inside the pallas-only
# paths so backend='reference' users never pay for it
from repro.kernels import pack as packing
from repro.kernels.pack import BLOCK_ROWS

PyTree = Any

# staleness ages start "infinitely old" so the FIRST gossip round always
# takes a fresh payload (cold buffers never mix in); half of int32 max so
# age + 1 cannot overflow
COLD_AGE = np.int32(2**30)


@dataclasses.dataclass(frozen=True)
class DAdamConfig:
    eta: float = 1e-3           # initial learning rate (paper's eta)
    beta1: float = 0.9
    beta2: float = 0.999
    tau: float = 1e-6           # paper's tau > 0 (denominator guard)
    period: int = 1             # p: communicate every p iterations
    weight_decay: float = 0.0   # L2 (paper: 1e-4 for CIFAR-10)
    bias_correction: bool = False  # paper's Alg. 1 has none; optional extra
    mixing: str = "roll"        # 'dense' | 'roll' (comm='stacked' only)
    moment_dtype: Optional[Any] = None  # e.g. jnp.bfloat16 for huge models
    backend: str = "reference"  # 'reference' (jnp tree_map) | 'pallas'
                                # (fused one-pass kernel over the packed
                                # parameter vector; interpret mode off-TPU)
    comm: str = "stacked"       # 'stacked' (roll over the leading worker
                                # dim) | 'axis' (ppermute over axis_name
                                # inside shard_map; one worker per slot)
    axis_name: str = "worker"   # mesh axis carrying the worker dim when
                                # comm='axis'
    model_parallel: int = 1     # inner model-parallel group size per
                                # worker (comm='axis' 2D mesh): the packed
                                # row dim is sharded M-ways over
                                # model_axis_name and each worker's local
                                # step runs on a (1, rows/M, 128) shard
    model_axis_name: str = "model"  # mesh axis carrying the inner model
                                # shards when model_parallel > 1
    staleness: Optional[int] = None  # straggler-tolerant gossip: mix the
                                # last-arrived neighbor payload, at most
                                # tau rounds old (None = synchronous;
                                # tau=0 == synchronous bit-for-bit)
    straggler_rate: float = 0.0  # probability a neighbor payload misses a
                                # round (deterministic per straggler_seed)
    straggler_seed: int = 0
    overlap: bool = False       # comm/compute overlap: issue round r's
                                # gossip payload eagerly and fold it into
                                # round r+1's mix, so the wire exchange
                                # runs concurrently with the next p local
                                # Adam steps. Wire-equivalent to a
                                # staleness bound of one round with EVERY
                                # payload exactly one round late.

    def validate(self) -> None:
        if not 0 <= self.beta1 < 1 or not 0 <= self.beta2 < 1:
            raise ValueError("beta1/beta2 must be in [0, 1)")
        if self.tau <= 0:
            raise ValueError("tau must be > 0")
        if self.period < 1:
            raise ValueError("period p must be >= 1")
        if self.mixing not in ("dense", "roll"):
            raise ValueError(f"unknown mixing {self.mixing!r}")
        if self.backend not in ("reference", "pallas"):
            raise ValueError(f"unknown backend {self.backend!r}")
        if self.comm not in ("stacked", "axis"):
            raise ValueError(f"unknown comm {self.comm!r}")
        if self.comm == "axis":
            if not self.axis_name:
                raise ValueError("comm='axis' needs a non-empty axis_name")
            if self.mixing == "dense":
                raise ValueError(
                    "comm='axis' gossips with ppermute along the graph "
                    "offsets and has no dense-mixing lowering; use "
                    "mixing='roll' (shift-invariant topology) or "
                    "comm='stacked'")
        if self.model_parallel < 1:
            raise ValueError(
                f"model_parallel must be >= 1, got {self.model_parallel}")
        if self.model_parallel > 1:
            if self.comm != "axis":
                raise ValueError(
                    "model_parallel > 1 is the 2D (worker x model) mesh "
                    "execution and requires comm='axis'")
            if self.backend != "pallas":
                raise ValueError(
                    "model_parallel > 1 shards the packed row dim of the "
                    "resident (K, rows, 128) state and requires "
                    "backend='pallas' (the reference pytree layout has no "
                    "uniform row dim to shard)")
            if not self.model_axis_name:
                raise ValueError(
                    "model_parallel > 1 needs a non-empty model_axis_name")
        if self.backend == "pallas" and self.bias_correction:
            raise ValueError(
                "backend='pallas' implements the paper's Alg. 1 update "
                "(no bias correction); use backend='reference' for "
                "bias_correction=True")
        if self.staleness is not None:
            if self.staleness < 0:
                raise ValueError(
                    f"staleness bound tau must be >= 0, got {self.staleness}")
            if self.mixing == "dense":
                raise ValueError(
                    "staleness-bounded gossip double-buffers per-offset "
                    "neighbor payloads; it requires the shift lowering "
                    "(mixing='roll')")
            if self.model_parallel > 1:
                raise ValueError(
                    "staleness buffers are per-worker payload copies and "
                    "are not row-sharded; staleness requires "
                    "model_parallel == 1")
        if not 0.0 <= self.straggler_rate < 1.0:
            raise ValueError(
                f"straggler_rate must be in [0, 1), got "
                f"{self.straggler_rate}")
        if self.straggler_rate > 0.0 and self.staleness is None:
            raise ValueError(
                "straggler_rate > 0 models delayed payload arrivals and "
                "needs a staleness bound (set staleness=tau)")
        if self.overlap:
            if self.staleness is not None:
                raise ValueError(
                    "overlap IS the staleness tau=1 wire schedule (every "
                    "payload exactly one round late); combining it with "
                    "an explicit staleness bound is ambiguous — choose "
                    "one")
            if self.mixing == "dense":
                raise ValueError(
                    "overlap double-buffers per-offset neighbor payloads "
                    "and requires the shift lowering (mixing='roll')")


class AdamMoments(NamedTuple):
    m: PyTree
    v: PyTree
    count: jax.Array  # scalar int32 step counter


def init_moments(params: PyTree, cfg: DAdamConfig) -> AdamMoments:
    dt = cfg.moment_dtype

    def z(x):
        return jnp.zeros(x.shape, dtype=dt or x.dtype)

    zeros = jax.tree_util.tree_map(z, params)
    return AdamMoments(
        m=zeros,
        v=jax.tree_util.tree_map(jnp.zeros_like, zeros),
        count=jnp.zeros((), jnp.int32),
    )


def _local_update_pallas(
    params: PyTree, grads: PyTree, mom: AdamMoments, cfg: DAdamConfig
) -> Tuple[PyTree, PyTree, PyTree]:
    """Alg. 1 lines 4-6 as ONE fused kernel pass over the whole parameter
    vector: the pytree is packed into a lane-aligned buffer (the update is
    elementwise, so worker/leaf boundaries are irrelevant), updated in VMEM
    tiles, and unpacked. Moments keep their own (possibly narrower) dtype
    via a second spec over the same layout.

    This is the PR-1 *repack* path: it re-spends pack/unpack HBM traffic
    every call. The steady-state pallas runtime keeps the state resident in
    packed form instead (:class:`PackedDAdamState`); this path remains for
    pytree-state callers (``local_update`` on raw trees) and as the
    repack-vs-resident baseline in ``benchmarks/fused_step.py``."""
    from repro.kernels import ops

    spec_p = packing.make_spec(params, block_rows=BLOCK_ROWS)
    spec_m = packing.make_spec(mom.m, block_rows=BLOCK_ROWS)
    po, mo, vo = ops.fused_adam(
        packing.pack(params, spec_p),
        packing.pack(grads, spec_p),
        packing.pack(mom.m, spec_m),
        packing.pack(mom.v, spec_m),
        eta=cfg.eta, beta1=cfg.beta1, beta2=cfg.beta2, tau=cfg.tau,
        weight_decay=cfg.weight_decay)
    return (packing.unpack(po, spec_p), packing.unpack(mo, spec_m),
            packing.unpack(vo, spec_m))


def local_update(
    params: PyTree, grads: PyTree, mom: AdamMoments, cfg: DAdamConfig
) -> Tuple[PyTree, AdamMoments]:
    """Lines 3-6 of Alg. 1 — elementwise, stacked-K transparent."""
    count = mom.count + 1

    if cfg.backend == "pallas":
        new_params, new_m, new_v = _local_update_pallas(params, grads, mom,
                                                        cfg)
        return new_params, AdamMoments(new_m, new_v, count)

    def upd(x, g, m, v):
        g = g.astype(m.dtype)
        if cfg.weight_decay:
            g = g + cfg.weight_decay * x.astype(m.dtype)
        m_new = cfg.beta1 * m + (1.0 - cfg.beta1) * g
        v_new = cfg.beta2 * v + (1.0 - cfg.beta2) * (g * g)
        if cfg.bias_correction:
            t = count.astype(m.dtype)
            m_hat = m_new / (1.0 - cfg.beta1 ** t)
            v_hat = v_new / (1.0 - cfg.beta2 ** t)
        else:
            m_hat, v_hat = m_new, v_new
        step = cfg.eta * m_hat / (jnp.sqrt(v_hat) + cfg.tau)
        return (x - step.astype(x.dtype)), m_new, v_new

    flat = jax.tree_util.tree_map(upd, params, grads, mom.m, mom.v)
    new_params = jax.tree_util.tree_map(lambda t: t[0], flat,
                                        is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], flat,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], flat,
                                   is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamMoments(new_m, new_v, count)


# ------------------------------- gossip ------------------------------------


def shift_worker(x: jax.Array, s: Any, K: int,
                 axis_name: Optional[str] = None) -> jax.Array:
    """Worker k reads worker ``src(k)``'s value — THE primitive both comm
    modes share, for every offset kind: a plain int is the circulant
    ``src(k) = (k + s) % K``, a :class:`~repro.core.topology.GridShift` the
    row-wrap-aware torus neighbor, a ``PermShift`` an explicit permutation.

    comm='stacked' (``axis_name=None``): a roll (or gather, for explicit
    permutations) over the leading worker dim. comm='axis': a ``ppermute``
    over the mesh axis built from the offset's permutation — round-indexed
    schedules just switch between such perms — shipping exactly one
    neighbor block per offset on the wire."""
    if axis_name is not None:
        if isinstance(s, (int, np.integer)):
            perm = [((k + int(s)) % K, k) for k in range(K)]  # (src, dst)
        else:
            src = offset_perm(s, K)
            perm = [(int(src[k]), k) for k in range(K)]
        return jax.lax.ppermute(x, axis_name, perm)
    if x.ndim < 1:
        return x
    if isinstance(s, (int, np.integer)):
        return jnp.roll(x, -int(s), axis=0)
    if isinstance(s, GridShift):
        # roll the worker dim as its (rows, cols) grid — the column roll
        # wraps within the row, which is what the flat circulant got wrong
        xg = x.reshape((s.rows, s.cols) + x.shape[1:])
        xg = jnp.roll(xg, (-s.dr, -s.dc), axis=(0, 1))
        return xg.reshape(x.shape)
    return jnp.take(x, jnp.asarray(offset_perm(s, K)), axis=0)


def gossip_dense(params: PyTree, W: jax.Array | np.ndarray) -> PyTree:
    """x^{(k)} <- sum_j W[k, j] x^{(j)} via a dense mixing matmul.

    Paper-faithful baseline. On a sharded worker axis XLA lowers this to an
    all-gather of the full parameter stack — the cost the optimized 'roll'
    path removes.
    """
    Wj = jnp.asarray(W)

    def mix(x):
        return jnp.einsum(
            "kj,j...->k...", Wj.astype(jnp.float32), x.astype(jnp.float32)
        ).astype(x.dtype)

    return jax.tree_util.tree_map(mix, params)


def gossip_shift(params: PyTree, topo: Topology,
                 axis_name: Optional[str] = None) -> PyTree:
    """Shift-invariant gossip — ONE implementation for both comm modes.

    mixed[k] = w_self * x[k] + sum_s w_s * x[(k + s) % K]

    With ``axis_name=None`` each shift is a roll over the leading worker
    dim (comm='stacked'; when that dim is sharded, XLA lowers each roll to
    a collective-permute touching only the true graph neighbors). With a
    mesh axis name the shift IS a ``ppermute`` (comm='axis', inside
    shard_map): ring gossip costs 2 neighbor transfers instead of a K-way
    gather, in either lowering.
    """
    if not topo.offsets:
        if topo.K == 1:
            return params
        raise ValueError(
            f"topology {topo.name!r} has no shift structure; use gossip_dense"
        )

    def mix(x):
        acc = (topo.self_weight * x.astype(jnp.float32))
        for s, w in zip(topo.offsets, topo.offset_weights):
            acc = acc + w * shift_worker(x, s, topo.K,
                                         axis_name).astype(jnp.float32)
        return acc.astype(x.dtype)

    return jax.tree_util.tree_map(mix, params)


def gossip_roll(params: PyTree, topo: Topology) -> PyTree:
    """comm='stacked' spelling of :func:`gossip_shift` (kept as the
    reference oracle the kernel/axis variants are pinned against)."""
    return gossip_shift(params, topo)


def gossip_axis(params: PyTree, topo: Topology, axis_name: str) -> PyTree:
    """comm='axis' spelling of :func:`gossip_shift`, for use inside
    ``shard_map`` with one worker per slot of ``axis_name``."""
    if topo.K == 1:
        return params
    return gossip_shift(params, topo, axis_name)


def gossip(params: PyTree, topo: Topology, cfg: DAdamConfig) -> PyTree:
    """The comm dispatch both backends' pytree paths share."""
    if cfg.comm == "axis":
        return gossip_axis(params, topo, cfg.axis_name)
    if cfg.mixing == "dense" or not topo.offsets:
        return gossip_dense(params, topo.weights)
    return gossip_shift(params, topo)


# backward-compatible name (pre-unification callers: baselines, tests)
gossip_stacked = gossip


# -------------------- straggler-tolerant (stale) gossip ---------------------


class StaleBufs(NamedTuple):
    """Double-buffered neighbor payloads for staleness-bounded gossip.

    ``bufs[i]`` holds the payload last taken from offset i's neighbor (same
    structure as the params / packed buffer); ``age[k, i]`` counts rounds
    since worker k last refreshed it. A round mixes the buffered copy while
    it is younger than the bound tau, and MUST take a fresh payload once
    ``age >= tau`` — so no mixed-in value is ever more than tau rounds old,
    and tau=0 degenerates to today's synchronous gossip bit-for-bit."""

    bufs: Tuple[Any, ...]
    age: jax.Array            # (K, deg) int32; (1, deg) inside shard_map


def _round_index(count: jax.Array, period: int) -> jax.Array:
    """0-based communication-round index at a comm step (count = p, 2p...)."""
    return jnp.maximum(count // period - 1, 0)


def _local_worker_rows(arr: jax.Array, cfg: DAdamConfig) -> jax.Array:
    """Slice a (K, ...) per-worker constant down to this worker's row when
    traced inside shard_map (comm='axis'); identity under comm='stacked'."""
    if cfg.comm != "axis":
        return arr
    k = jax.lax.axis_index(cfg.axis_name)
    return jax.lax.dynamic_slice_in_dim(arr, k, 1, axis=0)


def _arrival_mask(cfg: DAdamConfig, r: jax.Array, K: int,
                  deg: int) -> jax.Array:
    """(K, deg) bool — which neighbor payloads arrive in round r. Derived
    from the round index with a fixed seed, so every worker (and every
    shard_map slot) agrees on the same arrival pattern without
    communication, and a rerun reproduces the same straggler trace."""
    local = 1 if cfg.comm == "axis" else K
    if cfg.straggler_rate <= 0.0:
        return jnp.ones((local, deg), bool)
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.straggler_seed),
                             jnp.asarray(r, jnp.int32))
    mask = jax.random.uniform(key, (K, deg)) >= cfg.straggler_rate
    return _local_worker_rows(mask, cfg)


def init_stale(params_like: PyTree,
               topo: "Topology | TopologySchedule") -> StaleBufs:
    """Cold staleness buffers over ``topo``'s (union) offsets: zero
    payloads at COLD_AGE, forcing a fresh exchange on first use."""
    offs = comm_offsets(topo)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params_like)
    return StaleBufs(tuple(zeros for _ in offs),
                     jnp.full((topo.K, len(offs)), COLD_AGE, jnp.int32))


def gossip_shift_stale(params: PyTree, stale: StaleBufs, topo: Topology,
                       cfg: DAdamConfig, r: jax.Array
                       ) -> Tuple[PyTree, StaleBufs]:
    """Shift gossip with a staleness bound: round r mixes, per offset, the
    freshly shifted payload when it arrives (or when the buffered copy hits
    the bound tau) and the buffered <= tau-rounds-old copy otherwise. The
    local Adam half-step never waits — this is the straggler-tolerant
    overlap. With tau=0 every payload is forced fresh and the result is
    bit-for-bit :func:`gossip_shift`."""
    if not topo.offsets:
        return params, stale
    axis = cfg.axis_name if cfg.comm == "axis" else None
    tau = int(cfg.staleness)
    if tau == 0:
        # ages are non-negative, so take = arrive | (age >= 0) is
        # STATICALLY all-true and the buffered copies are never read:
        # run the literal synchronous mix (bit-for-bit gossip_shift —
        # routing payloads through buffer outputs would perturb XLA's FMA
        # fusion by 1 ulp) and pass the untouched buffers through.
        return (gossip_shift(params, topo, axis),
                StaleBufs(stale.bufs, jnp.zeros_like(stale.age)))
    arrive = _arrival_mask(cfg, r, topo.K, len(topo.offsets))
    take = arrive | (stale.age >= tau)
    new_age = jnp.where(take, 0, stale.age + 1).astype(stale.age.dtype)
    new_bufs = []
    for i, s in enumerate(topo.offsets):
        m = take[:, i]

        def pick(x, b, s=s):
            mm = m.reshape((-1,) + (1,) * (x.ndim - 1))
            return jnp.where(mm, shift_worker(x, s, topo.K, axis),
                             b.astype(x.dtype))

        new_bufs.append(jax.tree_util.tree_map(pick, params,
                                               stale.bufs[i]))

    def mix(x, *nbrs):
        acc = topo.self_weight * x.astype(jnp.float32)
        for w, nb in zip(topo.offset_weights, nbrs):
            acc = acc + w * nb.astype(jnp.float32)
        return acc.astype(x.dtype)

    mixed = jax.tree_util.tree_map(mix, params, *new_bufs)
    return mixed, StaleBufs(tuple(new_bufs), new_age)


def gossip_shift_overlap(params: PyTree, stale: StaleBufs, topo: Topology,
                         cfg: DAdamConfig) -> Tuple[PyTree, StaleBufs]:
    """Comm/compute-overlapped shift gossip: round r ISSUES this round's
    neighbor exchange (the fresh shifts) but MIXES the payloads issued at
    round r-1, held in the staleness buffers. The issued shifts have no
    data dependence on the mixed result, so XLA's async collectives +
    latency-hiding scheduler (see repro.launch.env) can run the wire
    exchange concurrently with the next p local Adam steps — a uniform
    delay-1 wire schedule, the deterministic cousin of
    :func:`gossip_shift_stale`'s bounded-staleness take.

    Cold buffers (first round, and post-:mod:`~repro.core.elastic` resize,
    marked by ``age >= COLD_AGE``) fold the fresh payload instead — the
    same forced-fresh rule the staleness bound applies at ``age >= tau``.
    """
    if not topo.offsets:
        return params, stale
    axis = cfg.axis_name if cfg.comm == "axis" else None
    cold = stale.age >= COLD_AGE
    fresh, used = [], []
    for i, s in enumerate(topo.offsets):
        c = cold[:, i]

        def issue(x, s=s):
            return shift_worker(x, s, topo.K, axis)

        def pick(f, b, c=c):
            cc = c.reshape((-1,) + (1,) * (f.ndim - 1))
            return jnp.where(cc, f, b.astype(f.dtype))

        f = jax.tree_util.tree_map(issue, params)
        fresh.append(f)
        used.append(jax.tree_util.tree_map(pick, f, stale.bufs[i]))

    def mix(x, *nbrs):
        acc = topo.self_weight * x.astype(jnp.float32)
        for w, nb in zip(topo.offset_weights, nbrs):
            acc = acc + w * nb.astype(jnp.float32)
        return acc.astype(x.dtype)

    mixed = jax.tree_util.tree_map(mix, params, *used)
    return mixed, StaleBufs(tuple(fresh), jnp.zeros_like(stale.age))


# -------------------- packed-resident gossip (pallas) ----------------------


def gossip_packed(buf: jax.Array, topo: Topology, cfg: DAdamConfig
                  ) -> jax.Array:
    """Gossip directly on the resident packed buffer — the state never
    leaves the (K, rows, LANE) layout in either comm mode.

    comm='stacked': shift-invariant graphs dispatch to the fused Pallas
    mixing kernel (one VMEM pass, no rolled intermediates); dense/non-shift
    topologies — and graphs too dense to keep every neighbor block in VMEM
    — fall back to the mixing einsum over the worker dim of the buffer.

    comm='axis' (inside shard_map, ``buf`` is this worker's (1, rows, LANE)
    shard): each offset is a ``ppermute`` of the packed row-block over the
    worker axis, accumulated in f32 — the wire carries exactly one packed
    neighbor block per graph offset."""
    from repro.kernels import ops
    from repro.kernels.gossip import MAX_FUSED_DEGREE

    if topo.K == 1:
        return buf
    if cfg.comm == "axis":
        if not topo.offsets:
            raise ValueError("comm='axis' gossip needs a shift-invariant "
                             "topology")
        acc = topo.self_weight * buf.astype(jnp.float32)
        for s, w in zip(topo.offsets, topo.offset_weights):
            acc = acc + w * shift_worker(buf, s, topo.K,
                                         cfg.axis_name).astype(jnp.float32)
        return acc.astype(buf.dtype)
    # PermShift offsets (randomized rings) have no index-map arithmetic the
    # fused kernel can express; they take the einsum against the entry's
    # weight matrix (ints and GridShifts fuse)
    fusable = all(isinstance(s, (int, np.integer)) or isinstance(s, GridShift)
                  for s in topo.offsets)
    if (cfg.mixing == "dense" or not topo.offsets or not fusable
            or len(topo.offsets) > MAX_FUSED_DEGREE):
        W = jnp.asarray(topo.weights, jnp.float32)
        return jnp.einsum("kj,jrc->krc", W,
                          buf.astype(jnp.float32)).astype(buf.dtype)
    return ops.gossip_mix(buf, topo.offsets, topo.offset_weights,
                          topo.self_weight)


def gossip_packed_stale(buf: jax.Array, stale: StaleBufs, topo: Topology,
                        cfg: DAdamConfig, r: jax.Array
                        ) -> Tuple[jax.Array, StaleBufs]:
    """Staleness-bounded gossip on the resident packed buffer: the
    payload-buffer update is elementwise over (K, rows, LANE) blocks, and
    the mix runs as the fused payload kernel (same accumulation order as
    ``gossip_mix``, so tau=0 is bit-for-bit the synchronous packed round).
    Under comm='axis' each fresh take is one ppermute of the packed block;
    a buffered take costs no wire traffic at all."""
    from repro.kernels import ops
    from repro.kernels.gossip import MAX_FUSED_DEGREE

    if not topo.offsets:
        return buf, stale
    axis = cfg.axis_name if cfg.comm == "axis" else None
    tau = int(cfg.staleness)
    if tau == 0:
        # statically always-fresh and the buffers are never read: run the
        # literal synchronous packed round (see gossip_shift_stale for why
        # this, not a masked select, is what keeps tau=0 bit-for-bit)
        return (gossip_packed(buf, topo, cfg),
                StaleBufs(stale.bufs, jnp.zeros_like(stale.age)))
    arrive = _arrival_mask(cfg, r, topo.K, len(topo.offsets))
    take = arrive | (stale.age >= tau)
    new_age = jnp.where(take, 0, stale.age + 1).astype(stale.age.dtype)
    used = []
    for i, s in enumerate(topo.offsets):
        m = take[:, i].reshape((-1, 1, 1))
        used.append(jnp.where(m, shift_worker(buf, s, topo.K, axis),
                              stale.bufs[i].astype(buf.dtype)))
    if axis is None and len(used) <= MAX_FUSED_DEGREE:
        mixed = ops.payload_mix(buf, used, topo.offset_weights,
                                topo.self_weight)
    else:
        acc = topo.self_weight * buf.astype(jnp.float32)
        for w, u in zip(topo.offset_weights, used):
            acc = acc + w * u.astype(jnp.float32)
        mixed = acc.astype(buf.dtype)
    return mixed, StaleBufs(tuple(used), new_age)


def gossip_packed_overlap(buf: jax.Array, stale: StaleBufs, topo: Topology,
                          cfg: DAdamConfig
                          ) -> Tuple[jax.Array, StaleBufs]:
    """Packed twin of :func:`gossip_shift_overlap`: issue this round's
    shifted packed blocks, mix last round's buffered ones (fresh on cold
    start / post-resize), with the same fused payload-mix kernel and f32
    accumulation order as the staleness path."""
    from repro.kernels import ops
    from repro.kernels.gossip import MAX_FUSED_DEGREE

    if not topo.offsets:
        return buf, stale
    axis = cfg.axis_name if cfg.comm == "axis" else None
    cold = stale.age >= COLD_AGE
    fresh, used = [], []
    for i, s in enumerate(topo.offsets):
        c = cold[:, i].reshape((-1, 1, 1))
        f = shift_worker(buf, s, topo.K, axis)
        fresh.append(f)
        used.append(jnp.where(c, f, stale.bufs[i].astype(buf.dtype)))
    if axis is None and len(used) <= MAX_FUSED_DEGREE:
        mixed = ops.payload_mix(buf, used, topo.offset_weights,
                                topo.self_weight)
    else:
        acc = topo.self_weight * buf.astype(jnp.float32)
        for w, u in zip(topo.offset_weights, used):
            acc = acc + w * u.astype(jnp.float32)
        mixed = acc.astype(buf.dtype)
    return mixed, StaleBufs(tuple(fresh), jnp.zeros_like(stale.age))


# --------------------- round dispatch (schedule-aware) ----------------------


def _gossip_round(params: PyTree, stale: Optional[StaleBufs],
                  topo: "Topology | TopologySchedule", cfg: DAdamConfig,
                  r: jax.Array) -> Tuple[PyTree, Optional[StaleBufs]]:
    """One communication round on the pytree path: schedule entries switch
    on the (traced) round index — each branch closes over its own STATIC
    offsets/weights, so a whole schedule still compiles to one step."""
    def once(op, topo_r):
        p, st = op
        if st is None:
            return gossip(p, topo_r, cfg), None
        if cfg.overlap:
            return gossip_shift_overlap(p, st, topo_r, cfg)
        return gossip_shift_stale(p, st, topo_r, cfg, r)

    if isinstance(topo, TopologySchedule):
        # per-edge payload buffers need the SAME offset tuple every round
        # (union views); without live buffers — no staleness/overlap, or
        # tau=0 where they are never read — each round gossips its entry
        use_union = stale is not None and (
            int(cfg.staleness or 0) > 0 or cfg.overlap)
        views = topo.union_views() if use_union else topo.entries
        if len(views) == 1:
            return once((params, stale), views[0])
        return jax.lax.switch(
            r % len(views),
            [(lambda op, v=v: once(op, v)) for v in views],
            (params, stale))
    return once((params, stale), topo)


def _gossip_packed_round(buf: jax.Array, stale: Optional[StaleBufs],
                         topo: "Topology | TopologySchedule",
                         cfg: DAdamConfig, r: jax.Array
                         ) -> Tuple[jax.Array, Optional[StaleBufs]]:
    """Packed twin of :func:`_gossip_round`."""
    def once(op, topo_r):
        b, st = op
        if st is None:
            return gossip_packed(b, topo_r, cfg), None
        if cfg.overlap:
            return gossip_packed_overlap(b, st, topo_r, cfg)
        return gossip_packed_stale(b, st, topo_r, cfg, r)

    if isinstance(topo, TopologySchedule):
        use_union = stale is not None and (
            int(cfg.staleness or 0) > 0 or cfg.overlap)
        views = topo.union_views() if use_union else topo.entries
        if len(views) == 1:
            return once((buf, stale), views[0])
        return jax.lax.switch(
            r % len(views),
            [(lambda op, v=v: once(op, v)) for v in views],
            (buf, stale))
    return once((buf, stale), topo)


# ------------------------------ state + step -------------------------------


class DAdamState(NamedTuple):
    params: PyTree          # stacked (K, ...) in stacked mode
    moments: AdamMoments
    # transient straggler-tolerant payload buffers (cfg.staleness != None);
    # stripped from checkpoints and rebuilt cold on restore
    stale: Optional[StaleBufs] = None


@jax.tree_util.register_pytree_node_class
class PackedDAdamState:
    """Resident packed D-Adam state for ``backend='pallas'``.

    The stacked, leaf-aligned ``(K, rows, 128)`` buffer is the *persistent*
    representation: params (``buf``) and both moments (``m``, ``v``) live
    packed across steps, so the fused-Adam and gossip kernels consume and
    produce it directly — zero per-step pack/unpack. Packing happens once
    in :func:`init`; unpacked pytree views materialize only at boundaries
    (``.params`` / ``.moments`` for eval, logging and checkpointing).

    The :class:`~repro.kernels.pack.PackSpec` pair rides along as *static*
    pytree aux_data, so the state jits/scans/conds like a NamedTuple while
    the specs stay Python-side."""

    __slots__ = ("buf", "m", "v", "count", "spec", "spec_m", "stale")

    def __init__(self, buf, m, v, count, spec, spec_m, stale=None):
        self.buf, self.m, self.v, self.count = buf, m, v, count
        self.spec, self.spec_m = spec, spec_m
        self.stale = stale

    def tree_flatten(self):
        return ((self.buf, self.m, self.v, self.count, self.stale),
                (self.spec, self.spec_m))

    @classmethod
    def tree_unflatten(cls, aux, children):
        buf, m, v, count, stale = children
        return cls(buf, m, v, count, *aux, stale)

    def with_stale(self, stale) -> "PackedDAdamState":
        return PackedDAdamState(self.buf, self.m, self.v, self.count,
                                self.spec, self.spec_m, stale)

    # ------- unpacked views: boundary use only (eval/log/checkpoint) -------

    @property
    def params(self) -> PyTree:
        return packing.unpack(self.buf, self.spec)

    @property
    def moments(self) -> AdamMoments:
        return AdamMoments(packing.unpack(self.m, self.spec_m),
                           packing.unpack(self.v, self.spec_m), self.count)

    def unpacked(self) -> DAdamState:
        """Portable (backend-agnostic) NamedTuple state — the checkpoint
        wire format, identical leaf-for-leaf to a reference-backend state."""
        return DAdamState(self.params, self.moments)

    @classmethod
    def from_unpacked(cls, state: DAdamState, *,
                      row_shards: int = 1) -> "PackedDAdamState":
        """``row_shards=M`` packs into the 2D-mesh row-sharded layout
        (each leaf split across M shard blocks; see kernels/pack.py)."""
        spec = packing.make_spec(state.params, stacked=True,
                                 block_rows=BLOCK_ROWS, leaf_align=True,
                                 row_shards=row_shards)
        spec_m = packing.make_spec(state.moments.m, stacked=True,
                                   block_rows=BLOCK_ROWS, leaf_align=True,
                                   row_shards=row_shards)
        return cls(packing.pack(state.params, spec),
                   packing.pack(state.moments.m, spec_m),
                   packing.pack(state.moments.v, spec_m),
                   state.moments.count, spec, spec_m)


def grads_buffer(grads: Any, spec: packing.PackSpec, dtype: Any,
                 like_shape: Optional[Tuple[int, ...]] = None) -> jax.Array:
    """Admit gradients in either form at the step boundary: an already
    packed ``(K, rows, 128)`` buffer passes through untouched (the
    steady-state path — differentiate the loss through ``packing.unpack``
    and AD's transpose delivers grads packed for free); a pytree —
    including a bare array for single-leaf parameter trees — is packed
    once here as a convenience.

    ``like_shape`` is the resident parameter buffer's shape; under
    comm='axis' it is the per-shard ``(K_local, rows, 128)`` shape inside
    shard_map (the spec keeps the *global* K), so buffer grads are checked
    against it rather than against ``spec.buf_shape()``."""
    want = tuple(like_shape) if like_shape is not None else spec.buf_shape()
    if isinstance(grads, jax.Array):
        if tuple(grads.shape) == want:
            return grads.astype(dtype)
        if len(spec.shapes) == 1 and tuple(grads.shape) == spec.shapes[0]:
            # bare-array gradient of a single-leaf parameter tree
            return packing.pack(grads, spec, dtype=dtype)
        raise ValueError(
            f"packed grads shape {tuple(grads.shape)} != resident "
            f"buffer {want}")
    return packing.pack(grads, spec, dtype=dtype)


def init(params_stacked: PyTree, cfg: DAdamConfig,
         topo: "Topology | TopologySchedule | None" = None
         ) -> "DAdamState | PackedDAdamState":
    cfg.validate()
    needs_bufs = cfg.staleness is not None or cfg.overlap
    if needs_bufs and topo is None:
        raise ValueError(
            "cfg.staleness/cfg.overlap buffer one payload per topology "
            "offset; init needs the topology (pass topo=, as "
            "make_optimizer does)")
    state = DAdamState(params_stacked, init_moments(params_stacked, cfg))
    if cfg.backend == "pallas":
        packed = PackedDAdamState.from_unpacked(
            state, row_shards=cfg.model_parallel)
        if needs_bufs:
            packed = packed.with_stale(init_stale(packed.buf, topo))
        return packed
    if needs_bufs:
        state = state._replace(stale=init_stale(params_stacked, topo))
    return state


def _fused_local_packed(state: PackedDAdamState, grads: Any,
                        cfg: DAdamConfig
                        ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                   jax.Array]:
    """Alg. 1 lines 3-6 on resident buffers: one fused kernel pass, no
    packing. Returns (params_buf, m_buf, v_buf, count)."""
    from repro.kernels import ops

    gbuf = grads_buffer(grads, state.spec, state.buf.dtype,
                        like_shape=state.buf.shape)
    po, mo, vo = ops.fused_adam(
        state.buf, gbuf, state.m, state.v,
        eta=cfg.eta, beta1=cfg.beta1, beta2=cfg.beta2, tau=cfg.tau,
        weight_decay=cfg.weight_decay)
    return po, mo, vo, state.count + 1


def _gossip_adam_eligible(topo: "Topology | TopologySchedule",
                          cfg: DAdamConfig) -> bool:
    """True when the synchronous comm='stacked' step can run as the
    single-pass ``gossip_adam_mix`` kernel: a static shift-invariant
    topology whose fused degree fits VMEM, with no payload buffers in
    flight (staleness/overlap route the mix through StaleBufs)."""
    from repro.kernels.gossip import MAX_GOSSIP_ADAM_DEGREE

    if isinstance(topo, TopologySchedule):
        return False
    if cfg.comm != "stacked" or cfg.mixing == "dense":
        return False
    if cfg.staleness is not None or cfg.overlap:
        return False
    if topo.K == 1 or not topo.offsets:
        return False
    if len(topo.offsets) > MAX_GOSSIP_ADAM_DEGREE:
        return False
    return all(isinstance(s, (int, np.integer, GridShift))
               for s in topo.offsets)


def _step_packed_fused(state: PackedDAdamState, grads: Any,
                       topo: Topology, cfg: DAdamConfig
                       ) -> PackedDAdamState:
    """Comm-step fast path: Adam half-step AND gossip mix in one VMEM
    pass over the resident buffers (``kernels.gossip.gossip_adam_mix``) —
    the half-stepped parameter stack never round-trips HBM. Bit-for-bit
    the two-pass (fused_adam → gossip_mix) sequence; non-comm steps under
    period > 1 run the plain fused_adam branch of the same cond."""
    from repro.kernels import ops

    gbuf = grads_buffer(grads, state.spec, state.buf.dtype,
                        like_shape=state.buf.shape)
    count = state.count + 1
    kw = dict(eta=cfg.eta, beta1=cfg.beta1, beta2=cfg.beta2, tau=cfg.tau,
              weight_decay=cfg.weight_decay)

    def fused(op):
        p, m, v = op
        return ops.gossip_adam_mix(p, gbuf, m, v, topo.offsets,
                                   topo.offset_weights, topo.self_weight,
                                   **kw)

    def plain(op):
        p, m, v = op
        return ops.fused_adam(p, gbuf, m, v, **kw)

    op = (state.buf, state.m, state.v)
    if cfg.period == 1:
        po, mo, vo = fused(op)
    else:
        do_comm = (count % cfg.period) == 0
        po, mo, vo = jax.lax.cond(do_comm, fused, plain, op)
    return PackedDAdamState(po, mo, vo, count, state.spec, state.spec_m,
                            state.stale)


def _step_packed(state: PackedDAdamState, grads: Any,
                 topo: "Topology | TopologySchedule",
                 cfg: DAdamConfig) -> PackedDAdamState:
    if _gossip_adam_eligible(topo, cfg):
        return _step_packed_fused(state, grads, topo, cfg)
    po, mo, vo, count = _fused_local_packed(state, grads, cfg)
    r = _round_index(count, cfg.period)

    def comm(op):
        return _gossip_packed_round(op[0], op[1], topo, cfg, r)

    if cfg.period == 1:
        buf, stale = comm((po, state.stale))
    else:
        do_comm = (count % cfg.period) == 0
        buf, stale = jax.lax.cond(do_comm, comm, lambda op: op,
                                  (po, state.stale))
    return PackedDAdamState(buf, mo, vo, count, state.spec, state.spec_m,
                            stale)


def step(
    state: "DAdamState | PackedDAdamState",
    grads: PyTree,
    topo: Topology,
    cfg: DAdamConfig,
) -> "DAdamState | PackedDAdamState":
    """One iteration of Alg. 1 with the communication-skip condition
    evaluated in-graph (lax.cond keeps a single jitted step). Under
    comm='axis' this function is traced inside shard_map (one worker per
    mesh slot) — the code is identical; only the worker shifts lower
    differently.

    Packed-resident states (pallas backend) never leave the (K, rows, 128)
    layout: fused-Adam and the gossip kernel consume the buffers directly.
    ``grads`` may be a congruent pytree (packed once at this boundary) or
    an already packed buffer (zero pack/unpack)."""
    if isinstance(state, PackedDAdamState):
        return _step_packed(state, grads, topo, cfg)
    half, mom = local_update(state.params, grads, state.moments, cfg)
    r = _round_index(mom.count, cfg.period)

    def comm(op):
        return _gossip_round(op[0], op[1], topo, cfg, r)

    if cfg.period == 1:
        new_params, stale = comm((half, state.stale))
        return DAdamState(new_params, mom, stale)
    do_comm = (mom.count % cfg.period) == 0
    new_params, stale = jax.lax.cond(do_comm, comm, lambda op: op,
                                     (half, state.stale))
    return DAdamState(new_params, mom, stale)


def round_step(
    state: "DAdamState | PackedDAdamState",
    grad_fn: Callable[[PyTree, Any], PyTree],
    batches: Any,  # pytree with leading dim p (one microbatch per local step)
    topo: Topology,
    cfg: DAdamConfig,
) -> "DAdamState | PackedDAdamState":
    """One *communication round* = p local steps (lax.scan) + one gossip.

    This is the unit the launcher lowers for the dry-run: the compiled HLO
    contains exactly one gossip exchange per p local Adam steps, so the
    roofline's collective bytes reflect the paper's skipping schedule.

    For packed-resident states ``grad_fn`` receives the raw (K, rows, 128)
    parameter buffer and may return grads as a congruent buffer (the
    zero-pack steady state: differentiate the loss through
    ``packing.unpack``) or as a pytree (packed at the boundary).
    """
    if isinstance(state, PackedDAdamState):
        def body_packed(carry: PackedDAdamState, batch):
            grads = grad_fn(carry.buf, batch)
            po, mo, vo, count = _fused_local_packed(carry, grads, cfg)
            return PackedDAdamState(po, mo, vo, count, carry.spec,
                                    carry.spec_m, carry.stale), ()

        inner, _ = jax.lax.scan(body_packed, state, batches)
        buf, stale = _gossip_packed_round(
            inner.buf, inner.stale, topo, cfg,
            _round_index(inner.count, cfg.period))
        return PackedDAdamState(buf, inner.m, inner.v, inner.count,
                                state.spec, state.spec_m, stale)

    def body(carry: DAdamState, batch):
        grads = grad_fn(carry.params, batch)
        half, mom = local_update(carry.params, grads, carry.moments, cfg)
        return DAdamState(half, mom, carry.stale), ()

    inner, _ = jax.lax.scan(body, state, batches)
    new_params, stale = _gossip_round(
        inner.params, inner.stale, topo, cfg,
        _round_index(inner.moments.count, cfg.period))
    return DAdamState(new_params, inner.moments, stale)


def consensus_error(params_stacked: PyTree) -> jax.Array:
    """(1/K) sum_k ||x_k - x_bar||^2 — the quantity Lemma 1 bounds."""
    def per_leaf(x):
        mean = jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True)
        return jnp.sum((x.astype(jnp.float32) - mean) ** 2) / x.shape[0]
    leaves = jax.tree_util.tree_leaves(
        jax.tree_util.tree_map(per_leaf, params_stacked))
    return sum(leaves)


def mean_params(params_stacked: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda x: jnp.mean(x.astype(jnp.float32), axis=0).astype(x.dtype),
        params_stacked)
