"""Elastic worker membership: resize a live optimizer state to a new K.

Serverless workers join and leave mid-run. ``resize_state`` takes the
current optimizer state (either backend) and a freshly built optimizer
for the new world size / topology, and produces a state for the new
optimizer that carries the surviving workers' parameters and Adam
moments across the membership change:

- **shrink** (workers leave): the trailing worker slots are dropped —
  their consensus mass is already mixed into the survivors by prior
  gossip rounds.
- **grow** (workers join), ``strategy="clone"``: new slots bootstrap
  from existing workers round-robin (``slot k -> slot k % K_old``), so
  a joiner starts at a live model instead of cold noise.
- **grow**, ``strategy="mean"``: new slots start at the current
  consensus mean — the natural warm start when joiners should not
  inherit any single worker's drift.

Everything topology-shaped is rebuilt for the NEW topology: CD-Adam
hats restart at zero (the CHOCO convention — hats re-warm within a few
compressed rounds) and straggler-comm buffers restart COLD via
``checkpoint.place_like``, which also repacks into the new optimizer's
resident layout and placement. The Adam step ``count`` is preserved so
the bias-correction schedule continues rather than restarting.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.checkpoint import io as ckpt_io
from repro.core import dadam

PyTree = Any

_STRATEGIES = ("clone", "mean")


def _resize_leaf(x: jax.Array, K_new: int, strategy: str) -> jax.Array:
    K_old = int(x.shape[0])
    if K_new == K_old:
        return x
    if K_new < K_old:
        return x[:K_new]
    if strategy == "clone":
        idx = jnp.arange(K_old, K_new) % K_old
        extra = x[idx]
    else:  # "mean"
        mean = jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True)
        extra = jnp.broadcast_to(
            mean, (K_new - K_old,) + x.shape[1:]).astype(x.dtype)
    return jnp.concatenate([x, extra], axis=0)


def _resize_tree(tree: PyTree, K_new: int, strategy: str) -> PyTree:
    return jax.tree_util.tree_map(
        lambda x: _resize_leaf(x, K_new, strategy), tree)


def resize_state(state: Any, opt_new: Any, *,
                 strategy: str = "clone") -> Any:
    """Carry ``state`` (D-Adam / CD-Adam, either backend) over to
    ``opt_new``'s world size, topology and backend.

    ``opt_new`` is a ``DecentralizedOptimizer`` built for the NEW
    membership (``make_optimizer(..., n_workers=K_new, ...)``). Params
    and Adam moments are resized along the worker axis per ``strategy``;
    the step count survives; hats and straggler buffers restart.
    """
    if strategy not in _STRATEGIES:
        raise ValueError(f"strategy must be one of {_STRATEGIES}, "
                         f"got {strategy!r}")
    K_new = int(opt_new.topo.K)
    portable = ckpt_io._to_portable(state)
    K_old = int(jax.tree_util.tree_leaves(portable.params)[0].shape[0])
    if K_old < 1 or K_new < 1:
        raise ValueError("world sizes must be >= 1")

    params = _resize_tree(portable.params, K_new, strategy)
    m = _resize_tree(portable.moments.m, K_new, strategy)
    v = _resize_tree(portable.moments.v, K_new, strategy)

    # A fresh init for the new optimizer supplies every topology-shaped
    # piece (zero hats sized to the new union edge set, packed layout,
    # cold comm buffers) — we graft the surviving params/moments into
    # its portable form and let place_like adapt backend + placement.
    like = opt_new.init(params)
    like_portable = ckpt_io._to_portable(like)
    moments = dadam.AdamMoments(m=m, v=v, count=portable.moments.count)
    portable_new = like_portable._replace(params=params, moments=moments)
    return ckpt_io.place_like(portable_new, like)
